# iGniter reproduction — build/verify entry points.
#
#   make verify       tier-1 gate: release build + full Rust test suite,
#                     bench compilation, lint (fmt + clippy), the Python
#                     Layer-1 tests, and the CI-quick sweep + bench gate
#                     (verify mirrors .github/workflows/ci.yml exactly)
#   make sweep-quick  the CI sweep invocation + baseline gate, standalone
#   make sweep-full-smoke  the CI full-space smoke lane (8 full-distribution
#                     scenarios through the indexed placement engine)
#   make sweep-chaos  the CI chaos lane: seeded fault injection
#                     (deaths/stragglers/hangs) served through the
#                     resilience stack, gated once a chaos baseline exists
#   make bless-bench-chaos  bless BENCH_baseline_chaos.json from a local run
#   make sweep-mig    the CI MIG lane: discrete-slice A100/H100 fleets
#                     through the fragmentation-aware packer, gated once
#                     a MIG baseline exists
#   make bless-bench-mig  bless BENCH_baseline_mig.json from a local run
#   make sweep-longtail  the CI long-tail lane: 200-1000 mostly-idle
#                     tenants through the idle-aware monitor fast path,
#                     gated once a long-tail baseline exists
#   make bless-bench-longtail  bless BENCH_baseline_longtail.json from a
#                     local run
#   make bless-golden regenerate + overwrite the dynamic-summary golden
#   make bless-bench  re-bless BENCH_baseline.json from a fresh local run
#   make artifacts    AOT-lower the model zoo to artifacts/ (needs jax)
#   make clean        drop build + result artifacts

CARGO ?= cargo
PYTHON ?= python

.PHONY: verify build test test-invariants bench-build fmt-check clippy pytest \
        sweep-quick sweep-full-smoke sweep-chaos sweep-mig sweep-longtail \
        bless-golden bless-bench bless-bench-chaos bless-bench-mig \
        bless-bench-longtail artifacts clean

# `test` already runs every integration target (serving invariants,
# determinism, sweep determinism, provisioner properties); `bench-build`
# compiles every bench target (`cargo bench --no-run`), including the
# sim-core throughput bench in benches/simulator.rs; `sweep-quick` runs
# the same sweep + regression gate as the CI bench-sweep job.
verify: build test bench-build fmt-check clippy pytest sweep-quick sweep-chaos sweep-mig sweep-longtail
	@echo "verify: OK"

# Standalone pass over just the serving/provisioning invariant +
# determinism suites (subset of `make test`; handy while iterating on
# the coordinator/provisioner/sweep).
test-invariants:
	$(CARGO) test -q --test serving_invariants --test determinism \
		--test provisioner_invariants --test sweep_determinism

fmt-check:
	$(CARGO) fmt --check

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

bench-build:
	$(CARGO) bench --no-run

# Exactly the CI bench-sweep job: quick sweep -> BENCH_sweep.json ->
# gate against the committed baseline (>20% regression fails; a
# provisional baseline gates at 5x until re-blessed).
sweep-quick: build
	$(CARGO) run --release -- sweep --scenarios 200 --seeds 2 --parallel 8 \
		--out BENCH_sweep.json
	$(PYTHON) scripts/check_bench_regression.py BENCH_baseline.json BENCH_sweep.json

# The CI full-space smoke lane: a few full-distribution scenarios (up to
# 1000 workloads) exercising the indexed placement engine end-to-end.
# Ungated (no full-space baseline); the job-level timeout in CI is the
# budget it must fit.
sweep-full-smoke: build
	$(CARGO) run --release -- sweep --full --scenarios 8 --seeds 1 --parallel 8 \
		--out BENCH_full_smoke.json

# The CI chaos lane: seeded fault plans (device deaths, stragglers,
# replica hangs) served through breakers/shed/hedge + failover respec.
# The binary enforces the structural bars (drops explicit, bounded);
# the run-over-run recovery/drop gates engage once a chaos baseline is
# blessed (bless-bench-chaos, or commit a green CI run's artifact).
sweep-chaos: build
	$(CARGO) run --release -- sweep --faults --scenarios 48 --seeds 2 --parallel 8 \
		--out BENCH_chaos.json
	@if [ -f BENCH_baseline_chaos.json ]; then \
		$(PYTHON) scripts/check_bench_regression.py BENCH_baseline_chaos.json BENCH_chaos.json; \
	else \
		echo "chaos lane ungated — run 'make bless-bench-chaos' and commit BENCH_baseline_chaos.json"; \
	fi

# The CI MIG lane: the quick-scale sweep over discrete-slice MIG fleets
# (A100/H100; legal 1g/2g/3g/4g/7g profiles of the 7-GPC envelope) with
# the fragmentation-aware packer head-to-head against FFD++ and the
# iGniter scorer.  The binary enforces the structural bar (packer never
# loses to FFD); the run-over-run stranded-capacity / cost-ratio gates
# engage once a MIG baseline is blessed (bless-bench-mig, or commit a
# green CI run's artifact).
sweep-mig: build
	$(CARGO) run --release -- sweep --fleet mig --scenarios 100 --seeds 2 --parallel 8 \
		--out BENCH_mig.json
	@if [ -f BENCH_baseline_mig.json ]; then \
		$(PYTHON) scripts/check_bench_regression.py BENCH_baseline_mig.json BENCH_mig.json; \
	else \
		echo "MIG lane ungated — run 'make bless-bench-mig' and commit BENCH_baseline_mig.json"; \
	fi

# The CI long-tail lane: the 200-1000-tenant mostly-idle scenario space
# (~90% of tenants at 0.1-2 rps, spiky/diurnal traces) through the
# idle-aware monitor fast path.  The binary enforces the structural bar
# (mean near-idle tenant fraction >= 0.75); the run-over-run throughput
# gate (`wall.sim_throughput_rps` is the headline) engages once a
# long-tail baseline is blessed (bless-bench-longtail, or commit a
# green CI run's artifact).
sweep-longtail: build
	$(CARGO) run --release -- sweep --longtail --scenarios 12 --seeds 2 --parallel 8 \
		--out BENCH_longtail.json
	@if [ -f BENCH_baseline_longtail.json ]; then \
		$(PYTHON) scripts/check_bench_regression.py BENCH_baseline_longtail.json BENCH_longtail.json; \
	else \
		echo "longtail lane ungated — run 'make bless-bench-longtail' and commit BENCH_baseline_longtail.json"; \
	fi

# Regenerate the dynamic-summary golden and the pinned sweep-fingerprint
# digest from this machine's run, overwriting the checked-in files
# (commit the result; see rust/tests/golden/README.md for when
# re-blessing is legitimate).
bless-golden:
	IGNITER_BLESS=1 $(CARGO) test -q golden_summary_regression
	rm -f rust/tests/golden/sweep_fingerprint.txt
	$(CARGO) test -q --test sweep_determinism quick_sweep_fingerprint_pinned

# Promote a fresh sweep run to the committed bench baseline (drops the
# provisional marker by replacing the file with measured numbers).
bless-bench: build
	$(CARGO) run --release -- sweep --scenarios 200 --seeds 2 --parallel 8 \
		--out BENCH_baseline.json
	@echo "BENCH_baseline.json re-blessed from this run — review and commit it"

# Promote a fresh chaos sweep to the chaos baseline (same shape as the
# sweep-chaos lane so the gate's config check matches).
bless-bench-chaos: build
	$(CARGO) run --release -- sweep --faults --scenarios 48 --seeds 2 --parallel 8 \
		--out BENCH_baseline_chaos.json
	@echo "BENCH_baseline_chaos.json blessed from this run — review and commit it"

# Promote a fresh MIG sweep to the MIG baseline (same shape as the
# sweep-mig lane so the gate's config check matches).
bless-bench-mig: build
	$(CARGO) run --release -- sweep --fleet mig --scenarios 100 --seeds 2 --parallel 8 \
		--out BENCH_baseline_mig.json
	@echo "BENCH_baseline_mig.json blessed from this run — review and commit it"

# Promote a fresh long-tail sweep to the long-tail baseline (same shape
# as the sweep-longtail lane so the gate's config check matches).
bless-bench-longtail: build
	$(CARGO) run --release -- sweep --longtail --scenarios 12 --seeds 2 --parallel 8 \
		--out BENCH_baseline_longtail.json
	@echo "BENCH_baseline_longtail.json blessed from this run — review and commit it"

pytest:
	$(PYTHON) -m pytest python/tests -q

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts

clean:
	$(CARGO) clean
	rm -rf results BENCH_sweep.json BENCH_full_smoke.json BENCH_chaos.json BENCH_mig.json BENCH_longtail.json
