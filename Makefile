# iGniter reproduction — build/verify entry points.
#
#   make verify      tier-1 gate: release build + full Rust test suite,
#                    bench compilation, lint (fmt + clippy), and the
#                    Python Layer-1 tests
#   make artifacts   AOT-lower the model zoo to artifacts/ (needs jax)
#   make clean       drop build + result artifacts

CARGO ?= cargo
PYTHON ?= python

.PHONY: verify build test test-invariants bench-build fmt-check clippy pytest artifacts clean

# `test` already runs every integration target (serving invariants,
# determinism, provisioner properties — the migration/autoscale sweep);
# `bench-build` compiles the autoscale closed-loop bench.
verify: build test bench-build fmt-check clippy pytest
	@echo "verify: OK"

# Standalone pass over just the serving/provisioning invariant +
# determinism suites (subset of `make test`; handy while iterating on
# the coordinator/provisioner).
test-invariants:
	$(CARGO) test -q --test serving_invariants --test determinism --test provisioner_invariants

fmt-check:
	$(CARGO) fmt --check

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

bench-build:
	$(CARGO) bench --no-run

pytest:
	$(PYTHON) -m pytest python/tests -q

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts

clean:
	$(CARGO) clean
	rm -rf results
