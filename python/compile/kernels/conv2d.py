"""Layer-1: 2-D convolution lowered to the Pallas matmul kernel via im2col.

The paper's workloads (AlexNet / ResNet-50 / VGG-19 / SSD) are convolution
dominated; TensorRT lowers their convolutions to implicit-GEMM CUDA kernels.
The TPU-idiomatic equivalent is explicit im2col (patch extraction is a cheap
gather that XLA fuses) feeding the MXU-shaped tiled matmul in ``matmul.py``,
so the hot FLOPs stay inside the Pallas kernel.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .matmul import matmul


def conv2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bias: Optional[jnp.ndarray] = None,
    *,
    stride: int = 1,
    padding: int = 0,
    activation: Optional[str] = None,
    bm: int = 2048,
    bn: int = 128,
    bk: int = 2048,
) -> jnp.ndarray:
    """NHWC conv: x (B, H, W, Cin), w (KH, KW, Cin, Cout) -> (B, HO, WO, Cout).

    Patch extraction (im2col) reshapes the problem to a
    ``(B*HO*WO, KH*KW*Cin) @ (KH*KW*Cin, Cout)`` matmul executed by the
    Pallas kernel, with bias + activation fused into its epilogue.

    Tile defaults (see EXPERIMENTS.md §Perf): conv matmuls are tall and
    skinny (M = B*HO*WO up to ~32k, K <= ~1k, N <= 128), so the M tile is
    large (512) and K/N are taken whole.  This keeps the Pallas grid — and
    hence pipeline depth — small: per-step VMEM is
    ``512*K*4 + K*128*4 + 512*128*4`` ≈ 2.3 MB at K = 864, comfortably
    inside a 16 MB VMEM with double buffering, while the deep-grid
    alternative (128³ tiles) costs ~40x more wall time under the
    interpret-mode while-loop lowering.
    """
    if x.ndim != 4 or w.ndim != 4:
        raise ValueError(f"conv2d expects NHWC x and KHWIO w, got {x.shape}, {w.shape}")
    b, h, wid, cin = x.shape
    kh, kw, cin2, cout = w.shape
    if cin != cin2:
        raise ValueError(f"channel mismatch: x has {cin}, w has {cin2}")

    ho = (h + 2 * padding - kh) // stride + 1
    wo = (wid + 2 * padding - kw) // stride + 1
    if ho <= 0 or wo <= 0:
        raise ValueError(f"empty output for x={x.shape} w={w.shape} "
                         f"stride={stride} padding={padding}")

    # im2col: (B, HO, WO, KH*KW*Cin).  conv_general_dilated_patches returns
    # feature dimension ordered as (Cin, KH, KW) for NHWC inputs, so the
    # weight matrix below is transposed to match.
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=((padding, padding), (padding, padding)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    cols = patches.reshape(b * ho * wo, cin * kh * kw)
    wmat = jnp.transpose(w, (2, 0, 1, 3)).reshape(cin * kh * kw, cout)

    out = matmul(cols, wmat, bias, activation=activation, bm=bm, bn=bn, bk=bk)
    return out.reshape(b, ho, wo, cout)


def conv_output_shape(
    x_shape: Tuple[int, int, int, int],
    w_shape: Tuple[int, int, int, int],
    stride: int,
    padding: int,
) -> Tuple[int, int, int, int]:
    """Static shape helper mirrored by the Rust model zoo."""
    b, h, w, _ = x_shape
    kh, kw, _, cout = w_shape
    ho = (h + 2 * padding - kh) // stride + 1
    wo = (w + 2 * padding - kw) // stride + 1
    return (b, ho, wo, cout)
