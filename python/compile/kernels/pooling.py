"""Layer-1: Pallas max-pooling kernel (window = stride, the only case the
model zoo needs) plus a global-average-pool helper.

Pooling is bandwidth bound, so the BlockSpec keeps whole (batch-row, W, C)
stripes resident and reduces in-register; each grid step handles one batch
element's output row stripe.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _maxpool_kernel(x_ref, o_ref, *, k: int):
    """x block: (1, H, W, C) -> o block: (1, H/k, W/k, C)."""
    x = x_ref[...]
    _, h, w, c = x.shape
    # (1, H/k, k, W/k, k, C): reduce the two window axes.
    xr = x.reshape(1, h // k, k, w // k, k, c)
    o_ref[...] = jnp.max(xr, axis=(2, 4))


@functools.partial(jax.jit, static_argnames=("k",))
def maxpool2d(x: jnp.ndarray, k: int = 2) -> jnp.ndarray:
    """NHWC max pool with square window ``k`` and stride ``k``.

    Requires H and W divisible by ``k`` (the model zoo pads upstream).
    Grid = (B,): one whole image per step — pooling is bandwidth bound and
    the per-image VMEM stripe is tiny (<= H*W*C*4 ≈ 100 KB for the zoo),
    so a shallow grid wins over per-row stripes (see EXPERIMENTS.md §Perf:
    the (B, H/k) grid cost ~6x more wall time under the interpret-mode
    while-loop lowering).
    """
    b, h, w, c = x.shape
    if h % k or w % k:
        raise ValueError(f"maxpool2d: H, W must divide k={k}, got {x.shape}")
    ho, wo = h // k, w // k

    return pl.pallas_call(
        functools.partial(_maxpool_kernel, k=k),
        grid=(b,),
        in_specs=[pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, ho, wo, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, ho, wo, c), x.dtype),
        interpret=True,
    )(x)


def global_avgpool(x: jnp.ndarray) -> jnp.ndarray:
    """NHWC -> (B, C) mean over spatial dims (pure jnp; XLA fuses it)."""
    return jnp.mean(x, axis=(1, 2))
