"""Layer-1 Pallas kernels: tiled matmul with optional fused bias + activation.

This is the compute hot-spot of every network in the zoo (convolutions are
lowered to matmul via im2col in ``conv2d.py``, dense layers call it directly).

Hardware adaptation (the paper targeted CUDA/TensorRT): instead of porting
threadblock tiling, the kernel is tiled for a VMEM-style scratchpad —
``BlockSpec`` expresses the HBM<->VMEM schedule, the MXU-friendly inner tile
is an ``(bm, bk) @ (bk, bn)`` contraction accumulated across the K grid
dimension (the Pallas pipeline emitter overlaps the HBM loads of grid step
k+1 with the compute of step k, which is the double-buffering the paper's
CUDA kernels do by hand).

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret-mode lowers to plain HLO so the Rust
runtime can run the resulting module anywhere.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-friendly tile sizes.  128x128 matches the MXU systolic array;
# for the tiny models in this repo the wrapper clamps tiles to the (padded)
# problem size so the grid never degenerates.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _activation(x: jnp.ndarray, kind: Optional[str]) -> jnp.ndarray:
    if kind is None or kind == "none":
        return x
    if kind == "relu":
        return jnp.maximum(x, 0.0)
    if kind == "sigmoid":
        return jax.nn.sigmoid(x)
    raise ValueError(f"unknown activation: {kind}")


def _matmul_kernel(x_ref, y_ref, o_ref, *, nsteps_k: int,
                   activation: Optional[str]):
    """Grid = (M/bm, N/bn, K/bk); K is the innermost (sequential) axis.

    The f32 output tile doubles as the accumulator: zeroed at k == 0,
    accumulated across K steps, activated at the last step.  This keeps the
    kernel portable across interpret-mode backends (no scratch semantics to
    worry about) at the cost of the activation being a separate pass over
    the tile — negligible next to the MXU contraction.
    """

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    if activation not in (None, "none"):
        @pl.when(pl.program_id(2) == nsteps_k - 1)
        def _act():
            o_ref[...] = _activation(o_ref[...], activation)


def _matmul_bias_kernel(x_ref, y_ref, b_ref, o_ref, *, nsteps_k: int,
                        activation: Optional[str]):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nsteps_k - 1)
    def _finish():
        out = o_ref[...] + b_ref[...].astype(jnp.float32)[None, :]
        o_ref[...] = _activation(out, activation)


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("activation", "bm", "bn", "bk"))
def matmul(
    x: jnp.ndarray,
    y: jnp.ndarray,
    bias: Optional[jnp.ndarray] = None,
    *,
    activation: Optional[str] = None,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
) -> jnp.ndarray:
    """``activation(x @ y + bias)`` as a tiled Pallas kernel.

    ``x``: (M, K), ``y``: (K, N), ``bias``: (N,) or None.  Inputs are padded
    up to tile multiples (zero padding is exact for matmul + bias +
    relu/sigmoid on the rows/cols that survive the final slice) and the
    result is sliced back to (M, N).  Output dtype is float32.
    """
    if x.ndim != 2 or y.ndim != 2:
        raise ValueError(f"matmul expects rank-2 operands, got {x.shape} @ {y.shape}")
    m, k = x.shape
    k2, n = y.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {y.shape}")

    # Clamp tiles to the padded problem so tiny layers get a small grid
    # instead of wasting a 128-wide tile on an 8-wide matrix.
    bm_ = min(bm, _ceil_to(m, 8))
    bn_ = min(bn, _ceil_to(n, 8))
    bk_ = min(bk, _ceil_to(k, 8))

    mp, np_, kp = _ceil_to(m, bm_), _ceil_to(n, bn_), _ceil_to(k, bk_)
    xp = jnp.pad(x.astype(jnp.float32), ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y.astype(jnp.float32), ((0, kp - k), (0, np_ - n)))

    grid = (mp // bm_, np_ // bn_, kp // bk_)
    nsteps_k = grid[2]

    if bias is not None:
        if bias.shape != (n,):
            raise ValueError(f"bias shape {bias.shape} != ({n},)")
        bp = jnp.pad(bias.astype(jnp.float32), (0, np_ - n))
        kernel = functools.partial(
            _matmul_bias_kernel, nsteps_k=nsteps_k, activation=activation
        )
        out = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
                pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
                pl.BlockSpec((bn_,), lambda i, j, kk: (j,)),
            ],
            out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            interpret=True,
        )(xp, yp, bp)
    else:
        kernel = functools.partial(
            _matmul_kernel, nsteps_k=nsteps_k, activation=activation
        )
        out = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
                pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
            ],
            out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            interpret=True,
        )(xp, yp)

    return out[:m, :n]


def vmem_footprint_bytes(bm: int, bn: int, bk: int, itemsize: int = 4) -> int:
    """Estimated per-step VMEM residency of the kernel (double-buffered
    input tiles + f32 output/accumulator tile).  Used by DESIGN.md §Perf
    and the kernel-shape sweep in python/tests."""
    x_tile = bm * bk * itemsize
    y_tile = bk * bn * itemsize
    out = bm * bn * 4
    return 2 * (x_tile + y_tile) + out


def mxu_utilization_estimate(m: int, n: int, k: int,
                             bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                             bk: int = DEFAULT_BK) -> float:
    """Fraction of MXU issue slots doing useful work = useful MACs over
    MACs issued for the padded problem.  1.0 when all dims divide tiles."""
    bm_ = min(bm, _ceil_to(m, 8))
    bn_ = min(bn, _ceil_to(n, 8))
    bk_ = min(bk, _ceil_to(k, 8))
    mp, np_, kp = _ceil_to(m, bm_), _ceil_to(n, bn_), _ceil_to(k, bk_)
    return (m * n * k) / float(mp * np_ * kp)
