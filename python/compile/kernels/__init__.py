"""Layer-1 Pallas kernels (interpret=True) and their pure-jnp oracles."""

from .matmul import matmul, vmem_footprint_bytes, mxu_utilization_estimate
from .conv2d import conv2d, conv_output_shape
from .conv_direct import conv2d_direct
from .pooling import maxpool2d, global_avgpool
from . import ref

__all__ = [
    "matmul",
    "conv2d",
    "conv_output_shape",
    "conv2d_direct",
    "maxpool2d",
    "global_avgpool",
    "vmem_footprint_bytes",
    "mxu_utilization_estimate",
    "ref",
]
