"""Layer-1 alternative schedule: *direct* (weight-stationary) convolution.

Where ``conv2d.py`` lowers convolution to one big im2col matmul (activation-
stationary: patches are materialized, weights stream through the MXU), this
kernel keeps the weights resident in VMEM and accumulates KH*KW shifted
``(HO*WO, Cin) @ (Cin, Cout)`` contractions per image — the classic direct
schedule.  Grid = (B,): one image per step, so per-step VMEM is the padded
image + the full filter bank + the output tile (all small for the zoo's
shapes).

Trade-off vs. im2col (measured in python/tests/test_conv_direct.py and
discussed in EXPERIMENTS.md §Perf): direct avoids the KH*KW-fold patch
blow-up in HBM traffic, but issues KH*KW smaller MXU contractions whose
inner dimension is only Cin — poor MXU utilization for the zoo's shallow
layers (Cin 3..96), which is why im2col remains the default everywhere.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _activation(x: jnp.ndarray, kind: Optional[str]) -> jnp.ndarray:
    if kind is None or kind == "none":
        return x
    if kind == "relu":
        return jnp.maximum(x, 0.0)
    if kind == "sigmoid":
        return jax.nn.sigmoid(x)
    raise ValueError(f"unknown activation: {kind}")


def _direct_kernel(x_ref, w_ref, b_ref, o_ref, *, stride: int, kh: int,
                   kw: int, ho: int, wo: int, activation: Optional[str]):
    """x block: (1, Hp, Wp, Cin) padded; w: (KH, KW, Cin, Cout)."""
    x = x_ref[...]
    w = w_ref[...]
    cout = w.shape[-1]
    acc = jnp.zeros((1, ho, wo, cout), dtype=jnp.float32)
    # Static KH x KW loop: each term is a strided spatial shift contracted
    # over Cin — the weight tile w[i, j] stays resident across the image.
    for i in range(kh):
        for j in range(kw):
            patch = jax.lax.slice(
                x,
                (0, i, j, 0),
                (1, i + (ho - 1) * stride + 1, j + (wo - 1) * stride + 1, x.shape[3]),
                (1, stride, stride, 1),
            )  # (1, HO, WO, Cin)
            acc = acc + jnp.einsum(
                "bhwc,cd->bhwd", patch, w[i, j], preferred_element_type=jnp.float32
            )
    acc = acc + b_ref[...].astype(jnp.float32)
    o_ref[...] = _activation(acc, activation)


@functools.partial(jax.jit, static_argnames=("stride", "padding", "activation"))
def conv2d_direct(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bias: Optional[jnp.ndarray] = None,
    *,
    stride: int = 1,
    padding: int = 0,
    activation: Optional[str] = None,
) -> jnp.ndarray:
    """NHWC direct convolution; same contract as ``conv2d.conv2d``."""
    if x.ndim != 4 or w.ndim != 4:
        raise ValueError(f"conv2d_direct expects NHWC x and KHWIO w, got {x.shape}, {w.shape}")
    b, h, wid, cin = x.shape
    kh, kw, cin2, cout = w.shape
    if cin != cin2:
        raise ValueError(f"channel mismatch: {cin} vs {cin2}")
    ho = (h + 2 * padding - kh) // stride + 1
    wo = (wid + 2 * padding - kw) // stride + 1
    if ho <= 0 or wo <= 0:
        raise ValueError("empty output")

    xp = jnp.pad(
        x.astype(jnp.float32),
        ((0, 0), (padding, padding), (padding, padding), (0, 0)),
    )
    hp, wp = xp.shape[1], xp.shape[2]
    bvec = (bias if bias is not None else jnp.zeros(cout)).astype(jnp.float32)

    kernel = functools.partial(
        _direct_kernel,
        stride=stride,
        kh=kh,
        kw=kw,
        ho=ho,
        wo=wo,
        activation=activation,
    )
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, hp, wp, cin), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((kh, kw, cin, cout), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((cout,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, ho, wo, cout), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, ho, wo, cout), jnp.float32),
        interpret=True,
    )(xp, w.astype(jnp.float32), bvec)


def vmem_footprint_direct(hp: int, wp: int, cin: int, kh: int, kw: int,
                          cout: int, ho: int, wo: int) -> int:
    """Per-step VMEM bytes: padded image + filters + f32 accumulator."""
    return 4 * (hp * wp * cin + kh * kw * cin * cout + ho * wo * cout)
