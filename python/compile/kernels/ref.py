"""Pure-jnp oracles for every Layer-1 Pallas kernel.

pytest (``python/tests``) asserts ``assert_allclose(kernel, ref)`` across a
hypothesis-driven sweep of shapes and parameters; the Rust integration tests
compare the AOT-compiled HLO modules against golden outputs produced through
these same oracles.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def _activation(x: jnp.ndarray, kind: Optional[str]) -> jnp.ndarray:
    if kind is None or kind == "none":
        return x
    if kind == "relu":
        return jnp.maximum(x, 0.0)
    if kind == "sigmoid":
        return jax.nn.sigmoid(x)
    raise ValueError(f"unknown activation: {kind}")


def ref_matmul(x, y, bias=None, *, activation=None):
    out = jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32))
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return _activation(out, activation)


def ref_conv2d(x, w, bias=None, *, stride=1, padding=0, activation=None):
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=((padding, padding), (padding, padding)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return _activation(out, activation)


def ref_maxpool2d(x, k=2):
    init = -jnp.inf if jnp.issubdtype(x.dtype, jnp.floating) else jnp.iinfo(x.dtype).min
    return jax.lax.reduce_window(
        x,
        init,
        jax.lax.max,
        window_dimensions=(1, k, k, 1),
        window_strides=(1, k, k, 1),
        padding="VALID",
    )


def ref_global_avgpool(x):
    return jnp.mean(x, axis=(1, 2))
