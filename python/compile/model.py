"""Layer-2: the DNN model zoo in JAX, built on the Layer-1 Pallas kernels.

The paper evaluates four networks (AlexNet, ResNet-50, VGG-19, SSD).  Full
ImageNet-scale TensorRT engines are not reproducible on this CPU-only
testbed, so the zoo contains architecturally-faithful scaled-down variants
("tiny_*") whose *relative* compute cost preserves the paper's ordering
(Table 3: 0.77 / 4.14 / 19.77 / 62.82 GFLOPs):

  tiny_alexnet : conv-pool stack + 2 FC heads          (lightest)
  tiny_resnet  : residual blocks + global-avg-pool head
  tiny_vgg     : doubled 3x3 conv blocks, FC head      (conv heavy)
  tiny_ssd     : conv backbone + multi-scale loc/cls detection heads (heaviest)

Every convolution / dense layer executes inside the Pallas matmul kernel
(``kernels.conv2d`` im2cols into it), every pool inside the Pallas pooling
kernel, so the whole forward pass lowers into one HLO module with the
Pallas pipeline inlined.  Weights are deterministic (seeded) and baked into
the module as constants: the Rust serving path ships *only* the input batch.
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, List, Tuple

import numpy as np
import jax.numpy as jnp

from .kernels import conv2d, global_avgpool, matmul, maxpool2d

# ---------------------------------------------------------------------------
# Deterministic parameter construction


class _ParamFactory:
    """He-initialised deterministic weights; tracks parameter count."""

    def __init__(self, seed: int):
        self._rng = np.random.RandomState(seed)
        self.param_count = 0

    def conv(self, kh: int, kw: int, cin: int, cout: int):
        fan_in = kh * kw * cin
        w = self._rng.randn(kh, kw, cin, cout).astype(np.float32)
        w *= np.sqrt(2.0 / fan_in)
        b = np.zeros(cout, dtype=np.float32)
        self.param_count += w.size + b.size
        return jnp.asarray(w), jnp.asarray(b)

    def dense(self, din: int, dout: int):
        w = self._rng.randn(din, dout).astype(np.float32) * np.sqrt(2.0 / din)
        b = np.zeros(dout, dtype=np.float32)
        self.param_count += w.size + b.size
        return jnp.asarray(w), jnp.asarray(b)


# ---------------------------------------------------------------------------
# Networks (NHWC, f32).  Classifiers take (B, 32, 32, 3) -> (B, 10) logits;
# tiny_ssd takes (B, 64, 64, 3) -> (B, anchors, 4 + classes).

NUM_CLASSES = 10
CLS_INPUT = (32, 32, 3)
SSD_INPUT = (64, 64, 3)
SSD_CLASSES = 8
SSD_ANCHORS_PER_CELL = 2


def _flatten(x: jnp.ndarray) -> jnp.ndarray:
    return x.reshape(x.shape[0], -1)


def build_tiny_alexnet() -> Tuple[Callable, int]:
    p = _ParamFactory(seed=11)
    w1, b1 = p.conv(3, 3, 3, 16)     # 32x32x3 -> 16x16x16 (stride 2)
    w2, b2 = p.conv(3, 3, 16, 32)    # 8x8x16 -> 8x8x32 (after pool)
    w3, b3 = p.conv(3, 3, 32, 48)    # 4x4x32 -> 4x4x48 (after pool)
    wf1, bf1 = p.dense(4 * 4 * 48, 96)
    wf2, bf2 = p.dense(96, NUM_CLASSES)

    def fwd(x: jnp.ndarray) -> jnp.ndarray:
        x = conv2d(x, w1, b1, stride=2, padding=1, activation="relu")
        x = maxpool2d(x, 2)
        x = conv2d(x, w2, b2, stride=1, padding=1, activation="relu")
        x = maxpool2d(x, 2)
        x = conv2d(x, w3, b3, stride=1, padding=1, activation="relu")
        x = matmul(_flatten(x), wf1, bf1, activation="relu")
        return matmul(x, wf2, bf2)

    return fwd, p.param_count


def build_tiny_resnet() -> Tuple[Callable, int]:
    p = _ParamFactory(seed=23)
    ws, bs = p.conv(3, 3, 3, 16)               # stem

    def res_block(cin: int, cout: int, stride: int):
        w1, b1 = p.conv(3, 3, cin, cout)
        w2, b2 = p.conv(3, 3, cout, cout)
        if stride != 1 or cin != cout:
            wsc, bsc = p.conv(1, 1, cin, cout)
        else:
            wsc = bsc = None

        def block(x: jnp.ndarray) -> jnp.ndarray:
            y = conv2d(x, w1, b1, stride=stride, padding=1, activation="relu")
            y = conv2d(y, w2, b2, stride=1, padding=1)
            sc = x if wsc is None else conv2d(x, wsc, bsc, stride=stride, padding=0)
            return jnp.maximum(y + sc, 0.0)

        return block

    blocks = [
        res_block(16, 16, 1),
        res_block(16, 32, 2),
        res_block(32, 32, 1),
        res_block(32, 64, 2),
    ]
    wf, bf = p.dense(64, NUM_CLASSES)

    def fwd(x: jnp.ndarray) -> jnp.ndarray:
        x = conv2d(x, ws, bs, stride=1, padding=1, activation="relu")
        for blk in blocks:
            x = blk(x)
        x = global_avgpool(x)
        return matmul(x, wf, bf)

    return fwd, p.param_count


def build_tiny_vgg() -> Tuple[Callable, int]:
    p = _ParamFactory(seed=37)

    def vgg_block(cin: int, cout: int):
        w1, b1 = p.conv(3, 3, cin, cout)
        w2, b2 = p.conv(3, 3, cout, cout)

        def block(x: jnp.ndarray) -> jnp.ndarray:
            x = conv2d(x, w1, b1, stride=1, padding=1, activation="relu")
            x = conv2d(x, w2, b2, stride=1, padding=1, activation="relu")
            return maxpool2d(x, 2)

        return block

    blocks = [vgg_block(3, 24), vgg_block(24, 48), vgg_block(48, 96)]
    wf1, bf1 = p.dense(4 * 4 * 96, 192)
    wf2, bf2 = p.dense(192, NUM_CLASSES)

    def fwd(x: jnp.ndarray) -> jnp.ndarray:
        for blk in blocks:
            x = blk(x)
        x = matmul(_flatten(x), wf1, bf1, activation="relu")
        return matmul(x, wf2, bf2)

    return fwd, p.param_count


def build_tiny_ssd() -> Tuple[Callable, int]:
    """SSD-style single-shot detector: conv backbone, two feature maps
    (16x16 and 8x8), per-cell loc (4) + cls (SSD_CLASSES) predictions for
    SSD_ANCHORS_PER_CELL anchors, concatenated over scales.

    Output: (B, 16*16*A + 8*8*A, 4 + SSD_CLASSES).
    """
    p = _ParamFactory(seed=41)
    w1, b1 = p.conv(3, 3, 3, 24)     # 64 -> 32 (stride 2)
    w2, b2 = p.conv(3, 3, 24, 48)    # 32 -> 16 (stride 2) => feature map 1
    w3, b3 = p.conv(3, 3, 48, 96)    # 16 -> 8 (stride 2)  => feature map 2
    a, c = SSD_ANCHORS_PER_CELL, SSD_CLASSES
    wl1, bl1 = p.conv(3, 3, 48, a * 4)
    wc1, bc1 = p.conv(3, 3, 48, a * c)
    wl2, bl2 = p.conv(3, 3, 96, a * 4)
    wc2, bc2 = p.conv(3, 3, 96, a * c)

    def head(fm: jnp.ndarray, wl, bl, wc, bc) -> jnp.ndarray:
        b_ = fm.shape[0]
        loc = conv2d(fm, wl, bl, stride=1, padding=1)
        cls = conv2d(fm, wc, bc, stride=1, padding=1)
        loc = loc.reshape(b_, -1, 4)
        cls = cls.reshape(b_, -1, c)
        return jnp.concatenate([loc, cls], axis=-1)

    def fwd(x: jnp.ndarray) -> jnp.ndarray:
        x = conv2d(x, w1, b1, stride=2, padding=1, activation="relu")
        f1 = conv2d(x, w2, b2, stride=2, padding=1, activation="relu")
        f2 = conv2d(f1, w3, b3, stride=2, padding=1, activation="relu")
        d1 = head(f1, wl1, bl1, wc1, bc1)
        d2 = head(f2, wl2, bl2, wc2, bc2)
        return jnp.concatenate([d1, d2], axis=1)

    return fwd, p.param_count


# ---------------------------------------------------------------------------
# Zoo registry — names must match rust/src/models/zoo.rs.

ZOO: Dict[str, dict] = {
    "alexnet": {"build": build_tiny_alexnet, "input": CLS_INPUT},
    "resnet50": {"build": build_tiny_resnet, "input": CLS_INPUT},
    "vgg19": {"build": build_tiny_vgg, "input": CLS_INPUT},
    "ssd": {"build": build_tiny_ssd, "input": SSD_INPUT},
}

MODEL_NAMES: List[str] = list(ZOO.keys())


@functools.lru_cache(maxsize=None)
def get_model(name: str) -> Tuple[Callable, Tuple[int, int, int], int]:
    """Return (forward_fn, input_hwc, param_count) for a zoo model."""
    if name not in ZOO:
        raise KeyError(f"unknown model {name!r}; zoo has {MODEL_NAMES}")
    entry = ZOO[name]
    fwd, nparams = entry["build"]()
    return fwd, entry["input"], nparams


def make_input(name: str, batch: int, seed: int = 0) -> np.ndarray:
    """Deterministic synthetic input batch (pixel values are irrelevant to
    latency; the golden-output tests fix seed=0)."""
    _, hwc, _ = get_model(name)
    rng = np.random.RandomState(seed + 1000)
    return rng.rand(batch, *hwc).astype(np.float32)
