"""AOT lowering: JAX model zoo -> HLO *text* artifacts for the Rust runtime.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published ``xla`` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly.

Outputs (under --out-dir, default ../artifacts):
  <model>_b<batch>.hlo.txt   one executable per (model, batch-size) variant
  golden_<model>.json        input/output pair at batch=1 for Rust numerics tests
  manifest.json              registry the Rust runtime loads at startup

Usage:  cd python && python -m compile.aot [--out-dir ../artifacts]
                                           [--models a,b,..] [--batches 1,4,..]
Python runs ONCE at build time (make artifacts); it is never on the
request path.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from .model import MODEL_NAMES, get_model, make_input

# Batch-size variants compiled per model.  The coordinator's dynamic batcher
# rounds a queue up to the nearest compiled variant (padding the batch), so
# this ladder bounds padding waste at 2x in the worst case.
DEFAULT_BATCHES = (1, 2, 4, 8, 16, 32)

GOLDEN_BATCH = 1


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the baked-in model weights MUST round-trip
    # through the text format (the default elides big literals as "{...}",
    # which parses back as garbage on the Rust side).
    return comp.as_hlo_text(print_large_constants=True)


def lower_model(name: str, batch: int):
    """Lower one zoo model at one batch size; returns (hlo_text, out_shape)."""
    fwd, hwc, _ = get_model(name)
    spec = jax.ShapeDtypeStruct((batch, *hwc), np.float32)
    lowered = jax.jit(fwd).lower(spec)
    out_shape = lowered.out_info.shape
    return to_hlo_text(lowered), tuple(out_shape)


def build_artifacts(out_dir: str, models, batches, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "models": []}

    for name in models:
        fwd, hwc, nparams = get_model(name)
        entry = {
            "name": name,
            "input_hwc": list(hwc),
            "param_count": nparams,
            "variants": [],
        }
        for batch in batches:
            t0 = time.time()
            hlo, out_shape = lower_model(name, batch)
            fname = f"{name}_b{batch}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(hlo)
            entry["variants"].append(
                {
                    "batch": batch,
                    "file": fname,
                    "input_shape": [batch, *hwc],
                    "output_shape": list(out_shape),
                }
            )
            if verbose:
                print(
                    f"  {fname}: {len(hlo) / 1e6:.2f} MB HLO text "
                    f"out={list(out_shape)} ({time.time() - t0:.1f}s)",
                    flush=True,
                )

        # Golden input/output for the Rust numerics integration test.
        x = make_input(name, GOLDEN_BATCH, seed=0)
        y = np.asarray(fwd(x))
        golden = {
            "model": name,
            "batch": GOLDEN_BATCH,
            "input_shape": list(x.shape),
            "output_shape": list(y.shape),
            "input": [float(v) for v in x.reshape(-1)],
            "output": [float(v) for v in y.reshape(-1)],
        }
        gname = f"golden_{name}.json"
        with open(os.path.join(out_dir, gname), "w") as f:
            json.dump(golden, f)
        entry["golden"] = gname
        manifest["models"].append(entry)
        if verbose:
            print(f"  {gname}: |out| mean {np.abs(y).mean():.4f}", flush=True)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--models", default=",".join(MODEL_NAMES))
    ap.add_argument("--batches", default=",".join(str(b) for b in DEFAULT_BATCHES))
    args = ap.parse_args()

    models = [m.strip() for m in args.models.split(",") if m.strip()]
    batches = [int(b) for b in args.batches.split(",") if b.strip()]
    for m in models:
        if m not in MODEL_NAMES:
            print(f"unknown model {m!r}; zoo: {MODEL_NAMES}", file=sys.stderr)
            return 2

    t0 = time.time()
    print(f"AOT-lowering {models} x batches {batches} -> {args.out_dir}")
    build_artifacts(args.out_dir, models, batches)
    print(f"done in {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
