"""Test-session wiring for the Layer-1 suite.

* Puts ``python/`` on ``sys.path`` so ``from compile import ...`` works
  regardless of the pytest invocation directory.
* Gates modules that import ``jax`` at collection time (missing
  ``hypothesis`` is handled by ``pytest.importorskip`` inside the two
  property-based modules, which also covers naming a file directly).
"""

import importlib.util
import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

collect_ignore = []
if importlib.util.find_spec("jax") is None:
    collect_ignore += ["test_kernels.py", "test_conv_direct.py", "test_models.py"]
