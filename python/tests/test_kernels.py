"""Layer-1 correctness: Pallas kernels vs. pure-jnp oracles.

Hypothesis sweeps shapes, tile sizes, and activations; every case asserts
assert_allclose(kernel, ref) — the core numerics signal of the build path.
"""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # not installable in the offline build container
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

import jax.numpy as jnp

from compile.kernels import (
    conv2d,
    conv_output_shape,
    global_avgpool,
    matmul,
    maxpool2d,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels import ref

RTOL = 2e-5
ATOL = 2e-5


def rand(shape, seed):
    return np.random.RandomState(seed).randn(*shape).astype(np.float32)


# ---------------------------------------------------------------------------
# matmul


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    act=st.sampled_from([None, "relu", "sigmoid"]),
    bias=st.booleans(),
    seed=st.integers(0, 2**20),
)
def test_matmul_matches_ref(m, k, n, act, bias, seed):
    x = rand((m, k), seed)
    y = rand((k, n), seed + 1)
    b = rand((n,), seed + 2) if bias else None
    out = matmul(jnp.array(x), jnp.array(y), None if b is None else jnp.array(b),
                 activation=act, bm=32, bn=32, bk=32)
    expect = ref.ref_matmul(x, y, b, activation=act)
    assert out.shape == (m, n)
    assert_allclose(np.asarray(out), np.asarray(expect), rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("tiles", [(8, 8, 8), (16, 32, 8), (64, 64, 64), (128, 128, 128)])
def test_matmul_tile_invariance(tiles):
    """Output must be independent of the BlockSpec tiling."""
    bm, bn, bk = tiles
    x, y, b = rand((70, 50), 0), rand((50, 90), 1), rand((90,), 2)
    base = ref.ref_matmul(x, y, b, activation="relu")
    out = matmul(jnp.array(x), jnp.array(y), jnp.array(b),
                 activation="relu", bm=bm, bn=bn, bk=bk)
    assert_allclose(np.asarray(out), np.asarray(base), rtol=RTOL, atol=ATOL)


def test_matmul_rejects_bad_shapes():
    with pytest.raises(ValueError):
        matmul(jnp.zeros((2, 3)), jnp.zeros((4, 5)))
    with pytest.raises(ValueError):
        matmul(jnp.zeros((2, 3)), jnp.zeros((3, 4)), jnp.zeros((5,)))
    with pytest.raises(ValueError):
        matmul(jnp.zeros((2, 3, 4)), jnp.zeros((3, 4)))


def test_matmul_bad_activation():
    with pytest.raises(ValueError):
        matmul(jnp.zeros((4, 4)), jnp.zeros((4, 4)), activation="tanh")


def test_vmem_and_mxu_helpers():
    # 128^2 f32 tiles: 2*(64KB+64KB) + 64KB = 320 KB
    assert vmem_footprint_bytes(128, 128, 128) == 2 * (65536 + 65536) + 65536
    assert mxu_utilization_estimate(128, 128, 128) == 1.0
    assert mxu_utilization_estimate(100, 100, 100) < 1.0
    assert mxu_utilization_estimate(100, 100, 100) > 0.2


# ---------------------------------------------------------------------------
# conv2d


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(4, 14),
    cin=st.integers(1, 8),
    cout=st.integers(1, 12),
    k=st.sampled_from([1, 3]),
    stride=st.sampled_from([1, 2]),
    padding=st.sampled_from([0, 1]),
    act=st.sampled_from([None, "relu"]),
    seed=st.integers(0, 2**20),
)
def test_conv2d_matches_ref(b, h, cin, cout, k, stride, padding, act, seed):
    if h + 2 * padding < k:
        return
    x = rand((b, h, h, cin), seed)
    w = rand((k, k, cin, cout), seed + 1)
    bias = rand((cout,), seed + 2)
    out = conv2d(jnp.array(x), jnp.array(w), jnp.array(bias),
                 stride=stride, padding=padding, activation=act, bm=32, bn=32, bk=32)
    expect = ref.ref_conv2d(x, w, bias, stride=stride, padding=padding, activation=act)
    assert out.shape == tuple(expect.shape)
    assert out.shape == conv_output_shape(x.shape, w.shape, stride, padding)
    assert_allclose(np.asarray(out), np.asarray(expect), rtol=5e-5, atol=5e-5)


def test_conv2d_channel_mismatch():
    with pytest.raises(ValueError):
        conv2d(jnp.zeros((1, 8, 8, 3)), jnp.zeros((3, 3, 4, 8)))


def test_conv2d_empty_output():
    with pytest.raises(ValueError):
        conv2d(jnp.zeros((1, 2, 2, 3)), jnp.zeros((5, 5, 3, 8)))


# ---------------------------------------------------------------------------
# pooling


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 3),
    hw_half=st.integers(1, 8),
    c=st.integers(1, 8),
    k=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**20),
)
def test_maxpool_matches_ref(b, hw_half, c, k, seed):
    h = hw_half * k
    x = rand((b, h, h, c), seed)
    out = maxpool2d(jnp.array(x), k)
    expect = ref.ref_maxpool2d(jnp.array(x), k)
    assert_allclose(np.asarray(out), np.asarray(expect), rtol=0, atol=0)


def test_maxpool_rejects_indivisible():
    with pytest.raises(ValueError):
        maxpool2d(jnp.zeros((1, 5, 4, 2)), 2)


def test_global_avgpool():
    x = rand((2, 4, 4, 3), 0)
    assert_allclose(
        np.asarray(global_avgpool(jnp.array(x))),
        np.asarray(ref.ref_global_avgpool(jnp.array(x))),
        rtol=1e-6,
        atol=1e-6,
    )
