"""Layer-2 model zoo tests: shapes, determinism, batch consistency, and the
AOT lowering contract (HLO text with full constants, manifest integrity)."""

import json
import os

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import aot
from compile.model import MODEL_NAMES, get_model, make_input

CLS = ["alexnet", "resnet50", "vgg19"]


@pytest.mark.parametrize("name", MODEL_NAMES)
def test_forward_shapes(name):
    fwd, hwc, nparams = get_model(name)
    x = make_input(name, 2)
    y = np.asarray(fwd(x))
    assert y.shape[0] == 2
    if name in CLS:
        assert y.shape == (2, 10)
    else:
        assert y.ndim == 3 and y.shape[2] == 4 + 8  # loc + classes
    assert nparams > 10_000
    assert np.isfinite(y).all()


@pytest.mark.parametrize("name", MODEL_NAMES)
def test_deterministic_weights(name):
    fwd1, _, _ = get_model(name)
    x = make_input(name, 1)
    a = np.asarray(fwd1(x))
    b = np.asarray(fwd1(x))
    assert_allclose(a, b, rtol=0, atol=0)


def test_batch_consistency():
    """Row i of a batched forward equals the single-request forward."""
    fwd, _, _ = get_model("alexnet")
    x = make_input("alexnet", 4)
    full = np.asarray(fwd(x))
    for i in range(4):
        single = np.asarray(fwd(x[i : i + 1]))
        assert_allclose(full[i : i + 1], single, rtol=2e-4, atol=2e-4)


def test_param_count_ordering():
    """VGG (conv-heavy) must dominate; matches the paper's Table-3 spirit."""
    sizes = {n: get_model(n)[2] for n in MODEL_NAMES}
    assert sizes["vgg19"] > sizes["alexnet"]
    assert sizes["vgg19"] > sizes["resnet50"]


def test_unknown_model_rejected():
    with pytest.raises(KeyError):
        get_model("bert")


# ---------------------------------------------------------------------------
# AOT lowering


def test_lower_produces_parseable_hlo_with_constants():
    hlo, out_shape = aot.lower_model("alexnet", 1)
    assert out_shape == (1, 10)
    assert hlo.startswith("HloModule")
    # weights must be embedded in full, never elided
    assert "constant({..." not in hlo
    assert len(hlo) > 500_000  # ~94k f32 params in text form


def test_build_artifacts_roundtrip(tmp_path):
    out = str(tmp_path / "artifacts")
    manifest = aot.build_artifacts(out, ["alexnet"], [1, 2], verbose=False)
    assert manifest["format"] == "hlo-text"
    files = set(os.listdir(out))
    assert {"alexnet_b1.hlo.txt", "alexnet_b2.hlo.txt",
            "golden_alexnet.json", "manifest.json"} <= files
    with open(os.path.join(out, "manifest.json")) as f:
        m = json.load(f)
    entry = m["models"][0]
    assert entry["name"] == "alexnet"
    assert [v["batch"] for v in entry["variants"]] == [1, 2]
    assert entry["variants"][0]["input_shape"] == [1, 32, 32, 3]
    # golden output must match a fresh forward
    with open(os.path.join(out, "golden_alexnet.json")) as f:
        g = json.load(f)
    fwd, hwc, _ = get_model("alexnet")
    x = np.array(g["input"], np.float32).reshape(g["input_shape"])
    y = np.asarray(fwd(x)).reshape(-1)
    assert_allclose(np.array(g["output"], np.float32), y, rtol=1e-5, atol=1e-5)


def test_repo_manifest_consistent_when_built():
    """If `make artifacts` has run, the checked-in manifest must cover the
    full zoo with the default batch ladder."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    path = os.path.join(art, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    with open(path) as f:
        m = json.load(f)
    names = {e["name"] for e in m["models"]}
    assert names == set(MODEL_NAMES)
    for e in m["models"]:
        for v in e["variants"]:
            assert os.path.exists(os.path.join(art, v["file"])), v["file"]
