"""Gate-script behavior for the chaos (fault-injection) sweep lane.

``scripts/check_bench_regression.py`` grew fault-aware paths: fault-free
runs keep the strict zero-drop rule, chaos runs (``config.faults: true``)
are required to have injected faults and closed recovery episodes, their
drops are bounded, and ``recovery_ms_p95`` / the dropped fraction gate
against the baseline — with skip notices when the baseline predates the
chaos lane.  These tests drive the script as a subprocess on synthetic
reports, exactly how CI invokes it.

The MIG lane (``config.mig: true``) follows the same shape: a MIG run
must have feasible MIG tasks and a packer-vs-FFD cost ratio at or below
1 (structural — the packer carries an FFD portfolio fallback), and
``mean_stranded_pct`` / ``packer_vs_ffd_cost_ratio`` gate against the
baseline with skip notices when the baseline predates the metrics.

The long-tail lane (``config.longtail: true``) adds no ratio gates of
its own — its headline is the generic ``wall.sim_throughput_rps`` —
but is structurally validated: at least one long-tail task must have
run and the mean near-idle tenant fraction must be present and at
least 0.75, else the lane is not measuring the long-tail regime.
"""

import json
import os
import subprocess
import sys

SCRIPT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", "scripts", "check_bench_regression.py")
)


def report(
    *,
    faults=None,
    dropped=0,
    arrivals=100_000,
    faults_injected=None,
    recovery_samples=None,
    recovery_ms_p95=None,
    mig=None,
    mig_tasks=None,
    mean_stranded_pct=None,
    packer_vs_ffd_cost_ratio=None,
    longtail=None,
    longtail_tasks=None,
    mean_near_idle_fraction=None,
):
    """A minimal structurally-valid sweep report."""
    agg = {
        "tasks": 10,
        "feasible": 10,
        "mean_cost_per_hour": 20.0,
        "mean_slo_attainment": 0.95,
        "total_migrations": 4,
        "total_served": arrivals - dropped,
        "total_arrivals": arrivals,
        "total_dropped": dropped,
        "total_gpu_seconds": 300.0,
        "mean_gpus": 5.0,
        "mean_pred_error": 0.1,
        "p95_pred_error": 0.2,
        "pred_err_samples": 400,
    }
    # fault keys are conditionally serialized by the Rust side; mirror that
    for key, val in (
        ("faults_injected", faults_injected),
        ("recovery_samples", recovery_samples),
        ("recovery_ms_p95", recovery_ms_p95),
        # MIG keys are likewise conditionally serialized by the Rust side
        ("mig_tasks", mig_tasks),
        ("mean_stranded_pct", mean_stranded_pct),
        ("packer_vs_ffd_cost_ratio", packer_vs_ffd_cost_ratio),
        # long-tail keys follow the same conditional-serialization pattern
        ("longtail_tasks", longtail_tasks),
        ("mean_near_idle_fraction", mean_near_idle_fraction),
    ):
        if val is not None:
            agg[key] = val
    config = {
        "scenarios": 10,
        "seeds": 1,
        "master_seed": 42,
        "min_workloads": 12,
        "max_workloads": 40,
        "epochs": 4,
        "epoch_ms": 1500.0,
        "mismatch": False,
        "calibrate": False,
    }
    if faults is not None:
        config["faults"] = faults
    if mig is not None:
        config["mig"] = mig
    if longtail is not None:
        config["longtail"] = longtail
    return {
        "config": config,
        "scenarios": [{"scenario": 0, "feasible": True}],
        "aggregate": agg,
        "wall": {
            "wall_s": 2.0,
            "served_per_wall_s": 50_000.0,
            "sim_throughput_rps": 400_000.0,
            "total_placements": 900,
            "plan_throughput_pps": 90_000.0,
        },
    }


def chaos_report(**overrides):
    kwargs = dict(
        faults=True,
        dropped=250,
        faults_injected=12,
        recovery_samples=6,
        recovery_ms_p95=900.0,
    )
    kwargs.update(overrides)
    return report(**kwargs)


def mig_report(**overrides):
    kwargs = dict(
        mig=True,
        mig_tasks=8,
        mean_stranded_pct=12.0,
        packer_vs_ffd_cost_ratio=0.93,
    )
    kwargs.update(overrides)
    return report(**kwargs)


def longtail_report(**overrides):
    kwargs = dict(
        longtail=True,
        longtail_tasks=10,
        mean_near_idle_fraction=0.9,
    )
    kwargs.update(overrides)
    return report(**kwargs)


def run_gate(tmp_path, base, cand):
    bp = tmp_path / "base.json"
    cp = tmp_path / "cand.json"
    bp.write_text(json.dumps(base))
    cp.write_text(json.dumps(cand))
    return subprocess.run(
        [sys.executable, SCRIPT, str(bp), str(cp)],
        capture_output=True,
        text=True,
    )


def test_fault_free_pass_is_unchanged(tmp_path):
    r = run_gate(tmp_path, report(), report())
    assert r.returncode == 0, r.stderr
    assert "bench gate: PASS" in r.stdout
    # no chaos rows for a fault-free run
    assert "recovery_ms_p95" not in r.stdout
    assert "dropped_fraction" not in r.stdout


def test_fault_free_run_with_drops_still_fails(tmp_path):
    r = run_gate(tmp_path, report(), report(dropped=3))
    assert r.returncode != 0
    assert "conservation violated" in r.stderr


def test_chaos_candidate_passes_and_gates_recovery(tmp_path):
    r = run_gate(tmp_path, chaos_report(), chaos_report())
    assert r.returncode == 0, r.stderr
    assert "recovery_ms_p95" in r.stdout
    assert "dropped_fraction" in r.stdout
    assert "bench gate: PASS" in r.stdout


def test_chaos_recovery_regression_fails(tmp_path):
    r = run_gate(tmp_path, chaos_report(), chaos_report(recovery_ms_p95=3000.0))
    assert r.returncode != 0
    assert "recovery_ms_p95" in r.stderr


def test_chaos_dropped_fraction_regression_fails(tmp_path):
    # baseline 0.25% -> candidate 5%: beyond both the baseline-relative
    # allowance and the 1% absolute floor
    r = run_gate(tmp_path, chaos_report(), chaos_report(dropped=5_000))
    assert r.returncode != 0
    assert "dropped_fraction" in r.stderr


def test_chaos_unbounded_drops_fail_structurally(tmp_path):
    r = run_gate(tmp_path, chaos_report(), chaos_report(dropped=20_000))
    assert r.returncode != 0
    assert "failover not absorbing faults" in r.stderr


def test_chaos_without_injected_faults_fails(tmp_path):
    r = run_gate(
        tmp_path,
        chaos_report(),
        chaos_report(dropped=0, faults_injected=None, recovery_samples=None, recovery_ms_p95=None),
    )
    assert r.returncode != 0
    assert "injected no faults" in r.stderr


def test_chaos_without_recovery_episodes_fails(tmp_path):
    r = run_gate(
        tmp_path,
        chaos_report(),
        chaos_report(recovery_samples=0),
    )
    assert r.returncode != 0
    assert "no recovery episodes" in r.stderr


def test_pre_chaos_baseline_skips_chaos_gates_with_notice(tmp_path):
    # A baseline blessed before the chaos lane: same shape (faults defaults
    # to false on both sides is NOT the case here — the candidate runs the
    # lane, so the baseline must too for the shape check; simulate a chaos
    # baseline blessed before the *metrics* existed).
    base = chaos_report(faults_injected=None, recovery_samples=None, recovery_ms_p95=None)
    # keep the baseline itself structurally a baseline (only the candidate
    # is structurally validated)
    r = run_gate(tmp_path, base, chaos_report())
    assert r.returncode == 0, r.stderr
    assert "skipped (baseline lacks 'aggregate.recovery_ms_p95'" in r.stdout
    assert "bench gate: PASS" in r.stdout


def test_faults_config_shape_mismatch_fails(tmp_path):
    # chaos candidate vs fault-free baseline: different distributions, the
    # shape check must refuse to ratio-gate them
    r = run_gate(tmp_path, report(), chaos_report())
    assert r.returncode != 0
    assert "does not match the baseline" in r.stderr


def test_pre_chaos_fault_free_baseline_still_shape_matches(tmp_path):
    # a baseline with no "faults" key at all (pre-chaos bless) gates a
    # fault-free candidate that now writes nothing either — setdefault on
    # both sides keeps them comparable
    r = run_gate(tmp_path, report(), report())
    assert r.returncode == 0, r.stderr


def test_mig_candidate_passes_and_gates_fragmentation(tmp_path):
    r = run_gate(tmp_path, mig_report(), mig_report())
    assert r.returncode == 0, r.stderr
    assert "mig_stranded_pct" in r.stdout
    assert "packer_vs_ffd" in r.stdout
    assert "bench gate: PASS" in r.stdout


def test_non_mig_run_prints_no_mig_rows(tmp_path):
    r = run_gate(tmp_path, report(), report())
    assert r.returncode == 0, r.stderr
    assert "mig" not in r.stdout.lower()


def test_mig_stranded_capacity_regression_fails(tmp_path):
    # baseline 12% -> candidate 20% stranded: ratio 1.67, beyond the 20% gate
    r = run_gate(tmp_path, mig_report(), mig_report(mean_stranded_pct=20.0))
    assert r.returncode != 0
    assert "mig_stranded_pct" in r.stderr


def test_mig_packer_losing_to_ffd_fails_structurally(tmp_path):
    # a ratio above 1 means the FFD portfolio fallback broke — this fails
    # even against a matching baseline, before any ratio-gating
    r = run_gate(
        tmp_path,
        mig_report(packer_vs_ffd_cost_ratio=1.05),
        mig_report(packer_vs_ffd_cost_ratio=1.05),
    )
    assert r.returncode != 0
    assert "portfolio fallback is broken" in r.stderr


def test_mig_run_without_feasible_mig_tasks_fails(tmp_path):
    r = run_gate(tmp_path, mig_report(), mig_report(mig_tasks=0))
    assert r.returncode != 0
    assert "no feasible MIG task" in r.stderr


def test_pre_mig_baseline_skips_mig_gates_with_notice(tmp_path):
    # a MIG baseline blessed before the fragmentation metrics existed:
    # shape-matches (config.mig on both sides) but skips the metric gates
    base = mig_report(mig_tasks=None, mean_stranded_pct=None, packer_vs_ffd_cost_ratio=None)
    r = run_gate(tmp_path, base, mig_report())
    assert r.returncode == 0, r.stderr
    assert "skipped (baseline lacks 'aggregate.mean_stranded_pct'" in r.stdout
    assert "skipped (baseline lacks 'aggregate.packer_vs_ffd_cost_ratio'" in r.stdout
    assert "bench gate: PASS" in r.stdout


def test_mig_config_shape_mismatch_fails(tmp_path):
    # MIG candidate vs non-MIG baseline: different fleets, different cost
    # distribution — the shape check must refuse to ratio-gate them
    r = run_gate(tmp_path, report(), mig_report())
    assert r.returncode != 0
    assert "does not match the baseline" in r.stderr


def test_longtail_candidate_passes(tmp_path):
    r = run_gate(tmp_path, longtail_report(), longtail_report())
    assert r.returncode == 0, r.stderr
    assert "bench gate: PASS" in r.stdout


def test_non_longtail_run_mentions_no_longtail(tmp_path):
    r = run_gate(tmp_path, report(), report())
    assert r.returncode == 0, r.stderr
    assert "longtail" not in r.stdout.lower()


def test_longtail_config_shape_mismatch_fails(tmp_path):
    # long-tail candidate vs plain baseline: a 200–1000-tenant mostly-idle
    # population has nothing in common with the 12–40-workload quick lane —
    # the shape check must refuse to ratio-gate them (the lane needs its
    # own blessed BENCH_longtail.json baseline)
    r = run_gate(tmp_path, report(), longtail_report())
    assert r.returncode != 0
    assert "does not match the baseline" in r.stderr


def test_longtail_run_without_longtail_tasks_fails(tmp_path):
    r = run_gate(tmp_path, longtail_report(), longtail_report(longtail_tasks=0))
    assert r.returncode != 0
    assert "no longtail task" in r.stderr


def test_longtail_run_missing_idle_fraction_fails(tmp_path):
    r = run_gate(
        tmp_path, longtail_report(), longtail_report(mean_near_idle_fraction=None)
    )
    assert r.returncode != 0
    assert "mean_near_idle_fraction" in r.stderr


def test_longtail_mostly_active_population_fails_structurally(tmp_path):
    # an "idle" lane whose tenants are mostly active measures nothing —
    # this fails even against a matching baseline, before any ratio-gating
    r = run_gate(
        tmp_path,
        longtail_report(mean_near_idle_fraction=0.5),
        longtail_report(mean_near_idle_fraction=0.5),
    )
    assert r.returncode != 0
    assert "not long-tailed" in r.stderr
