"""Direct-schedule conv kernel vs. the jnp oracle AND vs. the im2col
schedule — the two Pallas schedules must agree to float tolerance."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # not installable in the offline build container
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

import jax.numpy as jnp

from compile.kernels import conv2d
from compile.kernels.conv_direct import conv2d_direct, vmem_footprint_direct
from compile.kernels import ref


def rand(shape, seed):
    return np.random.RandomState(seed).randn(*shape).astype(np.float32)


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(4, 12),
    cin=st.integers(1, 8),
    cout=st.integers(1, 12),
    k=st.sampled_from([1, 3]),
    stride=st.sampled_from([1, 2]),
    padding=st.sampled_from([0, 1]),
    act=st.sampled_from([None, "relu"]),
    seed=st.integers(0, 2**20),
)
def test_direct_matches_ref(b, h, cin, cout, k, stride, padding, act, seed):
    if h + 2 * padding < k:
        return
    x = rand((b, h, h, cin), seed)
    w = rand((k, k, cin, cout), seed + 1)
    bias = rand((cout,), seed + 2)
    out = conv2d_direct(jnp.array(x), jnp.array(w), jnp.array(bias),
                        stride=stride, padding=padding, activation=act)
    expect = ref.ref_conv2d(x, w, bias, stride=stride, padding=padding, activation=act)
    assert out.shape == tuple(expect.shape)
    assert_allclose(np.asarray(out), np.asarray(expect), rtol=5e-5, atol=5e-5)


def test_direct_and_im2col_schedules_agree():
    """The two Pallas schedules compute the same convolution."""
    x = rand((2, 16, 16, 8), 0)
    w = rand((3, 3, 8, 24), 1)
    b = rand((24,), 2)
    a = conv2d(jnp.array(x), jnp.array(w), jnp.array(b),
               stride=1, padding=1, activation="relu")
    d = conv2d_direct(jnp.array(x), jnp.array(w), jnp.array(b),
                      stride=1, padding=1, activation="relu")
    assert_allclose(np.asarray(a), np.asarray(d), rtol=5e-5, atol=5e-5)


def test_direct_no_bias():
    x = rand((1, 6, 6, 3), 3)
    w = rand((3, 3, 3, 4), 4)
    out = conv2d_direct(jnp.array(x), jnp.array(w), padding=1)
    expect = ref.ref_conv2d(x, w, padding=1)
    assert_allclose(np.asarray(out), np.asarray(expect), rtol=5e-5, atol=5e-5)


def test_vmem_footprint_helper():
    # zoo worst case: 64x64 SSD stem, 3x3x3x24 filters
    bytes_ = vmem_footprint_direct(66, 66, 3, 3, 3, 24, 32, 32)
    assert bytes_ < 16 * 2**20, "direct schedule must fit VMEM"
    assert bytes_ > 0
