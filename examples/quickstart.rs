//! Quickstart: load one AOT-compiled model, verify its numerics against the
//! Python golden, and serve a few real batched requests through PJRT.
//!
//!   make artifacts && cargo run --release --example quickstart

use igniter::util::error::Result;
use igniter::runtime::{Engine, Manifest};
use std::path::Path;

fn main() -> Result<()> {
    if !igniter::runtime::PJRT_AVAILABLE {
        println!(
            "quickstart needs real PJRT compute, which this build stubs out \
             (see DESIGN.md §PJRT runtime) — nothing to run."
        );
        return Ok(());
    }
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let manifest = Manifest::load(&dir)?;
    println!("artifact zoo: {:?}", manifest.names());

    let mut engine = Engine::new(manifest)?;

    // 1. Numerics: the compiled HLO must reproduce the Python forward pass.
    let err = engine.verify_golden("resnet50", 1e-3)?;
    println!("resnet50 golden check: max |err| = {err:.2e}");

    // 2. Serve a batch of 8 synthetic requests.
    engine.load_variant("resnet50", 8)?;
    let lv = engine.variant("resnet50", 8).unwrap();
    let per_req: usize = lv.variant.input_len() / 8;
    let input: Vec<f32> = (0..8 * per_req).map(|i| (i % 255) as f32 / 255.0).collect();
    let t0 = std::time::Instant::now();
    let logits = lv.execute(&input)?;
    println!(
        "batch-8 inference: {} logits in {:.2} ms (wall clock, CPU PJRT)",
        logits.len(),
        t0.elapsed().as_secs_f64() * 1e3
    );

    // 3. Partial batch with padding (what the dynamic batcher does).
    let three = lv.execute_padded(&input[..3 * per_req], 3)?;
    println!("padded batch-3: {} logits", three.len());
    assert_eq!(three.len(), 3 * logits.len() / 8);
    println!("quickstart OK");
    Ok(())
}
