//! Provisioning walkthrough (the paper's Sec.-2.3 illustrative example):
//! the Table-1 trio A(15 ms, 500 r/s) / R(40 ms, 400 r/s) / V(60 ms,
//! 200 r/s) provisioned by all five strategies, with predicted latencies
//! against the half-SLO budget.
//!
//!   cargo run --release --example provisioning_demo

use igniter::gpu::GpuKind;
use igniter::provisioner::{ffd, gpulets, gslice, igniter as ig, Plan, ProfiledSystem};
use igniter::util::table::{f, pct, Table};
use igniter::workload::table1_workloads;

fn main() {
    let (hw, wls) = igniter::profiler::profile_all(GpuKind::V100, 42);
    let sys = ProfiledSystem {
        hw,
        coeffs: igniter::gpu::ALL_MODELS.iter().cloned().zip(wls).collect(),
    };
    let specs = table1_workloads();

    println!("Theorem-1 derived quantities (Eq. 17 / Eq. 18):");
    let derived = ig::derive_all(&sys, &specs);
    for (w, d) in derived.iter().enumerate() {
        let d = d.unwrap();
        println!(
            "  {}: b_appr = {}, r_lower = {}",
            specs[w].name,
            d.batch,
            pct(d.r_lower)
        );
    }
    println!();

    let plans: Vec<Plan> = vec![
        ig::provision(&sys, &specs),
        ffd::provision_ffd(&sys, &specs),
        ffd::provision_ffd_pp(&sys, &specs),
        gslice::provision_gslice(&sys, &specs),
        gpulets::provision_gpulets(&sys, &specs),
    ];

    let mut t = Table::new(
        "Table-1 example: plans + predicted latency vs. half-SLO",
        &["strategy", "gpus", "$/h", "workload", "r", "batch", "pred_ms", "half_slo", "ok"],
    );
    for plan in &plans {
        for (w, t_inf, _) in ig::predict_plan(&sys, &specs, plan) {
            let (g, a) = plan.find(w).unwrap();
            let _ = g;
            t.row(&[
                plan.strategy.clone(),
                plan.num_gpus().to_string(),
                format!("{:.2}", plan.cost_per_hour()),
                specs[w].name.clone(),
                pct(a.resources),
                a.batch.to_string(),
                f(t_inf, 2),
                f(specs[w].slo_ms / 2.0, 1),
                (t_inf <= specs[w].slo_ms / 2.0 + 1e-9).to_string(),
            ]);
        }
    }
    println!("{}", t.render());

    let ig_plan = &plans[0];
    println!(
        "iGniter fits all three on {} GPU(s) — paper Table 1: \
         A(10%,4) R(30%,8) V(37.5%,6) on one GPU, no violations.",
        ig_plan.num_gpus()
    );
}
