//! End-to-end driver (the EXPERIMENTS.md §End-to-end run): the full iGniter
//! pipeline on the paper's 12-workload App table —
//!
//!   1. lightweight profiling of the (simulated) V100 testbed,
//!   2. interference-aware provisioning (Alg. 1 + Alg. 2),
//!   3. a 30-second virtual-time serving run with the shadow-failover
//!      policy (P99 / throughput / SLO verdict per workload),
//!   4. real batched inference through the AOT-compiled HLO executables
//!      on the PJRT CPU client — proving all three layers compose.
//!
//!   make artifacts && cargo run --release --example serve_cluster

use igniter::util::error::Result;
use igniter::coordinator::{realrun, ClusterSim, Policy};
use igniter::gpu::GpuKind;
use igniter::provisioner::{self, ProfiledSystem};
use igniter::runtime::{Engine, Manifest};
use igniter::util::table::{f, pct, Table};
use igniter::workload::{app_workloads, ArrivalKind};
use std::path::Path;
use std::time::Instant;

fn main() -> Result<()> {
    let kind = GpuKind::V100;

    // 1. Profile (11 configs per workload; Sec. 3.1).
    let t0 = Instant::now();
    let (hw, wls) = igniter::profiler::profile_all(kind, 42);
    let sys = ProfiledSystem {
        hw,
        coeffs: igniter::gpu::ALL_MODELS.iter().cloned().zip(wls).collect(),
    };
    println!(
        "profiled {} workloads + hardware in {:.1} ms",
        sys.coeffs.len(),
        t0.elapsed().as_secs_f64() * 1e3
    );

    // 2. Provision the 12 workloads.
    let specs = app_workloads();
    let t1 = Instant::now();
    let plan = provisioner::provision(&sys, &specs);
    println!(
        "iGniter plan: {} GPUs (${:.2}/h) in {:.2} ms",
        plan.num_gpus(),
        plan.cost_per_hour(),
        t1.elapsed().as_secs_f64() * 1e3
    );
    let mut pt = Table::new("provisioning plan", &["gpu", "workload", "resources", "batch"]);
    for (g, a) in plan.all() {
        pt.row(&[
            format!("GPU{}", g + 1),
            specs[a.workload].name.clone(),
            pct(a.resources),
            a.batch.to_string(),
        ]);
    }
    println!("{}", pt.render());

    // 3. Serve for 30 s of virtual time.
    let mut sim = ClusterSim::new(
        kind,
        &plan,
        &specs,
        Policy::IgniterShadow,
        ArrivalKind::Constant,
        42,
        &[],
    );
    sim.set_horizon(30_000.0, 1_000.0);
    let stats = sim.run();
    let mut st = Table::new(
        "virtual-time serving (30 s, constant arrivals)",
        &["workload", "P99_ms", "SLO_ms", "rps", "target", "ok"],
    );
    let mut violations = 0;
    for s in &stats {
        let ok = !(s.violation || s.throughput_violation);
        if !ok {
            violations += 1;
        }
        st.row(&[
            s.name.clone(),
            f(s.p99_ms, 2),
            f(s.slo_ms, 0),
            f(s.achieved_rps, 0),
            f(s.rate_rps, 0),
            ok.to_string(),
        ]);
    }
    println!("{}", st.render());
    println!("SLO violations: {violations} (paper: 0 for iGniter)");

    // 4. Real compute through the compiled HLO executables.
    if !igniter::runtime::PJRT_AVAILABLE {
        println!(
            "(PJRT runtime stubbed — skipping the real-compute stage; \
             steps 1-3 above ran end-to-end)"
        );
        assert_eq!(violations, 0, "iGniter must meet every SLO");
        println!("serve_cluster OK (virtual-time only)");
        return Ok(());
    }
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let manifest = Manifest::load(&dir)?;
    let mut engine = Engine::new(manifest)?;
    let real = realrun::serve_real(&mut engine, &plan, &specs, 3, 42)?;
    let mut rt = Table::new(
        "real PJRT compute (wall clock)",
        &["workload", "model", "batch", "requests", "ms_per_batch"],
    );
    let mut total_reqs = 0;
    for s in &real {
        total_reqs += s.requests;
        rt.row(&[
            s.name.clone(),
            s.model.clone(),
            s.batch.to_string(),
            s.requests.to_string(),
            f(s.mean_batch_ms, 2),
        ]);
    }
    println!("{}", rt.render());
    println!(
        "served {total_reqs} real requests through {} compiled executables \
         (compile wall {:.1} s)",
        engine.loaded_count(),
        engine.compile_secs
    );
    assert_eq!(violations, 0, "iGniter must meet every SLO");
    println!("serve_cluster OK");
    Ok(())
}
