//! Heterogeneous-cluster selection (Sec. 4.1 Remark + Fig. 20): provision
//! the 12-workload App table on V100 (p3.2xlarge) and T4 (g4dn.xlarge)
//! pools, replicate workloads that cannot fit a single T4, and adopt the
//! cheapest plan.
//!
//!   cargo run --release --example heterogeneous

use igniter::gpu::GpuKind;
use igniter::provisioner::{heterogeneous, ProfiledSystem};
use igniter::util::table::{pct, Table};
use igniter::workload::app_workloads;

fn sys(kind: GpuKind) -> ProfiledSystem {
    let (hw, wls) = igniter::profiler::profile_all(kind, 42);
    ProfiledSystem {
        hw,
        coeffs: igniter::gpu::ALL_MODELS.iter().cloned().zip(wls).collect(),
    }
}

fn main() {
    let specs = app_workloads();
    let systems = [sys(GpuKind::V100), sys(GpuKind::T4)];
    let plans = heterogeneous::select_cheapest(&systems, &specs);

    let mut t = Table::new(
        "candidate plans (cheapest first; paper: 15x T4 $7.89/h vs 6x V100 $18.36/h)",
        &["gpu", "instances", "$/h", "expanded workloads"],
    );
    for tp in &plans {
        t.row(&[
            tp.plan.gpu.clone(),
            tp.plan.num_gpus().to_string(),
            format!("{:.2}", tp.plan.cost_per_hour()),
            tp.replicated.specs.len().to_string(),
        ]);
    }
    println!("{}", t.render());

    let winner = &plans[0];
    println!("selected {}:", winner.plan.gpu);
    let mut d = Table::new(
        "winning plan detail",
        &["gpu", "workload", "resources", "batch"],
    );
    for (g, a) in winner.plan.all() {
        d.row(&[
            format!("GPU{}", g + 1),
            winner.replicated.specs[a.workload].name.clone(),
            pct(a.resources),
            a.batch.to_string(),
        ]);
    }
    println!("{}", d.render());

    // replication report (the paper's "2+ g4dn.xlarge for W7/W8/W10/W12")
    for w in 0..specs.len() {
        let n = winner.replicated.origin.iter().filter(|&&o| o == w).count();
        if n > 1 {
            println!(
                "  {} split into {n} rate-sharing replicas ({} r/s each)",
                specs[w].name,
                specs[w].rate_rps / n as f64
            );
        }
    }
}
