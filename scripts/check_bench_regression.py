#!/usr/bin/env python3
"""Gate a sweep bench run against the committed baseline.

Usage: check_bench_regression.py BENCH_baseline.json BENCH_sweep.json

Compares three headline metrics of ``igniter sweep`` output:

* ``aggregate.mean_cost_per_hour``  — lower is better; fail if the
  candidate costs more than ``(1 + tol) x`` baseline.
* ``aggregate.mean_slo_attainment`` — higher is better; fail if below
  ``(1 - tol) x`` baseline.
* ``aggregate.mean_pred_error`` / ``aggregate.p95_pred_error`` — the
  performance model's serving-observed prediction error; lower is
  better, gated like cost (and subject to the same provisional-baseline
  5x widening).  A baseline that predates these metrics simply skips
  them (printed as such) instead of failing the shape check.
* ``wall.served_per_wall_s``        — sim throughput, higher is better;
  fail if below ``(1 - wall_tol) x`` baseline.
* ``wall.sim_throughput_rps``       — served virtual requests per second
  of *summed per-task* simulation wall (worker-count independent, the
  sim-core speed number `benches/simulator.rs` also reports); higher is
  better, gated like ``served_per_wall_s`` and skipped with a notice
  when the baseline predates the metric.
* ``wall.plan_throughput_pps``      — placement items per second of
  summed planning wall (offline Alg. 1 passes plus online
  respec/rebalance re-planning — the placement-engine speed number
  `benches/provisioner.rs` also reports); higher is better, gated like
  ``sim_throughput_rps`` and skipped with a notice when the baseline
  predates the metric (pre-PR-7 baselines).  Wall-clock is
  machine-noisy (hosted CI runners vary well beyond 20%), so it gets
  its own, wider tolerance and only gates when the baseline carries a
  measured value — bless the baseline FROM A CI ARTIFACT (download the
  ``bench-sweep`` artifact of a green run and commit it), never from a
  faster dev machine.

Chaos-lane runs (``igniter sweep --faults``; ``config.faults: true`` in
the report) are gated on two extra metrics:

* ``aggregate.recovery_ms_p95`` — worst per-task recovery p95 (fault
  instant to first replacement batch served); lower is better, gated
  like cost.  Skipped with a notice when the baseline predates it.
* dropped fraction — ``total_dropped / total_arrivals``; the chaos lane
  legitimately drops a bounded fraction (deadline shed + orphaned
  in-flight requests), so instead of the fault-free ``== 0`` rule it is
  gated against the baseline's fraction (with a 1% absolute floor).
  Structurally a chaos run must have injected faults, closed at least
  one recovery episode, and kept drops under 10% of arrivals.

Fault-free runs keep the strict zero-drop structural rule, and a
baseline blessed before the chaos lane existed shape-matches a
fault-free candidate via the ``faults: false`` default.

MIG-lane runs (``igniter sweep --fleet mig``; ``config.mig: true`` in
the report) are gated on two extra metrics:

* ``aggregate.mean_stranded_pct`` — mean stranded slice capacity
  (carved-but-idle GPCs as % of powered device capacity); lower is
  better, gated like cost.  Skipped with a notice when the baseline
  predates it.
* ``aggregate.packer_vs_ffd_cost_ratio`` — fragmentation-aware packer
  cost over FFD++ cost on identical slice-quantized demands; lower is
  better, gated like cost, and additionally bounded structurally at
  ``<= 1`` (the packer carries an FFD portfolio fallback, so losing to
  FFD outright means the fallback broke — not that packing merely got
  worse).

Structurally a MIG run must have at least one feasible MIG task, else
the lane gates nothing.  Baselines blessed before the MIG lane existed
shape-match non-MIG candidates via the ``mig: false`` default and skip
the MIG metric gates with a printed notice.

Long-tail-lane runs (``igniter sweep --longtail``; ``config.longtail:
true`` in the report) have no extra ratio gates — their headline number
is the generic ``wall.sim_throughput_rps`` (the idle-aware monitor fast
path is exactly what a mostly-idle tenant population measures) — but
they carry a structural bar: at least one long-tail task must have run,
``aggregate.mean_near_idle_fraction`` must be present, and the mean
near-idle tenant fraction must be at least 0.75 (a lane whose "idle"
tenants are mostly active is not measuring the long-tail regime).
Baselines blessed before the lane existed shape-match non-longtail
candidates via the ``longtail: false`` default; a longtail candidate
gated against a pre-longtail baseline fails the shape check and needs
its own blessed ``BENCH_longtail.json`` baseline (``make
bless-bench-longtail``).

``tol`` defaults to 0.20 (the 20% CI gate) and can be overridden with
``BENCH_TOLERANCE``; ``wall_tol`` defaults to 0.50 and can be
overridden with ``BENCH_WALL_TOLERANCE``.  A baseline marked ``"provisional": true`` (one that
was estimated rather than measured — see rust/tests/golden/README.md)
widens the deterministic tolerances 5x and skips the throughput gate
entirely; the job then prints a re-bless notice instead of pretending
the gate is sharp.  Structural validation (valid JSON, feasible tasks,
zero dropped requests) always applies.
"""

import json
import os
import sys


def die(msg: str) -> None:
    print(f"BENCH GATE FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def metric(doc: dict, path: str) -> float:
    cur = doc
    for seg in path.split("."):
        if not isinstance(cur, dict) or seg not in cur:
            die(f"missing metric '{path}'")
        cur = cur[seg]
    if not isinstance(cur, (int, float)) or isinstance(cur, bool):
        die(f"metric '{path}' is not a number: {cur!r}")
    return float(cur)


def metric_opt(doc: dict, path: str):
    """Like ``metric`` but returns None when the path is absent — for
    metrics added after a baseline was blessed."""
    cur = doc
    for seg in path.split("."):
        if not isinstance(cur, dict) or seg not in cur:
            return None
        cur = cur[seg]
    if not isinstance(cur, (int, float)) or isinstance(cur, bool):
        return None
    return float(cur)


def main() -> None:
    if len(sys.argv) != 3:
        die(f"usage: {sys.argv[0]} BENCH_baseline.json BENCH_sweep.json")
    base_path, cand_path = sys.argv[1], sys.argv[2]
    try:
        with open(base_path) as f:
            base = json.load(f)
        with open(cand_path) as f:
            cand = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        die(f"cannot load inputs: {e}")

    # -- structural validity of the candidate run -------------------------
    tasks = metric(cand, "aggregate.tasks")
    feasible = metric(cand, "aggregate.feasible")
    dropped = metric(cand, "aggregate.total_dropped")
    served = metric(cand, "aggregate.total_served")
    faults_on = bool(cand.get("config", {}).get("faults", False))
    if tasks <= 0 or feasible <= 0:
        die(f"sweep ran no feasible tasks (tasks={tasks}, feasible={feasible})")
    if faults_on:
        # chaos lane: drops are explicit and bounded, never silent — and
        # the lane must actually have exercised the failover machinery,
        # else it gates nothing
        if dropped < 0:
            die(f"chaos sweep residual {dropped} < 0 — requests double-counted")
        arrivals = metric(cand, "aggregate.total_arrivals")
        if dropped > arrivals * 0.10:
            die(
                f"chaos sweep dropped {dropped:.0f} of {arrivals:.0f} arrivals "
                "— failover not absorbing faults"
            )
        injected = metric_opt(cand, "aggregate.faults_injected")
        if injected is None or injected <= 0:
            die("chaos sweep injected no faults (the chaos lane gates nothing)")
        if metric_opt(cand, "aggregate.recovery_ms_p95") is None:
            die("chaos sweep lacks 'aggregate.recovery_ms_p95' (recovery metric broken)")
        episodes = metric_opt(cand, "aggregate.recovery_samples")
        if episodes is None or episodes <= 0:
            die("chaos sweep closed no recovery episodes (failover never replaced capacity)")
    elif dropped != 0:
        die(f"sweep dropped {dropped} requests — conservation violated")
    if served <= 0:
        die("sweep served no requests")
    if not isinstance(cand.get("scenarios"), list) or not cand["scenarios"]:
        die("candidate report has no per-scenario results")
    # Prediction-error telemetry must actually flow: a candidate that
    # emits the metric fields but recorded zero samples means the exec
    # observation path broke — and "no samples" would otherwise read as
    # zero error to the lower-is-better gate below.
    samples = metric_opt(cand, "aggregate.pred_err_samples")
    if samples is not None and samples <= 0:
        die("sweep recorded no prediction-error samples (telemetry path broken)")
    # Same for placement telemetry: a candidate emitting the planning
    # throughput with zero placements behind it means the counter plumbing
    # (provisioner -> planner -> runner) broke, and the wall gate below
    # would happily compare a meaningless number.
    placements = metric_opt(cand, "wall.total_placements")
    if placements is not None and placements <= 0:
        die("sweep recorded no placements (placement-engine telemetry broken)")
    # MIG lane: the run must actually have exercised discrete slice packing,
    # and the packer must never lose to plain FFD — it carries an FFD
    # portfolio fallback, so a ratio above 1 means the fallback broke
    # (a correctness bug), not that fragmentation merely got worse.
    mig_on = bool(cand.get("config", {}).get("mig", False))
    if mig_on:
        mig_tasks = metric_opt(cand, "aggregate.mig_tasks")
        if mig_tasks is None or mig_tasks <= 0:
            die("MIG sweep ran no feasible MIG task (the MIG lane gates nothing)")
        ratio = metric_opt(cand, "aggregate.packer_vs_ffd_cost_ratio")
        if ratio is None:
            die("MIG sweep lacks 'aggregate.packer_vs_ffd_cost_ratio' (head-to-head broken)")
        if ratio > 1.0 + 1e-6:
            die(
                f"packer_vs_ffd_cost_ratio {ratio:.4f} > 1 — the packer's FFD "
                "portfolio fallback is broken"
            )
    # Long-tail lane: the run must actually have drawn long-tail mixes, and
    # the population must be dominated by near-idle tenants — the lane's
    # headline `wall.sim_throughput_rps` measures the idle-aware monitor
    # fast path, which a mostly-active population would not exercise.
    longtail_on = bool(cand.get("config", {}).get("longtail", False))
    if longtail_on:
        lt_tasks = metric_opt(cand, "aggregate.longtail_tasks")
        if lt_tasks is None or lt_tasks <= 0:
            die("longtail sweep ran no longtail task (the longtail lane gates nothing)")
        idle_frac = metric_opt(cand, "aggregate.mean_near_idle_fraction")
        if idle_frac is None:
            die(
                "longtail sweep lacks 'aggregate.mean_near_idle_fraction' "
                "(active-fraction telemetry broken)"
            )
        if idle_frac < 0.75:
            die(
                f"longtail sweep near-idle fraction {idle_frac:.2f} < 0.75 — the "
                "lane is not long-tailed, so its throughput number is meaningless"
            )

    # -- comparability: the sweep shape must match the baseline's --------
    # (a different scenario count / seed count / master seed / space draws
    # from a different distribution, so ratio-gating the means would be
    # meaningless; parallel width is deliberately not part of the config
    # block — it never changes the deterministic results)
    base_cfg = dict(base.get("config", {}))
    cand_cfg = dict(cand.get("config", {}))
    # Config keys added after a baseline was blessed default to the
    # off/false state they implicitly had then — a PR-4-era baseline must
    # not fail the shape check merely because the candidate now reports
    # "mismatch"/"calibrate" (both lanes default off; a baseline blessed
    # WITH a lane on still mismatches a lane-off candidate, as it should).
    for cfg in (base_cfg, cand_cfg):
        cfg.setdefault("mismatch", False)
        cfg.setdefault("calibrate", False)
        cfg.setdefault("faults", False)
        cfg.setdefault("mig", False)
        cfg.setdefault("longtail", False)
    mismatched = sorted(
        k for k in set(base_cfg) | set(cand_cfg) if base_cfg.get(k) != cand_cfg.get(k)
    )
    if mismatched:
        die(
            "sweep config does not match the baseline's "
            f"({', '.join(f'{k}: {base_cfg.get(k)!r} vs {cand_cfg.get(k)!r}' for k in mismatched)}); "
            "run the gated sweep with the baseline's shape (make sweep-quick) "
            "or re-bless the baseline"
        )

    tol = float(os.environ.get("BENCH_TOLERANCE", "0.20"))
    wall_tol = float(os.environ.get("BENCH_WALL_TOLERANCE", "0.50"))
    provisional = bool(base.get("provisional", False))
    det_tol = tol * 5.0 if provisional else tol

    failures = []

    def gate(name: str, path: str, higher_is_better: bool, t: float) -> None:
        b = metric(base, path)
        c = metric(cand, path)
        if b <= 0:
            return  # nothing meaningful to compare against
        ratio = c / b
        ok = ratio >= (1.0 - t) if higher_is_better else ratio <= (1.0 + t)
        arrow = ">= " + f"{1.0 - t:.2f}" if higher_is_better else "<= " + f"{1.0 + t:.2f}"
        status = "ok" if ok else "REGRESSED"
        print(f"  {name:<22} baseline {b:12.4f}  candidate {c:12.4f}  ratio {ratio:6.3f} ({arrow}) {status}")
        if not ok:
            failures.append(name)

    print(f"bench gate: tolerance {det_tol:.0%}" + (" (provisional baseline)" if provisional else ""))
    gate("cost_per_hour", "aggregate.mean_cost_per_hour", False, det_tol)
    gate("slo_attainment", "aggregate.mean_slo_attainment", True, det_tol)
    for name, path in [
        ("pred_error_mean", "aggregate.mean_pred_error"),
        ("pred_error_p95", "aggregate.p95_pred_error"),
    ]:
        if metric_opt(base, path) is None:
            print(f"  {name:<22} skipped (baseline lacks '{path}' — re-bless to gate it)")
        else:
            gate(name, path, False, det_tol)  # prediction error: lower is better
    if provisional:
        print("  sim_throughput         skipped (baseline throughput is not a measurement)")
        print("  sim_throughput_rps     skipped (baseline throughput is not a measurement)")
        print("  plan_throughput_pps    skipped (baseline throughput is not a measurement)")
    else:
        gate("sim_throughput", "wall.served_per_wall_s", True, wall_tol)
        for name, path in [
            ("sim_throughput_rps", "wall.sim_throughput_rps"),
            ("plan_throughput_pps", "wall.plan_throughput_pps"),
        ]:
            if metric_opt(base, path) is None:
                print(
                    f"  {name:<22} skipped (baseline lacks "
                    f"'{path}' — re-bless to gate it)"
                )
            else:
                gate(name, path, True, wall_tol)

    if faults_on:
        # chaos-lane metrics: recovery time (lower is better) and the
        # dropped fraction (bounded against the baseline's fraction with
        # a 1% absolute floor — tiny integer drop counts are too noisy
        # for a bare ratio)
        path = "aggregate.recovery_ms_p95"
        if metric_opt(base, path) is None:
            print(f"  {'recovery_ms_p95':<22} skipped (baseline lacks '{path}' — re-bless to gate it)")
        else:
            gate("recovery_ms_p95", path, False, det_tol)
        b_arrivals = metric_opt(base, "aggregate.total_arrivals")
        b_dropped = metric_opt(base, "aggregate.total_dropped")
        if b_arrivals is None or b_arrivals <= 0 or b_dropped is None:
            print(
                f"  {'dropped_fraction':<22} skipped (baseline lacks chaos drop "
                "counts — re-bless to gate it)"
            )
        else:
            b_frac = b_dropped / b_arrivals
            c_frac = dropped / max(metric(cand, "aggregate.total_arrivals"), 1.0)
            allowed = max(b_frac * (1.0 + det_tol), 0.01)
            ok = c_frac <= allowed
            status = "ok" if ok else "REGRESSED"
            print(
                f"  {'dropped_fraction':<22} baseline {b_frac:12.4f}  candidate "
                f"{c_frac:12.4f}  (<= {allowed:.4f}) {status}"
            )
            if not ok:
                failures.append("dropped_fraction")

    if mig_on:
        # MIG-lane metrics: stranded slice capacity and the packer-vs-FFD
        # cost ratio, both lower is better (the <= 1 structural bar on the
        # ratio already ran above; this gates run-over-run drift within it)
        for name, path in [
            ("mig_stranded_pct", "aggregate.mean_stranded_pct"),
            ("packer_vs_ffd", "aggregate.packer_vs_ffd_cost_ratio"),
        ]:
            if metric_opt(base, path) is None:
                print(f"  {name:<22} skipped (baseline lacks '{path}' — re-bless to gate it)")
            else:
                gate(name, path, False, det_tol)

    if provisional:
        print(
            "\nNOTICE: BENCH_baseline.json is PROVISIONAL (estimated, not measured).\n"
            "Re-bless it from a real run on a reference machine:\n"
            "    make bless-bench\n"
            "then commit the regenerated baseline to sharpen this gate to "
            f"{tol:.0%}.",
        )

    if failures:
        die(f"regressed metrics: {', '.join(failures)}")
    print("bench gate: PASS")


if __name__ == "__main__":
    main()
