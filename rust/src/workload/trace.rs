//! Time-varying request-rate traces (the paper's future-work item (4):
//! "deploying a dynamic temporal and spatial GPU sharing strategy for
//! time-varying request arrival rates").
//!
//! A `RateTrace` maps epoch index -> per-workload arrival-rate multiplier.
//! Two consumers: `experiments::dynamic` re-runs Alg. 1 each epoch offline,
//! and `TracedArrivalGen` drives the **live** serving event loop — each
//! inter-arrival gap is sampled at the rate in effect at the current
//! virtual time, so Diurnal/Spiky/Ramp traces become closed-loop serving
//! scenarios rather than epoch replays (see `experiments::autoscale`).

use super::ArrivalKind;
use crate::util::rng::Rng;
use std::sync::Arc;

/// Shape of a synthetic rate trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TraceKind {
    /// Sinusoidal day/night swing between `floor` and 1.0 of nominal.
    Diurnal { period_epochs: usize, floor: f64 },
    /// Mostly flat at `base`, with bursts to 1.0 with probability `p`.
    Spiky { base: f64, p: f64 },
    /// Linear ramp from `from` to `to` of nominal across the horizon.
    Ramp { from: f64, to: f64 },
}

/// Per-workload rate multipliers across epochs.
#[derive(Debug, Clone)]
pub struct RateTrace {
    pub kind: TraceKind,
    pub epochs: usize,
    /// multiplier\[epoch\]\[workload\]
    pub multiplier: Vec<Vec<f64>>,
}

impl RateTrace {
    /// Build a trace for `n_workloads` over `epochs` epochs.  Workloads are
    /// phase-shifted so peaks do not all coincide (as in real multi-tenant
    /// clusters).
    pub fn generate(kind: TraceKind, epochs: usize, n_workloads: usize, seed: u64) -> RateTrace {
        let mut rng = Rng::new(seed);
        let phases: Vec<f64> = (0..n_workloads).map(|_| rng.f64()).collect();
        let mut multiplier = Vec::with_capacity(epochs);
        for e in 0..epochs {
            let mut row = Vec::with_capacity(n_workloads);
            for (w, &phase) in phases.iter().enumerate() {
                let m = match kind {
                    TraceKind::Diurnal {
                        period_epochs,
                        floor,
                    } => {
                        let t = (e as f64 / period_epochs.max(1) as f64 + phase)
                            * 2.0
                            * std::f64::consts::PI;
                        floor + (1.0 - floor) * 0.5 * (1.0 + t.sin())
                    }
                    TraceKind::Spiky { base, p } => {
                        let mut r = Rng::new(seed ^ ((e as u64) << 20) ^ w as u64);
                        if r.f64() < p {
                            1.0
                        } else {
                            base
                        }
                    }
                    TraceKind::Ramp { from, to } => {
                        from + (to - from) * e as f64 / (epochs.max(2) - 1) as f64
                    }
                };
                row.push(m.clamp(0.01, 1.0));
            }
            multiplier.push(row);
        }
        RateTrace {
            kind,
            epochs,
            multiplier,
        }
    }

    /// Multiplier for (epoch, workload).
    pub fn at(&self, epoch: usize, workload: usize) -> f64 {
        self.multiplier[epoch][workload]
    }

    /// Mean multiplier of an epoch (cluster-wide load level).
    pub fn epoch_mean(&self, epoch: usize) -> f64 {
        crate::util::stats::mean(&self.multiplier[epoch])
    }

    /// Continuous-time view: the multiplier in effect at virtual time
    /// `t_ms` when each epoch spans `epoch_ms`.  Times past the last epoch
    /// hold its level (the trace saturates rather than wrapping, so a
    /// serving horizon longer than the trace stays well-defined).
    pub fn multiplier_at(&self, t_ms: f64, epoch_ms: f64, workload: usize) -> f64 {
        let e = if epoch_ms > 0.0 && t_ms > 0.0 {
            (t_ms / epoch_ms) as usize
        } else {
            0
        };
        self.multiplier[e.min(self.epochs - 1)][workload]
    }

    /// Declared multiplier bounds of a trace kind, `(lo, hi)` — every
    /// generated multiplier lies in this interval (after the global 0.01
    /// floor).  Pinned here so tests and consumers share one source.
    pub fn bounds(kind: TraceKind) -> (f64, f64) {
        match kind {
            TraceKind::Diurnal { floor, .. } => (floor.max(0.01), 1.0),
            TraceKind::Spiky { base, .. } => (base.max(0.01), 1.0),
            TraceKind::Ramp { from, to } => {
                (from.min(to).max(0.01), from.max(to).clamp(0.01, 1.0))
            }
        }
    }
}

/// Arrival generator whose instantaneous rate follows a `RateTrace`: the
/// gap after each arrival is sampled at `base_rps x multiplier(now)`, so a
/// rate change takes effect within one inter-arrival time.  Deterministic
/// per seed, like `ArrivalGen`.
#[derive(Debug, Clone)]
pub struct TracedArrivalGen {
    kind: ArrivalKind,
    base_rps: f64,
    /// Shared, never mutated: one `RateTrace` can drive every workload's
    /// generator without per-group deep copies of the multiplier matrix.
    trace: Arc<RateTrace>,
    workload: usize,
    epoch_ms: f64,
    rng: Rng,
    next_ms: f64,
}

impl TracedArrivalGen {
    pub fn new(
        kind: ArrivalKind,
        base_rps: f64,
        trace: Arc<RateTrace>,
        workload: usize,
        epoch_ms: f64,
        seed: u64,
    ) -> TracedArrivalGen {
        TracedArrivalGen {
            kind,
            base_rps,
            trace,
            workload,
            epoch_ms,
            rng: Rng::new(seed),
            next_ms: 0.0,
        }
    }

    /// The nominal rate in effect at virtual time `t_ms` (req/s).
    pub fn rate_at(&self, t_ms: f64) -> f64 {
        (self.base_rps * self.trace.multiplier_at(t_ms, self.epoch_ms, self.workload)).max(1e-3)
    }

    /// Next arrival timestamp (ms since start), monotone increasing.
    pub fn next(&mut self) -> f64 {
        let rate = self.rate_at(self.next_ms);
        let gap_ms = match self.kind {
            ArrivalKind::Constant => 1000.0 / rate,
            ArrivalKind::Poisson => self.rng.exp(rate / 1000.0),
        };
        self.next_ms += gap_ms;
        self.next_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diurnal_swings_within_bounds() {
        let t = RateTrace::generate(
            TraceKind::Diurnal {
                period_epochs: 8,
                floor: 0.3,
            },
            32,
            12,
            1,
        );
        for e in 0..32 {
            for w in 0..12 {
                let m = t.at(e, w);
                assert!((0.3 - 1e-9..=1.0 + 1e-9).contains(&m), "m={m}");
            }
        }
        // it actually swings: the range across epochs is wide
        let w0: Vec<f64> = (0..32).map(|e| t.at(e, 0)).collect();
        let lo = w0.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = w0.iter().cloned().fold(0.0, f64::max);
        assert!(hi - lo > 0.5, "range {lo}..{hi}");
    }

    #[test]
    fn phases_differ_between_workloads() {
        let t = RateTrace::generate(
            TraceKind::Diurnal {
                period_epochs: 8,
                floor: 0.2,
            },
            8,
            6,
            2,
        );
        // not all workloads peak at the same epoch
        let peaks: Vec<usize> = (0..6)
            .map(|w| {
                (0..8)
                    .max_by(|&a, &b| t.at(a, w).partial_cmp(&t.at(b, w)).unwrap())
                    .unwrap()
            })
            .collect();
        let first = peaks[0];
        assert!(peaks.iter().any(|&p| p != first), "all peaks at {first}");
    }

    #[test]
    fn ramp_is_monotone() {
        let t = RateTrace::generate(TraceKind::Ramp { from: 0.2, to: 1.0 }, 10, 3, 3);
        for w in 0..3 {
            for e in 1..10 {
                assert!(t.at(e, w) >= t.at(e - 1, w) - 1e-12);
            }
        }
        assert!((t.at(0, 0) - 0.2).abs() < 1e-9);
        assert!((t.at(9, 0) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn spiky_hits_both_levels() {
        let t = RateTrace::generate(TraceKind::Spiky { base: 0.3, p: 0.25 }, 40, 4, 4);
        let all: Vec<f64> = t.multiplier.iter().flatten().cloned().collect();
        assert!(all.iter().any(|&m| m > 0.9));
        assert!(all.iter().any(|&m| m < 0.4));
    }

    #[test]
    fn deterministic() {
        let a = RateTrace::generate(TraceKind::Spiky { base: 0.5, p: 0.2 }, 10, 5, 9);
        let b = RateTrace::generate(TraceKind::Spiky { base: 0.5, p: 0.2 }, 10, 5, 9);
        assert_eq!(a.multiplier, b.multiplier);
    }

    /// Random generation parameters for the property sweep below.
    fn gen_params(r: &mut crate::util::rng::Rng) -> (u64, (usize, usize)) {
        (r.next_u64(), (1 + r.below(40) as usize, 1 + r.below(16) as usize))
    }

    fn kinds() -> [TraceKind; 3] {
        [
            TraceKind::Diurnal {
                period_epochs: 8,
                floor: 0.3,
            },
            TraceKind::Spiky { base: 0.25, p: 0.2 },
            TraceKind::Ramp { from: 0.15, to: 0.9 },
        ]
    }

    #[test]
    fn property_multipliers_within_declared_bounds_all_kinds() {
        // For every TraceKind, every generated multiplier must lie inside
        // RateTrace::bounds(kind) — across random seeds and shapes.
        crate::util::quick::forall(71, 40, gen_params, |&(seed, (epochs, n))| {
            for kind in kinds() {
                let (lo, hi) = RateTrace::bounds(kind);
                let t = RateTrace::generate(kind, epochs, n, seed);
                for (e, row) in t.multiplier.iter().enumerate() {
                    if row.len() != n {
                        return Err(format!("epoch {e}: {} workloads != {n}", row.len()));
                    }
                    for (w, &m) in row.iter().enumerate() {
                        if !(lo - 1e-9..=hi + 1e-9).contains(&m) {
                            return Err(format!(
                                "{kind:?} (e{e}, w{w}): m={m} outside [{lo}, {hi}] (seed {seed})"
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn property_bit_identical_per_seed_all_kinds() {
        // Same (kind, epochs, n, seed) must reproduce every multiplier
        // bit-for-bit; a different seed must actually change the trace
        // (phases for Diurnal/Spiky; Ramp is seed-free by construction).
        crate::util::quick::forall(72, 30, gen_params, |&(seed, (epochs, n))| {
            for kind in kinds() {
                let a = RateTrace::generate(kind, epochs, n, seed);
                let b = RateTrace::generate(kind, epochs, n, seed);
                if a.multiplier != b.multiplier {
                    return Err(format!("{kind:?} drifted across runs (seed {seed})"));
                }
            }
            let a = RateTrace::generate(kinds()[0], epochs.max(4), n.max(2), seed);
            let c = RateTrace::generate(kinds()[0], epochs.max(4), n.max(2), seed ^ 0xDEAD);
            if a.multiplier == c.multiplier {
                return Err(format!("diurnal ignores its seed (seed {seed})"));
            }
            Ok(())
        });
    }

    #[test]
    fn property_diurnal_peaks_phase_shifted_across_workloads() {
        // With enough workloads over a full period, peak epochs must not
        // all coincide — the phase shift is what makes multi-tenant
        // re-provisioning non-trivial.
        crate::util::quick::forall(
            73,
            25,
            |r| (r.next_u64(), 4 + r.below(12) as usize),
            |&(seed, n)| {
                if n < 6 {
                    // with few streams (or on shrink candidates) peak
                    // collisions are statistically possible; the property
                    // targets realistic multi-tenant widths
                    return Ok(());
                }
                let t = RateTrace::generate(
                    TraceKind::Diurnal {
                        period_epochs: 32,
                        floor: 0.2,
                    },
                    32,
                    n,
                    seed,
                );
                let peaks: Vec<usize> = (0..n)
                    .map(|w| {
                        (0..32)
                            .max_by(|&a, &b| t.at(a, w).partial_cmp(&t.at(b, w)).unwrap())
                            .unwrap()
                    })
                    .collect();
                if peaks.iter().all(|&p| p == peaks[0]) {
                    return Err(format!("all {n} peaks at epoch {} (seed {seed})", peaks[0]));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn multiplier_at_maps_time_to_epochs_and_saturates() {
        let t = RateTrace::generate(TraceKind::Ramp { from: 0.2, to: 1.0 }, 10, 2, 3);
        assert_eq!(t.multiplier_at(0.0, 5_000.0, 0), t.at(0, 0));
        assert_eq!(t.multiplier_at(4_999.0, 5_000.0, 0), t.at(0, 0));
        assert_eq!(t.multiplier_at(5_000.0, 5_000.0, 0), t.at(1, 0));
        assert_eq!(t.multiplier_at(47_500.0, 5_000.0, 1), t.at(9, 1));
        // past the end: hold the last epoch, don't wrap or panic
        assert_eq!(t.multiplier_at(1e9, 5_000.0, 0), t.at(9, 0));
    }

    #[test]
    fn traced_arrivals_track_the_trace_rate() {
        // Constant-kind gaps are exactly 1000 / (base * multiplier): a
        // two-epoch step trace must show the step in the arrival spacing.
        let mut tr = RateTrace::generate(TraceKind::Ramp { from: 0.5, to: 1.0 }, 2, 1, 1);
        tr.multiplier = vec![vec![0.5], vec![1.0]];
        let mut g =
            TracedArrivalGen::new(ArrivalKind::Constant, 100.0, Arc::new(tr), 0, 1_000.0, 7);
        let t1 = g.next(); // rate 50 rps -> 20 ms gap
        assert!((t1 - 20.0).abs() < 1e-9);
        let mut last = t1;
        while last < 1_000.0 {
            last = g.next();
        }
        let after = g.next() - last; // epoch 1: 100 rps -> 10 ms gap
        assert!((after - 10.0).abs() < 1e-9, "gap {after}");
    }

    #[test]
    fn traced_arrivals_deterministic_per_seed() {
        let tr = RateTrace::generate(TraceKind::Spiky { base: 0.3, p: 0.25 }, 8, 3, 5);
        let run = |seed: u64| {
            let mut g = TracedArrivalGen::new(
                ArrivalKind::Poisson,
                300.0,
                Arc::new(tr.clone()),
                1,
                500.0,
                seed,
            );
            (0..500).map(|_| g.next().to_bits()).collect::<Vec<_>>()
        };
        assert_eq!(run(11), run(11));
        assert_ne!(run(11), run(12));
    }
}
