//! Time-varying request-rate traces (the paper's future-work item (4):
//! "deploying a dynamic temporal and spatial GPU sharing strategy for
//! time-varying request arrival rates").
//!
//! A `RateTrace` maps epoch index -> per-workload arrival-rate multiplier;
//! `experiments::dynamic` re-runs Alg. 1 each epoch and compares the
//! epoch-by-epoch cost against static peak provisioning.

use crate::util::rng::Rng;

/// Shape of a synthetic rate trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TraceKind {
    /// Sinusoidal day/night swing between `floor` and 1.0 of nominal.
    Diurnal { period_epochs: usize, floor: f64 },
    /// Mostly flat at `base`, with bursts to 1.0 with probability `p`.
    Spiky { base: f64, p: f64 },
    /// Linear ramp from `from` to `to` of nominal across the horizon.
    Ramp { from: f64, to: f64 },
}

/// Per-workload rate multipliers across epochs.
#[derive(Debug, Clone)]
pub struct RateTrace {
    pub kind: TraceKind,
    pub epochs: usize,
    /// multiplier\[epoch\]\[workload\]
    pub multiplier: Vec<Vec<f64>>,
}

impl RateTrace {
    /// Build a trace for `n_workloads` over `epochs` epochs.  Workloads are
    /// phase-shifted so peaks do not all coincide (as in real multi-tenant
    /// clusters).
    pub fn generate(kind: TraceKind, epochs: usize, n_workloads: usize, seed: u64) -> RateTrace {
        let mut rng = Rng::new(seed);
        let phases: Vec<f64> = (0..n_workloads).map(|_| rng.f64()).collect();
        let mut multiplier = Vec::with_capacity(epochs);
        for e in 0..epochs {
            let mut row = Vec::with_capacity(n_workloads);
            for (w, &phase) in phases.iter().enumerate() {
                let m = match kind {
                    TraceKind::Diurnal {
                        period_epochs,
                        floor,
                    } => {
                        let t = (e as f64 / period_epochs.max(1) as f64 + phase)
                            * 2.0
                            * std::f64::consts::PI;
                        floor + (1.0 - floor) * 0.5 * (1.0 + t.sin())
                    }
                    TraceKind::Spiky { base, p } => {
                        let mut r = Rng::new(seed ^ ((e as u64) << 20) ^ w as u64);
                        if r.f64() < p {
                            1.0
                        } else {
                            base
                        }
                    }
                    TraceKind::Ramp { from, to } => {
                        from + (to - from) * e as f64 / (epochs.max(2) - 1) as f64
                    }
                };
                row.push(m.clamp(0.01, 1.0));
            }
            multiplier.push(row);
        }
        RateTrace {
            kind,
            epochs,
            multiplier,
        }
    }

    /// Multiplier for (epoch, workload).
    pub fn at(&self, epoch: usize, workload: usize) -> f64 {
        self.multiplier[epoch][workload]
    }

    /// Mean multiplier of an epoch (cluster-wide load level).
    pub fn epoch_mean(&self, epoch: usize) -> f64 {
        crate::util::stats::mean(&self.multiplier[epoch])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diurnal_swings_within_bounds() {
        let t = RateTrace::generate(
            TraceKind::Diurnal {
                period_epochs: 8,
                floor: 0.3,
            },
            32,
            12,
            1,
        );
        for e in 0..32 {
            for w in 0..12 {
                let m = t.at(e, w);
                assert!((0.3 - 1e-9..=1.0 + 1e-9).contains(&m), "m={m}");
            }
        }
        // it actually swings: the range across epochs is wide
        let w0: Vec<f64> = (0..32).map(|e| t.at(e, 0)).collect();
        let lo = w0.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = w0.iter().cloned().fold(0.0, f64::max);
        assert!(hi - lo > 0.5, "range {lo}..{hi}");
    }

    #[test]
    fn phases_differ_between_workloads() {
        let t = RateTrace::generate(
            TraceKind::Diurnal {
                period_epochs: 8,
                floor: 0.2,
            },
            8,
            6,
            2,
        );
        // not all workloads peak at the same epoch
        let peaks: Vec<usize> = (0..6)
            .map(|w| {
                (0..8)
                    .max_by(|&a, &b| t.at(a, w).partial_cmp(&t.at(b, w)).unwrap())
                    .unwrap()
            })
            .collect();
        let first = peaks[0];
        assert!(peaks.iter().any(|&p| p != first), "all peaks at {first}");
    }

    #[test]
    fn ramp_is_monotone() {
        let t = RateTrace::generate(TraceKind::Ramp { from: 0.2, to: 1.0 }, 10, 3, 3);
        for w in 0..3 {
            for e in 1..10 {
                assert!(t.at(e, w) >= t.at(e - 1, w) - 1e-12);
            }
        }
        assert!((t.at(0, 0) - 0.2).abs() < 1e-9);
        assert!((t.at(9, 0) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn spiky_hits_both_levels() {
        let t = RateTrace::generate(TraceKind::Spiky { base: 0.3, p: 0.25 }, 40, 4, 4);
        let all: Vec<f64> = t.multiplier.iter().flatten().cloned().collect();
        assert!(all.iter().any(|&m| m > 0.9));
        assert!(all.iter().any(|&m| m < 0.4));
    }

    #[test]
    fn deterministic() {
        let a = RateTrace::generate(TraceKind::Spiky { base: 0.5, p: 0.2 }, 10, 5, 9);
        let b = RateTrace::generate(TraceKind::Spiky { base: 0.5, p: 0.2 }, 10, 5, 9);
        assert_eq!(a.multiplier, b.multiplier);
    }
}
