//! Workload definitions: the paper's App1-3 x {AlexNet, ResNet-50, VGG-19,
//! SSD} SLO table (Table 3) and request arrival generators.

pub mod trace;

use crate::gpu::Model;
use crate::provisioner::types::WorkloadSpec;
use crate::util::rng::Rng;

/// Table 3: (model, latency SLO ms, throughput req/s) per App.
///
/// W1..W4 = App1(A,R,V,S), W5..W8 = App2, W9..W12 = App3.
pub const APP_TABLE: [(Model, f64, f64); 12] = [
    (Model::AlexNet, 10.0, 1200.0),
    (Model::ResNet50, 20.0, 400.0),
    (Model::Vgg19, 20.0, 300.0),
    (Model::Ssd, 25.0, 150.0),
    (Model::AlexNet, 15.0, 400.0),
    (Model::ResNet50, 30.0, 600.0),
    (Model::Vgg19, 30.0, 400.0),
    (Model::Ssd, 40.0, 50.0),
    (Model::AlexNet, 20.0, 800.0),
    (Model::ResNet50, 40.0, 200.0),
    (Model::Vgg19, 40.0, 200.0),
    (Model::Ssd, 55.0, 300.0),
];

/// The 12 paper workloads W1..W12.
pub fn app_workloads() -> Vec<WorkloadSpec> {
    APP_TABLE
        .iter()
        .enumerate()
        .map(|(i, &(m, slo, rate))| WorkloadSpec::new(i, m, slo, rate))
        .collect()
}

/// The Table-1 illustrative trio (Sec. 2.3): A/R/V with SLOs 15/40/60 ms
/// and rates 500/400/200 req/s.
pub fn table1_workloads() -> Vec<WorkloadSpec> {
    vec![
        WorkloadSpec::new(0, Model::AlexNet, 15.0, 500.0),
        WorkloadSpec::new(1, Model::ResNet50, 40.0, 400.0),
        WorkloadSpec::new(2, Model::Vgg19, 60.0, 200.0),
    ]
}

/// Split one workload's arrival stream into `k` even rate shares — the
/// per-replica traffic split used when a single gpulet (or a whole weaker
/// GPU) cannot sustain the workload's rate.  The SLO is unchanged: every
/// replica must individually meet the latency target on its share.
pub fn replica_shares(spec: &WorkloadSpec, k: usize) -> Vec<WorkloadSpec> {
    let k = k.max(1);
    (0..k)
        .map(|i| {
            let mut s = spec.clone();
            s.rate_rps = spec.rate_rps / k as f64;
            if k > 1 {
                s.name = format!("{}#{}", spec.name, i + 1);
            }
            s
        })
        .collect()
}

/// Request arrival process for one workload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalKind {
    /// Constant spacing at the nominal rate (paper's client behaviour).
    Constant,
    /// Poisson process at the nominal rate.
    Poisson,
}

/// Generates arrival times (ms) for a workload.
#[derive(Debug, Clone)]
pub struct ArrivalGen {
    pub kind: ArrivalKind,
    pub rate_rps: f64,
    rng: Rng,
    next_ms: f64,
}

impl ArrivalGen {
    pub fn new(kind: ArrivalKind, rate_rps: f64, seed: u64) -> ArrivalGen {
        ArrivalGen {
            kind,
            rate_rps,
            rng: Rng::new(seed),
            next_ms: 0.0,
        }
    }

    /// Next arrival timestamp (ms since start), monotone increasing.
    pub fn next(&mut self) -> f64 {
        let gap_ms = match self.kind {
            ArrivalKind::Constant => 1000.0 / self.rate_rps,
            ArrivalKind::Poisson => self.rng.exp(self.rate_rps / 1000.0),
        };
        self.next_ms += gap_ms;
        self.next_ms
    }
}

/// One workload's arrival process as the serving event loop consumes it:
/// either a steady `ArrivalGen` at the spec's nominal rate or a
/// `trace::TracedArrivalGen` whose instantaneous rate follows a
/// `RateTrace` (the closed-loop autoscaling scenarios).
#[derive(Debug, Clone)]
pub enum ArrivalStream {
    Steady(ArrivalGen),
    Traced(trace::TracedArrivalGen),
}

impl ArrivalStream {
    /// Next arrival timestamp (ms since start), monotone increasing.
    pub fn next(&mut self) -> f64 {
        match self {
            ArrivalStream::Steady(g) => g.next(),
            ArrivalStream::Traced(g) => g.next(),
        }
    }
}

/// Arrival timestamps pre-generated per refill, amortizing the
/// per-arrival enum dispatch + RNG call over a chunk.
pub const ARRIVAL_CHUNK: usize = 64;

/// Batched front-end over an [`ArrivalStream`]: `next()` serves from a
/// pre-generated chunk of [`ARRIVAL_CHUNK`] timestamps and refills
/// lazily.  Bit-identical to calling the stream directly — a generator's
/// state depends only on its own draw sequence, never on *when* the
/// consumer asks — so batching reorders nothing.
#[derive(Debug, Clone)]
pub struct ArrivalBuffer {
    stream: ArrivalStream,
    buf: Vec<f64>,
    pos: usize,
}

impl ArrivalBuffer {
    pub fn new(stream: ArrivalStream) -> ArrivalBuffer {
        ArrivalBuffer {
            stream,
            buf: Vec::with_capacity(ARRIVAL_CHUNK),
            pos: 0,
        }
    }

    /// Replace the underlying stream, discarding any buffered (not yet
    /// consumed) timestamps from the old one.
    pub fn set_stream(&mut self, stream: ArrivalStream) {
        self.stream = stream;
        self.buf.clear();
        self.pos = 0;
    }

    /// Next arrival timestamp (ms since start), monotone increasing.
    pub fn next(&mut self) -> f64 {
        if self.pos == self.buf.len() {
            self.buf.clear();
            for _ in 0..ARRIVAL_CHUNK {
                let t = self.stream.next();
                self.buf.push(t);
            }
            self.pos = 0;
        }
        let t = self.buf[self.pos];
        self.pos += 1;
        t
    }
}

/// Per-model feasible envelope `(slo_lo_ms, slo_hi_ms, rate_lo_rps,
/// rate_hi_rps)` — the Fig.-21 synthetic distribution, provisionable on
/// the stronger GPU at full resources.  Single source for both
/// `synthetic_workloads` and the sweep scenario generator
/// (`sweep::scenario`): tune a band here and every consumer follows.
pub fn envelope(model: Model) -> (f64, f64, f64, f64) {
    match model {
        Model::AlexNet => (10.0, 25.0, 200.0, 1200.0),
        Model::ResNet50 => (20.0, 45.0, 100.0, 600.0),
        Model::Vgg19 => (25.0, 60.0, 50.0, 400.0),
        Model::Ssd => (30.0, 60.0, 30.0, 300.0),
    }
}

/// Synthetic workload sets for scalability studies (Fig. 21): `n` workloads
/// cycling through the zoo with randomized-but-feasible SLOs and rates.
pub fn synthetic_workloads(n: usize, seed: u64) -> Vec<WorkloadSpec> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let model = crate::gpu::ALL_MODELS[i % crate::gpu::ALL_MODELS.len()];
            let (slo_lo, slo_hi, rate_lo, rate_hi) = envelope(model);
            WorkloadSpec::new(
                i,
                model,
                rng.range_f64(slo_lo, slo_hi),
                rng.range_f64(rate_lo, rate_hi).round(),
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twelve_workloads() {
        let w = app_workloads();
        assert_eq!(w.len(), 12);
        assert_eq!(w[0].name, "W1(alexnet)");
        assert_eq!(w[11].name, "W12(ssd)");
        assert_eq!(w[9].slo_ms, 40.0); // W10 = App3 ResNet-50
        assert_eq!(w[3].rate_rps, 150.0); // W4 = App1 SSD
    }

    #[test]
    fn replica_shares_preserve_total_rate_and_slo() {
        let spec = WorkloadSpec::new(3, Model::Ssd, 25.0, 450.0);
        let shares = replica_shares(&spec, 3);
        assert_eq!(shares.len(), 3);
        let total: f64 = shares.iter().map(|s| s.rate_rps).sum();
        assert!((total - 450.0).abs() < 1e-9);
        assert!(shares.iter().all(|s| s.slo_ms == 25.0));
        assert_eq!(shares[0].name, "W4(ssd)#1");
        assert_eq!(shares[2].name, "W4(ssd)#3");
        // k = 1 keeps the original name and rate
        let one = replica_shares(&spec, 1);
        assert_eq!(one[0].name, spec.name);
        assert_eq!(one[0].rate_rps, 450.0);
    }

    #[test]
    fn constant_arrivals_are_evenly_spaced() {
        let mut g = ArrivalGen::new(ArrivalKind::Constant, 500.0, 1);
        let t1 = g.next();
        let t2 = g.next();
        assert!((t1 - 2.0).abs() < 1e-9);
        assert!((t2 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn poisson_rate_approximately_right() {
        let mut g = ArrivalGen::new(ArrivalKind::Poisson, 400.0, 7);
        let mut last = 0.0;
        let n = 20_000;
        for _ in 0..n {
            last = g.next();
        }
        let measured = n as f64 / (last / 1000.0);
        assert!(
            (measured - 400.0).abs() < 15.0,
            "measured rate {measured:.1}"
        );
    }

    #[test]
    fn buffered_arrivals_match_the_unbuffered_stream() {
        for kind in [ArrivalKind::Constant, ArrivalKind::Poisson] {
            let mut raw = ArrivalStream::Steady(ArrivalGen::new(kind, 350.0, 99));
            let mut buffered =
                ArrivalBuffer::new(ArrivalStream::Steady(ArrivalGen::new(kind, 350.0, 99)));
            // cross several chunk boundaries
            for i in 0..(ARRIVAL_CHUNK * 3 + 7) {
                let a = raw.next();
                let b = buffered.next();
                assert_eq!(a.to_bits(), b.to_bits(), "{kind:?} arrival {i}");
            }
        }
    }

    #[test]
    fn set_stream_discards_buffered_arrivals() {
        let mut buffered = ArrivalBuffer::new(ArrivalStream::Steady(ArrivalGen::new(
            ArrivalKind::Constant,
            1000.0,
            1,
        )));
        buffered.next(); // forces a chunk of the old stream into the buffer
        buffered.set_stream(ArrivalStream::Steady(ArrivalGen::new(
            ArrivalKind::Constant,
            500.0,
            1,
        )));
        // first arrival of the NEW stream, not a leftover 1 ms gap
        assert!((buffered.next() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn synthetic_deterministic_and_sized() {
        let a = synthetic_workloads(100, 3);
        let b = synthetic_workloads(100, 3);
        assert_eq!(a, b);
        assert_eq!(a.len(), 100);
        assert!(a.iter().all(|w| w.slo_ms > 0.0 && w.rate_rps > 0.0));
    }
}
