//! Layer-3 serving coordinator, decomposed into a composable pipeline:
//!
//! * `router`  — request routing across a workload's replica group
//!   (least-outstanding-requests, weighted-by-resources);
//! * `batcher` — the Triton-style adaptive batcher behind `BatchPolicy`;
//! * `monitor` — SLO monitor actions behind `ServingPolicy` (iGniter
//!   shadow failover, GSLICE reactive tuner, static);
//! * `server`  — the deterministic discrete-event loop (`ClusterSim`)
//!   that owns devices + replica state and delegates every decision;
//! * `realrun` — the real-compute bridge to the PJRT runtime.

pub mod batcher;
pub mod monitor;
pub mod realrun;
pub mod router;
pub mod server;

pub use batcher::{BatchDecision, BatchPolicy, BatchView, EagerBatcher, TritonAdaptive};
pub use monitor::{
    GsliceTuner, PolicyCtx, ServingPolicy, ShadowFailover, StaticPolicy, MONITOR_PERIOD_MS,
    SHADOW_EXTRA,
};
pub use router::{RouteStrategy, Router};
pub use server::{ClusterSim, Policy, ReplicaState, TimelinePoint, WorkloadStats};
