//! Layer-3 serving coordinator: deterministic discrete-event serving of a
//! provisioning plan (router + dynamic batcher + SLO monitor + shadow
//! failover + GSLICE tuner) and the real-compute bridge to the PJRT
//! runtime.

pub mod realrun;
pub mod server;

pub use server::{ClusterSim, Policy, TimelinePoint, WorkloadStats};
