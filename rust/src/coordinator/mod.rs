//! Layer-3 serving coordinator, decomposed into a composable pipeline:
//!
//! * `router`    — request routing across a workload's replica group
//!   (least-outstanding-requests, weighted-by-resources);
//! * `batcher`   — the Triton-style adaptive batcher behind `BatchPolicy`;
//! * `estimator` — online per-workload arrival-rate EWMA + sustained
//!   drift detection (the sensing half of the closed loop);
//! * `monitor`   — SLO monitor actions behind `ServingPolicy` (iGniter
//!   shadow failover, GSLICE reactive tuner, static, and the closed-loop
//!   `Reprovisioner` that re-plans drifted workloads online);
//! * `server`    — the deterministic discrete-event loop (`ClusterSim`)
//!   that owns devices + replica state, delegates every decision, and
//!   realizes plan-deltas via shadow-instance migration (warm up, switch
//!   over, drain before retire);
//! * `realrun`   — the real-compute bridge to the PJRT runtime.

pub mod batcher;
pub mod estimator;
pub mod monitor;
pub mod realrun;
pub mod replicas;
pub mod router;
pub mod server;

pub use batcher::{BatchDecision, BatchPolicy, BatchView, EagerBatcher, TritonAdaptive};
pub use estimator::{Drift, RateEstimator};
pub use monitor::{
    GsliceTuner, PolicyCtx, Reprovisioner, Resilience, ServingPolicy, ShadowFailover,
    StaticPolicy, BREAKER_PROBATION_MS, DEFAULT_SAFETY, EXEC_OBS_SPAN_MS, HANG_TIMEOUT_MS,
    MONITOR_PERIOD_MS, SHADOW_EXTRA, STRAGGLER_TRIP_MULT,
};
pub use replicas::{ReplicaPhase, ReplicaSet, WINDOW_SPAN_MS};
pub use router::{RouteStrategy, Router};
pub use server::{
    dropped_requests, ClusterSim, Policy, TimelinePoint, WorkloadStats, MIGRATION_WARMUP_MS,
};
