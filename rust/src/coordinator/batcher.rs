//! The dynamic batcher, extracted behind the `BatchPolicy` trait so
//! batch-formation policy is a swappable component rather than an enum
//! arm baked into the event loop.
//!
//! The default `TritonAdaptive` policy mirrors Triton's dynamic batching:
//! dispatch as soon as the preferred batch size is reached, or when the
//! oldest queued request has waited out the `max_queue_delay` — here the
//! slack of the half-SLO after the (rolling) batch execution estimate.

/// What the batcher may observe about one replica's queue.
#[derive(Debug, Clone, Copy)]
pub struct BatchView {
    /// Requests currently waiting (not yet dispatched).
    pub queue_len: usize,
    /// Arrival time (ms) of the oldest waiting request.
    pub oldest_arrival: Option<f64>,
    /// Configured (preferred) batch size of the replica.
    pub max_batch: u32,
    /// The workload's latency SLO (ms).
    pub slo_ms: f64,
    /// Rolling estimate of batch execution latency (ms).
    pub exec_estimate_ms: f64,
}

/// Outcome of a batching decision.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BatchDecision {
    /// Dispatch a batch of this many requests now.
    Dispatch(u32),
    /// Hold; re-evaluate at this absolute virtual time (ms).
    Wait(f64),
    /// Queue empty — nothing to do until the next arrival.
    Idle,
}

/// A batch-formation policy: pure decision logic, no queue ownership.
pub trait BatchPolicy {
    fn name(&self) -> &'static str;
    /// Decide for one idle replica at virtual time `now`.
    fn decide(&self, now: f64, view: &BatchView) -> BatchDecision;
}

/// Triton-style adaptive batching: full batch or queue-delay timeout.
#[derive(Debug, Clone, Copy, Default)]
pub struct TritonAdaptive;

impl TritonAdaptive {
    /// Dynamic batching timeout: the slack of the half-SLO after the
    /// estimated execution time (Triton's max_queue_delay), floored so a
    /// pessimistic estimate cannot wedge the queue.
    pub fn timeout_ms(view: &BatchView) -> f64 {
        (view.slo_ms / 2.0 - view.exec_estimate_ms).max(0.1)
    }
}

impl BatchPolicy for TritonAdaptive {
    fn name(&self) -> &'static str {
        "triton-adaptive"
    }

    fn decide(&self, now: f64, view: &BatchView) -> BatchDecision {
        let Some(oldest) = view.oldest_arrival else {
            return BatchDecision::Idle;
        };
        let n = view.queue_len.min(view.max_batch as usize) as u32;
        if n == 0 {
            return BatchDecision::Idle;
        }
        let timeout = Self::timeout_ms(view);
        let full = view.queue_len >= view.max_batch as usize;
        if full || now - oldest >= timeout {
            BatchDecision::Dispatch(n)
        } else {
            BatchDecision::Wait(oldest + timeout)
        }
    }
}

/// Degenerate baseline: dispatch whatever is queued immediately (batch
/// size still capped).  Exists to prove the policy seam and to measure
/// what adaptive batching buys.
#[derive(Debug, Clone, Copy, Default)]
pub struct EagerBatcher;

impl BatchPolicy for EagerBatcher {
    fn name(&self) -> &'static str {
        "eager"
    }

    fn decide(&self, _now: f64, view: &BatchView) -> BatchDecision {
        let n = view.queue_len.min(view.max_batch as usize) as u32;
        if n == 0 {
            BatchDecision::Idle
        } else {
            BatchDecision::Dispatch(n)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(queue_len: usize, oldest: Option<f64>) -> BatchView {
        BatchView {
            queue_len,
            oldest_arrival: oldest,
            max_batch: 8,
            slo_ms: 40.0,
            exec_estimate_ms: 10.0,
        }
    }

    #[test]
    fn empty_queue_is_idle() {
        assert_eq!(TritonAdaptive.decide(5.0, &view(0, None)), BatchDecision::Idle);
        assert_eq!(EagerBatcher.decide(5.0, &view(0, None)), BatchDecision::Idle);
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        let d = TritonAdaptive.decide(100.0, &view(8, Some(99.9)));
        assert_eq!(d, BatchDecision::Dispatch(8));
        // over-full queue still capped at max_batch
        let d = TritonAdaptive.decide(100.0, &view(20, Some(99.9)));
        assert_eq!(d, BatchDecision::Dispatch(8));
    }

    #[test]
    fn partial_batch_waits_until_timeout() {
        // timeout = 40/2 - 10 = 10 ms after the oldest arrival
        let d = TritonAdaptive.decide(100.0, &view(3, Some(95.0)));
        assert_eq!(d, BatchDecision::Wait(105.0));
        // once the oldest request has aged past the timeout: dispatch
        let d = TritonAdaptive.decide(105.0, &view(3, Some(95.0)));
        assert_eq!(d, BatchDecision::Dispatch(3));
    }

    #[test]
    fn timeout_floored_for_pessimistic_estimates() {
        let v = BatchView {
            exec_estimate_ms: 100.0, // way past the half-SLO
            ..view(2, Some(50.0))
        };
        assert!((TritonAdaptive::timeout_ms(&v) - 0.1).abs() < 1e-12);
        assert_eq!(TritonAdaptive.decide(50.2, &v), BatchDecision::Dispatch(2));
    }

    #[test]
    fn eager_dispatches_anything() {
        assert_eq!(EagerBatcher.decide(0.0, &view(1, Some(0.0))), BatchDecision::Dispatch(1));
        assert_eq!(EagerBatcher.decide(0.0, &view(30, Some(0.0))), BatchDecision::Dispatch(8));
    }
}
