//! Real-compute serving bridge: drives the PJRT runtime with actual
//! batched inference requests following a provisioning plan's batch
//! configuration, proving the three layers compose (Pallas kernels inside
//! JAX models, AOT-lowered to HLO, executed from the Rust hot path with
//! Python nowhere in sight).
//!
//! Virtual-time performance numbers come from `server::ClusterSim`
//! (calibrated to the paper's V100 testbed); this module reports the
//! *wall-clock* CPU cost of the real compute separately.

use crate::provisioner::{Plan, WorkloadSpec};
use crate::runtime::Engine;
use crate::util::rng::Rng;
use crate::util::stats::OnlineStats;
use crate::util::error::{anyhow, Result};
use std::time::Instant;

/// Wall-clock serving report for one workload.
#[derive(Debug, Clone)]
pub struct RealRunStats {
    pub name: String,
    pub model: String,
    pub batch: u32,
    pub batches_run: u32,
    pub requests: u64,
    /// wall-clock per batch (ms)
    pub mean_batch_ms: f64,
    pub p_like_max_ms: f64,
    /// wall-clock throughput (req/s) of the real compute
    pub wall_rps: f64,
    /// mean |logit| as a sanity signal that real numerics flowed
    pub mean_abs_output: f64,
}

/// Execute `batches_per_workload` real batches for every allocation of
/// the plan (one run per replica — a workload split across several
/// gpulets exercises each replica's batch variant) through the compiled
/// HLO executables.
pub fn serve_real(
    engine: &mut Engine,
    plan: &Plan,
    specs: &[WorkloadSpec],
    batches_per_workload: u32,
    seed: u64,
) -> Result<Vec<RealRunStats>> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    let mut replica_no = vec![0usize; specs.len()];
    for (_, alloc) in plan.all() {
        let spec = &specs[alloc.workload];
        let k = plan.replica_count(alloc.workload);
        replica_no[alloc.workload] += 1;
        let label = if k > 1 {
            format!("{}#{}", spec.name, replica_no[alloc.workload])
        } else {
            spec.name.clone()
        };
        let model_name = spec.model.name();
        let art = engine
            .manifest()
            .model(model_name)
            .ok_or_else(|| anyhow!("model {model_name} missing from artifacts"))?
            .clone();
        let variant = art
            .variant_for(alloc.batch as usize)
            .ok_or_else(|| anyhow!("no variant for batch {}", alloc.batch))?
            .clone();
        engine.load_variant(model_name, variant.batch)?;
        let lv = engine.variant(model_name, variant.batch).unwrap();

        let per_req = art.input_elems_per_request();
        let n = (alloc.batch as usize).min(variant.batch);
        let mut stats = OnlineStats::new();
        let mut out_mag = OnlineStats::new();
        let mut served = 0u64;
        for _ in 0..batches_per_workload {
            let input: Vec<f32> = (0..n * per_req)
                .map(|_| rng.f64() as f32)
                .collect();
            let t0 = Instant::now();
            let y = lv.execute_padded(&input, n)?;
            stats.push(t0.elapsed().as_secs_f64() * 1e3);
            served += n as u64;
            let mag: f64 =
                y.iter().map(|v| v.abs() as f64).sum::<f64>() / y.len().max(1) as f64;
            out_mag.push(mag);
        }
        out.push(RealRunStats {
            name: label,
            model: model_name.to_string(),
            batch: alloc.batch,
            batches_run: batches_per_workload,
            requests: served,
            mean_batch_ms: stats.mean(),
            p_like_max_ms: stats.max(),
            wall_rps: served as f64 / (stats.mean() * batches_per_workload as f64) * 1e3,
            mean_abs_output: out_mag.mean(),
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuKind;
    use crate::provisioner::{self, ProfiledSystem};
    use crate::runtime::Manifest;
    use crate::workload::table1_workloads;
    use std::path::Path;

    #[test]
    fn real_serving_composes() {
        if !crate::runtime::PJRT_AVAILABLE {
            eprintln!("skipping: PJRT runtime stubbed");
            return;
        }
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let (hw, wls) = crate::profiler::profile_all(GpuKind::V100, 42);
        let sys = ProfiledSystem {
            hw,
            coeffs: crate::gpu::ALL_MODELS.iter().cloned().zip(wls).collect(),
        };
        let specs = table1_workloads();
        let plan = provisioner::provision(&sys, &specs);
        let manifest = Manifest::load(&dir).unwrap();
        let mut engine = Engine::new(manifest).unwrap();
        let stats = serve_real(&mut engine, &plan, &specs, 2, 99).unwrap();
        assert_eq!(stats.len(), 3);
        for st in &stats {
            assert!(st.requests > 0);
            assert!(st.mean_batch_ms > 0.0);
            assert!(
                st.mean_abs_output > 1e-3,
                "{}: outputs look like zeros",
                st.model
            );
        }
    }
}
