//! SLO monitoring policies behind the `ServingPolicy` trait: the iGniter
//! shadow failover (Sec. 4.2 "Dealing with Performance Prediction
//! Errors"), the GSLICE reactive threshold tuner, the static
//! no-adjustment baseline, and the closed-loop `Reprovisioner` (Sec. 5.3:
//! periodically re-provision only the workloads whose arrival rate
//! drifted, migrating them via shadow instances).
//!
//! A policy observes per-replica latency windows on every monitor tick
//! (and optional tuner period) through `PolicyCtx`, and may act on the
//! devices — grow a partition, kill/relaunch a process.  A policy may
//! also return `PlanDelta`s from `reprovision`; the event loop realizes
//! them (shadow warm-up, drain-before-retire) without knowing which
//! policy asked.  `server.rs` knows nothing about any specific policy.
//!
//! Replica state arrives as the struct-of-arrays [`ReplicaSet`]: a
//! policy's per-tick scan (phase filter + one window read) walks two
//! dense arrays instead of striding over whole replica structs.

use super::estimator::{Drift, RateEstimator};
use super::replicas::{ReplicaPhase, ReplicaSet};
use crate::gpu::GpuDevice;
use crate::perfmodel::{rel_error, CalibratedModel};
use crate::provisioner::{diff_plans, OnlinePlanner, Plan, PlanDelta, ProfiledSystem, WorkloadSpec};

/// Extra GPU resources granted to an activated shadow process: the smaller
/// of 10 % (the paper's measured max prediction error) and the remaining
/// resources on the device.
pub const SHADOW_EXTRA: f64 = 0.10;
/// SLO monitor period (paper: clients evaluate every second, iGniter
/// re-checks 0.5 s after a violation).
pub const MONITOR_PERIOD_MS: f64 = 500.0;
/// Minimum samples in a window before a P99 verdict is trusted.
pub const MIN_P99_SAMPLES: usize = 20;

/// Mutable view a policy gets on monitor/tune ticks.
pub struct PolicyCtx<'a> {
    pub devices: &'a mut [GpuDevice],
    pub replicas: &'a mut ReplicaSet,
}

/// Per-workload resilience switches a serving policy grants the event
/// loop (all off by default — fault-free serving is bit-identical to the
/// pre-fault-lane behaviour):
///
/// * `breaker` — run the straggler/hang detector each monitor tick; an
///   open breaker routes arrivals around the sick replica, a confirmed
///   hang is condemned (force-retired, queue re-homed) and replaced.
/// * `shed` — on a degraded group, drop an arrival at admission when the
///   best replica's expected drain already blows twice the SLO budget
///   (counted in `WorkloadStats::dropped`, never silent).
/// * `hedge` — on a degraded group, route by deterministic two-choice on
///   expected drain time instead of raw queue depth.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Resilience {
    pub breaker: bool,
    pub shed: bool,
    pub hedge: bool,
}

impl Resilience {
    /// Everything off (the default for every policy).
    pub const OFF: Resilience = Resilience {
        breaker: false,
        shed: false,
        hedge: false,
    };
    /// Everything on (the chaos sweep lane).
    pub const ALL: Resilience = Resilience {
        breaker: true,
        shed: true,
        hedge: true,
    };
}

/// An online serving policy applied while the event loop runs.
pub trait ServingPolicy {
    fn name(&self) -> &'static str;
    /// Called on every request arrival with its workload id (rate-sensing
    /// policies feed their estimators here; default: ignore).
    fn on_arrival(&mut self, _now: f64, _workload: usize) {}
    /// Called every `MONITOR_PERIOD_MS`.
    fn on_monitor(&mut self, _now: f64, _ctx: &mut PolicyCtx) {}
    /// Called every `MONITOR_PERIOD_MS`, after `on_monitor`: plan deltas
    /// the event loop must realize via in-place resize or shadow-instance
    /// migration (default: none).
    fn reprovision(&mut self, _now: f64, _ctx: &mut PolicyCtx) -> Vec<PlanDelta> {
        Vec::new()
    }
    /// Period of dedicated tune ticks, if the policy wants them.
    fn tune_period_ms(&self) -> Option<f64> {
        None
    }
    /// Called every `tune_period_ms()` when `Some`.
    fn on_tune(&mut self, _now: f64, _ctx: &mut PolicyCtx) {}
    /// Model-vs-observation relative latency errors the policy recorded
    /// over the run (empty unless the policy tracks predictions — see
    /// `Reprovisioner`).  Consumers: the sweep report's
    /// mean/p95-prediction-error metrics and the calibration experiment.
    fn prediction_errors(&self) -> &[f64] {
        &[]
    }
    /// `(performed placements, planning wall ms)` the policy's embedded
    /// planner accumulated over the run — the serving-side inputs of the
    /// sweep's `wall.plan_throughput_pps`.  Default: `(0, 0.0)` for
    /// policies that never re-plan.
    fn planning_activity(&self) -> (u64, f64) {
        (0, 0.0)
    }
    /// Resilience switches granted to workload `w` (default: all off —
    /// the event loop's fault-free paths stay bit-identical).
    fn resilience(&self, _workload: usize) -> Resilience {
        Resilience::OFF
    }
    /// MIG slice reconfigurations the policy's embedded planner performed
    /// on live devices over the run — the sweep's fragmentation-churn
    /// metric.  Default: 0 (continuous systems and planner-less policies).
    fn reconfigurations(&self) -> u64 {
        0
    }
}

/// Static plan: no runtime adjustment.
#[derive(Debug, Clone, Copy, Default)]
pub struct StaticPolicy;

impl ServingPolicy for StaticPolicy {
    fn name(&self) -> &'static str {
        "static"
    }
}

/// iGniter shadow failover: per replica, when the 1-second P99 violates
/// the SLO, kill the process and activate the pre-launched standby with
/// extra resources (capped by the device's free room).  One switch per
/// replica.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShadowFailover;

impl ShadowFailover {
    fn activate(ctx: &mut PolicyCtx, p: usize) {
        let reps = &mut *ctx.replicas;
        let gpu = reps.gpu[p];
        let tag = reps.tag[p];
        let free = ctx.devices[gpu].free_resources();
        let extra = SHADOW_EXTRA.min(free);
        let new_r = reps.resources[p] + extra;
        ctx.devices[gpu].kill(tag);
        // shadow takes over under the same tag with the grown partition
        ctx.devices[gpu].launch_unchecked(tag, reps.spec[p].model, new_r, reps.batch[p]);
        reps.resources[p] = new_r;
        reps.resources_dirty.push(p);
        reps.shadow_active[p] = true;
        reps.switches[p] += 1;
        // restart the latency records: the new process starts clean, so
        // final stats (P99 / achieved rate) describe the post-switch
        // process — the pre-switch violations are what the switch fixed
        reps.clear_records(p);
    }
}

impl ServingPolicy for ShadowFailover {
    fn name(&self) -> &'static str {
        "igniter-shadow"
    }

    fn on_monitor(&mut self, now: f64, ctx: &mut PolicyCtx) {
        for p in 0..ctx.replicas.len() {
            if ctx.replicas.shadow_active[p] || ctx.replicas.phase[p] != ReplicaPhase::Active {
                continue; // one switch per replica; never touch a
                          // warming/draining/retired migration replica
            }
            if let Some(p99) =
                ctx.replicas.window[p].percentile_since(now - 1_000.0, 0.99, MIN_P99_SAMPLES)
            {
                if p99 > ctx.replicas.spec[p].slo_ms {
                    Self::activate(ctx, p);
                }
            }
        }
    }
}

/// GSLICE's reactive threshold tuner (interference-unaware): per replica,
/// grow when the observed 10-second average violates half the SLO, shrink
/// when it undershoots by the tuning threshold — ignoring co-residents
/// entirely (it may oversubscribe the device, which the hardware then
/// time-slices).
#[derive(Debug, Clone, Copy)]
pub struct GsliceTuner {
    /// adjustment period (ms)
    pub period_ms: f64,
}

impl ServingPolicy for GsliceTuner {
    fn name(&self) -> &'static str {
        "gslice-tuner"
    }

    fn tune_period_ms(&self) -> Option<f64> {
        Some(self.period_ms)
    }

    fn on_tune(&mut self, now: f64, ctx: &mut PolicyCtx) {
        for p in 0..ctx.replicas.len() {
            if ctx.replicas.phase[p] != ReplicaPhase::Active {
                continue;
            }
            let Some(avg) = ctx.replicas.window[p].mean_since(now - 10_000.0, 10) else {
                continue;
            };
            let half = ctx.replicas.spec[p].slo_ms / 2.0;
            let gpu = ctx.replicas.gpu[p];
            let tag = ctx.replicas.tag[p];
            let step = ctx.devices[gpu].spec.r_unit * 2.0;
            if avg > half {
                let r = ctx.replicas.resources[p] + step;
                // interference-unaware: force the grow regardless of room
                ctx.devices[gpu].force_resources(tag, r);
                ctx.replicas.resources[p] = r;
                ctx.replicas.resources_dirty.push(p);
            } else if avg < half * (1.0 - crate::provisioner::gslice::TUNING_THRESHOLD) {
                let r = (ctx.replicas.resources[p] - step).max(ctx.devices[gpu].spec.r_unit);
                ctx.devices[gpu].force_resources(tag, r);
                ctx.replicas.resources[p] = r;
                ctx.replicas.resources_dirty.push(p);
            }
        }
    }
}

/// Span of recent exec-latency observations fed to calibration and the
/// prediction-error telemetry (ms).
pub const EXEC_OBS_SPAN_MS: f64 = 2_000.0;

/// Observed rate above this fraction of the allocation's predicted
/// capacity counts as headroom collapse (re-plan before queues build).
pub const HEADROOM_COLLAPSE: f64 = 0.90;
/// Consecutive collapsed ticks before the headroom trigger fires.
pub const COLLAPSE_SUSTAIN: u32 = 2;
/// Default re-plan padding: allocations target `observed x this`, so the
/// plan keeps absorbing rate growth while the estimator chases it.
pub const DEFAULT_SAFETY: f64 = 1.2;

/// Breaker trip threshold: recent observed exec latency beyond this
/// multiple of the model's (corrected) prediction marks the replica a
/// straggler.  Chosen above the paper's ~15 % max prediction error but
/// below the smallest injected dilation (2x), so real stragglers trip
/// and healthy noise never does.
pub const STRAGGLER_TRIP_MULT: f64 = 1.9;
/// A replica busy on one batch for longer than
/// `max(HANG_TIMEOUT_MS, exec_estimate x HANG_ESTIMATE_MULT)` is a
/// confirmed hang: condemn it (no batch legitimately runs seconds).
pub const HANG_TIMEOUT_MS: f64 = 2_000.0;
pub const HANG_ESTIMATE_MULT: f64 = 6.0;
/// Quiet spell before an open (non-condemned) breaker closes and the
/// replica is readmitted to routing — long enough for a transient
/// straggler span to show up as recovered observations.
pub const BREAKER_PROBATION_MS: f64 = 1_500.0;

/// The closed re-provisioning loop (iGniter Sec. 5.3): per-workload
/// `RateEstimator`s sense sustained arrival-rate drift or predicted-SLO
/// headroom collapse; on a trigger the embedded `OnlinePlanner` re-plans
/// **only the drifted workload** (`OnlinePlanner::respec`) and the
/// resulting plan-delta is returned to the event loop, which realizes it
/// via in-place partition resizes or shadow-instance migration (warm up
/// the new replicas, drain the old).  A periodic `rebalance` re-packs the
/// whole active set when that releases devices.
pub struct Reprovisioner {
    planner: OnlinePlanner,
    /// serving workload id -> current planner id
    live_ids: Vec<usize>,
    estimators: Vec<RateEstimator>,
    collapse_ticks: Vec<u32>,
    last_migration_ms: Vec<f64>,
    last_rebalance_ms: f64,
    migrations_planned: u32,
    /// Online calibration: feed serving-observed exec latencies into the
    /// planner's `CalibratedModel` and re-plan when the *corrected* model
    /// predicts an SLO breach (off by default — the planner then keeps
    /// the static analytic model and behaves exactly as before).
    calibrate: bool,
    /// rel_error(model-predicted t_inf, observed exec) per (tick,
    /// workload) with observations — the prediction-error telemetry.
    pred_errors: Vec<f64>,
    /// Scratch reused by every tick's predicted-violation pass (avoids a
    /// fresh `vec![false; n]` per monitor period).
    violation_scratch: Vec<bool>,
    /// Scratch holding the pre-respec plan for `diff_plans` — absorbed
    /// via `Plan::copy_from` each trigger instead of a fresh deep clone.
    plan_scratch: Plan,
    /// Wall time spent inside the embedded planner's respec/rebalance
    /// calls (ms) — the denominator side of `wall.plan_throughput_pps`.
    /// Measurement only: never feeds a placement or simulation decision.
    plan_wall_ms: f64,
    /// Devices whose death has already been failed over (the sim keeps a
    /// dead device in `ctx.devices` forever; react exactly once).
    dead_seen: Vec<bool>,
    /// Memoized `capacity_rps` results (workload -> Some(result)).
    /// `predict` is a pure function of the planner's plan and model, so
    /// the cache is flushed whenever either can change (every respec /
    /// rebalance / fail_device; every tick when calibrating, since the
    /// model itself then moves) plus a periodic full-recompute backstop —
    /// pure memoization, bitwise inert, and the reason a quiet workload's
    /// step-2 pass is O(1) instead of a `predict_full` per tick.
    cap_cache: Vec<Option<Option<f64>>>,
    /// Monitor ticks seen (drives the periodic cache-flush backstop).
    ticks: u64,
    /// Append-only workload -> replica-ids index (ascending; `ReplicaSet`
    /// never removes entries, so it only ever extends).  Replaces the
    /// per-workload full-set scans in `observed_exec_ms` — same members,
    /// same order, O(group) instead of O(replicas) per workload.
    members_of: Vec<Vec<usize>>,
    /// Replicas already absorbed into `members_of`.
    members_seen: usize,
    /// Scratch: per-workload migration-in-flight flags, rebuilt in one
    /// O(replicas) pass per tick instead of one scan per workload.
    in_flight_scratch: Vec<bool>,
    /// Resilience switches granted to every workload (see `Resilience`;
    /// `OFF` keeps fault-free serving bit-identical).
    resilience: Resilience,
    /// Re-plan for `observed x safety` so the fresh allocation keeps
    /// headroom while the estimator chases a rising rate.
    pub safety: f64,
    /// Per-workload cooldown between re-plans (ms).
    pub min_gap_ms: f64,
    /// Period of whole-cluster re-pack attempts (ms); 0 disables.
    pub rebalance_period_ms: f64,
}

impl Reprovisioner {
    /// `specs`/`plan` must be the set the plan was provisioned for — the
    /// estimators treat each spec's rate as its planned design point.
    pub fn new(sys: ProfiledSystem, specs: Vec<WorkloadSpec>, plan: Plan) -> Reprovisioner {
        let n = specs.len();
        let estimators = specs.iter().map(|s| RateEstimator::new(s.rate_rps)).collect();
        let plan_scratch = plan.clone();
        Reprovisioner {
            planner: OnlinePlanner::from_plan(sys, specs, plan),
            live_ids: (0..n).collect(),
            estimators,
            collapse_ticks: vec![0; n],
            last_migration_ms: vec![f64::NEG_INFINITY; n],
            last_rebalance_ms: 0.0,
            migrations_planned: 0,
            calibrate: false,
            pred_errors: Vec::new(),
            violation_scratch: Vec::new(),
            plan_scratch,
            plan_wall_ms: 0.0,
            dead_seen: Vec::new(),
            cap_cache: vec![None; n],
            ticks: 0,
            members_of: vec![Vec::new(); n],
            members_seen: 0,
            in_flight_scratch: Vec::new(),
            resilience: Resilience::OFF,
            safety: DEFAULT_SAFETY,
            // three monitor ticks: short enough to track a steep diurnal
            // slope step-by-step, long enough to stop per-tick churn
            min_gap_ms: 1_500.0,
            rebalance_period_ms: 10_000.0,
        }
    }

    /// Enable online calibration: the embedded planner re-plans with a
    /// `CalibratedModel` whose residual corrections are fit (recursive
    /// least squares) from the exec latencies the serving loop observes —
    /// the closed-loop answer to model mismatch (the Fig.-17 story made
    /// proactive).  With zero observations the calibrated model is
    /// bitwise the analytic one, so enabling this changes nothing until
    /// real observations diverge from the predictions.
    pub fn with_calibration(mut self) -> Reprovisioner {
        self.calibrate = true;
        self.planner.set_model(Box::new(CalibratedModel::new()));
        self
    }

    /// Is online calibration enabled?
    pub fn calibrating(&self) -> bool {
        self.calibrate
    }

    /// Grant resilience switches to every workload (the chaos lane passes
    /// `Resilience::ALL`).  Off by default.
    pub fn with_resilience(mut self, r: Resilience) -> Reprovisioner {
        self.resilience = r;
        self
    }

    /// Observations absorbed by the planner's model (0 when static).
    pub fn model_observations(&self) -> u64 {
        self.planner.model().observations()
    }

    /// Number of **plan-changing** re-plans (drift/violation respecs +
    /// adopted rebalances) so far; respecs that reproduce the standing
    /// placement are not counted.
    pub fn migrations_planned(&self) -> u32 {
        self.migrations_planned
    }

    /// The planner's current view of the cluster.
    pub fn plan(&self) -> &Plan {
        self.planner.plan()
    }

    /// Smoothed observed arrival rate of a serving workload (req/s).
    pub fn observed_rps(&self, workload: usize) -> f64 {
        self.estimators[workload].rate_rps()
    }

    /// Predicted capacity (req/s) of a workload's current allocation,
    /// memoized against the plan/model state (see `cap_cache`).
    fn capacity_rps(&mut self, workload: usize) -> Option<f64> {
        if let Some(cached) = self.cap_cache[workload] {
            return cached;
        }
        let id = self.live_ids[workload];
        let val = self
            .planner
            .predict(id)
            .map(|(_, thpt)| thpt * self.planner.plan().replica_count(id).max(1) as f64);
        self.cap_cache[workload] = Some(val);
        val
    }

    /// Drop every memoized capacity: the plan or the model is about to
    /// change (or just did), so cached predictions are no longer provably
    /// equal to fresh ones.
    fn flush_capacity_cache(&mut self) {
        self.cap_cache.fill(None);
    }

    /// Extend the append-only workload->members index over freshly
    /// launched replicas (`ReplicaSet` only ever appends).
    fn refresh_member_index(&mut self, reps: &ReplicaSet) {
        while self.members_seen < reps.len() {
            let p = self.members_seen;
            let w = reps.workload[p];
            if w < self.members_of.len() {
                self.members_of[w].push(p);
            }
            self.members_seen += 1;
        }
    }

    fn migration_in_flight(ctx: &PolicyCtx, workload: Option<usize>) -> bool {
        let reps = &*ctx.replicas;
        (0..reps.len()).any(|p| {
            workload.map_or(true, |w| reps.workload[p] == w)
                && matches!(reps.phase[p], ReplicaPhase::Warming | ReplicaPhase::Draining)
        })
    }

    /// Recent observed execution latency of workload `w` (ms): mean over
    /// its Active replicas' exec windows (dispatch -> completion + load,
    /// queueing excluded — directly comparable to predicted t_inf).
    /// Iterates only `w`'s members (same set, same ascending order as the
    /// full-set scan it replaced) and proves empty windows in O(1) via
    /// the newest-sample epoch, so a quiet workload costs O(members).
    fn observed_exec_ms(&self, ctx: &PolicyCtx, w: usize, now: f64) -> Option<f64> {
        let reps = &*ctx.replicas;
        let since = now - EXEC_OBS_SPAN_MS;
        let mut sum = 0.0;
        let mut n = 0u32;
        for &p in &self.members_of[w] {
            if p >= reps.len() {
                break; // index ran ahead of a test-harness replica set
            }
            if reps.phase[p] != ReplicaPhase::Active {
                continue;
            }
            if reps.exec_window[p].latest_t() < since {
                continue; // O(1): the since-filtered view is empty
            }
            if let Some(m) = reps.exec_window[p].mean_since(since, 1) {
                sum += m;
                n += 1;
            }
        }
        (n > 0).then(|| sum / n as f64)
    }

    /// Re-place workload `w` (serving index) for its currently observed
    /// rate with safety padding, bypassing the drift cooldown — shared by
    /// device-death failover and hang condemnation, where waiting out a
    /// cooldown means serving nothing.  Returns the plan-deltas to
    /// realize (empty when no feasible placement or nothing moved).
    fn respec_workload(&mut self, now: f64, w: usize) -> Vec<PlanDelta> {
        let observed = self.estimators[w].rate_rps();
        let target = (observed * self.safety).max(1.0);
        self.plan_scratch.copy_from(self.planner.plan());
        let t0 = std::time::Instant::now();
        let res = self.planner.respec(self.live_ids[w], target);
        self.plan_wall_ms += t0.elapsed().as_secs_f64() * 1e3;
        self.flush_capacity_cache();
        self.last_migration_ms[w] = now;
        let Ok((new_id, _)) = res else {
            return Vec::new();
        };
        let mut new_ids = self.live_ids.clone();
        new_ids[w] = new_id;
        let moved = diff_plans(&self.plan_scratch, self.planner.plan(), &self.live_ids, &new_ids);
        self.live_ids = new_ids;
        self.estimators[w].replanned(target);
        if !moved.is_empty() {
            self.migrations_planned += 1;
        }
        moved
    }

    /// Unplanned failover: a device the sim killed vanishes from the
    /// planner's world (`OnlinePlanner::fail_device`) and every workload
    /// that lost replicas on it is re-placed on the survivors — or on a
    /// freshly provisioned instance when nothing fits.  Reacts exactly
    /// once per dead device; a no-op while every device is healthy.
    fn check_failover(&mut self, now: f64, ctx: &mut PolicyCtx) -> Vec<PlanDelta> {
        let mut deltas = Vec::new();
        for g in 0..ctx.devices.len() {
            if !ctx.devices[g].is_dead() {
                continue;
            }
            if self.dead_seen.len() <= g {
                self.dead_seen.resize(g + 1, false);
            }
            if self.dead_seen[g] {
                continue;
            }
            self.dead_seen[g] = true;
            let t0 = std::time::Instant::now();
            let victims = self.planner.fail_device(g);
            self.plan_wall_ms += t0.elapsed().as_secs_f64() * 1e3;
            self.flush_capacity_cache();
            for id in victims {
                if let Some(w) = self.live_ids.iter().position(|&v| v == id) {
                    deltas.extend(self.respec_workload(now, w));
                }
            }
        }
        deltas
    }

    /// Straggler/hang detection, one pass per monitor tick (only when
    /// `resilience.breaker` is granted).  Stragglers — recent observed
    /// exec far past the (corrected) prediction — get an open breaker:
    /// routed around, readmitted after probation.  Hangs — busy on one
    /// batch beyond any plausible span — are condemned; the sim
    /// force-retires them and the replacement respec is returned here.
    fn run_breakers(&mut self, now: f64, ctx: &mut PolicyCtx) -> Vec<PlanDelta> {
        let mut deltas = Vec::new();
        for p in 0..ctx.replicas.len() {
            if ctx.replicas.phase[p] != ReplicaPhase::Active
                || ctx.replicas.lost[p]
                || ctx.replicas.condemned[p]
            {
                continue;
            }
            let w = ctx.replicas.workload[p];
            if w >= self.live_ids.len() {
                continue;
            }
            let hang_after =
                HANG_TIMEOUT_MS.max(ctx.replicas.exec_estimate[p] * HANG_ESTIMATE_MULT);
            if ctx.replicas.busy[p] && now - ctx.replicas.busy_since[p] > hang_after {
                ctx.replicas.condemned[p] = true;
                ctx.replicas.breaker_open[p] = true;
                ctx.replicas.breaker_since[p] = now;
                if !Self::migration_in_flight(ctx, Some(w)) {
                    deltas.extend(self.respec_workload(now, w));
                }
                continue;
            }
            if ctx.replicas.breaker_open[p] {
                // probation: give the replica a quiet spell, then readmit
                if now - ctx.replicas.breaker_since[p] >= BREAKER_PROBATION_MS {
                    ctx.replicas.breaker_open[p] = false;
                }
                continue;
            }
            if ctx.replicas.exec_window[p].latest_t() < now - EXEC_OBS_SPAN_MS {
                continue; // O(1) proof the window scan below would find nothing
            }
            let Some(obs) = ctx.replicas.exec_window[p].mean_since(now - EXEC_OBS_SPAN_MS, 2)
            else {
                continue;
            };
            let Some((raw, corrected)) = self.planner.predict_full(self.live_ids[w]) else {
                continue;
            };
            let pred = if self.calibrate { corrected.t_inf } else { raw.t_inf };
            if obs > pred * STRAGGLER_TRIP_MULT {
                ctx.replicas.breaker_open[p] = true;
                ctx.replicas.breaker_since[p] = now;
            }
        }
        deltas
    }
}

impl ServingPolicy for Reprovisioner {
    fn name(&self) -> &'static str {
        "reprovisioner"
    }

    fn on_arrival(&mut self, now: f64, workload: usize) {
        self.estimators[workload].on_arrival(now);
    }

    fn reprovision(&mut self, now: f64, ctx: &mut PolicyCtx) -> Vec<PlanDelta> {
        // Extend the append-only member index over replicas launched since
        // the last tick, and refresh the capacity memo: under calibration
        // the model absorbs observations every tick (predictions move), and
        // a periodic unconditional flush backstops any mutation path the
        // explicit flush sites might miss.
        self.refresh_member_index(ctx.replicas);
        self.ticks = self.ticks.wrapping_add(1);
        if self.calibrate || self.ticks % 16 == 0 {
            self.flush_capacity_cache();
        }

        // 0'. fault lane first: unplanned failover for freshly dead
        //     devices (always on — an outage is not drift and skips the
        //     cooldown), then breaker maintenance when granted.  Both are
        //     exact no-ops in fault-free serving.
        let mut fault_deltas = self.check_failover(now, ctx);
        if self.resilience.breaker {
            fault_deltas.extend(self.run_breakers(now, ctx));
        }

        // 0. one prediction pass per workload: error telemetry, and (when
        //    calibrating) the model feed plus the predicted-violation
        //    flags step 2 consumes.  The error series is recorded
        //    unconditionally — it is pure telemetry — but only the
        //    calibrated model absorbs observations, so with calibration
        //    off the serving behaviour is exactly the pre-calibration
        //    one.  The flags are sampled before this tick's observations
        //    update the fit (one-tick lag, well inside the re-plan
        //    cooldown) so each workload costs a single `predict_full` —
        //    which builds a device view per call — instead of two.
        let mut predicted_violation = std::mem::take(&mut self.violation_scratch);
        predicted_violation.clear();
        predicted_violation.resize(self.estimators.len(), false);
        for w in 0..self.estimators.len() {
            let observed = self.observed_exec_ms(ctx, w, now);
            if observed.is_none() && !self.calibrate {
                continue; // nothing to record, no trigger to arm
            }
            let id = self.live_ids[w];
            // Prediction side of the pairing.  When calibrating, the fit's
            // correctness requires the group mean: the observation side
            // averages every Active replica, and replicas under different
            // co-location would otherwise bias the residual.  With
            // calibration off this is telemetry only, so the cheap
            // first-replica view keeps the default sweep's monitor tick
            // at its pre-calibration cost (predict_group_mean scans the
            // whole plan per workload; fine opt-in, not fine by default —
            // the group-mean-vs-first-replica pairing skew is then an
            // accepted telemetry approximation for replicated workloads).
            let pred = if self.calibrate {
                self.planner.predict_group_mean(id)
            } else {
                self.planner
                    .predict_full(id)
                    .map(|(r, c)| (r.t_inf, c.t_inf))
            };
            let Some((raw, corrected)) = pred else {
                continue;
            };
            if self.calibrate {
                // calibration-only trigger: the corrected model says this
                // allocation no longer meets the half-SLO design point
                // (the analytic model can never trip this — its own
                // alloc_gpus growth guarantees the bound at plan time)
                predicted_violation[w] =
                    corrected > self.planner.specs()[id].slo_ms / 2.0 + 1e-9;
            }
            if let Some(observed) = observed {
                self.pred_errors.push(rel_error(corrected, observed));
                if self.calibrate {
                    // train on the RAW analytic prediction: fitting
                    // against the already-corrected one would be
                    // self-referential
                    let key = self.planner.specs()[id].model.name();
                    self.planner.model_mut().observe(key, raw, observed);
                }
            }
        }

        // 1. tick every estimator (the EWMA must advance even for
        //    workloads that cannot act this tick)
        for est in &mut self.estimators {
            est.on_tick(now);
        }
        let mut deltas = fault_deltas;

        // One O(replicas) pass computes every workload's in-flight flag —
        // the exact predicate `migration_in_flight` evaluates, hoisted out
        // of the per-workload loop below (which paid O(W x R) per tick).
        let mut in_flight = std::mem::take(&mut self.in_flight_scratch);
        in_flight.clear();
        in_flight.resize(self.estimators.len(), false);
        let mut any_in_flight = false;
        {
            let reps = &*ctx.replicas;
            for p in 0..reps.len() {
                if matches!(reps.phase[p], ReplicaPhase::Warming | ReplicaPhase::Draining) {
                    any_in_flight = true;
                    let w = reps.workload[p];
                    if w < in_flight.len() {
                        in_flight[w] = true;
                    }
                }
            }
        }

        // 2. drift / headroom triggers, one workload at a time
        for w in 0..self.estimators.len() {
            let observed = self.estimators[w].rate_rps();
            // collapse = the observed rate is eating into the allocation's
            // predicted capacity.  On a safety-padded plan (capacity ~=
            // 1.2x observed) this fires ~8% above the last design point —
            // before saturation.  On a plan provisioned with no pad it
            // fires once at the steady rate, the re-plan establishes the
            // pad, and the loop goes quiet (cap then > observed / 0.9).
            let collapsed = self
                .capacity_rps(w)
                .map_or(false, |cap| observed > cap * HEADROOM_COLLAPSE);
            self.collapse_ticks[w] = if collapsed { self.collapse_ticks[w] + 1 } else { 0 };
            if now - self.last_migration_ms[w] < self.min_gap_ms {
                continue;
            }
            if in_flight[w] {
                continue; // one migration per workload at a time
            }
            let drift = self.estimators[w].sustained_drift();
            let predicted_violation = predicted_violation[w];
            if drift.is_none() && self.collapse_ticks[w] < COLLAPSE_SUSTAIN && !predicted_violation
            {
                continue;
            }
            // Down-drift re-plans are lazy by construction (DOWN_DRIFT
            // hysteresis in the estimator); up-drift and collapse are
            // eager.  Re-plan only this workload, for the observed rate
            // plus safety headroom — falling back toward the bare
            // observed rate when the padded target is infeasible on one
            // gpulet (near a workload's peak), and never churning on a
            // target that would not actually change the design point.
            let planned = self.estimators[w].planned_rps();
            let candidates = [
                (observed * self.safety).max(1.0),
                (observed * 1.05).max(1.0),
                observed.max(1.0),
            ];
            let mut adopted = None;
            self.plan_scratch.copy_from(self.planner.plan());
            for &target in &candidates {
                // a predicted violation re-plans even at an unchanged (or
                // gently declining) design point: the goal is a
                // re-*sized* placement under the corrected model, not a
                // new rate target — without it, a mild Down drift would
                // gate every candidate and leave the breach standing
                let gains = predicted_violation
                    || if drift == Some(Drift::Down) {
                        target < planned
                    } else {
                        target > planned * 1.02
                    };
                if !gains {
                    break;
                }
                let t0 = std::time::Instant::now();
                let res = self.planner.respec(self.live_ids[w], target);
                self.plan_wall_ms += t0.elapsed().as_secs_f64() * 1e3;
                self.flush_capacity_cache();
                if let Ok((new_id, _)) = res {
                    adopted = Some((new_id, target));
                    break;
                }
            }
            self.collapse_ticks[w] = 0;
            self.last_migration_ms[w] = now; // cooldown even on no-op
            if let Some((new_id, target)) = adopted {
                let mut new_ids = self.live_ids.clone();
                new_ids[w] = new_id;
                let moved =
                    diff_plans(&self.plan_scratch, self.planner.plan(), &self.live_ids, &new_ids);
                self.live_ids = new_ids;
                self.estimators[w].replanned(target);
                // count only plan-*changing* re-plans: a respec that
                // reproduces the same placement (e.g. a best-effort
                // allocation the corrected model still predicts past the
                // SLO — nothing further to do) must not inflate the
                // migrations metric every cooldown period
                if !moved.is_empty() {
                    self.migrations_planned += 1;
                    deltas.extend(moved);
                }
            }
        }

        // 3. periodic whole-cluster re-pack, only in quiet moments
        if self.rebalance_period_ms > 0.0
            && now - self.last_rebalance_ms >= self.rebalance_period_ms
            && deltas.is_empty()
            && !any_in_flight
        {
            self.last_rebalance_ms = now;
            self.plan_scratch.copy_from(self.planner.plan());
            let t0 = std::time::Instant::now();
            let rebalanced = self.planner.rebalance();
            self.plan_wall_ms += t0.elapsed().as_secs_f64() * 1e3;
            self.flush_capacity_cache();
            if rebalanced.is_some() {
                let moved = diff_plans(
                    &self.plan_scratch,
                    self.planner.plan(),
                    &self.live_ids,
                    &self.live_ids,
                );
                for d in &moved {
                    if let PlanDelta::Migrate(m) = d {
                        self.last_migration_ms[m.workload] = now;
                    }
                }
                if !moved.is_empty() {
                    self.migrations_planned += 1;
                }
                deltas.extend(moved);
            }
        }
        // park the scratch buffers for next tick's reuse
        self.violation_scratch = predicted_violation;
        self.in_flight_scratch = in_flight;
        deltas
    }

    fn prediction_errors(&self) -> &[f64] {
        &self.pred_errors
    }

    fn planning_activity(&self) -> (u64, f64) {
        (self.planner.placements(), self.plan_wall_ms)
    }

    fn resilience(&self, _workload: usize) -> Resilience {
        self.resilience
    }

    fn reconfigurations(&self) -> u64 {
        self.planner.reconfigurations()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuKind;
    use crate::provisioner::{self, PlanDelta};
    use crate::workload::table1_workloads;
    use std::sync::Arc;

    fn sys() -> ProfiledSystem {
        let (hw, wls) = crate::profiler::profile_all(GpuKind::V100, 42);
        ProfiledSystem {
            hw,
            coeffs: crate::gpu::ALL_MODELS.iter().cloned().zip(wls).collect(),
        }
    }

    /// Drive the reprovisioner directly (no event loop): feed every
    /// workload constant arrivals at `rates[w]` over the tick range,
    /// carrying per-workload arrival clocks in `t_next`.
    fn drive(
        rp: &mut Reprovisioner,
        rates: &[f64],
        ticks: std::ops::RangeInclusive<u32>,
        t_next: &mut [f64],
    ) -> Vec<PlanDelta> {
        let mut devices: Vec<GpuDevice> = Vec::new();
        let mut replicas = ReplicaSet::new();
        let mut out = Vec::new();
        for tick in ticks {
            let now = tick as f64 * MONITOR_PERIOD_MS;
            for (w, &rate) in rates.iter().enumerate() {
                let gap = 1000.0 / rate;
                while t_next[w] < now {
                    rp.on_arrival(t_next[w], w);
                    t_next[w] += gap;
                }
            }
            let mut ctx = PolicyCtx {
                devices: &mut devices,
                replicas: &mut replicas,
            };
            out.extend(rp.reprovision(now, &mut ctx));
        }
        out
    }

    fn planned_rates(specs: &[crate::provisioner::WorkloadSpec]) -> Vec<f64> {
        specs.iter().map(|s| s.rate_rps).collect()
    }

    #[test]
    fn reprovisioner_replans_on_sustained_up_drift() {
        let s = sys();
        let specs = table1_workloads();
        let plan = provisioner::provision(&s, &specs);
        let mut rp = Reprovisioner::new(s, specs.clone(), plan);
        rp.rebalance_period_ms = 0.0; // isolate the drift path
        // W1 (planned 500 rps) observes a sustained 1000 rps; the others
        // stay at their design points
        let mut rates = planned_rates(&specs);
        rates[0] = 1000.0;
        let mut clocks = vec![0.0; specs.len()];
        let deltas = drive(&mut rp, &rates, 1..=24, &mut clocks);
        assert!(rp.migrations_planned() >= 1, "never re-planned");
        assert!(
            deltas.iter().any(|d| match d {
                PlanDelta::Migrate(m) => m.workload == 0,
                PlanDelta::Resize { workload, .. } => *workload == 0,
            }),
            "no delta for the drifted workload: {deltas:?}"
        );
        // the new design point covers the observed rate with headroom
        assert!(rp.observed_rps(0) > 900.0, "ewma {}", rp.observed_rps(0));
        // ...and its allocation is predicted-SLO feasible
        let cap = rp.capacity_rps(0).expect("workload lost its allocation");
        assert!(cap >= 1000.0 * 0.999, "capacity {cap:.0} below observed");
    }

    #[test]
    fn reprovisioner_shrinks_on_sustained_down_drift() {
        let s = sys();
        let specs = table1_workloads();
        let plan = provisioner::provision(&s, &specs);
        let before_alloc = plan.find(0).unwrap().1.resources;
        let mut rp = Reprovisioner::new(s, specs.clone(), plan);
        rp.rebalance_period_ms = 0.0;
        // W1 collapses to a tenth of its planned rate
        let mut rates = planned_rates(&specs);
        rates[0] = 50.0;
        let mut clocks = vec![0.0; specs.len()];
        let deltas = drive(&mut rp, &rates, 1..=24, &mut clocks);
        assert!(rp.migrations_planned() >= 1, "never re-planned");
        assert!(!deltas.is_empty());
        let after = rp.plan().replicas(rp.live_ids[0]);
        assert_eq!(after.len(), 1);
        assert!(
            after[0].1.resources < before_alloc - 1e-9,
            "allocation did not shrink: {} -> {}",
            before_alloc,
            after[0].1.resources
        );
    }

    #[test]
    fn calibration_learns_slowdown_and_replans_proactively() {
        // Simulate a world whose true exec latency runs 1.4x the analytic
        // prediction at every operating point (a coefficient-mismatch
        // regime): the calibrated reprovisioner must learn the residual
        // from the observed exec stream, trip the predicted-violation
        // trigger, and grow W1's allocation until the *corrected* model
        // meets the half-SLO again — all without any rate drift.
        let s = sys();
        let specs = table1_workloads();
        let plan = provisioner::provision(&s, &specs);
        let (gpu0, alloc0) = plan.find(0).unwrap();
        let r_before = alloc0.resources;
        let mut rp = Reprovisioner::new(s, specs.clone(), plan.clone()).with_calibration();
        rp.rebalance_period_ms = 0.0;
        assert!(rp.calibrating());

        let mut devices: Vec<GpuDevice> = Vec::new();
        let mut replicas = ReplicaSet::new();
        replicas.launch(
            Arc::new(specs[0].clone()),
            0,
            gpu0,
            0,
            alloc0.resources,
            alloc0.batch,
            ReplicaPhase::Active,
        );
        let rates = planned_rates(&specs);
        let mut clocks = vec![0.0; specs.len()];
        for tick in 1..=24u32 {
            let now = tick as f64 * MONITOR_PERIOD_MS;
            // ground truth: observed exec = 1.4x the analytic prediction
            // of the *current* allocation
            let raw_now = rp.planner.predict_full(rp.live_ids[0]).unwrap().0;
            replicas.exec_window[0].push(now, raw_now.t_inf * 1.4);
            for (w, &rate) in rates.iter().enumerate() {
                let gap = 1000.0 / rate;
                while clocks[w] < now {
                    rp.on_arrival(clocks[w], w);
                    clocks[w] += gap;
                }
            }
            let mut ctx = PolicyCtx {
                devices: &mut devices,
                replicas: &mut replicas,
            };
            let _ = rp.reprovision(now, &mut ctx);
        }

        assert!(
            rp.model_observations() >= crate::perfmodel::MIN_OBSERVATIONS,
            "only {} observations absorbed",
            rp.model_observations()
        );
        assert!(!rp.prediction_errors().is_empty());
        assert!(
            rp.migrations_planned() >= 1,
            "calibration never triggered a re-plan"
        );
        // the corrected prediction of the re-planned allocation is back
        // inside the design point, and the allocation actually grew
        let id = rp.live_ids[0];
        let (_, corrected) = rp.planner.predict_full(id).unwrap();
        assert!(
            corrected.t_inf <= specs[0].slo_ms / 2.0 * 1.05,
            "corrected t_inf {:.2} still past half-SLO",
            corrected.t_inf
        );
        let r_after: f64 = rp.plan().replicas(id).iter().map(|(_, a)| a.resources).sum();
        assert!(
            r_after > r_before + 1e-9,
            "allocation did not grow: {r_before} -> {r_after}"
        );
    }

    #[test]
    fn uncalibrated_reprovisioner_ignores_the_observation_stream() {
        // Same mismatch world, calibration off: the error telemetry still
        // records, but the model absorbs nothing and no predicted-
        // violation re-plan fires (rate steady, capacity believed fine).
        let s = sys();
        let specs = table1_workloads();
        let plan = provisioner::provision(&s, &specs);
        let (gpu0, alloc0) = plan.find(0).unwrap();
        let mut rp = Reprovisioner::new(s, specs.clone(), plan.clone());
        rp.rebalance_period_ms = 0.0;
        assert!(!rp.calibrating());
        let mut devices: Vec<GpuDevice> = Vec::new();
        let mut replicas = ReplicaSet::new();
        replicas.launch(
            Arc::new(specs[0].clone()),
            0,
            gpu0,
            0,
            alloc0.resources,
            alloc0.batch,
            ReplicaPhase::Active,
        );
        let rates = planned_rates(&specs);
        let mut clocks = vec![0.0; specs.len()];
        for tick in 1..=12u32 {
            let now = tick as f64 * MONITOR_PERIOD_MS;
            let raw_now = rp.planner.predict_full(rp.live_ids[0]).unwrap().0;
            replicas.exec_window[0].push(now, raw_now.t_inf * 1.4);
            for (w, &rate) in rates.iter().enumerate() {
                let gap = 1000.0 / rate;
                while clocks[w] < now {
                    rp.on_arrival(clocks[w], w);
                    clocks[w] += gap;
                }
            }
            let mut ctx = PolicyCtx {
                devices: &mut devices,
                replicas: &mut replicas,
            };
            let _ = rp.reprovision(now, &mut ctx);
        }
        assert_eq!(rp.model_observations(), 0);
        assert!(!rp.prediction_errors().is_empty(), "telemetry must record");
        // the recorded errors sit at the injected residual:
        // |pred - obs| / obs = 0.4 / 1.4 for a constant 1.4x slowdown
        let mean: f64 =
            rp.prediction_errors().iter().sum::<f64>() / rp.prediction_errors().len() as f64;
        assert!((0.25..0.33).contains(&mean), "mean error {mean:.3}");
    }

    #[test]
    fn dead_device_triggers_cooldown_free_failover() {
        // Kill device 0 of a freshly provisioned fleet at t = 100 ms —
        // far inside the drift cooldown.  Every workload resident on it
        // must be re-placed immediately, and never onto the dead device.
        let s = sys();
        let specs = table1_workloads();
        let plan = provisioner::provision(&s, &specs);
        let n_gpus = plan.num_gpus();
        let victims: Vec<usize> = plan.gpus[0].iter().map(|a| a.workload).collect();
        assert!(!victims.is_empty(), "fixture: device 0 must host someone");
        let mut rp = Reprovisioner::new(s, specs.clone(), plan);
        rp.rebalance_period_ms = 0.0;
        let mut devices: Vec<GpuDevice> =
            (0..n_gpus).map(|g| GpuDevice::new(GpuKind::V100, g as u64)).collect();
        devices[0].fail();
        let mut replicas = ReplicaSet::new();
        let deltas = rp.reprovision(
            100.0,
            &mut PolicyCtx {
                devices: &mut devices,
                replicas: &mut replicas,
            },
        );
        for &w in &victims {
            assert!(
                deltas
                    .iter()
                    .any(|d| matches!(d, PlanDelta::Migrate(m) if m.workload == w)),
                "victim {w} was not re-placed: {deltas:?}"
            );
        }
        for d in &deltas {
            if let PlanDelta::Migrate(m) = d {
                assert!(
                    m.to.iter().all(|(g, _)| *g != 0),
                    "replacement landed on the dead device: {m:?}"
                );
            }
        }
        assert!(rp.migrations_planned() >= victims.len() as u32);
        // the death is reacted to exactly once
        let again = rp.reprovision(
            600.0,
            &mut PolicyCtx {
                devices: &mut devices,
                replicas: &mut replicas,
            },
        );
        assert!(
            again
                .iter()
                .all(|d| !matches!(d, PlanDelta::Migrate(m) if victims.contains(&m.workload))),
            "second tick re-failed the same device: {again:?}"
        );
    }

    #[test]
    fn breaker_trips_on_stragglers_and_condemns_hangs() {
        let s = sys();
        let specs = table1_workloads();
        let plan = provisioner::provision(&s, &specs);
        let (gpu0, alloc0) = plan.find(0).unwrap();
        let (gpu1, alloc1) = plan.find(1).unwrap();
        let mut rp =
            Reprovisioner::new(s, specs.clone(), plan.clone()).with_resilience(Resilience::ALL);
        rp.rebalance_period_ms = 0.0;
        let mut devices: Vec<GpuDevice> = Vec::new();
        let mut replicas = ReplicaSet::new();
        replicas.launch(
            Arc::new(specs[0].clone()),
            0,
            gpu0,
            0,
            alloc0.resources,
            alloc0.batch,
            ReplicaPhase::Active,
        );
        // straggling observations: 3x the model's prediction
        let raw = rp.planner.predict_full(0).unwrap().0.t_inf;
        replicas.exec_window[0].push(400.0, raw * 3.0);
        replicas.exec_window[0].push(450.0, raw * 3.0);
        rp.reprovision(
            500.0,
            &mut PolicyCtx {
                devices: &mut devices,
                replicas: &mut replicas,
            },
        );
        assert!(replicas.breaker_open[0], "straggler never tripped");
        assert!(!replicas.condemned[0], "a straggler is not a hang");
        assert_eq!(replicas.breaker_since[0], 500.0);
        // probation: with the bad window aged out, the breaker closes
        rp.reprovision(
            2_500.0,
            &mut PolicyCtx {
                devices: &mut devices,
                replicas: &mut replicas,
            },
        );
        assert!(!replicas.breaker_open[0], "probation never closed it");
        // hang: a replica wedged on one batch far past any plausible span
        replicas.launch(
            Arc::new(specs[1].clone()),
            1,
            gpu1,
            1,
            alloc1.resources,
            alloc1.batch,
            ReplicaPhase::Active,
        );
        replicas.busy[1] = true;
        replicas.busy_since[1] = 500.0;
        let deltas = rp.reprovision(
            4_000.0,
            &mut PolicyCtx {
                devices: &mut devices,
                replicas: &mut replicas,
            },
        );
        assert!(replicas.condemned[1], "hang never condemned");
        assert!(replicas.breaker_open[1]);
        assert!(
            deltas
                .iter()
                .any(|d| matches!(d, PlanDelta::Migrate(m) if m.workload == 1)),
            "condemnation did not spawn a replacement: {deltas:?}"
        );
    }

    #[test]
    fn reprovisioner_steady_rate_converges_and_goes_quiet() {
        // At a steady rate the loop may re-plan the fed workload at most
        // once — establishing its safety pad on a plan that was
        // provisioned without one — and must then stay quiet: once
        // capacity ~= observed x safety, neither drift nor headroom
        // collapse can re-trigger.
        let s = sys();
        let specs = table1_workloads();
        let plan = provisioner::provision(&s, &specs);
        let mut rp = Reprovisioner::new(s, specs.clone(), plan);
        rp.rebalance_period_ms = 0.0;
        let rates = planned_rates(&specs);
        let mut clocks = vec![0.0; specs.len()];
        drive(&mut rp, &rates, 1..=24, &mut clocks);
        let settled = rp.migrations_planned();
        assert!(
            settled <= specs.len() as u32,
            "steady rates churned {settled} re-plans"
        );
        // a further long stretch at the same rates changes nothing
        let late = drive(&mut rp, &rates, 25..=48, &mut clocks);
        assert!(late.is_empty(), "late churn: {late:?}");
        assert_eq!(rp.migrations_planned(), settled);
    }
}
