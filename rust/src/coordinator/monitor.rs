//! SLO monitoring policies behind the `ServingPolicy` trait: the iGniter
//! shadow failover (Sec. 4.2 "Dealing with Performance Prediction
//! Errors"), the GSLICE reactive threshold tuner, and the static
//! no-adjustment baseline.
//!
//! A policy observes per-replica latency windows on every monitor tick
//! (and optional tuner period) through `PolicyCtx`, and may act on the
//! devices — grow a partition, kill/relaunch a process.  The event loop
//! in `server.rs` knows nothing about any specific policy.

use super::server::ReplicaState;
use crate::gpu::GpuDevice;

/// Extra GPU resources granted to an activated shadow process: the smaller
/// of 10 % (the paper's measured max prediction error) and the remaining
/// resources on the device.
pub const SHADOW_EXTRA: f64 = 0.10;
/// SLO monitor period (paper: clients evaluate every second, iGniter
/// re-checks 0.5 s after a violation).
pub const MONITOR_PERIOD_MS: f64 = 500.0;
/// Minimum samples in a window before a P99 verdict is trusted.
pub const MIN_P99_SAMPLES: usize = 20;

/// Mutable view a policy gets on monitor/tune ticks.
pub struct PolicyCtx<'a> {
    pub devices: &'a mut [GpuDevice],
    pub replicas: &'a mut [ReplicaState],
}

/// An online serving policy applied while the event loop runs.
pub trait ServingPolicy {
    fn name(&self) -> &'static str;
    /// Called every `MONITOR_PERIOD_MS`.
    fn on_monitor(&mut self, _now: f64, _ctx: &mut PolicyCtx) {}
    /// Period of dedicated tune ticks, if the policy wants them.
    fn tune_period_ms(&self) -> Option<f64> {
        None
    }
    /// Called every `tune_period_ms()` when `Some`.
    fn on_tune(&mut self, _now: f64, _ctx: &mut PolicyCtx) {}
}

/// Static plan: no runtime adjustment.
#[derive(Debug, Clone, Copy, Default)]
pub struct StaticPolicy;

impl ServingPolicy for StaticPolicy {
    fn name(&self) -> &'static str {
        "static"
    }
}

/// iGniter shadow failover: per replica, when the 1-second P99 violates
/// the SLO, kill the process and activate the pre-launched standby with
/// extra resources (capped by the device's free room).  One switch per
/// replica.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShadowFailover;

impl ShadowFailover {
    fn activate(ctx: &mut PolicyCtx, p: usize) {
        let gpu = ctx.replicas[p].gpu;
        let tag = ctx.replicas[p].tag;
        let free = ctx.devices[gpu].free_resources();
        let extra = SHADOW_EXTRA.min(free);
        let new_r = ctx.replicas[p].resources + extra;
        ctx.devices[gpu].kill(tag);
        // shadow takes over under the same tag with the grown partition
        ctx.devices[gpu].launch_unchecked(
            tag,
            ctx.replicas[p].spec.model,
            new_r,
            ctx.replicas[p].batch,
        );
        let rep = &mut ctx.replicas[p];
        rep.resources = new_r;
        rep.shadow_active = true;
        rep.switches += 1;
        // restart the latency records: the new process starts clean, so
        // final stats (P99 / achieved rate) describe the post-switch
        // process — the pre-switch violations are what the switch fixed
        rep.window.clear();
        rep.hist.clear();
        rep.recorded = 0;
        rep.lat_sum = 0.0;
        rep.queue_sum = 0.0;
        rep.exec_sum = 0.0;
    }
}

impl ServingPolicy for ShadowFailover {
    fn name(&self) -> &'static str {
        "igniter-shadow"
    }

    fn on_monitor(&mut self, now: f64, ctx: &mut PolicyCtx) {
        for p in 0..ctx.replicas.len() {
            if ctx.replicas[p].shadow_active {
                continue; // one switch per replica
            }
            let rep = &ctx.replicas[p];
            if let Some(p99) = rep
                .window
                .percentile_since(now - 1_000.0, 0.99, MIN_P99_SAMPLES)
            {
                if p99 > rep.spec.slo_ms {
                    Self::activate(ctx, p);
                }
            }
        }
    }
}

/// GSLICE's reactive threshold tuner (interference-unaware): per replica,
/// grow when the observed 10-second average violates half the SLO, shrink
/// when it undershoots by the tuning threshold — ignoring co-residents
/// entirely (it may oversubscribe the device, which the hardware then
/// time-slices).
#[derive(Debug, Clone, Copy)]
pub struct GsliceTuner {
    /// adjustment period (ms)
    pub period_ms: f64,
}

impl ServingPolicy for GsliceTuner {
    fn name(&self) -> &'static str {
        "gslice-tuner"
    }

    fn tune_period_ms(&self) -> Option<f64> {
        Some(self.period_ms)
    }

    fn on_tune(&mut self, now: f64, ctx: &mut PolicyCtx) {
        for p in 0..ctx.replicas.len() {
            let rep = &ctx.replicas[p];
            let Some(avg) = rep.window.mean_since(now - 10_000.0, 10) else {
                continue;
            };
            let half = rep.spec.slo_ms / 2.0;
            let gpu = rep.gpu;
            let tag = rep.tag;
            let step = ctx.devices[gpu].spec.r_unit * 2.0;
            if avg > half {
                let r = rep.resources + step;
                // interference-unaware: force the grow regardless of room
                ctx.devices[gpu].force_resources(tag, r);
                ctx.replicas[p].resources = r;
            } else if avg < half * (1.0 - crate::provisioner::gslice::TUNING_THRESHOLD) {
                let r = (rep.resources - step).max(ctx.devices[gpu].spec.r_unit);
                ctx.devices[gpu].force_resources(tag, r);
                ctx.replicas[p].resources = r;
            }
        }
    }
}
