//! Struct-of-arrays replica state.  The monitor tick and the timeline
//! sampler scan *every* replica once per period, but each scan touches
//! only two or three fields — with an array-of-structs layout every
//! touch dragged a whole ~300-byte `ReplicaState` cache line in.  Here
//! each field lives in its own dense `Vec`, so a phase scan walks one
//! byte-per-replica array and the hot dispatch path (`busy`, `batch`,
//! `exec_estimate`, `queue`) stays within a few contiguous lines.
//!
//! Request timestamps are NOT stored here: queues are [`ReqQueue`]
//! handles into the sim-wide [`crate::sim::slab::RequestSlab`] arena.
//! Workload specs are shared via `Arc` — launching a migration replica
//! clones a pointer, not a `String`.

use crate::provisioner::WorkloadSpec;
use crate::sim::slab::ReqQueue;
use crate::util::stats::{LatencyHistogram, SlidingWindow};
use std::sync::Arc;

/// Latency-window span (ms): long enough for the slowest consumer (the
/// GSLICE tuner reads 10 s), bounded so monitor scans never grow with the
/// total served count.
pub const WINDOW_SPAN_MS: f64 = 10_000.0;

/// Lifecycle of a serving replica under shadow-instance migration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaPhase {
    /// Receiving and serving traffic.
    Active,
    /// Freshly launched migration target: loaded on the device but not
    /// yet routable (model load / context warm-up in progress).
    Warming,
    /// Replaced by a migration: receives no new arrivals, finishes its
    /// queued + in-flight requests, then retires.
    Draining,
    /// Drained and killed; kept for lifetime stats only.
    Retired,
}

/// All replicas' serving state, one parallel `Vec` per field (index =
/// global replica id).  Fields are public so `monitor::ServingPolicy`
/// implementations can act on them; disjoint-field mutable borrows
/// through one `&mut ReplicaSet` are legal, which the serving loop
/// leans on.
#[derive(Debug, Default)]
pub struct ReplicaSet {
    pub spec: Vec<Arc<WorkloadSpec>>,
    /// Workload id (index into the submitted specs).
    pub workload: Vec<usize>,
    pub gpu: Vec<usize>,
    /// Device process tag (globally unique replica index).
    pub tag: Vec<u64>,
    pub resources: Vec<f64>,
    pub batch: Vec<u32>,
    /// Waiting + in-flight request arrival times (popped on completion);
    /// handle into the sim's shared `RequestSlab`.
    pub queue: Vec<ReqQueue>,
    pub busy: Vec<bool>,
    /// rolling estimate of batch execution latency (ms) for the batcher
    pub exec_estimate: Vec<f64>,
    /// time-bounded latency records (completion time, latency)
    pub window: Vec<SlidingWindow>,
    /// time-bounded *execution-span* records (completion time, exec ms):
    /// dispatch -> completion + load, one entry per batch.  Queueing is
    /// excluded, so these are directly comparable to the performance
    /// model's t_inf — the observation stream the calibration layer
    /// (`monitor::Reprovisioner`) fits its residual corrections from.
    pub exec_window: Vec<SlidingWindow>,
    pub hist: Vec<LatencyHistogram>,
    pub served: Vec<u64>,
    /// post-warmup latency records and their component sums (ms)
    pub recorded: Vec<u64>,
    pub lat_sum: Vec<f64>,
    pub queue_sum: Vec<f64>,
    pub exec_sum: Vec<f64>,
    /// shadow process state (iGniter policy)
    pub shadow_active: Vec<bool>,
    pub switches: Vec<u32>,
    /// migration lifecycle phase
    pub phase: Vec<ReplicaPhase>,
    /// Fault state (sim-side): a hung replica accepts dispatches but its
    /// completions are suppressed until the breaker condemns it.
    pub hung: Vec<bool>,
    /// Force-retired by device death or condemnation: any stale
    /// `Complete`/`TryDispatch` events still in the calendar are ignored.
    pub lost: Vec<bool>,
    /// Breaker state (policy-side): an open breaker removes the replica
    /// from its group's routable set until probation closes it.
    pub breaker_open: Vec<bool>,
    pub breaker_since: Vec<f64>,
    /// When the current in-flight batch was dispatched (hang detection:
    /// busy for far longer than any plausible exec span trips the
    /// breaker).
    pub busy_since: Vec<f64>,
    /// Sim time this replica was launched — lets the recovery metric
    /// distinguish replacement capacity (launched after the fault) from
    /// survivors.
    pub launched_ms: Vec<f64>,
    /// Policy verdict: this replica is dead-to-us (hang confirmed);
    /// the sim force-retires it and re-queues its requests on the next
    /// breaker-enforcement pass.
    pub condemned: Vec<bool>,
    /// Set-level change log (NOT per-replica): replica ids whose
    /// `resources`/`batch` a policy wrote directly through `PolicyCtx`
    /// (shadow activation, GSLICE tuning) instead of via a plan-delta.
    /// The serving loop drains it after every policy hook and refreshes
    /// the affected groups' cached aggregates, keeping the idle-monitor
    /// fast path bitwise-identical to the full member walk.
    pub resources_dirty: Vec<usize>,
}

impl ReplicaSet {
    pub fn new() -> ReplicaSet {
        ReplicaSet::default()
    }

    pub fn len(&self) -> usize {
        self.workload.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workload.is_empty()
    }

    /// Append fresh serving-process state, shared by the initial plan
    /// launch and the migration shadow launch; returns the new replica's
    /// index.  A `Warming` replica starts busy so the batcher leaves it
    /// alone until switch-over opens it.
    #[allow(clippy::too_many_arguments)]
    pub fn launch(
        &mut self,
        spec: Arc<WorkloadSpec>,
        workload: usize,
        gpu: usize,
        tag: u64,
        resources: f64,
        batch: u32,
        phase: ReplicaPhase,
    ) -> usize {
        let p = self.len();
        self.workload.push(workload);
        self.gpu.push(gpu);
        self.tag.push(tag);
        self.resources.push(resources);
        self.batch.push(batch);
        self.queue.push(ReqQueue::new());
        self.busy.push(phase == ReplicaPhase::Warming);
        self.exec_estimate.push(spec.slo_ms / 4.0);
        self.window.push(SlidingWindow::new(WINDOW_SPAN_MS));
        self.exec_window.push(SlidingWindow::new(WINDOW_SPAN_MS));
        self.hist.push(LatencyHistogram::new());
        self.served.push(0);
        self.recorded.push(0);
        self.lat_sum.push(0.0);
        self.queue_sum.push(0.0);
        self.exec_sum.push(0.0);
        self.shadow_active.push(false);
        self.switches.push(0);
        self.phase.push(phase);
        self.hung.push(false);
        self.lost.push(false);
        self.breaker_open.push(false);
        self.breaker_since.push(0.0);
        self.busy_since.push(0.0);
        self.launched_ms.push(0.0);
        self.condemned.push(false);
        self.spec.push(spec);
        p
    }

    /// Reset replica `p`'s latency records — used by shadow failover when
    /// the relaunched process should be judged on fresh observations.
    pub fn clear_records(&mut self, p: usize) {
        self.window[p].clear();
        self.exec_window[p].clear();
        self.hist[p].clear();
        self.recorded[p] = 0;
        self.lat_sum[p] = 0.0;
        self.queue_sum[p] = 0.0;
        self.exec_sum[p] = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::Model;

    #[test]
    fn launch_appends_one_slot_per_field() {
        let mut set = ReplicaSet::new();
        let spec = Arc::new(WorkloadSpec::new(0, Model::AlexNet, 16.0, 400.0));
        let p = set.launch(Arc::clone(&spec), 0, 2, 7, 0.4, 4, ReplicaPhase::Active);
        assert_eq!(p, 0);
        assert_eq!(set.len(), 1);
        assert!(!set.busy[0], "Active launches idle");
        assert_eq!(set.exec_estimate[0], 4.0); // slo/4
        let q = set.launch(spec, 0, 3, 8, 0.2, 4, ReplicaPhase::Warming);
        assert_eq!(q, 1);
        assert!(set.busy[1], "Warming launches busy (batcher keep-out)");
        assert_eq!(set.gpu, vec![2, 3]);
        assert_eq!(set.tag, vec![7, 8]);
        // fault state launches clean
        assert!(!set.hung[0] && !set.lost[0] && !set.condemned[0]);
        assert!(!set.breaker_open[0]);
        assert_eq!(set.launched_ms, vec![0.0, 0.0]);
    }

    #[test]
    fn clear_records_resets_observations_only() {
        let mut set = ReplicaSet::new();
        let spec = Arc::new(WorkloadSpec::new(1, Model::Ssd, 40.0, 100.0));
        set.launch(spec, 1, 0, 0, 0.5, 8, ReplicaPhase::Active);
        set.window[0].push(100.0, 12.0);
        set.hist[0].record(0.012);
        set.recorded[0] = 1;
        set.lat_sum[0] = 12.0;
        set.served[0] = 5;
        set.switches[0] = 1;
        set.clear_records(0);
        assert_eq!(set.recorded[0], 0);
        assert_eq!(set.lat_sum[0], 0.0);
        assert!(set.window[0].mean_since(0.0, 1).is_none());
        // lifetime counters survive a record reset
        assert_eq!(set.served[0], 5);
        assert_eq!(set.switches[0], 1);
    }
}
