//! Request routing across a workload's replica group.
//!
//! The provisioner may place several allocations under one workload id
//! (a workload whose rate exceeds a single gpulet — see
//! `provisioner::igniter::replica_split`); at serving time every arrival
//! of that workload must be steered to exactly one replica.  Two
//! deterministic strategies:
//!
//! * `LeastOutstanding` — pick the replica with the fewest outstanding
//!   requests (waiting + in-flight), lowest replica index on ties.  This
//!   is the join-the-shortest-queue default: it adapts to transient
//!   imbalance (a replica slowed by co-runner interference drains less,
//!   so it receives less).
//! * `WeightedByResources` — smooth weighted round-robin keyed on each
//!   replica's current GPU partition, for heterogeneous replica sizes
//!   (e.g. after a shadow switch grew one replica).
//!
//! Both are pure functions of the observed state plus per-workload credit
//! counters, so identical seeds replay to identical routes.

/// Routing strategy across the replicas of one workload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteStrategy {
    /// Join the replica with the fewest outstanding requests.
    LeastOutstanding,
    /// Smooth weighted round-robin proportional to replica resources.
    WeightedByResources,
}

/// Per-workload routing state (credit counters for the weighted strategy).
#[derive(Debug, Clone)]
pub struct Router {
    strategy: RouteStrategy,
    /// credits[w][j]: accumulated weight of workload w's j-th replica.
    credits: Vec<Vec<f64>>,
}

impl Router {
    /// `group_sizes[w]` = number of replicas of workload `w`.
    pub fn new(strategy: RouteStrategy, group_sizes: &[usize]) -> Router {
        Router {
            strategy,
            credits: group_sizes.iter().map(|&n| vec![0.0; n]).collect(),
        }
    }

    /// Route one arrival of workload `w` to a member of `group` (global
    /// replica indices).  `outstanding(p)` and `weight(p)` observe the
    /// replica's queue depth and current resources.
    pub fn route<F, W>(&mut self, w: usize, group: &[usize], outstanding: F, weight: W) -> usize
    where
        F: Fn(usize) -> usize,
        W: Fn(usize) -> f64,
    {
        assert!(!group.is_empty(), "workload {w} has no replicas");
        if group.len() == 1 {
            return group[0];
        }
        match self.strategy {
            RouteStrategy::LeastOutstanding => {
                // min_by_key returns the first minimum: lowest replica
                // index wins ties, deterministically.
                *group.iter().min_by_key(|&&p| outstanding(p)).unwrap()
            }
            RouteStrategy::WeightedByResources => {
                let credits = &mut self.credits[w];
                // The eligible set changes across a shadow migration (new
                // replicas join, draining ones leave): restart the credit
                // walk at the new size.  Deterministic — a pure function
                // of the routed group sizes.
                if credits.len() != group.len() {
                    *credits = vec![0.0; group.len()];
                }
                let mut total = 0.0;
                for (j, &p) in group.iter().enumerate() {
                    // a replica always drains at least a floor share, so a
                    // zero-resource corner cannot starve the credit walk
                    let wgt = weight(p).max(1e-6);
                    credits[j] += wgt;
                    total += wgt;
                }
                let mut best = 0;
                for j in 1..credits.len() {
                    if credits[j] > credits[best] + 1e-12 {
                        best = j;
                    }
                }
                credits[best] -= total;
                group[best]
            }
        }
    }
}

impl Router {
    /// Hedged dispatch for degraded groups (a breaker is open somewhere
    /// in the workload): deterministic power-of-two-choices on *expected
    /// drain time* rather than raw queue depth.  The two members with the
    /// fewest outstanding requests are compared by `drain_ms(p)` (queue
    /// depth x exec estimate / batch) and the faster drainer wins — so a
    /// recovering-but-slow survivor is not flooded just because its queue
    /// momentarily looks short.  Pure in its inputs: lowest index wins
    /// every tie, no RNG.
    pub fn route_hedged<F, D>(&mut self, _w: usize, group: &[usize], outstanding: F, drain_ms: D) -> usize
    where
        F: Fn(usize) -> usize,
        D: Fn(usize) -> f64,
    {
        assert!(!group.is_empty(), "hedged route over an empty group");
        if group.len() == 1 {
            return group[0];
        }
        // first and second minima by outstanding count (first-index ties)
        let (mut a, mut b) = (group[0], usize::MAX);
        for &p in &group[1..] {
            if outstanding(p) < outstanding(a) {
                b = a;
                a = p;
            } else if b == usize::MAX || outstanding(p) < outstanding(b) {
                b = p;
            }
        }
        if b == usize::MAX {
            return a;
        }
        // hedge: between the two shortest queues, prefer the faster
        // drain; `a` (the earlier/shorter member) keeps exact ties
        if drain_ms(b).total_cmp(&drain_ms(a)) == std::cmp::Ordering::Less {
            b
        } else {
            a
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_replica_short_circuits() {
        let mut r = Router::new(RouteStrategy::LeastOutstanding, &[1]);
        assert_eq!(r.route(0, &[7], |_| 99, |_| 1.0), 7);
    }

    #[test]
    fn least_outstanding_picks_shortest_queue_with_fifo_ties() {
        let mut r = Router::new(RouteStrategy::LeastOutstanding, &[3]);
        let depths = [4usize, 2, 2];
        let picked = r.route(0, &[10, 11, 12], |p| depths[p - 10], |_| 1.0);
        assert_eq!(picked, 11, "first of the tied minima wins");
        let depths2 = [0usize, 2, 2];
        assert_eq!(r.route(0, &[10, 11, 12], |p| depths2[p - 10], |_| 1.0), 10);
    }

    #[test]
    fn weighted_credits_restart_on_group_size_change() {
        // Simulates a shadow switch: one replica group is replaced by a
        // two-replica group mid-run; the credit walk must adapt instead
        // of panicking or starving a member.
        let mut r = Router::new(RouteStrategy::WeightedByResources, &[1]);
        assert_eq!(r.route(0, &[0], |_| 0, |_| 0.5), 0);
        let weights = [0.0, 0.25, 0.25];
        let mut counts = [0usize; 3];
        for _ in 0..100 {
            let p = r.route(0, &[1, 2], |_| 0, |p| weights[p]);
            counts[p] += 1;
        }
        assert_eq!(counts[1], 50);
        assert_eq!(counts[2], 50);
    }

    #[test]
    fn hedged_route_prefers_the_faster_drain_of_the_two_shortest() {
        let mut r = Router::new(RouteStrategy::LeastOutstanding, &[3]);
        // depths: replica 12 is clearly loaded; 10 and 11 tie on depth
        // but 11 drains twice as fast -> hedge picks 11 over the
        // index-order tie-break plain LeastOutstanding would use
        let depths = [2usize, 2, 9];
        let drains = [80.0, 40.0, 10.0];
        let picked = r.route_hedged(0, &[10, 11, 12], |p| depths[p - 10], |p| drains[p - 10]);
        assert_eq!(picked, 11);
        // exact drain ties fall back to the lower index
        let flat = [50.0, 50.0, 50.0];
        assert_eq!(
            r.route_hedged(0, &[10, 11, 12], |p| depths[p - 10], |p| flat[p - 10]),
            10
        );
        // single member short-circuits like the plain strategies
        assert_eq!(r.route_hedged(0, &[7], |_| 3, |_| 1.0), 7);
        // replay determinism: same inputs, same pick
        let again = r.route_hedged(0, &[10, 11, 12], |p| depths[p - 10], |p| drains[p - 10]);
        assert_eq!(again, 11);
    }

    #[test]
    fn weighted_round_robin_tracks_resources() {
        // replica 0 has twice the resources of replica 1: over 300 routes
        // it must receive ~2/3 of the traffic, deterministically.
        let mut r = Router::new(RouteStrategy::WeightedByResources, &[2]);
        let weights = [0.5, 0.25];
        let mut counts = [0usize; 2];
        for _ in 0..300 {
            let p = r.route(0, &[0, 1], |_| 0, |p| weights[p]);
            counts[p] += 1;
        }
        assert_eq!(counts[0] + counts[1], 300);
        assert_eq!(counts[0], 200, "smooth WRR is exact on rational weights");
        // identical fresh router replays identically
        let mut r2 = Router::new(RouteStrategy::WeightedByResources, &[2]);
        let first: Vec<usize> = (0..10).map(|_| r2.route(0, &[0, 1], |_| 0, |p| weights[p])).collect();
        let mut r3 = Router::new(RouteStrategy::WeightedByResources, &[2]);
        let second: Vec<usize> = (0..10).map(|_| r3.route(0, &[0, 1], |_| 0, |p| weights[p])).collect();
        assert_eq!(first, second);
    }
}
