//! The Layer-3 serving coordinator: request routing, dynamic batching,
//! P99 SLO monitoring, the iGniter shadow-process failover (Sec. 4.2
//! "Dealing with Performance Prediction Errors"), and the GSLICE reactive
//! tuner — all running on the discrete-event engine so every experiment is
//! deterministic per seed.
//!
//! Time unit: virtual milliseconds.

use crate::gpu::{GpuDevice, GpuKind};
use crate::provisioner::{Plan, WorkloadSpec};
use crate::sim::EventQueue;
use crate::util::stats::{percentile, LatencyHistogram};
use crate::workload::{ArrivalGen, ArrivalKind};
use std::collections::VecDeque;

/// Online policy applied during serving.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Policy {
    /// Static plan, no runtime adjustment.
    Static,
    /// iGniter: pre-launched shadow processes absorb prediction errors.
    IgniterShadow,
    /// GSLICE's reactive threshold tuner (interference-unaware).
    GsliceTuner {
        /// adjustment period (ms)
        period_ms: f64,
    },
}

/// Extra GPU resources granted to an activated shadow process: the smaller
/// of 10 % (the paper's measured max prediction error) and the remaining
/// resources on the device.
pub const SHADOW_EXTRA: f64 = 0.10;
/// SLO monitor period (paper: clients evaluate every second, iGniter
/// re-checks 0.5 s after a violation).
pub const MONITOR_PERIOD_MS: f64 = 500.0;

#[derive(Debug, Clone)]
enum Event {
    Arrival { w: usize },
    TryDispatch { w: usize },
    Complete { w: usize, n: u32, dispatched: f64, t_load: f64 },
    Monitor,
    Tune,
}

/// Per-workload serving state.
#[derive(Debug)]
struct ProcState {
    spec: WorkloadSpec,
    gpu: usize,
    resources: f64,
    batch: u32,
    queue: VecDeque<f64>,
    busy: bool,
    /// rolling estimate of batch execution latency (ms) for the batcher
    exec_estimate: f64,
    /// lifetime latency records (completion time, latency)
    window: Vec<(f64, f64)>,
    hist: LatencyHistogram,
    served: u64,
    arrivals: ArrivalGen,
    /// shadow process state (iGniter policy)
    shadow_active: bool,
    switches: u32,
    /// timeline samples for Figs. 15-17: (t, p99_ms, achieved_rps, r, batch)
    timeline: Vec<TimelinePoint>,
    served_since_sample: u64,
    last_sample_ms: f64,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimelinePoint {
    pub t_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub rps: f64,
    pub resources: f64,
    pub batch: u32,
}

/// Result of a serving run for one workload.
#[derive(Debug, Clone)]
pub struct WorkloadStats {
    pub name: String,
    pub slo_ms: f64,
    pub rate_rps: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub achieved_rps: f64,
    pub served: u64,
    pub violation: bool,
    pub throughput_violation: bool,
    pub shadow_switches: u32,
    pub timeline: Vec<TimelinePoint>,
    pub final_resources: f64,
    pub final_batch: u32,
}

/// The cluster serving simulation.
pub struct ClusterSim {
    devices: Vec<GpuDevice>,
    procs: Vec<ProcState>,
    events: EventQueue<Event>,
    policy: Policy,
    horizon_ms: f64,
    /// warm-up to exclude from stats (ms)
    warmup_ms: f64,
}

impl ClusterSim {
    /// Build from a provisioning plan.  `underprovision` injects prediction
    /// errors by shaving resources off specific workloads (Fig. 17).
    pub fn new(
        kind: GpuKind,
        plan: &Plan,
        specs: &[WorkloadSpec],
        policy: Policy,
        arrival: ArrivalKind,
        seed: u64,
        underprovision: &[(usize, f64)],
    ) -> ClusterSim {
        let mut devices: Vec<GpuDevice> = (0..plan.num_gpus())
            .map(|g| GpuDevice::new(kind, seed ^ (g as u64 + 1)))
            .collect();
        let mut procs = Vec::new();
        for (g, alloc) in plan.all() {
            let mut r = alloc.resources;
            if let Some((_, shave)) = underprovision.iter().find(|(w, _)| *w == alloc.workload) {
                r = (r - shave).max(devices[g].spec.r_unit);
            }
            let spec = specs[alloc.workload].clone();
            // launch_unchecked: interference-unaware plans (GSLICE+) may
            // oversubscribe a device; the hardware then time-slices SMs.
            devices[g].launch_unchecked(alloc.workload as u64, spec.model, r, alloc.batch);
            procs.push(ProcState {
                gpu: g,
                resources: r,
                batch: alloc.batch,
                queue: VecDeque::new(),
                busy: false,
                exec_estimate: spec.slo_ms / 4.0,
                window: Vec::new(),
                hist: LatencyHistogram::new(),
                served: 0,
                arrivals: ArrivalGen::new(arrival, spec.rate_rps, seed ^ (0x5EED + alloc.workload as u64)),
                shadow_active: false,
                switches: 0,
                timeline: Vec::new(),
                served_since_sample: 0,
                last_sample_ms: 0.0,
                spec,
            });
        }
        // procs indexed by workload id: sort
        procs.sort_by_key(|p| p.spec.id);
        ClusterSim {
            devices,
            procs,
            events: EventQueue::new(),
            policy,
            horizon_ms: 30_000.0,
            warmup_ms: 1_000.0,
        }
    }

    pub fn set_horizon(&mut self, horizon_ms: f64, warmup_ms: f64) {
        self.horizon_ms = horizon_ms;
        self.warmup_ms = warmup_ms;
    }

    /// Dynamic batching timeout for a workload: the slack of the half-SLO
    /// after the estimated execution time (Triton's max_queue_delay).
    fn batch_timeout(&self, w: usize) -> f64 {
        let p = &self.procs[w];
        (p.spec.slo_ms / 2.0 - p.exec_estimate).max(0.1)
    }

    fn try_dispatch(&mut self, w: usize) {
        let now = self.events.now();
        let (can, n) = {
            let p = &self.procs[w];
            if p.busy || p.queue.is_empty() {
                (false, 0)
            } else {
                let oldest_age = now - p.queue.front().copied().unwrap_or(now);
                let full = p.queue.len() >= p.batch as usize;
                let timed_out = oldest_age >= self.batch_timeout(w);
                (
                    full || timed_out,
                    p.queue.len().min(p.batch as usize) as u32,
                )
            }
        };
        if !can || n == 0 {
            // re-check when the timeout of the oldest request expires
            let p = &self.procs[w];
            if !p.busy {
                if let Some(&oldest) = p.queue.front() {
                    let due = oldest + self.batch_timeout(w);
                    self.events
                        .schedule_at(due.max(now + 0.01), Event::TryDispatch { w });
                }
            }
            return;
        }
        let p = &mut self.procs[w];
        let tag = p.spec.id as u64;
        let gpu = p.gpu;
        p.busy = true;
        let q = self.devices[gpu]
            .query_latency(tag, n)
            .expect("process vanished");
        // Pipeline: the process is busy for t_gpu + t_feedback; the batch's
        // own latency includes its data loading (Eq. 1).
        let busy = q.t_gpu + q.t_feedback;
        self.procs[w].exec_estimate =
            0.8 * self.procs[w].exec_estimate + 0.2 * (q.t_inf);
        self.events.schedule_in(
            busy,
            Event::Complete {
                w,
                n,
                dispatched: now,
                t_load: q.t_load,
            },
        );
    }

    fn p99_since(&self, w: usize, since: f64) -> Option<f64> {
        let lat: Vec<f64> = self.procs[w]
            .window
            .iter()
            .filter(|(t, _)| *t >= since)
            .map(|(_, l)| *l)
            .collect();
        if lat.len() < 20 {
            None
        } else {
            Some(percentile(&lat, 0.99))
        }
    }

    /// iGniter shadow failover: kill the original process, activate the
    /// standby with extra resources (capped by the device's free room).
    fn activate_shadow(&mut self, w: usize) {
        let gpu = self.procs[w].gpu;
        let tag = self.procs[w].spec.id as u64;
        let free = self.devices[gpu].free_resources();
        let extra = SHADOW_EXTRA.min(free);
        let new_r = self.procs[w].resources + extra;
        self.devices[gpu].kill(tag);
        // shadow takes over under the same tag with grown partition
        self.devices[gpu].launch_unchecked(tag, self.procs[w].spec.model, new_r, self.procs[w].batch);
        self.procs[w].resources = new_r;
        self.procs[w].shadow_active = true;
        self.procs[w].switches += 1;
        // restart the P99 window: the new process starts clean
        self.procs[w].window.clear();
    }

    /// GSLICE reactive tuner: per workload, grow when the observed average
    /// violates half the SLO, shrink when it undershoots by 4x the
    /// threshold — ignoring co-residents entirely (it may oversubscribe
    /// the device, which the hardware then time-slices).
    fn gslice_tune(&mut self) {
        let now = self.events.now();
        for w in 0..self.procs.len() {
            let since = now - 10_000.0;
            let lat: Vec<f64> = self.procs[w]
                .window
                .iter()
                .filter(|(t, _)| *t >= since)
                .map(|(_, l)| *l)
                .collect();
            if lat.len() < 10 {
                continue;
            }
            let avg = crate::util::stats::mean(&lat);
            let half = self.procs[w].spec.slo_ms / 2.0;
            let gpu = self.procs[w].gpu;
            let tag = self.procs[w].spec.id as u64;
            let step = self.devices[gpu].spec.r_unit * 2.0;
            if avg > half {
                let r = self.procs[w].resources + step;
                // interference-unaware: force the grow regardless of room
                self.devices[gpu].force_resources(tag, r);
                self.procs[w].resources = r;
            } else if avg < half * (1.0 - crate::provisioner::gslice::TUNING_THRESHOLD) {
                let r = (self.procs[w].resources - step).max(self.devices[gpu].spec.r_unit);
                self.devices[gpu].force_resources(tag, r);
                self.procs[w].resources = r;
            }
        }
    }

    fn sample_timeline(&mut self) {
        let now = self.events.now();
        for w in 0..self.procs.len() {
            let since = now - 1_000.0;
            let p99 = self.p99_since(w, since).unwrap_or(f64::NAN);
            let lat: Vec<f64> = self.procs[w]
                .window
                .iter()
                .filter(|(t, _)| *t >= since)
                .map(|(_, l)| *l)
                .collect();
            let mean = crate::util::stats::mean(&lat);
            let p = &mut self.procs[w];
            let dt = (now - p.last_sample_ms).max(1e-9);
            let rps = p.served_since_sample as f64 / dt * 1000.0;
            p.timeline.push(TimelinePoint {
                t_ms: now,
                p99_ms: p99,
                mean_ms: mean,
                rps,
                resources: p.resources,
                batch: p.batch,
            });
            p.served_since_sample = 0;
            p.last_sample_ms = now;
        }
    }

    /// Run the simulation to the horizon; returns per-workload stats.
    pub fn run(&mut self) -> Vec<WorkloadStats> {
        // seed arrivals + monitor
        for w in 0..self.procs.len() {
            let t = self.procs[w].arrivals.next();
            self.events.schedule_at(t, Event::Arrival { w });
        }
        self.events.schedule_at(MONITOR_PERIOD_MS, Event::Monitor);
        if let Policy::GsliceTuner { period_ms } = self.policy {
            self.events.schedule_at(period_ms, Event::Tune);
        }

        while let Some(&t) = self.events.peek_time().as_ref() {
            if t > self.horizon_ms {
                break;
            }
            let (now, ev) = self.events.pop().unwrap();
            match ev {
                Event::Arrival { w } => {
                    self.procs[w].queue.push_back(now);
                    let next = self.procs[w].arrivals.next();
                    self.events.schedule_at(next, Event::Arrival { w });
                    self.try_dispatch(w);
                }
                Event::TryDispatch { w } => self.try_dispatch(w),
                Event::Complete {
                    w,
                    n,
                    dispatched,
                    t_load,
                } => {
                    let record = now >= self.warmup_ms;
                    let p = &mut self.procs[w];
                    for _ in 0..n {
                        let arr = p.queue.pop_front().expect("queue underflow");
                        // Eq. 1 view: latency = queueing + load + gpu + feedback
                        let lat = (now + t_load) - arr;
                        debug_assert!(lat >= 0.0);
                        if record {
                            p.window.push((now, lat));
                            p.hist.record(lat / 1000.0);
                        }
                        p.served += 1;
                        p.served_since_sample += 1;
                    }
                    let _ = dispatched;
                    p.busy = false;
                    self.try_dispatch(w);
                }
                Event::Monitor => {
                    self.sample_timeline();
                    if self.policy == Policy::IgniterShadow {
                        for w in 0..self.procs.len() {
                            if self.procs[w].shadow_active {
                                continue; // one switch per workload
                            }
                            let since = now - 1_000.0;
                            if let Some(p99) = self.p99_since(w, since) {
                                if p99 > self.procs[w].spec.slo_ms {
                                    self.activate_shadow(w);
                                }
                            }
                        }
                    }
                    self.events
                        .schedule_in(MONITOR_PERIOD_MS, Event::Monitor);
                }
                Event::Tune => {
                    self.gslice_tune();
                    if let Policy::GsliceTuner { period_ms } = self.policy {
                        self.events.schedule_in(period_ms, Event::Tune);
                    }
                }
            }
        }

        // final stats
        self.procs
            .iter()
            .map(|p| {
                let lat: Vec<f64> = p.window.iter().map(|(_, l)| *l).collect();
                let p99 = percentile(&lat, 0.99);
                let mean = crate::util::stats::mean(&lat);
                let span_ms = self.horizon_ms - self.warmup_ms;
                let achieved = lat.len() as f64 / span_ms * 1000.0;
                WorkloadStats {
                    name: p.spec.name.clone(),
                    slo_ms: p.spec.slo_ms,
                    rate_rps: p.spec.rate_rps,
                    p99_ms: p99,
                    mean_ms: mean,
                    achieved_rps: achieved,
                    served: p.served,
                    violation: p99 > p.spec.slo_ms,
                    throughput_violation: achieved < p.spec.rate_rps * 0.95,
                    shadow_switches: p.switches,
                    timeline: p.timeline.clone(),
                    final_resources: p.resources,
                    final_batch: p.batch,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuKind;
    use crate::provisioner::{self, ProfiledSystem};
    use crate::workload::{app_workloads, table1_workloads};

    fn sys() -> ProfiledSystem {
        let (hw, wls) = crate::profiler::profile_all(GpuKind::V100, 42);
        ProfiledSystem {
            hw,
            coeffs: crate::gpu::ALL_MODELS.iter().cloned().zip(wls).collect(),
        }
    }

    #[test]
    fn table1_serving_meets_slos() {
        let s = sys();
        let specs = table1_workloads();
        let plan = provisioner::provision(&s, &specs);
        let mut sim = ClusterSim::new(
            GpuKind::V100,
            &plan,
            &specs,
            Policy::IgniterShadow,
            ArrivalKind::Constant,
            7,
            &[],
        );
        sim.set_horizon(10_000.0, 1_000.0);
        let stats = sim.run();
        for st in &stats {
            assert!(
                !st.violation,
                "{}: P99 {:.2} > SLO {}",
                st.name, st.p99_ms, st.slo_ms
            );
            assert!(
                !st.throughput_violation,
                "{}: {:.0} rps < {:.0}",
                st.name, st.achieved_rps, st.rate_rps
            );
        }
    }

    #[test]
    fn igniter_plan_serves_12_workloads() {
        let s = sys();
        let specs = app_workloads();
        let plan = provisioner::provision(&s, &specs);
        let mut sim = ClusterSim::new(
            GpuKind::V100,
            &plan,
            &specs,
            Policy::IgniterShadow,
            ArrivalKind::Constant,
            11,
            &[],
        );
        sim.set_horizon(8_000.0, 1_000.0);
        let stats = sim.run();
        let violations = stats.iter().filter(|s| s.violation).count();
        assert_eq!(violations, 0, "{stats:#?}");
    }

    #[test]
    fn underprovision_triggers_shadow() {
        // Fig. 17: an injected prediction error makes W1 violate; the
        // shadow process takes over and restores the SLO.
        let s = sys();
        let specs = table1_workloads();
        let plan = provisioner::provision(&s, &specs);
        let mut sim = ClusterSim::new(
            GpuKind::V100,
            &plan,
            &specs,
            Policy::IgniterShadow,
            ArrivalKind::Constant,
            13,
            &[(0, 0.05)],
        );
        sim.set_horizon(12_000.0, 1_000.0);
        let stats = sim.run();
        assert!(stats[0].shadow_switches >= 1, "shadow never activated");
        // after the switch the tail must be under the SLO again: check the
        // last timeline samples
        let tail: Vec<&TimelinePoint> = stats[0]
            .timeline
            .iter()
            .filter(|t| t.t_ms > 8_000.0 && !t.p99_ms.is_nan())
            .collect();
        assert!(!tail.is_empty());
        assert!(
            tail.iter().all(|t| t.p99_ms <= specs[0].slo_ms * 1.05),
            "tail still violating: {tail:?}"
        );
    }

    #[test]
    fn deterministic_runs() {
        let s = sys();
        let specs = table1_workloads();
        let plan = provisioner::provision(&s, &specs);
        let run = |seed| {
            let mut sim = ClusterSim::new(
                GpuKind::V100,
                &plan,
                &specs,
                Policy::Static,
                ArrivalKind::Poisson,
                seed,
                &[],
            );
            sim.set_horizon(5_000.0, 500.0);
            sim.run()
                .iter()
                .map(|s| (s.served, s.p99_ms))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(3), run(3));
        assert_ne!(run(3), run(4));
    }

    #[test]
    fn queueing_latency_counted() {
        // With a rate far above capacity, latency must blow past the SLO.
        let s = sys();
        let mut specs = table1_workloads();
        specs[0].rate_rps = 4000.0; // way beyond the plan's design point
        let plan_specs = table1_workloads();
        let plan = provisioner::provision(&s, &plan_specs);
        let mut sim = ClusterSim::new(
            GpuKind::V100,
            &plan,
            &specs,
            Policy::Static,
            ArrivalKind::Constant,
            5,
            &[],
        );
        sim.set_horizon(4_000.0, 500.0);
        let stats = sim.run();
        assert!(stats[0].violation, "overload did not violate: {stats:?}");
    }
}
