//! The Layer-3 serving event loop.  `ClusterSim` owns the discrete-event
//! clock, the devices, and the per-replica serving state; every policy
//! decision is delegated to the composable submodules:
//!
//! * `router`  — which replica of a workload receives an arrival;
//! * `batcher` — when a replica dispatches a batch (`BatchPolicy`);
//! * `monitor` — what the SLO monitor does about violations
//!   (`ServingPolicy`: shadow failover, GSLICE tuning, or nothing).
//!
//! A provisioning plan may carry several allocations per workload id (a
//! replica group — see `provisioner::igniter::replica_split`); the sim
//! serves each replica as its own process and reports stats aggregated
//! per workload.  Latency windows are time-bounded `SlidingWindow`s, so
//! monitor ticks cost O(window), not O(lifetime).
//!
//! The loop is **closed**: every monitor tick the serving policy may
//! return `PlanDelta`s (see `monitor::Reprovisioner`), which the sim
//! realizes live — in-place partition resizes, or **shadow-instance
//! migration**: the new replicas warm up while the old ones keep
//! serving; at switch-over new arrivals route to the fresh replicas and
//! the old ones drain to completion before their processes are killed.
//! No request is ever dropped and in-flight work finishes on the old
//! gpulet (`arrivals == served + still_queued` holds through any number
//! of migrations).
//!
//! **Fault lane** (see DESIGN.md §"Fault injection and failover"): an
//! optional [`FaultPlan`] injects device deaths, transient stragglers,
//! and replica hangs through the same calendar queue.  Every fault is
//! pre-drawn at plan-generation time, so the sim itself consumes no
//! extra randomness — an *empty* plan is a bitwise no-op.  Under faults
//! the conservation law widens to `arrivals == served + still_queued +
//! dropped`: every dropped request is counted explicitly (orphans with
//! no surviving replica to requeue on, or deadline sheds under a
//! [`monitor::Resilience`] policy).
//!
//! Hot-path layout (see DESIGN.md §"Sim-core memory layout"): replica
//! state is a struct-of-arrays [`ReplicaSet`], request timestamps live
//! in one shared [`RequestSlab`] arena, `Event` is a small `Copy`
//! payload (migration batches park in per-group `fresh_batches`), and
//! arrivals are drawn through a chunked [`ArrivalBuffer`].
//!
//! Time unit: virtual milliseconds.

use super::batcher::{BatchDecision, BatchPolicy, BatchView, TritonAdaptive};
use super::monitor::{
    GsliceTuner, PolicyCtx, Resilience, ServingPolicy, ShadowFailover, StaticPolicy,
    MIN_P99_SAMPLES, MONITOR_PERIOD_MS,
};
use super::replicas::{ReplicaPhase, ReplicaSet};
use super::router::{RouteStrategy, Router};
use crate::gpu::{GpuDevice, GpuKind};
use crate::provisioner::{Plan, PlanDelta, WorkloadSpec};
use crate::sim::faults::{FaultKind, FaultPlan};
use crate::sim::slab::RequestSlab;
use crate::sim::EventQueue;
use crate::util::stats::{mean, percentile_sorted, LatencyHistogram};
use crate::workload::trace::{RateTrace, TracedArrivalGen};
use crate::workload::{ArrivalBuffer, ArrivalGen, ArrivalKind, ArrivalStream};
use std::collections::VecDeque;
use std::sync::Arc;

pub use super::replicas::WINDOW_SPAN_MS;

/// Shadow warm-up span (ms): model load + CUDA context for a freshly
/// launched migration replica.  The old replicas keep serving for the
/// whole warm-up, so arrivals never wait on a cold process.
pub const MIGRATION_WARMUP_MS: f64 = 250.0;

/// Online policy applied during serving (the classic enum front-end; each
/// variant maps onto a `monitor::ServingPolicy` implementation).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Policy {
    /// Static plan, no runtime adjustment.
    Static,
    /// iGniter: pre-launched shadow processes absorb prediction errors.
    IgniterShadow,
    /// GSLICE's reactive threshold tuner (interference-unaware).
    GsliceTuner {
        /// adjustment period (ms)
        period_ms: f64,
    },
}

impl Policy {
    fn build(self) -> Box<dyn ServingPolicy> {
        match self {
            Policy::Static => Box::new(StaticPolicy),
            Policy::IgniterShadow => Box::new(ShadowFailover),
            Policy::GsliceTuner { period_ms } => Box::new(GsliceTuner { period_ms }),
        }
    }
}

/// Fixed-size event payload: ~10^6 of these flow through the calendar
/// queue per simulated second, so none of the variants may own heap data
/// (the migration fresh-batch `Vec` lives in `WorkloadGroup` instead).
#[derive(Debug, Clone, Copy)]
enum Event {
    /// One request of workload group `g` arrives (routed on pop).
    Arrival { g: usize },
    /// Re-evaluate batching for replica `p`.
    TryDispatch { p: usize },
    /// Replica `p` finishes a batch of `n` dispatched at `dispatched`.
    Complete {
        p: usize,
        n: u32,
        dispatched: f64,
        t_load: f64,
    },
    Monitor,
    Tune,
    /// A migration's warm-up finished: activate the oldest pending fresh
    /// batch of group `g` (parked in `WorkloadGroup::fresh_batches`) and
    /// start draining the replicas it replaces.
    SwitchOver { g: usize },
    /// Injected fault number `f` of the sim's `FaultPlan` fires (the
    /// payload indexes the plan so the variant stays `Copy`).
    Fault { f: u32 },
}

/// Per-workload bookkeeping: the replica group, its shared arrival stream,
/// and the aggregated timeline.
struct WorkloadGroup {
    spec: Arc<WorkloadSpec>,
    /// Global replica indices of this workload's group (including
    /// warming/draining/retired migration members, in launch order).
    members: Vec<usize>,
    /// Cached `Active` subset of `members` — the arrival fast path routes
    /// over this without rescanning phases; rebuilt only at the rare
    /// phase transitions (migration switch-over).
    routable: Vec<usize>,
    arrivals: ArrivalBuffer,
    /// Pending migration payloads in schedule order: `apply_delta` pushes
    /// a fresh-replica batch here and schedules a payload-free
    /// `SwitchOver { g }`; the event pops the front.  Same-group
    /// switch-overs pop in their schedule order (the event queue is FIFO
    /// at equal times), so multiple in-flight migrations behave exactly
    /// as when each event carried its own `Vec`.
    fresh_batches: VecDeque<Vec<usize>>,
    arrivals_count: u64,
    /// Requests explicitly given up on: orphans of a dead replica with no
    /// surviving group member to take them, plus deadline sheds when the
    /// group's `Resilience` policy enables shedding.
    dropped_count: u64,
    /// Instant of the group's unresolved device-death fault; cleared when
    /// the first replica launched *after* it completes a batch (that span
    /// is the recovery-time sample).
    fault_at: Option<f64>,
    /// Per-workload resilience policy, cached from the serving policy so
    /// the arrival hot path reads a struct instead of a virtual call.
    resilience: Resilience,
    /// True while any fault state is live on this group (open breaker,
    /// undetected hang, unresolved death): arrivals take the cold path
    /// with shed/hedge hooks instead of the plain router.
    degraded: bool,
    timeline: Vec<TimelinePoint>,
    served_since_sample: u64,
    last_sample_ms: f64,
    /// Activity epoch: completion time of the newest *recorded* latency
    /// sample pushed into any member's sliding window (`-inf` before the
    /// first).  Monotone by event order.  Together with
    /// `served_since_sample == 0` it proves the monitor's 1 s lookback
    /// would pool zero samples, admitting the O(1) idle fast path in
    /// `sample_timeline` (see DESIGN.md "Idle-aware monitor").
    last_window_push_ms: f64,
    /// Cached non-`Retired` member aggregates — the `resources` sum and
    /// `batch` max the full timeline walk would compute.  Refreshed by
    /// `refresh_group_aggregates` at every phase/partition mutation
    /// (launch, resize, retire, switch-over, device death, policy-side
    /// writes via `ReplicaSet::resources_dirty`), so quiet ticks read
    /// them in O(1) bitwise-identically to the re-summed walk.
    agg_resources: f64,
    agg_batch: u32,
}

/// Timeline samples for Figs. 15-17, aggregated over the replica group.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimelinePoint {
    pub t_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub rps: f64,
    /// summed over replicas
    pub resources: f64,
    /// max over replicas
    pub batch: u32,
}

/// Result of a serving run for one workload (replica-group aggregate).
#[derive(Debug, Clone)]
pub struct WorkloadStats {
    pub name: String,
    pub slo_ms: f64,
    pub rate_rps: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    /// Mean queueing delay (arrival -> dispatch) of recorded requests.
    pub mean_queue_ms: f64,
    /// Mean execution span (dispatch -> completion + load) of recorded
    /// requests; `mean_queue_ms + mean_exec_ms == mean_ms`.
    pub mean_exec_ms: f64,
    pub achieved_rps: f64,
    pub served: u64,
    /// Arrivals observed inside the horizon.
    pub arrivals: u64,
    /// Requests still waiting or in flight at the horizon.
    pub still_queued: u64,
    /// Requests explicitly dropped (fault orphans with no survivor to
    /// requeue on, deadline sheds).  Zero in fault-free serving.
    pub dropped: u64,
    pub violation: bool,
    pub throughput_violation: bool,
    pub shadow_switches: u32,
    pub timeline: Vec<TimelinePoint>,
    /// Summed over the replica group.
    pub final_resources: f64,
    pub final_batch: u32,
    /// Lifetime served count per replica, in group order.
    pub replica_served: Vec<u64>,
}

///// Request-conservation residual over a stats set:
/// `Σ (arrivals - served - still_queued)`.  Zero by the drain-before-
/// switch invariant in fault-free serving; under an injected `FaultPlan`
/// it equals `Σ dropped` — every lost request is accounted for
/// explicitly, never silently.  Every harness gates on it through this
/// one definition (sweep runner, autoscale and calibration experiments).
pub fn dropped_requests(stats: &[WorkloadStats]) -> i64 {
    stats
        .iter()
        .map(|s| s.arrivals as i64 - s.served as i64 - s.still_queued as i64)
        .sum()
}

/// The cluster serving simulation.
pub struct ClusterSim {
    kind: GpuKind,
    seed: u64,
    arrival_kind: ArrivalKind,
    devices: Vec<GpuDevice>,
    /// Struct-of-arrays replica state (index = global replica id).
    replicas: ReplicaSet,
    /// Shared arena backing every replica's request-timestamp queue.
    req_slab: RequestSlab,
    groups: Vec<WorkloadGroup>,
    /// replica index -> group index
    group_of: Vec<usize>,
    events: EventQueue<Event>,
    router: Router,
    batcher: Box<dyn BatchPolicy>,
    policy: Box<dyn ServingPolicy>,
    horizon_ms: f64,
    /// warm-up to exclude from stats (ms)
    warmup_ms: f64,
    /// integrated occupied-device time (device-ms), sampled per monitor
    /// tick — a device with zero resident processes is released and free
    gpu_ms: f64,
    last_occupancy_ms: f64,
    /// executed shadow migrations (plan-deltas with a placement change)
    migrations: u32,
    /// Injected fault schedule (empty by default: zero extra events, the
    /// fault-free event stream is bitwise unchanged).
    fault_plan: FaultPlan,
    /// Per-device straggler state: `(dilation factor, until_ms)` — every
    /// batch dispatched on the device before `until_ms` runs `factor`x
    /// slower.  `(1.0, 0.0)` = healthy.
    straggler: Vec<(f64, f64)>,
    /// Faults that actually landed on a live target (a death drawn for an
    /// already-empty fleet is not counted).
    faults_injected: u64,
    /// Recovery-time samples: device-death instant -> first batch served
    /// by a replica launched after it.
    recovery_ms: Vec<f64>,
    /// pooled latency scratch reused by `sample_timeline` (one buffer for
    /// the whole sim instead of one allocation per group per tick)
    lat_scratch: Vec<f64>,
    /// Idle-group monitor fast path (on by default): quiet groups take an
    /// O(1) timeline sample instead of the full member walk.  The off
    /// position runs the reference walk every tick — provably bitwise
    /// identical; the switch exists so the property tests and the
    /// long-tail bench can compare the two on the same build.
    idle_fast_path: bool,
    /// `false` when no breaker/hang/loss state can ever arise this run
    /// (every group's `Resilience` is off and the fault plan is empty):
    /// `enforce_breakers` then returns in O(1) instead of scanning every
    /// replica's flags each tick.  Computed once in `run`.
    breakers_armed: bool,
}

impl ClusterSim {
    /// Build from a provisioning plan.  `underprovision` injects prediction
    /// errors by shaving resources off every replica of specific workloads
    /// (Fig. 17).
    pub fn new(
        kind: GpuKind,
        plan: &Plan,
        specs: &[WorkloadSpec],
        policy: Policy,
        arrival: ArrivalKind,
        seed: u64,
        underprovision: &[(usize, f64)],
    ) -> ClusterSim {
        let mut devices: Vec<GpuDevice> = (0..plan.num_gpus())
            .map(|g| GpuDevice::new(kind, seed ^ (g as u64 + 1)))
            .collect();
        // one shared Arc per spec: replicas and groups clone pointers
        let specs_arc: Vec<Arc<WorkloadSpec>> = specs.iter().cloned().map(Arc::new).collect();
        let mut replicas = ReplicaSet::new();
        for (g, alloc) in plan.all() {
            let mut r = alloc.resources;
            if let Some((_, shave)) = underprovision.iter().find(|(w, _)| *w == alloc.workload) {
                r = (r - shave).max(devices[g].spec.r_unit);
            }
            let spec = Arc::clone(&specs_arc[alloc.workload]);
            let tag = replicas.len() as u64;
            // launch_unchecked: interference-unaware plans (GSLICE+) may
            // oversubscribe a device; the hardware then time-slices SMs.
            devices[g].launch_unchecked(tag, spec.model, r, alloc.batch);
            replicas.launch(spec, alloc.workload, g, tag, r, alloc.batch, ReplicaPhase::Active);
        }
        // Replica groups in workload-id order: stats index == workload id
        // whenever the plan covers every spec (the common case).
        let mut groups: Vec<WorkloadGroup> = Vec::new();
        for (w, spec) in specs_arc.iter().enumerate() {
            let members: Vec<usize> =
                (0..replicas.len()).filter(|&p| replicas.workload[p] == w).collect();
            if members.is_empty() {
                continue;
            }
            groups.push(WorkloadGroup {
                spec: Arc::clone(spec),
                routable: members.clone(),
                members,
                arrivals: ArrivalBuffer::new(ArrivalStream::Steady(ArrivalGen::new(
                    arrival,
                    spec.rate_rps,
                    seed ^ (0x5EED + w as u64),
                ))),
                fresh_batches: VecDeque::new(),
                arrivals_count: 0,
                dropped_count: 0,
                fault_at: None,
                resilience: Resilience::OFF,
                degraded: false,
                timeline: Vec::new(),
                served_since_sample: 0,
                last_sample_ms: 0.0,
                last_window_push_ms: f64::NEG_INFINITY,
                agg_resources: 0.0,
                agg_batch: 0,
            });
        }
        let group_sizes: Vec<usize> = groups.iter().map(|g| g.members.len()).collect();
        let mut group_of = vec![usize::MAX; replicas.len()];
        for (g, grp) in groups.iter().enumerate() {
            for &p in &grp.members {
                group_of[p] = g;
            }
        }
        let num_devices = devices.len();
        let mut sim = ClusterSim {
            kind,
            seed,
            arrival_kind: arrival,
            devices,
            replicas,
            req_slab: RequestSlab::new(),
            groups,
            group_of,
            events: EventQueue::new(),
            router: Router::new(RouteStrategy::LeastOutstanding, &group_sizes),
            batcher: Box::new(TritonAdaptive),
            policy: policy.build(),
            horizon_ms: 30_000.0,
            warmup_ms: 1_000.0,
            gpu_ms: 0.0,
            last_occupancy_ms: 0.0,
            migrations: 0,
            fault_plan: FaultPlan::none(),
            straggler: vec![(1.0, 0.0); num_devices],
            faults_injected: 0,
            recovery_ms: Vec::new(),
            lat_scratch: Vec::new(),
            idle_fast_path: true,
            breakers_armed: true,
        };
        for g in 0..sim.groups.len() {
            sim.refresh_group_aggregates(g);
        }
        sim
    }

    pub fn set_horizon(&mut self, horizon_ms: f64, warmup_ms: f64) {
        self.horizon_ms = horizon_ms;
        self.warmup_ms = warmup_ms;
    }

    /// Swap the routing strategy (resets routing credits).
    pub fn set_route_strategy(&mut self, strategy: RouteStrategy) {
        let group_sizes: Vec<usize> = self.groups.iter().map(|g| g.members.len()).collect();
        self.router = Router::new(strategy, &group_sizes);
    }

    /// Swap the batch-formation policy.
    pub fn set_batch_policy(&mut self, batcher: Box<dyn BatchPolicy>) {
        self.batcher = batcher;
    }

    /// Swap the online serving policy (replaces the `Policy` enum choice).
    pub fn set_serving_policy(&mut self, policy: Box<dyn ServingPolicy>) {
        self.policy = policy;
    }

    /// The active serving policy (read-only) — lets callers pull
    /// policy-side measurements (e.g. `Reprovisioner::prediction_errors`)
    /// back out after `run`.
    pub fn serving_policy(&self) -> &dyn ServingPolicy {
        self.policy.as_ref()
    }

    /// Drive every workload's arrivals from a time-varying `RateTrace`
    /// (each epoch spans `epoch_ms` of virtual time) instead of the
    /// steady nominal rate: the live counterpart of the epoch-replay in
    /// `experiments::dynamic`.  Deterministic per the sim's seed.  The
    /// trace is cloned once and shared across groups via `Arc`.
    pub fn set_rate_trace(&mut self, trace: &RateTrace, epoch_ms: f64) {
        let trace = Arc::new(trace.clone());
        for grp in &mut self.groups {
            grp.arrivals.set_stream(ArrivalStream::Traced(TracedArrivalGen::new(
                self.arrival_kind,
                grp.spec.rate_rps,
                Arc::clone(&trace),
                grp.spec.id,
                epoch_ms,
                self.seed ^ (0x5EED + grp.spec.id as u64),
            )));
        }
    }

    /// Integrated occupied-device time (GPU-seconds) over the run so far
    /// — a device whose last resident retired is released and stops
    /// accruing.  Final after `run` returns.
    pub fn gpu_seconds(&self) -> f64 {
        self.gpu_ms / 1000.0
    }

    /// Number of executed shadow migrations (placement-changing deltas).
    pub fn migrations(&self) -> u32 {
        self.migrations
    }

    /// Install an injected-fault schedule (see `sim::faults`).  An empty
    /// plan schedules nothing and the run is bitwise identical to one
    /// where this was never called.
    pub fn set_fault_plan(&mut self, plan: FaultPlan) {
        self.fault_plan = plan;
    }

    /// Injected faults that landed on a live target.
    pub fn faults_injected(&self) -> u64 {
        self.faults_injected
    }

    /// Toggle the idle-group monitor fast path (default on).  `false`
    /// runs the reference full-walk `sample_timeline` every tick; both
    /// positions produce bitwise-identical runs — the switch exists so
    /// tests and benches can prove exactly that.
    pub fn set_idle_fast_path(&mut self, on: bool) {
        self.idle_fast_path = on;
    }

    /// Recompute group `g`'s cached non-`Retired` aggregates with the
    /// exact walk `sample_timeline`'s reference path performs (same
    /// member order, same accumulation expressions), so the cached values
    /// are bitwise what the walk would re-derive.  Called at every
    /// mutation of a member's phase, resources, or batch; mutations are
    /// rare (plan deltas, retirements, faults), so quiet monitor ticks
    /// never pay this.
    fn refresh_group_aggregates(&mut self, g: usize) {
        let mut resources = 0.0;
        let mut batch = 0u32;
        for &p in &self.groups[g].members {
            if self.replicas.phase[p] != ReplicaPhase::Retired {
                resources += self.replicas.resources[p];
                batch = batch.max(self.replicas.batch[p]);
            }
        }
        let grp = &mut self.groups[g];
        grp.agg_resources = resources;
        grp.agg_batch = batch;
    }

    /// Absorb policy-side direct writes to `replicas.resources` (shadow
    /// activation, GSLICE tuning): drain the change log and refresh the
    /// touched groups' aggregates.  Runs after every policy hook.
    fn drain_resources_dirty(&mut self) {
        while let Some(p) = self.replicas.resources_dirty.pop() {
            let g = self.group_of[p];
            self.refresh_group_aggregates(g);
        }
    }

    /// Recovery-time samples (ms): device-death instant to the first
    /// batch served by a replica launched after the fault.
    pub fn recovery_ms(&self) -> &[f64] {
        &self.recovery_ms
    }

    fn try_dispatch(&mut self, p: usize) {
        let now = self.events.now();
        if self.replicas.busy[p] {
            return;
        }
        let view = BatchView {
            queue_len: self.replicas.queue[p].len(),
            oldest_arrival: self.req_slab.front(&self.replicas.queue[p]),
            max_batch: self.replicas.batch[p],
            slo_ms: self.replicas.spec[p].slo_ms,
            exec_estimate_ms: self.replicas.exec_estimate[p],
        };
        match self.batcher.decide(now, &view) {
            BatchDecision::Idle => {}
            BatchDecision::Wait(due) => {
                // re-check when the timeout of the oldest request expires
                self.events
                    .schedule_at(due.max(now + 0.01), Event::TryDispatch { p });
            }
            BatchDecision::Dispatch(n) => {
                debug_assert!(n > 0 && n as usize <= self.replicas.queue[p].len());
                let tag = self.replicas.tag[p];
                let gpu = self.replicas.gpu[p];
                let q = self.devices[gpu]
                    .query_latency(tag, n)
                    .expect("process vanished");
                // Pipeline: the process is busy for t_gpu + t_feedback; the
                // batch's own latency includes its data loading (Eq. 1).
                let mut busy = q.t_gpu + q.t_feedback;
                let mut t_inf = q.t_inf;
                let mut t_load = q.t_load;
                // Straggler dilation is applied only inside this branch so
                // the healthy path keeps its exact pre-fault float values
                // (`x * 1.0` is not guaranteed bitwise-free of effect for
                // every rounding mode; skipping the multiply is).
                let (dil, until) = self.straggler[gpu];
                if until > now && dil > 1.0 {
                    busy *= dil;
                    t_inf *= dil;
                    t_load *= dil;
                }
                self.replicas.busy[p] = true;
                self.replicas.busy_since[p] = now;
                self.replicas.exec_estimate[p] =
                    0.8 * self.replicas.exec_estimate[p] + 0.2 * t_inf;
                self.events.schedule_in(
                    busy,
                    Event::Complete {
                        p,
                        n,
                        dispatched: now,
                        t_load,
                    },
                );
            }
        }
    }

    /// Charge the elapsed interval at the current occupancy (a device
    /// with no resident process is released — it costs nothing).
    fn accrue_gpu_time(&mut self, now: f64) {
        let occupied = self.devices.iter().filter(|d| d.co_located() > 0).count();
        self.gpu_ms += occupied as f64 * (now - self.last_occupancy_ms);
        self.last_occupancy_ms = now;
    }

    /// Grow the device pool so `gpu` is a valid index (the online planner
    /// may provision fresh instances mid-run).  Seeding matches the
    /// constructor so device noise stays deterministic per sim seed.
    fn ensure_devices(&mut self, gpu: usize) {
        while self.devices.len() <= gpu {
            let g = self.devices.len();
            self.devices
                .push(GpuDevice::new(self.kind, self.seed ^ (g as u64 + 1)));
        }
        if self.straggler.len() < self.devices.len() {
            self.straggler.resize(self.devices.len(), (1.0, 0.0));
        }
    }

    /// A draining replica finished its last request: kill the process and
    /// keep the carcass for lifetime stats.
    fn retire(&mut self, p: usize) {
        debug_assert_eq!(self.replicas.phase[p], ReplicaPhase::Draining);
        debug_assert!(self.replicas.queue[p].is_empty() && !self.replicas.busy[p]);
        // settle the occupancy integral at pre-retire state: a device this
        // kill vacates mid-interval was occupied up to exactly this instant
        let now = self.events.now();
        self.accrue_gpu_time(now);
        let tag = self.replicas.tag[p];
        let gpu = self.replicas.gpu[p];
        self.devices[gpu].kill(tag);
        self.replicas.phase[p] = ReplicaPhase::Retired;
        self.replicas.resources[p] = 0.0;
        let g = self.group_of[p];
        self.refresh_group_aggregates(g);
    }

    /// Recompute group `g`'s routable set: `Active` members whose breaker
    /// is closed.  If open breakers would empty a group that still has
    /// `Active` members, every `Active` member is readmitted — degraded
    /// service beats no service, and the breaker's job is to *shift*
    /// traffic, never to black-hole a workload.
    fn rebuild_routable(&mut self, g: usize) {
        let phases = &self.replicas.phase;
        let breaker = &self.replicas.breaker_open;
        let WorkloadGroup {
            members, routable, ..
        } = &mut self.groups[g];
        routable.clear();
        routable.extend(
            members
                .iter()
                .copied()
                .filter(|&p| phases[p] == ReplicaPhase::Active && !breaker[p]),
        );
        if routable.is_empty() {
            routable.extend(
                members
                    .iter()
                    .copied()
                    .filter(|&p| phases[p] == ReplicaPhase::Active),
            );
        }
    }

    /// Recompute the cached `degraded` flag (cold-path arrival switch).
    fn refresh_degraded(&mut self, g: usize) {
        let reps = &self.replicas;
        let grp = &mut self.groups[g];
        grp.degraded = grp.fault_at.is_some()
            || grp
                .members
                .iter()
                .any(|&p| reps.breaker_open[p] || (reps.hung[p] && !reps.lost[p]));
    }

    /// Drain replica `p`'s orphaned requests onto its surviving group
    /// members (round-robin, arrival timestamps preserved), or count them
    /// as explicitly dropped when nobody is left to take them.
    fn requeue_orphans(&mut self, p: usize, g: usize) {
        let survivors: Vec<usize> = {
            let reps = &self.replicas;
            self.groups[g]
                .members
                .iter()
                .copied()
                .filter(|&q| {
                    q != p
                        && reps.phase[q] == ReplicaPhase::Active
                        && !reps.lost[q]
                        && !reps.hung[q]
                })
                .collect()
        };
        let mut i = 0usize;
        while let Some(arr) = self.req_slab.pop_front(&mut self.replicas.queue[p]) {
            if survivors.is_empty() {
                self.groups[g].dropped_count += 1;
            } else {
                let q = survivors[i % survivors.len()];
                self.req_slab.push_back(&mut self.replicas.queue[q], arr);
                i += 1;
            }
        }
        for &q in &survivors {
            self.try_dispatch(q);
        }
    }

    /// Forced retirement outside the drain protocol (device death or a
    /// condemned hang): the process is gone *now*, in-flight work and all
    /// — any stale `Complete` still in the calendar is suppressed by the
    /// `lost` flag, and the queue is re-homed or dropped explicitly.
    fn force_retire(&mut self, p: usize, now: f64) {
        self.accrue_gpu_time(now);
        let tag = self.replicas.tag[p];
        let gpu = self.replicas.gpu[p];
        if !self.devices[gpu].is_dead() {
            self.devices[gpu].kill(tag);
        }
        self.replicas.phase[p] = ReplicaPhase::Retired;
        self.replicas.resources[p] = 0.0;
        self.replicas.lost[p] = true;
        self.replicas.busy[p] = true; // keep the batcher off the corpse
        let g = self.group_of[p];
        self.refresh_group_aggregates(g);
        self.rebuild_routable(g);
        self.requeue_orphans(p, g);
        self.refresh_degraded(g);
    }

    /// Fire injected fault `f` of the plan.  Targets were drawn as raw
    /// `u64`s at plan-generation time and resolve against the *live*
    /// entity set here (modulo), so the sim never consumes RNG for
    /// faults; a fault whose eligible set is empty dissipates un-counted.
    fn apply_fault(&mut self, f: usize) {
        let now = self.events.now();
        match self.fault_plan.events[f].kind {
            FaultKind::DeviceDeath { target } => self.apply_device_death(target, now),
            FaultKind::Straggler {
                target,
                factor,
                span_ms,
            } => self.apply_straggler(target, factor, span_ms, now),
            FaultKind::ReplicaHang { target } => self.apply_hang(target, now),
        }
    }

    /// Kill an occupied device: every resident replica is lost with its
    /// in-flight batch, orphaned queues re-home onto group survivors (or
    /// drop, counted), and the affected groups start their recovery
    /// clocks.  Replacement capacity arrives through the serving policy
    /// (`Reprovisioner` failover respec) — the sim only breaks things.
    fn apply_device_death(&mut self, target: u64, now: f64) {
        let eligible: Vec<usize> = (0..self.devices.len())
            .filter(|&g| !self.devices[g].is_dead() && self.devices[g].co_located() > 0)
            .collect();
        if eligible.is_empty() {
            return;
        }
        let gpu = eligible[(target % eligible.len() as u64) as usize];
        self.faults_injected += 1;
        // the device was occupied right up to the failure instant
        self.accrue_gpu_time(now);
        self.devices[gpu].fail();
        let mut hit: Vec<usize> = Vec::new();
        for p in 0..self.replicas.len() {
            if self.replicas.gpu[p] != gpu || self.replicas.phase[p] == ReplicaPhase::Retired {
                continue;
            }
            self.replicas.phase[p] = ReplicaPhase::Retired;
            self.replicas.resources[p] = 0.0;
            self.replicas.lost[p] = true;
            self.replicas.busy[p] = true;
            let g = self.group_of[p];
            if !hit.contains(&g) {
                hit.push(g);
            }
        }
        for &g in &hit {
            self.groups[g].fault_at = Some(now);
            self.refresh_group_aggregates(g);
            self.rebuild_routable(g);
        }
        // re-home orphans only after every loss on the device is marked,
        // so nothing lands on a doomed sibling replica
        for p in 0..self.replicas.len() {
            if self.replicas.lost[p]
                && self.replicas.gpu[p] == gpu
                && !self.replicas.queue[p].is_empty()
            {
                let g = self.group_of[p];
                self.requeue_orphans(p, g);
            }
        }
        for &g in &hit {
            self.refresh_degraded(g);
        }
    }

    /// Transient slowdown of one occupied device: batches dispatched on
    /// it run `factor`x slower until the span elapses (thermal throttle /
    /// noisy PCIe neighbour).  Self-healing — no recovery clock.
    fn apply_straggler(&mut self, target: u64, factor: f64, span_ms: f64, now: f64) {
        let eligible: Vec<usize> = (0..self.devices.len())
            .filter(|&g| !self.devices[g].is_dead() && self.devices[g].co_located() > 0)
            .collect();
        if eligible.is_empty() {
            return;
        }
        let gpu = eligible[(target % eligible.len() as u64) as usize];
        self.faults_injected += 1;
        self.straggler[gpu] = (factor, now + span_ms);
    }

    /// Wedge one Active replica: it keeps its queue and in-flight batch
    /// but never completes again.  Detection (busy far past any plausible
    /// exec span) and condemnation are the breaker's job — until then the
    /// router keeps feeding it, which is exactly the failure mode the
    /// detector exists to bound.
    fn apply_hang(&mut self, target: u64, now: f64) {
        let eligible: Vec<usize> = (0..self.replicas.len())
            .filter(|&p| {
                self.replicas.phase[p] == ReplicaPhase::Active
                    && !self.replicas.lost[p]
                    && !self.replicas.hung[p]
            })
            .collect();
        if eligible.is_empty() {
            return;
        }
        let p = eligible[(target % eligible.len() as u64) as usize];
        self.faults_injected += 1;
        self.replicas.hung[p] = true;
        if !self.replicas.busy[p] {
            self.replicas.busy[p] = true;
            self.replicas.busy_since[p] = now;
        }
        let g = self.group_of[p];
        self.refresh_degraded(g);
    }

    /// Realize the policy's breaker verdicts (runs every monitor tick,
    /// after `reprovision`): condemned replicas are force-retired with
    /// their queues re-homed, and every group's routable set and degraded
    /// flag are rebuilt against the current breaker state.  Early-outs to
    /// a flag scan when no fault state exists anywhere.
    fn enforce_breakers(&mut self, now: f64) {
        if !self.breakers_armed {
            // with every group's resilience off and no fault plan,
            // nothing can ever set these flags (they are only written by
            // breaker-granted policies and injected faults) — skip even
            // the O(replicas) flag scan.  Debug builds verify the claim.
            debug_assert!(
                !(0..self.replicas.len()).any(|p| {
                    let r = &self.replicas;
                    r.condemned[p] || r.breaker_open[p] || r.hung[p] || r.lost[p]
                }),
                "fault state arose with breakers unarmed"
            );
            return;
        }
        let reps = &self.replicas;
        let any = (0..reps.len())
            .any(|p| reps.condemned[p] || reps.breaker_open[p] || reps.hung[p] || reps.lost[p]);
        if !any && self.fault_plan.is_empty() {
            return;
        }
        for p in 0..self.replicas.len() {
            if self.replicas.condemned[p]
                && !self.replicas.lost[p]
                && self.replicas.phase[p] != ReplicaPhase::Retired
            {
                self.force_retire(p, now);
            }
        }
        for g in 0..self.groups.len() {
            self.rebuild_routable(g);
            self.refresh_degraded(g);
        }
    }

    /// Cold-path arrival for a degraded group: per-workload `Resilience`
    /// hooks apply — deadline shed (the best replica's expected drain
    /// already blows twice the SLO budget: drop at admission, counted)
    /// and hedged dispatch (deterministic two-choice on expected drain
    /// time instead of raw queue depth).  All decisions are pure
    /// functions of observed state — no RNG, replay-identical.
    fn degraded_arrival(&mut self, g: usize, now: f64) {
        let bookkeep = |sim: &mut ClusterSim, g: usize| {
            sim.groups[g].arrivals_count += 1;
            let w = sim.groups[g].spec.id;
            sim.policy.on_arrival(now, w);
            let next = sim.groups[g].arrivals.next();
            sim.events.schedule_at(next, Event::Arrival { g });
        };
        if self.groups[g].routable.is_empty() {
            // the whole group is gone (death took every replica and the
            // replacement is still warming): nowhere to even queue
            bookkeep(self, g);
            self.groups[g].dropped_count += 1;
            return;
        }
        let res = self.groups[g].resilience;
        let p = {
            let grp = &self.groups[g];
            let queues = &self.replicas.queue;
            let resources = &self.replicas.resources;
            let est = &self.replicas.exec_estimate;
            let batches = &self.replicas.batch;
            let drain = |p: usize| {
                est[p] * (queues[p].len() as f64 / batches[p].max(1) as f64 + 1.0)
            };
            if res.hedge {
                self.router
                    .route_hedged(g, &grp.routable, |p| queues[p].len(), drain)
            } else {
                self.router
                    .route(g, &grp.routable, |p| queues[p].len(), |p| resources[p])
            }
        };
        if res.shed {
            let est_wait = self.replicas.exec_estimate[p]
                * (self.replicas.queue[p].len() as f64 / self.replicas.batch[p].max(1) as f64
                    + 1.0);
            if est_wait > self.groups[g].spec.slo_ms * 2.0 {
                bookkeep(self, g);
                self.groups[g].dropped_count += 1;
                return;
            }
        }
        bookkeep(self, g);
        self.req_slab.push_back(&mut self.replicas.queue[p], now);
        self.try_dispatch(p);
    }

    /// Realize one plan-delta from the serving policy.
    fn apply_delta(&mut self, delta: PlanDelta) {
        match delta {
            PlanDelta::Resize {
                workload,
                gpu,
                resources,
            } => {
                // in-place MPS partition resize of the live replica
                if let Some(p) = (0..self.replicas.len()).find(|&p| {
                    self.replicas.workload[p] == workload
                        && self.replicas.gpu[p] == gpu
                        && matches!(
                            self.replicas.phase[p],
                            ReplicaPhase::Active | ReplicaPhase::Warming
                        )
                }) {
                    let tag = self.replicas.tag[p];
                    self.devices[gpu].force_resources(tag, resources);
                    self.replicas.resources[p] = resources;
                    let g = self.group_of[p];
                    self.refresh_group_aggregates(g);
                }
            }
            PlanDelta::Migrate(m) => {
                if m.to.is_empty() {
                    return; // never drain a group down to zero replicas
                }
                if self.events.now() + MIGRATION_WARMUP_MS > self.horizon_ms {
                    // the switch-over could never fire: starting the
                    // migration would only leave phantom Warming replicas
                    // (and a migration count) the run can't realize
                    return;
                }
                let Some(g) = self.groups.iter().position(|grp| grp.spec.id == m.workload)
                else {
                    return;
                };
                // settle the occupancy integral before the launches below
                // change which devices are occupied
                let now = self.events.now();
                self.accrue_gpu_time(now);
                // launch the shadow replicas; they warm up while the old
                // group keeps serving (busy=true keeps the batcher away)
                let spec = Arc::clone(&self.groups[g].spec);
                let mut fresh = Vec::with_capacity(m.to.len());
                for (gpu, alloc) in &m.to {
                    self.ensure_devices(*gpu);
                    let tag = self.replicas.len() as u64;
                    self.devices[*gpu].launch_unchecked(
                        tag,
                        spec.model,
                        alloc.resources,
                        alloc.batch,
                    );
                    let p = self.replicas.launch(
                        Arc::clone(&spec),
                        m.workload,
                        *gpu,
                        tag,
                        alloc.resources,
                        alloc.batch,
                        ReplicaPhase::Warming,
                    );
                    self.replicas.launched_ms[p] = now;
                    self.group_of.push(g);
                    self.groups[g].members.push(p);
                    fresh.push(p);
                }
                self.migrations += 1;
                self.groups[g].fresh_batches.push_back(fresh);
                self.refresh_group_aggregates(g);
                self.events
                    .schedule_in(MIGRATION_WARMUP_MS, Event::SwitchOver { g });
            }
        }
    }

    fn sample_timeline(&mut self) {
        let now = self.events.now();
        // take the pooled scratch out so group/replica borrows stay clean;
        // sorting it once serves both the P99 and (order-free) the mean —
        // latency records are finite by construction, so the sort is the
        // same total_cmp order `percentile` would use after NaN filtering
        let mut lat = std::mem::take(&mut self.lat_scratch);
        for g in 0..self.groups.len() {
            let since = now - 1_000.0;
            // Idle fast path: `served_since_sample == 0` rules out any
            // completion since the last tick, and the activity epoch
            // proves every *recorded* window push predates the lookback
            // (`values_since_into` keeps `t >= since`, so a strictly
            // older newest-push means the pooled walk returns nothing).
            // The emitted point uses the same expressions as the walk
            // below over an empty pool — NaN p99 (below MIN_P99_SAMPLES),
            // `mean(&[])`, exactly-zero rps — and the cached aggregates,
            // which `refresh_group_aggregates` keeps bitwise equal to
            // the re-summed member walk.  A conservatively-new epoch only
            // forces an unnecessary full walk, never a wrong skip.
            if self.idle_fast_path {
                let grp = &mut self.groups[g];
                if grp.served_since_sample == 0 && grp.last_window_push_ms < since {
                    lat.clear();
                    let p99 = f64::NAN;
                    let mean_ms = mean(&lat);
                    let dt = (now - grp.last_sample_ms).max(1e-9);
                    let rps = grp.served_since_sample as f64 / dt * 1000.0;
                    grp.timeline.push(TimelinePoint {
                        t_ms: now,
                        p99_ms: p99,
                        mean_ms,
                        rps,
                        resources: grp.agg_resources,
                        batch: grp.agg_batch,
                    });
                    grp.served_since_sample = 0;
                    grp.last_sample_ms = now;
                    continue;
                }
            }
            lat.clear();
            let mut resources = 0.0;
            let mut batch = 0u32;
            for &p in &self.groups[g].members {
                self.replicas.window[p].values_since_into(since, &mut lat);
                if self.replicas.phase[p] != ReplicaPhase::Retired {
                    resources += self.replicas.resources[p];
                    batch = batch.max(self.replicas.batch[p]);
                }
            }
            let p99 = if lat.len() < MIN_P99_SAMPLES {
                f64::NAN
            } else {
                lat.sort_unstable_by(f64::total_cmp);
                percentile_sorted(&lat, 0.99)
            };
            let mean_ms = mean(&lat);
            let grp = &mut self.groups[g];
            let dt = (now - grp.last_sample_ms).max(1e-9);
            let rps = grp.served_since_sample as f64 / dt * 1000.0;
            grp.timeline.push(TimelinePoint {
                t_ms: now,
                p99_ms: p99,
                mean_ms,
                rps,
                resources,
                batch,
            });
            grp.served_since_sample = 0;
            grp.last_sample_ms = now;
        }
        self.lat_scratch = lat;
    }

    /// Run the simulation to the horizon; returns per-workload stats.
    pub fn run(&mut self) -> Vec<WorkloadStats> {
        // seed arrivals + monitor (+ tune when the policy wants it)
        for g in 0..self.groups.len() {
            let t = self.groups[g].arrivals.next();
            self.events.schedule_at(t, Event::Arrival { g });
        }
        self.events.schedule_at(MONITOR_PERIOD_MS, Event::Monitor);
        if let Some(period) = self.policy.tune_period_ms() {
            self.events.schedule_at(period, Event::Tune);
        }
        // fault schedule + per-workload resilience cache: an empty plan
        // adds zero events, so the fault-free stream is bitwise unchanged
        for f in 0..self.fault_plan.events.len() {
            let at = self.fault_plan.events[f].at_ms;
            self.events.schedule_at(at, Event::Fault { f: f as u32 });
        }
        for g in 0..self.groups.len() {
            let w = self.groups[g].spec.id;
            self.groups[g].resilience = self.policy.resilience(w);
        }
        // O(1) breaker-maintenance guard: resilience grants are cached
        // once per run (just above) and the fault plan is fixed, so a
        // run with everything off provably never raises fault state —
        // `enforce_breakers` then skips even its per-replica flag scan.
        self.breakers_armed = !self.fault_plan.is_empty()
            || self.groups.iter().any(|g| g.resilience != Resilience::OFF);

        while let Some(t) = self.events.peek_time() {
            if t > self.horizon_ms {
                break;
            }
            let (now, ev) = self.events.pop().unwrap();
            match ev {
                Event::Arrival { g } => {
                    if self.groups[g].degraded {
                        // cold path: resilience hooks (shed/hedge) apply
                        self.degraded_arrival(g, now);
                        continue;
                    }
                    // route among the cached Active members only: warming
                    // shadows are not ready, draining ones are retiring
                    let grp = &self.groups[g];
                    let queues = &self.replicas.queue;
                    let res = &self.replicas.resources;
                    let p = self
                        .router
                        .route(g, &grp.routable, |p| queues[p].len(), |p| res[p]);
                    self.req_slab.push_back(&mut self.replicas.queue[p], now);
                    self.groups[g].arrivals_count += 1;
                    let w = self.groups[g].spec.id;
                    self.policy.on_arrival(now, w);
                    let next = self.groups[g].arrivals.next();
                    self.events.schedule_at(next, Event::Arrival { g });
                    self.try_dispatch(p);
                }
                Event::TryDispatch { p } => self.try_dispatch(p),
                Event::Complete {
                    p,
                    n,
                    dispatched,
                    t_load,
                } => {
                    if self.replicas.lost[p] || self.replicas.hung[p] {
                        // the process died or wedged with this batch in
                        // flight: the completion never happens (a lost
                        // replica's queue was already re-homed; a hung
                        // one keeps its requests until condemnation)
                        continue;
                    }
                    let record = now >= self.warmup_ms;
                    let reps = &mut self.replicas;
                    // queueing-vs-execution split: every request of the
                    // batch executes for the same span after dispatch
                    let exec_ms = (now + t_load) - dispatched;
                    // one observation per batch, warm-up included — the
                    // calibration consumer applies its own gating
                    reps.exec_window[p].push(now, exec_ms);
                    for _ in 0..n {
                        let arr = self
                            .req_slab
                            .pop_front(&mut reps.queue[p])
                            .expect("queue underflow");
                        // Eq. 1 view: latency = queueing + load + gpu + feedback
                        let lat = (now + t_load) - arr;
                        debug_assert!(lat >= 0.0);
                        if record {
                            reps.window[p].push(now, lat);
                            reps.hist[p].record(lat / 1000.0);
                            reps.recorded[p] += 1;
                            reps.lat_sum[p] += lat;
                            reps.queue_sum[p] += dispatched - arr;
                            reps.exec_sum[p] += exec_ms;
                        }
                        reps.served[p] += 1;
                    }
                    reps.busy[p] = false;
                    let g = self.group_of[p];
                    self.groups[g].served_since_sample += n as u64;
                    if record {
                        // activity epoch: this batch pushed recorded
                        // latency samples at `now`
                        self.groups[g].last_window_push_ms = now;
                    }
                    // recovery clock: the first batch served by a replica
                    // launched after the group's fault closes the sample
                    if let Some(t0) = self.groups[g].fault_at {
                        if self.replicas.launched_ms[p] > t0 {
                            self.recovery_ms.push(now - t0);
                            self.groups[g].fault_at = None;
                            self.refresh_degraded(g);
                        }
                    }
                    self.try_dispatch(p);
                    // a draining replica with nothing left retires now
                    if self.replicas.phase[p] == ReplicaPhase::Draining
                        && self.replicas.queue[p].is_empty()
                        && !self.replicas.busy[p]
                    {
                        self.retire(p);
                    }
                }
                Event::Monitor => {
                    self.sample_timeline();
                    self.accrue_gpu_time(now);
                    let deltas = {
                        let mut ctx = PolicyCtx {
                            devices: &mut self.devices,
                            replicas: &mut self.replicas,
                        };
                        self.policy.on_monitor(now, &mut ctx);
                        self.policy.reprovision(now, &mut ctx)
                    };
                    // absorb any direct resource writes the hooks made
                    // (shadow activation) into the group aggregates
                    self.drain_resources_dirty();
                    // realize any breaker verdicts the policy just made
                    // (condemnations retire + re-home before the deltas
                    // launch replacements)
                    self.enforce_breakers(now);
                    for d in deltas {
                        self.apply_delta(d);
                    }
                    self.events.schedule_in(MONITOR_PERIOD_MS, Event::Monitor);
                }
                Event::Tune => {
                    let mut ctx = PolicyCtx {
                        devices: &mut self.devices,
                        replicas: &mut self.replicas,
                    };
                    self.policy.on_tune(now, &mut ctx);
                    self.drain_resources_dirty();
                    if let Some(period) = self.policy.tune_period_ms() {
                        self.events.schedule_in(period, Event::Tune);
                    }
                }
                Event::SwitchOver { g } => {
                    let mut fresh = self.groups[g]
                        .fresh_batches
                        .pop_front()
                        .expect("switch-over without a pending fresh batch");
                    // a device death may have taken fresh replicas while
                    // they warmed (phase forced to Retired): they never
                    // open.  If the whole batch died, skip the switch —
                    // the old replicas keep serving.
                    fresh.retain(|&p| self.replicas.phase[p] == ReplicaPhase::Warming);
                    if fresh.is_empty() {
                        continue;
                    }
                    // drain everything the fresh replicas replace...
                    for i in 0..self.groups[g].members.len() {
                        let p = self.groups[g].members[i];
                        if fresh.contains(&p) {
                            continue;
                        }
                        if self.replicas.phase[p] == ReplicaPhase::Active {
                            self.replicas.phase[p] = ReplicaPhase::Draining;
                            if self.replicas.queue[p].is_empty() && !self.replicas.busy[p] {
                                self.retire(p); // already idle
                            }
                        }
                    }
                    // ...then open the fresh ones for traffic
                    for &p in &fresh {
                        self.replicas.phase[p] = ReplicaPhase::Active;
                        self.replicas.busy[p] = false;
                    }
                    // rebuild the routing cache for the new Active set
                    // (the aggregate refresh is belt-and-braces: phase
                    // flips among non-Retired members leave the cached
                    // sum/max unchanged, and any retire() above already
                    // refreshed — but switch-overs are rare and the
                    // refresh is bitwise a no-op when nothing changed)
                    self.refresh_group_aggregates(g);
                    self.rebuild_routable(g);
                    for p in fresh {
                        self.try_dispatch(p);
                    }
                }
                Event::Fault { f } => self.apply_fault(f as usize),
            }
        }
        // charge the tail interval (last monitor tick -> horizon)
        self.accrue_gpu_time(self.horizon_ms);

        // final stats: aggregate each replica group
        let span_ms = self.horizon_ms - self.warmup_ms;
        self.groups
            .iter()
            .map(|grp| {
                let mut hist = LatencyHistogram::new();
                let mut served = 0u64;
                let mut recorded = 0u64;
                let (mut lat_sum, mut queue_sum, mut exec_sum) = (0.0, 0.0, 0.0);
                let mut switches = 0u32;
                let mut final_resources = 0.0;
                let mut final_batch = 0u32;
                let mut still_queued = 0u64;
                let mut replica_served = Vec::with_capacity(grp.members.len());
                for &p in &grp.members {
                    let reps = &self.replicas;
                    // lifetime stats span every member — including
                    // replicas retired by a shadow migration, so P99 and
                    // the conservation counters cover the whole run
                    hist.merge(&reps.hist[p]);
                    served += reps.served[p];
                    recorded += reps.recorded[p];
                    lat_sum += reps.lat_sum[p];
                    queue_sum += reps.queue_sum[p];
                    exec_sum += reps.exec_sum[p];
                    switches += reps.switches[p];
                    still_queued += reps.queue[p].len() as u64;
                    replica_served.push(reps.served[p]);
                    // ...but the "current configuration" fields describe
                    // only what is still on a device
                    if reps.phase[p] != ReplicaPhase::Retired {
                        final_resources += reps.resources[p];
                        final_batch = final_batch.max(reps.batch[p]);
                    }
                }
                // lifetime P99 from the merged log-bucket histogram (~2 %
                // relative resolution) — exact per-sample history is no
                // longer retained beyond the sliding window
                let p99 = hist.percentile(0.99) * 1000.0;
                // all three means share the recorded == 0 -> NaN treatment
                // so the documented breakdown identity always holds
                let per_recorded = |sum: f64| {
                    if recorded == 0 {
                        f64::NAN
                    } else {
                        sum / recorded as f64
                    }
                };
                let achieved = recorded as f64 / span_ms * 1000.0;
                // Hold throughput to the load actually *offered* inside the
                // horizon (capped by the nominal spec): a traced arrival
                // process runs below nominal by design and must not be
                // misreported as a throughput violation.
                let offered = grp.arrivals_count as f64 / self.horizon_ms * 1000.0;
                WorkloadStats {
                    name: grp.spec.name.clone(),
                    slo_ms: grp.spec.slo_ms,
                    rate_rps: grp.spec.rate_rps,
                    p99_ms: p99,
                    mean_ms: per_recorded(lat_sum),
                    mean_queue_ms: per_recorded(queue_sum),
                    mean_exec_ms: per_recorded(exec_sum),
                    achieved_rps: achieved,
                    served,
                    arrivals: grp.arrivals_count,
                    still_queued,
                    dropped: grp.dropped_count,
                    violation: p99 > grp.spec.slo_ms,
                    throughput_violation: achieved < offered.min(grp.spec.rate_rps) * 0.95,
                    shadow_switches: switches,
                    timeline: grp.timeline.clone(),
                    final_resources,
                    final_batch,
                    replica_served,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::EagerBatcher;
    use crate::coordinator::monitor::Reprovisioner;
    use crate::gpu::{GpuKind, Model};
    use crate::provisioner::{self, Alloc, Migration, ProfiledSystem};
    use crate::sim::faults::FaultEvent;
    use crate::workload::trace::TraceKind;
    use crate::workload::{app_workloads, table1_workloads};

    /// Test policy that emits a fixed delta batch on one monitor tick.
    struct ScriptedDeltas {
        at_tick: u32,
        tick: u32,
        deltas: Vec<PlanDelta>,
    }

    impl ScriptedDeltas {
        fn new(at_tick: u32, deltas: Vec<PlanDelta>) -> ScriptedDeltas {
            ScriptedDeltas {
                at_tick,
                tick: 0,
                deltas,
            }
        }
    }

    impl ServingPolicy for ScriptedDeltas {
        fn name(&self) -> &'static str {
            "scripted"
        }

        fn reprovision(&mut self, _now: f64, _ctx: &mut PolicyCtx) -> Vec<PlanDelta> {
            self.tick += 1;
            if self.tick == self.at_tick {
                std::mem::take(&mut self.deltas)
            } else {
                Vec::new()
            }
        }
    }

    fn one_workload_sim(resources: f64, batch: u32) -> (ClusterSim, Vec<WorkloadSpec>) {
        let s = sys();
        let specs = vec![WorkloadSpec::new(0, Model::AlexNet, 15.0, 400.0)];
        let mut plan = provisioner::Plan::new("test-migration", &s.hw);
        plan.gpus.push(vec![Alloc {
            workload: 0,
            resources,
            batch,
        }]);
        let sim = ClusterSim::new(
            GpuKind::V100,
            &plan,
            &specs,
            Policy::Static,
            ArrivalKind::Constant,
            41,
            &[],
        );
        (sim, specs)
    }

    fn sys() -> ProfiledSystem {
        let (hw, wls) = crate::profiler::profile_all(GpuKind::V100, 42);
        ProfiledSystem {
            hw,
            coeffs: crate::gpu::ALL_MODELS.iter().cloned().zip(wls).collect(),
        }
    }

    #[test]
    fn table1_serving_meets_slos() {
        let s = sys();
        let specs = table1_workloads();
        let plan = provisioner::provision(&s, &specs);
        let mut sim = ClusterSim::new(
            GpuKind::V100,
            &plan,
            &specs,
            Policy::IgniterShadow,
            ArrivalKind::Constant,
            7,
            &[],
        );
        sim.set_horizon(10_000.0, 1_000.0);
        let stats = sim.run();
        for st in &stats {
            assert!(
                !st.violation,
                "{}: P99 {:.2} > SLO {}",
                st.name, st.p99_ms, st.slo_ms
            );
            assert!(
                !st.throughput_violation,
                "{}: {:.0} rps < {:.0}",
                st.name, st.achieved_rps, st.rate_rps
            );
        }
    }

    #[test]
    fn igniter_plan_serves_12_workloads() {
        let s = sys();
        let specs = app_workloads();
        let plan = provisioner::provision(&s, &specs);
        let mut sim = ClusterSim::new(
            GpuKind::V100,
            &plan,
            &specs,
            Policy::IgniterShadow,
            ArrivalKind::Constant,
            11,
            &[],
        );
        sim.set_horizon(8_000.0, 1_000.0);
        let stats = sim.run();
        let violations = stats.iter().filter(|s| s.violation).count();
        assert_eq!(violations, 0, "{stats:#?}");
    }

    #[test]
    fn underprovision_triggers_shadow() {
        // Fig. 17: an injected prediction error makes W1 violate; the
        // shadow process takes over and restores the SLO.
        let s = sys();
        let specs = table1_workloads();
        let plan = provisioner::provision(&s, &specs);
        let mut sim = ClusterSim::new(
            GpuKind::V100,
            &plan,
            &specs,
            Policy::IgniterShadow,
            ArrivalKind::Constant,
            13,
            &[(0, 0.05)],
        );
        sim.set_horizon(12_000.0, 1_000.0);
        let stats = sim.run();
        assert!(stats[0].shadow_switches >= 1, "shadow never activated");
        // after the switch the tail must be under the SLO again: check the
        // last timeline samples
        let tail: Vec<&TimelinePoint> = stats[0]
            .timeline
            .iter()
            .filter(|t| t.t_ms > 8_000.0 && !t.p99_ms.is_nan())
            .collect();
        assert!(!tail.is_empty());
        assert!(
            tail.iter().all(|t| t.p99_ms <= specs[0].slo_ms * 1.05),
            "tail still violating: {tail:?}"
        );
    }

    #[test]
    fn deterministic_runs() {
        let s = sys();
        let specs = table1_workloads();
        let plan = provisioner::provision(&s, &specs);
        let run = |seed| {
            let mut sim = ClusterSim::new(
                GpuKind::V100,
                &plan,
                &specs,
                Policy::Static,
                ArrivalKind::Poisson,
                seed,
                &[],
            );
            sim.set_horizon(5_000.0, 500.0);
            sim.run()
                .iter()
                .map(|s| (s.served, s.p99_ms))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(3), run(3));
        assert_ne!(run(3), run(4));
    }

    #[test]
    fn idle_skip_point_equals_the_computed_one_at_the_boundary() {
        // A group whose only recorded sample has aged out of the 1 s
        // lookback is skip-admissible; the emitted O(1) point must be
        // bit-identical to the full walk's at the same instant.
        let (mut sim, _) = one_workload_sim(0.5, 4);
        sim.replicas.window[0].push(300.0, 12.0);
        sim.groups[0].last_window_push_ms = 300.0;
        sim.groups[0].served_since_sample = 0;
        // advance the clock to 1 500 ms: the sample is 1 200 ms old
        sim.events.schedule_at(1_500.0, Event::Monitor);
        let _ = sim.events.pop();
        sim.sample_timeline();
        let fast = *sim.groups[0].timeline.last().unwrap();
        // recompute with the walk at the identical instant
        sim.groups[0].timeline.clear();
        sim.groups[0].last_sample_ms = 0.0;
        sim.set_idle_fast_path(false);
        sim.sample_timeline();
        let slow = *sim.groups[0].timeline.last().unwrap();
        let bits = |p: &TimelinePoint| {
            (
                p.t_ms.to_bits(),
                p.p99_ms.to_bits(),
                p.mean_ms.to_bits(),
                p.rps.to_bits(),
                p.resources.to_bits(),
                p.batch,
            )
        };
        assert_eq!(bits(&fast), bits(&slow), "fast {fast:?} != slow {slow:?}");
        assert!(fast.p99_ms.is_nan() && fast.rps == 0.0);
        assert_eq!(fast.resources, 0.5);
        assert_eq!(fast.batch, 4);
        // ...and a sample still inside the lookback denies the skip: the
        // walk pools it (mean = the sample), proving the predicate sits
        // exactly at the window edge rather than merely near it
        let (mut live, _) = one_workload_sim(0.5, 4);
        live.replicas.window[0].push(800.0, 12.0);
        live.groups[0].last_window_push_ms = 800.0;
        live.events.schedule_at(1_500.0, Event::Monitor);
        let _ = live.events.pop();
        live.sample_timeline();
        let point = *live.groups[0].timeline.last().unwrap();
        assert_eq!(point.mean_ms, 12.0, "in-window sample was skipped: {point:?}");
    }

    #[test]
    fn property_idle_fast_path_is_bitwise_identical_to_the_full_walk() {
        // Long-tail-shaped mixes (one heavy hitter, eleven near-idle
        // tenants): for random seeds and tail rates, serving with the
        // idle fast path must be bit-for-bit the full-walk run —
        // timelines, latency stats, and final partitions all compared
        // through `to_bits` (NaN p99 points included).
        let s = sys();
        crate::util::quick::forall(
            77,
            3,
            |r| (r.next_u64(), r.range_f64(0.1, 2.0)),
            |&(seed, tail)| {
                let tail = tail.clamp(0.1, 2.0);
                let mut specs = app_workloads();
                for w in specs.iter_mut().skip(1) {
                    w.rate_rps = tail;
                }
                let plan = provisioner::provision(&s, &specs);
                let run = |fast: bool| {
                    let mut sim = ClusterSim::new(
                        GpuKind::V100,
                        &plan,
                        &specs,
                        Policy::IgniterShadow,
                        ArrivalKind::Poisson,
                        seed,
                        &[],
                    );
                    sim.set_idle_fast_path(fast);
                    sim.set_horizon(4_000.0, 500.0);
                    let stats = sim.run();
                    stats
                        .iter()
                        .map(|st| {
                            (
                                st.served,
                                st.arrivals,
                                st.p99_ms.to_bits(),
                                st.mean_ms.to_bits(),
                                st.final_resources.to_bits(),
                                st.final_batch,
                                st.timeline
                                    .iter()
                                    .map(|t| {
                                        (
                                            t.t_ms.to_bits(),
                                            t.p99_ms.to_bits(),
                                            t.mean_ms.to_bits(),
                                            t.rps.to_bits(),
                                            t.resources.to_bits(),
                                            t.batch,
                                        )
                                    })
                                    .collect::<Vec<_>>(),
                            )
                        })
                        .collect::<Vec<_>>()
                };
                let fast = run(true);
                if fast != run(false) {
                    return Err(format!("fast path diverged (seed {seed}, tail {tail})"));
                }
                // the tail must actually go quiet — otherwise the
                // property never exercised the skip
                let quiet = fast[1..].iter().any(|(_, _, _, _, _, _, tl)| {
                    tl.iter().any(|&(_, _, _, rps, _, _)| rps == 0.0_f64.to_bits())
                });
                if !quiet {
                    return Err(format!("no quiet tick at tail rate {tail}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn queueing_latency_counted() {
        // With a rate far above capacity, latency must blow past the SLO.
        let s = sys();
        let mut specs = table1_workloads();
        specs[0].rate_rps = 4000.0; // way beyond the plan's design point
        let plan_specs = table1_workloads();
        let plan = provisioner::provision(&s, &plan_specs);
        let mut sim = ClusterSim::new(
            GpuKind::V100,
            &plan,
            &specs,
            Policy::Static,
            ArrivalKind::Constant,
            5,
            &[],
        );
        sim.set_horizon(4_000.0, 500.0);
        let stats = sim.run();
        assert!(stats[0].violation, "overload did not violate: {stats:?}");
        // the blow-up is queueing, not execution: the breakdown shows it
        assert!(
            stats[0].mean_queue_ms > stats[0].mean_exec_ms,
            "queue {:.2} !> exec {:.2}",
            stats[0].mean_queue_ms,
            stats[0].mean_exec_ms
        );
    }

    #[test]
    fn gslice_tuner_grows_violating_partition() {
        // Serve with an injected under-provisioning under the reactive
        // tuner: it must grow the victim's partition over time.
        let s = sys();
        let specs = table1_workloads();
        let plan = provisioner::provision(&s, &specs);
        let start = plan.find(0).unwrap().1.resources - 0.05;
        let mut sim = ClusterSim::new(
            GpuKind::V100,
            &plan,
            &specs,
            Policy::GsliceTuner { period_ms: 2_000.0 },
            ArrivalKind::Constant,
            19,
            &[(0, 0.05)],
        );
        sim.set_horizon(14_000.0, 1_000.0);
        let stats = sim.run();
        assert!(
            stats[0].final_resources > start + 1e-9,
            "tuner never grew: {:.3} vs start {:.3}",
            stats[0].final_resources,
            start
        );
    }

    #[test]
    fn two_replicas_of_one_workload_round_robin() {
        // Regression for the old one-replica assumption: ClusterSim::new
        // used to index procs by workload id after sorting, silently
        // breaking on multi-allocation plans.  A plan with two allocations
        // for one workload must now split the traffic across both.
        let s = sys();
        let specs = vec![crate::provisioner::WorkloadSpec::new(
            0,
            Model::ResNet50,
            40.0,
            600.0,
        )];
        // derive a per-replica share for half the rate, one on each GPU
        let (batch, r_lower) = crate::perfmodel::lower_bound_resources(
            &s.hw,
            s.coeffs_for(Model::ResNet50),
            40.0,
            300.0,
        )
        .unwrap();
        let mut plan = provisioner::Plan::new("test-replicas", &s.hw);
        for _ in 0..2 {
            plan.gpus.push(vec![Alloc {
                workload: 0,
                resources: r_lower,
                batch,
            }]);
        }
        let mut sim = ClusterSim::new(
            GpuKind::V100,
            &plan,
            &specs,
            Policy::Static,
            ArrivalKind::Constant,
            23,
            &[],
        );
        sim.set_horizon(8_000.0, 1_000.0);
        let stats = sim.run();
        assert_eq!(stats.len(), 1, "stats aggregate per workload");
        assert_eq!(stats[0].replica_served.len(), 2);
        let total: u64 = stats[0].replica_served.iter().sum();
        assert_eq!(total, stats[0].served);
        for (j, &served) in stats[0].replica_served.iter().enumerate() {
            assert!(
                served as f64 >= 0.4 * total as f64,
                "replica {j} starved: {:?}",
                stats[0].replica_served
            );
        }
        assert!(!stats[0].violation, "P99 {:.2}", stats[0].p99_ms);
        assert!(!stats[0].throughput_violation);
        // request conservation across the group
        assert_eq!(stats[0].arrivals, stats[0].served + stats[0].still_queued);
    }

    #[test]
    fn weighted_routing_follows_resources() {
        // Two replicas at 2:1 resources under WeightedByResources must
        // receive traffic ~2:1.
        let s = sys();
        let specs = vec![crate::provisioner::WorkloadSpec::new(
            0,
            Model::AlexNet,
            15.0,
            600.0,
        )];
        let mut plan = provisioner::Plan::new("test-weighted", &s.hw);
        plan.gpus.push(vec![Alloc {
            workload: 0,
            resources: 0.5,
            batch: 4,
        }]);
        plan.gpus.push(vec![Alloc {
            workload: 0,
            resources: 0.25,
            batch: 4,
        }]);
        let mut sim = ClusterSim::new(
            GpuKind::V100,
            &plan,
            &specs,
            Policy::Static,
            ArrivalKind::Constant,
            29,
            &[],
        );
        sim.set_route_strategy(RouteStrategy::WeightedByResources);
        sim.set_horizon(6_000.0, 0.0);
        let stats = sim.run();
        let ratio =
            stats[0].replica_served[0] as f64 / stats[0].replica_served[1].max(1) as f64;
        assert!(
            (1.8..=2.2).contains(&ratio),
            "served split {:?} (ratio {ratio:.2})",
            stats[0].replica_served
        );
    }

    #[test]
    fn latency_breakdown_sums_to_mean() {
        let s = sys();
        let specs = table1_workloads();
        let plan = provisioner::provision(&s, &specs);
        let mut sim = ClusterSim::new(
            GpuKind::V100,
            &plan,
            &specs,
            Policy::Static,
            ArrivalKind::Constant,
            31,
            &[],
        );
        sim.set_horizon(6_000.0, 1_000.0);
        for st in sim.run() {
            assert!(
                (st.mean_queue_ms + st.mean_exec_ms - st.mean_ms).abs() < 1e-9,
                "{}: {:.4} + {:.4} != {:.4}",
                st.name,
                st.mean_queue_ms,
                st.mean_exec_ms,
                st.mean_ms
            );
            assert!(st.mean_queue_ms >= 0.0);
            assert!(st.mean_exec_ms > 0.0);
        }
    }

    #[test]
    fn shadow_migration_moves_workload_without_dropping_requests() {
        // Script a migration to a brand-new device at t = 2 s: the fresh
        // replica warms up while the old one serves, then the old one
        // drains and retires.  Conservation and the SLO must hold across
        // the switch, and the vacated device stops accruing GPU-seconds.
        let (mut sim, specs) = one_workload_sim(0.4, 4);
        sim.set_serving_policy(Box::new(ScriptedDeltas::new(
            4,
            vec![PlanDelta::Migrate(Migration {
                workload: 0,
                to: vec![(
                    1,
                    Alloc {
                        workload: 0,
                        resources: 0.4,
                        batch: 4,
                    },
                )],
            })],
        )));
        sim.set_horizon(8_000.0, 0.0);
        let stats = sim.run();
        assert_eq!(sim.migrations(), 1);
        assert_eq!(stats[0].arrivals, stats[0].served + stats[0].still_queued);
        assert_eq!(stats[0].replica_served.len(), 2, "old + fresh replica");
        assert!(
            stats[0].replica_served.iter().all(|&s| s > 0),
            "both replicas must have served: {:?}",
            stats[0].replica_served
        );
        // lifetime P99 spans the switch and stays within the SLO
        assert!(
            !stats[0].violation,
            "P99 {:.2} > SLO {}",
            stats[0].p99_ms, specs[0].slo_ms
        );
        // only the fresh replica is still configured
        assert!((stats[0].final_resources - 0.4).abs() < 1e-9);
        // gpu0 released after the drain: well under 2 devices x 8 s
        let gs = sim.gpu_seconds();
        assert!(
            (7.9..11.0).contains(&gs),
            "gpu-seconds {gs:.2} (expected ~8.5: gpu0 ~2.5 s + gpu1 ~6 s)"
        );
    }

    #[test]
    fn resize_delta_adjusts_partition_in_place() {
        let (mut sim, _) = one_workload_sim(0.3, 4);
        sim.set_serving_policy(Box::new(ScriptedDeltas::new(
            4,
            vec![PlanDelta::Resize {
                workload: 0,
                gpu: 0,
                resources: 0.5,
            }],
        )));
        sim.set_horizon(6_000.0, 0.0);
        let stats = sim.run();
        assert_eq!(sim.migrations(), 0, "a resize is not a migration");
        assert!((stats[0].final_resources - 0.5).abs() < 1e-9);
        assert_eq!(stats[0].replica_served.len(), 1);
        assert_eq!(stats[0].arrivals, stats[0].served + stats[0].still_queued);
    }

    #[test]
    fn rate_trace_drives_live_arrival_process() {
        // A two-epoch step trace (0.5x then 1.0x of 400 rps over 4 s
        // epochs) must produce ~400*0.5*4 + 400*1.0*4 = 2400 arrivals.
        let (mut sim, _) = one_workload_sim(0.5, 4);
        let mut trace = crate::workload::trace::RateTrace::generate(
            TraceKind::Ramp { from: 0.5, to: 1.0 },
            2,
            1,
            1,
        );
        trace.multiplier = vec![vec![0.5], vec![1.0]];
        sim.set_rate_trace(&trace, 4_000.0);
        sim.set_horizon(8_000.0, 0.0);
        let stats = sim.run();
        assert!(
            (2300..=2500).contains(&(stats[0].arrivals as i64)),
            "arrivals {} != ~2400",
            stats[0].arrivals
        );
        assert_eq!(stats[0].arrivals, stats[0].served + stats[0].still_queued);
    }

    /// Conservation under faults: every arrival is served, still queued,
    /// or explicitly dropped — nothing vanishes.
    fn assert_conservation(stats: &[WorkloadStats]) {
        for st in stats {
            assert_eq!(
                st.arrivals,
                st.served + st.still_queued + st.dropped,
                "{}: {} arrivals != {} served + {} queued + {} dropped",
                st.name,
                st.arrivals,
                st.served,
                st.still_queued,
                st.dropped
            );
        }
    }

    #[test]
    fn device_death_fails_over_and_recovers() {
        // Kill an occupied device mid-run under the closed-loop
        // reprovisioner: victims are re-placed on survivors (or a fresh
        // instance), the recovery clock closes, and every request is
        // accounted for.
        let s = sys();
        let specs = app_workloads();
        let plan = provisioner::provision(&s, &specs);
        let rp = Reprovisioner::new(sys(), specs.clone(), plan.clone())
            .with_resilience(Resilience::ALL);
        let mut sim = ClusterSim::new(
            GpuKind::V100,
            &plan,
            &specs,
            Policy::Static,
            ArrivalKind::Constant,
            43,
            &[],
        );
        sim.set_serving_policy(Box::new(rp));
        let mut fp = FaultPlan::none();
        fp.events.push(FaultEvent {
            at_ms: 3_000.0,
            kind: FaultKind::DeviceDeath { target: 0 },
        });
        sim.set_fault_plan(fp);
        sim.set_horizon(20_000.0, 1_000.0);
        let stats = sim.run();
        assert_eq!(sim.faults_injected(), 1);
        assert_conservation(&stats);
        // the failover migration executed and replacement capacity served
        assert!(sim.migrations() >= 1, "no failover migration ran");
        assert!(
            !sim.recovery_ms().is_empty(),
            "no recovery sample: replacement never served"
        );
        for &r in sim.recovery_ms() {
            assert!(
                r > 0.0 && r < 10_000.0,
                "implausible recovery span {r:.0} ms"
            );
        }
        // losses are bounded: the outage window, not the whole run
        let arrivals: u64 = stats.iter().map(|s| s.arrivals).sum();
        let dropped: u64 = stats.iter().map(|s| s.dropped).sum();
        assert!(
            (dropped as f64) < arrivals as f64 * 0.10,
            "dropped {dropped} of {arrivals} arrivals"
        );
        // the residual definition now equals the explicit drop count
        assert_eq!(dropped_requests(&stats), dropped as i64);
    }

    #[test]
    fn straggler_dilates_latency_then_heals() {
        let run = |with_fault: bool| {
            let (mut sim, _) = one_workload_sim(0.4, 4);
            if with_fault {
                let mut fp = FaultPlan::none();
                fp.events.push(FaultEvent {
                    at_ms: 2_000.0,
                    kind: FaultKind::Straggler {
                        target: 0,
                        factor: 4.0,
                        span_ms: 2_000.0,
                    },
                });
                sim.set_fault_plan(fp);
            }
            sim.set_horizon(8_000.0, 0.0);
            let stats = sim.run();
            (sim.faults_injected(), stats)
        };
        let (healthy_faults, healthy) = run(false);
        let (faults, dilated) = run(true);
        assert_eq!(healthy_faults, 0);
        assert_eq!(faults, 1);
        assert_conservation(&healthy);
        assert_conservation(&dilated);
        assert_eq!(healthy[0].dropped, 0);
        assert_eq!(dilated[0].dropped, 0, "a straggler drops nothing");
        assert!(
            dilated[0].p99_ms > healthy[0].p99_ms * 1.5,
            "dilation invisible: {:.2} vs {:.2}",
            dilated[0].p99_ms,
            healthy[0].p99_ms
        );
        // the span is transient: the run still serves the full load
        assert_eq!(dilated[0].arrivals, healthy[0].arrivals);
        assert!(dilated[0].served > 0);
    }

    #[test]
    fn hang_is_condemned_requeued_and_replaced() {
        // Wedge one of two replicas: the breaker condemns it, its queue
        // re-homes onto the survivor, and a replacement group is warmed
        // and switched in — with every request accounted for.
        let s = sys();
        let specs = vec![crate::provisioner::WorkloadSpec::new(
            0,
            Model::ResNet50,
            40.0,
            600.0,
        )];
        let (batch, r_lower) = crate::perfmodel::lower_bound_resources(
            &s.hw,
            s.coeffs_for(Model::ResNet50),
            40.0,
            300.0,
        )
        .unwrap();
        let mut plan = provisioner::Plan::new("test-hang", &s.hw);
        for _ in 0..2 {
            plan.gpus.push(vec![Alloc {
                workload: 0,
                resources: r_lower,
                batch,
            }]);
        }
        let rp = Reprovisioner::new(sys(), specs.clone(), plan.clone())
            .with_resilience(Resilience::ALL);
        let mut sim = ClusterSim::new(
            GpuKind::V100,
            &plan,
            &specs,
            Policy::Static,
            ArrivalKind::Constant,
            47,
            &[],
        );
        sim.set_serving_policy(Box::new(rp));
        let mut fp = FaultPlan::none();
        fp.events.push(FaultEvent {
            at_ms: 3_000.0,
            kind: FaultKind::ReplicaHang { target: 0 },
        });
        sim.set_fault_plan(fp);
        sim.set_horizon(15_000.0, 1_000.0);
        let stats = sim.run();
        assert_eq!(sim.faults_injected(), 1);
        assert_conservation(&stats);
        assert!(
            stats[0].replica_served.len() > 2,
            "no replacement replica was ever launched: {:?}",
            stats[0].replica_served
        );
        let replacement_served: u64 = stats[0].replica_served[2..].iter().sum();
        assert!(
            replacement_served > 0,
            "replacements never served: {:?}",
            stats[0].replica_served
        );
    }

    #[test]
    fn batch_policy_is_swappable() {
        // The eager batcher trades batching efficiency for queue delay but
        // must still serve the full load on a plan with headroom.
        let s = sys();
        let specs = table1_workloads();
        let plan = provisioner::provision(&s, &specs);
        let mut sim = ClusterSim::new(
            GpuKind::V100,
            &plan,
            &specs,
            Policy::Static,
            ArrivalKind::Constant,
            37,
            &[],
        );
        sim.set_batch_policy(Box::new(EagerBatcher));
        sim.set_horizon(6_000.0, 1_000.0);
        let stats = sim.run();
        for st in &stats {
            assert!(st.served > 0);
            assert_eq!(st.arrivals, st.served + st.still_queued);
        }
    }
}
