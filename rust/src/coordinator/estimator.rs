//! Online per-workload arrival-rate estimation — the sensing half of the
//! closed re-provisioning loop (iGniter Sec. 5.3 adapts to workload
//! changes by periodically re-provisioning only the affected workloads;
//! this module decides *which* workloads those are).
//!
//! A `RateEstimator` counts arrivals in a time-bounded `SlidingWindow`
//! and smooths the instantaneous rate with an EWMA on every monitor
//! tick.  It flags **sustained** drift relative to the rate the current
//! allocation was planned for: a short burst inside the plan's headroom
//! is absorbed, but `SUSTAIN_TICKS` consecutive out-of-band ticks raise
//! `Drift::Up` / `Drift::Down`.  The reprovisioner combines this with a
//! predicted-SLO headroom check (observed rate approaching the predicted
//! capacity of the allocation) to trigger a re-plan before queues build.
//!
//! Everything is a pure function of the pushed `(t, arrival)` sequence
//! and the tick times, so closed-loop runs stay bit-identical per seed.

use crate::util::stats::SlidingWindow;

/// Span of the arrival-counting window (ms).  Long enough to smooth
/// Poisson noise at low rates, short enough to react within a few ticks.
pub const EST_WINDOW_MS: f64 = 5_000.0;
/// EWMA smoothing factor applied to the windowed rate on each tick.
pub const EWMA_ALPHA: f64 = 0.3;
/// Sustained observed rate above `planned x UP_DRIFT` flags `Drift::Up`.
pub const UP_DRIFT: f64 = 1.10;
/// Sustained observed rate below `planned x DOWN_DRIFT` flags `Drift::Down`.
pub const DOWN_DRIFT: f64 = 0.70;
/// Consecutive out-of-band ticks before a drift verdict is trusted.
pub const SUSTAIN_TICKS: u32 = 3;

/// Direction of a sustained arrival-rate drift.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Drift {
    /// The workload outgrew its allocation: re-plan eagerly.
    Up,
    /// The workload shrank well below its allocation: re-plan lazily to
    /// release resources.
    Down,
}

/// EWMA arrival-rate tracker for one workload.
#[derive(Debug, Clone)]
pub struct RateEstimator {
    arrivals: SlidingWindow,
    /// Rate the current allocation was planned for (req/s).
    planned_rps: f64,
    ewma_rps: f64,
    ticked: bool,
    verdict: Option<Drift>,
    sustained: u32,
}

impl RateEstimator {
    pub fn new(planned_rps: f64) -> RateEstimator {
        RateEstimator {
            arrivals: SlidingWindow::new(EST_WINDOW_MS),
            planned_rps,
            ewma_rps: planned_rps,
            ticked: false,
            verdict: None,
            sustained: 0,
        }
    }

    /// Record one arrival at virtual time `t` (ms).
    pub fn on_arrival(&mut self, t: f64) {
        self.arrivals.push(t, 1.0);
    }

    /// Update the estimate at a monitor tick; returns the smoothed rate.
    pub fn on_tick(&mut self, now: f64) -> f64 {
        let span_ms = EST_WINDOW_MS.min(now).max(1.0);
        let n = self.arrivals.count_since(now - span_ms);
        let inst = n as f64 / span_ms * 1000.0;
        self.ewma_rps = if self.ticked {
            EWMA_ALPHA * inst + (1.0 - EWMA_ALPHA) * self.ewma_rps
        } else {
            self.ticked = true;
            inst
        };
        let v = if self.ewma_rps > self.planned_rps * UP_DRIFT {
            Some(Drift::Up)
        } else if self.ewma_rps < self.planned_rps * DOWN_DRIFT {
            Some(Drift::Down)
        } else {
            None
        };
        if v == self.verdict {
            if v.is_some() {
                self.sustained += 1;
            }
        } else {
            self.verdict = v;
            self.sustained = u32::from(v.is_some());
        }
        self.ewma_rps
    }

    /// Current smoothed arrival rate (req/s).
    pub fn rate_rps(&self) -> f64 {
        self.ewma_rps
    }

    /// Rate the current allocation was planned for (req/s).
    pub fn planned_rps(&self) -> f64 {
        self.planned_rps
    }

    /// The drift verdict, once it has held for `SUSTAIN_TICKS` ticks.
    pub fn sustained_drift(&self) -> Option<Drift> {
        if self.sustained >= SUSTAIN_TICKS {
            self.verdict
        } else {
            None
        }
    }

    /// The workload was re-planned for `new_planned_rps`: rebase drift
    /// detection on the new design point.
    pub fn replanned(&mut self, new_planned_rps: f64) {
        self.planned_rps = new_planned_rps;
        self.verdict = None;
        self.sustained = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feed(est: &mut RateEstimator, rate_rps: f64, from_ms: f64, to_ms: f64) {
        let gap = 1000.0 / rate_rps;
        let mut t = from_ms;
        while t < to_ms {
            est.on_arrival(t);
            t += gap;
        }
    }

    #[test]
    fn tracks_a_steady_rate() {
        let mut e = RateEstimator::new(200.0);
        feed(&mut e, 200.0, 0.0, 6_000.0);
        for tick in 1..=12 {
            e.on_tick(tick as f64 * 500.0);
        }
        assert!((e.rate_rps() - 200.0).abs() < 20.0, "ewma {}", e.rate_rps());
        assert_eq!(e.sustained_drift(), None);
    }

    #[test]
    fn sustained_up_drift_flags_after_sustain_ticks() {
        let mut e = RateEstimator::new(100.0);
        // 3x the planned rate, long enough to dominate the window
        feed(&mut e, 300.0, 0.0, 8_000.0);
        let mut first_flag_tick = None;
        for tick in 1..=16 {
            e.on_tick(tick as f64 * 500.0);
            if e.sustained_drift().is_some() && first_flag_tick.is_none() {
                first_flag_tick = Some(tick);
            }
        }
        assert_eq!(e.sustained_drift(), Some(Drift::Up));
        let t = first_flag_tick.expect("never flagged");
        assert!(t >= SUSTAIN_TICKS as usize, "flagged too early (tick {t})");
    }

    #[test]
    fn short_burst_within_headroom_does_not_flag() {
        // A 0.2 s 3x burst adds ~40 arrivals to the 5 s window: the
        // windowed rate peaks below planned x UP_DRIFT, so no verdict.
        let mut e = RateEstimator::new(100.0);
        feed(&mut e, 100.0, 0.0, 4_000.0);
        feed(&mut e, 300.0, 4_000.0, 4_200.0);
        feed(&mut e, 100.0, 4_200.0, 10_000.0);
        for tick in 1..=20 {
            e.on_tick(tick as f64 * 500.0);
            assert_eq!(e.sustained_drift(), None, "flagged at tick {tick}");
        }
    }

    #[test]
    fn down_drift_and_replanned_rebase() {
        let mut e = RateEstimator::new(400.0);
        feed(&mut e, 100.0, 0.0, 8_000.0);
        for tick in 1..=16 {
            e.on_tick(tick as f64 * 500.0);
        }
        assert_eq!(e.sustained_drift(), Some(Drift::Down));
        // after re-planning for the observed rate the verdict resets
        e.replanned(e.rate_rps() * 1.2);
        assert_eq!(e.sustained_drift(), None);
        feed(&mut e, 100.0, 8_000.0, 12_000.0);
        for tick in 17..=24 {
            e.on_tick(tick as f64 * 500.0);
        }
        assert_eq!(e.sustained_drift(), None, "re-flagged at the new design point");
    }

    #[test]
    fn deterministic_per_input_sequence() {
        let run = || {
            let mut e = RateEstimator::new(250.0);
            feed(&mut e, 320.0, 0.0, 7_000.0);
            (1..=14).map(|t| e.on_tick(t as f64 * 500.0).to_bits()).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }
}
