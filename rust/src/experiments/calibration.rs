//! Online calibration under model mismatch — the Fig.-17
//! prediction-error story, closed-loop (beyond the paper; cf. arXiv
//! 2501.16909 on static interference models drifting from ground truth).
//!
//! The planner is handed **optimistically wrong** coefficients: every
//! workload class's believed timing is scaled by `(1 - mismatch)`, so the
//! provisioned plan under-sizes its gpulets while the simulator's physics
//! stay the unperturbed ground truth.  The same plan is then served three
//! ways:
//!
//!   * `static`      — no runtime adjustment: the mismatch lands on the
//!     tail unchecked (capacity below the arrival rate ⇒ queues build);
//!   * `closed-loop` — the `Reprovisioner` with the *static* believed
//!     model: it can sense headroom collapse, but every re-plan re-uses
//!     the same wrong coefficients;
//!   * `calibrated`  — `Reprovisioner::with_calibration`: observed exec
//!     latencies feed the RLS residual fit, re-plans trust the corrected
//!     model, and allocations grow to what the physics actually need.
//!
//! SLO attainment is judged on the **steady-state tail** (the last
//! quarter of the horizon, from the per-second timeline P99s): the whole
//! point of calibration is converging to a compliant configuration, and
//! lifetime P99 would forever bill the pre-convergence transient against
//! it.  Lifetime attainment is reported alongside for honesty, and
//! request conservation (`dropped == 0`) must hold throughout.

use super::common::{emit, profiled_system, SEED};
use crate::coordinator::{dropped_requests, ClusterSim, Policy, Reprovisioner, WorkloadStats};
use crate::gpu::GpuKind;
use crate::provisioner::{self, ProfiledSystem, WorkloadSpec};
use crate::util::error::Result;
use crate::util::stats::{mean, percentile};
use crate::util::table::{f, Table};
use crate::workload::{app_workloads, ArrivalKind};

/// Outcome of one policy's serving run under mismatch.
#[derive(Debug, Clone, PartialEq)]
pub struct CalibrationOutcome {
    /// Fraction of workloads whose tail-window timeline P99s all met the
    /// SLO (the steady-state verdict).
    pub tail_attainment: f64,
    /// Fraction of workloads whose lifetime P99 met the SLO.
    pub lifetime_attainment: f64,
    /// Mean / p95 of the policy-recorded prediction error (NaN-free;
    /// zero when the policy records none, e.g. `static`).
    pub mean_pred_error: f64,
    pub p95_pred_error: f64,
    pub migrations: u32,
    pub dropped: i64,
    pub served: u64,
}

/// One mismatch level's three-way comparison.
#[derive(Debug, Clone, PartialEq)]
pub struct CalibrationRow {
    pub mismatch: f64,
    pub static_run: CalibrationOutcome,
    pub uncalibrated: CalibrationOutcome,
    pub calibrated: CalibrationOutcome,
}

/// The believed system: truth with every class's timing scaled by
/// `1 - mismatch` (optimistic — the direction that hurts).
fn believed(truth: &ProfiledSystem, mismatch: f64) -> ProfiledSystem {
    let mut sys = truth.clone();
    for (_, wc) in &mut sys.coeffs {
        wc.scale_time(1.0 - mismatch);
    }
    sys
}

/// Tail-window attainment: a workload passes when every non-NaN timeline
/// P99 sample in the last quarter of the horizon meets its SLO (falling
/// back to the lifetime verdict when the tail has no trusted samples).
fn tail_attainment(stats: &[WorkloadStats], horizon_ms: f64) -> f64 {
    let cut = horizon_ms * 0.75;
    let met = stats
        .iter()
        .filter(|s| {
            let tail: Vec<&crate::coordinator::TimelinePoint> = s
                .timeline
                .iter()
                .filter(|t| t.t_ms >= cut && !t.p99_ms.is_nan())
                .collect();
            if tail.is_empty() {
                !s.violation
            } else {
                tail.iter().all(|t| t.p99_ms <= s.slo_ms)
            }
        })
        .count();
    met as f64 / stats.len().max(1) as f64
}

fn outcome(sim: &ClusterSim, stats: &[WorkloadStats], horizon_ms: f64) -> CalibrationOutcome {
    let lifetime = stats.iter().filter(|s| !s.violation).count();
    let errs = sim.serving_policy().prediction_errors();
    let (m, p95) = if errs.is_empty() {
        (0.0, 0.0)
    } else {
        (mean(errs), percentile(errs, 0.95))
    };
    CalibrationOutcome {
        tail_attainment: tail_attainment(stats, horizon_ms),
        lifetime_attainment: lifetime as f64 / stats.len().max(1) as f64,
        mean_pred_error: m,
        p95_pred_error: p95,
        migrations: sim.migrations(),
        dropped: dropped_requests(stats),
        served: stats.iter().map(|s| s.served).sum(),
    }
}

/// Run the three-way comparison at one mismatch level.  Deterministic
/// per seed; constant arrivals at the nominal rates isolate the model
/// error from rate drift.
pub fn calibration_summary(
    kind: GpuKind,
    specs: &[WorkloadSpec],
    mismatch: f64,
    horizon_ms: f64,
    seed: u64,
) -> CalibrationRow {
    let truth = profiled_system(kind, SEED);
    let bel = believed(&truth, mismatch);
    // the plan is provisioned from the *believed* coefficients — it is
    // exactly as wrong as the model
    let plan = provisioner::provision(&bel, specs);

    let serve = |policy: Option<Reprovisioner>| -> (ClusterSim, Vec<WorkloadStats>) {
        let mut sim = ClusterSim::new(
            kind,
            &plan,
            specs,
            Policy::Static,
            ArrivalKind::Constant,
            seed,
            &[],
        );
        if let Some(p) = policy {
            sim.set_serving_policy(Box::new(p));
        }
        sim.set_horizon(horizon_ms, 1_000.0);
        let stats = sim.run();
        (sim, stats)
    };

    let (st_sim, st_stats) = serve(None);
    let (un_sim, un_stats) = serve(Some(Reprovisioner::new(
        bel.clone(),
        specs.to_vec(),
        plan.clone(),
    )));
    let (ca_sim, ca_stats) = serve(Some(
        Reprovisioner::new(bel.clone(), specs.to_vec(), plan.clone()).with_calibration(),
    ));

    CalibrationRow {
        mismatch,
        static_run: outcome(&st_sim, &st_stats, horizon_ms),
        uncalibrated: outcome(&un_sim, &un_stats, horizon_ms),
        calibrated: outcome(&ca_sim, &ca_stats, horizon_ms),
    }
}

/// The `calibration` experiment: mismatch levels 0/10/20/30% x
/// {static, closed-loop, calibrated} over a 60 s horizon.
pub fn calibration(kind: GpuKind) -> Result<()> {
    let specs = app_workloads();
    let mut t = Table::new(
        "Online calibration under model mismatch (planner believes every \
         class (1-m)x faster than physics; tail attainment = last-quarter \
         timeline P99s vs SLO; drops must be 0)",
        &[
            "mismatch",
            "policy",
            "tail_attain",
            "lifetime",
            "pred_err",
            "pred_err_p95",
            "migrations",
            "dropped",
        ],
    );
    for &m in &[0.0, 0.10, 0.20, 0.30] {
        let row = calibration_summary(kind, &specs, m, 60_000.0, SEED);
        for (name, o) in [
            ("static", &row.static_run),
            ("closed-loop", &row.uncalibrated),
            ("calibrated", &row.calibrated),
        ] {
            t.row(&[
                format!("{:.0}%", m * 100.0),
                name.into(),
                format!("{:.1}%", o.tail_attainment * 100.0),
                format!("{:.1}%", o.lifetime_attainment * 100.0),
                f(o.mean_pred_error, 3),
                f(o.p95_pred_error, 3),
                o.migrations.to_string(),
                o.dropped.to_string(),
            ]);
        }
    }
    emit(&t, "calibration");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::table1_workloads;

    #[test]
    fn calibrated_recovers_what_the_static_model_loses() {
        // The acceptance bar: under a 25% optimistic mismatch the static
        // model's plan under-serves (tail attainment < 1), and the
        // calibrated closed loop ends at least as good as both the
        // static serve and the uncalibrated closed loop — with zero
        // drops everywhere and a strictly better verdict than static.
        let specs = table1_workloads();
        let row = calibration_summary(GpuKind::V100, &specs, 0.25, 30_000.0, SEED);
        for o in [&row.static_run, &row.uncalibrated, &row.calibrated] {
            assert_eq!(o.dropped, 0, "conservation violated: {o:?}");
            assert!(o.served > 0);
        }
        assert!(
            row.static_run.tail_attainment < 1.0,
            "25% mismatch did not hurt the static plan: {:?}",
            row.static_run
        );
        assert!(
            row.calibrated.tail_attainment >= row.static_run.tail_attainment,
            "calibrated {:.2} < static {:.2}",
            row.calibrated.tail_attainment,
            row.static_run.tail_attainment
        );
        assert!(
            row.calibrated.tail_attainment >= row.uncalibrated.tail_attainment,
            "calibrated {:.2} < uncalibrated {:.2}",
            row.calibrated.tail_attainment,
            row.uncalibrated.tail_attainment
        );
        assert!(
            row.calibrated.tail_attainment > row.static_run.tail_attainment,
            "calibration changed nothing over static at 25% mismatch"
        );
        assert!(
            row.calibrated.migrations >= 1,
            "the calibrated loop never re-planned"
        );
        // the calibrated model's believed error ends below the
        // uncalibrated one's (it learned the residual)
        assert!(
            row.calibrated.mean_pred_error < row.uncalibrated.mean_pred_error,
            "calibration did not shrink the believed error: {:.3} vs {:.3}",
            row.calibrated.mean_pred_error,
            row.uncalibrated.mean_pred_error
        );
    }

    #[test]
    fn zero_mismatch_keeps_everyone_compliant() {
        // With a correct model nothing should degrade: all three serve
        // modes attain their SLOs and conserve requests (calibration is
        // clamped to never shrink allocations, so it cannot hurt).
        let specs = table1_workloads();
        let row = calibration_summary(GpuKind::V100, &specs, 0.0, 20_000.0, SEED);
        for (name, o) in [
            ("static", &row.static_run),
            ("closed-loop", &row.uncalibrated),
            ("calibrated", &row.calibrated),
        ] {
            assert_eq!(o.dropped, 0, "{name} dropped requests");
            assert_eq!(
                o.tail_attainment, 1.0,
                "{name} tail attainment {:.2} under a correct model",
                o.tail_attainment
            );
        }
    }

    #[test]
    fn summary_is_deterministic() {
        let specs = table1_workloads();
        let a = calibration_summary(GpuKind::V100, &specs, 0.2, 12_000.0, 7);
        let b = calibration_summary(GpuKind::V100, &specs, 0.2, 12_000.0, 7);
        assert_eq!(a, b);
    }
}
