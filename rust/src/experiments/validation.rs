//! Model-validation experiments (Sec. 5.2): Figs. 11-13 — observed
//! (simulator ground truth with measurement noise) vs. predicted
//! (analytical model from profiled coefficients), including the gpu-lets+
//! pairwise predictor where the paper compares against it.

use super::common::{emit, measure, profiled_system, SEED};
use crate::gpu::{GpuDevice, GpuKind, Model};
use crate::perfmodel::{self, PlacedWorkload};
use crate::provisioner::gpulets;
use crate::util::table::{f, pct, Table};
use crate::util::error::Result;

fn observe(kind: GpuKind, placed: &[(Model, f64, u32)], target: usize, seed: u64) -> f64 {
    let (mean, _) = measure(3, || {
        let mut d = GpuDevice::new(kind, seed);
        for (i, &(m, r, b)) in placed.iter().enumerate() {
            assert!(d.launch(i as u64, m, r, b), "placement over 100%");
        }
        d.query_latency(target as u64, placed[target].2).unwrap().t_inf
    });
    mean
}

fn igniter_predict(
    sys: &crate::provisioner::ProfiledSystem,
    placed: &[(Model, f64, u32)],
    target: usize,
) -> f64 {
    let view: Vec<PlacedWorkload> = placed
        .iter()
        .map(|&(m, r, b)| PlacedWorkload {
            coeffs: sys.coeffs_for(m),
            batch: b as f64,
            resources: r,
        })
        .collect();
    perfmodel::predict(&sys.hw, &view, target).t_inf
}

/// gpu-lets+ can only predict pairs: solo + pairwise dilation of t_gpu.
fn gpulets_predict(
    sys: &crate::provisioner::ProfiledSystem,
    placed: &[(Model, f64, u32)],
    target: usize,
) -> Option<f64> {
    if placed.len() != 2 {
        return None;
    }
    let (m, r, b) = placed[target];
    let (om, or, ob) = placed[1 - target];
    let wc = sys.coeffs_for(m);
    let solo = perfmodel::predict_solo(&sys.hw, wc, b as f64, r);
    let t = PlacedWorkload {
        coeffs: wc,
        batch: b as f64,
        resources: r,
    };
    let o = PlacedWorkload {
        coeffs: sys.coeffs_for(om),
        batch: ob as f64,
        resources: or,
    };
    Some(solo.t_load + solo.t_feedback + solo.t_gpu * gpulets::pair_dilation(&t, &o))
}

/// Fig. 11: co-located VGG-19 + SSD, batch 3 each, resources swept.
pub fn fig11(kind: GpuKind) -> Result<()> {
    let sys = profiled_system(kind, SEED);
    let mut t = Table::new(
        "Fig. 11 — observed vs. predicted latency (ms), VGG-19 + SSD co-located, b=3 \
         (paper: iGniter err 0.04-2.32% V / 0.89-7.61% S)",
        &[
            "r_vgg", "r_ssd", "model", "observed", "iGniter", "err", "gpu-lets+", "err(gl)",
        ],
    );
    let mut max_err: f64 = 0.0;
    for &(rv, rs) in &[(0.2, 0.3), (0.3, 0.4), (0.4, 0.5), (0.5, 0.5), (0.3, 0.6)] {
        let placed = [(Model::Vgg19, rv, 3u32), (Model::Ssd, rs, 3u32)];
        for (ti, name) in [(0usize, "vgg19"), (1, "ssd")] {
            let obs = observe(kind, &placed, ti, SEED ^ (ti as u64) ^ ((rv * 100.0) as u64));
            let pred = igniter_predict(&sys, &placed, ti);
            let gl = gpulets_predict(&sys, &placed, ti).unwrap();
            let err = perfmodel::rel_error(pred, obs);
            max_err = max_err.max(err);
            t.row(&[
                pct(rv),
                pct(rs),
                name.to_string(),
                f(obs, 2),
                f(pred, 2),
                pct(err),
                f(gl, 2),
                pct(perfmodel::rel_error(gl, obs)),
            ]);
        }
    }
    emit(&t, "fig11");
    println!("max iGniter prediction error: {}", pct(max_err));
    Ok(())
}

/// Fig. 12: co-located AlexNet + ResNet-50, 50 % each, batch swept 1-32.
pub fn fig12(kind: GpuKind) -> Result<()> {
    let sys = profiled_system(kind, SEED);
    let mut t = Table::new(
        "Fig. 12 — observed vs. predicted latency (ms), AlexNet + ResNet-50 at 50% each \
         (paper: iGniter err 3.91-5.90% A / 1.10-9.29% R)",
        &["batch", "model", "observed", "iGniter", "err", "gpu-lets+", "err(gl)"],
    );
    for &b in &[1u32, 2, 4, 8, 16, 32] {
        let placed = [(Model::AlexNet, 0.5, b), (Model::ResNet50, 0.5, b)];
        for (ti, name) in [(0usize, "alexnet"), (1, "resnet50")] {
            let obs = observe(kind, &placed, ti, SEED ^ (b as u64) << 2 ^ ti as u64);
            let pred = igniter_predict(&sys, &placed, ti);
            let gl = gpulets_predict(&sys, &placed, ti).unwrap();
            t.row(&[
                b.to_string(),
                name.to_string(),
                f(obs, 2),
                f(pred, 2),
                pct(perfmodel::rel_error(pred, obs)),
                f(gl, 2),
                pct(perfmodel::rel_error(gl, obs)),
            ]);
        }
    }
    emit(&t, "fig12");
    Ok(())
}

/// Fig. 13: all four models co-located at 25 % each, batch 3 — beyond
/// gpu-lets' pairwise reach.
pub fn fig13(kind: GpuKind) -> Result<()> {
    let sys = profiled_system(kind, SEED);
    let mut t = Table::new(
        "Fig. 13 — observed vs. iGniter-predicted latency (ms), 4 co-located models \
         at 25% each, b=3 (paper: err 1.53-5.02%; gpu-lets cannot predict >2)",
        &["model", "observed", "predicted", "err", "sched_ms", "freq_mhz"],
    );
    let placed = [
        (Model::AlexNet, 0.25, 3u32),
        (Model::ResNet50, 0.25, 3),
        (Model::Vgg19, 0.25, 3),
        (Model::Ssd, 0.25, 3),
    ];
    let mut errs = Vec::new();
    for ti in 0..4 {
        let obs = observe(kind, &placed, ti, SEED ^ (77 + ti as u64));
        let view: Vec<PlacedWorkload> = placed
            .iter()
            .map(|&(m, r, b)| PlacedWorkload {
                coeffs: sys.coeffs_for(m),
                batch: b as f64,
                resources: r,
            })
            .collect();
        let p = perfmodel::predict(&sys.hw, &view, ti);
        let err = perfmodel::rel_error(p.t_inf, obs);
        errs.push(err);
        t.row(&[
            placed[ti].0.name().to_string(),
            f(obs, 2),
            f(p.t_inf, 2),
            pct(err),
            f(p.t_sched, 3),
            f(p.freq_mhz, 0),
        ]);
    }
    emit(&t, "fig13");
    println!(
        "error band: {} .. {}",
        pct(errs.iter().cloned().fold(f64::INFINITY, f64::min)),
        pct(errs.iter().cloned().fold(0.0, f64::max))
    );
    Ok(())
}

/// Replica-share validation: for a workload whose rate exceeds one V100
/// gpulet, Alg. 2 splits it into even rate-sharing replicas; compare each
/// replica's *predicted* latency/throughput (analytical model on its
/// share) against the *observed* serving behaviour of the multi-replica
/// `ClusterSim` pipeline.
pub fn replica_shares(kind: GpuKind) -> Result<()> {
    use crate::coordinator::{ClusterSim, Policy};
    use crate::provisioner::WorkloadSpec;
    use crate::workload::ArrivalKind;

    let sys = profiled_system(kind, SEED);
    // deterministic search for a just-over-capacity ResNet-50 rate
    let rate = crate::provisioner::igniter::over_capacity_rate(&sys, Model::ResNet50, 40.0, 400.0);
    let specs = vec![WorkloadSpec::new(0, Model::ResNet50, 40.0, rate)];
    let plan = crate::provisioner::provision(&sys, &specs);
    let k = plan.replica_count(0);
    let share = rate / k as f64;

    let horizon_ms = 10_000.0;
    let mut sim = ClusterSim::new(
        kind,
        &plan,
        &specs,
        Policy::IgniterShadow,
        ArrivalKind::Constant,
        SEED,
        &[],
    );
    sim.set_horizon(horizon_ms, 1_000.0);
    let stats = sim.run();

    let mut t = Table::new(
        "Replica-share validation — over-capacity workload split across \
         gpulets: per-replica predicted t_inf / share vs. observed serving",
        &[
            "replica", "gpu", "resources", "batch", "pred_t_inf", "share_rps", "obs_rps",
        ],
    );
    let preds = crate::provisioner::predict_plan(&sys, &specs, &plan);
    for (j, ((g, a), (_, t_inf, _))) in plan.replicas(0).iter().zip(preds.iter()).enumerate() {
        let obs_rps = stats[0].replica_served[j] as f64 / horizon_ms * 1000.0;
        t.row(&[
            format!("{}#{}", specs[0].name, j + 1),
            format!("GPU{}", g + 1),
            pct(a.resources),
            a.batch.to_string(),
            f(*t_inf, 2),
            f(share, 0),
            f(obs_rps, 0),
        ]);
    }
    emit(&t, "replica_shares");
    println!(
        "{} replicas, workload P99 {:.2} ms vs SLO {:.0} ms",
        k, stats[0].p99_ms, specs[0].slo_ms
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig11_12_13_run_and_errors_small() {
        fig11(GpuKind::V100).unwrap();
        fig12(GpuKind::V100).unwrap();
        fig13(GpuKind::V100).unwrap();
    }

    #[test]
    fn igniter_beats_gpulets_on_multi_colocation() {
        // With 4 co-located workloads the iGniter model still predicts
        // within ~10%; gpu-lets+ has no prediction at all (None).
        let kind = GpuKind::V100;
        let sys = profiled_system(kind, SEED);
        let placed = [
            (Model::AlexNet, 0.25, 3u32),
            (Model::ResNet50, 0.25, 3),
            (Model::Vgg19, 0.25, 3),
            (Model::Ssd, 0.25, 3),
        ];
        assert!(gpulets_predict(&sys, &placed, 0).is_none());
        for ti in 0..4 {
            let obs = observe(kind, &placed, ti, 123 + ti as u64);
            let pred = igniter_predict(&sys, &placed, ti);
            let e = perfmodel::rel_error(pred, obs);
            assert!(e < 0.12, "{ti}: err {:.1}%", e * 100.0);
        }
    }

    #[test]
    fn replica_share_validation_runs_and_splits_evenly() {
        replica_shares(GpuKind::V100).unwrap();
        let out = std::fs::read_to_string(
            super::super::common::results_dir().join("replica_shares.csv"),
        )
        .unwrap();
        // at least two replica rows behind the header
        assert!(out.lines().count() >= 3, "{out}");
    }

    #[test]
    fn pairwise_prediction_errors_reasonable() {
        // Sec. 5.2 band: single-digit percent errors for pairs.
        let kind = GpuKind::V100;
        let sys = profiled_system(kind, SEED);
        for &b in &[2u32, 8, 24] {
            let placed = [(Model::AlexNet, 0.5, b), (Model::ResNet50, 0.5, b)];
            for ti in 0..2 {
                let obs = observe(kind, &placed, ti, 55 + b as u64 + ti as u64);
                let pred = igniter_predict(&sys, &placed, ti);
                let e = perfmodel::rel_error(pred, obs);
                assert!(e < 0.12, "b={b} ti={ti}: err {:.1}%", e * 100.0);
            }
        }
    }
}
