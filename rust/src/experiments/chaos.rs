//! Chaos serving: the same workload mix served fault-free and under a
//! directed fault schedule (one GPU death, one straggler episode, one
//! replica hang), with the full resilience stack answering — breaker
//! condemnation, deadline shed / hedged dispatch for degraded groups,
//! and cooldown-free failover respec through the placement engine.
//!
//! The acceptance story this harness prints: serving *through* faults
//! costs a bounded, explicitly-counted fraction of requests and a
//! measurable recovery time — never silent loss, never a stuck cluster.

use super::common::{emit, profiled_system, SEED};
use crate::coordinator::{dropped_requests, ClusterSim, Policy, Reprovisioner, Resilience};
use crate::gpu::GpuKind;
use crate::provisioner::{self, WorkloadSpec};
use crate::sim::faults::{FaultEvent, FaultKind, FaultPlan};
use crate::util::error::Result;
use crate::util::stats::percentile;
use crate::util::table::{f, Table};
use crate::workload::{app_workloads, ArrivalKind};

/// Outcome of one serving run of the chaos comparison.
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosOutcome {
    pub served: u64,
    pub arrivals: u64,
    /// Explicitly dropped (shed + orphaned); equals the conservation
    /// residual `arrivals - served - still_queued`.
    pub dropped: i64,
    /// Fraction of workloads whose lifetime P99 met the SLO.
    pub slo_attainment: f64,
    pub migrations: u32,
    pub faults_injected: u64,
    pub recovery_episodes: usize,
    /// P95 over recovery episodes (fault instant -> first batch served
    /// by a replacement replica); 0 when none closed.
    pub recovery_ms_p95: f64,
}

/// The directed schedule: all three fault kinds, spaced so each recovery
/// completes before the next injection and well inside the horizon.
fn directed_plan(horizon_ms: f64) -> FaultPlan {
    FaultPlan {
        events: vec![
            FaultEvent {
                at_ms: 0.25 * horizon_ms,
                kind: FaultKind::DeviceDeath { target: 0 },
            },
            FaultEvent {
                at_ms: 0.45 * horizon_ms,
                kind: FaultKind::Straggler {
                    target: 1,
                    factor: 3.0,
                    span_ms: 800.0,
                },
            },
            FaultEvent {
                at_ms: 0.60 * horizon_ms,
                kind: FaultKind::ReplicaHang { target: 2 },
            },
        ],
    }
}

fn serve_once(
    kind: GpuKind,
    specs: &[WorkloadSpec],
    horizon_ms: f64,
    seed: u64,
    faults: Option<FaultPlan>,
) -> ChaosOutcome {
    let sys = profiled_system(kind, SEED);
    let plan = provisioner::provision(&sys, specs);
    let mut sim = ClusterSim::new(
        kind,
        &plan,
        specs,
        Policy::Static,
        ArrivalKind::Poisson,
        seed,
        &[],
    );
    let mut rp = Reprovisioner::new(sys.clone(), specs.to_vec(), plan.clone());
    if faults.is_some() {
        rp = rp.with_resilience(Resilience::ALL);
    }
    sim.set_serving_policy(Box::new(rp));
    if let Some(fp) = faults {
        sim.set_fault_plan(fp);
    }
    sim.set_horizon(horizon_ms, 1_000.0);
    let stats = sim.run();
    let met = stats.iter().filter(|s| !s.violation).count();
    let recovery = sim.recovery_ms();
    ChaosOutcome {
        served: stats.iter().map(|s| s.served).sum(),
        arrivals: stats.iter().map(|s| s.arrivals).sum(),
        dropped: dropped_requests(&stats),
        slo_attainment: met as f64 / stats.len().max(1) as f64,
        migrations: sim.migrations(),
        faults_injected: sim.faults_injected(),
        recovery_episodes: recovery.len(),
        recovery_ms_p95: if recovery.is_empty() {
            0.0
        } else {
            percentile(recovery, 0.95)
        },
    }
}

/// Run the comparison: identical mix + seed, fault-free vs the directed
/// fault schedule with full resilience.  Deterministic per seed.
pub fn chaos_summary(
    kind: GpuKind,
    specs: &[WorkloadSpec],
    horizon_ms: f64,
    seed: u64,
) -> (ChaosOutcome, ChaosOutcome) {
    let clean = serve_once(kind, specs, horizon_ms, seed, None);
    let faulted = serve_once(kind, specs, horizon_ms, seed, Some(directed_plan(horizon_ms)));
    (clean, faulted)
}

pub fn chaos(kind: GpuKind) -> Result<()> {
    let specs = app_workloads();
    let (clean, faulted) = chaos_summary(kind, &specs, 20_000.0, SEED);
    let mut t = Table::new(
        "Serving through faults: GPU death + straggler + replica hang vs \
         the same run fault-free (12 workloads, 20 s horizon; drops are \
         explicit and bounded, recovery = fault -> first replacement batch)",
        &[
            "lane",
            "faults",
            "served",
            "dropped",
            "drop_pct",
            "slo_attainment",
            "migrations",
            "recovery_p95_ms",
        ],
    );
    let row = |t: &mut Table, name: &str, o: &ChaosOutcome| {
        t.row(&[
            name.into(),
            o.faults_injected.to_string(),
            o.served.to_string(),
            o.dropped.to_string(),
            format!(
                "{:.2}%",
                100.0 * o.dropped.max(0) as f64 / o.arrivals.max(1) as f64
            ),
            format!("{:.1}%", o.slo_attainment * 100.0),
            o.migrations.to_string(),
            f(o.recovery_ms_p95, 0),
        ]);
    };
    row(&mut t, "fault-free", &clean);
    row(&mut t, "chaos+failover", &faulted);
    emit(&t, "chaos");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn failover_serves_through_the_directed_fault_schedule() {
        let specs = app_workloads();
        let (clean, faulted) = chaos_summary(GpuKind::V100, &specs, 16_000.0, SEED);
        // fault-free lane is the usual closed loop: nothing dropped
        assert_eq!(clean.dropped, 0);
        assert_eq!(clean.faults_injected, 0);
        // every directed fault lands (live targets exist at fire time)
        assert_eq!(faulted.faults_injected, 3, "{faulted:?}");
        // failover replaced the dead device's capacity and the clock ran
        assert!(faulted.migrations >= 1, "no failover respec: {faulted:?}");
        assert!(
            faulted.recovery_episodes >= 1 && faulted.recovery_ms_p95 > 0.0,
            "recovery never measured: {faulted:?}"
        );
        assert!(
            faulted.recovery_ms_p95 < 10_000.0,
            "recovery too slow: {faulted:?}"
        );
        // drops are explicit, non-negative, and a bounded fraction
        assert!(faulted.dropped >= 0, "double-counted serving: {faulted:?}");
        assert!(
            (faulted.dropped as u64) <= faulted.arrivals / 10,
            "unbounded loss: {faulted:?}"
        );
        assert!(faulted.served > 0);
    }

    #[test]
    fn chaos_summary_is_deterministic() {
        let specs = app_workloads();
        let a = chaos_summary(GpuKind::V100, &specs, 12_000.0, 7);
        let b = chaos_summary(GpuKind::V100, &specs, 12_000.0, 7);
        assert_eq!(a, b);
    }
}
