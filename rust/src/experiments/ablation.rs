//! Ablation study (DESIGN.md design-choice validation, not a paper figure):
//! how much does each of the three interference terms — scheduler (Eq. 6),
//! L2 cache (Eq. 8), power/frequency (Eq. 9) — contribute to prediction
//! accuracy?  Disabling a term turns the model into one of the paper's
//! straw-men (e.g. "no cache + no power" ≈ an Eq.-(11)-only solo model).

use super::common::{emit, measure, profiled_system, SEED};
use crate::gpu::{GpuDevice, GpuKind, Model};
use crate::perfmodel::{self, model::ModelTerms, PlacedWorkload};
use crate::util::table::{pct, Table};
use crate::util::error::Result;

/// Co-location scenarios used for the error measurement: the paper's
/// Fig.-13 quad plus two heavy pairs and a 5-way stack.
fn scenarios() -> Vec<Vec<(Model, f64, u32)>> {
    vec![
        vec![
            (Model::AlexNet, 0.25, 3),
            (Model::ResNet50, 0.25, 3),
            (Model::Vgg19, 0.25, 3),
            (Model::Ssd, 0.25, 3),
        ],
        vec![(Model::Vgg19, 0.5, 8), (Model::Ssd, 0.5, 8)],
        vec![(Model::AlexNet, 0.5, 16), (Model::ResNet50, 0.5, 16)],
        vec![
            (Model::Vgg19, 0.2, 16),
            (Model::Vgg19, 0.2, 16),
            (Model::Vgg19, 0.2, 16),
            (Model::Vgg19, 0.2, 16),
            (Model::Vgg19, 0.2, 16),
        ],
    ]
}

fn observed(kind: GpuKind, placed: &[(Model, f64, u32)], target: usize, seed: u64) -> f64 {
    let (mean, _) = measure(3, || {
        let mut d = GpuDevice::new(kind, seed);
        for (i, &(m, r, b)) in placed.iter().enumerate() {
            assert!(d.launch(i as u64, m, r, b));
        }
        d.query_latency(target as u64, placed[target].2).unwrap().t_inf
    });
    mean
}

/// Run the ablation: mean relative prediction error per model variant.
pub fn ablation(kind: GpuKind) -> Result<()> {
    let sys = profiled_system(kind, SEED);
    let variants: [(&str, ModelTerms); 5] = [
        ("full model", ModelTerms::ALL),
        (
            "- scheduler",
            ModelTerms {
                scheduler: false,
                ..ModelTerms::ALL
            },
        ),
        (
            "- cache",
            ModelTerms {
                cache: false,
                ..ModelTerms::ALL
            },
        ),
        (
            "- power",
            ModelTerms {
                power: false,
                ..ModelTerms::ALL
            },
        ),
        ("solo-only (none)", ModelTerms::NONE),
    ];

    let mut t = Table::new(
        "Ablation — mean |prediction error| across co-location scenarios \
         (each row disables one interference term of Eqs. 6/8/9)",
        &["model variant", "mean_err", "max_err"],
    );
    let mut results = Vec::new();
    for (name, terms) in variants {
        let mut errs = Vec::new();
        for (si, placed) in scenarios().iter().enumerate() {
            let view: Vec<PlacedWorkload> = placed
                .iter()
                .map(|&(m, r, b)| PlacedWorkload {
                    coeffs: sys.coeffs_for(m),
                    batch: b as f64,
                    resources: r,
                })
                .collect();
            for target in 0..placed.len() {
                let obs = observed(kind, placed, target, SEED ^ ((si as u64) << 8) ^ target as u64);
                let pred = perfmodel::model::predict_with(&sys.hw, &view, target, terms).t_inf;
                errs.push(perfmodel::rel_error(pred, obs));
            }
        }
        let mean = crate::util::stats::mean(&errs);
        let max = errs.iter().cloned().fold(0.0, f64::max);
        results.push((name, mean));
        t.row(&[name.to_string(), pct(mean), pct(max)]);
    }
    emit(&t, "ablation");

    // sanity: the full model must dominate every ablation
    let full = results[0].1;
    for (name, err) in &results[1..] {
        if *err < full {
            println!("note: '{name}' beat the full model ({:.2}% vs {:.2}%)", err * 100.0, full * 100.0);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_model_dominates_ablations() {
        let kind = GpuKind::V100;
        let sys = profiled_system(kind, SEED);
        let placed = scenarios().remove(0);
        let view: Vec<PlacedWorkload> = placed
            .iter()
            .map(|&(m, r, b)| PlacedWorkload {
                coeffs: sys.coeffs_for(m),
                batch: b as f64,
                resources: r,
            })
            .collect();
        let mut errs = std::collections::BTreeMap::new();
        for (name, terms) in [
            ("full", ModelTerms::ALL),
            ("none", ModelTerms::NONE),
            (
                "nocache",
                ModelTerms {
                    cache: false,
                    ..ModelTerms::ALL
                },
            ),
        ] {
            let mut es = Vec::new();
            for target in 0..placed.len() {
                let obs = observed(kind, &placed, target, 900 + target as u64);
                let pred =
                    perfmodel::model::predict_with(&sys.hw, &view, target, terms).t_inf;
                es.push(perfmodel::rel_error(pred, obs));
            }
            errs.insert(name, crate::util::stats::mean(&es));
        }
        assert!(errs["full"] < errs["nocache"], "{errs:?}");
        assert!(errs["nocache"] < errs["none"] + 0.05, "{errs:?}");
        assert!(errs["full"] < errs["none"], "{errs:?}");
        // cache is the dominant term on the quad scenario
        assert!(errs["none"] > 0.05, "ablated model should err >5%: {errs:?}");
    }

    #[test]
    fn ablation_harness_runs() {
        ablation(GpuKind::V100).unwrap();
    }
}
