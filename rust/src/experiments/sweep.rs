//! Sweep experiment harness: a small CI-quick fleet-scale sweep printed
//! as the usual results table (and persisted to `results/sweep.{txt,csv}`).
//! The heavyweight entry point is `igniter sweep ...` (see `main.rs`),
//! which also writes the machine-readable `BENCH_sweep.json` the CI bench
//! gate compares against `BENCH_baseline.json`.

use super::common::{emit, SEED};
use crate::gpu::GpuKind;
use crate::sweep::{run_sweep, ScenarioSpace, SweepConfig};
use crate::util::error::Result;
use crate::util::table::{f, Table};

/// Run a reduced quick sweep and summarize per fleet shape.
pub fn sweep(_kind: GpuKind) -> Result<()> {
    let cfg = SweepConfig {
        scenarios: 12,
        seeds: 2,
        parallel: 4,
        master_seed: SEED,
        space: ScenarioSpace::quick(),
        calibrate: false,
    };
    let report = run_sweep(&cfg);
    let agg = report.aggregate();

    let mut t = Table::new(
        "Fleet-scale scenario sweep (CI-quick space: randomized mixes x \
         SLO tiers x fleets x live traces, closed-loop serving per task)",
        &[
            "fleet",
            "tasks",
            "mean_$per_h",
            "slo_attain",
            "migrations",
            "served",
            "dropped",
        ],
    );
    for fleet in ["v100", "t4", "hetero"] {
        let rs: Vec<_> = report
            .results
            .iter()
            .filter(|r| r.fleet == fleet && r.feasible)
            .collect();
        if rs.is_empty() {
            continue;
        }
        let n = rs.len() as f64;
        t.row(&[
            fleet.to_string(),
            rs.len().to_string(),
            f(rs.iter().map(|r| r.cost_per_hour).sum::<f64>() / n, 2),
            format!(
                "{:.1}%",
                rs.iter().map(|r| r.slo_attainment).sum::<f64>() / n * 100.0
            ),
            rs.iter().map(|r| r.migrations as u64).sum::<u64>().to_string(),
            rs.iter().map(|r| r.served).sum::<u64>().to_string(),
            rs.iter().map(|r| r.dropped).sum::<i64>().to_string(),
        ]);
    }
    t.row(&[
        "ALL".to_string(),
        format!("{}/{}", agg.feasible, agg.tasks),
        f(agg.mean_cost_per_hour, 2),
        format!("{:.1}%", agg.mean_slo_attainment * 100.0),
        agg.total_migrations.to_string(),
        agg.total_served.to_string(),
        agg.total_dropped.to_string(),
    ]);
    emit(&t, "sweep");
    println!(
        "wall {:.2}s  ({:.1} scenarios/s, {:.0} served req/s of wall)",
        report.wall_s,
        report.results.len() as f64 / report.wall_s.max(1e-9),
        agg.total_served as f64 / report.wall_s.max(1e-9),
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_harness_runs_and_conserves() {
        sweep(GpuKind::V100).unwrap();
        let csv =
            std::fs::read_to_string(super::super::common::results_dir().join("sweep.csv")).unwrap();
        let all_line = csv.lines().last().unwrap();
        assert!(all_line.starts_with("ALL"), "{all_line}");
        // dropped column (last) must be zero across the whole sweep
        assert_eq!(all_line.rsplit(',').next().unwrap().trim(), "0", "{all_line}");
    }
}
