//! Shared context and helpers for the experiment harnesses.

use crate::gpu::{GpuKind, Model};
use crate::provisioner::ProfiledSystem;
use crate::util::table::Table;
use std::path::PathBuf;

/// Default measurement seed (all experiments are deterministic per seed).
pub const SEED: u64 = 42;

/// Build the profiled system for a GPU type (hardware + all 4 workloads).
pub fn profiled_system(kind: GpuKind, seed: u64) -> ProfiledSystem {
    crate::profiler::profile_system(kind, seed)
}

/// Results directory (results/ at the repo root).
pub fn results_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("results")
}

/// Print a table and persist it as results/<stem>.{txt,csv}.
pub fn emit(table: &Table, stem: &str) {
    println!("{}", table.render());
    if let Err(e) = table.save(&results_dir(), stem) {
        eprintln!("warning: could not save results/{stem}: {e}");
    }
}

/// The three motivation-experiment models (Sec. 2.2).
pub const MOTIVATION_MODELS: [Model; 3] = [Model::AlexNet, Model::ResNet50, Model::Vgg19];

/// Mean over repeated noisy measurements of a closure.
pub fn measure<F: FnMut() -> f64>(reps: usize, mut f: F) -> (f64, f64) {
    let xs: Vec<f64> = (0..reps).map(|_| f()).collect();
    (crate::util::stats::mean(&xs), crate::util::stats::std(&xs))
}
