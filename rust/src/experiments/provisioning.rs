//! Provisioning-effectiveness experiments (Sec. 2.3 + Sec. 5.3):
//! Table 1 and Figs. 14-19.

use super::common::{emit, profiled_system, SEED};
use crate::coordinator::{ClusterSim, Policy};
use crate::gpu::GpuKind;
use crate::provisioner::{
    ffd, gpulets, gslice, igniter, Plan, ProfiledSystem, WorkloadSpec,
};
use crate::util::table::{f, pct, Table};
use crate::workload::{app_workloads, table1_workloads, ArrivalKind};
use crate::util::error::Result;

/// Serve a plan in the DES and count P99 / throughput SLO violations.
pub fn serve_and_count(
    kind: GpuKind,
    plan: &Plan,
    specs: &[WorkloadSpec],
    policy: Policy,
    horizon_ms: f64,
    seed: u64,
) -> (Vec<crate::coordinator::WorkloadStats>, usize) {
    let mut sim = ClusterSim::new(kind, plan, specs, policy, ArrivalKind::Constant, seed, &[]);
    sim.set_horizon(horizon_ms, 1_000.0);
    let stats = sim.run();
    let violations = stats
        .iter()
        .filter(|s| s.violation || s.throughput_violation)
        .count();
    (stats, violations)
}

fn plan_summary(sys: &ProfiledSystem, specs: &[WorkloadSpec], plan: &Plan) -> String {
    let mut parts = Vec::new();
    for (g, allocs) in plan.gpus.iter().enumerate() {
        let inner: Vec<String> = allocs
            .iter()
            .map(|a| {
                format!(
                    "{}({:.1}%,{})",
                    specs[a.workload].model.short(),
                    a.resources * 100.0,
                    a.batch
                )
            })
            .collect();
        parts.push(format!("GPU{}: {}", g + 1, inner.join(" ")));
    }
    let _ = sys;
    parts.join(" | ")
}

/// Table 1: the illustrative A/R/V example under GSLICE+, gpu-lets+ and
/// iGniter — plans and serving-measured violations.
pub fn table1(kind: GpuKind) -> Result<()> {
    let sys = profiled_system(kind, SEED);
    let specs = table1_workloads();
    let mut t = Table::new(
        "Table 1 — provisioning plans + SLO violations for A(15ms,500r/s) \
         R(40ms,400r/s) V(60ms,200r/s) (paper: GSLICE 2 viol., gpu-lets 2 viol. \
         on 2 GPUs, iGniter 0 on 1 GPU)",
        &["strategy", "gpus", "plan", "violations"],
    );
    for (plan, policy) in [
        (gslice::provision_gslice(&sys, &specs), Policy::Static),
        (gpulets::provision_gpulets(&sys, &specs), Policy::Static),
        (igniter::provision(&sys, &specs), Policy::IgniterShadow),
    ] {
        let (stats, violations) = serve_and_count(kind, &plan, &specs, policy, 15_000.0, SEED);
        let viol_names: Vec<&str> = stats
            .iter()
            .filter(|s| s.violation || s.throughput_violation)
            .map(|s| s.name.as_str())
            .collect();
        t.row(&[
            plan.strategy.clone(),
            plan.num_gpus().to_string(),
            plan_summary(&sys, &specs, &plan),
            if violations == 0 {
                "none".to_string()
            } else {
                format!("{} ({})", violations, viol_names.join(","))
            },
        ]);
    }
    emit(&t, "table1");
    Ok(())
}

/// Fig. 14: plans, costs and serving violations for the 12 workloads under
/// all four strategies.
pub fn fig14(kind: GpuKind) -> Result<()> {
    let sys = profiled_system(kind, SEED);
    let specs = app_workloads();
    let mut t = Table::new(
        "Fig. 14 — 12-workload provisioning: GPUs, hourly cost, SLO violations \
         (paper: iGniter 6/$18.36/0, gpu-lets+ 8/$24.48/3, FFD+ 5/$15.30/10, \
         GSLICE+ 6/$18.36/3)",
        &["strategy", "gpus", "cost_per_h", "violations", "violating"],
    );
    let mut details = Table::new(
        "Fig. 14 (detail) — per-workload P99 vs. SLO under each strategy",
        &["strategy", "workload", "P99_ms", "SLO_ms", "rps", "target_rps", "ok"],
    );
    for (plan, policy) in [
        (igniter::provision(&sys, &specs), Policy::IgniterShadow),
        (gpulets::provision_gpulets(&sys, &specs), Policy::Static),
        (ffd::provision_ffd(&sys, &specs), Policy::Static),
        (
            gslice::provision_gslice(&sys, &specs),
            Policy::GsliceTuner { period_ms: 10_000.0 },
        ),
    ] {
        let (stats, violations) =
            serve_and_count(kind, &plan, &specs, policy, 30_000.0, SEED);
        let viol_names: Vec<&str> = stats
            .iter()
            .filter(|s| s.violation || s.throughput_violation)
            .map(|s| s.name.as_str())
            .collect();
        t.row(&[
            plan.strategy.clone(),
            plan.num_gpus().to_string(),
            format!("${:.2}", plan.cost_per_hour()),
            violations.to_string(),
            viol_names.join(","),
        ]);
        for s in &stats {
            details.row(&[
                plan.strategy.clone(),
                s.name.clone(),
                f(s.p99_ms, 2),
                f(s.slo_ms, 0),
                f(s.achieved_rps, 0),
                f(s.rate_rps, 0),
                (!(s.violation || s.throughput_violation)).to_string(),
            ]);
        }
    }
    emit(&t, "fig14");
    emit(&details, "fig14_detail");
    Ok(())
}

/// Figs. 15-16: W10 (SSD App3) latency/throughput and allocation over time
/// under GSLICE+ vs. iGniter.
pub fn fig15_16(kind: GpuKind) -> Result<()> {
    let sys = profiled_system(kind, SEED);
    let specs = app_workloads();
    let mut t15 = Table::new(
        "Fig. 15 — W10 mean latency (ms) & throughput (r/s) over time \
         (paper: GSLICE+ oscillates around the 12.5 ms half-SLO and breaks \
         the 150 r/s target; iGniter stays put)",
        &["t_s", "gslice_lat", "gslice_rps", "igniter_lat", "igniter_rps"],
    );
    let mut t16 = Table::new(
        "Fig. 16 — W10 allocated resources / batch over time",
        &["t_s", "gslice_r", "gslice_b", "igniter_r", "igniter_b"],
    );

    let run = |plan: &Plan, policy: Policy| {
        let mut sim = ClusterSim::new(kind, plan, &specs, policy, ArrivalKind::Constant, SEED, &[]);
        sim.set_horizon(70_000.0, 1_000.0);
        sim.run()
    };
    let gs = run(
        &gslice::provision_gslice(&sys, &specs),
        Policy::GsliceTuner { period_ms: 12_500.0 },
    );
    let ig = run(&igniter::provision(&sys, &specs), Policy::IgniterShadow);
    let w10 = 9usize; // W10 = index 9
    let gt = &gs[w10].timeline;
    let it = &ig[w10].timeline;
    for (a, b) in gt.iter().zip(it.iter()) {
        if (a.t_ms / 1000.0).fract() < 1e-9 && a.t_ms % 5000.0 < 1.0 {
            t15.row(&[
                f(a.t_ms / 1000.0, 0),
                f(a.mean_ms, 2),
                f(a.rps, 0),
                f(b.mean_ms, 2),
                f(b.rps, 0),
            ]);
            t16.row(&[
                f(a.t_ms / 1000.0, 0),
                pct(a.resources),
                a.batch.to_string(),
                pct(b.resources),
                b.batch.to_string(),
            ]);
        }
    }
    emit(&t15, "fig15");
    emit(&t16, "fig16");
    println!(
        "W10 end-to-end: GSLICE+ P99 {:.2} ms ({} r/s), iGniter P99 {:.2} ms ({} r/s), SLO {} ms / {} r/s",
        gs[w10].p99_ms,
        gs[w10].achieved_rps as u64,
        ig[w10].p99_ms,
        ig[w10].achieved_rps as u64,
        specs[w10].slo_ms,
        specs[w10].rate_rps as u64,
    );
    Ok(())
}

/// Fig. 17: shadow-process handling of an injected prediction error on W1.
pub fn fig17(kind: GpuKind) -> Result<()> {
    let sys = profiled_system(kind, SEED);
    let specs = app_workloads();
    let plan = igniter::provision(&sys, &specs);
    let mut sim = ClusterSim::new(
        kind,
        &plan,
        &specs,
        Policy::IgniterShadow,
        ArrivalKind::Constant,
        SEED,
        &[(0, 0.075)], // shave 7.5% off W1 = injected prediction error
    );
    sim.set_horizon(10_000.0, 0.0);
    let stats = sim.run();
    let mut t = Table::new(
        "Fig. 17 — W1 P99 (ms) over time with an injected under-provisioning \
         (paper: violation at 1 s, shadow switch at ~1.5 s, then under SLO)",
        &["t_s", "p99_ms", "resources", "slo_ms"],
    );
    for p in &stats[0].timeline {
        t.row(&[
            f(p.t_ms / 1000.0, 1),
            f(p.p99_ms, 2),
            pct(p.resources),
            f(specs[0].slo_ms, 0),
        ]);
    }
    emit(&t, "fig17");
    println!(
        "shadow switches for W1: {} (paper: mechanism triggered 2 times total)",
        stats[0].shadow_switches
    );
    Ok(())
}

/// Fig. 18: per-workload allocated resources under the four strategies.
pub fn fig18(kind: GpuKind) -> Result<()> {
    let sys = profiled_system(kind, SEED);
    let specs = app_workloads();
    let plans = [
        igniter::provision(&sys, &specs),
        gpulets::provision_gpulets(&sys, &specs),
        ffd::provision_ffd(&sys, &specs),
        gslice::provision_gslice(&sys, &specs),
    ];
    let mut t = Table::new(
        "Fig. 18 — allocated GPU resources per workload \
         (paper: gpu-lets+ >= iGniter everywhere; FFD+ <= iGniter)",
        &["workload", "iGniter", "gpu-lets+", "FFD+", "GSLICE+"],
    );
    for w in 0..specs.len() {
        let mut row = vec![specs[w].name.clone()];
        for p in &plans {
            row.push(pct(p.find(w).unwrap().1.resources));
        }
        t.row(&row);
    }
    emit(&t, "fig18");
    Ok(())
}

/// Fig. 19: where each strategy places W2 (App2 of AlexNet) and with how
/// much — the placement-quality microscope.
pub fn fig19(kind: GpuKind) -> Result<()> {
    let sys = profiled_system(kind, SEED);
    let specs = app_workloads();
    let w2 = 4usize; // W5 in our indexing is App2 AlexNet? paper W2 = App2 of
                     // AlexNet in their figure; our App2-AlexNet is index 4.
    let mut t = Table::new(
        "Fig. 19 — placement of App2-AlexNet under the four strategies \
         (paper: FFD+ causes violations; iGniter places it with the least \
         extra resources)",
        &["strategy", "gpu", "resources", "batch"],
    );
    for plan in [
        ffd::provision_ffd(&sys, &specs),
        gpulets::provision_gpulets(&sys, &specs),
        ffd::provision_ffd_pp(&sys, &specs),
        igniter::provision(&sys, &specs),
    ] {
        let (g, a) = plan.find(w2).unwrap();
        t.row(&[
            plan.strategy.clone(),
            format!("GPU{}", g + 1),
            pct(a.resources),
            a.batch.to_string(),
        ]);
    }
    emit(&t, "fig19");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_and_fig19_run() {
        table1(GpuKind::V100).unwrap();
        fig19(GpuKind::V100).unwrap();
    }

    #[test]
    fn fig14_shape_matches_paper() {
        // The headline: iGniter 0 violations at cost <= GSLICE+ <= gpu-lets+;
        // FFD+ cheapest with the most violations.
        let kind = GpuKind::V100;
        let sys = profiled_system(kind, SEED);
        let specs = app_workloads();

        let ig = igniter::provision(&sys, &specs);
        let gl = gpulets::provision_gpulets(&sys, &specs);
        let fd = ffd::provision_ffd(&sys, &specs);

        let (_, v_ig) = serve_and_count(kind, &ig, &specs, Policy::IgniterShadow, 15_000.0, SEED);
        let (_, v_gl) = serve_and_count(kind, &gl, &specs, Policy::Static, 15_000.0, SEED);
        let (_, v_fd) = serve_and_count(kind, &fd, &specs, Policy::Static, 15_000.0, SEED);

        assert_eq!(v_ig, 0, "iGniter must have zero violations");
        assert!(v_fd >= 3, "FFD+ should violate many, got {v_fd}");
        assert!(v_fd > v_gl, "FFD+ ({v_fd}) should violate more than gpu-lets+ ({v_gl})");
        assert!(ig.cost_per_hour() < gl.cost_per_hour());
        assert!(fd.cost_per_hour() <= ig.cost_per_hour());
    }
}
