//! Cost-vs-performance tradeoff sweep — the paper's future-work item (3):
//! "negotiating the tradeoff between minimizing the monetary cost and
//! maximizing the performance of DNN inference workloads".
//!
//! The knob is an SLO-scale lambda applied to every workload's latency SLO:
//! lambda < 1 demands stricter tails (more resources, more GPUs), lambda > 1
//! relaxes them.  The sweep exposes the cost curve a deployment can
//! negotiate against, plus the infeasibility cliff where SLOs become
//! unachievable at full device resources.

use super::common::{emit, profiled_system, SEED};
use crate::gpu::GpuKind;
use crate::perfmodel::AnalyticModel;
use crate::provisioner::{self, WorkloadSpec};
use crate::util::table::{f, Table};
use crate::workload::app_workloads;
use crate::util::error::Result;

/// Scale all SLOs by `lambda`.
fn scaled(specs: &[WorkloadSpec], lambda: f64) -> Vec<WorkloadSpec> {
    specs
        .iter()
        .map(|s| {
            let mut c = s.clone();
            c.slo_ms = s.slo_ms * lambda;
            c
        })
        .collect()
}

pub fn pareto(kind: GpuKind) -> Result<()> {
    let sys = profiled_system(kind, SEED);
    let specs = app_workloads();
    let mut t = Table::new(
        "Cost vs. SLO-tightness sweep (future-work 3): hourly cost of the \
         iGniter plan as every latency SLO is scaled by lambda",
        &["lambda", "feasible", "gpus", "cost_per_h", "mean_headroom"],
    );
    for &lambda in &[0.6, 0.7, 0.8, 0.9, 1.0, 1.2, 1.5, 2.0, 3.0] {
        let es = scaled(&specs, lambda);
        let derived = provisioner::derive_all(&sys, &es);
        if derived.iter().any(|d| d.is_none()) {
            t.row(&[
                f(lambda, 2),
                "no".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ]);
            continue;
        }
        let plan =
            provisioner::igniter::provision_with_derived(&AnalyticModel::ALL, &sys, &es, &derived);
        // headroom: how far below the half-SLO the predictions sit
        let preds = provisioner::predict_plan(&sys, &es, &plan);
        let headrooms: Vec<f64> = preds
            .iter()
            .map(|(w, t_inf, _)| 1.0 - t_inf / (es[*w].slo_ms / 2.0))
            .collect();
        t.row(&[
            f(lambda, 2),
            "yes".into(),
            plan.num_gpus().to_string(),
            format!("${:.2}", plan.cost_per_hour()),
            format!("{:.1}%", crate::util::stats::mean(&headrooms) * 100.0),
        ]);
    }
    emit(&t, "pareto");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_is_monotone_decreasing_in_lambda() {
        let sys = profiled_system(GpuKind::V100, SEED);
        let specs = app_workloads();
        let mut last_gpus = usize::MAX;
        for &lambda in &[0.8, 1.0, 1.5, 2.5] {
            let es = scaled(&specs, lambda);
            let derived = provisioner::derive_all(&sys, &es);
            if derived.iter().any(|d| d.is_none()) {
                continue;
            }
            let plan = provisioner::igniter::provision_with_derived(
                &AnalyticModel::ALL,
                &sys,
                &es,
                &derived,
            );
            assert!(
                plan.num_gpus() <= last_gpus,
                "lambda={lambda}: {} > {last_gpus}",
                plan.num_gpus()
            );
            last_gpus = plan.num_gpus();
        }
        assert!(last_gpus < usize::MAX, "no feasible lambda");
    }

    #[test]
    fn tight_slos_eventually_infeasible() {
        let sys = profiled_system(GpuKind::V100, SEED);
        let specs = app_workloads();
        let es = scaled(&specs, 0.05);
        let derived = provisioner::derive_all(&sys, &es);
        assert!(derived.iter().any(|d| d.is_none()), "0.05x SLOs should be infeasible");
    }

    #[test]
    fn pareto_harness_runs() {
        pareto(GpuKind::V100).unwrap();
    }
}
