//! Dynamic re-provisioning under time-varying arrival rates — the paper's
//! future-work item (4), built on `workload::trace` + `provisioner::online`.
//!
//! Each epoch the arrival rates change per a diurnal trace; three policies
//! are compared:
//!   * `static-peak`   — provision once for nominal (peak) rates;
//!   * `reprovision`   — run Alg. 1 from scratch every epoch;
//!   * `online`        — incremental: eagerly re-place workloads whose
//!                       rate grew, lazily (20 % hysteresis) those that
//!                       shrank; rebalance when it saves GPUs.
//!
//! Metric: GPU-hours (cost) summed across epochs, with zero predicted SLO
//! violations required everywhere.

use super::common::{emit, profiled_system, SEED};
use crate::gpu::GpuKind;
use crate::provisioner::{self, online::OnlinePlanner, ProfiledSystem, WorkloadSpec};
use crate::util::table::{f, Table};
use crate::workload::trace::{RateTrace, TraceKind};
use crate::workload::app_workloads;
use crate::util::error::Result;

fn scaled(specs: &[WorkloadSpec], trace: &RateTrace, epoch: usize) -> Vec<WorkloadSpec> {
    specs
        .iter()
        .enumerate()
        .map(|(w, s)| {
            let mut c = s.clone();
            c.rate_rps = (s.rate_rps * trace.at(epoch, w)).max(1.0);
            c
        })
        .collect()
}

/// Count predicted violations of a plan against a spec set.  Each
/// allocation is held to its *replica share* of the workload's rate, so
/// plans that split an over-capacity workload across gpulets are judged
/// per replica (predict_plan emits one entry per allocation).
fn violations(sys: &ProfiledSystem, specs: &[WorkloadSpec], plan: &provisioner::Plan) -> usize {
    provisioner::predict_plan(sys, specs, plan)
        .iter()
        .filter(|(w, t, h)| {
            let share = specs[*w].rate_rps / plan.replica_count(*w).max(1) as f64;
            *t > specs[*w].slo_ms / 2.0 + 1e-6 || *h < share * 0.999
        })
        .count()
}

/// Summary of the epoch-replay comparison — structured so the golden
/// regression test can pin the whole output while the live closed-loop
/// path (`experiments::autoscale`) evolves next to it.
#[derive(Debug, Clone, PartialEq)]
pub struct DynamicSummary {
    pub epochs: usize,
    pub static_cost: f64,
    pub re_cost: f64,
    pub re_viol: usize,
    pub online_cost: f64,
    pub online_viol: usize,
}

impl DynamicSummary {
    /// Stable text form for the checked-in golden (6 decimals: immune to
    /// last-bit float noise, sensitive to any real behavioral drift).
    pub fn golden_lines(&self) -> String {
        format!(
            "epochs {}\nstatic_cost {:.6}\nre_cost {:.6}\nre_viol {}\nonline_cost {:.6}\nonline_viol {}\n",
            self.epochs, self.static_cost, self.re_cost, self.re_viol,
            self.online_cost, self.online_viol
        )
    }
}

pub fn dynamic_summary(kind: GpuKind) -> Result<DynamicSummary> {
    let sys = profiled_system(kind, SEED);
    let specs = app_workloads();
    let epochs = 24; // one simulated day, hourly re-provisioning
    let trace = RateTrace::generate(
        TraceKind::Diurnal {
            period_epochs: 24,
            floor: 0.25,
        },
        epochs,
        specs.len(),
        SEED,
    );

    // static-peak: one plan for nominal rates, held all day.
    let peak_plan = provisioner::provision(&sys, &specs);
    let static_cost = peak_plan.cost_per_hour() * epochs as f64;

    // reprovision: full Alg. 1 per epoch.
    let mut re_cost = 0.0;
    let mut re_viol = 0;
    for e in 0..epochs {
        let es = scaled(&specs, &trace, e);
        let plan = provisioner::provision(&sys, &es);
        re_cost += plan.cost_per_hour();
        re_viol += violations(&sys, &es, &plan);
    }

    // online: incremental planner, re-adding workloads whose rate moved
    // >20 % since their last placement; rebalance each epoch.
    let mut online_cost = 0.0;
    let mut online_viol = 0;
    let mut op = OnlinePlanner::new(sys.clone());
    let mut live_ids: Vec<usize> = Vec::new();
    let mut last_rate: Vec<f64> = Vec::new();
    {
        let e0 = scaled(&specs, &trace, 0);
        for s in &e0 {
            let (id, _) = op.add(WorkloadSpec::new(0, s.model, s.slo_ms, s.rate_rps))?;
            live_ids.push(id);
            last_rate.push(s.rate_rps);
        }
    }
    for e in 0..epochs {
        let es = scaled(&specs, &trace, e);
        if e > 0 {
            for (w, s) in es.iter().enumerate() {
                // eager on growth (any rate above the placed one risks an
                // SLO violation), lazy on shrink (20 % hysteresis).
                let grew = s.rate_rps > last_rate[w] * 1.001;
                let shrank_enough = s.rate_rps < last_rate[w] * 0.80;
                if grew || shrank_enough {
                    op.remove(live_ids[w])?;
                    let (id, _) = op.add(WorkloadSpec::new(0, s.model, s.slo_ms, s.rate_rps))?;
                    live_ids[w] = id;
                    last_rate[w] = s.rate_rps;
                }
            }
            op.rebalance();
        }
        online_cost += op.cost_per_hour();
        // violation check through the online planner's own predictions
        for (w, s) in es.iter().enumerate() {
            if let Some((t_inf, thpt)) = op.predict(live_ids[w]) {
                // placed for last_rate[w] >= current? violation only if the
                // *current* rate exceeds predicted capacity or latency SLO
                if t_inf > s.slo_ms / 2.0 + 1e-6 || thpt < s.rate_rps * 0.999 {
                    online_viol += 1;
                }
            } else {
                online_viol += 1;
            }
        }
    }

    Ok(DynamicSummary {
        epochs,
        static_cost,
        re_cost,
        re_viol,
        online_cost,
        online_viol,
    })
}

pub fn dynamic(kind: GpuKind) -> Result<()> {
    let DynamicSummary {
        static_cost,
        re_cost,
        re_viol,
        online_cost,
        online_viol,
        ..
    } = dynamic_summary(kind)?;

    let mut t = Table::new(
        "Dynamic provisioning over a 24-epoch diurnal trace (future-work 4): \
         GPU-hours and predicted violations per policy",
        &["policy", "gpu_hours_cost", "savings_vs_static", "violations"],
    );
    t.row(&[
        "static-peak".into(),
        f(static_cost, 2),
        "0.0%".into(),
        "0".into(),
    ]);
    t.row(&[
        "reprovision/epoch".into(),
        f(re_cost, 2),
        format!("{:.1}%", (1.0 - re_cost / static_cost) * 100.0),
        re_viol.to_string(),
    ]);
    t.row(&[
        "online (eager-grow)".into(),
        f(online_cost, 2),
        format!("{:.1}%", (1.0 - online_cost / static_cost) * 100.0),
        online_viol.to_string(),
    ]);
    emit(&t, "dynamic");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dynamic_saves_cost_without_violations() {
        let kind = GpuKind::V100;
        let sys = profiled_system(kind, SEED);
        let specs = app_workloads();
        let trace = RateTrace::generate(
            TraceKind::Diurnal {
                period_epochs: 8,
                floor: 0.25,
            },
            8,
            specs.len(),
            SEED,
        );
        let peak = provisioner::provision(&sys, &specs);
        let mut re_cost = 0.0;
        for e in 0..8 {
            let es = scaled(&specs, &trace, e);
            let plan = provisioner::provision(&sys, &es);
            assert_eq!(violations(&sys, &es, &plan), 0, "epoch {e}");
            re_cost += plan.cost_per_hour();
        }
        let static_cost = peak.cost_per_hour() * 8.0;
        assert!(
            re_cost < static_cost * 0.95,
            "re-provisioning should save >5%: {re_cost} vs {static_cost}"
        );
    }

    #[test]
    fn dynamic_harness_runs() {
        dynamic(GpuKind::V100).unwrap();
    }

    #[test]
    fn golden_summary_regression() {
        // Pin the full epoch-replay output so it cannot silently drift
        // while the live autoscale path is grown beside it.  Blessing is
        // gated: `IGNITER_BLESS=1` writes the golden explicitly; a plain
        // local run with no golden still blesses (with a loud warning)
        // so a fresh checkout isn't broken, but in CI (`CI` set) a
        // missing golden FAILS — a fresh CI checkout must compare
        // against the committed file, never against itself.
        let a = dynamic_summary(GpuKind::V100).unwrap();
        let b = dynamic_summary(GpuKind::V100).unwrap();
        assert_eq!(a, b, "epoch replay is not deterministic");
        // structural floor, golden or not: re-provisioning must save cost
        // with zero predicted violations in every policy
        assert!(a.static_cost > 0.0);
        assert!(a.re_cost < a.static_cost);
        assert!(a.online_cost < a.static_cost);
        assert_eq!(a.re_viol, 0, "epoch re-provisioning violated SLOs");
        assert_eq!(a.online_viol, 0, "online planner violated SLOs");

        let text = a.golden_lines();
        let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("rust/tests/golden/dynamic_summary.txt");
        let blessing = std::env::var("IGNITER_BLESS").as_deref() == Ok("1");
        match std::fs::read_to_string(&path) {
            Ok(want) if blessing => {
                if text != want {
                    std::fs::write(&path, &text).unwrap();
                    eprintln!("re-blessed {path:?} (IGNITER_BLESS=1); commit it");
                }
            }
            Ok(want) => assert_eq!(
                text, want,
                "dynamic summary drifted from the golden; if the change is \
                 intentional, re-run with IGNITER_BLESS=1 and commit {path:?}"
            ),
            Err(_) if blessing || std::env::var("CI").is_err() => {
                std::fs::create_dir_all(path.parent().unwrap()).unwrap();
                std::fs::write(&path, &text).unwrap();
                if !blessing {
                    eprintln!(
                        "WARNING: golden {path:?} was absent and has been \
                         blessed from this run — this compares the code \
                         against itself.  Commit the file (see \
                         rust/tests/golden/README.md) so later runs and CI \
                         regress against a pinned baseline."
                    );
                }
            }
            Err(_) => panic!(
                "golden {path:?} is missing in CI: a fresh checkout would \
                 bless itself and the regression test would pass vacuously. \
                 Run `make bless-golden` locally and commit the file."
            ),
        }
    }
}
