//! Motivation experiments (Sec. 2.2): Figs. 3-9 — the interference
//! phenomenology of the simulated testbed, regenerated in the paper's own
//! sweep parameters.

use super::common::{emit, measure, profiled_system, MOTIVATION_MODELS, SEED};
use crate::gpu::{GpuDevice, GpuKind, Model};
use crate::util::table::{f, Table};
use crate::util::error::Result;

/// Fig. 3: normalized latency of A/R/V vs. 1-5 identical co-located
/// workloads, each at 20 % of the GPU (batch 4, 3 repetitions).
pub fn fig3(kind: GpuKind) -> Result<()> {
    let mut t = Table::new(
        "Fig. 3 — normalized inference latency vs. co-located identical workloads \
         (20% GPU each, batch 4; paper: +0.83%..+34.98% from 2 to 5)",
        &["model", "n=1", "n=2", "n=3", "n=4", "n=5"],
    );
    for model in MOTIVATION_MODELS {
        let mut row = vec![model.name().to_string()];
        let mut solo = 0.0;
        for n in 1..=5u64 {
            let (mean, _) = measure(3, || {
                let mut d = GpuDevice::new(kind, SEED ^ n);
                for i in 0..n {
                    assert!(d.launch(i, model, 0.2, 4));
                }
                d.query_latency(0, 4).unwrap().t_inf
            });
            if n == 1 {
                solo = mean;
            }
            row.push(format!("{:.3}", mean / solo));
        }
        t.row(&row);
    }
    emit(&t, "fig3");
    Ok(())
}

/// Fig. 4: normalized latency of ResNet-50 (50 %, b=16) co-located with
/// AlexNet or VGG-19 (50 %) whose batch varies 1..32.
pub fn fig4(kind: GpuKind) -> Result<()> {
    let batches = [1u32, 2, 4, 8, 16, 32];
    let mut header = vec!["co-runner".to_string()];
    header.extend(batches.iter().map(|b| format!("b={b}")));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "Fig. 4 — normalized ResNet-50 latency (50%, b=16) vs. co-runner batch \
         (paper: +6.36%..+13.93%)",
        &hdr,
    );
    let solo = {
        let mut d = GpuDevice::noiseless(kind);
        d.launch(0, Model::ResNet50, 0.5, 16);
        d.query_latency(0, 16).unwrap().t_inf
    };
    for co in [Model::AlexNet, Model::Vgg19] {
        let mut row = vec![co.name().to_string()];
        for &b in &batches {
            let (mean, _) = measure(3, || {
                let mut d = GpuDevice::new(kind, SEED ^ b as u64);
                d.launch(0, Model::ResNet50, 0.5, 16);
                d.launch(1, co, 0.5, b);
                d.query_latency(0, 16).unwrap().t_inf
            });
            row.push(format!("{:.3}", mean / solo));
        }
        t.row(&row);
    }
    emit(&t, "fig4");
    Ok(())
}

/// Fig. 5: total kernel scheduling delay (ms) vs. #workloads.
pub fn fig5(kind: GpuKind) -> Result<()> {
    let mut t = Table::new(
        "Fig. 5 — scheduling delay (ms) vs. co-located workloads \
         (paper: linear growth; ResNet-50 steeper than AlexNet)",
        &["model", "n=1", "n=2", "n=3", "n=4", "n=5"],
    );
    for model in MOTIVATION_MODELS {
        let mut row = vec![model.name().to_string()];
        for n in 1..=5u64 {
            let mut d = GpuDevice::new(kind, SEED ^ n);
            for i in 0..n {
                assert!(d.launch(i, model, 0.2, 4));
            }
            row.push(f(d.query_latency(0, 4).unwrap().t_sched, 4));
        }
        t.row(&row);
    }
    emit(&t, "fig5");
    Ok(())
}

/// Fig. 6: ResNet-50 GPU active time + L2 hit ratio vs. #workloads.
pub fn fig6(kind: GpuKind) -> Result<()> {
    let mut t = Table::new(
        "Fig. 6 — ResNet-50 active time vs. L2 hit ratio \
         (paper: inversely related)",
        &["n", "active_ms", "l2_hit_ratio"],
    );
    for n in 1..=5u64 {
        let mut d = GpuDevice::new(kind, SEED ^ n);
        for i in 0..n {
            assert!(d.launch(i, Model::ResNet50, 0.2, 4));
        }
        let q = d.query_latency(0, 4).unwrap();
        t.row(&[n.to_string(), f(q.t_act, 3), f(d.l2_hit_ratio(), 3)]);
    }
    emit(&t, "fig6");
    Ok(())
}

/// Fig. 7: GPU power + frequency for VGG-19 / ResNet-50 vs. #workloads.
pub fn fig7(kind: GpuKind) -> Result<()> {
    let mut t = Table::new(
        "Fig. 7 — GPU power (W) and frequency (MHz) vs. co-located workloads \
         (paper: frequency drops once power hits the 300 W cap)",
        &["model", "n", "power_w", "freq_mhz"],
    );
    for model in [Model::Vgg19, Model::ResNet50] {
        for n in 1..=5u64 {
            let mut d = GpuDevice::new(kind, SEED ^ n);
            for i in 0..n {
                assert!(d.launch(i, model, 0.2, 16));
            }
            t.row(&[
                model.name().to_string(),
                n.to_string(),
                f(d.power_demand_w(), 1),
                f(d.frequency_mhz(), 0),
            ]);
        }
    }
    emit(&t, "fig7");
    Ok(())
}

/// Fig. 8: ResNet-50 GPU active time vs. batch x resources (the Eq.-11
/// surface the profiler fits).
pub fn fig8(kind: GpuKind) -> Result<()> {
    let rs = [0.2, 0.4, 0.6, 0.8, 1.0];
    let mut header = vec!["batch".to_string()];
    header.extend(rs.iter().map(|r| format!("r={:.0}%", r * 100.0)));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "Fig. 8 — ResNet-50 GPU active time (ms): ~1/r in resources, \
         quadratic-ish in batch",
        &hdr,
    );
    for b in [1u32, 2, 4, 8, 16, 32] {
        let mut row = vec![b.to_string()];
        for &r in &rs {
            let mut d = GpuDevice::noiseless(kind);
            d.launch(0, Model::ResNet50, r, b);
            row.push(f(d.query_latency(0, b).unwrap().t_act, 3));
        }
        t.row(&row);
    }
    emit(&t, "fig8");
    Ok(())
}

/// Fig. 9: power and L2 cache utilization vs. GPU processing ability
/// (linear laws the profiler fits).
pub fn fig9(kind: GpuKind) -> Result<()> {
    let mut t = Table::new(
        "Fig. 9 — ResNet-50 power (W) and L2 utilization vs. processing \
         ability b/k_act (paper: both linear)",
        &["batch", "ability_q_per_ms", "power_w", "l2_util"],
    );
    let prof = crate::gpu::profile(Model::ResNet50, kind);
    let idle = GpuDevice::noiseless(kind).spec.idle_power_w;
    for b in [1u32, 2, 4, 8, 16, 24, 32] {
        let mut d = GpuDevice::noiseless(kind);
        d.launch(0, Model::ResNet50, 1.0, b);
        let q = d.query_latency(0, b).unwrap();
        let ability = b as f64 / q.t_act;
        t.row(&[
            b.to_string(),
            f(ability, 3),
            f(d.power_demand_w() - idle, 1),
            f(prof.cache_util(b as f64, 1.0), 4),
        ]);
    }
    emit(&t, "fig9");

    // verification: the fitted profiler lines should match these samples
    let sys = profiled_system(kind, SEED);
    let wc = sys.coeffs_for(Model::ResNet50);
    println!(
        "fitted power line: {:.2} * ability + {:.2} (W above idle)\n\
         fitted cache line: {:.4} * ability + {:.4}",
        wc.alpha_power, wc.beta_power, wc.alpha_cacheutil, wc.beta_cacheutil
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_motivation_figures_run() {
        for fun in [fig3, fig4, fig5, fig6, fig7, fig8, fig9] {
            fun(GpuKind::V100).unwrap();
        }
        // artifacts written
        for stem in ["fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9"] {
            assert!(super::super::common::results_dir()
                .join(format!("{stem}.csv"))
                .exists());
        }
    }
}
