//! Overhead + heterogeneous experiments: Fig. 20 (T4 cluster) and Fig. 21
//! (algorithm computation/memory scalability), plus the Sec.-5.4 profiling
//! overhead accounting.

use super::common::{emit, profiled_system, SEED};
use crate::gpu::GpuKind;
use crate::provisioner::{heterogeneous, igniter};
use crate::util::table::{f, Table};
use crate::workload::{app_workloads, synthetic_workloads};
use crate::util::error::Result;
use std::time::Instant;

/// Fig. 20: heterogeneous cluster — provision the 12 workloads on T4s and
/// V100s, pick the cheapest.
pub fn fig20() -> Result<()> {
    let specs = app_workloads();
    let systems = [
        profiled_system(GpuKind::V100, SEED),
        profiled_system(GpuKind::T4, SEED),
    ];
    let plans = heterogeneous::select_cheapest(&systems, &specs);
    let mut t = Table::new(
        "Fig. 20 — heterogeneous provisioning (paper: 15x g4dn.xlarge $7.89/h \
         beats 6x p3.2xlarge $18.36/h; W7/W8/W10/W12 need multiple T4s)",
        &["gpu", "instances", "cost_per_h", "replicated_workloads"],
    );
    for tp in &plans {
        let mut replicated: Vec<String> = Vec::new();
        for w in 0..specs.len() {
            let n = tp.replicated.origin.iter().filter(|&&o| o == w).count();
            if n > 1 {
                replicated.push(format!("{}x{}", specs[w].name, n));
            }
        }
        t.row(&[
            tp.plan.gpu.clone(),
            tp.plan.num_gpus().to_string(),
            format!("${:.2}", tp.plan.cost_per_hour()),
            replicated.join(" "),
        ]);
    }
    emit(&t, "fig20");
    println!(
        "selected: {} ({} instances, ${:.2}/h)",
        plans[0].plan.gpu,
        plans[0].plan.num_gpus(),
        plans[0].plan.cost_per_hour()
    );
    Ok(())
}

fn rss_mb() -> f64 {
    // VmRSS from /proc/self/statm (pages) — Linux only, best effort.
    std::fs::read_to_string("/proc/self/statm")
        .ok()
        .and_then(|s| {
            s.split_whitespace()
                .nth(1)
                .and_then(|p| p.parse::<f64>().ok())
        })
        .map(|pages| pages * 4096.0 / 1e6)
        .unwrap_or(f64::NAN)
}

/// Fig. 21: Alg.-1 computation time and memory vs. 10..1000 workloads.
pub fn fig21(kind: GpuKind) -> Result<()> {
    let sys = profiled_system(kind, SEED);
    let mut t = Table::new(
        "Fig. 21 — iGniter strategy overhead vs. #workloads \
         (paper: 3.64 ms @ 12, <= 4.61 s and <= 55 MB @ 1000; O(m^2) time, O(m) mem)",
        &["workloads", "time_ms", "rss_delta_mb", "gpus", "replica_allocs"],
    );
    for &n in &[10usize, 50, 100, 200, 500, 1000] {
        let specs = synthetic_workloads(n, SEED);
        let rss0 = rss_mb();
        let t0 = Instant::now();
        let plan = igniter::provision(&sys, &specs);
        let dt = t0.elapsed().as_secs_f64() * 1e3;
        let drss = (rss_mb() - rss0).max(0.0);
        // allocations beyond one per workload: over-capacity splits
        let extra: usize = (0..n)
            .map(|w| plan.replica_count(w).saturating_sub(1))
            .sum();
        t.row(&[
            n.to_string(),
            f(dt, 2),
            f(drss, 2),
            plan.num_gpus().to_string(),
            extra.to_string(),
        ]);
    }
    emit(&t, "fig21");
    Ok(())
}

/// Sec. 5.4: profiling overhead — how many simulated-testbed measurements
/// the lightweight profiler needs (the paper's wall-clock ~4 min per model
/// corresponds to 11 configs x a few seconds of queries; here we report
/// the measurement counts and the wall cost of the whole fitting pipeline).
pub fn overhead() -> Result<()> {
    let mut t = Table::new(
        "Sec. 5.4 — profiling overhead (paper: 231-247 s per workload on the \
         real testbed; 11 configs only vs. 1,280 for exhaustive)",
        &["item", "value"],
    );
    let t0 = Instant::now();
    let _hw = crate::profiler::profile_hardware(GpuKind::V100, SEED);
    let hw_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t1 = Instant::now();
    for &m in &crate::gpu::ALL_MODELS {
        let _ = crate::profiler::profile_workload(m, GpuKind::V100, SEED);
    }
    let wl_ms = t1.elapsed().as_secs_f64() * 1e3;
    t.row(&["configs per workload".into(), "11".into()]);
    t.row(&[
        "queries per config".into(),
        crate::profiler::QUERIES_PER_CONFIG.to_string(),
    ]);
    t.row(&["exhaustive grid (paper)".into(), "1280".into()]);
    t.row(&["hardware profiling wall (ms)".into(), f(hw_ms, 2)]);
    t.row(&["4-workload profiling wall (ms)".into(), f(wl_ms, 2)]);
    emit(&t, "overhead");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig20_runs_and_t4_wins() {
        fig20().unwrap();
        let out = std::fs::read_to_string(
            super::super::common::results_dir().join("fig20.csv"),
        )
        .unwrap();
        let first_data_line = out.lines().nth(1).unwrap();
        assert!(first_data_line.starts_with("T4"), "{first_data_line}");
    }

    #[test]
    fn fig21_scales() {
        // smoke-run a reduced version inline (full fig21 runs in the CLI)
        let sys = profiled_system(GpuKind::V100, SEED);
        let specs = synthetic_workloads(100, SEED);
        let t0 = Instant::now();
        let plan = igniter::provision(&sys, &specs);
        let dt = t0.elapsed().as_secs_f64();
        plan.validate(specs.len(), 1.0).unwrap();
        assert!(dt < 5.0, "100 workloads took {dt:.1}s");
    }
}
