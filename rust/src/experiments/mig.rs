//! MIG experiment harness: a small CI-quick sweep over the discrete-slice
//! A100/H100 fleets, summarizing fragmentation (stranded GPCs) and the
//! packer-vs-FFD/iGniter head-to-head per fleet.  The heavyweight entry
//! point is `igniter sweep --fleet mig ...` (see `main.rs`), which also
//! writes the machine-readable `BENCH_mig.json` the CI bench gate
//! compares against `BENCH_baseline_mig.json`.

use super::common::{emit, SEED};
use crate::gpu::GpuKind;
use crate::sweep::{run_sweep, ScenarioSpace, SweepConfig};
use crate::util::error::{bail, Result};
use crate::util::table::{f, Table};

/// Run a reduced MIG sweep and summarize per MIG fleet.
pub fn mig(_kind: GpuKind) -> Result<()> {
    let cfg = SweepConfig {
        scenarios: 12,
        seeds: 2,
        parallel: 4,
        master_seed: SEED,
        space: ScenarioSpace::mig(),
        calibrate: false,
    };
    let report = run_sweep(&cfg);
    let agg = report.aggregate();

    let mut t = Table::new(
        "MIG fleets (discrete 1g/2g/3g/4g/7g slices, zero cross-slice \
         interference): fragmentation-aware packer vs FFD vs iGniter on \
         identical slice-quantized demands",
        &[
            "fleet",
            "tasks",
            "packed_$per_h",
            "ffd_$per_h",
            "igniter_$per_h",
            "stranded_pct",
            "reconfigs",
            "slo_attain",
        ],
    );
    for fleet in ["mig-a100", "mig-h100"] {
        let rs: Vec<_> = report
            .results
            .iter()
            .filter(|r| r.fleet == fleet && r.feasible)
            .collect();
        if rs.is_empty() {
            continue;
        }
        let n = rs.len() as f64;
        t.row(&[
            fleet.to_string(),
            rs.len().to_string(),
            f(rs.iter().map(|r| r.mig_cost_packed).sum::<f64>() / n, 2),
            f(rs.iter().map(|r| r.mig_cost_ffd).sum::<f64>() / n, 2),
            f(rs.iter().map(|r| r.mig_cost_igniter).sum::<f64>() / n, 2),
            format!(
                "{:.1}%",
                rs.iter().map(|r| r.stranded_capacity_pct).sum::<f64>() / n
            ),
            rs.iter().map(|r| r.reconfigurations).sum::<u64>().to_string(),
            format!(
                "{:.1}%",
                rs.iter().map(|r| r.slo_attainment).sum::<f64>() / n * 100.0
            ),
        ]);
    }
    t.row(&[
        "ALL".to_string(),
        format!("{}/{}", agg.mig_tasks, agg.tasks),
        f(agg.mean_mig_cost_packed, 2),
        f(agg.mean_mig_cost_ffd, 2),
        f(agg.mean_mig_cost_igniter, 2),
        format!("{:.1}%", agg.mean_stranded_pct),
        agg.total_reconfigurations.to_string(),
        format!("{:.1}%", agg.mean_slo_attainment * 100.0),
    ]);
    emit(&t, "mig");
    println!(
        "packer vs FFD cost ratio {:.4}  (wall {:.2}s)",
        agg.packer_vs_ffd_cost_ratio, report.wall_s
    );
    if agg.mig_tasks == 0 {
        bail!("MIG sweep produced no feasible MIG task");
    }
    if agg.packer_vs_ffd_cost_ratio > 1.0 + 1e-9 {
        bail!(
            "packer_vs_ffd_cost_ratio {} > 1 — portfolio fallback broken",
            agg.packer_vs_ffd_cost_ratio
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mig_harness_runs_and_the_packer_never_loses() {
        mig(GpuKind::V100).unwrap();
        let csv =
            std::fs::read_to_string(super::super::common::results_dir().join("mig.csv")).unwrap();
        let all_line = csv.lines().last().unwrap();
        assert!(all_line.starts_with("ALL"), "{all_line}");
    }
}
