//! Closed-loop autoscaling under a live time-varying trace (beyond the
//! paper; iGniter Sec. 5.3 + future-work item 4, made live): the same
//! diurnal day is served twice through the full router/batcher/monitor
//! event loop —
//!
//!   * `static-peak`  — one plan provisioned for the nominal (peak)
//!     rates, held for the whole horizon;
//!   * `closed-loop`  — provisioned for the trace's opening rates, then
//!     estimator -> `Reprovisioner` -> shadow-instance migration adapts
//!     the cluster online as rates drift.
//!
//! Metrics: integrated GPU-seconds (devices whose last process retired
//! are released), lifetime-P99 SLO attainment, executed migrations, and
//! dropped requests (must be zero — migration conserves every request).

use super::common::{emit, profiled_system, SEED};
use crate::coordinator::{dropped_requests, ClusterSim, Policy, Reprovisioner};
use crate::gpu::GpuKind;
use crate::provisioner::{self, WorkloadSpec};
use crate::util::error::Result;
use crate::util::table::{f, Table};
use crate::workload::trace::{RateTrace, TraceKind};
use crate::workload::{app_workloads, ArrivalKind};

/// Outcome of one policy's traced serving run.
#[derive(Debug, Clone, PartialEq)]
pub struct PolicyOutcome {
    pub gpu_seconds: f64,
    /// Fraction of workloads whose lifetime P99 met the SLO.
    pub slo_attainment: f64,
    pub migrations: u32,
    /// `arrivals - served - still_queued`, summed; must be 0.
    pub dropped: i64,
    pub served: u64,
}

/// Side-by-side result of the autoscale comparison.
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscaleSummary {
    pub static_peak: PolicyOutcome,
    pub closed_loop: PolicyOutcome,
}

fn outcome(sim: &ClusterSim, stats: &[crate::coordinator::WorkloadStats]) -> PolicyOutcome {
    let met = stats.iter().filter(|s| !s.violation).count();
    let dropped = dropped_requests(stats);
    PolicyOutcome {
        gpu_seconds: sim.gpu_seconds(),
        slo_attainment: met as f64 / stats.len().max(1) as f64,
        migrations: sim.migrations(),
        dropped,
        served: stats.iter().map(|s| s.served).sum(),
    }
}

/// Run the comparison: `epochs` trace epochs of `epoch_ms` each (the
/// diurnal period spans the whole horizon).  Deterministic per seed.
pub fn autoscale_summary(
    kind: GpuKind,
    specs: &[WorkloadSpec],
    epochs: usize,
    epoch_ms: f64,
    seed: u64,
) -> AutoscaleSummary {
    let sys = profiled_system(kind, SEED);
    let trace = RateTrace::generate(
        TraceKind::Diurnal {
            period_epochs: epochs,
            floor: 0.35,
        },
        epochs,
        specs.len(),
        seed,
    );
    let horizon_ms = epochs as f64 * epoch_ms;

    // -- static peak: provision once for the nominal (= peak) rates ------
    let peak_plan = provisioner::provision(&sys, specs);
    let mut st = ClusterSim::new(
        kind,
        &peak_plan,
        specs,
        Policy::Static,
        ArrivalKind::Constant,
        seed,
        &[],
    );
    st.set_rate_trace(&trace, epoch_ms);
    st.set_horizon(horizon_ms, 1_000.0);
    let st_stats = st.run();
    let static_peak = outcome(&st, &st_stats);

    // -- closed loop: provision for the opening rates (plus the
    //    reprovisioner's safety pad), then adapt online ------------------
    let safety = crate::coordinator::monitor::DEFAULT_SAFETY;
    let opening: Vec<WorkloadSpec> = specs
        .iter()
        .enumerate()
        .map(|(w, s)| {
            let mut c = s.clone();
            c.rate_rps = (s.rate_rps * trace.at(0, w) * safety).max(1.0);
            c
        })
        .collect();
    let open_plan = provisioner::provision(&sys, &opening);
    let mut cl = ClusterSim::new(
        kind,
        &open_plan,
        specs,
        Policy::Static,
        ArrivalKind::Constant,
        seed,
        &[],
    );
    cl.set_serving_policy(Box::new(Reprovisioner::new(
        sys.clone(),
        opening,
        open_plan.clone(),
    )));
    cl.set_rate_trace(&trace, epoch_ms);
    cl.set_horizon(horizon_ms, 1_000.0);
    let cl_stats = cl.run();
    let closed_loop = outcome(&cl, &cl_stats);

    AutoscaleSummary {
        static_peak,
        closed_loop,
    }
}

pub fn autoscale(kind: GpuKind) -> Result<()> {
    let specs = app_workloads();
    let s = autoscale_summary(kind, &specs, 24, 2_500.0, SEED);
    let mut t = Table::new(
        "Closed-loop autoscaling vs static peak over a live 60 s diurnal \
         trace (12 workloads, shadow-instance migration; drops must be 0)",
        &[
            "policy",
            "gpu_seconds",
            "savings",
            "slo_attainment",
            "migrations",
            "dropped",
            "served",
        ],
    );
    let row = |t: &mut Table, name: &str, o: &PolicyOutcome, base: f64| {
        t.row(&[
            name.into(),
            f(o.gpu_seconds, 1),
            format!("{:.1}%", (1.0 - o.gpu_seconds / base) * 100.0),
            format!("{:.1}%", o.slo_attainment * 100.0),
            o.migrations.to_string(),
            o.dropped.to_string(),
            o.served.to_string(),
        ]);
    };
    let base = s.static_peak.gpu_seconds;
    row(&mut t, "static-peak", &s.static_peak, base);
    row(&mut t, "closed-loop", &s.closed_loop, base);
    emit(&t, "autoscale");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::table1_workloads;

    #[test]
    fn closed_loop_matches_slo_attainment_with_fewer_gpu_seconds() {
        // The acceptance bar: on a diurnal day the closed loop must meet
        // at least static-peak's SLO attainment while consuming
        // measurably fewer GPU-seconds, with zero requests dropped
        // across all shadow migrations.
        let specs = app_workloads();
        let s = autoscale_summary(GpuKind::V100, &specs, 16, 2_500.0, SEED);
        assert_eq!(s.static_peak.dropped, 0);
        assert_eq!(s.closed_loop.dropped, 0, "migration dropped requests");
        assert!(
            s.closed_loop.slo_attainment >= s.static_peak.slo_attainment,
            "attainment {:.2} < static {:.2}",
            s.closed_loop.slo_attainment,
            s.static_peak.slo_attainment
        );
        assert!(
            s.closed_loop.gpu_seconds < s.static_peak.gpu_seconds * 0.95,
            "not measurably fewer GPU-seconds: {:.1} vs {:.1}",
            s.closed_loop.gpu_seconds,
            s.static_peak.gpu_seconds
        );
        assert!(
            s.closed_loop.migrations >= 1,
            "the loop never actually closed"
        );
        assert!(s.closed_loop.served > 0 && s.static_peak.served > 0);
    }

    #[test]
    fn autoscale_summary_is_deterministic() {
        let specs = table1_workloads();
        let a = autoscale_summary(GpuKind::V100, &specs, 8, 1_500.0, 7);
        let b = autoscale_summary(GpuKind::V100, &specs, 8, 1_500.0, 7);
        assert_eq!(a, b);
    }
}
