//! Experiment harnesses: one per paper table/figure (see DESIGN.md §5 for
//! the index).  Each prints the paper's rows/series as an ASCII table and
//! writes results/<id>.{txt,csv}.

pub mod ablation;
pub mod autoscale;
pub mod calibration;
pub mod chaos;
pub mod common;
pub mod dynamic;
pub mod mig;
pub mod pareto;
pub mod motivation;
pub mod overhead;
pub mod provisioning;
pub mod sweep;
pub mod validation;

use crate::gpu::GpuKind;
use crate::util::error::{bail, Result};

/// All experiment ids, in paper order.
pub const ALL: [&str; 17] = [
    "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "table1", "fig11",
    "fig12", "fig13", "fig14", "fig15", "fig17", "fig18", "fig19", "fig20",
];

/// Run one experiment by id ("all" runs the full battery + fig21/overhead).
pub fn run(id: &str, kind: GpuKind) -> Result<()> {
    match id {
        "fig3" => motivation::fig3(kind),
        "fig4" => motivation::fig4(kind),
        "fig5" => motivation::fig5(kind),
        "fig6" => motivation::fig6(kind),
        "fig7" => motivation::fig7(kind),
        "fig8" => motivation::fig8(kind),
        "fig9" => motivation::fig9(kind),
        "table1" => provisioning::table1(kind),
        "fig11" => validation::fig11(kind),
        "fig12" => validation::fig12(kind),
        "fig13" => validation::fig13(kind),
        "fig14" => provisioning::fig14(kind),
        "fig15" | "fig16" => provisioning::fig15_16(kind),
        "fig17" => provisioning::fig17(kind),
        "fig18" => provisioning::fig18(kind),
        "fig19" => provisioning::fig19(kind),
        "fig20" => overhead::fig20(),
        "ablation" => ablation::ablation(kind),
        "autoscale" => autoscale::autoscale(kind),
        "calibration" => calibration::calibration(kind),
        "chaos" => chaos::chaos(kind),
        "dynamic" => dynamic::dynamic(kind),
        "mig" => mig::mig(kind),
        "pareto" => pareto::pareto(kind),
        "fig21" => overhead::fig21(kind),
        "overhead" => overhead::overhead(),
        "replicas" => validation::replica_shares(kind),
        "sweep" => sweep::sweep(kind),
        "all" => {
            for id in ALL {
                println!("\n=== {id} ===");
                run(id, kind)?;
            }
            run("fig21", kind)?;
            run("overhead", kind)?;
            run("replicas", kind)?;
            run("ablation", kind)?;
            run("dynamic", kind)?;
            run("autoscale", kind)?;
            run("calibration", kind)?;
            run("chaos", kind)?;
            run("sweep", kind)?;
            run("mig", kind)?;
            run("pareto", kind)
        }
        other => bail!("unknown experiment '{other}'; known: {ALL:?} + fig21, overhead, replicas, ablation, dynamic, autoscale, calibration, chaos, sweep, mig, pareto, all"),
    }
}
