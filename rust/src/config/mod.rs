//! Configuration system: JSON descriptions of workload sets and cluster
//! settings so deployments are driven by files rather than code edits
//! (`igniter provision --config cluster.json`).
//!
//! Schema (all fields except `workloads` optional):
//! ```json
//! {
//!   "gpu": "v100",
//!   "seed": 42,
//!   "strategy": "igniter",
//!   "workloads": [
//!     {"model": "resnet50", "slo_ms": 40, "rate_rps": 400, "name": "search-rank"},
//!     {"model": "ssd", "slo_ms": 55, "rate_rps": 300}
//!   ],
//!   "serving": {"horizon_s": 30, "arrival": "constant", "policy": "shadow"}
//! }
//! ```

use crate::gpu::{GpuKind, Model};
use crate::provisioner::WorkloadSpec;
use crate::util::json::Json;
use crate::util::error::{anyhow, bail, Context, Result};
use std::path::Path;

/// Serving-section options.
#[derive(Debug, Clone, PartialEq)]
pub struct ServingConfig {
    pub horizon_s: f64,
    pub poisson: bool,
    pub policy: String,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            horizon_s: 30.0,
            poisson: false,
            policy: "shadow".to_string(),
        }
    }
}

/// A fully parsed deployment configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct Config {
    pub gpu: GpuKind,
    pub seed: u64,
    pub strategy: String,
    pub workloads: Vec<WorkloadSpec>,
    pub serving: ServingConfig,
}

impl Config {
    pub fn load(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Config> {
        let j = Json::parse(text).context("parsing config JSON")?;

        let gpu_s = j.get("gpu").and_then(|g| g.as_str()).unwrap_or("v100");
        let gpu = GpuKind::parse(gpu_s).ok_or_else(|| anyhow!("unknown gpu '{gpu_s}'"))?;

        let strategy = j
            .get("strategy")
            .and_then(|s| s.as_str())
            .unwrap_or("igniter")
            .to_string();
        if !["igniter", "ffd", "ffd++", "gslice", "gpulets"].contains(&strategy.as_str()) {
            bail!("unknown strategy '{strategy}'");
        }

        let warr = j
            .get("workloads")
            .and_then(|w| w.as_arr())
            .ok_or_else(|| anyhow!("config missing 'workloads' array"))?;
        if warr.is_empty() {
            bail!("config has no workloads");
        }
        let mut workloads = Vec::new();
        for (i, w) in warr.iter().enumerate() {
            let model_s = w
                .get("model")
                .and_then(|m| m.as_str())
                .ok_or_else(|| anyhow!("workload {i}: missing 'model'"))?;
            let model = Model::parse(model_s)
                .ok_or_else(|| anyhow!("workload {i}: unknown model '{model_s}'"))?;
            let slo_ms = w
                .get("slo_ms")
                .and_then(|s| s.as_f64())
                .ok_or_else(|| anyhow!("workload {i}: missing 'slo_ms'"))?;
            let rate_rps = w
                .get("rate_rps")
                .and_then(|r| r.as_f64())
                .ok_or_else(|| anyhow!("workload {i}: missing 'rate_rps'"))?;
            if slo_ms <= 0.0 || rate_rps <= 0.0 {
                bail!("workload {i}: slo_ms and rate_rps must be positive");
            }
            let mut spec = WorkloadSpec::new(i, model, slo_ms, rate_rps);
            if let Some(name) = w.get("name").and_then(|n| n.as_str()) {
                spec.name = format!("{name}({})", model.name());
            }
            workloads.push(spec);
        }

        let serving = match j.get("serving") {
            None => ServingConfig::default(),
            Some(s) => {
                let policy = s
                    .get("policy")
                    .and_then(|p| p.as_str())
                    .unwrap_or("shadow")
                    .to_string();
                if !["shadow", "static", "gslice"].contains(&policy.as_str()) {
                    bail!("unknown serving policy '{policy}'");
                }
                ServingConfig {
                    horizon_s: s.get("horizon_s").and_then(|h| h.as_f64()).unwrap_or(30.0),
                    poisson: s
                        .get("arrival")
                        .and_then(|a| a.as_str())
                        .map(|a| a == "poisson")
                        .unwrap_or(false),
                    policy,
                }
            }
        };

        Ok(Config {
            gpu,
            seed: j.get("seed").and_then(|s| s.as_u64()).unwrap_or(42),
            strategy,
            workloads,
            serving,
        })
    }

    /// Serialize back to JSON (round-trips through `parse`).
    pub fn to_json(&self) -> Json {
        let wl: Vec<Json> = self
            .workloads
            .iter()
            .map(|w| {
                Json::obj()
                    .set("model", w.model.name())
                    .set("slo_ms", w.slo_ms)
                    .set("rate_rps", w.rate_rps)
            })
            .collect();
        Json::obj()
            .set("gpu", self.gpu.name().to_ascii_lowercase())
            .set("seed", self.seed)
            .set("strategy", self.strategy.as_str())
            .set("workloads", Json::Arr(wl))
            .set(
                "serving",
                Json::obj()
                    .set("horizon_s", self.serving.horizon_s)
                    .set(
                        "arrival",
                        if self.serving.poisson { "poisson" } else { "constant" },
                    )
                    .set("policy", self.serving.policy.as_str()),
            )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "gpu": "t4",
      "seed": 7,
      "strategy": "gpulets",
      "workloads": [
        {"model": "resnet50", "slo_ms": 40, "rate_rps": 400, "name": "rank"},
        {"model": "ssd", "slo_ms": 55, "rate_rps": 300}
      ],
      "serving": {"horizon_s": 10, "arrival": "poisson", "policy": "static"}
    }"#;

    #[test]
    fn parse_full() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.gpu, GpuKind::T4);
        assert_eq!(c.seed, 7);
        assert_eq!(c.strategy, "gpulets");
        assert_eq!(c.workloads.len(), 2);
        assert_eq!(c.workloads[0].name, "rank(resnet50)");
        assert_eq!(c.workloads[1].model, Model::Ssd);
        assert!(c.serving.poisson);
        assert_eq!(c.serving.horizon_s, 10.0);
    }

    #[test]
    fn defaults_applied() {
        let c = Config::parse(
            r#"{"workloads": [{"model": "alexnet", "slo_ms": 15, "rate_rps": 100}]}"#,
        )
        .unwrap();
        assert_eq!(c.gpu, GpuKind::V100);
        assert_eq!(c.strategy, "igniter");
        assert_eq!(c.serving, ServingConfig::default());
    }

    #[test]
    fn rejects_bad_input() {
        assert!(Config::parse("{}").is_err()); // no workloads
        assert!(Config::parse(r#"{"workloads": []}"#).is_err());
        assert!(
            Config::parse(r#"{"workloads": [{"model": "bert", "slo_ms": 1, "rate_rps": 1}]}"#)
                .is_err()
        );
        assert!(Config::parse(
            r#"{"workloads": [{"model": "ssd", "slo_ms": -5, "rate_rps": 1}]}"#
        )
        .is_err());
        assert!(Config::parse(
            r#"{"strategy": "magic", "workloads": [{"model": "ssd", "slo_ms": 5, "rate_rps": 1}]}"#
        )
        .is_err());
        assert!(Config::parse("not json").is_err());
    }

    #[test]
    fn roundtrip() {
        let c = Config::parse(SAMPLE).unwrap();
        let c2 = Config::parse(&c.to_json().to_string_pretty()).unwrap();
        assert_eq!(c.gpu, c2.gpu);
        assert_eq!(c.strategy, c2.strategy);
        assert_eq!(c.workloads.len(), c2.workloads.len());
        assert_eq!(c.serving, c2.serving);
    }
}
