//! Cluster layer: EC2 instance catalog and the *GPU device launcher* of
//! Fig. 10 — the component that turns a provisioning `Plan` into concrete
//! deployment actions: instances to launch, MPS partitions to set
//! (`set_active_thread_percentage`), Triton serving processes (plus their
//! pre-launched shadow standbys) to start, and — for the online planner —
//! the minimal rolling-update diff between two consecutive plans.

use crate::gpu::GpuKind;
use crate::provisioner::{Plan, WorkloadSpec};
use crate::util::json::Json;

/// An EC2 GPU instance type (Sec. 5.1 / 5.3).
#[derive(Debug, Clone, PartialEq)]
pub struct InstanceType {
    pub name: &'static str,
    pub gpu: GpuKind,
    pub vcpus: u32,
    pub memory_gb: u32,
    pub price_per_hour: f64,
}

/// The paper's two instance types.
pub const CATALOG: [InstanceType; 2] = [
    InstanceType {
        name: "p3.2xlarge",
        gpu: GpuKind::V100,
        vcpus: 8,
        memory_gb: 61,
        price_per_hour: 3.06,
    },
    InstanceType {
        name: "g4dn.xlarge",
        gpu: GpuKind::T4,
        vcpus: 4,
        memory_gb: 16,
        price_per_hour: 0.526,
    },
];

pub fn instance_for(gpu: GpuKind) -> &'static InstanceType {
    CATALOG.iter().find(|i| i.gpu == gpu).expect("catalog")
}

pub fn instance_by_name(name: &str) -> Option<&'static InstanceType> {
    CATALOG.iter().find(|i| i.name == name)
}

/// One serving process to start on an instance.
#[derive(Debug, Clone, PartialEq)]
pub struct ProcessSpec {
    pub workload: usize,
    pub workload_name: String,
    pub model: String,
    /// MPS active-thread percentage (0-100).
    pub mps_percentage: f64,
    pub batch: u32,
    /// Pre-launched standby with extra resources (Sec. 4.2).
    pub shadow: bool,
}

/// One instance of the deployment.
#[derive(Debug, Clone, PartialEq)]
pub struct InstancePlan {
    pub index: usize,
    pub instance_type: &'static InstanceType,
    pub processes: Vec<ProcessSpec>,
}

/// A complete deployment manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct Deployment {
    pub strategy: String,
    pub instances: Vec<InstancePlan>,
}

/// Rolling-update actions between two deployments.
#[derive(Debug, Clone, PartialEq)]
pub enum Action {
    LaunchInstance { index: usize, instance_type: String },
    TerminateInstance { index: usize },
    StartProcess { instance: usize, process: ProcessSpec },
    StopProcess { instance: usize, workload: usize },
    Reconfigure { instance: usize, process: ProcessSpec },
}

/// Build a deployment manifest from a plan (the launcher's input).
pub fn deploy(plan: &Plan, specs: &[WorkloadSpec], with_shadows: bool) -> Deployment {
    let gpu = GpuKind::parse(&plan.gpu).expect("plan gpu kind");
    let itype = instance_for(gpu);
    let instances = plan
        .gpus
        .iter()
        .enumerate()
        .map(|(i, allocs)| InstancePlan {
            index: i,
            instance_type: itype,
            processes: allocs
                .iter()
                .map(|a| ProcessSpec {
                    workload: a.workload,
                    workload_name: specs[a.workload].name.clone(),
                    model: specs[a.workload].model.name().to_string(),
                    mps_percentage: a.resources * 100.0,
                    batch: a.batch,
                    shadow: with_shadows,
                })
                .collect(),
        })
        .collect();
    Deployment {
        strategy: plan.strategy.clone(),
        instances,
    }
}

impl Deployment {
    pub fn total_processes(&self) -> usize {
        self.instances.iter().map(|i| i.processes.len()).sum()
    }

    pub fn cost_per_hour(&self) -> f64 {
        self.instances
            .iter()
            .filter(|i| !i.processes.is_empty())
            .map(|i| i.instance_type.price_per_hour)
            .sum()
    }

    /// Declarative JSON manifest (what an orchestrator would consume).
    pub fn to_json(&self) -> Json {
        let inst: Vec<Json> = self
            .instances
            .iter()
            .map(|i| {
                let procs: Vec<Json> = i
                    .processes
                    .iter()
                    .map(|p| {
                        Json::obj()
                            .set("workload", p.workload_name.as_str())
                            .set("model", p.model.as_str())
                            .set("mps_active_thread_percentage", p.mps_percentage)
                            .set("preferred_batch", p.batch as usize)
                            .set("shadow_standby", p.shadow)
                    })
                    .collect();
                Json::obj()
                    .set("index", i.index)
                    .set("instance_type", i.instance_type.name)
                    .set("processes", Json::Arr(procs))
            })
            .collect();
        Json::obj()
            .set("strategy", self.strategy.as_str())
            .set("cost_per_hour", self.cost_per_hour())
            .set("instances", Json::Arr(inst))
    }

    /// Shell-like launch script (documentation of the exact commands the
    /// paper's prototype issues via MPS + Triton).
    pub fn to_script(&self) -> String {
        let mut s = String::new();
        for i in &self.instances {
            if i.processes.is_empty() {
                continue;
            }
            s.push_str(&format!(
                "# instance {} ({})\n",
                i.index, i.instance_type.name
            ));
            s.push_str("nvidia-cuda-mps-control -d\n");
            for p in &i.processes {
                s.push_str(&format!(
                    "echo set_active_thread_percentage $SERVER_PID {:.1} | nvidia-cuda-mps-control\n\
                     tritonserver --model {} --preferred-batch-size {}  # {}\n",
                    p.mps_percentage, p.model, p.batch, p.workload_name
                ));
                if p.shadow {
                    s.push_str(&format!(
                        "tritonserver --model {} --standby  # shadow for {}\n",
                        p.model, p.workload_name
                    ));
                }
            }
        }
        s
    }
}

/// Minimal rolling-update diff: which instances to launch/terminate and
/// which processes to start/stop/reconfigure to move `from` -> `to`.
pub fn diff(from: &Deployment, to: &Deployment) -> Vec<Action> {
    let mut actions = Vec::new();
    let max = from.instances.len().max(to.instances.len());
    for idx in 0..max {
        let f = from.instances.get(idx);
        let t = to.instances.get(idx);
        match (f, t) {
            (None, Some(t)) => {
                actions.push(Action::LaunchInstance {
                    index: idx,
                    instance_type: t.instance_type.name.to_string(),
                });
                for p in &t.processes {
                    actions.push(Action::StartProcess {
                        instance: idx,
                        process: p.clone(),
                    });
                }
            }
            (Some(_), None) => actions.push(Action::TerminateInstance { index: idx }),
            (Some(f), Some(t)) => {
                // stopped processes
                for fp in &f.processes {
                    if !t.processes.iter().any(|tp| tp.workload == fp.workload) {
                        actions.push(Action::StopProcess {
                            instance: idx,
                            workload: fp.workload,
                        });
                    }
                }
                // started / reconfigured
                for tp in &t.processes {
                    match f.processes.iter().find(|fp| fp.workload == tp.workload) {
                        None => actions.push(Action::StartProcess {
                            instance: idx,
                            process: tp.clone(),
                        }),
                        Some(fp) if fp != tp => actions.push(Action::Reconfigure {
                            instance: idx,
                            process: tp.clone(),
                        }),
                        _ => {}
                    }
                }
                // empty -> terminate
                if t.processes.is_empty() && !f.processes.is_empty() {
                    actions.push(Action::TerminateInstance { index: idx });
                }
            }
            (None, None) => {}
        }
    }
    actions
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuKind;
    use crate::provisioner::{self, ProfiledSystem};
    use crate::workload::{app_workloads, table1_workloads};

    fn sys() -> ProfiledSystem {
        let (hw, wls) = crate::profiler::profile_all(GpuKind::V100, 42);
        ProfiledSystem {
            hw,
            coeffs: crate::gpu::ALL_MODELS.iter().cloned().zip(wls).collect(),
        }
    }

    #[test]
    fn deployment_mirrors_plan() {
        let s = sys();
        let specs = app_workloads();
        let plan = provisioner::provision(&s, &specs);
        let d = deploy(&plan, &specs, true);
        assert_eq!(d.instances.len(), plan.num_gpus());
        assert_eq!(d.total_processes(), 12);
        assert!((d.cost_per_hour() - plan.cost_per_hour()).abs() < 1e-9);
        // every process percentage within (0, 100]
        for i in &d.instances {
            for p in &i.processes {
                assert!(p.mps_percentage > 0.0 && p.mps_percentage <= 100.0);
                assert!(p.shadow);
            }
        }
    }

    #[test]
    fn manifest_json_and_script() {
        let s = sys();
        let specs = table1_workloads();
        let plan = provisioner::provision(&s, &specs);
        let d = deploy(&plan, &specs, true);
        let j = d.to_json();
        assert_eq!(
            j.path("instances.0.instance_type").unwrap().as_str(),
            Some("p3.2xlarge")
        );
        let script = d.to_script();
        assert!(script.contains("set_active_thread_percentage"));
        assert!(script.contains("tritonserver --model resnet50"));
        assert!(script.contains("--standby"));
    }

    #[test]
    fn diff_empty_for_identical() {
        let s = sys();
        let specs = table1_workloads();
        let plan = provisioner::provision(&s, &specs);
        let d = deploy(&plan, &specs, false);
        assert!(diff(&d, &d).is_empty());
    }

    #[test]
    fn diff_detects_changes() {
        let s = sys();
        let specs = table1_workloads();
        let plan = provisioner::provision(&s, &specs);
        let d1 = deploy(&plan, &specs, false);

        // grow workload 0 by one unit and move nothing else
        let mut plan2 = plan.clone();
        let (g, _) = plan2.find(0).unwrap();
        for a in &mut plan2.gpus[g] {
            if a.workload == 0 {
                a.resources += 0.025;
            }
        }
        let d2 = deploy(&plan2, &specs, false);
        let actions = diff(&d1, &d2);
        assert_eq!(actions.len(), 1);
        assert!(matches!(actions[0], Action::Reconfigure { .. }));

        // dropping a workload produces a stop
        let mut plan3 = plan.clone();
        for g in &mut plan3.gpus {
            g.retain(|a| a.workload != 1);
        }
        let d3 = deploy(&plan3, &specs, false);
        let actions = diff(&d1, &d3);
        assert!(actions
            .iter()
            .any(|a| matches!(a, Action::StopProcess { workload: 1, .. })));
    }

    #[test]
    fn diff_launches_new_instances() {
        let s = sys();
        let specs = app_workloads();
        let trio = table1_workloads();
        let small = provisioner::provision(&s, &trio);
        let big = provisioner::provision(&s, &specs);
        let d_small = deploy(&small, &trio, false);
        let d_big = deploy(&big, &specs, false);
        let actions = diff(&d_small, &d_big);
        let launches = actions
            .iter()
            .filter(|a| matches!(a, Action::LaunchInstance { .. }))
            .count();
        assert_eq!(launches, d_big.instances.len() - d_small.instances.len());
    }

    #[test]
    fn catalog_lookup() {
        assert_eq!(instance_for(GpuKind::V100).name, "p3.2xlarge");
        assert_eq!(instance_by_name("g4dn.xlarge").unwrap().gpu, GpuKind::T4);
        assert!(instance_by_name("p4d.24xlarge").is_none());
    }
}
