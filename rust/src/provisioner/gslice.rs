//! GSLICE+ baseline (Dhakal et al., SoCC'20, patched per Sec. 5.1 with
//! iGniter's placement).
//!
//! GSLICE is *interference-unaware*: it starts each workload from its solo
//! lower bound and then **reactively** tunes the allocated resources and
//! batch per workload against a fixed tuning threshold (10 %) using the
//! observed average latency — oscillating around the SLO (Fig. 15/16) and
//! never shrinking an allocation that currently meets its SLO.  The static
//! plan below captures the state after the paper's "five adjustments"
//! (Sec. 5.3); the live adjustment loop is `coordinator::monitor::GsliceTuner`
//! for the Fig. 15/16 experiment.

use super::igniter::derive_all;
use crate::perfmodel::AnalyticModel;
use super::types::{Alloc, Plan, ProfiledSystem, WorkloadSpec};

/// GSLICE's tuning threshold (fraction of the half-SLO).
pub const TUNING_THRESHOLD: f64 = 0.10;
/// Resource step per adjustment (one allocation unit, like iGniter's Alg.2
/// granularity — GSLICE uses percentage steps of similar size).
pub const ADJUST_ROUNDS: usize = 5;

/// Observed (here: simulator ground-truth) average latency of workload `i`
/// of `allocs` on one device.
fn observed_latency(
    sys: &ProfiledSystem,
    specs: &[WorkloadSpec],
    allocs: &[Alloc],
    i: usize,
    device_seed: u64,
) -> f64 {
    use crate::gpu::GpuDevice;
    let kind = crate::gpu::GpuKind::parse(&sys.hw.gpu).expect("gpu kind");
    let mut d = GpuDevice::new(kind, device_seed);
    for a in allocs {
        // unchecked: GSLICE's force-grown allocations may oversubscribe
        d.launch_unchecked(a.workload as u64, specs[a.workload].model, a.resources, a.batch);
    }
    let a = &allocs[i];
    let mut lat = Vec::new();
    for _ in 0..5 {
        lat.push(d.query_latency(a.workload as u64, a.batch).unwrap().t_inf);
    }
    crate::util::stats::mean(&lat)
}

/// GSLICE+ provisioning: iGniter's placement skeleton (the "+" patch —
/// which workloads land on which GPU), but device sizing by the reactive
/// threshold tuner instead of the analytical interference model.  The
/// tuner is interference-*unaware*: it grows a violating workload by a 5 %
/// step regardless of the device's remaining headroom (the hardware then
/// time-slices, Sec. 2.3's "over-allocation"), and it shrinks a workload
/// whose average latency undershoots the threshold band — the source of
/// Fig. 15's oscillation.  It observes *average* latency only, so tail
/// (P99) violations survive tuning.
pub fn provision_gslice(sys: &ProfiledSystem, specs: &[WorkloadSpec]) -> Plan {
    let derived = derive_all(sys, specs);
    let hw = &sys.hw;

    // Placement skeleton from iGniter's placer (the patch in Sec. 5.1).
    let skeleton =
        super::igniter::provision_with_derived(&AnalyticModel::ALL, sys, specs, &derived);
    let mut plan = Plan::new("GSLICE+", hw);
    // GSLICE starts every workload from its solo lower bound.
    plan.gpus = skeleton
        .gpus
        .iter()
        .map(|allocs| {
            allocs
                .iter()
                .map(|a| Alloc {
                    workload: a.workload,
                    resources: derived[a.workload].unwrap().r_lower,
                    batch: derived[a.workload].unwrap().batch,
                })
                .collect()
        })
        .collect();

    // Reactive tuning rounds against observed average latency.
    for round in 0..ADJUST_ROUNDS {
        for g in 0..plan.gpus.len() {
            let allocs = plan.gpus[g].clone();
            for (i, a) in allocs.iter().enumerate() {
                let spec = &specs[a.workload];
                let obs = observed_latency(sys, specs, &plan.gpus[g], i, 1000 + round as u64);
                let half = spec.slo_ms / 2.0;
                if obs > half {
                    // violating: force-grow by 5 % (interference-unaware —
                    // no headroom check; may oversubscribe the device)
                    plan.gpus[g][i].resources += hw.r_unit * 2.0;
                } else if obs < half * (1.0 - TUNING_THRESHOLD) {
                    // undershooting the band: shrink (Fig. 15 oscillation)
                    let step = hw.r_unit * 2.0;
                    if plan.gpus[g][i].resources > step + hw.r_unit / 2.0 {
                        plan.gpus[g][i].resources -= step;
                    }
                }
            }
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuKind;
    use crate::provisioner::igniter;
    use crate::workload::app_workloads;

    fn sys() -> ProfiledSystem {
        let (hw, wls) = crate::profiler::profile_all(GpuKind::V100, 42);
        ProfiledSystem {
            hw,
            coeffs: crate::gpu::ALL_MODELS.iter().cloned().zip(wls).collect(),
        }
    }

    #[test]
    fn valid_plan() {
        let s = sys();
        let specs = app_workloads();
        let p = provision_gslice(&s, &specs);
        // GSLICE may oversubscribe devices (interference-unaware growth),
        // but every workload must still be placed exactly once.
        p.validate(specs.len(), 2.0).unwrap();
    }

    #[test]
    fn some_violations_remain() {
        // Fig. 14: GSLICE+ leaves ~3 workloads violating under the true
        // interference, despite tuning.
        let s = sys();
        let specs = app_workloads();
        let p = provision_gslice(&s, &specs);
        let violations = igniter::predict_plan(&s, &specs, &p)
            .iter()
            .filter(|(w, t, _)| *t > specs[*w].slo_ms / 2.0 + 1e-9)
            .count();
        assert!(
            (1..=8).contains(&violations),
            "GSLICE+ violations = {violations}"
        );
    }

    #[test]
    fn cost_between_ffd_and_gpulets() {
        let s = sys();
        let specs = app_workloads();
        let gs = provision_gslice(&s, &specs);
        let ig = igniter::provision(&s, &specs);
        // paper: GSLICE+ lands at the same #GPUs as iGniter (6), with
        // violations; allow a band around that.
        let diff = gs.num_gpus() as i64 - ig.num_gpus() as i64;
        assert!(diff.abs() <= 1, "gslice {} vs igniter {}", gs.num_gpus(), ig.num_gpus());
    }

    #[test]
    fn deterministic() {
        let s = sys();
        let specs = app_workloads();
        assert_eq!(provision_gslice(&s, &specs), provision_gslice(&s, &specs));
    }
}
