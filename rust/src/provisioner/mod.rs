//! GPU resource provisioning strategies: the paper's iGniter (Alg. 1 + 2)
//! and the Sec.-5.1 baselines (FFD+, FFD++, GSLICE+, gpu-lets+), plus the
//! heterogeneous-cluster extension.

pub mod engine;
pub mod ffd;
pub mod gpulets;
pub mod gslice;
pub mod heterogeneous;
pub mod igniter;
pub mod mig;
pub mod online;
pub mod partition;
pub mod types;

pub use engine::PlacementEngine;
pub use partition::PartitionModel;
pub use igniter::{
    alloc_gpus, alloc_gpus_into, derive_all, find_best_linear, predict_plan, provision,
    provision_with, provision_with_linear, replica_split, validate_replica_shares, Derived,
    MAX_REPLICAS,
};
pub use online::{OnlinePlanner, Placed};
pub use types::{diff_plans, Alloc, Migration, Plan, PlanDelta, ProfiledSystem, WorkloadSpec};
