//! MIG provisioning strategies: fragmentation-aware packing over discrete
//! slices, plus the FFD and Alg.-1 baselines it competes against.
//!
//! On a MIG device the sizing problem is *exact*: slices are
//! hardware-isolated, so a tenant placed at its (slice-quantized)
//! Theorem-1 lower bound meets its half-SLO no matter who arrives later —
//! `alloc_gpus`' growth loop never fires and co-residents never change.
//! What remains is pure bin packing, and the cost driver is **stranded
//! capacity**: free GPCs on devices you pay for but cannot use
//! (ParvaGPU's observation).  Three strategies run head-to-head over
//! identical slice demands:
//!
//! * `provision_mig_packed` — best-fit decreasing (minimize residual free
//!   GPCs per placement) with a first-fit portfolio fallback, so its
//!   device count is `<=` FFD's on *every* input, not just on average;
//! * `provision_mig_ffd` — first-fit decreasing, the FFD++ analogue
//!   (sizing is already exact, so FFD+ and FFD++ coincide here);
//! * `provision_mig_igniter` — Alg. 1 under the interference-collapsed
//!   model: every placement predicts zero interference growth, so the
//!   min-`r_inter` objective degenerates and the paper's strategy reduces
//!   to first-fit — the quantitative form of "interference-awareness
//!   stops paying on MIG".
//!
//! All three emit ordinary `Plan`s whose allocations are slice fractions
//! (`g/7`), so `Plan::validate`, the cluster simulator, and the cost
//! accounting work unchanged.

use super::engine::PlacementEngine;
use super::igniter::{self, Derived};
use super::partition::{self, PartitionModel};
use super::types::{Plan, ProfiledSystem, WorkloadSpec};
use crate::perfmodel::model::ModelTerms;
use crate::perfmodel::AnalyticModel;

/// The planner-side performance model on MIG hardware: isolation
/// collapses every interference term, leaving exact solo predictions.
pub fn mig_model() -> AnalyticModel {
    AnalyticModel::with_terms(ModelTerms::NONE)
}

/// Slice-quantize a derived set: each Theorem-1 lower bound rounds up to
/// the smallest legal MIG profile covering it.  Batch sizes are
/// unchanged — Eq. 17 does not depend on the partition grid.
pub fn quantize_derived(derived: &[Option<Derived>]) -> Vec<Option<Derived>> {
    derived
        .iter()
        .map(|d| {
            d.map(|d| Derived {
                batch: d.batch,
                r_lower: PartitionModel::Mig.quantize_demand(d.r_lower),
            })
        })
        .collect()
}

/// Placement items in Alg.-1 order: slice demand descending, stable on
/// workload id (the same sort `place_items` uses).
fn sorted_items(derived: &[Option<Derived>]) -> Vec<(usize, Derived)> {
    let mut items: Vec<(usize, Derived)> = derived
        .iter()
        .enumerate()
        .filter_map(|(w, d)| d.map(|d| (w, d)))
        .collect();
    items.sort_by(|(wa, da), (wb, db)| {
        db.r_lower
            .partial_cmp(&da.r_lower)
            .unwrap()
            .then(wa.cmp(wb))
    });
    items
}

/// Shared packing loop: decreasing items through the engine's headroom
/// index (free-GPC buckets), best-fit or first-fit per item.
fn pack(
    sys: &ProfiledSystem,
    specs: &[WorkloadSpec],
    derived: &[Option<Derived>],
    strategy: &str,
    best_fit: bool,
) -> Plan {
    let mut plan = Plan::new(strategy, &sys.hw);
    plan.gpus.push(Vec::new());
    let mut engine = PlacementEngine::new(&sys.hw);
    engine.push_device(sys, specs, &[]);
    for (w, d) in sorted_items(derived) {
        engine.place_discrete(sys, specs, &mut plan, w, d, best_fit);
    }
    plan
}

/// Fragmentation-aware packer (the adopted MIG strategy): best-fit
/// decreasing, falling back to the first-fit packing when that lands on
/// fewer devices.  The portfolio makes `cost <= FFD cost` a structural
/// guarantee rather than a statistical one.
pub fn provision_mig_packed(
    sys: &ProfiledSystem,
    specs: &[WorkloadSpec],
    derived: &[Option<Derived>],
) -> Plan {
    let bfd = pack(sys, specs, derived, "MIG-packed", true);
    let mut ffd = pack(sys, specs, derived, "MIG-packed", false);
    if ffd.num_gpus() < bfd.num_gpus() {
        ffd.strategy = "MIG-packed(ffd)".to_string();
        ffd
    } else {
        bfd
    }
}

/// First-fit decreasing baseline over the same slice demands.
pub fn provision_mig_ffd(
    sys: &ProfiledSystem,
    specs: &[WorkloadSpec],
    derived: &[Option<Derived>],
) -> Plan {
    pack(sys, specs, derived, "MIG-FFD", false)
}

/// Alg. 1 under the collapsed model — the paper's strategy transplanted
/// onto MIG, as the head-to-head's third corner.
pub fn provision_mig_igniter(
    sys: &ProfiledSystem,
    specs: &[WorkloadSpec],
    derived: &[Option<Derived>],
) -> Plan {
    let model = mig_model();
    let mut plan = igniter::provision_with_derived(&model, sys, specs, derived);
    plan.strategy = "MIG-iGniter".to_string();
    plan
}

/// The MIG provisioning entry the partition-model routing calls:
/// slice-quantize the derived demands and run the adopted packer.
pub fn provision_mig(
    sys: &ProfiledSystem,
    specs: &[WorkloadSpec],
    derived: &[Option<Derived>],
) -> Plan {
    provision_mig_packed(sys, specs, &quantize_derived(derived))
}

/// Head-to-head result on one MIG system over identical demands: the
/// adopted packed plan plus the baselines' costs and the fragmentation
/// metrics the sweep reports.
#[derive(Debug, Clone)]
pub struct MigHeadToHead {
    pub packed: Plan,
    pub cost_packed: f64,
    pub cost_ffd: f64,
    pub cost_igniter: f64,
    /// Stranded capacity of the adopted packed plan (% of provisioned GPCs).
    pub stranded_pct: f64,
    /// Placement items executed across all three strategies.
    pub placements: usize,
}

/// Run all three strategies on identical slice-quantized demands.
pub fn head_to_head(
    sys: &ProfiledSystem,
    specs: &[WorkloadSpec],
    derived: &[Option<Derived>],
) -> MigHeadToHead {
    let q = quantize_derived(derived);
    let packed = provision_mig_packed(sys, specs, &q);
    let ffd = provision_mig_ffd(sys, specs, &q);
    let ig = provision_mig_igniter(sys, specs, &q);
    MigHeadToHead {
        cost_packed: packed.cost_per_hour(),
        cost_ffd: ffd.cost_per_hour(),
        cost_igniter: ig.cost_per_hour(),
        stranded_pct: partition::stranded_pct(&packed),
        placements: packed.total_allocs() + ffd.total_allocs() + ig.total_allocs(),
        packed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::{GpuKind, Model};
    use crate::perfmodel;
    use crate::provisioner::WorkloadSpec;
    use crate::util::quick::forall;
    use crate::util::rng::Rng;
    use crate::workload::synthetic_workloads;

    fn sys(kind: GpuKind) -> ProfiledSystem {
        let (hw, wls) = crate::profiler::profile_all(kind, 42);
        ProfiledSystem {
            hw,
            coeffs: crate::gpu::ALL_MODELS.iter().cloned().zip(wls).collect(),
        }
    }

    /// Specs clamped so every workload derives without replication.
    fn feasible_specs(n: usize, seed: u64) -> Vec<WorkloadSpec> {
        synthetic_workloads(n, seed)
            .into_iter()
            .map(|mut w| {
                w.rate_rps = w.rate_rps.min(150.0);
                w.slo_ms = w.slo_ms.max(40.0);
                w
            })
            .collect()
    }

    #[test]
    fn packed_plans_are_slice_legal_and_meet_slos() {
        let s = sys(GpuKind::A100);
        forall(
            2042,
            10,
            |r: &mut Rng| (r.next_u64(), 6 + r.below(20) as usize),
            |&(seed, n)| {
                let specs = feasible_specs(n, seed);
                let derived = igniter::derive_all(&s, &specs);
                if derived.iter().any(|d| d.is_none()) {
                    return Ok(()); // replication handled by the routing layer
                }
                let q = quantize_derived(&derived);
                for plan in [
                    provision_mig_packed(&s, &specs, &q),
                    provision_mig_ffd(&s, &specs, &q),
                    provision_mig_igniter(&s, &specs, &q),
                ] {
                    partition::plan_is_legal(&plan).map_err(|e| format!("{}: {e}", plan.strategy))?;
                    plan.validate(specs.len(), s.hw.r_max)
                        .map_err(|e| format!("{}: {e}", plan.strategy))?;
                    // solo (= exact on MIG) predictions meet every
                    // half-SLO and per-replica throughput share
                    igniter::validate_replica_shares(&mig_model(), &s, &specs, &plan)
                        .map_err(|e| format!("{}: {e}", plan.strategy))?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_packer_never_costs_more_than_ffd_or_igniter() {
        // The head-to-head differential: at equal (met) SLO attainment the
        // fragmentation packer's cost is <= both baselines', forall seeds.
        for kind in [GpuKind::A100, GpuKind::H100] {
            let s = sys(kind);
            forall(
                77,
                12,
                |r: &mut Rng| (r.next_u64(), 4 + r.below(28) as usize),
                |&(seed, n)| {
                    let specs = feasible_specs(n, seed);
                    let derived = igniter::derive_all(&s, &specs);
                    if derived.iter().any(|d| d.is_none()) {
                        return Ok(());
                    }
                    let h = head_to_head(&s, &specs, &derived);
                    if h.cost_packed > h.cost_ffd + 1e-9 {
                        return Err(format!("packed {} > ffd {}", h.cost_packed, h.cost_ffd));
                    }
                    if h.cost_packed > h.cost_igniter + 1e-9 {
                        return Err(format!(
                            "packed {} > igniter {}",
                            h.cost_packed, h.cost_igniter
                        ));
                    }
                    Ok(())
                },
            );
        }
    }

    #[test]
    fn igniter_on_mig_degenerates_to_first_fit() {
        // With all interference terms collapsed, Alg. 1's min-r_inter scan
        // sees zero growth everywhere and early-breaks on the first fitting
        // device — exactly first-fit.  Same device count as MIG-FFD.
        let s = sys(GpuKind::A100);
        let specs = feasible_specs(16, 4242);
        let derived = igniter::derive_all(&s, &specs);
        assert!(derived.iter().all(|d| d.is_some()));
        let q = quantize_derived(&derived);
        let ig = provision_mig_igniter(&s, &specs, &q);
        let ffd = provision_mig_ffd(&s, &specs, &q);
        assert_eq!(ig.num_gpus(), ffd.num_gpus());
    }

    #[test]
    fn best_fit_beats_first_fit_on_a_crafted_instance() {
        // Demands 4g,3g,3g,2g,2g,... constructed so first-fit strands
        // capacity that best-fit recovers: the packer must win strictly
        // somewhere, otherwise it is not actually doing anything.
        let s = sys(GpuKind::A100);
        let found_strict_win = std::cell::Cell::new(false);
        forall(
            1234,
            40,
            |r: &mut Rng| (r.next_u64(), 6 + r.below(30) as usize),
            |&(seed, n)| {
                let specs = feasible_specs(n, seed);
                let derived = igniter::derive_all(&s, &specs);
                if derived.iter().any(|d| d.is_none()) {
                    return Ok(());
                }
                let h = head_to_head(&s, &specs, &derived);
                if h.cost_packed < h.cost_ffd - 1e-9 || h.stranded_pct < 1e-12 {
                    found_strict_win.set(true);
                }
                Ok(())
            },
        );
        assert!(
            found_strict_win.get(),
            "packer never strictly beat FFD nor achieved zero stranding on 40 seeded instances"
        );
    }

    #[test]
    fn quantized_demands_cover_and_replication_routes_around_overflow() {
        let s = sys(GpuKind::A100);
        // a rate needing more than one full A100 derives to None...
        let rate = igniter::over_capacity_rate(&s, Model::ResNet50, 40.0, 400.0);
        let spec = WorkloadSpec::new(0, Model::ResNet50, 40.0, rate);
        assert!(
            perfmodel::lower_bound_resources(&s.hw, s.coeffs_for(Model::ResNet50), 40.0, rate)
                .is_none()
        );
        // ...and replica_split still finds an even share that fits
        let (k, d) = igniter::replica_split(&s, &spec).expect("split feasible");
        assert!(k >= 2);
        assert!(PartitionModel::Mig.quantize_demand(d.r_lower) <= 1.0 + 1e-9);
    }
}
