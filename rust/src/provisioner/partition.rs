//! Partition models: how one physical device's compute is divided among
//! co-resident tenants.
//!
//! The paper's provisioner assumes **continuous** gpulets (MPS
//! active-thread percentages on a 2.5 % grid).  MIG generations
//! (A100/H100) instead expose **discrete** slices: a device has seven
//! GPCs, tenants get one of the legal compute profiles 1g/2g/3g/4g/7g
//! (5g and 6g are not manufacturable), and a slice can only be
//! reconfigured while it is empty — a live replica is never resized in
//! place.  Because every slice owns its SMs, L2 partition, and scheduler,
//! co-tenants do not interfere: the planner's interference terms collapse
//! to solo predictions (`AnalyticModel::with_terms(ModelTerms::NONE)`),
//! and the provisioning objective shifts from minimizing interference
//! growth to minimizing **stranded slice capacity** (fragmentation),
//! following ParvaGPU (arXiv 2409.14447).
//!
//! This module is the abstraction boundary: `PartitionModel::Continuous`
//! routes to today's Alg.-1 path bit-identically; `PartitionModel::Mig`
//! routes to the slice-quantized packers in `provisioner::mig`.
//!
//! Simplification vs. real MIG: any multiset of legal profiles summing to
//! at most 7 GPCs is accepted (the hardware's placement-tree constraints
//! on slice *positions* are not modeled — they would only tighten the
//! packing, never loosen it).

use super::types::{Alloc, Plan};
use crate::gpu::GpuKind;

/// GPCs per MIG device (the 7g envelope).
pub const MIG_GPC_PER_DEVICE: u32 = 7;

/// Legal MIG compute profiles in GPCs, ascending.
pub const MIG_PROFILES_GPC: [u32; 5] = [1, 2, 3, 4, 7];

/// How a device partitions its compute among tenants.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PartitionModel {
    /// Continuous MPS-style gpulets on the `r_unit` grid (V100/T4) —
    /// today's behavior, byte for byte.
    Continuous,
    /// Discrete MIG slices (A100/H100): legal profiles only, reconfig
    /// only of empty slices, zero cross-slice interference.
    Mig,
}

impl PartitionModel {
    pub fn for_kind(kind: GpuKind) -> PartitionModel {
        if kind.is_mig() {
            PartitionModel::Mig
        } else {
            PartitionModel::Continuous
        }
    }

    /// Resolve from a profiled system's GPU label (`HardwareCoeffs::gpu`).
    /// Unknown labels are continuous — the conservative default.
    pub fn for_gpu_name(name: &str) -> PartitionModel {
        GpuKind::parse(name).map_or(PartitionModel::Continuous, PartitionModel::for_kind)
    }

    pub fn is_mig(self) -> bool {
        self == PartitionModel::Mig
    }

    /// Quantize a Theorem-1 lower bound to this partition grid.
    /// Continuous demands pass through untouched (`lower_bound_resources`
    /// already lands on the `r_unit` grid — re-quantizing here would
    /// break the bit-identity contract); MIG demands round up to the
    /// smallest legal profile that covers them.
    pub fn quantize_demand(self, r: f64) -> f64 {
        match self {
            PartitionModel::Continuous => r,
            PartitionModel::Mig => gpc_fraction(demand_gpc(r)),
        }
    }
}

/// Device fraction of a `g`-GPC slice.
pub fn gpc_fraction(gpc: u32) -> f64 {
    gpc as f64 / MIG_GPC_PER_DEVICE as f64
}

/// Smallest legal profile (in GPCs) covering the fraction `r`.  Demands
/// just above 4g take the whole device: 5g/6g do not exist.
pub fn demand_gpc(r: f64) -> u32 {
    let need = (r * MIG_GPC_PER_DEVICE as f64 - 1e-9).ceil().max(1.0) as u32;
    let need = need.min(MIG_GPC_PER_DEVICE);
    *MIG_PROFILES_GPC
        .iter()
        .find(|&&p| p >= need)
        .unwrap_or(&MIG_GPC_PER_DEVICE)
}

/// The GPC count of an allocation fraction, when it sits exactly on the
/// slice grid (within float tolerance); `None` for off-grid fractions.
pub fn slice_gpc(r: f64) -> Option<u32> {
    let g = (r * MIG_GPC_PER_DEVICE as f64).round();
    if g < 1.0 || g > MIG_GPC_PER_DEVICE as f64 {
        return None;
    }
    if (r * MIG_GPC_PER_DEVICE as f64 - g).abs() < 1e-6 {
        Some(g as u32)
    } else {
        None
    }
}

/// MIG legality of one device's allocation list: every tenant holds a
/// legal profile and the profiles sum within the 7-GPC envelope.
pub fn device_is_legal(allocs: &[Alloc]) -> Result<(), String> {
    let mut total = 0u32;
    for a in allocs {
        match slice_gpc(a.resources) {
            Some(g) if MIG_PROFILES_GPC.contains(&g) => total += g,
            Some(g) => return Err(format!("w{}: {g}g is not a legal MIG profile", a.workload)),
            None => {
                return Err(format!(
                    "w{}: allocation {:.4} is off the slice grid",
                    a.workload, a.resources
                ))
            }
        }
    }
    if total > MIG_GPC_PER_DEVICE {
        return Err(format!("slices sum to {total}g > {MIG_GPC_PER_DEVICE}g"));
    }
    Ok(())
}

/// MIG legality of a whole plan.
pub fn plan_is_legal(plan: &Plan) -> Result<(), String> {
    for (g, allocs) in plan.gpus.iter().enumerate() {
        device_is_legal(allocs).map_err(|e| format!("gpu {g}: {e}"))?;
    }
    Ok(())
}

/// Stranded capacity of a MIG plan: free GPCs on provisioned devices
/// (paid for but unusable by the current packing), in whole GPCs.
pub fn stranded_gpc(plan: &Plan) -> u32 {
    plan.gpus
        .iter()
        .map(|allocs| {
            let used: u32 = allocs.iter().filter_map(|a| slice_gpc(a.resources)).sum();
            MIG_GPC_PER_DEVICE.saturating_sub(used)
        })
        .sum()
}

/// Stranded capacity as a percentage of all provisioned GPCs (0 for an
/// empty plan).
pub fn stranded_pct(plan: &Plan) -> f64 {
    let devices = plan.num_gpus() as f64;
    if devices == 0.0 {
        return 0.0;
    }
    100.0 * stranded_gpc(plan) as f64 / (devices * MIG_GPC_PER_DEVICE as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quick::forall;
    use crate::util::rng::Rng;

    fn alloc(workload: usize, resources: f64) -> Alloc {
        Alloc {
            workload,
            resources,
            batch: 4,
        }
    }

    #[test]
    fn partition_model_resolution() {
        assert_eq!(PartitionModel::for_kind(GpuKind::V100), PartitionModel::Continuous);
        assert_eq!(PartitionModel::for_kind(GpuKind::T4), PartitionModel::Continuous);
        assert_eq!(PartitionModel::for_kind(GpuKind::A100), PartitionModel::Mig);
        assert_eq!(PartitionModel::for_kind(GpuKind::H100), PartitionModel::Mig);
        assert_eq!(PartitionModel::for_gpu_name("A100"), PartitionModel::Mig);
        assert_eq!(PartitionModel::for_gpu_name("V100"), PartitionModel::Continuous);
        // unknown labels fall back to continuous
        assert_eq!(PartitionModel::for_gpu_name("tpu-v4"), PartitionModel::Continuous);
    }

    #[test]
    fn continuous_quantize_is_the_identity() {
        // bitwise — the continuous path must not touch the demand
        for r in [0.025, 0.3, 0.617, 1.0, 0.12345] {
            assert_eq!(
                PartitionModel::Continuous.quantize_demand(r).to_bits(),
                r.to_bits()
            );
        }
    }

    #[test]
    fn demand_rounds_up_to_legal_profiles_only() {
        // exact table: fraction -> GPCs
        assert_eq!(demand_gpc(0.01), 1);
        assert_eq!(demand_gpc(1.0 / 7.0), 1);
        assert_eq!(demand_gpc(0.15), 2);
        assert_eq!(demand_gpc(2.0 / 7.0), 2);
        assert_eq!(demand_gpc(0.3), 3);
        assert_eq!(demand_gpc(0.5), 4);
        // 5g and 6g do not exist: anything past 4g takes the device
        assert_eq!(demand_gpc(4.1 / 7.0), 7);
        assert_eq!(demand_gpc(6.0 / 7.0), 7);
        assert_eq!(demand_gpc(1.0), 7);
    }

    #[test]
    fn property_quantized_demand_is_legal_and_covering() {
        forall(
            99,
            300,
            |r: &mut Rng| r.range_f64(1e-6, 1.0),
            |&r| {
                let g = demand_gpc(r);
                if !MIG_PROFILES_GPC.contains(&g) {
                    return Err(format!("{r} -> illegal profile {g}g"));
                }
                if gpc_fraction(g) + 1e-9 < r {
                    return Err(format!("{r} -> {g}g does not cover the demand"));
                }
                // round-trips through the grid detector
                if slice_gpc(PartitionModel::Mig.quantize_demand(r)) != Some(g) {
                    return Err(format!("{r} -> {g}g does not round-trip"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn device_legality() {
        // 4g + 3g fills the envelope
        assert!(device_is_legal(&[alloc(0, 4.0 / 7.0), alloc(1, 3.0 / 7.0)]).is_ok());
        // seven 1g tenants fill it too
        let ones: Vec<Alloc> = (0..7).map(|w| alloc(w, 1.0 / 7.0)).collect();
        assert!(device_is_legal(&ones).is_ok());
        // 4g + 4g overflows
        let e = device_is_legal(&[alloc(0, 4.0 / 7.0), alloc(1, 4.0 / 7.0)]).unwrap_err();
        assert!(e.contains("8g"), "{e}");
        // a 5g slice is not a thing
        let e = device_is_legal(&[alloc(0, 5.0 / 7.0)]).unwrap_err();
        assert!(e.contains("not a legal"), "{e}");
        // off-grid continuous allocations are rejected
        assert!(device_is_legal(&[alloc(0, 0.3)]).is_err());
    }

    #[test]
    fn stranded_capacity_accounting() {
        let mut plan = Plan {
            strategy: "t".into(),
            gpu: "A100".into(),
            unit_price: 4.1,
            gpus: vec![
                vec![alloc(0, 4.0 / 7.0), alloc(1, 2.0 / 7.0)], // 1g stranded
                vec![alloc(2, 7.0 / 7.0)],                      // full
            ],
        };
        assert_eq!(stranded_gpc(&plan), 1);
        assert!((stranded_pct(&plan) - 100.0 / 14.0).abs() < 1e-9);
        plan.gpus.push(Vec::new()); // an empty provisioned device: all 7 stranded
        assert_eq!(stranded_gpc(&plan), 8);
        assert_eq!(stranded_gpc(&Plan::default()), 0);
        assert_eq!(stranded_pct(&Plan::default()), 0.0);
    }
}
