//! Shared types of the GPU resource provisioning layer: workload SLO
//! specifications, per-GPU allocations, and complete provisioning plans.

use crate::gpu::Model;
use crate::perfmodel::{HardwareCoeffs, PlacedWorkload, WorkloadCoeffs};
use crate::util::json::Json;

/// A DNN inference workload with its performance SLO (input to Alg. 1).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSpec {
    /// Stable id (index into the submitted set; `W1..W12` in the paper).
    pub id: usize,
    /// Display name, e.g. "W4(resnet50)".
    pub name: String,
    pub model: Model,
    /// Latency SLO T_slo (ms).
    pub slo_ms: f64,
    /// Request arrival rate R (req/s).
    pub rate_rps: f64,
}

impl WorkloadSpec {
    pub fn new(id: usize, model: Model, slo_ms: f64, rate_rps: f64) -> WorkloadSpec {
        WorkloadSpec {
            id,
            name: format!("W{}({})", id + 1, model.name()),
            model,
            slo_ms,
            rate_rps,
        }
    }
}

/// One workload's allocation on a specific GPU.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Alloc {
    pub workload: usize,
    /// Fraction of the device (MPS active-thread percentage).
    pub resources: f64,
    /// Configured batch size.
    pub batch: u32,
}

/// A complete provisioning plan over a homogeneous GPU pool.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Plan {
    /// Strategy that produced the plan (for reporting).
    pub strategy: String,
    /// GPU type label.
    pub gpu: String,
    /// Hourly price per GPU instance.
    pub unit_price: f64,
    /// Allocations per GPU device (index = device id).
    pub gpus: Vec<Vec<Alloc>>,
}

impl Plan {
    pub fn new(strategy: &str, hw: &HardwareCoeffs) -> Plan {
        Plan {
            strategy: strategy.to_string(),
            gpu: hw.gpu.clone(),
            unit_price: hw.unit_price,
            gpus: Vec::new(),
        }
    }

    pub fn num_gpus(&self) -> usize {
        self.gpus.len()
    }

    /// Total allocations across all devices (every replica counts once) —
    /// the number of placement items Alg. 1 executed to build the plan.
    pub fn total_allocs(&self) -> usize {
        self.gpus.iter().map(|g| g.len()).sum()
    }

    /// Become a copy of `other`, reusing this plan's existing allocations
    /// (strings, outer `Vec`, per-device `Vec`s) instead of deep-cloning.
    /// The online loop snapshots the standing plan every trigger
    /// (`diff_plans` needs the before-image), so this is hot.
    pub fn copy_from(&mut self, other: &Plan) {
        self.strategy.clone_from(&other.strategy);
        self.gpu.clone_from(&other.gpu);
        self.unit_price = other.unit_price;
        self.gpus.clone_from(&other.gpus);
    }

    /// Hourly monetary cost C (Eq. 12): #instances x unit price.
    pub fn cost_per_hour(&self) -> f64 {
        self.num_gpus() as f64 * self.unit_price
    }

    /// Sum of allocated resources on one device.
    pub fn allocated(&self, gpu: usize) -> f64 {
        self.gpus[gpu].iter().map(|a| a.resources).sum()
    }

    /// Find a workload's (gpu, alloc) — the first replica when several.
    pub fn find(&self, workload: usize) -> Option<(usize, Alloc)> {
        for (g, allocs) in self.gpus.iter().enumerate() {
            if let Some(a) = allocs.iter().find(|a| a.workload == workload) {
                return Some((g, *a));
            }
        }
        None
    }

    /// A workload's replica group: every allocation carrying its id, in
    /// (gpu, position) order.  The j-th entry is replica j; a workload
    /// whose rate exceeds one gpulet gets several, possibly on different
    /// GPUs, each sized for an even share of the arrival rate.
    pub fn replicas(&self, workload: usize) -> Vec<(usize, Alloc)> {
        self.all()
            .filter(|(_, a)| a.workload == workload)
            .map(|(g, a)| (g, *a))
            .collect()
    }

    /// Number of replicas provisioned for a workload (0 if unplaced).
    pub fn replica_count(&self, workload: usize) -> usize {
        self.all().filter(|(_, a)| a.workload == workload).count()
    }

    /// The `PlacedWorkload` view of one device — the **single source of
    /// device views**: placement scoring (`DeviceScorer::from_placed`),
    /// replica validation, plan prediction, and the online planner all
    /// build on this instead of hand-rolling the mapping.
    pub fn placed_device<'a>(
        &self,
        sys: &'a ProfiledSystem,
        specs: &[WorkloadSpec],
        gpu: usize,
    ) -> Vec<PlacedWorkload<'a>> {
        sys.placed_of(specs, &self.gpus[gpu])
    }

    /// All allocations as (gpu, alloc) pairs.
    pub fn all(&self) -> impl Iterator<Item = (usize, &Alloc)> {
        self.gpus
            .iter()
            .enumerate()
            .flat_map(|(g, v)| v.iter().map(move |a| (g, a)))
    }

    /// Structural invariants: every workload placed exactly once
    /// (Constraint 16) and no device over-allocated (Constraint 15).
    pub fn validate(&self, n_workloads: usize, r_max: f64) -> Result<(), String> {
        let mut seen = vec![0usize; n_workloads];
        for (g, allocs) in self.gpus.iter().enumerate() {
            let total: f64 = allocs.iter().map(|a| a.resources).sum();
            if total > r_max + 1e-6 {
                return Err(format!("gpu {g} over-allocated: {total:.3}"));
            }
            for a in allocs {
                if a.workload >= n_workloads {
                    return Err(format!("gpu {g}: unknown workload {}", a.workload));
                }
                if a.resources <= 0.0 {
                    return Err(format!("gpu {g}: w{} has no resources", a.workload));
                }
                if a.batch == 0 {
                    return Err(format!("gpu {g}: w{} has batch 0", a.workload));
                }
                seen[a.workload] += 1;
            }
        }
        for (w, &n) in seen.iter().enumerate() {
            if n == 0 {
                return Err(format!("workload {w} unplaced"));
            }
            // replicated placement (heterogeneous extension) is allowed,
            // but the common case is exactly once
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let gpus: Vec<Json> = self
            .gpus
            .iter()
            .map(|allocs| {
                Json::Arr(
                    allocs
                        .iter()
                        .map(|a| {
                            Json::obj()
                                .set("workload", a.workload)
                                .set("resources", a.resources)
                                .set("batch", a.batch as usize)
                        })
                        .collect(),
                )
            })
            .collect();
        Json::obj()
            .set("strategy", self.strategy.as_str())
            .set("gpu", self.gpu.as_str())
            .set("unit_price", self.unit_price)
            .set("cost_per_hour", self.cost_per_hour())
            .set("gpus", Json::Arr(gpus))
    }
}

/// Shadow-instance migration of one workload's replica group (the paper's
/// Sec. 4.2/5.3 mechanism, generalized): the serving layer warms the `to`
/// replicas up while the current ones keep serving, then switches new
/// arrivals over and drains the old replicas to completion — no request is
/// ever dropped and in-flight work finishes on the old gpulets.
#[derive(Debug, Clone, PartialEq)]
pub struct Migration {
    /// Serving workload id (index into the submitted spec set).
    pub workload: usize,
    /// New replica placement: `(gpu, alloc)` pairs in group order.
    pub to: Vec<(usize, Alloc)>,
}

/// One step of a plan-delta produced by online re-provisioning: what the
/// serving layer must do to realize the planner's new allocation.
#[derive(Debug, Clone, PartialEq)]
pub enum PlanDelta {
    /// Replace the workload's replica group via shadow-instance migration.
    Migrate(Migration),
    /// Adjust a co-resident allocation in place (same gpu, same batch —
    /// an MPS partition resize, no process restart needed).
    Resize {
        workload: usize,
        gpu: usize,
        resources: f64,
    },
}

/// Diff two plans into the serving-layer deltas that turn `old` into
/// `new`.  The plans may index workloads differently (the `OnlinePlanner`
/// assigns a fresh id on every re-add): `old_ids[w]` / `new_ids[w]` map
/// serving workload `w` to its id in each plan.  A workload whose replica
/// set keeps the same `(gpu, batch)` shape gets in-place `Resize` steps
/// for changed partitions; any placement change becomes a `Migrate`.
pub fn diff_plans(old: &Plan, new: &Plan, old_ids: &[usize], new_ids: &[usize]) -> Vec<PlanDelta> {
    assert_eq!(old_ids.len(), new_ids.len());
    let mut out = Vec::new();
    for w in 0..old_ids.len() {
        let o = old.replicas(old_ids[w]);
        let n = new.replicas(new_ids[w]);
        // Two replicas of one workload on the same device cannot be told
        // apart by a (workload, gpu) resize — migrate such groups instead.
        let dup_gpu = n
            .iter()
            .enumerate()
            .any(|(j, (g, _))| n[..j].iter().any(|(g2, _)| g2 == g));
        let same_shape = !dup_gpu
            && o.len() == n.len()
            && o.iter()
                .zip(&n)
                .all(|((og, oa), (ng, na))| og == ng && oa.batch == na.batch);
        if same_shape {
            for ((g, oa), (_, na)) in o.iter().zip(&n) {
                if (oa.resources - na.resources).abs() > 1e-12 {
                    out.push(PlanDelta::Resize {
                        workload: w,
                        gpu: *g,
                        resources: na.resources,
                    });
                }
            }
        } else {
            out.push(PlanDelta::Migrate(Migration {
                workload: w,
                to: n
                    .into_iter()
                    .map(|(g, mut a)| {
                        a.workload = w;
                        (g, a)
                    })
                    .collect(),
            }));
        }
    }
    out
}

/// Bundle of profiled knowledge the strategies work from.
#[derive(Debug, Clone)]
pub struct ProfiledSystem {
    pub hw: HardwareCoeffs,
    /// Coefficients indexed by zoo model.
    pub coeffs: Vec<(Model, WorkloadCoeffs)>,
}

impl ProfiledSystem {
    pub fn coeffs_for(&self, model: Model) -> &WorkloadCoeffs {
        &self
            .coeffs
            .iter()
            .find(|(m, _)| *m == model)
            .expect("model not profiled")
            .1
    }

    /// Build the `PlacedWorkload` view of an allocation list, in
    /// allocation order (predictions are positional).
    pub fn placed_of<'a>(
        &'a self,
        specs: &[WorkloadSpec],
        allocs: &[Alloc],
    ) -> Vec<PlacedWorkload<'a>> {
        allocs
            .iter()
            .map(|a| PlacedWorkload {
                coeffs: self.coeffs_for(specs[a.workload].model),
                batch: a.batch as f64,
                resources: a.resources,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan() -> Plan {
        Plan {
            strategy: "test".into(),
            gpu: "V100".into(),
            unit_price: 3.06,
            gpus: vec![
                vec![
                    Alloc {
                        workload: 0,
                        resources: 0.4,
                        batch: 4,
                    },
                    Alloc {
                        workload: 1,
                        resources: 0.5,
                        batch: 8,
                    },
                ],
                vec![Alloc {
                    workload: 2,
                    resources: 0.9,
                    batch: 2,
                }],
            ],
        }
    }

    #[test]
    fn cost_and_lookup() {
        let p = plan();
        assert_eq!(p.num_gpus(), 2);
        assert!((p.cost_per_hour() - 6.12).abs() < 1e-9);
        assert_eq!(p.find(1).unwrap().0, 0);
        assert_eq!(p.find(2).unwrap().0, 1);
        assert!(p.find(9).is_none());
        assert!((p.allocated(0) - 0.9).abs() < 1e-12);
    }

    #[test]
    fn validate_ok() {
        assert!(plan().validate(3, 1.0).is_ok());
    }

    #[test]
    fn replica_groups() {
        let mut p = plan();
        assert_eq!(p.replica_count(0), 1);
        assert_eq!(p.replica_count(9), 0);
        // add a second replica of workload 2 on GPU 0
        p.gpus[0].push(Alloc {
            workload: 2,
            resources: 0.05,
            batch: 2,
        });
        assert_eq!(p.replica_count(2), 2);
        let reps = p.replicas(2);
        assert_eq!(reps.len(), 2);
        // (gpu, position) order: GPU0's copy precedes GPU1's
        assert_eq!(reps[0].0, 0);
        assert_eq!(reps[1].0, 1);
        assert!((reps[1].1.resources - 0.9).abs() < 1e-12);
        // replicated placement still validates (Constraint 16 allows it)
        assert!(p.validate(3, 1.0).is_ok());
    }

    #[test]
    fn validate_catches_overallocation() {
        let mut p = plan();
        p.gpus[0].push(Alloc {
            workload: 2,
            resources: 0.2,
            batch: 1,
        });
        assert!(p.validate(3, 1.0).unwrap_err().contains("over-allocated"));
    }

    #[test]
    fn validate_catches_unplaced() {
        let p = plan();
        assert!(p.validate(4, 1.0).unwrap_err().contains("unplaced"));
    }

    #[test]
    fn diff_plans_resize_vs_migrate() {
        let old = plan();
        // same shape, grown partition for w1 on gpu 0 -> Resize
        let mut grown = plan();
        grown.gpus[0][1].resources = 0.55;
        let ids = [0, 1, 2];
        let d = diff_plans(&old, &grown, &ids, &ids);
        assert_eq!(
            d,
            vec![PlanDelta::Resize {
                workload: 1,
                gpu: 0,
                resources: 0.55
            }]
        );
        // moved gpu -> Migrate carrying the new placement
        let mut moved = plan();
        let a = moved.gpus[0].remove(1);
        moved.gpus[1].push(a);
        let d = diff_plans(&old, &moved, &ids, &ids);
        assert_eq!(d.len(), 1);
        match &d[0] {
            PlanDelta::Migrate(m) => {
                assert_eq!(m.workload, 1);
                assert_eq!(m.to.len(), 1);
                assert_eq!(m.to[0].0, 1);
                assert_eq!(m.to[0].1.workload, 1);
            }
            other => panic!("expected Migrate, got {other:?}"),
        }
        // batch change also requires a restart -> Migrate
        let mut rebatched = plan();
        rebatched.gpus[1][0].batch = 4;
        let d = diff_plans(&old, &rebatched, &ids, &ids);
        assert!(matches!(&d[0], PlanDelta::Migrate(m) if m.workload == 2));
        // identical plans diff to nothing
        assert!(diff_plans(&old, &plan(), &ids, &ids).is_empty());
    }

    #[test]
    fn diff_plans_translates_renumbered_ids() {
        // The online planner re-ids a workload on every re-add: the diff
        // must follow the id maps and stamp the serving id on the output.
        let old = plan();
        let mut new = plan();
        new.gpus[0][1].workload = 7; // w1 re-added under planner id 7
        new.gpus[0][1].resources = 0.6;
        let d = diff_plans(&old, &new, &[0, 1, 2], &[0, 7, 2]);
        assert_eq!(
            d,
            vec![PlanDelta::Resize {
                workload: 1,
                gpu: 0,
                resources: 0.6
            }]
        );
    }

    #[test]
    fn json_shape() {
        let j = plan().to_json();
        assert_eq!(j.get("strategy").unwrap().as_str(), Some("test"));
        assert_eq!(j.path("gpus.0.1.batch").unwrap().as_usize(), Some(8));
    }

    #[test]
    fn placed_device_mirrors_the_allocation_list() {
        let (hw, wls) = crate::profiler::profile_all(crate::gpu::GpuKind::V100, 42);
        let sys = ProfiledSystem {
            hw,
            coeffs: crate::gpu::ALL_MODELS.iter().cloned().zip(wls).collect(),
        };
        let specs: Vec<WorkloadSpec> = (0..3)
            .map(|i| WorkloadSpec::new(i, Model::ResNet50, 40.0, 100.0))
            .collect();
        let p = plan();
        let view = p.placed_device(&sys, &specs, 0);
        assert_eq!(view.len(), 2);
        for (v, a) in view.iter().zip(&p.gpus[0]) {
            assert_eq!(v.batch, a.batch as f64);
            assert_eq!(v.resources, a.resources);
            assert_eq!(v.coeffs.name, "resnet50");
        }
    }
}
