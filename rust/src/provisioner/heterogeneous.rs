//! Heterogeneous-cluster extension (Sec. 4.1 "Remark" + Fig. 20): run
//! Alg. 1 per GPU type and adopt the cheapest plan.
//!
//! Workloads whose lower bound exceeds a weaker device (`r_lower > r_max`)
//! are **replicated**: the arrival rate is split across k replicas, k
//! chosen minimally so each replica is feasible.  This realizes the
//! paper's "iGniter provisions 2+ g4dn.xlarge instances for W7, W8, W10,
//! and W12" behaviour and its future-work item (2).

use super::igniter;
use super::mig;
use super::partition::PartitionModel;
use super::types::{Plan, ProfiledSystem, WorkloadSpec};
use crate::perfmodel::{self, AnalyticModel, PerfModel};

/// A workload set expanded with replicas; `origin[i]` maps expanded index
/// -> original workload index.
#[derive(Debug, Clone)]
pub struct ReplicatedSpecs {
    pub specs: Vec<WorkloadSpec>,
    pub origin: Vec<usize>,
}

/// Split infeasible workloads into the minimum number of rate-sharing
/// replicas that are individually feasible on this GPU type.
pub fn replicate_for(sys: &ProfiledSystem, specs: &[WorkloadSpec]) -> Option<ReplicatedSpecs> {
    let mut out = ReplicatedSpecs {
        specs: Vec::new(),
        origin: Vec::new(),
    };
    for (w, spec) in specs.iter().enumerate() {
        let wc = sys.coeffs_for(spec.model);
        let mut k = 1usize;
        loop {
            // Even per-replica traffic split (workload::replica_shares);
            // feasibility is checked on the first share — they are equal.
            let shares = crate::workload::replica_shares(spec, k);
            if perfmodel::lower_bound_resources(&sys.hw, wc, shares[0].slo_ms, shares[0].rate_rps)
                .is_some()
            {
                for mut s in shares {
                    s.id = out.specs.len();
                    out.specs.push(s);
                    out.origin.push(w);
                }
                break;
            }
            k += 1;
            if k > igniter::MAX_REPLICAS {
                return None; // infeasible even with MAX_REPLICAS replicas
            }
        }
    }
    Some(out)
}

/// Result of provisioning one GPU type.
#[derive(Debug, Clone)]
pub struct TypedPlan {
    pub plan: Plan,
    pub replicated: ReplicatedSpecs,
}

impl TypedPlan {
    /// Placement items Alg. 1 executed to build this plan (every replica
    /// of every workload) — the per-candidate work unit
    /// `wall.plan_throughput_pps` counts.
    pub fn placements(&self) -> usize {
        self.plan.total_allocs()
    }
}

/// Provision with iGniter on one GPU type, replicating as needed
/// (static analytic scoring).
pub fn provision_on(sys: &ProfiledSystem, specs: &[WorkloadSpec]) -> Option<TypedPlan> {
    provision_on_with(&AnalyticModel::ALL, sys, specs)
}

/// `provision_on` scored by an arbitrary [`PerfModel`].
///
/// Routes by the system's [`PartitionModel`]: continuous gpulets take the
/// Alg.-1 path unchanged; MIG parts take the fragmentation-aware packer
/// (`provisioner::mig`), where the caller's model is irrelevant because
/// hardware isolation collapses scoring to solo predictions.
pub fn provision_on_with(
    model: &dyn PerfModel,
    sys: &ProfiledSystem,
    specs: &[WorkloadSpec],
) -> Option<TypedPlan> {
    let replicated = replicate_for(sys, specs)?;
    let derived = igniter::derive_all(sys, &replicated.specs);
    if derived.iter().any(|d| d.is_none()) {
        return None;
    }
    let plan = match PartitionModel::for_gpu_name(&sys.hw.gpu) {
        PartitionModel::Continuous => {
            igniter::provision_with_derived(model, sys, &replicated.specs, &derived)
        }
        PartitionModel::Mig => mig::provision_mig(sys, &replicated.specs, &derived),
    };
    Some(TypedPlan { plan, replicated })
}

/// MIG head-to-head for the sweep runner: replicate + derive once, then
/// run the fragmentation-aware packer against MIG-FFD and MIG-iGniter on
/// identical demands.  `None` when the workload set is infeasible on this
/// part even with replication.
pub fn provision_mig_head_to_head(
    sys: &ProfiledSystem,
    specs: &[WorkloadSpec],
) -> Option<(TypedPlan, mig::MigHeadToHead)> {
    let replicated = replicate_for(sys, specs)?;
    let derived = igniter::derive_all(sys, &replicated.specs);
    if derived.iter().any(|d| d.is_none()) {
        return None;
    }
    let h2h = mig::head_to_head(sys, &replicated.specs, &derived);
    let plan = h2h.packed.clone();
    Some((TypedPlan { plan, replicated }, h2h))
}

/// Heterogeneous selection: provision on every profiled system and return
/// all candidate plans sorted by hourly cost (cheapest first).
pub fn select_cheapest(
    systems: &[ProfiledSystem],
    specs: &[WorkloadSpec],
) -> Vec<TypedPlan> {
    let mut plans: Vec<TypedPlan> = systems
        .iter()
        .filter_map(|sys| provision_on(sys, specs))
        .collect();
    plans.sort_by(|a, b| {
        a.plan
            .cost_per_hour()
            .partial_cmp(&b.plan.cost_per_hour())
            .unwrap()
    });
    plans
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuKind;
    use crate::workload::app_workloads;

    fn sys(kind: GpuKind) -> ProfiledSystem {
        let (hw, wls) = crate::profiler::profile_all(kind, 42);
        ProfiledSystem {
            hw,
            coeffs: crate::gpu::ALL_MODELS.iter().cloned().zip(wls).collect(),
        }
    }

    #[test]
    fn v100_needs_no_replication() {
        let s = sys(GpuKind::V100);
        let r = replicate_for(&s, &app_workloads()).unwrap();
        assert_eq!(r.specs.len(), 12);
    }

    #[test]
    fn t4_replicates_heavy_workloads() {
        // Fig. 20: W7 / W8(?) / W10 / W12-class workloads need multiple T4s.
        let s = sys(GpuKind::T4);
        let r = replicate_for(&s, &app_workloads()).unwrap();
        assert!(r.specs.len() > 12, "no replication happened");
        // every original workload still covered
        for w in 0..12 {
            assert!(r.origin.contains(&w));
        }
        // total rate preserved per original workload
        let specs = app_workloads();
        for w in 0..12 {
            let total: f64 = r
                .specs
                .iter()
                .zip(&r.origin)
                .filter(|(_, &o)| o == w)
                .map(|(s, _)| s.rate_rps)
                .sum();
            assert!((total - specs[w].rate_rps).abs() < 1e-6);
        }
    }

    #[test]
    fn t4_plan_cheaper_than_v100() {
        // Fig. 20: 15 g4dn.xlarge ($7.89/h) beats 6 p3.2xlarge ($18.36/h).
        let systems = [sys(GpuKind::V100), sys(GpuKind::T4)];
        let plans = select_cheapest(&systems, &app_workloads());
        assert_eq!(plans.len(), 2);
        assert_eq!(plans[0].plan.gpu, "T4", "cheapest should be T4");
        assert!(plans[0].plan.cost_per_hour() < plans[1].plan.cost_per_hour());
        // paper scale: T4 count in the low tens, V100 around 6
        let t4 = plans[0].plan.num_gpus();
        assert!((10..=22).contains(&t4), "T4 count {t4}");
    }

    #[test]
    fn continuous_partition_path_is_a_bitwise_noop() {
        // Satellite contract: routing through PartitionModel must leave
        // V100/T4 plans byte-identical to the direct Alg.-1 call.
        for kind in [GpuKind::V100, GpuKind::T4] {
            let s = sys(kind);
            let specs = app_workloads();
            let routed = provision_on(&s, &specs).unwrap();
            let replicated = replicate_for(&s, &specs).unwrap();
            let derived = igniter::derive_all(&s, &replicated.specs);
            let direct =
                igniter::provision_with_derived(&AnalyticModel::ALL, &s, &replicated.specs, &derived);
            assert_eq!(routed.plan, direct, "{kind:?} plan diverged");
            for (a, b) in routed
                .plan
                .gpus
                .iter()
                .flatten()
                .zip(direct.gpus.iter().flatten())
            {
                assert_eq!(a.resources.to_bits(), b.resources.to_bits());
            }
        }
    }

    #[test]
    fn mig_systems_route_to_the_slice_packer() {
        for kind in [GpuKind::A100, GpuKind::H100] {
            let s = sys(kind);
            let tp = provision_on(&s, &app_workloads()).unwrap();
            assert!(tp.plan.strategy.starts_with("MIG-packed"), "{}", tp.plan.strategy);
            crate::provisioner::partition::plan_is_legal(&tp.plan).unwrap();
            tp.plan
                .validate(tp.replicated.specs.len(), s.hw.r_max)
                .unwrap();
        }
    }

    #[test]
    fn mig_head_to_head_is_consistent_with_routing() {
        let s = sys(GpuKind::A100);
        let specs = app_workloads();
        let (tp, h2h) = provision_mig_head_to_head(&s, &specs).unwrap();
        let routed = provision_on(&s, &specs).unwrap();
        assert_eq!(tp.plan, routed.plan, "head-to-head packed plan diverged");
        assert!(h2h.cost_packed <= h2h.cost_ffd + 1e-9);
        assert!(h2h.cost_packed <= h2h.cost_igniter + 1e-9);
        assert!(h2h.stranded_pct >= 0.0 && h2h.stranded_pct < 100.0);
    }

    #[test]
    fn mig_parts_join_heterogeneous_selection() {
        let systems = [sys(GpuKind::V100), sys(GpuKind::T4), sys(GpuKind::A100)];
        let plans = select_cheapest(&systems, &app_workloads());
        assert_eq!(plans.len(), 3);
        for w in plans.windows(2) {
            assert!(w[0].plan.cost_per_hour() <= w[1].plan.cost_per_hour());
        }
    }

    #[test]
    fn replicated_plans_meet_slos() {
        let s = sys(GpuKind::T4);
        let tp = provision_on(&s, &app_workloads()).unwrap();
        tp.plan
            .validate(tp.replicated.specs.len(), s.hw.r_max)
            .unwrap();
        for (w, t_inf, thpt) in igniter::predict_plan(&s, &tp.replicated.specs, &tp.plan) {
            let spec = &tp.replicated.specs[w];
            assert!(t_inf <= spec.slo_ms / 2.0 + 1e-6, "{} violated", spec.name);
            assert!(thpt >= spec.rate_rps * 0.999);
        }
    }
}
