//! The fleet-scale placement engine: indexed candidate search with
//! admissible pruning and persistent per-device scorer state.
//!
//! Alg. 1 places every item by probing *every* device with a fresh
//! `alloc_gpus` growth loop — O(items × devices × growth) at the `full()`
//! sweep scale.  The engine makes that scan sub-linear in fleet size
//! while producing **bit-identical plans** to the exhaustive reference
//! (`igniter::place_items_linear`), via three mechanisms:
//!
//! 1. **Headroom index** — devices bucketed by quantized free capacity
//!    (`floor((r_max - used) / r_unit)`).  A candidate list for an item
//!    with lower bound `r_lower` gathers every device in buckets
//!    `>= floor((r_lower - 1e-6) / r_unit)`.  The quantization margin
//!    (1e-6 ≫ the accumulated fp error of the in-order sums) makes the
//!    filter a **superset** of the exact check, which is then re-applied
//!    per candidate: `used[g] + r_lower > r_max + 1e-9` — bitwise the
//!    entry reject `alloc_gpus` computes, because `used[g]` is maintained
//!    as the same in-order `Iterator::sum` over the device's allocation
//!    list.  Candidates are visited in ascending device order, so the
//!    sequential best-so-far comparisons (whose `1e-12` epsilon is not
//!    transitive) replay the exhaustive scan's exact decision sequence.
//!
//! 2. **Persistent scorer state** — each device carries its residents'
//!    cached `cache_util`/`power_w` contributions and the in-order
//!    aggregate sums (the exact values `DeviceScorer::resum` produces).
//!    A probe seeds its growth scorer through
//!    [`DeviceScorer::from_cached`] with zero coefficient-law
//!    evaluations; the state is refreshed once per adopted mutation
//!    (`sync_device`), not once per probe.
//!
//! 3. **Admissible pruning** — the min-`r_inter` objective is a sum of
//!    non-negative `r_unit` growth steps, so exact lower bounds are
//!    cheap:
//!    * `r_inter == 0.0` exactly when the first growth pass finds no
//!      violator (identical floats subtract to exactly `+0.0`), in which
//!      case the probe's answer **is** residents + item at `r_lower` —
//!      no growth loop runs at all;
//!    * once the running best is `0.0`, no later device can satisfy
//!      `r_inter < best - 1e-12` (r_inter ≥ 0), so the scan stops;
//!    * a first-pass violator count `v ≥ 1` proves
//!      `r_inter ≥ v·r_unit - 1e-9` (each violator grows by at least one
//!      `r_unit` step; the 1e-9 slack dominates every accumulated
//!      rounding term), so a device with
//!      `v·r_unit - 1e-9 ≥ best - 1e-12` is skipped — it could never
//!      have updated `best`, hence every later comparison is unchanged.
//!
//!    The first pass itself runs on the persistent aggregates with the
//!    same expressions the growth loop's pass 1 evaluates, so the
//!    violator count is derived from bit-identical predictions.
//!
//! The differential property tests below pin every step of an
//! incremental placement run against the retained linear reference.

use super::igniter::{self, Derived};
use super::types::{Alloc, Plan, ProfiledSystem, WorkloadSpec};
use crate::perfmodel::model::{self, PlacedWorkload};
use crate::perfmodel::{DeviceScorer, HardwareCoeffs, PerfModel};

/// One resident allocation with its cached interference contributions —
/// the lifetime-free mirror of a `ScoredSlot` (the planner owns its
/// `ProfiledSystem`, so the engine cannot hold borrowed coefficients).
#[derive(Debug, Clone, Copy)]
struct SlotCache {
    workload: usize,
    batch: u32,
    resources: f64,
    /// Cached `coeffs.cache_util(batch, resources)`.
    cache_util: f64,
    /// Cached `coeffs.power_w(batch, resources)` (W above idle).
    power_w: f64,
}

impl SlotCache {
    fn of(sys: &ProfiledSystem, specs: &[WorkloadSpec], a: &Alloc) -> SlotCache {
        let wc = sys.coeffs_for(specs[a.workload].model);
        SlotCache {
            workload: a.workload,
            batch: a.batch,
            resources: a.resources,
            cache_util: wc.cache_util(a.batch as f64, a.resources),
            power_w: wc.power_w(a.batch as f64, a.resources),
        }
    }

    fn alloc(&self) -> Alloc {
        Alloc {
            workload: self.workload,
            resources: self.resources,
            batch: self.batch,
        }
    }
}

/// Persistent per-device scorer state: the residents' cached
/// contributions plus the in-order aggregates a fresh
/// `DeviceScorer::from_placed` would compute.
#[derive(Debug, Clone, Default)]
struct DeviceState {
    slots: Vec<SlotCache>,
    /// In-order Σ resources — bitwise the entry total `alloc_gpus` sums.
    used: f64,
    /// In-order Σ cache-util over residents (`DeviceScorer::resum`).
    sum_cache: f64,
    /// In-order Σ per-process power over residents (W above idle).
    sum_power: f64,
}

/// Bucketed free-capacity index: `buckets[k]` holds the devices whose
/// quantized free capacity is `k` allocation units.  Conservative by
/// construction — every device passing the exact headroom check is in a
/// bucket `>= need_bucket(r_lower)`; extra candidates are re-filtered by
/// the exact check, so the index can speed the scan up but never change
/// its outcome.
#[derive(Debug, Clone)]
struct HeadroomIndex {
    r_unit: f64,
    r_max: f64,
    buckets: Vec<Vec<u32>>,
    /// Device id -> its current bucket.
    bucket_of: Vec<u32>,
}

impl HeadroomIndex {
    fn new(hw: &HardwareCoeffs) -> HeadroomIndex {
        // floor(r_max / r_unit) whole units of capacity, +1 for bucket 0.
        let top = (hw.r_max / hw.r_unit + 1e-9).floor() as usize;
        HeadroomIndex {
            r_unit: hw.r_unit,
            r_max: hw.r_max,
            buckets: vec![Vec::new(); top + 1],
            bucket_of: Vec::new(),
        }
    }

    /// Quantized free capacity of a device with `used` allocated.  The
    /// `+1e-9` slack keeps a device that passes the exact float check
    /// from being rounded down out of its bucket.
    fn free_bucket(&self, used: f64) -> usize {
        let q = ((self.r_max - used) / self.r_unit + 1e-9).floor();
        if q <= 0.0 {
            0
        } else {
            (q as usize).min(self.buckets.len() - 1)
        }
    }

    /// Lowest bucket that can possibly host an item needing `r_lower`.
    /// The 1e-6 margin under-quantizes the demand, so this is always
    /// `<= free_bucket` of any device the exact check accepts.
    fn need_bucket(&self, r_lower: f64) -> usize {
        let q = ((r_lower - 1e-6) / self.r_unit).floor();
        if q <= 0.0 {
            0
        } else {
            (q as usize).min(self.buckets.len() - 1)
        }
    }

    fn push(&mut self, used: f64) {
        let g = self.bucket_of.len() as u32;
        let b = self.free_bucket(used);
        self.buckets[b].push(g);
        self.bucket_of.push(b as u32);
    }

    fn update(&mut self, g: usize, used: f64) {
        let b = self.free_bucket(used);
        let old = self.bucket_of[g] as usize;
        if old == b {
            return;
        }
        let v = &mut self.buckets[old];
        let pos = v
            .iter()
            .position(|&x| x == g as u32)
            .expect("device present in its recorded bucket");
        v.swap_remove(pos);
        self.buckets[b].push(g as u32);
        self.bucket_of[g] = b as u32;
    }

    /// Gather the candidate superset for an item needing `r_lower`, in
    /// ascending device order (the scan order the linear reference uses).
    fn candidates(&self, r_lower: f64, out: &mut Vec<u32>) {
        out.clear();
        for b in &self.buckets[self.need_bucket(r_lower)..] {
            out.extend_from_slice(b);
        }
        out.sort_unstable();
    }

    fn clear(&mut self) {
        for b in &mut self.buckets {
            b.clear();
        }
        self.bucket_of.clear();
    }
}

/// The indexed, pruning min-interference placement engine.  Owned by the
/// offline `place_items` pass (one per provisioning run) and by the
/// `OnlinePlanner` (persistent across every `place`/`remove`/`respec`/
/// `rebalance`); its device mirror must be kept in sync with the plan it
/// places into — `place` does so itself, external plan mutations call
/// `sync_device`/`rebuild`.
#[derive(Debug, Clone)]
pub struct PlacementEngine {
    devices: Vec<DeviceState>,
    index: HeadroomIndex,
    /// Failed devices (fault injection): excluded from every candidate
    /// scan, so replacements land on survivors or fresh capacity.  Kept
    /// positionally aligned with `devices` and preserved across
    /// `rebuild` — device ids are stable for the life of a plan.
    dead: Vec<bool>,
    // Probe scratch, reused across all (item, device) probes.
    cand_ids: Vec<u32>,
    cand_alloc: Vec<Alloc>,
    best_alloc: Vec<Alloc>,
}

impl PlacementEngine {
    /// An engine over an empty fleet.
    pub fn new(hw: &HardwareCoeffs) -> PlacementEngine {
        PlacementEngine {
            devices: Vec::new(),
            index: HeadroomIndex::new(hw),
            dead: Vec::new(),
            cand_ids: Vec::new(),
            cand_alloc: Vec::new(),
            best_alloc: Vec::new(),
        }
    }

    /// Exclude device `g` from all future placements (its freed capacity
    /// must never look attractive to the failover re-plan).
    pub fn mark_dead(&mut self, g: usize) {
        self.dead[g] = true;
    }

    pub fn is_dead(&self, g: usize) -> bool {
        self.dead.get(g).copied().unwrap_or(false)
    }

    pub fn any_dead(&self) -> bool {
        self.dead.iter().any(|&d| d)
    }

    /// An engine mirroring an existing plan.
    pub fn from_plan(sys: &ProfiledSystem, specs: &[WorkloadSpec], plan: &Plan) -> PlacementEngine {
        let mut e = PlacementEngine::new(&sys.hw);
        for g in &plan.gpus {
            e.push_device(sys, specs, g);
        }
        e
    }

    pub fn num_devices(&self) -> usize {
        self.devices.len()
    }

    /// Re-mirror every device of `plan` (used after wholesale plan
    /// replacement: rebalance adoption, respec rollback).
    pub fn rebuild(&mut self, sys: &ProfiledSystem, specs: &[WorkloadSpec], plan: &Plan) {
        self.devices.truncate(plan.gpus.len());
        self.index.clear();
        // device ids are stable, so existing dead flags stay positional;
        // grown (or shrunk) fleets default the delta to alive
        self.dead.resize(plan.gpus.len(), false);
        for (g, allocs) in plan.gpus.iter().enumerate() {
            if g < self.devices.len() {
                Self::refresh(&mut self.devices[g], sys, specs, allocs);
                self.index.push(self.devices[g].used);
            } else {
                self.push_device(sys, specs, allocs);
            }
        }
    }

    /// Append a device holding `allocs`.
    pub fn push_device(&mut self, sys: &ProfiledSystem, specs: &[WorkloadSpec], allocs: &[Alloc]) {
        let mut dev = DeviceState::default();
        Self::refresh(&mut dev, sys, specs, allocs);
        self.index.push(dev.used);
        self.devices.push(dev);
        self.dead.resize(self.devices.len(), false);
    }

    /// Re-mirror device `g` after its allocation list changed.
    pub fn sync_device(
        &mut self,
        g: usize,
        sys: &ProfiledSystem,
        specs: &[WorkloadSpec],
        allocs: &[Alloc],
    ) {
        Self::refresh(&mut self.devices[g], sys, specs, allocs);
        self.index.update(g, self.devices[g].used);
    }

    fn refresh(dev: &mut DeviceState, sys: &ProfiledSystem, specs: &[WorkloadSpec], allocs: &[Alloc]) {
        // Reuse cached contributions for slots the mutation left alone
        // (alloc_gpus preserves order, so unchanged residents stay
        // positionally aligned); recompute only what moved.
        for (i, a) in allocs.iter().enumerate() {
            let reusable = dev.slots.get(i).is_some_and(|s| {
                s.workload == a.workload && s.batch == a.batch && s.resources == a.resources
            });
            if !reusable {
                let sc = SlotCache::of(sys, specs, a);
                if i < dev.slots.len() {
                    dev.slots[i] = sc;
                } else {
                    dev.slots.push(sc);
                }
            }
        }
        dev.slots.truncate(allocs.len());
        // In-order sums — bitwise what alloc_gpus' entry total and
        // DeviceScorer::resum would compute over this list.
        dev.used = allocs.iter().map(|a| a.resources).sum();
        dev.sum_cache = dev.slots.iter().map(|s| s.cache_util).sum();
        dev.sum_power = dev.slots.iter().map(|s| s.power_w).sum();
    }

    /// The pruned min-`r_inter` scan: returns the chosen device and its
    /// `r_inter` (the winning allocation is left in `self.best_alloc`),
    /// or `None` when no existing device can host the item.  Decision-
    /// equivalent, bit for bit, to the exhaustive scan over all devices.
    fn search(
        &mut self,
        pmodel: &dyn PerfModel,
        sys: &ProfiledSystem,
        specs: &[WorkloadSpec],
        w: usize,
        d: Derived,
    ) -> Option<(usize, f64)> {
        let hw = &sys.hw;
        let terms = pmodel.terms();
        let item_wc = sys.coeffs_for(specs[w].model);
        // The item's contributions at its lower bound, computed once per
        // item instead of once per probed device.
        let item = SlotCache {
            workload: w,
            batch: d.batch,
            resources: d.r_lower,
            cache_util: item_wc.cache_util(d.batch as f64, d.r_lower),
            power_w: item_wc.power_w(d.batch as f64, d.r_lower),
        };

        let mut cand_ids = std::mem::take(&mut self.cand_ids);
        let mut cand = std::mem::take(&mut self.cand_alloc);
        let mut best_alloc = std::mem::take(&mut self.best_alloc);
        self.index.candidates(d.r_lower, &mut cand_ids);

        let mut best: Option<(usize, f64)> = None;
        for &gu in &cand_ids {
            let g = gu as usize;
            // A dead device's emptied capacity is not capacity.
            if self.dead[g] {
                continue;
            }
            let dev = &self.devices[g];
            // Exact headroom check — bitwise the reject alloc_gpus hits.
            if dev.used + d.r_lower > hw.r_max + 1e-9 {
                continue;
            }
            if let Some((_, b)) = best {
                // r_inter is a sum of non-negative growth steps: a
                // zero-interference best cannot be beaten, stop probing.
                if b == 0.0 {
                    break;
                }
            }

            // First growth pass over the persistent aggregates: the same
            // predictions pass 1 of grow_allocs would make, so the
            // violator count is exact.
            let m = dev.slots.len() + 1;
            let sum_cache = dev.sum_cache + item.cache_util;
            let demand_w = hw.idle_power_w + (dev.sum_power + item.power_w);
            let mut violators = 0usize;
            for s in dev.slots.iter().chain(std::iter::once(&item)) {
                let coeffs = sys.coeffs_for(specs[s.workload].model);
                let placed = PlacedWorkload {
                    coeffs,
                    batch: s.batch as f64,
                    resources: s.resources,
                };
                let others_util = if terms.cache {
                    sum_cache - s.cache_util
                } else {
                    0.0
                };
                let pred = pmodel.correct(
                    &coeffs.name,
                    model::predict_core(hw, &placed, m, others_util, demand_w, terms),
                );
                if pred.t_inf > specs[s.workload].slo_ms / 2.0 + 1e-9 {
                    violators += 1;
                }
            }

            if violators == 0 {
                // Zero growth: the probe IS the final allocation
                // (residents + item at r_lower) and r_inter == 0.0
                // exactly — identical floats subtract to +0.0.
                cand.clear();
                cand.extend(dev.slots.iter().map(SlotCache::alloc));
                cand.push(item.alloc());
                let r_inter = 0.0;
                if best.map_or(true, |(_, b)| r_inter < b - 1e-12) {
                    best = Some((g, r_inter));
                    std::mem::swap(&mut best_alloc, &mut cand);
                }
                continue;
            }
            if let Some((_, b)) = best {
                // Admissible prune: this device's r_inter (if its growth
                // even succeeds) is provably >= violators*r_unit - 1e-9,
                // so it can never pass the `< best - 1e-12` update rule.
                if violators as f64 * hw.r_unit - 1e-9 >= b - 1e-12 {
                    continue;
                }
            }

            // Full growth, seeded from the cached contributions (no
            // coefficient-law evaluations before the first resize).
            cand.clear();
            cand.extend(dev.slots.iter().map(SlotCache::alloc));
            cand.push(item.alloc());
            let mut scorer = DeviceScorer::from_cached(
                hw,
                dev.slots.iter().chain(std::iter::once(&item)).map(|s| {
                    (
                        PlacedWorkload {
                            coeffs: sys.coeffs_for(specs[s.workload].model),
                            batch: s.batch as f64,
                            resources: s.resources,
                        },
                        s.cache_util,
                        s.power_w,
                    )
                }),
            );
            if igniter::grow_allocs(pmodel, hw, specs, &mut scorer, &mut cand) {
                // Positional r_inter, exactly as the linear scan sums it.
                let mut r_inter = 0.0;
                for (i, a) in cand.iter().enumerate() {
                    let before = if i < dev.slots.len() {
                        dev.slots[i].resources
                    } else {
                        d.r_lower
                    };
                    r_inter += a.resources - before;
                }
                if best.map_or(true, |(_, b)| r_inter < b - 1e-12) {
                    best = Some((g, r_inter));
                    std::mem::swap(&mut best_alloc, &mut cand);
                }
            }
        }
        self.cand_ids = cand_ids;
        self.cand_alloc = cand;
        self.best_alloc = best_alloc;
        best
    }

    /// Alg. 1's inner step for one item: place `(w, d)` on the device
    /// with minimum increased-interference resources, mutating `plan`
    /// (and the engine mirror) — provisioning a fresh device when no
    /// existing one fits.  Returns `(device, provisioned_fresh)`.
    pub fn place(
        &mut self,
        pmodel: &dyn PerfModel,
        sys: &ProfiledSystem,
        specs: &[WorkloadSpec],
        plan: &mut Plan,
        w: usize,
        d: Derived,
    ) -> (usize, bool) {
        match self.search(pmodel, sys, specs, w, d) {
            Some((g, _)) => {
                plan.gpus[g].clone_from(&self.best_alloc);
                self.sync_device(g, sys, specs, &plan.gpus[g]);
                (g, false)
            }
            None => {
                // Fresh device (Alg. 1 lines 13-15), still through the
                // growth loop: a calibrated model may grow the lone item
                // past its analytic bound; when even the full device
                // cannot meet the corrected bound, the best effort is
                // the FULL device (see igniter::place_items_linear).
                let mut cand = std::mem::take(&mut self.cand_alloc);
                let ok = igniter::alloc_gpus_into(
                    pmodel, sys, specs, &[], w, d.r_lower, d.batch, &mut cand,
                );
                if !ok {
                    cand.clear();
                    cand.push(Alloc {
                        workload: w,
                        resources: sys.hw.r_max,
                        batch: d.batch,
                    });
                }
                plan.gpus.push(cand.clone());
                self.cand_alloc = cand;
                let g = plan.gpus.len() - 1;
                self.push_device(sys, specs, &plan.gpus[g]);
                (g, true)
            }
        }
    }

    /// Discrete-partition placement (MIG): place `(w, d)` — whose
    /// `r_lower` is already slice-quantized — without any growth loop,
    /// since MIG slices are hardware-isolated and residents never grow
    /// when a neighbor arrives.  Candidate devices come from the same
    /// headroom index Alg. 1 uses: a free-GPC count is exactly a
    /// quantized-headroom bucket when `r_unit` is one GPC.
    ///
    /// `best_fit = true` is the fragmentation-aware rule (ParvaGPU's
    /// objective): among fitting devices, minimize the residual free
    /// capacity after placement, ties to the lowest device id.
    /// `best_fit = false` is plain first-fit (candidates are scanned in
    /// ascending device order).  Returns `(device, provisioned_fresh)`.
    pub fn place_discrete(
        &mut self,
        sys: &ProfiledSystem,
        specs: &[WorkloadSpec],
        plan: &mut Plan,
        w: usize,
        d: Derived,
        best_fit: bool,
    ) -> (usize, bool) {
        let hw = &sys.hw;
        let mut cand_ids = std::mem::take(&mut self.cand_ids);
        self.index.candidates(d.r_lower, &mut cand_ids);
        let mut best: Option<(usize, f64)> = None;
        for &gu in &cand_ids {
            let g = gu as usize;
            if self.dead[g] {
                continue;
            }
            let dev = &self.devices[g];
            // Exact re-check behind the conservative bucket filter.
            if dev.used + d.r_lower > hw.r_max + 1e-9 {
                continue;
            }
            if !best_fit {
                best = Some((g, 0.0));
                break;
            }
            let residual = hw.r_max - dev.used - d.r_lower;
            if best.map_or(true, |(_, b)| residual < b - 1e-12) {
                best = Some((g, residual));
            }
        }
        self.cand_ids = cand_ids;
        let alloc = Alloc {
            workload: w,
            resources: d.r_lower,
            batch: d.batch,
        };
        match best {
            Some((g, _)) => {
                plan.gpus[g].push(alloc);
                self.sync_device(g, sys, specs, &plan.gpus[g]);
                (g, false)
            }
            None => {
                plan.gpus.push(vec![alloc]);
                let g = plan.gpus.len() - 1;
                self.push_device(sys, specs, &plan.gpus[g]);
                (g, true)
            }
        }
    }

    /// Engine-state consistency check for tests: the mirror must match a
    /// from-scratch rebuild of `plan` bit for bit.
    #[cfg(test)]
    fn assert_mirrors(&self, sys: &ProfiledSystem, specs: &[WorkloadSpec], plan: &Plan) {
        assert_eq!(self.devices.len(), plan.gpus.len(), "device count drift");
        for (g, allocs) in plan.gpus.iter().enumerate() {
            let dev = &self.devices[g];
            assert_eq!(dev.slots.len(), allocs.len(), "gpu {g} slot drift");
            let mut fresh = DeviceState::default();
            Self::refresh(&mut fresh, sys, specs, allocs);
            assert_eq!(dev.used.to_bits(), fresh.used.to_bits(), "gpu {g} used");
            assert_eq!(dev.sum_cache.to_bits(), fresh.sum_cache.to_bits());
            assert_eq!(dev.sum_power.to_bits(), fresh.sum_power.to_bits());
            for (s, f) in dev.slots.iter().zip(&fresh.slots) {
                assert_eq!(s.cache_util.to_bits(), f.cache_util.to_bits());
                assert_eq!(s.power_w.to_bits(), f.power_w.to_bits());
            }
            // bucket membership is consistent
            let b = self.index.bucket_of[g] as usize;
            assert!(
                self.index.buckets[b].contains(&(g as u32)),
                "gpu {g} missing from bucket {b}"
            );
            assert_eq!(b, self.index.free_bucket(dev.used), "gpu {g} stale bucket");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuKind;
    use crate::perfmodel::AnalyticModel;
    use crate::util::quick::forall;
    use crate::util::rng::Rng;
    use crate::workload::synthetic_workloads;

    fn sys(kind: GpuKind) -> ProfiledSystem {
        let (hw, wls) = crate::profiler::profile_all(kind, 42);
        ProfiledSystem {
            hw,
            coeffs: crate::gpu::ALL_MODELS.iter().cloned().zip(wls).collect(),
        }
    }

    fn plans_equal_bitwise(a: &Plan, b: &Plan) -> Result<(), String> {
        if a.gpus.len() != b.gpus.len() {
            return Err(format!("gpu count {} != {}", a.gpus.len(), b.gpus.len()));
        }
        for (g, (ga, gb)) in a.gpus.iter().zip(&b.gpus).enumerate() {
            if ga.len() != gb.len() {
                return Err(format!("gpu {g}: {} vs {} allocs", ga.len(), gb.len()));
            }
            for (i, (x, y)) in ga.iter().zip(gb).enumerate() {
                if x.workload != y.workload
                    || x.batch != y.batch
                    || x.resources.to_bits() != y.resources.to_bits()
                {
                    return Err(format!("gpu {g} slot {i}: {x:?} != {y:?}"));
                }
            }
        }
        Ok(())
    }

    /// The tentpole differential property: every step of an incremental
    /// engine-driven placement run — including the maintained index and
    /// persistent aggregates — must pick the same device with the same
    /// grown allocation as the exhaustive linear reference.
    #[test]
    fn stepwise_search_matches_linear_reference_bitwise() {
        for kind in [GpuKind::V100, GpuKind::T4] {
            let s = sys(kind);
            forall(
                1042,
                12,
                |r: &mut Rng| (r.next_u64(), 8 + r.below(25) as usize),
                |&(seed, n)| {
                    let specs: Vec<WorkloadSpec> = synthetic_workloads(n, seed)
                        .into_iter()
                        // clamp to rates feasible without replication on
                        // this GPU type so every item derives
                        .map(|mut w| {
                            w.rate_rps = w.rate_rps.min(120.0);
                            w.slo_ms = w.slo_ms.max(40.0);
                            w
                        })
                        .collect();
                    let derived = igniter::derive_all(&s, &specs);
                    let mut plan = Plan::new("diff", &s.hw);
                    plan.gpus.push(Vec::new());
                    let mut engine = PlacementEngine::new(&s.hw);
                    engine.push_device(&s, &specs, &[]);
                    let model = AnalyticModel::ALL;
                    for (w, d) in derived.iter().enumerate() {
                        let Some(d) = *d else { continue };
                        // linear reference decision over the same state
                        let lin = igniter::find_best_linear(&model, &s, &specs, &plan.gpus, w, d);
                        let got = engine.search(&model, &s, &specs, w, d);
                        match (&lin, &got) {
                            (None, None) => {}
                            (Some((lg, la, lr)), Some((eg, er))) => {
                                if lg != eg {
                                    return Err(format!("w{w}: device {lg} vs {eg}"));
                                }
                                if lr.to_bits() != er.to_bits() {
                                    return Err(format!("w{w}: r_inter {lr} vs {er}"));
                                }
                                if la != &engine.best_alloc {
                                    return Err(format!(
                                        "w{w}: alloc {la:?} vs {:?}",
                                        engine.best_alloc
                                    ));
                                }
                            }
                            _ => return Err(format!("w{w}: {lin:?} vs {got:?}")),
                        }
                        // adopt through the engine so the next step
                        // exercises the incremental maintenance
                        engine.place(&model, &s, &specs, &mut plan, w, d);
                        engine.assert_mirrors(&s, &specs, &plan);
                    }
                    Ok(())
                },
            );
        }
    }

    #[test]
    fn headroom_index_is_a_superset_filter() {
        // Whatever the bucket layout, every device passing the exact
        // check must appear in the candidate list.
        let s = sys(GpuKind::V100);
        forall(
            7,
            40,
            |r: &mut Rng| {
                let n = 1 + r.below(12) as usize;
                (0..n).map(|_| r.range_f64(0.0, 1.0)).collect::<Vec<f64>>()
            },
            |useds| {
                let mut idx = HeadroomIndex::new(&s.hw);
                for &u in useds {
                    idx.push(u);
                }
                let mut out = Vec::new();
                for r_lower in [0.05, 0.1, 0.25, 0.5, 0.9, 1.0] {
                    idx.candidates(r_lower, &mut out);
                    for (g, &u) in useds.iter().enumerate() {
                        let passes = u + r_lower <= s.hw.r_max + 1e-9;
                        if passes && !out.contains(&(g as u32)) {
                            return Err(format!(
                                "device {g} (used {u}) missing for r_lower {r_lower}"
                            ));
                        }
                    }
                    // ascending order — the linear scan's decision order
                    if !out.windows(2).all(|w| w[0] < w[1]) {
                        return Err(format!("candidates not ascending: {out:?}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn index_updates_track_mutations() {
        let s = sys(GpuKind::V100);
        let mut idx = HeadroomIndex::new(&s.hw);
        idx.push(0.0);
        idx.push(0.95);
        let mut out = Vec::new();
        idx.candidates(0.5, &mut out);
        assert_eq!(out, vec![0]);
        idx.update(0, 0.9); // device 0 fills up
        idx.update(1, 0.1); // device 1 drains
        idx.candidates(0.5, &mut out);
        assert_eq!(out, vec![1]);
        // no-op update keeps membership intact
        idx.update(1, 0.1);
        idx.candidates(0.5, &mut out);
        assert_eq!(out, vec![1]);
    }

    #[test]
    fn dead_devices_are_never_placement_candidates() {
        let s = sys(GpuKind::V100);
        let specs = crate::workload::app_workloads();
        let derived = igniter::derive_all(&s, &specs);
        let model = AnalyticModel::ALL;
        let mut plan = Plan::new("dead", &s.hw);
        plan.gpus.push(Vec::new());
        let mut engine = PlacementEngine::new(&s.hw);
        engine.push_device(&s, &specs, &[]);
        // kill the (empty, maximally attractive) device 0
        engine.mark_dead(0);
        assert!(engine.any_dead());
        let d = derived[0].expect("workload 0 derives");
        let (g, fresh) = engine.place(&model, &s, &specs, &mut plan, 0, d);
        assert_ne!(g, 0, "placed onto the dead device");
        assert!(fresh, "no live device existed — must provision fresh");
        // subsequent placements keep avoiding the dead device too
        let d1 = derived[1].expect("workload 1 derives");
        let (g1, _) = engine.place(&model, &s, &specs, &mut plan, 1, d1);
        assert_ne!(g1, 0);
        // a rebuild over the same plan preserves the dead flag
        engine.rebuild(&s, &specs, &plan);
        assert!(engine.is_dead(0) && !engine.is_dead(g));
        engine.assert_mirrors(&s, &specs, &plan);
    }

    #[test]
    fn offline_provision_is_bitwise_the_linear_reference() {
        // End-to-end: the engine-backed provision equals the retained
        // linear implementation on the paper's 12-workload set.
        let s = sys(GpuKind::V100);
        let specs = crate::workload::app_workloads();
        let a = igniter::provision_with(&AnalyticModel::ALL, &s, &specs);
        let b = igniter::provision_with_linear(&AnalyticModel::ALL, &s, &specs);
        plans_equal_bitwise(&a, &b).unwrap();
    }
}
