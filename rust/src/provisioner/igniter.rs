//! The iGniter provisioning strategy: Algorithm 1 (workload placement with
//! minimum interference growth) and Algorithm 2 (`alloc_gpus`, iterative
//! GPU resource reallocation until every resident workload meets half its
//! SLO under the predicted interference).
//!
//! Workloads whose rate exceeds what a single gpulet can sustain at full
//! resources are split into the minimum number of even rate-sharing
//! **replicas** (`replica_split`), each placed independently — the plan
//! then carries several allocations under one workload id (see
//! `Plan::replicas`), and `validate_replica_shares` checks every replica's
//! predicted latency/throughput against its share of the traffic.

use super::engine::PlacementEngine;
use super::types::{Alloc, Plan, ProfiledSystem, WorkloadSpec};
use crate::gpu::Model;
use crate::perfmodel::{self, AnalyticModel, DeviceScorer, PerfModel};
use crate::workload::replica_shares;

/// Replication cap: a workload needing more than this many gpulets is
/// treated as infeasible (matches `heterogeneous::replicate_for`).
pub const MAX_REPLICAS: usize = 16;

/// Per-workload derived quantities (Theorem 1).
#[derive(Debug, Clone, Copy)]
pub struct Derived {
    pub batch: u32,
    pub r_lower: f64,
}

/// Compute (b_appr, r_lower) for each workload; `None` entries are
/// infeasible on this GPU type at full resources (heterogeneous clusters
/// handle them by replication — see `heterogeneous.rs`).
pub fn derive_all(sys: &ProfiledSystem, specs: &[WorkloadSpec]) -> Vec<Option<Derived>> {
    specs
        .iter()
        .map(|w| {
            perfmodel::lower_bound_resources(&sys.hw, sys.coeffs_for(w.model), w.slo_ms, w.rate_rps)
                .map(|(batch, r_lower)| Derived { batch, r_lower })
        })
        .collect()
}

/// Algorithm 2: place workload `w` (with lower bound `r_lower_w` and batch
/// `batch_w`) onto the device currently holding `resident`, then reallocate
/// until every workload on the device satisfies `t_inf <= T_slo / 2` under
/// `model`'s prediction, or the device runs out of resources.
///
/// Scoring goes through an incremental [`DeviceScorer`]: the device
/// aggregates are built once and updated in step with the grown
/// allocations, so each growth pass costs O(m) instead of the old O(m²)
/// rebuild-and-resum per resident.  The scorer's analytic output is
/// bit-identical to the full recomputation; `model.correct` then applies
/// any calibrated residual on top.
///
/// Returns the post-placement allocations (including `w` last) or `None`
/// if the device cannot host the workload.
pub fn alloc_gpus(
    model: &dyn PerfModel,
    sys: &ProfiledSystem,
    specs: &[WorkloadSpec],
    resident: &[Alloc],
    w: usize,
    r_lower_w: f64,
    batch_w: u32,
) -> Option<Vec<Alloc>> {
    let mut allocs = Vec::new();
    alloc_gpus_into(model, sys, specs, resident, w, r_lower_w, batch_w, &mut allocs)
        .then_some(allocs)
}

/// Allocation-reusing core of [`alloc_gpus`]: writes the post-placement
/// allocations into `out` (cleared first) and returns whether the device
/// can host the workload.  `out` keeps its capacity across calls, so the
/// online planner's candidate scans stop allocating a fresh `Vec` per
/// (device, target) probe.  On `false`, `out`'s contents are unspecified.
#[allow(clippy::too_many_arguments)]
pub fn alloc_gpus_into(
    model: &dyn PerfModel,
    sys: &ProfiledSystem,
    specs: &[WorkloadSpec],
    resident: &[Alloc],
    w: usize,
    r_lower_w: f64,
    batch_w: u32,
    out: &mut Vec<Alloc>,
) -> bool {
    let hw = &sys.hw;
    let allocs = out;
    allocs.clear();
    allocs.extend_from_slice(resident);
    allocs.push(Alloc {
        workload: w,
        resources: r_lower_w,
        batch: batch_w,
    });

    let total = |a: &[Alloc]| -> f64 { a.iter().map(|x| x.resources).sum() };
    if total(allocs) > hw.r_max + 1e-9 {
        return false;
    }

    // Iteratively grow SLO-violating workloads by r_unit (lines 2-11).
    let mut scorer = DeviceScorer::from_placed(hw, sys.placed_of(specs, allocs));
    grow_allocs(model, hw, specs, &mut scorer, allocs)
}

/// Algorithm 2's growth loop (lines 2-11), factored out so the placement
/// engine can run it over a scorer seeded from cached contributions
/// ([`DeviceScorer::from_cached`]) instead of a fresh `from_placed`
/// rebuild.  `scorer` must mirror `allocs` slot for slot on entry.
/// Returns whether the device hosts the set (the same contract as
/// [`alloc_gpus_into`] after its entry check).
pub(crate) fn grow_allocs(
    model: &dyn PerfModel,
    hw: &crate::perfmodel::HardwareCoeffs,
    specs: &[WorkloadSpec],
    scorer: &mut DeviceScorer,
    allocs: &mut Vec<Alloc>,
) -> bool {
    let total = |a: &[Alloc]| -> f64 { a.iter().map(|x| x.resources).sum() };
    let terms = model.terms();
    let mut flag = true;
    while flag {
        flag = false;
        let mut grow: Vec<usize> = Vec::new();
        for (i, a) in allocs.iter().enumerate() {
            let coeffs = scorer.placed(i).coeffs;
            let pred = model.correct(&coeffs.name, scorer.predict_with(i, terms));
            if pred.t_inf > specs[a.workload].slo_ms / 2.0 + 1e-9 {
                grow.push(i);
            }
        }
        for i in grow {
            allocs[i].resources += hw.r_unit;
            scorer.set_resources(i, allocs[i].resources);
            flag = true;
        }
        if total(allocs) > hw.r_max + 1e-9 {
            return false;
        }
    }
    true
}

/// Minimum replica count `k` (with the per-replica `Derived`) such that an
/// even 1/k rate share of the workload is feasible on this GPU type at
/// full resources.  `None` when even `MAX_REPLICAS` shares stay infeasible
/// (an SLO so tight that `delta <= 0` no amount of replication fixes).
pub fn replica_split(sys: &ProfiledSystem, spec: &WorkloadSpec) -> Option<(usize, Derived)> {
    for k in 1..=MAX_REPLICAS {
        let shares = replica_shares(spec, k);
        let share = &shares[0];
        if let Some((batch, r_lower)) = perfmodel::lower_bound_resources(
            &sys.hw,
            sys.coeffs_for(spec.model),
            share.slo_ms,
            share.rate_rps,
        ) {
            return Some((k, Derived { batch, r_lower }));
        }
    }
    None
}

/// Deterministically find a rate just past what one gpulet of this GPU
/// type can sustain for `(model, slo_ms)`: geometric search upward from
/// `start_rps` until `lower_bound_resources` turns infeasible.  Shared by
/// the replica-validation experiment and the over-capacity tests so the
/// search never diverges between them.
pub fn over_capacity_rate(sys: &ProfiledSystem, model: Model, slo_ms: f64, start_rps: f64) -> f64 {
    let wc = sys.coeffs_for(model);
    let mut rate = start_rps;
    while perfmodel::lower_bound_resources(&sys.hw, wc, slo_ms, rate).is_some() {
        rate *= 1.5;
    }
    rate
}

/// Algorithm 1: the iGniter cost-efficient provisioning strategy, scored
/// by the static analytic model (the paper's configuration).
pub fn provision(sys: &ProfiledSystem, specs: &[WorkloadSpec]) -> Plan {
    provision_with(&AnalyticModel::ALL, sys, specs)
}

/// Algorithm 1 scored by an arbitrary [`PerfModel`] (the online planner
/// re-packs with its — possibly calibrated — model through this).
///
/// Workloads whose `derive` entry is `None` (rate beyond a full gpulet)
/// are split into even rate-sharing replicas and every replica placed
/// independently; panics only when a workload stays infeasible past
/// `MAX_REPLICAS` (i.e. the SLO itself cannot be met at any rate).
pub fn provision_with(model: &dyn PerfModel, sys: &ProfiledSystem, specs: &[WorkloadSpec]) -> Plan {
    let plan = place_items(model, sys, specs, expand_items(sys, specs));
    // Static models must always produce a self-consistently valid plan.
    // A calibrated model is exempt: its corrected SLOs may be genuinely
    // unsatisfiable on this GPU type (that is the *finding*, not a bug),
    // in which case the plan is the best-effort growth.
    if model.observations() == 0 {
        debug_assert!(
            validate_replica_shares(model, sys, specs, &plan).is_ok(),
            "{:?}",
            validate_replica_shares(model, sys, specs, &plan)
        );
    }
    plan
}

/// [`provision_with`] driven by the retained exhaustive device scan
/// (`place_items_linear`) instead of the indexed engine — the bitwise
/// reference the differential tests and the provisioner bench pin the
/// engine against.
pub fn provision_with_linear(
    model: &dyn PerfModel,
    sys: &ProfiledSystem,
    specs: &[WorkloadSpec],
) -> Plan {
    place_items_linear(model, sys, specs, expand_items(sys, specs))
}

/// Expand workloads into placement items: feasible workloads place once;
/// over-capacity workloads split into the minimum even rate-sharing
/// replica count, one item per replica.  Panics only when a workload
/// stays infeasible past `MAX_REPLICAS`.
fn expand_items(sys: &ProfiledSystem, specs: &[WorkloadSpec]) -> Vec<(usize, Derived)> {
    let derived = derive_all(sys, specs);
    let mut items: Vec<(usize, Derived)> = Vec::new();
    for (w, d) in derived.iter().enumerate() {
        match d {
            Some(d) => items.push((w, *d)),
            None => {
                let (k, d) = replica_split(sys, &specs[w]).unwrap_or_else(|| {
                    panic!(
                        "workload {} infeasible on {} even with {MAX_REPLICAS} replicas",
                        specs[w].name, sys.hw.gpu
                    )
                });
                for _ in 0..k {
                    items.push((w, d));
                }
            }
        }
    }
    items
}

/// Alg. 1 over an externally derived set (the heterogeneous wrapper
/// expands infeasible workloads into replica *specs* first, so each entry
/// here is exactly one placement item).
pub fn provision_with_derived(
    model: &dyn PerfModel,
    sys: &ProfiledSystem,
    specs: &[WorkloadSpec],
    derived: &[Option<Derived>],
) -> Plan {
    place_items(model, sys, specs, derived_items(derived))
}

/// [`provision_with_derived`] on the retained exhaustive scan — the
/// linear reference for the heterogeneous provisioning path.
pub fn provision_with_derived_linear(
    model: &dyn PerfModel,
    sys: &ProfiledSystem,
    specs: &[WorkloadSpec],
    derived: &[Option<Derived>],
) -> Plan {
    place_items_linear(model, sys, specs, derived_items(derived))
}

fn derived_items(derived: &[Option<Derived>]) -> Vec<(usize, Derived)> {
    derived
        .iter()
        .enumerate()
        .filter_map(|(w, d)| d.map(|d| (w, d)))
        .collect()
}

/// Shared placement loop of Alg. 1: sort items by `r_lower` descending
/// and greedily place each on the GPU with minimum increased-interference
/// resources, provisioning a fresh GPU when none fits.
///
/// The device scan runs on the indexed [`PlacementEngine`] (headroom
/// buckets + persistent per-device scorer state + admissible pruning) —
/// bitwise plan-identical to [`place_items_linear`], pinned by the
/// differential property tests in `engine.rs` and
/// `tests/provisioner_invariants.rs`.
fn place_items(
    model: &dyn PerfModel,
    sys: &ProfiledSystem,
    specs: &[WorkloadSpec],
    mut items: Vec<(usize, Derived)>,
) -> Plan {
    let mut plan = Plan::new("iGniter", &sys.hw);
    plan.gpus.push(Vec::new()); // g <- 1

    // Sort by r_lower descending (line 3); the sort is stable, so equal
    // keys — in particular replicas of one workload — keep their order.
    items.sort_by(|(wa, da), (wb, db)| {
        db.r_lower
            .partial_cmp(&da.r_lower)
            .unwrap()
            .then(wa.cmp(wb))
    });

    let mut engine = PlacementEngine::new(&sys.hw);
    engine.push_device(sys, specs, &[]);
    for &(w, d) in &items {
        engine.place(model, sys, specs, &mut plan, w, d);
    }
    plan
}

/// The retained exhaustive placement loop: scans every device per item
/// with a fresh `alloc_gpus` probe.  O(items × devices × growth) — kept
/// verbatim as the bitwise reference the engine is pinned against, and
/// as the baseline side of `benches/provisioner.rs`.
pub fn place_items_linear(
    model: &dyn PerfModel,
    sys: &ProfiledSystem,
    specs: &[WorkloadSpec],
    mut items: Vec<(usize, Derived)>,
) -> Plan {
    let hw = &sys.hw;
    let mut plan = Plan::new("iGniter", hw);
    plan.gpus.push(Vec::new()); // g <- 1

    // Sort by r_lower descending (line 3); the sort is stable, so equal
    // keys — in particular replicas of one workload — keep their order.
    items.sort_by(|(wa, da), (wb, db)| {
        db.r_lower
            .partial_cmp(&da.r_lower)
            .unwrap()
            .then(wa.cmp(wb))
    });

    // Running per-device allocation totals: a device without `r_lower`
    // headroom can never host the item (alloc_gpus' entry check), so it
    // is skipped before the resident-copy + predict work.
    let mut used: Vec<f64> = vec![0.0];

    for &(w, d) in &items {
        // Greedily find the GPU with minimum increased-interference
        // resources (lines 5-12).
        let mut best: Option<(usize, Vec<Alloc>, f64)> = None;
        for g in 0..plan.gpus.len() {
            if used[g] + d.r_lower > hw.r_max + 1e-9 {
                continue; // bitwise the same reject alloc_gpus would hit
            }
            if let Some(alloc) = alloc_gpus(model, sys, specs, &plan.gpus[g], w, d.r_lower, d.batch)
            {
                // r_inter = sum of increases over current residents plus
                // the new item's growth above its own lower bound.
                // `alloc_gpus` preserves order (residents first, the new
                // item last), so the comparison is positional — replicas
                // of one workload co-resident on a device stay distinct.
                let mut r_inter = 0.0;
                for (i, a) in alloc.iter().enumerate() {
                    let before = if i < plan.gpus[g].len() {
                        plan.gpus[g][i].resources
                    } else {
                        d.r_lower
                    };
                    r_inter += a.resources - before;
                }
                let better = match &best {
                    None => true,
                    Some((_, _, b)) => r_inter < *b - 1e-12,
                };
                if better {
                    best = Some((g, alloc, r_inter));
                }
            }
        }
        match best {
            Some((g, alloc, _)) => {
                used[g] = alloc.iter().map(|a| a.resources).sum();
                plan.gpus[g] = alloc;
            }
            None => {
                // Provision a new GPU (lines 13-15).  Placement still goes
                // through alloc_gpus: with the analytic model the solo
                // Theorem-1 bound needs no growth (this reduces to placing
                // at r_lower), but a calibrated model may have to grow the
                // lone item past its analytic lower bound right away.  If
                // even the whole device cannot meet the (corrected) bound
                // the growth loop overflows r_max and returns None — the
                // best effort on an otherwise idle device is then the FULL
                // device, not the analytic minimum.
                let alloc = alloc_gpus(model, sys, specs, &[], w, d.r_lower, d.batch)
                    .unwrap_or_else(|| {
                        vec![Alloc {
                            workload: w,
                            resources: sys.hw.r_max,
                            batch: d.batch,
                        }]
                    });
                used.push(alloc.iter().map(|a| a.resources).sum());
                plan.gpus.push(alloc);
            }
        }
    }
    plan
}

/// One exhaustive min-`r_inter` scan over the current devices for a
/// single item — the per-step linear reference `engine::search` is
/// differentially tested against.  Returns the winning device, its grown
/// allocation list, and its `r_inter`, or `None` when no device fits.
pub fn find_best_linear(
    model: &dyn PerfModel,
    sys: &ProfiledSystem,
    specs: &[WorkloadSpec],
    gpus: &[Vec<Alloc>],
    w: usize,
    d: Derived,
) -> Option<(usize, Vec<Alloc>, f64)> {
    let hw = &sys.hw;
    let mut best: Option<(usize, Vec<Alloc>, f64)> = None;
    for (g, residents) in gpus.iter().enumerate() {
        let entry: f64 = residents.iter().map(|a| a.resources).sum();
        if entry + d.r_lower > hw.r_max + 1e-9 {
            continue;
        }
        if let Some(alloc) = alloc_gpus(model, sys, specs, residents, w, d.r_lower, d.batch) {
            let mut r_inter = 0.0;
            for (i, a) in alloc.iter().enumerate() {
                let before = if i < residents.len() {
                    residents[i].resources
                } else {
                    d.r_lower
                };
                r_inter += a.resources - before;
            }
            let better = match &best {
                None => true,
                Some((_, _, b)) => r_inter < *b - 1e-12,
            };
            if better {
                best = Some((g, alloc, r_inter));
            }
        }
    }
    best
}

/// Validate every allocation of a plan against its *replica share* of the
/// workload's traffic under `model`: predicted `t_inf <= T_slo / 2` and
/// predicted throughput covering `rate / replica_count` (the even
/// per-replica arrival split the coordinator's router realizes).
///
/// Predictions run through one [`DeviceScorer`] per GPU — the device
/// aggregates are summed once, so validation is O(allocations) instead
/// of O(allocations × residents).  Bit-identical to per-slot
/// `model.predict` (the scorer property tests pin this).
pub fn validate_replica_shares(
    model: &dyn PerfModel,
    sys: &ProfiledSystem,
    specs: &[WorkloadSpec],
    plan: &Plan,
) -> Result<(), String> {
    let terms = model.terms();
    for g in 0..plan.gpus.len() {
        let scorer = DeviceScorer::from_placed(&sys.hw, plan.placed_device(sys, specs, g));
        for (i, a) in plan.gpus[g].iter().enumerate() {
            let spec = &specs[a.workload];
            let k = plan.replica_count(a.workload).max(1);
            let share = spec.rate_rps / k as f64;
            let p = model.correct(&scorer.placed(i).coeffs.name, scorer.predict_with(i, terms));
            if p.t_inf > spec.slo_ms / 2.0 + 1e-6 {
                return Err(format!(
                    "gpu {g}: {} replica predicted t_inf {:.2} > half-SLO {:.2}",
                    spec.name,
                    p.t_inf,
                    spec.slo_ms / 2.0
                ));
            }
            if p.throughput_rps < share * 0.999 {
                return Err(format!(
                    "gpu {g}: {} replica predicted throughput {:.0} < share {:.0} (k={k})",
                    spec.name, p.throughput_rps, share
                ));
            }
        }
    }
    Ok(())
}

/// Predict the latency/throughput of every placed workload of a plan.
/// Returns (workload, predicted t_inf ms, predicted throughput req/s).
pub fn predict_plan(
    sys: &ProfiledSystem,
    specs: &[WorkloadSpec],
    plan: &Plan,
) -> Vec<(usize, f64, f64)> {
    let mut out = Vec::new();
    for g in 0..plan.gpus.len() {
        let placed = plan.placed_device(sys, specs, g);
        for (i, a) in plan.gpus[g].iter().enumerate() {
            let p = perfmodel::predict(&sys.hw, &placed, i);
            out.push((a.workload, p.t_inf, p.throughput_rps));
        }
    }
    out.sort_by_key(|x| x.0);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::{GpuKind, Model};
    use crate::profiler;

    fn sys() -> ProfiledSystem {
        let (hw, wls) = profiler::profile_all(GpuKind::V100, 42);
        ProfiledSystem {
            hw,
            coeffs: crate::gpu::ALL_MODELS.iter().cloned().zip(wls).collect(),
        }
    }

    fn table1_specs() -> Vec<WorkloadSpec> {
        vec![
            WorkloadSpec::new(0, Model::AlexNet, 15.0, 500.0),
            WorkloadSpec::new(1, Model::ResNet50, 40.0, 400.0),
            WorkloadSpec::new(2, Model::Vgg19, 60.0, 200.0),
        ]
    }

    #[test]
    fn table1_fits_one_gpu() {
        // Table 1: iGniter fits A+R+V on a single V100 with SLOs met.
        let s = sys();
        let specs = table1_specs();
        let plan = provision(&s, &specs);
        assert_eq!(plan.num_gpus(), 1, "{plan:?}");
        plan.validate(3, s.hw.r_max).unwrap();
        for (w, t_inf, thpt) in predict_plan(&s, &specs, &plan) {
            assert!(
                t_inf <= specs[w].slo_ms / 2.0 + 1e-6,
                "{}: {t_inf:.2} > {}",
                specs[w].name,
                specs[w].slo_ms / 2.0
            );
            assert!(thpt >= specs[w].rate_rps * 0.999);
        }
    }

    #[test]
    fn table1_batches_match_paper() {
        // Paper Table 1: iGniter plan A(10%, 4), R(30%, 8), V(37.5%, 6).
        let s = sys();
        let specs = table1_specs();
        let d = derive_all(&s, &specs);
        let (ba, br, bv) = (
            d[0].unwrap().batch,
            d[1].unwrap().batch,
            d[2].unwrap().batch,
        );
        assert!((3..=5).contains(&ba), "A batch {ba}");
        assert!((7..=9).contains(&br), "R batch {br}");
        assert!((5..=7).contains(&bv), "V batch {bv}");
    }

    #[test]
    fn alloc_gpus_grows_resident_under_interference() {
        // Placing a noisy neighbour must grow the resident allocation
        // relative to its lower bound when its SLO becomes tight.
        let s = sys();
        let specs = vec![
            WorkloadSpec::new(0, Model::ResNet50, 22.0, 400.0),
            WorkloadSpec::new(1, Model::Vgg19, 60.0, 200.0),
        ];
        let d = derive_all(&s, &specs);
        let d0 = d[0].unwrap();
        let d1 = d[1].unwrap();
        let resident = vec![Alloc {
            workload: 0,
            resources: d0.r_lower,
            batch: d0.batch,
        }];
        let alloc =
            alloc_gpus(&AnalyticModel::ALL, &s, &specs, &resident, 1, d1.r_lower, d1.batch)
                .unwrap();
        let r0_after = alloc.iter().find(|a| a.workload == 0).unwrap().resources;
        assert!(
            r0_after >= d0.r_lower,
            "resident shrunk: {r0_after} < {}",
            d0.r_lower
        );
        // the total must stay within the device
        let total: f64 = alloc.iter().map(|a| a.resources).sum();
        assert!(total <= 1.0 + 1e-9);
    }

    #[test]
    fn alloc_gpus_refuses_overflow() {
        let s = sys();
        let specs = vec![
            WorkloadSpec::new(0, Model::Ssd, 25.0, 300.0),
            WorkloadSpec::new(1, Model::Ssd, 25.0, 300.0),
        ];
        let d = derive_all(&s, &specs);
        let d0 = d[0].unwrap();
        // two heavy SSDs at ~full demand cannot share one device
        let resident = vec![Alloc {
            workload: 0,
            resources: d0.r_lower,
            batch: d0.batch,
        }];
        assert!(alloc_gpus(
            &AnalyticModel::ALL,
            &s,
            &specs,
            &resident,
            1,
            d[1].unwrap().r_lower,
            d[1].unwrap().batch
        )
        .is_none());
    }

    #[test]
    fn all_slos_met_for_12_workloads() {
        let s = sys();
        let specs = crate::workload::app_workloads();
        let plan = provision(&s, &specs);
        plan.validate(specs.len(), s.hw.r_max).unwrap();
        for (w, t_inf, thpt) in predict_plan(&s, &specs, &plan) {
            assert!(
                t_inf <= specs[w].slo_ms / 2.0 + 1e-6,
                "{} violated: {t_inf:.2}",
                specs[w].name
            );
            assert!(thpt >= specs[w].rate_rps * 0.999, "{} thpt", specs[w].name);
        }
        // paper scale: 6 V100s for the 12 workloads
        assert!(
            (4..=8).contains(&plan.num_gpus()),
            "GPUs = {}",
            plan.num_gpus()
        );
    }

    #[test]
    fn determinism() {
        let s = sys();
        let specs = crate::workload::app_workloads();
        assert_eq!(provision(&s, &specs), provision(&s, &specs));
    }

    #[test]
    fn replica_split_covers_over_capacity_rate() {
        let s = sys();
        let rate = over_capacity_rate(&s, Model::ResNet50, 40.0, 400.0);
        let spec = WorkloadSpec::new(0, Model::ResNet50, 40.0, rate);
        let (k, d) = replica_split(&s, &spec).expect("split must be feasible");
        assert!(k >= 2, "over-capacity rate needs >1 replica, got {k}");
        // the per-share bound must itself be feasible
        assert!(d.r_lower <= s.hw.r_max + 1e-9);
        // sanity: an infeasible SLO (sub-ms) cannot be saved by replication
        let bad = WorkloadSpec::new(0, Model::ResNet50, 0.5, 100.0);
        assert!(replica_split(&s, &bad).is_none());
    }

    #[test]
    fn provision_splits_over_capacity_workload_into_replicas() {
        let s = sys();
        let rate = over_capacity_rate(&s, Model::ResNet50, 40.0, 400.0);
        let specs = vec![
            WorkloadSpec::new(0, Model::ResNet50, 40.0, rate),
            WorkloadSpec::new(1, Model::AlexNet, 15.0, 500.0),
        ];
        let plan = provision(&s, &specs);
        plan.validate(2, s.hw.r_max).unwrap();
        assert!(
            plan.replica_count(0) >= 2,
            "workload beyond one GPU must replicate: {plan:?}"
        );
        assert_eq!(plan.replica_count(1), 1);
        validate_replica_shares(&AnalyticModel::ALL, &s, &specs, &plan).unwrap();
        // deterministic across runs
        assert_eq!(plan, provision(&s, &specs));
    }

    #[test]
    fn trait_threaded_provision_is_bitwise_the_default() {
        // Threading the PerfModel trait (and the DeviceScorer underneath)
        // must not move a single bit of the default plan — the acceptance
        // bar for the whole refactor.
        let s = sys();
        let specs = crate::workload::app_workloads();
        assert_eq!(provision(&s, &specs), provision_with(&AnalyticModel::ALL, &s, &specs));
        // a zero-observation calibrated model is the same plan too
        let cal = crate::perfmodel::CalibratedModel::new();
        assert_eq!(provision(&s, &specs), provision_with(&cal, &s, &specs));
    }

    #[test]
    fn calibrated_model_grows_allocations_under_learned_slowdown() {
        // A model that has learned "resnet50 runs 1.4x the analytic
        // prediction" must provision at least as many resources for a
        // ResNet workload as the static model — the mechanism behind
        // closed-loop mismatch recovery.
        let s = sys();
        let specs = vec![WorkloadSpec::new(0, Model::ResNet50, 30.0, 300.0)];
        let base = provision(&s, &specs);
        let mut cal = crate::perfmodel::CalibratedModel::new();
        let solo = crate::perfmodel::predict_solo(
            &s.hw,
            s.coeffs_for(Model::ResNet50),
            8.0,
            0.3,
        );
        for _ in 0..16 {
            cal.observe("resnet50", solo.t_inf, solo.t_inf * 1.4);
        }
        let grown = provision_with(&cal, &s, &specs);
        let r_base = base.find(0).unwrap().1.resources;
        let r_grown = grown.find(0).unwrap().1.resources;
        assert!(
            r_grown > r_base + 1e-9,
            "calibrated allocation {r_grown} !> static {r_base}"
        );
    }
}
