//! The iGniter provisioning strategy: Algorithm 1 (workload placement with
//! minimum interference growth) and Algorithm 2 (`alloc_gpus`, iterative
//! GPU resource reallocation until every resident workload meets half its
//! SLO under the predicted interference).

use super::types::{Alloc, Plan, ProfiledSystem, WorkloadSpec};
use crate::perfmodel::{self, PlacedWorkload};

/// Per-workload derived quantities (Theorem 1).
#[derive(Debug, Clone, Copy)]
pub struct Derived {
    pub batch: u32,
    pub r_lower: f64,
}

/// Compute (b_appr, r_lower) for each workload; `None` entries are
/// infeasible on this GPU type at full resources (heterogeneous clusters
/// handle them by replication — see `heterogeneous.rs`).
pub fn derive_all(sys: &ProfiledSystem, specs: &[WorkloadSpec]) -> Vec<Option<Derived>> {
    specs
        .iter()
        .map(|w| {
            perfmodel::lower_bound_resources(&sys.hw, sys.coeffs_for(w.model), w.slo_ms, w.rate_rps)
                .map(|(batch, r_lower)| Derived { batch, r_lower })
        })
        .collect()
}

/// Algorithm 2: place workload `w` (with lower bound `r_lower_w` and batch
/// `batch_w`) onto the device currently holding `resident`, then reallocate
/// until every workload on the device satisfies `t_inf <= T_slo / 2` or the
/// device runs out of resources.
///
/// Returns the post-placement allocations (including `w` last) or `None`
/// if the device cannot host the workload.
pub fn alloc_gpus(
    sys: &ProfiledSystem,
    specs: &[WorkloadSpec],
    resident: &[Alloc],
    w: usize,
    r_lower_w: f64,
    batch_w: u32,
) -> Option<Vec<Alloc>> {
    let hw = &sys.hw;
    let mut allocs: Vec<Alloc> = resident.to_vec();
    allocs.push(Alloc {
        workload: w,
        resources: r_lower_w,
        batch: batch_w,
    });

    let total = |a: &[Alloc]| -> f64 { a.iter().map(|x| x.resources).sum() };
    if total(&allocs) > hw.r_max + 1e-9 {
        return None;
    }

    // Iteratively grow SLO-violating workloads by r_unit (lines 2-11).
    let mut flag = true;
    while flag {
        flag = false;
        let placed: Vec<PlacedWorkload> = allocs
            .iter()
            .map(|a| PlacedWorkload {
                coeffs: sys.coeffs_for(specs[a.workload].model),
                batch: a.batch as f64,
                resources: a.resources,
            })
            .collect();
        let mut grow: Vec<usize> = Vec::new();
        for (i, a) in allocs.iter().enumerate() {
            let pred = perfmodel::predict(hw, &placed, i);
            if pred.t_inf > specs[a.workload].slo_ms / 2.0 + 1e-9 {
                grow.push(i);
            }
        }
        for i in grow {
            allocs[i].resources += hw.r_unit;
            flag = true;
        }
        if total(&allocs) > hw.r_max + 1e-9 {
            return None;
        }
    }
    Some(allocs)
}

/// Algorithm 1: the iGniter cost-efficient provisioning strategy.
///
/// Workloads whose `derive` entry is `None` are skipped (the heterogeneous
/// wrapper replicates them first); panics in the homogeneous API if any is
/// infeasible so callers notice.
pub fn provision(sys: &ProfiledSystem, specs: &[WorkloadSpec]) -> Plan {
    let derived = derive_all(sys, specs);
    for (w, d) in derived.iter().enumerate() {
        assert!(
            d.is_some(),
            "workload {} infeasible on {} at full resources",
            specs[w].name,
            sys.hw.gpu
        );
    }
    provision_with_derived(sys, specs, &derived)
}

pub fn provision_with_derived(
    sys: &ProfiledSystem,
    specs: &[WorkloadSpec],
    derived: &[Option<Derived>],
) -> Plan {
    let hw = &sys.hw;
    let mut plan = Plan::new("iGniter", hw);
    plan.gpus.push(Vec::new()); // g <- 1

    // Sort by r_lower descending (line 3).
    let mut order: Vec<usize> = (0..specs.len()).filter(|&w| derived[w].is_some()).collect();
    order.sort_by(|&a, &b| {
        let ra = derived[a].unwrap().r_lower;
        let rb = derived[b].unwrap().r_lower;
        rb.partial_cmp(&ra).unwrap().then(a.cmp(&b))
    });

    for &w in &order {
        let d = derived[w].unwrap();
        // Greedily find the GPU with minimum increased-interference
        // resources (lines 5-12).
        let mut best: Option<(usize, Vec<Alloc>, f64)> = None;
        for g in 0..plan.gpus.len() {
            if let Some(alloc) = alloc_gpus(sys, specs, &plan.gpus[g], w, d.r_lower, d.batch) {
                // r_inter = sum of increases over current residents plus
                // the new workload's growth above its own lower bound.
                let mut r_inter = 0.0;
                for a in &alloc {
                    let before = plan.gpus[g]
                        .iter()
                        .find(|x| x.workload == a.workload)
                        .map(|x| x.resources)
                        .unwrap_or(if a.workload == w { d.r_lower } else { 0.0 });
                    r_inter += a.resources - before;
                }
                let better = match &best {
                    None => true,
                    Some((_, _, b)) => r_inter < *b - 1e-12,
                };
                if better {
                    best = Some((g, alloc, r_inter));
                }
            }
        }
        match best {
            Some((g, alloc, _)) => plan.gpus[g] = alloc,
            None => {
                // Provision a new GPU (lines 13-15) and place at r_lower.
                plan.gpus.push(vec![Alloc {
                    workload: w,
                    resources: d.r_lower,
                    batch: d.batch,
                }]);
            }
        }
    }
    plan
}

/// Predict the latency/throughput of every placed workload of a plan.
/// Returns (workload, predicted t_inf ms, predicted throughput req/s).
pub fn predict_plan(
    sys: &ProfiledSystem,
    specs: &[WorkloadSpec],
    plan: &Plan,
) -> Vec<(usize, f64, f64)> {
    let mut out = Vec::new();
    for g in 0..plan.gpus.len() {
        let placed: Vec<PlacedWorkload> = plan.gpus[g]
            .iter()
            .map(|a| PlacedWorkload {
                coeffs: sys.coeffs_for(specs[a.workload].model),
                batch: a.batch as f64,
                resources: a.resources,
            })
            .collect();
        for (i, a) in plan.gpus[g].iter().enumerate() {
            let p = perfmodel::predict(&sys.hw, &placed, i);
            out.push((a.workload, p.t_inf, p.throughput_rps));
        }
    }
    out.sort_by_key(|x| x.0);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::{GpuKind, Model};
    use crate::profiler;

    fn sys() -> ProfiledSystem {
        let (hw, wls) = profiler::profile_all(GpuKind::V100, 42);
        ProfiledSystem {
            hw,
            coeffs: crate::gpu::ALL_MODELS.iter().cloned().zip(wls).collect(),
        }
    }

    fn table1_specs() -> Vec<WorkloadSpec> {
        vec![
            WorkloadSpec::new(0, Model::AlexNet, 15.0, 500.0),
            WorkloadSpec::new(1, Model::ResNet50, 40.0, 400.0),
            WorkloadSpec::new(2, Model::Vgg19, 60.0, 200.0),
        ]
    }

    #[test]
    fn table1_fits_one_gpu() {
        // Table 1: iGniter fits A+R+V on a single V100 with SLOs met.
        let s = sys();
        let specs = table1_specs();
        let plan = provision(&s, &specs);
        assert_eq!(plan.num_gpus(), 1, "{plan:?}");
        plan.validate(3, s.hw.r_max).unwrap();
        for (w, t_inf, thpt) in predict_plan(&s, &specs, &plan) {
            assert!(
                t_inf <= specs[w].slo_ms / 2.0 + 1e-6,
                "{}: {t_inf:.2} > {}",
                specs[w].name,
                specs[w].slo_ms / 2.0
            );
            assert!(thpt >= specs[w].rate_rps * 0.999);
        }
    }

    #[test]
    fn table1_batches_match_paper() {
        // Paper Table 1: iGniter plan A(10%, 4), R(30%, 8), V(37.5%, 6).
        let s = sys();
        let specs = table1_specs();
        let d = derive_all(&s, &specs);
        let (ba, br, bv) = (
            d[0].unwrap().batch,
            d[1].unwrap().batch,
            d[2].unwrap().batch,
        );
        assert!((3..=5).contains(&ba), "A batch {ba}");
        assert!((7..=9).contains(&br), "R batch {br}");
        assert!((5..=7).contains(&bv), "V batch {bv}");
    }

    #[test]
    fn alloc_gpus_grows_resident_under_interference() {
        // Placing a noisy neighbour must grow the resident allocation
        // relative to its lower bound when its SLO becomes tight.
        let s = sys();
        let specs = vec![
            WorkloadSpec::new(0, Model::ResNet50, 22.0, 400.0),
            WorkloadSpec::new(1, Model::Vgg19, 60.0, 200.0),
        ];
        let d = derive_all(&s, &specs);
        let d0 = d[0].unwrap();
        let d1 = d[1].unwrap();
        let resident = vec![Alloc {
            workload: 0,
            resources: d0.r_lower,
            batch: d0.batch,
        }];
        let alloc = alloc_gpus(&s, &specs, &resident, 1, d1.r_lower, d1.batch).unwrap();
        let r0_after = alloc.iter().find(|a| a.workload == 0).unwrap().resources;
        assert!(
            r0_after >= d0.r_lower,
            "resident shrunk: {r0_after} < {}",
            d0.r_lower
        );
        // the total must stay within the device
        let total: f64 = alloc.iter().map(|a| a.resources).sum();
        assert!(total <= 1.0 + 1e-9);
    }

    #[test]
    fn alloc_gpus_refuses_overflow() {
        let s = sys();
        let specs = vec![
            WorkloadSpec::new(0, Model::Ssd, 25.0, 300.0),
            WorkloadSpec::new(1, Model::Ssd, 25.0, 300.0),
        ];
        let d = derive_all(&s, &specs);
        let d0 = d[0].unwrap();
        // two heavy SSDs at ~full demand cannot share one device
        let resident = vec![Alloc {
            workload: 0,
            resources: d0.r_lower,
            batch: d0.batch,
        }];
        assert!(alloc_gpus(&s, &specs, &resident, 1, d[1].unwrap().r_lower, d[1].unwrap().batch)
            .is_none());
    }

    #[test]
    fn all_slos_met_for_12_workloads() {
        let s = sys();
        let specs = crate::workload::app_workloads();
        let plan = provision(&s, &specs);
        plan.validate(specs.len(), s.hw.r_max).unwrap();
        for (w, t_inf, thpt) in predict_plan(&s, &specs, &plan) {
            assert!(
                t_inf <= specs[w].slo_ms / 2.0 + 1e-6,
                "{} violated: {t_inf:.2}",
                specs[w].name
            );
            assert!(thpt >= specs[w].rate_rps * 0.999, "{} thpt", specs[w].name);
        }
        // paper scale: 6 V100s for the 12 workloads
        assert!(
            (4..=8).contains(&plan.num_gpus()),
            "GPUs = {}",
            plan.num_gpus()
        );
    }

    #[test]
    fn determinism() {
        let s = sys();
        let specs = crate::workload::app_workloads();
        assert_eq!(provision(&s, &specs), provision(&s, &specs));
    }
}
