//! Online (incremental) provisioning: "iGniter is periodically executed to
//! provision GPU resources for newly-arrived inference workloads"
//! (Sec. 4.2).  Instead of re-packing the whole cluster, an `OnlinePlanner`
//! mutates the live plan: arrivals go to the min-interference device
//! (Alg. 1's inner step, which may also grow residents per Alg. 2),
//! departures free their partition, and `rebalance` compares against a
//! from-scratch Alg.-1 plan to decide whether a full re-pack would save
//! instances (the paper's periodic execution).

use super::igniter::{alloc_gpus, derive_all, provision_with_derived};
use super::types::{Alloc, Plan, ProfiledSystem, WorkloadSpec};
use crate::util::error::{anyhow, Result};

/// A live, mutable provisioning state.
#[derive(Debug, Clone)]
pub struct OnlinePlanner {
    sys: ProfiledSystem,
    specs: Vec<WorkloadSpec>,
    plan: Plan,
    /// workloads currently active (by spec index)
    active: Vec<bool>,
}

/// Outcome of an arrival.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Placed {
    /// Placed on an existing device (index), possibly growing residents.
    Existing(usize),
    /// A new device was provisioned (index).
    NewGpu(usize),
}

impl OnlinePlanner {
    /// Start with an empty cluster.
    pub fn new(sys: ProfiledSystem) -> OnlinePlanner {
        let plan = Plan::new("iGniter-online", &sys.hw);
        OnlinePlanner {
            sys,
            specs: Vec::new(),
            plan,
            active: Vec::new(),
        }
    }

    /// Start from an existing offline plan.
    pub fn from_plan(sys: ProfiledSystem, specs: Vec<WorkloadSpec>, plan: Plan) -> OnlinePlanner {
        let active = vec![true; specs.len()];
        OnlinePlanner {
            sys,
            specs,
            plan,
            active,
        }
    }

    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    pub fn specs(&self) -> &[WorkloadSpec] {
        &self.specs
    }

    pub fn active_count(&self) -> usize {
        self.active.iter().filter(|&&a| a).count()
    }

    /// Devices currently holding at least one workload.
    pub fn occupied_gpus(&self) -> usize {
        self.plan.gpus.iter().filter(|g| !g.is_empty()).count()
    }

    /// Hourly cost of the *occupied* devices (empty ones are released).
    pub fn cost_per_hour(&self) -> f64 {
        self.occupied_gpus() as f64 * self.sys.hw.unit_price
    }

    /// Handle a newly-arrived workload: place on the device with the
    /// minimum interference-induced resource growth; provision a new
    /// device if none fits.  Returns the workload's id and where it went.
    pub fn add(&mut self, mut spec: WorkloadSpec) -> Result<(usize, Placed)> {
        let id = self.specs.len();
        spec.id = id;
        let derived = derive_all(&self.sys, std::slice::from_ref(&spec))[0]
            .ok_or_else(|| anyhow!("{} infeasible on {}", spec.name, self.sys.hw.gpu))?;
        self.specs.push(spec);
        self.active.push(true);

        // Greedy min-interference placement over live devices (Alg. 1 inner
        // loop against the current allocations).
        let mut best: Option<(usize, Vec<Alloc>, f64)> = None;
        for g in 0..self.plan.gpus.len() {
            if let Some(alloc) = alloc_gpus(
                &self.sys,
                &self.specs,
                &self.plan.gpus[g],
                id,
                derived.r_lower,
                derived.batch,
            ) {
                let mut r_inter = 0.0;
                for a in &alloc {
                    let before = self.plan.gpus[g]
                        .iter()
                        .find(|x| x.workload == a.workload)
                        .map(|x| x.resources)
                        .unwrap_or(if a.workload == id { derived.r_lower } else { 0.0 });
                    r_inter += a.resources - before;
                }
                if best.as_ref().map_or(true, |(_, _, b)| r_inter < *b - 1e-12) {
                    best = Some((g, alloc, r_inter));
                }
            }
        }
        Ok(match best {
            Some((g, alloc, _)) => {
                self.plan.gpus[g] = alloc;
                (id, Placed::Existing(g))
            }
            None => {
                self.plan.gpus.push(vec![Alloc {
                    workload: id,
                    resources: derived.r_lower,
                    batch: derived.batch,
                }]);
                (id, Placed::NewGpu(self.plan.gpus.len() - 1))
            }
        })
    }

    /// Handle a departed workload: free its partition.  Co-residents keep
    /// their (now generous) allocations until the next `rebalance`.
    pub fn remove(&mut self, id: usize) -> Result<()> {
        if id >= self.specs.len() || !self.active[id] {
            return Err(anyhow!("workload {id} not active"));
        }
        self.active[id] = false;
        for g in &mut self.plan.gpus {
            g.retain(|a| a.workload != id);
        }
        Ok(())
    }

    /// Periodic re-pack: run Alg. 1 from scratch on the active set and
    /// adopt the new plan if it occupies fewer devices.  Returns the new
    /// occupied-GPU count if adopted.
    pub fn rebalance(&mut self) -> Option<usize> {
        let live: Vec<WorkloadSpec> = self
            .specs
            .iter()
            .filter(|s| self.active[s.id])
            .cloned()
            .collect();
        if live.is_empty() {
            self.plan.gpus.clear();
            return Some(0);
        }
        // Re-index into a dense spec set for the offline pass.
        let mut dense = live.clone();
        for (i, s) in dense.iter_mut().enumerate() {
            s.id = i;
        }
        let derived = derive_all(&self.sys, &dense);
        if derived.iter().any(|d| d.is_none()) {
            return None;
        }
        let fresh = provision_with_derived(&self.sys, &dense, &derived);
        if fresh.num_gpus() < self.occupied_gpus() {
            // translate back to original ids
            let mut gpus = Vec::new();
            for g in &fresh.gpus {
                gpus.push(
                    g.iter()
                        .map(|a| Alloc {
                            workload: live[a.workload].id,
                            resources: a.resources,
                            batch: a.batch,
                        })
                        .collect(),
                );
            }
            self.plan.gpus = gpus;
            Some(self.occupied_gpus())
        } else {
            None
        }
    }

    /// Predicted (t_inf, throughput) of one active workload.
    pub fn predict(&self, id: usize) -> Option<(f64, f64)> {
        let (g, _) = self.plan.find(id)?;
        let placed: Vec<crate::perfmodel::PlacedWorkload> = self.plan.gpus[g]
            .iter()
            .map(|a| crate::perfmodel::PlacedWorkload {
                coeffs: self.sys.coeffs_for(self.specs[a.workload].model),
                batch: a.batch as f64,
                resources: a.resources,
            })
            .collect();
        let idx = self.plan.gpus[g].iter().position(|a| a.workload == id)?;
        let p = crate::perfmodel::predict(&self.sys.hw, &placed, idx);
        Some((p.t_inf, p.throughput_rps))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::{GpuKind, Model};
    use crate::workload::app_workloads;

    fn sys() -> ProfiledSystem {
        let (hw, wls) = crate::profiler::profile_all(GpuKind::V100, 42);
        ProfiledSystem {
            hw,
            coeffs: crate::gpu::ALL_MODELS.iter().cloned().zip(wls).collect(),
        }
    }

    #[test]
    fn incremental_arrivals_meet_slos() {
        let mut op = OnlinePlanner::new(sys());
        for spec in app_workloads() {
            let (id, _) = op.add(WorkloadSpec::new(0, spec.model, spec.slo_ms, spec.rate_rps)).unwrap();
            // every active workload must still meet its half-SLO
            let _ = id;
            for w in 0..op.specs().len() {
                let (t_inf, thpt) = op.predict(w).unwrap();
                assert!(
                    t_inf <= op.specs()[w].slo_ms / 2.0 + 1e-6,
                    "{} violated after arrival",
                    op.specs()[w].name
                );
                assert!(thpt >= op.specs()[w].rate_rps * 0.999);
            }
        }
        // online placement is near the offline plan (within +2 GPUs)
        assert!(
            (6..=8).contains(&op.occupied_gpus()),
            "online GPUs = {}",
            op.occupied_gpus()
        );
    }

    #[test]
    fn departures_free_capacity_and_rebalance_compacts() {
        let mut op = OnlinePlanner::new(sys());
        let mut ids = Vec::new();
        for spec in app_workloads() {
            ids.push(op.add(WorkloadSpec::new(0, spec.model, spec.slo_ms, spec.rate_rps)).unwrap().0);
        }
        let before = op.occupied_gpus();
        // remove the eight heaviest (every non-AlexNet workload)
        for (i, spec) in app_workloads().iter().enumerate() {
            if spec.model != Model::AlexNet {
                op.remove(ids[i]).unwrap();
            }
        }
        assert_eq!(op.active_count(), 3);
        let rebalanced = op.rebalance();
        assert!(rebalanced.is_some(), "rebalance should compact");
        assert!(op.occupied_gpus() < before);
        // the three AlexNets easily share one device
        assert_eq!(op.occupied_gpus(), 1, "{:?}", op.plan());
        // SLOs still hold after compaction
        for s in op.specs().iter().filter(|s| s.model == Model::AlexNet) {
            let (t_inf, _) = op.predict(s.id).unwrap();
            assert!(t_inf <= s.slo_ms / 2.0 + 1e-6);
        }
    }

    #[test]
    fn remove_errors() {
        let mut op = OnlinePlanner::new(sys());
        assert!(op.remove(0).is_err());
        let (id, _) = op.add(WorkloadSpec::new(0, Model::AlexNet, 15.0, 100.0)).unwrap();
        op.remove(id).unwrap();
        assert!(op.remove(id).is_err(), "double remove");
    }

    #[test]
    fn from_plan_matches_offline() {
        let s = sys();
        let specs = app_workloads();
        let plan = crate::provisioner::provision(&s, &specs);
        let op = OnlinePlanner::from_plan(s, specs.clone(), plan.clone());
        assert_eq!(op.occupied_gpus(), plan.num_gpus());
        assert_eq!(op.active_count(), 12);
        for w in 0..12 {
            assert!(op.predict(w).is_some());
        }
    }

    #[test]
    fn infeasible_arrival_rejected_cleanly() {
        let mut op = OnlinePlanner::new(sys());
        let before = op.specs().len();
        // sub-millisecond SLO is impossible
        assert!(op.add(WorkloadSpec::new(0, Model::Ssd, 0.5, 10.0)).is_err());
        assert_eq!(op.specs().len(), before, "failed arrival must not leak");
    }
}
