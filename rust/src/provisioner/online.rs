//! Online (incremental) provisioning: "iGniter is periodically executed to
//! provision GPU resources for newly-arrived inference workloads"
//! (Sec. 4.2).  Instead of re-packing the whole cluster, an `OnlinePlanner`
//! mutates the live plan: arrivals go to the min-interference device
//! (Alg. 1's inner step, which may also grow residents per Alg. 2),
//! departures free their partition, and `rebalance` compares against a
//! from-scratch Alg.-1 plan to decide whether a full re-pack would save
//! instances (the paper's periodic execution).

use super::engine::PlacementEngine;
use super::igniter::{derive_all, provision_with, provision_with_derived, replica_split, Derived};
use super::partition::PartitionModel;
use super::types::{Alloc, Plan, ProfiledSystem, WorkloadSpec};
use crate::perfmodel::{model, AnalyticModel, PerfModel, Prediction};
use crate::util::error::{anyhow, Result};

/// A live, mutable provisioning state.
#[derive(Debug, Clone)]
pub struct OnlinePlanner {
    sys: ProfiledSystem,
    specs: Vec<WorkloadSpec>,
    plan: Plan,
    /// workloads currently active (by spec index)
    active: Vec<bool>,
    /// The performance model every placement decision scores with.  The
    /// default is the static `AnalyticModel`; the serving `Reprovisioner`
    /// swaps in a `CalibratedModel` it feeds from observed latencies, so
    /// re-plans trust the corrected predictions.
    model: Box<dyn PerfModel>,
    /// Pre-respec plan snapshot, reused across respecs (`Plan::copy_from`)
    /// so the atomic-rollback guarantee stops costing a deep clone per
    /// re-plan attempt.
    rollback: Plan,
    /// The persistent indexed placement engine: headroom buckets +
    /// per-device scorer state, kept in sync with `plan` across every
    /// mutation (place syncs itself; remove/respec-rollback/rebalance
    /// resync explicitly) so each arrival probe reuses the maintained
    /// state instead of rebuilding it per device.
    engine: PlacementEngine,
    /// Placement items executed so far (initial plan + every later
    /// arrival/respec replica) — the numerator of
    /// `wall.plan_throughput_pps`.
    placements: u64,
    /// How this system's devices partition compute (resolved once from
    /// the GPU label).  MIG systems quantize every demand to the slice
    /// grid, place best-fit-decreasing through the discrete engine path,
    /// and score with the interference-free model.
    partition: PartitionModel,
    /// MIG slice reconfigurations performed on devices that were hosting
    /// other live tenants at the time (carving a slice for an arrival, or
    /// destroying one on departure).  Fresh/empty devices don't count —
    /// their partition layout is written before anyone is running — and
    /// neither does `rebalance`, which models a drained re-pack rather
    /// than live surgery.  Always 0 on continuous systems.
    reconfigurations: u64,
}

/// Outcome of an arrival.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Placed {
    /// Placed on an existing device (index), possibly growing residents.
    Existing(usize),
    /// A new device was provisioned (index).
    NewGpu(usize),
}

impl OnlinePlanner {
    /// The scoring model matching the partition model: interference-free
    /// on MIG (slices are hardware-isolated), the full analytic model on
    /// continuous gpulets.
    fn default_model(partition: PartitionModel) -> Box<dyn PerfModel> {
        if partition.is_mig() {
            Box::new(super::mig::mig_model())
        } else {
            Box::new(AnalyticModel::ALL)
        }
    }

    /// Start with an empty cluster (static analytic model).
    pub fn new(sys: ProfiledSystem) -> OnlinePlanner {
        let plan = Plan::new("iGniter-online", &sys.hw);
        let engine = PlacementEngine::new(&sys.hw);
        let partition = PartitionModel::for_gpu_name(&sys.hw.gpu);
        OnlinePlanner {
            sys,
            specs: Vec::new(),
            rollback: plan.clone(),
            plan,
            active: Vec::new(),
            model: Self::default_model(partition),
            engine,
            placements: 0,
            partition,
            reconfigurations: 0,
        }
    }

    /// Start from an existing offline plan (static analytic model).
    pub fn from_plan(sys: ProfiledSystem, specs: Vec<WorkloadSpec>, plan: Plan) -> OnlinePlanner {
        let active = vec![true; specs.len()];
        let engine = PlacementEngine::from_plan(&sys, &specs, &plan);
        let partition = PartitionModel::for_gpu_name(&sys.hw.gpu);
        OnlinePlanner {
            sys,
            specs,
            rollback: plan.clone(),
            plan,
            active,
            model: Self::default_model(partition),
            engine,
            placements: 0,
            partition,
            reconfigurations: 0,
        }
    }

    /// Swap the performance model used for every later placement.
    pub fn set_model(&mut self, model: Box<dyn PerfModel>) {
        self.model = model;
    }

    pub fn model(&self) -> &dyn PerfModel {
        self.model.as_ref()
    }

    pub fn model_mut(&mut self) -> &mut dyn PerfModel {
        self.model.as_mut()
    }

    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    pub fn specs(&self) -> &[WorkloadSpec] {
        &self.specs
    }

    pub fn active_count(&self) -> usize {
        self.active.iter().filter(|&&a| a).count()
    }

    /// Devices currently holding at least one workload.
    pub fn occupied_gpus(&self) -> usize {
        self.plan.gpus.iter().filter(|g| !g.is_empty()).count()
    }

    /// Hourly cost of the *occupied* devices (empty ones are released).
    pub fn cost_per_hour(&self) -> f64 {
        self.occupied_gpus() as f64 * self.sys.hw.unit_price
    }

    /// Handle a newly-arrived workload: place on the device with the
    /// minimum interference-induced resource growth; provision a new
    /// device if none fits.  A rate beyond one gpulet at full resources
    /// is split into the minimum number of even rate-sharing replicas
    /// (as in offline `provision`), each placed independently under the
    /// same id.  Returns the workload's id and where its last replica
    /// went.
    pub fn add(&mut self, mut spec: WorkloadSpec) -> Result<(usize, Placed)> {
        let id = self.specs.len();
        spec.id = id;
        let (k, derived) = match derive_all(&self.sys, std::slice::from_ref(&spec))[0] {
            Some(d) => (1, d),
            None => replica_split(&self.sys, &spec)
                .ok_or_else(|| anyhow!("{} infeasible on {}", spec.name, self.sys.hw.gpu))?,
        };
        // MIG: round the demand up to the smallest covering slice profile
        // (identity on continuous systems).
        let derived = Derived {
            r_lower: self.partition.quantize_demand(derived.r_lower),
            ..derived
        };
        self.specs.push(spec);
        self.active.push(true);
        let mut placed = Placed::NewGpu(self.plan.gpus.len());
        for _ in 0..k {
            placed = self.place(id, derived);
        }
        Ok((id, placed))
    }

    /// Greedy min-interference placement of one allocation item (Alg. 1
    /// inner loop against the current live allocations), through the
    /// persistent indexed engine — decision-identical to the retained
    /// exhaustive scan (`igniter::find_best_linear`), which had no
    /// headroom skip: the engine's exact entry check is bitwise the
    /// reject `alloc_gpus_into` would hit on those devices anyway.
    fn place(&mut self, id: usize, derived: Derived) -> Placed {
        self.placements += 1;
        let (g, fresh) = if self.partition.is_mig() {
            // Discrete path: best-fit over free slice capacity — there is
            // no interference to score and no resident growth to probe.
            let (g, fresh) =
                self.engine
                    .place_discrete(&self.sys, &self.specs, &mut self.plan, id, derived, true);
            if !fresh && self.plan.gpus[g].len() > 1 {
                // carved a slice on a device already hosting live tenants
                self.reconfigurations += 1;
            }
            debug_assert!(
                super::partition::device_is_legal(&self.plan.gpus[g]).is_ok(),
                "illegal MIG device after place: {:?}",
                self.plan.gpus[g]
            );
            (g, fresh)
        } else {
            self.engine.place(
                self.model.as_ref(),
                &self.sys,
                &self.specs,
                &mut self.plan,
                id,
                derived,
            )
        };
        if fresh {
            Placed::NewGpu(g)
        } else {
            Placed::Existing(g)
        }
    }

    /// Placement items executed so far: the denominator work-count of
    /// `wall.plan_throughput_pps` (each arrival replica, respec replica,
    /// and adopted-rebalance allocation counts once).
    pub fn placements(&self) -> u64 {
        self.placements
    }

    /// MIG slice reconfigurations on live devices so far (0 on continuous
    /// systems) — see the field doc for exactly what counts.
    pub fn reconfigurations(&self) -> u64 {
        self.reconfigurations
    }

    /// The partition model this planner routes through.
    pub fn partition(&self) -> PartitionModel {
        self.partition
    }

    /// Handle a departed workload: free its partition.  Co-residents keep
    /// their (now generous) allocations until the next `rebalance`.
    pub fn remove(&mut self, id: usize) -> Result<()> {
        if id >= self.specs.len() || !self.active[id] {
            return Err(anyhow!("workload {id} not active"));
        }
        self.active[id] = false;
        for g in 0..self.plan.gpus.len() {
            let before = self.plan.gpus[g].len();
            self.plan.gpus[g].retain(|a| a.workload != id);
            if self.plan.gpus[g].len() != before {
                if self.partition.is_mig() && !self.plan.gpus[g].is_empty() {
                    // destroyed a slice while co-tenants keep running
                    self.reconfigurations += 1;
                }
                self.engine
                    .sync_device(g, &self.sys, &self.specs, &self.plan.gpus[g]);
            }
        }
        Ok(())
    }

    /// Re-provision a single active workload for a new arrival rate —
    /// iGniter's Sec.-5.3 response to workload changes: only the affected
    /// workload is re-placed (min-interference, possibly growing
    /// co-residents), everything else stays put.  Atomic: when the new
    /// rate is infeasible the planner state is left exactly as it was.
    /// Returns the workload's new id and placement.  Note: each re-spec
    /// retires the old id and appends a fresh spec entry (ids are never
    /// reused), so planner state grows linearly in the number of
    /// re-plans — fine at simulation scale, by design.
    pub fn respec(&mut self, id: usize, new_rate_rps: f64) -> Result<(usize, Placed)> {
        if id >= self.specs.len() || !self.active[id] {
            return Err(anyhow!("workload {id} not active"));
        }
        // snapshot into the reusable rollback plan instead of deep-cloning
        let mut rollback = std::mem::take(&mut self.rollback);
        rollback.copy_from(&self.plan);
        let (model, slo_ms) = (self.specs[id].model, self.specs[id].slo_ms);
        let res = self
            .remove(id)
            .and_then(|()| self.add(WorkloadSpec::new(0, model, slo_ms, new_rate_rps)));
        if res.is_err() {
            // rollback: re-activate the old placement untouched, and
            // re-mirror the engine onto the restored plan (the failed
            // attempt's remove already resynced some devices).
            self.active[id] = true;
            std::mem::swap(&mut self.plan, &mut rollback);
            self.engine.rebuild(&self.sys, &self.specs, &self.plan);
        }
        self.rollback = rollback;
        res
    }

    /// Realize a device failure: drop the dead device's allocations from
    /// the plan, exclude it from every future candidate scan, and return
    /// the ids of the active workloads that lost replicas there.  The
    /// caller (the serving policy's failover path) drives `respec` for
    /// each returned id to place replacement capacity on survivors — or
    /// on fresh devices when the survivors are full, the cloud's answer
    /// to instance loss.
    pub fn fail_device(&mut self, g: usize) -> Vec<usize> {
        if g >= self.plan.gpus.len() {
            return Vec::new();
        }
        let mut hit: Vec<usize> = Vec::new();
        for a in &self.plan.gpus[g] {
            if self.active[a.workload] && !hit.contains(&a.workload) {
                hit.push(a.workload);
            }
        }
        self.plan.gpus[g].clear();
        self.engine
            .sync_device(g, &self.sys, &self.specs, &self.plan.gpus[g]);
        self.engine.mark_dead(g);
        hit
    }

    /// True once any device has been failed via `fail_device`.
    pub fn any_device_dead(&self) -> bool {
        self.engine.any_dead()
    }

    /// Periodic re-pack: run Alg. 1 from scratch on the active set and
    /// adopt the new plan if it occupies fewer devices.  Returns the new
    /// occupied-GPU count if adopted.
    pub fn rebalance(&mut self) -> Option<usize> {
        // A from-scratch re-pack lays allocations onto devices 0..n in
        // order — it cannot express "skip the dead ones" — so once any
        // device has failed, compaction is off for the rest of the run.
        if self.engine.any_dead() {
            return None;
        }
        let live: Vec<WorkloadSpec> = self
            .specs
            .iter()
            .filter(|s| self.active[s.id])
            .cloned()
            .collect();
        if live.is_empty() {
            self.plan.gpus.clear();
            self.engine.rebuild(&self.sys, &self.specs, &self.plan);
            return Some(0);
        }
        // Re-index into a dense spec set for the offline pass.
        let mut dense = live.clone();
        for (i, s) in dense.iter_mut().enumerate() {
            s.id = i;
        }
        let fresh = if self.partition.is_mig() {
            // Drained re-pack through the fragmentation-aware slice
            // packer; replica indices map back to dense ones via origin.
            let replicated = super::heterogeneous::replicate_for(&self.sys, &dense)?;
            let derived = derive_all(&self.sys, &replicated.specs);
            if derived.iter().any(|d| d.is_none()) {
                return None;
            }
            let mut plan = super::mig::provision_mig(&self.sys, &replicated.specs, &derived);
            for a in plan.gpus.iter_mut().flatten() {
                a.workload = replicated.origin[a.workload];
            }
            plan
        } else {
            let derived = derive_all(&self.sys, &dense);
            if derived.iter().any(|d| d.is_none()) {
                // some active workload needs replicas: use the full Alg.-1
                // front-end, which splits.  Feasibility is guaranteed —
                // every active workload was placed by add/respec, so its
                // replica_split succeeds.
                provision_with(self.model.as_ref(), &self.sys, &dense)
            } else {
                provision_with_derived(self.model.as_ref(), &self.sys, &dense, &derived)
            }
        };
        // the from-scratch pass executed one placement item per allocation
        self.placements += fresh.total_allocs() as u64;
        if fresh.num_gpus() < self.occupied_gpus() {
            // translate back to original ids
            let mut gpus = Vec::new();
            for g in &fresh.gpus {
                gpus.push(
                    g.iter()
                        .map(|a| Alloc {
                            workload: live[a.workload].id,
                            resources: a.resources,
                            batch: a.batch,
                        })
                        .collect(),
                );
            }
            self.plan.gpus = gpus;
            self.engine.rebuild(&self.sys, &self.specs, &self.plan);
            Some(self.occupied_gpus())
        } else {
            None
        }
    }

    /// Predicted (t_inf, throughput) of one active workload under the
    /// planner's model (calibrated corrections included when installed).
    pub fn predict(&self, id: usize) -> Option<(f64, f64)> {
        let (_, corrected) = self.predict_full(id)?;
        Some((corrected.t_inf, corrected.throughput_rps))
    }

    /// Both views of one active workload's first replica: the raw
    /// analytic prediction and the model-corrected one.  The raw half is
    /// what calibration trains against (feeding corrected predictions
    /// back into the fit would be self-referential).
    pub fn predict_full(&self, id: usize) -> Option<(Prediction, Prediction)> {
        let (g, _) = self.plan.find(id)?;
        let placed = self.plan.placed_device(&self.sys, &self.specs, g);
        let idx = self.plan.gpus[g].iter().position(|a| a.workload == id)?;
        let raw = model::predict_with(&self.sys.hw, &placed, idx, self.model.terms());
        let corrected = self.model.correct(&placed[idx].coeffs.name, raw);
        Some((raw, corrected))
    }

    /// Group-mean `(raw t_inf, corrected t_inf)` over **every** replica
    /// of `id`.  This is what the calibration feed pairs against the
    /// group-mean observed exec latency: replicas of one workload can sit
    /// under very different co-location (one solo, one with three noisy
    /// neighbours), so a single-replica prediction against a group-mean
    /// observation would bias the residual fit in either direction.
    pub fn predict_group_mean(&self, id: usize) -> Option<(f64, f64)> {
        let mut raw_sum = 0.0;
        let mut cor_sum = 0.0;
        let mut n = 0u32;
        for g in 0..self.plan.gpus.len() {
            if !self.plan.gpus[g].iter().any(|a| a.workload == id) {
                continue;
            }
            let placed = self.plan.placed_device(&self.sys, &self.specs, g);
            for (idx, a) in self.plan.gpus[g].iter().enumerate() {
                if a.workload != id {
                    continue;
                }
                let raw = model::predict_with(&self.sys.hw, &placed, idx, self.model.terms());
                let corrected = self.model.correct(&placed[idx].coeffs.name, raw);
                raw_sum += raw.t_inf;
                cor_sum += corrected.t_inf;
                n += 1;
            }
        }
        if n == 0 {
            None
        } else {
            Some((raw_sum / n as f64, cor_sum / n as f64))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::{GpuKind, Model};
    use crate::workload::app_workloads;

    fn sys() -> ProfiledSystem {
        let (hw, wls) = crate::profiler::profile_all(GpuKind::V100, 42);
        ProfiledSystem {
            hw,
            coeffs: crate::gpu::ALL_MODELS.iter().cloned().zip(wls).collect(),
        }
    }

    #[test]
    fn incremental_arrivals_meet_slos() {
        let mut op = OnlinePlanner::new(sys());
        for spec in app_workloads() {
            let (id, _) = op
                .add(WorkloadSpec::new(0, spec.model, spec.slo_ms, spec.rate_rps))
                .unwrap();
            // every active workload must still meet its half-SLO
            let _ = id;
            for w in 0..op.specs().len() {
                let (t_inf, thpt) = op.predict(w).unwrap();
                assert!(
                    t_inf <= op.specs()[w].slo_ms / 2.0 + 1e-6,
                    "{} violated after arrival",
                    op.specs()[w].name
                );
                assert!(thpt >= op.specs()[w].rate_rps * 0.999);
            }
        }
        // online placement is near the offline plan (within +2 GPUs)
        assert!(
            (6..=8).contains(&op.occupied_gpus()),
            "online GPUs = {}",
            op.occupied_gpus()
        );
    }

    #[test]
    fn departures_free_capacity_and_rebalance_compacts() {
        let mut op = OnlinePlanner::new(sys());
        let mut ids = Vec::new();
        for spec in app_workloads() {
            let spec = WorkloadSpec::new(0, spec.model, spec.slo_ms, spec.rate_rps);
            ids.push(op.add(spec).unwrap().0);
        }
        let before = op.occupied_gpus();
        // remove the eight heaviest (every non-AlexNet workload)
        for (i, spec) in app_workloads().iter().enumerate() {
            if spec.model != Model::AlexNet {
                op.remove(ids[i]).unwrap();
            }
        }
        assert_eq!(op.active_count(), 3);
        let rebalanced = op.rebalance();
        assert!(rebalanced.is_some(), "rebalance should compact");
        assert!(op.occupied_gpus() < before);
        // the three AlexNets easily share one device
        assert_eq!(op.occupied_gpus(), 1, "{:?}", op.plan());
        // SLOs still hold after compaction
        for s in op.specs().iter().filter(|s| s.model == Model::AlexNet) {
            let (t_inf, _) = op.predict(s.id).unwrap();
            assert!(t_inf <= s.slo_ms / 2.0 + 1e-6);
        }
    }

    #[test]
    fn remove_errors() {
        let mut op = OnlinePlanner::new(sys());
        assert!(op.remove(0).is_err());
        let (id, _) = op.add(WorkloadSpec::new(0, Model::AlexNet, 15.0, 100.0)).unwrap();
        op.remove(id).unwrap();
        assert!(op.remove(id).is_err(), "double remove");
    }

    #[test]
    fn from_plan_matches_offline() {
        let s = sys();
        let specs = app_workloads();
        let plan = crate::provisioner::provision(&s, &specs);
        let op = OnlinePlanner::from_plan(s, specs.clone(), plan.clone());
        assert_eq!(op.occupied_gpus(), plan.num_gpus());
        assert_eq!(op.active_count(), 12);
        for w in 0..12 {
            assert!(op.predict(w).is_some());
        }
    }

    #[test]
    fn respec_replans_one_workload_and_rolls_back_on_failure() {
        let mut op = OnlinePlanner::new(sys());
        let (a, _) = op.add(WorkloadSpec::new(0, Model::AlexNet, 15.0, 400.0)).unwrap();
        let (r, _) = op.add(WorkloadSpec::new(0, Model::ResNet50, 30.0, 300.0)).unwrap();
        let plan_before = op.plan().clone();
        // grow AlexNet's rate: new id, still feasible, ResNet untouched
        let (a2, _) = op.respec(a, 900.0).unwrap();
        assert_ne!(a2, a);
        assert_eq!(op.active_count(), 2);
        let (t_inf, thpt) = op.predict(a2).unwrap();
        assert!(t_inf <= 15.0 / 2.0 + 1e-6);
        assert!(thpt >= 900.0 * 0.999);
        assert!(op.predict(r).is_some(), "co-resident lost its allocation");
        // infeasible respec: a rate past one gpulet now replica-splits,
        // so exceed what even MAX_REPLICAS even shares can cover —
        // planner state must be exactly what it was before the attempt
        let plan_mid = op.plan().clone();
        let one_gpulet =
            crate::provisioner::igniter::over_capacity_rate(&op.sys, Model::AlexNet, 15.0, 900.0);
        let huge = one_gpulet * 2.0 * crate::provisioner::igniter::MAX_REPLICAS as f64;
        assert!(op.respec(a2, huge).is_err());
        assert_eq!(*op.plan(), plan_mid, "failed respec mutated the plan");
        assert_eq!(op.active_count(), 2);
        assert!(op.predict(a2).is_some());
        // double respec of a stale id fails cleanly
        assert!(op.respec(a, 100.0).is_err());
        let _ = plan_before;
    }

    #[test]
    fn add_and_respec_replicate_over_capacity_rates() {
        // The closed loop must be able to scale a workload back *past*
        // one gpulet: add/respec split into even rate-sharing replicas
        // exactly like offline provision() (regression: respec used to
        // collapse a group to one replica and then fail forever on the
        // way back up).
        let s = sys();
        let rate =
            crate::provisioner::igniter::over_capacity_rate(&s, Model::ResNet50, 40.0, 400.0);
        let mut op = OnlinePlanner::new(s);
        let (id, _) = op
            .add(WorkloadSpec::new(0, Model::ResNet50, 40.0, rate))
            .unwrap();
        assert!(op.plan().replica_count(id) >= 2, "{:?}", op.plan());
        // trough: collapses to a single replica
        let (id2, _) = op.respec(id, 100.0).unwrap();
        assert_eq!(op.plan().replica_count(id2), 1);
        assert_eq!(op.plan().replica_count(id), 0, "old group lingers");
        // peak again: the split must come back
        let (id3, _) = op.respec(id2, rate).unwrap();
        assert!(op.plan().replica_count(id3) >= 2, "{:?}", op.plan());
        // never overcommitted along the way
        for g in 0..op.plan().gpus.len() {
            assert!(op.plan().allocated(g) <= op.sys.hw.r_max + 1e-9);
        }
        for w in 0..op.specs().len() {
            if w == id3 {
                let (t_inf, _) = op.predict(w).unwrap();
                assert!(t_inf <= 40.0 / 2.0 + 1e-6);
            }
        }
    }

    #[test]
    fn calibrated_model_drives_larger_respec_allocations() {
        // Swap in a CalibratedModel that has learned "resnet50 runs 1.4x
        // the analytic prediction": the next respec must grow the
        // allocation past what the static model provisioned, and the
        // corrected prediction must meet the half-SLO again.
        let mut op = OnlinePlanner::new(sys());
        let (id, _) = op
            .add(WorkloadSpec::new(0, Model::ResNet50, 30.0, 300.0))
            .unwrap();
        let r_static = op.plan().find(id).unwrap().1.resources;
        let (raw, corrected) = op.predict_full(id).unwrap();
        // analytic model: corrected == raw bit for bit
        assert_eq!(raw.t_inf.to_bits(), corrected.t_inf.to_bits());
        let mut cal = crate::perfmodel::CalibratedModel::new();
        for _ in 0..16 {
            cal.observe("resnet50", raw.t_inf, raw.t_inf * 1.4);
        }
        op.set_model(Box::new(cal));
        assert_eq!(op.model().name(), "calibrated");
        let (id2, _) = op.respec(id, 300.0).unwrap();
        let r_cal = op.plan().find(id2).unwrap().1.resources;
        assert!(
            r_cal > r_static + 1e-9,
            "calibrated respec did not grow: {r_cal} vs {r_static}"
        );
        let (_, c) = op.predict_full(id2).unwrap();
        assert!(c.t_inf <= 30.0 / 2.0 + 1e-6, "corrected t_inf {}", c.t_inf);
    }

    #[test]
    fn fail_device_replans_victims_onto_survivors() {
        let mut op = OnlinePlanner::new(sys());
        let mut ids = Vec::new();
        for spec in app_workloads() {
            ids.push(
                op.add(WorkloadSpec::new(0, spec.model, spec.slo_ms, spec.rate_rps))
                    .unwrap()
                    .0,
            );
        }
        let gpus_before = op.plan().gpus.len();
        assert!(gpus_before >= 2, "need a multi-device plan to kill from");
        // kill device 0 and respec every victim, as the failover path does
        let victims = op.fail_device(0);
        assert!(!victims.is_empty(), "device 0 hosted nothing");
        assert!(op.any_device_dead());
        assert!(op.plan().gpus[0].is_empty(), "dead device still holds allocs");
        for &w in &victims {
            let rate = op.specs()[w].rate_rps;
            let (nw, _) = op.respec(w, rate).expect("failover respec");
            // the replacement never lands on the dead device
            let (g, _) = op.plan().find(nw).expect("replacement placed");
            assert_ne!(g, 0, "replacement placed on the dead device");
            let (t_inf, thpt) = op.predict(nw).unwrap();
            assert!(t_inf <= op.specs()[nw].slo_ms / 2.0 + 1e-6);
            assert!(thpt >= rate * 0.999);
        }
        assert!(op.plan().gpus[0].is_empty(), "something crept back onto gpu 0");
        // untouched workloads keep their placements through the failover
        for (&id, spec) in ids.iter().zip(app_workloads().iter()) {
            if !victims.contains(&id) {
                assert!(op.predict(id).is_some(), "{} lost its allocation", spec.name);
            }
        }
        // compaction stays off for the rest of the run: a from-scratch
        // re-pack would happily reuse device 0
        assert_eq!(op.rebalance(), None, "rebalance ran with a dead device");
    }

    fn mig_sys() -> ProfiledSystem {
        let (hw, wls) = crate::profiler::profile_all(GpuKind::A100, 42);
        ProfiledSystem {
            hw,
            coeffs: crate::gpu::ALL_MODELS.iter().cloned().zip(wls).collect(),
        }
    }

    /// Every allocation in the plan as (workload, resources-bits, batch),
    /// sorted — for exact "nobody else moved" comparisons.
    fn alloc_set(plan: &Plan) -> Vec<(usize, usize, u64, u32)> {
        let mut v: Vec<_> = plan
            .gpus
            .iter()
            .enumerate()
            .flat_map(|(g, allocs)| {
                allocs
                    .iter()
                    .map(move |a| (g, a.workload, a.resources.to_bits(), a.batch))
            })
            .collect();
        v.sort();
        v
    }

    #[test]
    fn mig_arrivals_are_slice_legal_and_never_touch_live_residents() {
        let mut op = OnlinePlanner::new(mig_sys());
        assert!(op.partition().is_mig());
        for spec in app_workloads() {
            let before = alloc_set(op.plan());
            op.add(WorkloadSpec::new(0, spec.model, spec.slo_ms, spec.rate_rps))
                .unwrap();
            // reconfig never evicts or resizes a live replica: every
            // pre-arrival allocation survives byte-identically in place
            let after = alloc_set(op.plan());
            for item in &before {
                assert!(after.contains(item), "arrival moved a live replica: {item:?}");
            }
            crate::provisioner::partition::plan_is_legal(op.plan()).unwrap();
            // isolation: every active workload still meets its half-SLO
            for w in 0..op.specs().len() {
                let (t_inf, thpt) = op.predict(w).unwrap();
                assert!(t_inf <= op.specs()[w].slo_ms / 2.0 + 1e-6);
                assert!(thpt >= op.specs()[w].rate_rps * 0.999);
            }
        }
    }

    #[test]
    fn mig_reconfigurations_count_live_device_surgery_only() {
        let mut op = OnlinePlanner::new(mig_sys());
        // first arrival carves a fresh device: no live tenants, no reconfig
        let (a, _) = op.add(WorkloadSpec::new(0, Model::AlexNet, 15.0, 100.0)).unwrap();
        assert_eq!(op.reconfigurations(), 0);
        // second small arrival lands next to it: live-device carve
        let (b, placed) = op.add(WorkloadSpec::new(0, Model::AlexNet, 15.0, 100.0)).unwrap();
        assert_eq!(placed, Placed::Existing(0));
        assert_eq!(op.reconfigurations(), 1);
        // removing one while the other keeps running: live-device destroy
        op.remove(a).unwrap();
        assert_eq!(op.reconfigurations(), 2);
        // removing the last tenant empties the device: not counted
        op.remove(b).unwrap();
        assert_eq!(op.reconfigurations(), 2);
        // continuous systems never count
        let mut cont = OnlinePlanner::new(sys());
        let (x, _) = cont.add(WorkloadSpec::new(0, Model::AlexNet, 15.0, 100.0)).unwrap();
        cont.add(WorkloadSpec::new(0, Model::AlexNet, 15.0, 100.0)).unwrap();
        cont.remove(x).unwrap();
        assert_eq!(cont.reconfigurations(), 0);
    }

    #[test]
    fn mig_rebalance_repacks_on_the_slice_grid() {
        let mut op = OnlinePlanner::new(mig_sys());
        let mut ids = Vec::new();
        for spec in app_workloads() {
            ids.push(
                op.add(WorkloadSpec::new(0, spec.model, spec.slo_ms, spec.rate_rps))
                    .unwrap()
                    .0,
            );
        }
        let before = op.occupied_gpus();
        for (i, spec) in app_workloads().iter().enumerate() {
            if spec.model != Model::AlexNet {
                op.remove(ids[i]).unwrap();
            }
        }
        let adopted = op.rebalance();
        // Post-rebalance invariant: never worse than before, and never
        // worse than what a from-scratch slice pack of the live set needs
        // (rebalance adopts the fresh pack exactly when it's tighter).
        assert!(op.occupied_gpus() <= before);
        let live: Vec<WorkloadSpec> = op
            .specs()
            .iter()
            .filter(|s| s.model == Model::AlexNet)
            .cloned()
            .collect();
        let scratch = crate::provisioner::heterogeneous::provision_on(&op.sys, &live)
            .unwrap()
            .plan
            .num_gpus();
        assert!(
            op.occupied_gpus() <= scratch,
            "rebalance left {} devices, fresh pack needs {scratch}",
            op.occupied_gpus()
        );
        if let Some(n) = adopted {
            assert_eq!(n, op.occupied_gpus());
            assert!(n < before);
        }
        crate::provisioner::partition::plan_is_legal(op.plan()).unwrap();
        for s in op.specs().iter().filter(|s| s.model == Model::AlexNet) {
            let (t_inf, _) = op.predict(s.id).unwrap();
            assert!(t_inf <= s.slo_ms / 2.0 + 1e-6);
        }
    }

    #[test]
    fn infeasible_arrival_rejected_cleanly() {
        let mut op = OnlinePlanner::new(sys());
        let before = op.specs().len();
        // sub-millisecond SLO is impossible
        assert!(op.add(WorkloadSpec::new(0, Model::Ssd, 0.5, 10.0)).is_err());
        assert_eq!(op.specs().len(), before, "failed arrival must not leak");
    }
}
