//! FFD baselines (Sec. 5.1 / Fig. 19):
//!
//! * **FFD+**  — First-Fit-Decreasing bin packing that always allocates the
//!   interference-*oblivious* lower bound `r_lower` (Eq. 18) and packs onto
//!   the first GPU with room.  Cheapest plan, most SLO violations.
//! * **FFD++** — FFD placement order, but each candidate device is sized
//!   with iGniter's `alloc_gpus` (Alg. 2), i.e. interference-aware sizing
//!   with first-fit (not min-interference) placement.

use super::igniter::{alloc_gpus, derive_all};
use super::types::{Alloc, Plan, ProfiledSystem, WorkloadSpec};
use crate::perfmodel::AnalyticModel;

/// FFD+: interference-oblivious lower-bound packing.
pub fn provision_ffd(sys: &ProfiledSystem, specs: &[WorkloadSpec]) -> Plan {
    let derived = derive_all(sys, specs);
    let hw = &sys.hw;
    let mut plan = Plan::new("FFD+", hw);

    let mut order: Vec<usize> = (0..specs.len()).collect();
    order.sort_by(|&a, &b| {
        let ra = derived[a].expect("infeasible workload").r_lower;
        let rb = derived[b].expect("infeasible workload").r_lower;
        rb.partial_cmp(&ra).unwrap().then(a.cmp(&b))
    });

    for &w in &order {
        let d = derived[w].unwrap();
        let slot = plan.gpus.iter().position(|g| {
            g.iter().map(|a| a.resources).sum::<f64>() + d.r_lower <= hw.r_max + 1e-9
        });
        let alloc = Alloc {
            workload: w,
            resources: d.r_lower,
            batch: d.batch,
        };
        match slot {
            Some(g) => plan.gpus[g].push(alloc),
            None => plan.gpus.push(vec![alloc]),
        }
    }
    plan
}

/// FFD++: first-fit placement with Alg.-2 interference-aware sizing.
pub fn provision_ffd_pp(sys: &ProfiledSystem, specs: &[WorkloadSpec]) -> Plan {
    let derived = derive_all(sys, specs);
    let hw = &sys.hw;
    let mut plan = Plan::new("FFD++", hw);

    let mut order: Vec<usize> = (0..specs.len()).collect();
    order.sort_by(|&a, &b| {
        let ra = derived[a].expect("infeasible workload").r_lower;
        let rb = derived[b].expect("infeasible workload").r_lower;
        rb.partial_cmp(&ra).unwrap().then(a.cmp(&b))
    });

    // Running per-device totals, maintained as the same in-order sum
    // alloc_gpus takes on entry: the headroom pre-skip below is bitwise
    // the reject it would hit, so first-fit picks the same device while
    // skipping the resident-copy + predict work on full ones.
    let mut used: Vec<f64> = Vec::new();
    for &w in &order {
        let d = derived[w].unwrap();
        let mut placed = false;
        for g in 0..plan.gpus.len() {
            if used[g] + d.r_lower > hw.r_max + 1e-9 {
                continue;
            }
            if let Some(alloc) = alloc_gpus(
                &AnalyticModel::ALL,
                sys,
                specs,
                &plan.gpus[g],
                w,
                d.r_lower,
                d.batch,
            ) {
                used[g] = alloc.iter().map(|a| a.resources).sum();
                plan.gpus[g] = alloc;
                placed = true;
                break; // first fit
            }
        }
        if !placed {
            plan.gpus.push(vec![Alloc {
                workload: w,
                resources: d.r_lower,
                batch: d.batch,
            }]);
            used.push(d.r_lower);
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuKind;
    use crate::provisioner::igniter;
    use crate::workload::app_workloads;

    fn sys() -> ProfiledSystem {
        let (hw, wls) = crate::profiler::profile_all(GpuKind::V100, 42);
        ProfiledSystem {
            hw,
            coeffs: crate::gpu::ALL_MODELS.iter().cloned().zip(wls).collect(),
        }
    }

    #[test]
    fn ffd_is_cheapest_but_violates() {
        let s = sys();
        let specs = app_workloads();
        let ffd = provision_ffd(&s, &specs);
        let ig = igniter::provision(&s, &specs);
        ffd.validate(specs.len(), s.hw.r_max).unwrap();
        // Fig. 14: FFD+ uses fewer (or equal) GPUs than iGniter...
        assert!(ffd.num_gpus() <= ig.num_gpus());
        // ...but its plan predicts SLO violations under interference.
        let violations = igniter::predict_plan(&s, &specs, &ffd)
            .iter()
            .filter(|(w, t, _)| *t > specs[*w].slo_ms / 2.0 + 1e-9)
            .count();
        assert!(violations >= 3, "FFD+ predicted violations = {violations}");
    }

    #[test]
    fn ffd_pp_meets_slos_with_first_fit() {
        let s = sys();
        let specs = app_workloads();
        let p = provision_ffd_pp(&s, &specs);
        p.validate(specs.len(), s.hw.r_max).unwrap();
        for (w, t_inf, _) in igniter::predict_plan(&s, &specs, &p) {
            assert!(
                t_inf <= specs[w].slo_ms / 2.0 + 1e-6,
                "{} violated under FFD++",
                specs[w].name
            );
        }
    }

    #[test]
    fn ffd_pp_never_cheaper_than_igniter() {
        // iGniter's min-interference placement should never need more
        // GPUs than first-fit with the same sizing rule.
        let s = sys();
        let specs = app_workloads();
        let pp = provision_ffd_pp(&s, &specs);
        let ig = igniter::provision(&s, &specs);
        assert!(ig.num_gpus() <= pp.num_gpus());
    }

    #[test]
    fn ffd_lower_bounds_exactly() {
        let s = sys();
        let specs = app_workloads();
        let derived = derive_all(&s, &specs);
        let p = provision_ffd(&s, &specs);
        for (_, a) in p.all() {
            assert_eq!(a.resources, derived[a.workload].unwrap().r_lower);
        }
    }
}
