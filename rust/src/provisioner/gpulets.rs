//! gpu-lets+ baseline (Choi et al., USENIX ATC'22, as patched in Sec. 5.1).
//!
//! Characteristics reproduced from the paper's description:
//!  * allocates the "most efficient amount" of GPU resources (the knee of
//!    the throughput-vs-resources curve) from the coarse menu
//!    {20 %, 40 %, 50 %, 60 %, 80 %} (Sec. 5.3);
//!  * at most **two** workloads per GPU;
//!  * pairwise linear-regression interference model, applied only to the
//!    **newly-arrived** workload — the resident workload's allocation and
//!    batch are never revisited (the root cause of its SLO violations);
//!  * best-fit placement (GPU with the least remaining room that still
//!    fits);
//!  * "+" patch: the batch size is set to just meet the arrival rate
//!    (Eq. 17), like iGniter, instead of "as large as possible".

use super::igniter::derive_all;
use super::types::{Alloc, Plan, ProfiledSystem, WorkloadSpec};
use crate::perfmodel::{AnalyticModel, PerfModel, PlacedWorkload};

/// The five resource choices gpu-lets supports.
pub const GPULETS_CHOICES: [f64; 5] = [0.2, 0.4, 0.5, 0.6, 0.8];

/// Throughput-maximizing headroom over the arrival rate: gpu-lets sizes
/// each workload for peak throughput, not for just-enough latency.
pub const THROUGHPUT_HEADROOM: f64 = 1.5;

/// Most-efficient resource amount: the smallest menu choice whose solo
/// throughput reaches `THROUGHPUT_HEADROOM` x the arrival rate while the
/// solo latency fits half the SLO; falls back to the smallest merely
/// feasible choice, then to the largest.
pub fn efficient_resources(
    model: &dyn PerfModel,
    sys: &ProfiledSystem,
    spec: &WorkloadSpec,
    batch: u32,
) -> f64 {
    let wc = sys.coeffs_for(spec.model);
    let solo = |r: f64| model.predict_solo(&sys.hw, wc, batch as f64, r);
    let feasible = |r: f64| {
        let p = solo(r);
        p.t_inf <= spec.slo_ms / 2.0 && p.throughput_rps >= spec.rate_rps
    };
    for &r in GPULETS_CHOICES.iter() {
        if feasible(r) && solo(r).throughput_rps >= THROUGHPUT_HEADROOM * spec.rate_rps {
            return r;
        }
    }
    for &r in GPULETS_CHOICES.iter() {
        if feasible(r) {
            return r;
        }
    }
    *GPULETS_CHOICES.last().unwrap()
}

/// Pairwise interference predictor: latency dilation of `target` when
/// paired with `other`, via the linear L2-utilization regression gpu-lets
/// fits offline (a single shared slope, unlike iGniter's per-workload
/// alpha_cache; ignores scheduler and power contention — and therefore
/// needs nothing from the profiled system beyond the two placements).
pub fn pair_dilation(target: &PlacedWorkload, other: &PlacedWorkload) -> f64 {
    // gpu-lets regresses latency increase on the co-runner's L2 + DRAM
    // utilization; with our observables this reduces to a fixed global
    // slope over the pair's aggregate cache utilization.
    const GLOBAL_SLOPE: f64 = 0.75;
    let u = other.coeffs.cache_util(other.batch, other.resources);
    1.0 + GLOBAL_SLOPE * u * (target.coeffs.cache_util(target.batch, target.resources) * 2.0 + 0.7)
}

/// Predicted pair latency for the *new* workload only (the resident one is
/// assumed unaffected — gpu-lets' blind spot).
fn predicted_new_latency(
    model: &dyn PerfModel,
    sys: &ProfiledSystem,
    spec: &WorkloadSpec,
    alloc: &Alloc,
    resident: Option<(&WorkloadSpec, &Alloc)>,
) -> f64 {
    let wc = sys.coeffs_for(spec.model);
    let solo = model.predict_solo(&sys.hw, wc, alloc.batch as f64, alloc.resources);
    match resident {
        None => solo.t_inf,
        Some((rs, ra)) => {
            let target = PlacedWorkload {
                coeffs: wc,
                batch: alloc.batch as f64,
                resources: alloc.resources,
            };
            let other = PlacedWorkload {
                coeffs: sys.coeffs_for(rs.model),
                batch: ra.batch as f64,
                resources: ra.resources,
            };
            solo.t_load + solo.t_feedback + (solo.t_gpu) * pair_dilation(&target, &other)
        }
    }
}

/// gpu-lets+ provisioning (static analytic solo model, as the baseline
/// system ships it).
pub fn provision_gpulets(sys: &ProfiledSystem, specs: &[WorkloadSpec]) -> Plan {
    let model = AnalyticModel::ALL;
    let derived = derive_all(sys, specs);
    let hw = &sys.hw;
    let mut plan = Plan::new("gpu-lets+", hw);

    // Largest demand first (as in the paper's experiments).
    let mut order: Vec<usize> = (0..specs.len()).collect();
    order.sort_by(|&a, &b| {
        let ra = derived[a].expect("infeasible").r_lower;
        let rb = derived[b].expect("infeasible").r_lower;
        rb.partial_cmp(&ra).unwrap().then(a.cmp(&b))
    });

    for &w in &order {
        let batch = derived[w].unwrap().batch;
        let r = efficient_resources(&model, sys, &specs[w], batch);
        let alloc = Alloc {
            workload: w,
            resources: r,
            batch,
        };

        // Best-fit over GPUs with < 2 residents and enough room, where the
        // *new* workload's pair-predicted latency meets half its SLO.
        let mut best: Option<(usize, f64)> = None; // (gpu, leftover)
        for g in 0..plan.gpus.len() {
            if plan.gpus[g].len() >= 2 {
                continue;
            }
            let used: f64 = plan.gpus[g].iter().map(|a| a.resources).sum();
            if used + r > hw.r_max + 1e-9 {
                continue;
            }
            let resident = plan.gpus[g]
                .first()
                .map(|a| (&specs[a.workload], a));
            let t_new = predicted_new_latency(&model, sys, &specs[w], &alloc, resident);
            if t_new > specs[w].slo_ms / 2.0 {
                continue;
            }
            let leftover = hw.r_max - used - r;
            if best.map_or(true, |(_, l)| leftover < l) {
                best = Some((g, leftover));
            }
        }
        match best {
            Some((g, _)) => plan.gpus[g].push(alloc),
            None => plan.gpus.push(vec![alloc]),
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::{GpuKind, Model};
    use crate::provisioner::igniter;
    use crate::workload::app_workloads;

    fn sys() -> ProfiledSystem {
        let (hw, wls) = crate::profiler::profile_all(GpuKind::V100, 42);
        ProfiledSystem {
            hw,
            coeffs: crate::gpu::ALL_MODELS.iter().cloned().zip(wls).collect(),
        }
    }

    #[test]
    fn resources_come_from_menu() {
        let s = sys();
        let specs = app_workloads();
        let p = provision_gpulets(&s, &specs);
        p.validate(specs.len(), s.hw.r_max).unwrap();
        for (_, a) in p.all() {
            assert!(
                GPULETS_CHOICES.iter().any(|&c| (c - a.resources).abs() < 1e-9),
                "resource {} not from menu",
                a.resources
            );
        }
    }

    #[test]
    fn at_most_two_per_gpu() {
        let s = sys();
        let p = provision_gpulets(&s, &app_workloads());
        assert!(p.gpus.iter().all(|g| g.len() <= 2));
    }

    #[test]
    fn costs_more_than_igniter() {
        // Fig. 14: gpu-lets+ provisions the most GPUs (8 vs iGniter's 6).
        let s = sys();
        let specs = app_workloads();
        let gl = provision_gpulets(&s, &specs);
        let ig = igniter::provision(&s, &specs);
        assert!(
            gl.num_gpus() > ig.num_gpus(),
            "gpu-lets {} !> igniter {}",
            gl.num_gpus(),
            ig.num_gpus()
        );
    }

    #[test]
    fn allocates_geq_igniter_per_workload() {
        // Fig. 18: per-workload resources under gpu-lets+ >= iGniter.
        let s = sys();
        let specs = app_workloads();
        let gl = provision_gpulets(&s, &specs);
        let ig = igniter::provision(&s, &specs);
        let mut geq = 0;
        for w in 0..specs.len() {
            let rg = gl.find(w).unwrap().1.resources;
            let ri = ig.find(w).unwrap().1.resources;
            if rg >= ri - 1e-9 {
                geq += 1;
            }
        }
        assert!(geq >= 10, "only {geq}/12 workloads >= iGniter allocation");
    }

    #[test]
    fn efficient_resources_feasibility_fallback() {
        let s = sys();
        let m = AnalyticModel::ALL;
        // an easy workload should get a small menu choice
        let easy = WorkloadSpec::new(0, Model::AlexNet, 25.0, 100.0);
        let b = igniter::derive_all(&s, &[easy.clone()])[0].unwrap().batch;
        let r = efficient_resources(&m, &s, &easy, b);
        assert!(r <= 0.5, "easy workload got {r}");
        // a heavy workload must climb the menu
        let hard = WorkloadSpec::new(1, Model::Ssd, 25.0, 300.0);
        let b2 = igniter::derive_all(&s, &[hard.clone()])[0].unwrap().batch;
        let r2 = efficient_resources(&m, &s, &hard, b2);
        assert!(r2 >= 0.6, "heavy workload got {r2}");
    }
}
