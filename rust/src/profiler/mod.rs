//! Lightweight workload/hardware profiling (Sec. 3.1 "Obtaining Model
//! Coefficients").
//!
//! Mirrors the paper's procedure against the simulated testbed:
//!  * hardware coefficients: P, F, p_idle from device telemetry
//!    ("nvidia-smi"), B_pcie by a timed transfer, alpha_f by pushing the
//!    device past its power cap, (alpha_sch, beta_sch) by co-locating 2-5
//!    copies of a reference workload and fitting the per-kernel delay;
//!  * workload coefficients: exactly **11 configurations** of
//!    (batch, resources) per workload run alone — far fewer than the
//!    40 x 32 grid — least-squares fitted to Eq. (11) and the Fig.-9
//!    power / cache-utilization lines, plus a co-location sweep for
//!    alpha_cache.
//!
//! Each configuration is "measured" by repeated queries on the *noisy*
//! device, exactly like timing a real Triton process.

use crate::gpu::{GpuDevice, GpuKind, Model};
use crate::perfmodel::coeffs::{HardwareCoeffs, WorkloadCoeffs};
use crate::util::lsq;
use crate::util::stats;

/// The paper's 11 profiling configurations: (batch, resources).
pub const PROFILE_CONFIGS: [(u32, f64); 11] = [
    (1, 0.2),
    (1, 0.5),
    (1, 1.0),
    (4, 0.35),
    (4, 0.75),
    (8, 0.2),
    (8, 0.5),
    (8, 1.0),
    (16, 0.65),
    (32, 0.4),
    (32, 1.0),
];

/// Queries per configuration (the paper repeats each experiment 3 times;
/// we average a short burst per config).
pub const QUERIES_PER_CONFIG: usize = 9;

/// Instance price per GPU type ($/h): p3.2xlarge / g4dn.xlarge (Sec. 5);
/// MIG generations priced per device from p4d.24xlarge / p5.48xlarge
/// (8-GPU instances, so 1/8 of the on-demand instance price).
pub fn unit_price(kind: GpuKind) -> f64 {
    match kind {
        GpuKind::V100 => 3.06,
        GpuKind::T4 => 0.526,
        GpuKind::A100 => 4.10,
        GpuKind::H100 => 12.29,
    }
}

/// Profile the hardware-specific coefficients of a GPU type.
/// `seed` controls measurement noise reproducibility.
pub fn profile_hardware(kind: GpuKind, seed: u64) -> HardwareCoeffs {
    let probe = GpuDevice::new(kind, seed);
    let spec = probe.spec.clone();

    // P, F, p_idle: device telemetry (nvidia-smi).
    // B_pcie: timed reference transfer.
    let measured_pcie = {
        let bytes = 64e6;
        let ms = spec.pcie_ms(bytes);
        bytes / (ms * 1e6)
    };

    // (alpha_sch, beta_sch): co-locate 2..=5 copies of VGG-19 (the paper's
    // reference for hardware profiling) and fit per-kernel delay vs m.
    let vgg = crate::gpu::profile(Model::Vgg19, kind);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for m in 2..=5u64 {
        let mut d = GpuDevice::new(kind, seed ^ m);
        for i in 0..m {
            d.launch(i, Model::Vgg19, (spec.r_max / m as f64).min(0.2), 8);
        }
        let mut delays = Vec::new();
        for _ in 0..QUERIES_PER_CONFIG {
            delays.push(d.query_latency(0, 8).unwrap().t_sched);
        }
        let per_kernel = stats::mean(&delays) / vgg.n_kernels as f64;
        xs.push(m as f64);
        ys.push(per_kernel - vgg.k_sch);
    }
    let (alpha_sch, beta_sch) = lsq::fit_line(&xs, &ys).unwrap_or((0.0, 0.0));

    // alpha_f: stack power-hungry workloads until the cap is exceeded and
    // fit frequency vs. excess demand.
    let mut fx = Vec::new();
    let mut fy = Vec::new();
    for m in 1..=6u64 {
        let mut d = GpuDevice::new(kind, seed ^ (100 + m));
        for i in 0..m {
            d.launch(i, Model::Ssd, (spec.r_max / m as f64).min(0.35), 16);
        }
        let demand = d.power_demand_w();
        if demand > spec.max_power_w {
            fx.push(demand - spec.max_power_w);
            fy.push(d.frequency_mhz() - spec.max_freq_mhz);
        }
    }
    let alpha_f = if fx.len() >= 2 {
        lsq::fit_line(&fx, &fy).map(|(a, _)| a).unwrap_or(-1.0)
    } else {
        // cap not reachable in the sweep: fall back to a single-point slope
        if let (Some(&x), Some(&y)) = (fx.first(), fy.first()) {
            y / x
        } else {
            -1.0
        }
    };

    HardwareCoeffs {
        gpu: spec.kind.name().to_string(),
        max_power_w: spec.max_power_w,
        max_freq_mhz: spec.max_freq_mhz,
        idle_power_w: spec.idle_power_w,
        pcie_gbps: measured_pcie,
        alpha_f,
        alpha_sch,
        beta_sch,
        r_unit: spec.r_unit,
        r_max: spec.r_max,
        unit_price: unit_price(kind),
    }
}

/// Profile the workload-specific coefficients of one model on one GPU type.
pub fn profile_workload(model: Model, kind: GpuKind, seed: u64) -> WorkloadCoeffs {
    let truth = crate::gpu::profile(model, kind); // transfer sizes + n_k are
                                                  // Nsight-observable facts
    let spec = GpuDevice::noiseless(kind).spec.clone();

    // --- solo sweep over the 11 configurations --------------------------
    let mut kact_samples = Vec::new(); // (b, r, active ms)
    let mut ability = Vec::new();
    let mut power = Vec::new();
    let mut cache = Vec::new();
    let mut sched = Vec::new();
    for (i, &(b, r)) in PROFILE_CONFIGS.iter().enumerate() {
        let mut d = GpuDevice::new(kind, seed ^ (i as u64 + 1));
        assert!(d.launch(0, model, r, b));
        let mut act = Vec::new();
        for _ in 0..QUERIES_PER_CONFIG {
            let q = d.query_latency(0, b).unwrap();
            act.push(q.t_act);
            sched.push(q.t_sched);
        }
        let t_act = stats::mean(&act);
        kact_samples.push((b as f64, r, t_act));
        // telemetry at this operating point (Nsight Compute / nvidia-smi)
        let ab = b as f64 / t_act;
        ability.push(ab);
        power.push(d.power_demand_w() - spec.idle_power_w);
        cache.push(cache_util_probe(&d));
    }

    let kact = lsq::fit_kact(&kact_samples).expect("k_act fit failed");
    let (alpha_power, beta_power) = lsq::fit_line(&ability, &power).unwrap_or((0.0, 0.0));
    let (alpha_cacheutil, beta_cacheutil) =
        lsq::fit_line(&ability, &cache).unwrap_or((0.0, 0.0));
    let k_sch = stats::mean(&sched) / truth.n_kernels as f64;

    // --- alpha_cache: co-locate with 1..=4 ResNet-50 co-runners of known
    //     cache utilization and fit the dilation slope ------------------
    let co_model = if model == Model::ResNet50 {
        Model::Vgg19
    } else {
        Model::ResNet50
    };
    let solo_act = {
        let mut d = GpuDevice::new(kind, seed ^ 0xAA);
        d.launch(0, model, 0.25, 8);
        let xs: Vec<f64> = (0..QUERIES_PER_CONFIG)
            .map(|_| d.query_latency(0, 8).unwrap().t_act)
            .collect();
        stats::mean(&xs)
    };
    let mut ux = Vec::new();
    let mut uy = Vec::new();
    for m in 1..=4u64 {
        let mut d = GpuDevice::new(kind, seed ^ (0xBB + m));
        d.launch(0, model, 0.25, 8);
        let co_r = ((1.0 - 0.25) / m as f64).min(0.2);
        for i in 0..m {
            d.launch(100 + i, co_model, co_r, 8);
        }
        // aggregate co-runner utilization is observable via Nsight Compute
        let co_truth = crate::gpu::profile(co_model, kind);
        let u: f64 = (0..m).map(|_| co_truth.cache_util(8.0, co_r)).sum();
        let xs: Vec<f64> = (0..QUERIES_PER_CONFIG)
            .map(|_| d.query_latency(0, 8).unwrap().t_act)
            .collect();
        ux.push(u);
        uy.push(stats::mean(&xs) / solo_act - 1.0);
    }
    let alpha_cache = lsq::fit_line(&ux, &uy).map(|(a, _)| a).unwrap_or(0.0).max(0.0);

    WorkloadCoeffs {
        name: model.name().to_string(),
        d_load_bytes: truth.d_load_bytes,
        d_feedback_bytes: truth.d_feedback_bytes,
        n_kernels: truth.n_kernels as f64,
        k_sch,
        kact,
        alpha_power,
        beta_power,
        alpha_cacheutil,
        beta_cacheutil,
        alpha_cache,
    }
}

/// Nsight-Compute-style probe of a solo process's L2 utilization.
fn cache_util_probe(d: &GpuDevice) -> f64 {
    let s = &d.slots()[0];
    crate::gpu::profile(s.model, d.spec.kind).cache_util(s.batch as f64, s.resources)
}

/// Profile everything needed by the provisioner for one GPU type.
pub fn profile_all(kind: GpuKind, seed: u64) -> (HardwareCoeffs, Vec<WorkloadCoeffs>) {
    let hw = profile_hardware(kind, seed);
    let wls = crate::gpu::ALL_MODELS
        .iter()
        .map(|&m| profile_workload(m, kind, seed ^ m as u64))
        .collect();
    (hw, wls)
}

/// Profile a complete [`ProfiledSystem`] — the bundle every provisioning
/// strategy and the serving loop consume, and the canonical input to the
/// performance-model layer (`AnalyticModel` reads these coefficients;
/// `CalibratedModel` corrects them online).
pub fn profile_system(kind: GpuKind, seed: u64) -> crate::provisioner::ProfiledSystem {
    let (hw, wls) = profile_all(kind, seed);
    crate::provisioner::ProfiledSystem {
        hw,
        coeffs: crate::gpu::ALL_MODELS.iter().cloned().zip(wls).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::model::{predict_solo, rel_error};

    #[test]
    fn hardware_coeffs_recovered() {
        let hw = profile_hardware(GpuKind::V100, 42);
        assert_eq!(hw.max_power_w, 300.0);
        assert_eq!(hw.max_freq_mhz, 1530.0);
        assert!((hw.pcie_gbps - 10.0).abs() < 0.2);
        // alpha_f should be near the ground-truth -1.025
        assert!(
            (hw.alpha_f - (-1.025)).abs() < 0.3,
            "alpha_f = {}",
            hw.alpha_f
        );
        // scheduling slope near the ground-truth alpha_sch
        assert!(
            (hw.alpha_sch - 0.00475).abs() < 0.002,
            "alpha_sch = {}",
            hw.alpha_sch
        );
    }

    #[test]
    fn workload_fit_predicts_solo_latency() {
        // The fitted model must predict held-out (b, r) points within a
        // few percent — Sec. 5.2's headline accuracy claim, solo case.
        let hw = profile_hardware(GpuKind::V100, 7);
        for &m in &crate::gpu::ALL_MODELS {
            let wc = profile_workload(m, GpuKind::V100, 7);
            for &(b, r) in &[(2u32, 0.3f64), (12, 0.55), (24, 0.8)] {
                let mut d = GpuDevice::noiseless(GpuKind::V100);
                d.launch(0, m, r, b);
                let obs = d.query_latency(0, b).unwrap().t_inf;
                let pred = predict_solo(&hw, &wc, b as f64, r).t_inf;
                let e = rel_error(pred, obs);
                assert!(e < 0.08, "{m:?} b={b} r={r}: err {:.2}%", e * 100.0);
            }
        }
    }

    #[test]
    fn alpha_cache_positive_and_sane() {
        let wc = profile_workload(Model::ResNet50, GpuKind::V100, 3);
        assert!(
            wc.alpha_cache > 0.3 && wc.alpha_cache < 2.5,
            "alpha_cache = {}",
            wc.alpha_cache
        );
    }

    #[test]
    fn profiling_is_deterministic_per_seed() {
        let a = profile_workload(Model::AlexNet, GpuKind::V100, 5);
        let b = profile_workload(Model::AlexNet, GpuKind::V100, 5);
        assert_eq!(a, b);
    }

    #[test]
    fn t4_profile_slower() {
        let v = profile_workload(Model::Vgg19, GpuKind::V100, 9);
        let t = profile_workload(Model::Vgg19, GpuKind::T4, 9);
        assert!(t.k_act(8.0, 0.5) > v.k_act(8.0, 0.5));
    }
}
