//! ASCII table + CSV formatting for experiment harness output: every
//! `igniter experiment figN` prints the paper's rows/series through this.

/// Column-aligned ASCII table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: Some(title.to_string()),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths.iter()) {
                s.push_str(&format!(" {c:>w$} |", w = w));
            }
            s
        };
        let sep = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&line(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out
    }

    /// CSV rendering (for results/*.csv artifacts).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write both .txt (ASCII) and .csv into `results/` under `stem`.
    pub fn save(&self, results_dir: &std::path::Path, stem: &str) -> std::io::Result<()> {
        std::fs::create_dir_all(results_dir)?;
        std::fs::write(results_dir.join(format!("{stem}.txt")), self.render())?;
        std::fs::write(results_dir.join(format!("{stem}.csv")), self.to_csv())?;
        Ok(())
    }
}

/// Shorthand for formatting floats in tables.
pub fn f(x: f64, decimals: usize) -> String {
    format!("{x:.decimals$}")
}

pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

pub fn ms(x_secs: f64) -> String {
    format!("{:.2}", x_secs * 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["model", "lat(ms)"]);
        t.row(&["alexnet".into(), "1.25".into()]);
        t.row(&["vgg19".into(), "10.50".into()]);
        let s = t.render();
        assert!(s.contains("| alexnet |"), "{s}");
        assert!(s.lines().count() >= 6);
        // all body lines same width
        let ws: Vec<usize> = s.lines().skip(1).map(|l| l.len()).collect();
        assert!(ws.windows(2).all(|w| w[0] == w[1]), "{s}");
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["has,comma".into(), "has\"quote".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"has,comma\""));
        assert!(csv.contains("\"has\"\"quote\""));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        Table::new("x", &["a", "b"]).row(&["only-one".into()]);
    }

    #[test]
    fn helpers() {
        assert_eq!(f(1.23456, 2), "1.23");
        assert_eq!(pct(0.256), "25.6%");
        assert_eq!(ms(0.01234), "12.34");
    }
}
