//! Deterministic PRNG substrate (no `rand` crate available offline).
//!
//! SplitMix64 core with helpers for the distributions the simulator and the
//! workload generators need: uniform, normal (Box–Muller), exponential
//! (inter-arrival times of Poisson request processes) and Poisson counts.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes; splittable so
/// every simulator component can own an independent deterministic stream.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng {
            state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15),
        }
    }

    /// Derive an independent stream (used to give each GPU device / workload
    /// its own deterministic noise source).
    pub fn split(&mut self, tag: u64) -> Rng {
        let s = self.next_u64() ^ tag.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        Rng::new(s)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) via Lemire's method (unbiased enough here).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let mut u1 = self.f64();
        if u1 <= f64::MIN_POSITIVE {
            u1 = f64::MIN_POSITIVE;
        }
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with given rate (events/sec); inter-arrival sampling.
    pub fn exp(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        let mut u = self.f64();
        if u <= f64::MIN_POSITIVE {
            u = f64::MIN_POSITIVE;
        }
        -u.ln() / rate
    }

    /// Poisson count via inversion (small lambda) or normal approx (large).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 64.0 {
            let x = self.normal_ms(lambda, lambda.sqrt());
            return x.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
            if k > 10_000 {
                return k; // numerical guard
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(1);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(9);
        let rate = 250.0;
        let n = 50_000;
        let mean = (0..n).map(|_| r.exp(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.0002, "mean={mean}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = Rng::new(11);
        for lambda in [0.5, 4.0, 30.0, 200.0] {
            let n = 20_000;
            let mean = (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.05,
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn split_streams_differ() {
        let mut root = Rng::new(5);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
