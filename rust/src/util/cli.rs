//! Command-line parsing substrate (clap is unavailable offline).
//!
//! Supports `program <subcommand> [--flag] [--opt value | --opt=value]
//! [positional...]` which is all the `igniter` binary and examples need.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    /// `known_flags` lists valueless options; everything else starting with
    /// `--` consumes the following token (or its `=` suffix) as a value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, known_flags: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    out.options
                        .insert(stripped[..eq].to_string(), stripped[eq + 1..].to_string());
                } else if known_flags.contains(&stripped) {
                    out.flags.push(stripped.to_string());
                } else if let Some(next) = it.peek() {
                    if next.starts_with("--") {
                        out.flags.push(stripped.to_string());
                    } else {
                        out.options.insert(stripped.to_string(), it.next().unwrap());
                    }
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn from_env(known_flags: &[&str]) -> Args {
        Args::parse(std::env::args().skip(1), known_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> usize {
        self.opt(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> u64 {
        self.opt(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Comma-separated list option.
    pub fn opt_list(&self, name: &str) -> Option<Vec<String>> {
        self.opt(name)
            .map(|s| s.split(',').map(|p| p.trim().to_string()).filter(|p| !p.is_empty()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()), &["verbose", "json"])
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse(&["experiment", "fig14", "extra"]);
        assert_eq!(a.subcommand.as_deref(), Some("experiment"));
        assert_eq!(a.positional, vec!["fig14", "extra"]);
    }

    #[test]
    fn options_both_syntaxes() {
        let a = parse(&["serve", "--gpus", "4", "--seed=99"]);
        assert_eq!(a.opt("gpus"), Some("4"));
        assert_eq!(a.opt_u64("seed", 0), 99);
    }

    #[test]
    fn known_flags_do_not_eat_values() {
        let a = parse(&["run", "--verbose", "pos1", "--out", "x.json"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
        assert_eq!(a.opt("out"), Some("x.json"));
    }

    #[test]
    fn trailing_unknown_flag() {
        let a = parse(&["run", "--mystery"]);
        assert!(a.flag("mystery"));
    }

    #[test]
    fn unknown_option_before_another_option_is_flag() {
        let a = parse(&["run", "--alpha", "--beta", "7"]);
        assert!(a.flag("alpha"));
        assert_eq!(a.opt("beta"), Some("7"));
    }

    #[test]
    fn list_and_defaults() {
        let a = parse(&["x", "--models", "alexnet, vgg19,ssd"]);
        assert_eq!(
            a.opt_list("models").unwrap(),
            vec!["alexnet", "vgg19", "ssd"]
        );
        assert_eq!(a.opt_f64("rate", 2.5), 2.5);
        assert_eq!(a.opt_or("missing", "dflt"), "dflt");
    }
}
