//! Error-handling substrate (anyhow is unavailable offline): a boxed-free
//! error type carrying a context chain, the `anyhow!` / `bail!` macros, and
//! a `Context` extension trait for `Result`.
//!
//! Mirrors the subset of the `anyhow` API this crate uses so call sites
//! read identically: `anyhow!("model {name} missing")`, `bail!(...)`,
//! `.context("parsing manifest.json")`, `.with_context(|| format!(...))`.
//! `Display` prints the outermost message; the alternate form (`{:#}`)
//! prints the whole chain separated by `: `, like `anyhow`.

use std::fmt;

/// Convenience alias used across the crate (same shape as `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error message with an optional chain of underlying causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error {
            msg: msg.to_string(),
            source: None,
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context(self, msg: impl fmt::Display) -> Error {
        Error {
            msg: msg.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// The outermost message (without the cause chain).
    pub fn message(&self) -> &str {
        &self.msg
    }

    /// Iterate the chain outermost-first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        out.into_iter()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: full chain, anyhow-style.
            write!(f, "{}", self.chain().collect::<Vec<_>>().join(": "))
        } else {
            f.write_str(&self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

// Like `anyhow`, any std error converts implicitly (enables `?` on
// `ParseIntError`, `io::Error`, etc.).  `Error` itself deliberately does
// NOT implement `std::error::Error`, which keeps this blanket impl
// coherent with `impl<T> From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to any
/// `Result` whose error is displayable.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        // `{e:#}` so a wrapped `Error`'s own cause chain survives the
        // re-wrap (plain `{e}` would keep only its outermost message);
        // other error types ignore the alternate flag.
        self.map_err(|e| Error::msg(format!("{e:#}")).context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{e:#}")).context(f()))
    }
}

/// Construct an [`Error`] from a format string (`anyhow!`-compatible).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return with a formatted [`Error`] (`bail!`-compatible).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

// Make the macros importable as `use crate::util::error::{anyhow, bail}`
// (or `igniter::util::error::{...}` from tests/benches/examples), matching
// how the `anyhow` crate was imported before.
pub use crate::{anyhow, bail};

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 42)
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "inner 42");
        assert_eq!(format!("{e:#}"), "inner 42");
    }

    #[test]
    fn context_chains() {
        let e: Error = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 42");
        assert_eq!(e.chain().count(), 2);
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn context_on_error_result_keeps_chain() {
        let inner: Result<()> = Err(anyhow!("root").context("mid"));
        let e = inner.context("top").unwrap_err();
        assert_eq!(format!("{e:#}"), "top: mid: root");
    }

    #[test]
    fn with_context_lazy() {
        let r: std::result::Result<(), std::num::ParseIntError> =
            "x".parse::<i32>().map(|_| ());
        let e = r.with_context(|| format!("parsing {}", "x")).unwrap_err();
        assert_eq!(e.to_string(), "parsing x");
        assert!(format!("{e:#}").contains("invalid digit"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn anyhow_macro_formats() {
        let name = "vgg19";
        let e = anyhow!("model {name} missing from artifacts");
        assert_eq!(e.to_string(), "model vgg19 missing from artifacts");
    }
}
