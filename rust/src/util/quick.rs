//! Property-testing substrate (proptest is unavailable offline).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` on `cases` generated inputs;
//! on failure it greedily shrinks the input via the value's `Shrink`
//! implementation and panics with the minimal counterexample.  Used by the
//! provisioner/coordinator invariant tests (routing, batching, placement).

use crate::util::rng::Rng;
use std::fmt::Debug;

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized + Clone {
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        (*self as u64).shrink().into_iter().map(|x| x as usize).collect()
    }
}

impl Shrink for f64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0.0 {
            out.push(0.0);
            out.push(self / 2.0);
            if self.fract() != 0.0 {
                out.push(self.trunc());
            }
        }
        out
    }
}

impl Shrink for String {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if !self.is_empty() {
            out.push(String::new());
            let half: String = self.chars().take(self.chars().count() / 2).collect();
            out.push(half);
        }
        out
    }
}

impl Shrink for bool {
    fn shrink(&self) -> Vec<Self> {
        if *self {
            vec![false]
        } else {
            Vec::new()
        }
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // remove halves, remove single elements, shrink single elements
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        if self.len() <= 8 {
            for i in 0..self.len() {
                let mut v = self.clone();
                v.remove(i);
                out.push(v);
            }
            for i in 0..self.len() {
                for smaller in self[i].shrink() {
                    let mut v = self.clone();
                    v[i] = smaller;
                    out.push(v);
                }
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self.0.shrink().into_iter().map(|a| (a, self.1.clone())).collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Outcome of a single property check.
pub type PropResult = Result<(), String>;

/// Run a property over `cases` random inputs; shrink on failure.
pub fn forall<T, G, P>(seed: u64, cases: usize, mut gen: G, prop: P)
where
    T: Shrink + Debug,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> PropResult,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            let (min_input, min_msg) = shrink_loop(input, msg, &prop);
            panic!(
                "property failed (case {case}/{cases}, seed {seed}):\n  \
                 minimal counterexample: {min_input:?}\n  error: {min_msg}"
            );
        }
    }
}

fn shrink_loop<T, P>(mut input: T, mut msg: String, prop: &P) -> (T, String)
where
    T: Shrink + Debug,
    P: Fn(&T) -> PropResult,
{
    'outer: for _ in 0..200 {
        for cand in input.shrink() {
            if let Err(m) = prop(&cand) {
                input = cand;
                msg = m;
                continue 'outer;
            }
        }
        break;
    }
    (input, msg)
}

/// Assert helper producing `PropResult`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall(
            1,
            200,
            |r| r.below(1000),
            |&x| {
                if x < 1000 {
                    Ok(())
                } else {
                    Err(format!("{x} out of range"))
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn failing_property_shrinks() {
        forall(
            2,
            500,
            |r| r.below(10_000),
            |&x| {
                if x < 50 {
                    Ok(())
                } else {
                    Err("too big".into())
                }
            },
        );
    }

    #[test]
    fn shrink_vec_reaches_small() {
        // A vec property failing whenever it contains an element > 5
        // should shrink near the minimal [6].
        let bad = vec![9u64, 3, 7, 6, 2];
        let (min, _) = shrink_loop(bad, "seed".into(), &|v: &Vec<u64>| {
            if v.iter().any(|&x| x > 5) {
                Err("has big".into())
            } else {
                Ok(())
            }
        });
        assert!(min.len() <= 1, "minimal {min:?}");
    }

    #[test]
    fn tuple_shrink_covers_both_sides() {
        let t = (10u64, 4u64);
        let cands = t.shrink();
        assert!(cands.iter().any(|&(a, _)| a < 10));
        assert!(cands.iter().any(|&(_, b)| b < 4));
    }
}
