//! Shared substrates built from scratch for the offline toolchain:
//! PRNG, streaming stats, least-squares fitting, JSON, CLI parsing,
//! property testing, and table/CSV formatting.

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod lazy;
pub mod lsq;
pub mod quick;
pub mod rng;
pub mod stats;
pub mod table;
