//! Micro-benchmark harness (criterion is unavailable offline): warmup +
//! timed iterations with mean / p50 / p99 reporting, used by the
//! `rust/benches/*.rs` targets (`cargo bench`).

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p99 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Run `f` for `warmup` + `iters` iterations and report timing stats.
/// The closure's return value is consumed via `std::hint::black_box` so
/// the optimizer cannot elide the work.
pub fn bench<T, F: FnMut() -> T>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: crate::util::stats::percentile_sorted(&samples, 0.5),
        p99_ns: crate::util::stats::percentile_sorted(&samples, 0.99),
        min_ns: samples[0],
    };
    println!("{}", r.report());
    r
}

/// Time a single invocation (for expensive end-to-end runs).
pub fn bench_once<T, F: FnOnce() -> T>(name: &str, f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    let ns = t0.elapsed().as_nanos() as f64;
    println!(
        "{:<44} {:>10} iters  once {:>12}",
        name,
        1,
        fmt_ns(ns)
    );
    (out, ns)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop-ish", 5, 50, || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p99_ns);
        assert!(r.min_ns <= r.p50_ns);
        assert_eq!(r.iters, 50);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("us"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }
}
