//! Least-squares fitting substrate (Sec. 3.1 "obtained by fitting ... using
//! the least squares method").
//!
//! * `linear_lsq` — general linear least squares over arbitrary basis
//!   functions via normal equations + Gaussian elimination with partial
//!   pivoting (design matrices here are tiny: <= 11 x 5).
//! * `polyfit` — polynomial basis convenience.
//! * `fit_line` — slope/intercept (used for power & cache-util vs.
//!   processing ability, Fig. 9, and scheduling delay vs. #workloads).
//! * `fit_kact` — the paper's Eq. (11): nonlinear in k4 only, so a
//!   golden-section search over k4 wraps a linear solve for (k1,k2,k3,k5).

/// Solve `A x = b` (n x n) by Gaussian elimination with partial pivoting.
pub fn solve(a: &[Vec<f64>], b: &[f64]) -> Option<Vec<f64>> {
    let n = b.len();
    assert!(a.len() == n && a.iter().all(|r| r.len() == n));
    let mut m: Vec<Vec<f64>> = a
        .iter()
        .zip(b.iter())
        .map(|(row, &bi)| {
            let mut r = row.clone();
            r.push(bi);
            r
        })
        .collect();

    for col in 0..n {
        // partial pivot
        let piv = (col..n).max_by(|&i, &j| {
            m[i][col]
                .abs()
                .partial_cmp(&m[j][col].abs())
                .unwrap_or(std::cmp::Ordering::Equal)
        })?;
        if m[piv][col].abs() < 1e-12 {
            return None; // singular
        }
        m.swap(col, piv);
        for row in 0..n {
            if row != col {
                let f = m[row][col] / m[col][col];
                for k in col..=n {
                    m[row][k] -= f * m[col][k];
                }
            }
        }
    }
    Some((0..n).map(|i| m[i][n] / m[i][i]).collect())
}

/// Linear least squares: find `c` minimising `||X c - y||²` where
/// `X[i][j] = basis_j(sample_i)` is given row-wise.
pub fn linear_lsq(design: &[Vec<f64>], y: &[f64]) -> Option<Vec<f64>> {
    let n = design.len();
    if n == 0 || n != y.len() {
        return None;
    }
    let p = design[0].len();
    // Normal equations: (X^T X) c = X^T y, with tiny ridge for conditioning.
    let mut xtx = vec![vec![0.0; p]; p];
    let mut xty = vec![0.0; p];
    for (row, &yi) in design.iter().zip(y.iter()) {
        assert_eq!(row.len(), p);
        for j in 0..p {
            xty[j] += row[j] * yi;
            for k in 0..p {
                xtx[j][k] += row[j] * row[k];
            }
        }
    }
    for (j, row) in xtx.iter_mut().enumerate() {
        row[j] += 1e-9;
    }
    solve(&xtx, &xty)
}

/// Fit `y = c[0] + c[1] x + ... + c[deg] x^deg`.
pub fn polyfit(x: &[f64], y: &[f64], deg: usize) -> Option<Vec<f64>> {
    let design: Vec<Vec<f64>> = x
        .iter()
        .map(|&xi| (0..=deg).map(|d| xi.powi(d as i32)).collect())
        .collect();
    linear_lsq(&design, y)
}

/// Fit `y = a x + b`; returns (a, b).
pub fn fit_line(x: &[f64], y: &[f64]) -> Option<(f64, f64)> {
    let c = polyfit(x, y, 1)?;
    Some((c[1], c[0]))
}

/// Coefficients of the paper's Eq. (11):
/// `k_act = (k1 b² + k2 b + k3) / (r + k4) + k5`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KactFit {
    pub k1: f64,
    pub k2: f64,
    pub k3: f64,
    pub k4: f64,
    pub k5: f64,
    /// Residual sum of squares of the winning fit.
    pub rss: f64,
}

impl KactFit {
    pub fn eval(&self, batch: f64, r: f64) -> f64 {
        (self.k1 * batch * batch + self.k2 * batch + self.k3) / (r + self.k4) + self.k5
    }
}

fn kact_rss_for_k4(samples: &[(f64, f64, f64)], k4: f64) -> Option<(f64, Vec<f64>)> {
    // Given k4, the model is linear in (k1, k2, k3, k5) with basis
    // [b²/(r+k4), b/(r+k4), 1/(r+k4), 1].
    let design: Vec<Vec<f64>> = samples
        .iter()
        .map(|&(b, r, _)| {
            let d = r + k4;
            vec![b * b / d, b / d, 1.0 / d, 1.0]
        })
        .collect();
    let y: Vec<f64> = samples.iter().map(|&(_, _, t)| t).collect();
    let c = linear_lsq(&design, &y)?;
    let rss: f64 = samples
        .iter()
        .map(|&(b, r, t)| {
            let d = r + k4;
            let pred = c[0] * b * b / d + c[1] * b / d + c[2] / d + c[3];
            (pred - t).powi(2)
        })
        .sum();
    Some((rss, c))
}

/// Fit Eq. (11) from `(batch, resources, active_time)` samples.
/// `resources` in (0, 1]; golden-section search over `k4 ∈ [0, 1]`.
pub fn fit_kact(samples: &[(f64, f64, f64)]) -> Option<KactFit> {
    if samples.len() < 5 {
        return None;
    }
    let phi = (5f64.sqrt() - 1.0) / 2.0;
    let mut best: Option<(f64, f64, Vec<f64>)> = None;
    // Golden-section over unimodal-ish RSS(k4); also coarse-scan to avoid
    // local minima from noisy profiles.
    for i in 0..=20 {
        let k4 = i as f64 / 20.0;
        if let Some((rss, c)) = kact_rss_for_k4(samples, k4) {
            if best.as_ref().map_or(true, |(b, _, _)| rss < *b) {
                best = Some((rss, k4, c));
            }
        }
    }
    let centre = best.as_ref()?.1;
    let mut lo = (centre - 0.05).max(0.0);
    let mut hi = (centre + 0.05).min(1.0);
    for _ in 0..40 {
        let m1 = hi - phi * (hi - lo);
        let m2 = lo + phi * (hi - lo);
        let r1 = kact_rss_for_k4(samples, m1).map(|(r, _)| r).unwrap_or(f64::INFINITY);
        let r2 = kact_rss_for_k4(samples, m2).map(|(r, _)| r).unwrap_or(f64::INFINITY);
        if r1 < r2 {
            hi = m2;
        } else {
            lo = m1;
        }
    }
    let k4 = 0.5 * (lo + hi);
    let (rss, c) = kact_rss_for_k4(samples, k4)?;
    let (rss, k4, c) = if rss < best.as_ref()?.0 {
        (rss, k4, c)
    } else {
        best.unwrap()
    };
    Some(KactFit {
        k1: c[0],
        k2: c[1],
        k3: c[2],
        k4,
        k5: c[3],
        rss,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solve_identity() {
        let a = vec![vec![1.0, 0.0], vec![0.0, 1.0]];
        let x = solve(&a, &[3.0, 4.0]).unwrap();
        assert!((x[0] - 3.0).abs() < 1e-12 && (x[1] - 4.0).abs() < 1e-12);
    }

    #[test]
    fn solve_singular_is_none() {
        let a = vec![vec![1.0, 2.0], vec![2.0, 4.0]];
        assert!(solve(&a, &[1.0, 2.0]).is_none());
    }

    #[test]
    fn polyfit_exact_quadratic() {
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|&v| 2.0 * v * v - 3.0 * v + 1.0).collect();
        let c = polyfit(&x, &y, 2).unwrap();
        assert!((c[0] - 1.0).abs() < 1e-6, "{c:?}");
        assert!((c[1] + 3.0).abs() < 1e-6, "{c:?}");
        assert!((c[2] - 2.0).abs() < 1e-6, "{c:?}");
    }

    #[test]
    fn fit_line_recovers() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.5, 4.5, 6.5, 8.5];
        let (a, b) = fit_line(&x, &y).unwrap();
        assert!((a - 2.0).abs() < 1e-9 && (b - 0.5).abs() < 1e-9);
    }

    #[test]
    fn kact_fit_recovers_ground_truth() {
        // Ground truth in paper units (active time ms, r in (0,1]).
        let truth = KactFit {
            k1: 0.002,
            k2: 0.11,
            k3: 0.35,
            k4: 0.08,
            k5: 0.12,
            rss: 0.0,
        };
        let mut samples = Vec::new();
        for &b in &[1.0, 4.0, 8.0, 16.0, 32.0] {
            for &r in &[0.2, 0.4, 0.6, 0.8, 1.0] {
                samples.push((b, r, truth.eval(b, r)));
            }
        }
        let fit = fit_kact(&samples).unwrap();
        for &(b, r, t) in &samples {
            let rel = (fit.eval(b, r) - t).abs() / t.max(1e-9);
            assert!(rel < 1e-3, "b={b} r={r} rel={rel} fit={fit:?}");
        }
        assert!((fit.k4 - truth.k4).abs() < 0.02, "{fit:?}");
    }

    #[test]
    fn kact_fit_with_noise_is_close() {
        let truth = KactFit {
            k1: 0.001,
            k2: 0.2,
            k3: 0.5,
            k4: 0.05,
            k5: 0.3,
            rss: 0.0,
        };
        let mut rng = crate::util::rng::Rng::new(17);
        let mut samples = Vec::new();
        for &b in &[1.0, 2.0, 4.0, 8.0, 16.0, 32.0] {
            for &r in &[0.2, 0.35, 0.5, 0.75, 1.0] {
                let t = truth.eval(b, r) * (1.0 + 0.01 * rng.normal());
                samples.push((b, r, t));
            }
        }
        let fit = fit_kact(&samples).unwrap();
        // predictions within a few percent despite 1% measurement noise
        for &(b, r, _) in &samples {
            let rel = (fit.eval(b, r) - truth.eval(b, r)).abs() / truth.eval(b, r);
            assert!(rel < 0.05, "b={b} r={r} rel={rel}");
        }
    }

    #[test]
    fn kact_fit_needs_enough_samples() {
        assert!(fit_kact(&[(1.0, 0.5, 1.0); 4]).is_none());
    }
}
