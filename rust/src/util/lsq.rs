//! Least-squares fitting substrate (Sec. 3.1 "obtained by fitting ... using
//! the least squares method").
//!
//! * `linear_lsq` — general linear least squares over arbitrary basis
//!   functions via normal equations + Gaussian elimination with partial
//!   pivoting (design matrices here are tiny: <= 11 x 5).
//! * `polyfit` — polynomial basis convenience.
//! * `fit_line` — slope/intercept (used for power & cache-util vs.
//!   processing ability, Fig. 9, and scheduling delay vs. #workloads).
//! * `fit_kact` — the paper's Eq. (11): nonlinear in k4 only, so a
//!   golden-section search over k4 wraps a linear solve for (k1,k2,k3,k5).

/// Solve `A x = b` (n x n) by Gaussian elimination with partial pivoting.
pub fn solve(a: &[Vec<f64>], b: &[f64]) -> Option<Vec<f64>> {
    let n = b.len();
    assert!(a.len() == n && a.iter().all(|r| r.len() == n));
    let mut m: Vec<Vec<f64>> = a
        .iter()
        .zip(b.iter())
        .map(|(row, &bi)| {
            let mut r = row.clone();
            r.push(bi);
            r
        })
        .collect();

    for col in 0..n {
        // partial pivot
        let piv = (col..n).max_by(|&i, &j| {
            m[i][col]
                .abs()
                .partial_cmp(&m[j][col].abs())
                .unwrap_or(std::cmp::Ordering::Equal)
        })?;
        if m[piv][col].abs() < 1e-12 {
            return None; // singular
        }
        m.swap(col, piv);
        for row in 0..n {
            if row != col {
                let f = m[row][col] / m[col][col];
                for k in col..=n {
                    m[row][k] -= f * m[col][k];
                }
            }
        }
    }
    Some((0..n).map(|i| m[i][n] / m[i][i]).collect())
}

/// Linear least squares: find `c` minimising `||X c - y||²` where
/// `X[i][j] = basis_j(sample_i)` is given row-wise.
pub fn linear_lsq(design: &[Vec<f64>], y: &[f64]) -> Option<Vec<f64>> {
    let n = design.len();
    if n == 0 || n != y.len() {
        return None;
    }
    let p = design[0].len();
    // Normal equations: (X^T X) c = X^T y, with tiny ridge for conditioning.
    let mut xtx = vec![vec![0.0; p]; p];
    let mut xty = vec![0.0; p];
    for (row, &yi) in design.iter().zip(y.iter()) {
        assert_eq!(row.len(), p);
        for j in 0..p {
            xty[j] += row[j] * yi;
            for k in 0..p {
                xtx[j][k] += row[j] * row[k];
            }
        }
    }
    for (j, row) in xtx.iter_mut().enumerate() {
        row[j] += 1e-9;
    }
    solve(&xtx, &xty)
}

/// Fit `y = c[0] + c[1] x + ... + c[deg] x^deg`.
pub fn polyfit(x: &[f64], y: &[f64], deg: usize) -> Option<Vec<f64>> {
    let design: Vec<Vec<f64>> = x
        .iter()
        .map(|&xi| (0..=deg).map(|d| xi.powi(d as i32)).collect())
        .collect();
    linear_lsq(&design, y)
}

/// Fit `y = a x + b`; returns (a, b).
pub fn fit_line(x: &[f64], y: &[f64]) -> Option<(f64, f64)> {
    let c = polyfit(x, y, 1)?;
    Some((c[1], c[0]))
}

/// Coefficients of the paper's Eq. (11):
/// `k_act = (k1 b² + k2 b + k3) / (r + k4) + k5`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KactFit {
    pub k1: f64,
    pub k2: f64,
    pub k3: f64,
    pub k4: f64,
    pub k5: f64,
    /// Residual sum of squares of the winning fit.
    pub rss: f64,
}

impl KactFit {
    pub fn eval(&self, batch: f64, r: f64) -> f64 {
        (self.k1 * batch * batch + self.k2 * batch + self.k3) / (r + self.k4) + self.k5
    }
}

fn kact_rss_for_k4(samples: &[(f64, f64, f64)], k4: f64) -> Option<(f64, Vec<f64>)> {
    // Given k4, the model is linear in (k1, k2, k3, k5) with basis
    // [b²/(r+k4), b/(r+k4), 1/(r+k4), 1].
    let design: Vec<Vec<f64>> = samples
        .iter()
        .map(|&(b, r, _)| {
            let d = r + k4;
            vec![b * b / d, b / d, 1.0 / d, 1.0]
        })
        .collect();
    let y: Vec<f64> = samples.iter().map(|&(_, _, t)| t).collect();
    let c = linear_lsq(&design, &y)?;
    let rss: f64 = samples
        .iter()
        .map(|&(b, r, t)| {
            let d = r + k4;
            let pred = c[0] * b * b / d + c[1] * b / d + c[2] / d + c[3];
            (pred - t).powi(2)
        })
        .sum();
    Some((rss, c))
}

/// Fit Eq. (11) from `(batch, resources, active_time)` samples.
/// `resources` in (0, 1]; golden-section search over `k4 ∈ [0, 1]`.
pub fn fit_kact(samples: &[(f64, f64, f64)]) -> Option<KactFit> {
    if samples.len() < 5 {
        return None;
    }
    let phi = (5f64.sqrt() - 1.0) / 2.0;
    let mut best: Option<(f64, f64, Vec<f64>)> = None;
    // Golden-section over unimodal-ish RSS(k4); also coarse-scan to avoid
    // local minima from noisy profiles.
    for i in 0..=20 {
        let k4 = i as f64 / 20.0;
        if let Some((rss, c)) = kact_rss_for_k4(samples, k4) {
            if best.as_ref().map_or(true, |(b, _, _)| rss < *b) {
                best = Some((rss, k4, c));
            }
        }
    }
    let centre = best.as_ref()?.1;
    let mut lo = (centre - 0.05).max(0.0);
    let mut hi = (centre + 0.05).min(1.0);
    for _ in 0..40 {
        let m1 = hi - phi * (hi - lo);
        let m2 = lo + phi * (hi - lo);
        let r1 = kact_rss_for_k4(samples, m1).map(|(r, _)| r).unwrap_or(f64::INFINITY);
        let r2 = kact_rss_for_k4(samples, m2).map(|(r, _)| r).unwrap_or(f64::INFINITY);
        if r1 < r2 {
            hi = m2;
        } else {
            lo = m1;
        }
    }
    let k4 = 0.5 * (lo + hi);
    let (rss, c) = kact_rss_for_k4(samples, k4)?;
    let (rss, k4, c) = if rss < best.as_ref()?.0 {
        (rss, k4, c)
    } else {
        best.unwrap()
    };
    Some(KactFit {
        k1: c[0],
        k2: c[1],
        k3: c[2],
        k4,
        k5: c[3],
        rss,
    })
}

/// Recursive least squares over a 2-term basis: fits `y ≈ theta · x` one
/// sample at a time via the Sherman-Morrison update of the inverse normal
/// equations, with exponential forgetting `lambda` (1.0 = plain LSQ).
///
/// The online counterpart of `linear_lsq` for streams — used by
/// `perfmodel::CalibratedModel` to fit per-workload-class residual
/// corrections (`observed = a * predicted + b`) from serving telemetry
/// without retaining the samples.
#[derive(Debug, Clone, PartialEq)]
pub struct Rls2 {
    theta: [f64; 2],
    /// Inverse covariance estimate P (symmetric 2x2).
    p: [[f64; 2]; 2],
    lambda: f64,
    n: u64,
}

impl Rls2 {
    /// `init_theta` is the prior coefficient vector; `p0` scales the prior
    /// covariance (large = weak prior, the first samples dominate);
    /// `lambda` in (0, 1] is the forgetting factor.
    pub fn new(init_theta: [f64; 2], p0: f64, lambda: f64) -> Rls2 {
        assert!(p0 > 0.0 && lambda > 0.0 && lambda <= 1.0);
        Rls2 {
            theta: init_theta,
            p: [[p0, 0.0], [0.0, p0]],
            lambda,
            n: 0,
        }
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    pub fn theta(&self) -> [f64; 2] {
        self.theta
    }

    pub fn predict(&self, x: [f64; 2]) -> f64 {
        self.theta[0] * x[0] + self.theta[1] * x[1]
    }

    /// Absorb one `(x, y)` sample.  Non-finite inputs are ignored (a
    /// poisoned P matrix would corrupt every later prediction).
    pub fn update(&mut self, x: [f64; 2], y: f64) {
        if !(x[0].is_finite() && x[1].is_finite() && y.is_finite()) {
            return;
        }
        let px = [
            self.p[0][0] * x[0] + self.p[0][1] * x[1],
            self.p[1][0] * x[0] + self.p[1][1] * x[1],
        ];
        let denom = self.lambda + x[0] * px[0] + x[1] * px[1];
        if denom <= 1e-12 {
            return;
        }
        let k = [px[0] / denom, px[1] / denom];
        let err = y - self.predict(x);
        self.theta[0] += k[0] * err;
        self.theta[1] += k[1] * err;
        // P <- (P - k (x^T P)) / lambda; x^T P == px^T by symmetry.
        for i in 0..2 {
            for j in 0..2 {
                self.p[i][j] = (self.p[i][j] - k[i] * px[j]) / self.lambda;
            }
        }
        // Anti-wind-up: with lambda < 1 and a barely-exciting regressor
        // (a steady operating point feeds near-constant x), P inflates by
        // ~1/lambda per update along the unexcited direction — classic
        // RLS covariance wind-up that first makes theta noise-hypersensitive
        // and eventually overflows P to inf (NaN-poisoning every later
        // update).  Rescale whenever the trace passes the cap; the
        // direction of P is preserved, only its magnitude is bounded.
        let tr = self.p[0][0] + self.p[1][1];
        if tr > P_TRACE_CAP {
            let s = P_TRACE_CAP / tr;
            for row in &mut self.p {
                for v in row {
                    *v *= s;
                }
            }
        }
        self.n += 1;
    }
}

/// Upper bound on trace(P): large enough never to bind during normal
/// convergence (P0 starts at ~1e2-1e6 per axis and shrinks along excited
/// directions), small enough that unbounded forgetting-driven growth is
/// cut off long before f64 overflow.
const P_TRACE_CAP: f64 = 1e7;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solve_identity() {
        let a = vec![vec![1.0, 0.0], vec![0.0, 1.0]];
        let x = solve(&a, &[3.0, 4.0]).unwrap();
        assert!((x[0] - 3.0).abs() < 1e-12 && (x[1] - 4.0).abs() < 1e-12);
    }

    #[test]
    fn solve_singular_is_none() {
        let a = vec![vec![1.0, 2.0], vec![2.0, 4.0]];
        assert!(solve(&a, &[1.0, 2.0]).is_none());
    }

    #[test]
    fn polyfit_exact_quadratic() {
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|&v| 2.0 * v * v - 3.0 * v + 1.0).collect();
        let c = polyfit(&x, &y, 2).unwrap();
        assert!((c[0] - 1.0).abs() < 1e-6, "{c:?}");
        assert!((c[1] + 3.0).abs() < 1e-6, "{c:?}");
        assert!((c[2] - 2.0).abs() < 1e-6, "{c:?}");
    }

    #[test]
    fn fit_line_recovers() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.5, 4.5, 6.5, 8.5];
        let (a, b) = fit_line(&x, &y).unwrap();
        assert!((a - 2.0).abs() < 1e-9 && (b - 0.5).abs() < 1e-9);
    }

    #[test]
    fn kact_fit_recovers_ground_truth() {
        // Ground truth in paper units (active time ms, r in (0,1]).
        let truth = KactFit {
            k1: 0.002,
            k2: 0.11,
            k3: 0.35,
            k4: 0.08,
            k5: 0.12,
            rss: 0.0,
        };
        let mut samples = Vec::new();
        for &b in &[1.0, 4.0, 8.0, 16.0, 32.0] {
            for &r in &[0.2, 0.4, 0.6, 0.8, 1.0] {
                samples.push((b, r, truth.eval(b, r)));
            }
        }
        let fit = fit_kact(&samples).unwrap();
        for &(b, r, t) in &samples {
            let rel = (fit.eval(b, r) - t).abs() / t.max(1e-9);
            assert!(rel < 1e-3, "b={b} r={r} rel={rel} fit={fit:?}");
        }
        assert!((fit.k4 - truth.k4).abs() < 0.02, "{fit:?}");
    }

    #[test]
    fn kact_fit_with_noise_is_close() {
        let truth = KactFit {
            k1: 0.001,
            k2: 0.2,
            k3: 0.5,
            k4: 0.05,
            k5: 0.3,
            rss: 0.0,
        };
        let mut rng = crate::util::rng::Rng::new(17);
        let mut samples = Vec::new();
        for &b in &[1.0, 2.0, 4.0, 8.0, 16.0, 32.0] {
            for &r in &[0.2, 0.35, 0.5, 0.75, 1.0] {
                let t = truth.eval(b, r) * (1.0 + 0.01 * rng.normal());
                samples.push((b, r, t));
            }
        }
        let fit = fit_kact(&samples).unwrap();
        // predictions within a few percent despite 1% measurement noise
        for &(b, r, _) in &samples {
            let rel = (fit.eval(b, r) - truth.eval(b, r)).abs() / truth.eval(b, r);
            assert!(rel < 0.05, "b={b} r={r} rel={rel}");
        }
    }

    #[test]
    fn kact_fit_needs_enough_samples() {
        assert!(fit_kact(&[(1.0, 0.5, 1.0); 4]).is_none());
    }

    #[test]
    fn rls_recovers_a_line_from_a_stream() {
        // y = 1.3 x + 0.7 with mild noise; the recursive fit must land on
        // the truth and its predictions must interpolate.
        let mut rls = Rls2::new([1.0, 0.0], 1e3, 1.0);
        let mut rng = crate::util::rng::Rng::new(5);
        for i in 0..200 {
            let x = 5.0 + (i % 40) as f64;
            let y = 1.3 * x + 0.7 + 0.02 * rng.normal();
            rls.update([x, 1.0], y);
        }
        assert_eq!(rls.n(), 200);
        let [a, b] = rls.theta();
        assert!((a - 1.3).abs() < 0.02, "a = {a}");
        assert!((b - 0.7).abs() < 0.4, "b = {b}");
        assert!((rls.predict([20.0, 1.0]) - 26.7).abs() < 0.2);
    }

    #[test]
    fn rls_agrees_with_batch_lsq() {
        // With lambda = 1 and a weak prior, the stream solution must match
        // the batch normal-equations solution on the same samples.
        let xs = [2.0, 4.0, 7.0, 11.0, 16.0, 22.0];
        let ys: Vec<f64> = xs.iter().map(|x| 0.8 * x + 2.0).collect();
        let mut rls = Rls2::new([0.0, 0.0], 1e6, 1.0);
        for (&x, &y) in xs.iter().zip(&ys) {
            rls.update([x, 1.0], y);
        }
        let design: Vec<Vec<f64>> = xs.iter().map(|&x| vec![x, 1.0]).collect();
        let c = linear_lsq(&design, &ys).unwrap();
        assert!((rls.theta()[0] - c[0]).abs() < 1e-3, "{:?} vs {c:?}", rls.theta());
        assert!((rls.theta()[1] - c[1]).abs() < 1e-2);
    }

    #[test]
    fn rls_survives_a_long_steady_stream_without_wind_up() {
        // Forgetting (lambda < 1) + a constant regressor is the classic
        // covariance wind-up case: without the trace cap, P overflows
        // after ~1e5 updates and the fit NaN-poisons itself.  A long
        // steady stream must stay finite and keep predicting the stream.
        let mut rls = Rls2::new([1.0, 0.0], 100.0, 0.995);
        let x = [20.0, 1.0];
        for _ in 0..300_000 {
            rls.update(x, 26.0);
        }
        let [a, b] = rls.theta();
        assert!(a.is_finite() && b.is_finite(), "theta wound up: {a}, {b}");
        assert!((rls.predict(x) - 26.0).abs() < 1e-6);
        // ...and it still adapts afterwards (P did not collapse to zero)
        for _ in 0..500 {
            rls.update(x, 30.0);
        }
        assert!((rls.predict(x) - 30.0).abs() < 0.5, "{}", rls.predict(x));
    }

    #[test]
    fn rls_ignores_poison() {
        let mut rls = Rls2::new([1.0, 0.0], 100.0, 0.99);
        rls.update([f64::NAN, 1.0], 3.0);
        rls.update([2.0, 1.0], f64::INFINITY);
        assert_eq!(rls.n(), 0);
        assert_eq!(rls.theta(), [1.0, 0.0]);
        rls.update([2.0, 1.0], 3.0);
        assert_eq!(rls.n(), 1);
    }
}
