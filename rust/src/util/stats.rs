//! Streaming statistics substrate: Welford online moments, percentile
//! estimation, a fixed-bucket latency histogram (hdrhistogram is not
//! available offline), and the time-bounded `SlidingWindow` the SLO
//! monitor computes P99 over.

use std::collections::VecDeque;

/// Online mean/variance (Welford).
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        self.mean += d * other.n as f64 / n as f64;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64 / n as f64);
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Exact percentile of a sample (nearest-rank on a sorted copy).
/// `q` in [0, 1]; e.g. `percentile(&lat, 0.99)` for P99.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    // Drop NaN samples up front: the old partial_cmp().unwrap() comparator
    // panicked mid-sort on one bad sample, and total_cmp alone would place
    // sign-bit NaNs (e.g. x86-64's 0.0/0.0) at the FRONT, corrupting low
    // quantiles.  Ranks are taken over the valid samples only.
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    if v.is_empty() {
        return f64::NAN;
    }
    v.sort_by(f64::total_cmp);
    percentile_sorted(&v, q)
}

/// Percentile of an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let q = q.clamp(0.0, 1.0);
    let rank = (q * (sorted.len() as f64 - 1.0)).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        f64::NAN
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Time-bounded sample window over a monotonic clock: a ring buffer of
/// `(timestamp, value)` pairs that retains only the last `span_ms` of
/// samples.  `push` is amortized O(1) (each sample is enqueued once and
/// evicted once), so long-horizon serving runs never rescan their full
/// lifetime history; percentile/mean queries cost O(window), bounded by
/// `span_ms x arrival rate` rather than total served requests.
///
/// Determinism: the retained contents are a pure function of the pushed
/// `(t, value)` sequence — eviction compares timestamps only, so identical
/// seeds replay to bit-identical windows.
#[derive(Debug, Clone)]
pub struct SlidingWindow {
    span_ms: f64,
    buf: VecDeque<(f64, f64)>,
}

impl SlidingWindow {
    pub fn new(span_ms: f64) -> SlidingWindow {
        SlidingWindow {
            span_ms,
            buf: VecDeque::new(),
        }
    }

    pub fn span_ms(&self) -> f64 {
        self.span_ms
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn clear(&mut self) {
        self.buf.clear();
    }

    /// Record `value` at time `t` (ms).  Timestamps must be non-decreasing
    /// (the DES pops events in time order); samples older than
    /// `t - span_ms` are evicted from the front.
    pub fn push(&mut self, t: f64, value: f64) {
        debug_assert!(
            self.buf.back().map_or(true, |&(t0, _)| t >= t0),
            "SlidingWindow timestamps must be monotonic"
        );
        self.buf.push_back((t, value));
        let cutoff = t - self.span_ms;
        while let Some(&(t0, _)) = self.buf.front() {
            if t0 < cutoff {
                self.buf.pop_front();
            } else {
                break;
            }
        }
    }

    /// Timestamp of the newest retained sample, `-inf` when empty — an
    /// O(1) emptiness proof for the `*_since` queries: `latest_t() <
    /// since` holds iff the since-filtered view is empty (timestamps are
    /// monotone, so the newest sample bounds them all).  The idle-aware
    /// monitor gates its per-replica window walks on this.
    pub fn latest_t(&self) -> f64 {
        self.buf.back().map_or(f64::NEG_INFINITY, |&(t, _)| t)
    }

    /// Number of samples recorded at `t >= since` (no allocation — the
    /// rate estimator counts arrivals in its window every monitor tick).
    pub fn count_since(&self, since: f64) -> usize {
        self.buf.iter().filter(|(t, _)| *t >= since).count()
    }

    /// Values recorded at `t >= since` (newest-bounded by the span).
    pub fn values_since(&self, since: f64) -> Vec<f64> {
        let mut out = Vec::new();
        self.values_since_into(since, &mut out);
        out
    }

    /// Append the values recorded at `t >= since` to `out` without
    /// allocating a fresh vector — the timeline sampler pools every
    /// replica of a group into one reused scratch buffer per monitor
    /// tick, so large sweeps don't churn an allocation per replica.
    pub fn values_since_into(&self, since: f64, out: &mut Vec<f64>) {
        out.extend(
            self.buf
                .iter()
                .filter(|(t, _)| *t >= since)
                .map(|(_, v)| *v),
        );
    }

    /// Percentile of the samples at `t >= since`; `None` below
    /// `min_samples` (an SLO verdict needs statistical mass).
    pub fn percentile_since(&self, since: f64, q: f64, min_samples: usize) -> Option<f64> {
        let vals = self.values_since(since);
        if vals.len() < min_samples.max(1) {
            None
        } else {
            Some(percentile(&vals, q))
        }
    }

    /// Mean of the samples at `t >= since`; `None` below `min_samples`.
    pub fn mean_since(&self, since: f64, min_samples: usize) -> Option<f64> {
        let vals = self.values_since(since);
        if vals.len() < min_samples.max(1) {
            None
        } else {
            Some(mean(&vals))
        }
    }
}

/// Log-bucketed latency histogram: 1 us .. ~100 s with ~2% relative
/// resolution; O(1) record, O(buckets) percentile.  Values in seconds.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    total: u64,
    /// exact tracking of min/max for reporting
    min: f64,
    max: f64,
}

const HIST_BUCKETS: usize = 1024;
const HIST_LO: f64 = 1e-6; // 1 microsecond
const HIST_HI: f64 = 100.0; // 100 seconds

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram {
            counts: vec![0; HIST_BUCKETS],
            total: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    fn bucket_of(x: f64) -> usize {
        let x = x.clamp(HIST_LO, HIST_HI);
        let t = (x / HIST_LO).ln() / (HIST_HI / HIST_LO).ln();
        ((t * (HIST_BUCKETS - 1) as f64).round() as usize).min(HIST_BUCKETS - 1)
    }

    fn bucket_value(i: usize) -> f64 {
        let t = i as f64 / (HIST_BUCKETS - 1) as f64;
        HIST_LO * (HIST_HI / HIST_LO).powf(t)
    }

    pub fn record(&mut self, x: f64) {
        self.counts[Self::bucket_of(x)] += 1;
        self.total += 1;
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn percentile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return Self::bucket_value(i);
            }
        }
        self.max
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn clear(&mut self) {
        self.counts.iter_mut().for_each(|c| *c = 0);
        self.total = 0;
        self.min = f64::INFINITY;
        self.max = f64::NEG_INFINITY;
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 3.5, -1.0, 0.25, 9.0];
        let mut o = OnlineStats::new();
        xs.iter().for_each(|&x| o.push(x));
        assert!((o.mean() - mean(&xs)).abs() < 1e-12);
        assert!((o.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(o.count(), 6);
        assert_eq!(o.min(), -1.0);
        assert_eq!(o.max(), 9.0);
    }

    #[test]
    fn welford_merge() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        xs[..40].iter().for_each(|&x| a.push(x));
        xs[40..].iter().for_each(|&x| b.push(x));
        a.merge(&b);
        let mut all = OnlineStats::new();
        xs.iter().for_each(|&x| all.push(x));
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.std() - all.std()).abs() < 1e-9);
    }

    #[test]
    fn latest_t_tracks_the_newest_sample_and_survives_expiry() {
        let mut w = SlidingWindow::new(100.0);
        // empty window: NEG_INFINITY is strictly below any `since`, so
        // the idle-skip `latest_t() < since` proof holds vacuously
        assert_eq!(w.latest_t(), f64::NEG_INFINITY);
        w.push(10.0, 1.0);
        w.push(50.0, 2.0);
        assert_eq!(w.latest_t(), 50.0);
        // pushing past the span expires the old samples but the newest
        // timestamp is by construction the back of the buffer
        w.push(500.0, 3.0);
        assert_eq!(w.latest_t(), 500.0);
    }

    #[test]
    fn percentile_basic() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 100.0);
        let p50 = percentile(&xs, 0.5);
        assert!((p50 - 50.0).abs() <= 1.0);
        let p99 = percentile(&xs, 0.99);
        assert!((p99 - 99.0).abs() <= 1.0);
    }

    #[test]
    fn percentile_tolerates_nan_samples() {
        // Regression: a single NaN sample used to panic the
        // partial_cmp().unwrap() comparator inside sort.  NaNs are now
        // excluded and ranks run over the valid samples — including
        // sign-bit NaNs like 0.0/0.0, which total_cmp alone would sort
        // to the front.
        let xs = [3.0, f64::NAN, 1.0, 0.0 / 0.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 0.5), 2.0);
        assert_eq!(percentile(&xs, 1.0), 3.0);
        // all-NaN input degrades to NaN, same as empty
        assert!(percentile(&[f64::NAN], 0.5).is_nan());
    }

    #[test]
    fn histogram_percentile_accuracy() {
        let mut h = LatencyHistogram::new();
        let mut r = Rng::new(2);
        let mut xs = Vec::new();
        for _ in 0..50_000 {
            // latencies around 5-50 ms
            let x = 0.005 + 0.045 * r.f64();
            h.record(x);
            xs.push(x);
        }
        for q in [0.5, 0.9, 0.99] {
            let exact = percentile(&xs, q);
            let est = h.percentile(q);
            let rel = (est - exact).abs() / exact;
            assert!(rel < 0.03, "q={q} exact={exact} est={est} rel={rel}");
        }
    }

    #[test]
    fn histogram_clear_and_merge() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(0.010);
        b.record(0.030);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        a.clear();
        assert_eq!(a.count(), 0);
        assert!(a.percentile(0.5).is_nan());
    }

    #[test]
    fn sliding_window_evicts_old_samples() {
        let mut w = SlidingWindow::new(1_000.0);
        for i in 0..100 {
            w.push(i as f64 * 100.0, i as f64);
        }
        // last push at t=9900 -> cutoff 8900 -> retains t in [8900, 9900]
        assert_eq!(w.len(), 11, "window holds only the last second");
        let vals = w.values_since(9_500.0);
        assert_eq!(vals, vec![95.0, 96.0, 97.0, 98.0, 99.0]);
        assert_eq!(w.count_since(9_500.0), 5);
        assert_eq!(w.count_since(0.0), w.len());
    }

    #[test]
    fn sliding_window_percentile_and_mean() {
        let mut w = SlidingWindow::new(10_000.0);
        for i in 1..=100 {
            w.push(i as f64, i as f64);
        }
        let p99 = w.percentile_since(0.0, 0.99, 20).unwrap();
        assert!((p99 - 99.0).abs() <= 1.0);
        assert!(w.percentile_since(0.0, 0.99, 200).is_none(), "min_samples");
        let m = w.mean_since(51.0, 1).unwrap();
        assert!((m - 75.5).abs() < 1e-9);
        assert!(w.mean_since(1_000.0, 1).is_none(), "no samples in range");
    }

    #[test]
    fn sliding_window_bounded_versus_lifetime() {
        // The size after N pushes depends on the span, not on N — the
        // property that makes long-horizon monitor ticks O(window).
        let mut w = SlidingWindow::new(500.0);
        for i in 0..1_000_000u64 {
            w.push(i as f64, 1.0);
        }
        assert!(w.len() <= 502, "window grew with lifetime: {}", w.len());
        w.clear();
        assert!(w.is_empty());
    }

    #[test]
    fn empty_stats_are_nan() {
        assert!(mean(&[]).is_nan());
        assert!(percentile(&[], 0.5).is_nan());
        assert!(OnlineStats::new().mean().is_nan());
    }
}
