//! Minimal JSON substrate (serde is unavailable offline): a recursive-descent
//! parser and a writer, used for the artifact manifest, golden files,
//! experiment results, and configuration.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // -- constructors ------------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), val.into());
        }
        self
    }

    // -- accessors ---------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `get` chained through a dotted path: `j.path("models.0.name")`.
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for seg in path.split('.') {
            cur = match cur {
                Json::Obj(m) => m.get(seg)?,
                Json::Arr(v) => v.get(seg.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    pub fn f64s(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|j| j.as_f64()).collect()
    }

    pub fn usizes(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|j| j.as_usize()).collect()
    }

    // -- parse / print -----------------------------------------------------
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(indent + 1));
                    }
                    item.write(out, indent + 1, pretty);
                }
                if pretty && !v.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    val.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && self.b[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map_or(false, |c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("bad number '{s}'")))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5]).unwrap();
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 sequence
                    let rest = &self.b[self.pos..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..ch_len.min(rest.len())])
                        .map_err(|_| self.err("bad utf-8"))?;
                    s.push_str(chunk);
                    self.pos += chunk.len();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1.5", "3.25e2", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            let back = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, back, "{s}");
        }
    }

    #[test]
    fn parse_nested() {
        let src = r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.path("a.2.b").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.path("a.0").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(v.get("d").unwrap().as_bool(), Some(true));
        assert_eq!(v.path("a.2.c"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::obj()
            .set("name", "alexnet")
            .set("batch", 4usize)
            .set("lat", vec![1.5, 2.5])
            .set("nested", Json::obj().set("x", true));
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café \t ✓""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café \t ✓");
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn errors_are_reported() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn fuzz_roundtrip_random_values() {
        // Property: any randomly generated Json value survives
        // print -> parse exactly.
        use crate::util::quick::forall;
        use crate::util::rng::Rng;

        fn gen_value(r: &mut Rng, depth: usize) -> Json {
            match if depth == 0 { r.below(4) } else { r.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(r.bool()),
                2 => Json::Num((r.f64() * 2e6 - 1e6).round() / 16.0),
                3 => {
                    let n = r.below(8) as usize;
                    Json::Str(
                        (0..n)
                            .map(|_| {
                                char::from_u32(0x20 + r.below(0x250) as u32).unwrap_or('x')
                            })
                            .collect(),
                    )
                }
                4 => Json::Arr((0..r.below(4)).map(|_| gen_value(r, depth - 1)).collect()),
                _ => {
                    let mut m = std::collections::BTreeMap::new();
                    for i in 0..r.below(4) {
                        m.insert(format!("k{i}"), gen_value(r, depth - 1));
                    }
                    Json::Obj(m)
                }
            }
        }

        forall(
            77,
            300,
            |r| {
                let v = gen_value(r, 3);
                vec![v.to_string(), v.to_string_pretty()]
            },
            |texts| {
                let a = Json::parse(&texts[0]).map_err(|e| e.to_string())?;
                let b = Json::parse(&texts[1]).map_err(|e| e.to_string())?;
                if a != b {
                    return Err("compact and pretty disagree".into());
                }
                let again = Json::parse(&a.to_string()).map_err(|e| e.to_string())?;
                if again != a {
                    return Err("roundtrip changed value".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn large_float_array() {
        let xs: Vec<f64> = (0..1000).map(|i| i as f64 * 0.25 - 30.0).collect();
        let j: Json = xs.clone().into();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.f64s().unwrap(), xs);
    }
}
