//! Lazy statics substrate (once_cell is unavailable offline): a minimal
//! `Lazy<T>` over `std::sync::OnceLock`, API-compatible with
//! `once_cell::sync::Lazy` for the `static X: Lazy<T> = Lazy::new(|| ...)`
//! pattern the integration tests use.

use std::ops::Deref;
use std::sync::OnceLock;

/// A value initialized on first access.  Thread-safe; the initializer runs
/// at most once even under concurrent first access.
pub struct Lazy<T, F = fn() -> T> {
    cell: OnceLock<T>,
    init: F,
}

impl<T, F: Fn() -> T> Lazy<T, F> {
    pub const fn new(init: F) -> Lazy<T, F> {
        Lazy {
            cell: OnceLock::new(),
            init,
        }
    }

    /// Force initialization and return the value.
    pub fn force(this: &Lazy<T, F>) -> &T {
        this.cell.get_or_init(|| (this.init)())
    }
}

impl<T, F: Fn() -> T> Deref for Lazy<T, F> {
    type Target = T;

    fn deref(&self) -> &T {
        Lazy::force(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn initializes_once() {
        static CALLS: AtomicUsize = AtomicUsize::new(0);
        static V: Lazy<Vec<u32>> = Lazy::new(|| {
            CALLS.fetch_add(1, Ordering::SeqCst);
            vec![1, 2, 3]
        });
        assert_eq!(V.len(), 3);
        assert_eq!(V[2], 3);
        assert_eq!(CALLS.load(Ordering::SeqCst), 1);
        assert_eq!(*Lazy::force(&V), vec![1, 2, 3]);
        assert_eq!(CALLS.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn concurrent_first_access_is_single_init() {
        static CALLS: AtomicUsize = AtomicUsize::new(0);
        static V: Lazy<u64> = Lazy::new(|| {
            CALLS.fetch_add(1, Ordering::SeqCst);
            99
        });
        let handles: Vec<_> = (0..8)
            .map(|_| std::thread::spawn(|| *V))
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 99);
        }
        assert_eq!(CALLS.load(Ordering::SeqCst), 1);
    }
}
