//! The performance-model layer API: everything that *scores* a placement
//! (Alg. 1/2 growth, replica validation, online re-planning, serving-time
//! capacity checks) goes through a [`PerfModel`] rather than the free
//! functions in [`super::model`], so the analytic Sec.-3.1 predictor and
//! the online-calibrated variant ([`super::calibrate::CalibratedModel`])
//! are interchangeable.
//!
//! Layering contract:
//!
//! * the **analytic core** (`model::predict_with` / `DeviceScorer`) stays
//!   a pure function of profiled coefficients — implementations never
//!   replace it, they *correct* its output per workload class via
//!   [`PerfModel::correct`];
//! * the Theorem-1 closed forms (`appropriate_batch`,
//!   `lower_bound_resources`) remain analytic seeds: calibration steers
//!   the iterative growth and validation around them, exactly like the
//!   paper's Alg. 2 absorbs Eq.-17/18 approximation error;
//! * `correct` keys on the *model-zoo class* (`WorkloadCoeffs::name`) —
//!   the residual corrects the class's fitted coefficients, which every
//!   workload of that class shares; the affine-in-prediction basis lets
//!   one fit track distinct operating points.

use super::coeffs::{HardwareCoeffs, WorkloadCoeffs};
use super::model::{self, ModelTerms, PlacedWorkload, Prediction};

/// A (possibly stateful) DNN-inference performance model.
pub trait PerfModel: std::fmt::Debug {
    /// Short label for reports ("analytic", "calibrated").
    fn name(&self) -> &'static str;

    /// Which interference terms the analytic core evaluates.
    fn terms(&self) -> ModelTerms {
        ModelTerms::ALL
    }

    /// Residual correction applied on top of an analytic prediction for
    /// workload class `key` (a model-zoo name).  The default — and the
    /// calibrated model with zero observations — returns `pred`
    /// **unchanged, bit for bit**: every determinism golden and sweep
    /// fingerprint rides on that identity.
    fn correct(&self, key: &str, pred: Prediction) -> Prediction {
        let _ = key;
        pred
    }

    /// Predict `placed[target]` under the device's co-location (Eq. 1-11
    /// through the analytic core, then `correct`).
    fn predict(&self, hw: &HardwareCoeffs, placed: &[PlacedWorkload], target: usize) -> Prediction {
        let raw = model::predict_with(hw, placed, target, self.terms());
        self.correct(&placed[target].coeffs.name, raw)
    }

    /// Predict a workload running alone on a GPU of this type.
    fn predict_solo(
        &self,
        hw: &HardwareCoeffs,
        w: &WorkloadCoeffs,
        batch: f64,
        r: f64,
    ) -> Prediction {
        let raw = model::predict_solo_with(hw, w, batch, r, self.terms());
        self.correct(&w.name, raw)
    }

    /// Predicted total device power demand (Eq. 10).
    fn power_demand_w(&self, hw: &HardwareCoeffs, placed: &[PlacedWorkload]) -> f64 {
        model::power_demand_w(hw, placed)
    }

    /// Absorb one serving-observed (analytic-predicted, observed)
    /// execution-latency pair (ms) for workload class `key`.  No-op for
    /// static models.
    fn observe(&mut self, key: &str, predicted_ms: f64, observed_ms: f64) {
        let _ = (key, predicted_ms, observed_ms);
    }

    /// Total observations absorbed so far (0 for static models).
    fn observations(&self) -> u64 {
        0
    }

    /// Clone into a box (lets plan-carrying owners like `OnlinePlanner`
    /// stay `Clone`).
    fn clone_box(&self) -> Box<dyn PerfModel>;
}

impl Clone for Box<dyn PerfModel> {
    fn clone(&self) -> Box<dyn PerfModel> {
        self.clone_box()
    }
}

/// The paper's static analytic model (Sec. 3.1): pure coefficients, no
/// correction.  This is the default model everywhere — threading it
/// through the trait is bitwise-identical to calling the free functions.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AnalyticModel {
    pub terms: ModelTerms,
}

impl AnalyticModel {
    /// All interference terms on (the normal configuration).
    pub const ALL: AnalyticModel = AnalyticModel {
        terms: ModelTerms::ALL,
    };

    pub fn with_terms(terms: ModelTerms) -> AnalyticModel {
        AnalyticModel { terms }
    }
}

impl PerfModel for AnalyticModel {
    fn name(&self) -> &'static str {
        "analytic"
    }

    fn terms(&self) -> ModelTerms {
        self.terms
    }

    fn clone_box(&self) -> Box<dyn PerfModel> {
        Box::new(*self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuKind;

    #[test]
    fn analytic_trait_path_is_bitwise_the_free_functions() {
        let (hw, wls) = crate::profiler::profile_all(GpuKind::V100, 42);
        let placed: Vec<PlacedWorkload> = wls
            .iter()
            .map(|wc| PlacedWorkload {
                coeffs: wc,
                batch: 8.0,
                resources: 0.2,
            })
            .collect();
        let m = AnalyticModel::ALL;
        for i in 0..placed.len() {
            let a = m.predict(&hw, &placed, i);
            let b = model::predict(&hw, &placed, i);
            assert_eq!(a.t_inf.to_bits(), b.t_inf.to_bits());
            assert_eq!(a.throughput_rps.to_bits(), b.throughput_rps.to_bits());
        }
        let s = m.predict_solo(&hw, &wls[0], 4.0, 0.3);
        let f = model::predict_solo(&hw, &wls[0], 4.0, 0.3);
        assert_eq!(s.t_inf.to_bits(), f.t_inf.to_bits());
        assert_eq!(
            m.power_demand_w(&hw, &placed).to_bits(),
            model::power_demand_w(&hw, &placed).to_bits()
        );
    }

    #[test]
    fn terms_thread_through_the_trait() {
        let (hw, wls) = crate::profiler::profile_all(GpuKind::V100, 42);
        let placed: Vec<PlacedWorkload> = (0..4)
            .map(|_| PlacedWorkload {
                coeffs: &wls[1],
                batch: 8.0,
                resources: 0.25,
            })
            .collect();
        let all = AnalyticModel::ALL.predict(&hw, &placed, 0).t_inf;
        let none = AnalyticModel::with_terms(ModelTerms::NONE)
            .predict(&hw, &placed, 0)
            .t_inf;
        assert!(none < all, "disabling interference terms must not slow solo");
    }
}
