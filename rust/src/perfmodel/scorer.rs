//! Incremental per-device interference scoring.
//!
//! Placement search (Alg. 1 / Alg. 2) evaluates the analytical model for
//! *every resident of every candidate device, every growth pass*.  The
//! naive implementation rebuilds the `PlacedWorkload` view and re-sums the
//! device aggregates (Σ cache-util for Eq. 8, Σ power for Eqs. 9-10) per
//! prediction — O(m) coefficient-law evaluations per candidate, O(m²) per
//! pass.  `DeviceScorer` caches each slot's contributions and maintains
//! the per-device running totals, so a candidate prediction is O(1): two
//! subtractions plus the constant-time Eq. 1-11 tail
//! (`model::predict_core`).
//!
//! ## Bitwise invariant
//!
//! `scorer.predict_with(i, terms)` is **bit-identical** to
//! `model::predict_with(hw, &placed, i, terms)` for the equivalent placed
//! list, after *any* interleaving of `place` / `remove` / `set_resources`.
//! Two design rules make that hold (property-tested below):
//!
//! 1. every mutation recomputes the affected slot's contributions with the
//!    same pure coefficient laws and then **re-adds the totals in slot
//!    order** (`resum`), so the running sums are exactly the in-order sums
//!    a fresh rebuild would produce — never an accumulate/subtract drift;
//! 2. `model::predict_with` itself derives the co-runner aggregate as
//!    `total - own` (see the aggregation invariant there), the same
//!    expression the scorer uses.

use super::coeffs::HardwareCoeffs;
use super::model::{self, ModelTerms, PlacedWorkload, Prediction};

/// One resident process with its cached interference contributions.
#[derive(Debug, Clone)]
struct ScoredSlot<'a> {
    placed: PlacedWorkload<'a>,
    /// Cached `coeffs.cache_util(batch, resources)`.
    cache_util: f64,
    /// Cached `coeffs.power_w(batch, resources)` (W above idle).
    power_w: f64,
}

impl<'a> ScoredSlot<'a> {
    fn new(placed: PlacedWorkload<'a>) -> ScoredSlot<'a> {
        let cache_util = placed.coeffs.cache_util(placed.batch, placed.resources);
        let power_w = placed.coeffs.power_w(placed.batch, placed.resources);
        ScoredSlot {
            placed,
            cache_util,
            power_w,
        }
    }
}

/// Incremental device view: cached per-slot contributions + running
/// in-order aggregates.  Slot order is placement order — it must mirror
/// the `Vec<Alloc>` the caller scores against (the residents first, any
/// newly placed item last), because `predict_with` is positional.
#[derive(Debug, Clone)]
pub struct DeviceScorer<'a> {
    hw: &'a HardwareCoeffs,
    slots: Vec<ScoredSlot<'a>>,
    /// In-order Σ cache-util over all slots.
    sum_cache: f64,
    /// In-order Σ per-process power (W above idle).
    sum_power: f64,
}

impl<'a> DeviceScorer<'a> {
    pub fn new(hw: &'a HardwareCoeffs) -> DeviceScorer<'a> {
        DeviceScorer {
            hw,
            slots: Vec::new(),
            sum_cache: 0.0,
            sum_power: 0.0,
        }
    }

    /// Build from an existing device view (O(m) coefficient evaluations —
    /// paid once, not per candidate).
    pub fn from_placed(
        hw: &'a HardwareCoeffs,
        placed: impl IntoIterator<Item = PlacedWorkload<'a>>,
    ) -> DeviceScorer<'a> {
        let mut s = DeviceScorer::new(hw);
        for p in placed {
            s.slots.push(ScoredSlot::new(p));
        }
        s.resum();
        s
    }

    /// Seed from *cached* slot contributions — the placement engine's
    /// persistent per-device state.  No coefficient-law evaluations run:
    /// the caller guarantees each `(cache_util, power_w)` pair is the
    /// cached output of the same pure laws `ScoredSlot::new` evaluates
    /// for that placement, so the bitwise invariant carries over (the
    /// in-order `resum` is identical to `from_placed`'s).
    pub fn from_cached(
        hw: &'a HardwareCoeffs,
        slots: impl IntoIterator<Item = (PlacedWorkload<'a>, f64, f64)>,
    ) -> DeviceScorer<'a> {
        let mut s = DeviceScorer::new(hw);
        for (placed, cache_util, power_w) in slots {
            s.slots.push(ScoredSlot {
                placed,
                cache_util,
                power_w,
            });
        }
        s.resum();
        s
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// The placement backing slot `i`.
    pub fn placed(&self, i: usize) -> &PlacedWorkload<'a> {
        &self.slots[i].placed
    }

    /// Sum of nominal partitions on the device.
    pub fn allocated(&self) -> f64 {
        self.slots.iter().map(|s| s.placed.resources).sum()
    }

    /// Re-add both aggregates in slot order.  O(len) float additions, no
    /// coefficient-law evaluations; keeps the totals bitwise equal to what
    /// a from-scratch rebuild would sum (incremental `+=`/`-=` would drift
    /// in the last ulp after removals).
    fn resum(&mut self) {
        self.sum_cache = self.slots.iter().map(|s| s.cache_util).sum();
        self.sum_power = self.slots.iter().map(|s| s.power_w).sum();
    }

    /// Append a placement (the new item scores last, as in `alloc_gpus`).
    pub fn place(&mut self, p: PlacedWorkload<'a>) {
        self.slots.push(ScoredSlot::new(p));
        self.resum();
    }

    /// Remove slot `i` (later slots shift down, preserving order).
    pub fn remove(&mut self, i: usize) -> PlacedWorkload<'a> {
        let s = self.slots.remove(i);
        self.resum();
        s.placed
    }

    /// Resize slot `i`'s partition (the Alg.-2 growth step).
    pub fn set_resources(&mut self, i: usize, resources: f64) {
        self.slots[i].placed.resources = resources;
        let refreshed = ScoredSlot::new(self.slots[i].placed.clone());
        self.slots[i] = refreshed;
        self.resum();
    }

    /// Total device power demand (Eq. 10) — idle + the running total.
    pub fn power_demand_w(&self) -> f64 {
        self.hw.idle_power_w + self.sum_power
    }

    /// O(1) prediction for slot `target` (Eqs. 1-11, all terms).
    pub fn predict(&self, target: usize) -> Prediction {
        self.predict_with(target, ModelTerms::ALL)
    }

    /// O(1) prediction with selectable terms; bit-identical to
    /// `model::predict_with` over the equivalent placed list.
    pub fn predict_with(&self, target: usize, terms: ModelTerms) -> Prediction {
        let s = &self.slots[target];
        let others_util = if terms.cache {
            self.sum_cache - s.cache_util
        } else {
            0.0
        };
        model::predict_core(
            self.hw,
            &s.placed,
            self.slots.len(),
            others_util,
            self.power_demand_w(),
            terms,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuKind;
    use crate::util::quick::forall;
    use crate::util::rng::Rng;

    fn bits(p: &Prediction) -> [u64; 8] {
        [
            p.t_load.to_bits(),
            p.t_sched.to_bits(),
            p.t_act.to_bits(),
            p.t_feedback.to_bits(),
            p.freq_mhz.to_bits(),
            p.t_gpu.to_bits(),
            p.t_inf.to_bits(),
            p.throughput_rps.to_bits(),
        ]
    }

    /// For every slot and term set, the incremental scorer must equal the
    /// full free-function recomputation bit for bit.
    fn matches_full(scorer: &DeviceScorer, hw: &HardwareCoeffs) -> Result<(), String> {
        let placed: Vec<PlacedWorkload> =
            (0..scorer.len()).map(|i| scorer.placed(i).clone()).collect();
        for terms in [
            ModelTerms::ALL,
            ModelTerms::NONE,
            ModelTerms {
                scheduler: true,
                cache: false,
                power: true,
            },
            ModelTerms {
                scheduler: false,
                cache: true,
                power: false,
            },
        ] {
            for i in 0..placed.len() {
                let inc = scorer.predict_with(i, terms);
                let full = model::predict_with(hw, &placed, i, terms);
                if bits(&inc) != bits(&full) {
                    return Err(format!(
                        "slot {i} terms {terms:?}: incremental {inc:?} != full {full:?}"
                    ));
                }
            }
        }
        Ok(())
    }

    #[test]
    fn incremental_aggregates_bitwise_match_full_recomputation() {
        // The tentpole determinism guard: random place/remove/resize
        // sequences never let the running aggregates drift from a full
        // rebuild — goldens and sweep fingerprints depend on it.
        let (hw, wls) = crate::profiler::profile_all(GpuKind::V100, 42);
        forall(
            42,
            60,
            |r: &mut Rng| r.next_u64(),
            |&seed| {
                let mut rng = Rng::new(seed);
                let mut scorer = DeviceScorer::new(&hw);
                for _ in 0..24 {
                    let op = rng.below(3);
                    if op == 0 || scorer.is_empty() {
                        let wc = &wls[rng.below(wls.len() as u64) as usize];
                        scorer.place(PlacedWorkload {
                            coeffs: wc,
                            batch: rng.range_u64(1, 32) as f64,
                            resources: rng.range_f64(0.05, 0.5),
                        });
                    } else if op == 1 {
                        let i = rng.below(scorer.len() as u64) as usize;
                        scorer.remove(i);
                    } else {
                        let i = rng.below(scorer.len() as u64) as usize;
                        scorer.set_resources(i, rng.range_f64(0.05, 0.95));
                    }
                    matches_full(&scorer, &hw)?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn from_placed_equals_placing_one_by_one() {
        let (hw, wls) = crate::profiler::profile_all(GpuKind::V100, 7);
        let placed: Vec<PlacedWorkload> = (0..4)
            .map(|i| PlacedWorkload {
                coeffs: &wls[i % wls.len()],
                batch: 4.0 + i as f64,
                resources: 0.2,
            })
            .collect();
        let bulk = DeviceScorer::from_placed(&hw, placed.iter().cloned());
        let mut one = DeviceScorer::new(&hw);
        for p in placed.iter().cloned() {
            one.place(p);
        }
        for i in 0..placed.len() {
            assert_eq!(bits(&bulk.predict(i)), bits(&one.predict(i)));
        }
        assert_eq!(bulk.power_demand_w().to_bits(), one.power_demand_w().to_bits());
        assert!((bulk.allocated() - 0.8).abs() < 1e-12);
    }

    #[test]
    fn from_cached_equals_from_placed_bitwise() {
        // The engine harvests (cache_util, power_w) once per mutation and
        // replays them through from_cached — the seeded scorer must be
        // indistinguishable from a from_placed rebuild.
        let (hw, wls) = crate::profiler::profile_all(GpuKind::V100, 42);
        let placed: Vec<PlacedWorkload> = (0..5)
            .map(|i| PlacedWorkload {
                coeffs: &wls[i % wls.len()],
                batch: 2.0 + i as f64,
                resources: 0.1 + 0.05 * i as f64,
            })
            .collect();
        let full = DeviceScorer::from_placed(&hw, placed.iter().cloned());
        let cached = DeviceScorer::from_cached(
            &hw,
            placed.iter().cloned().map(|p| {
                let cu = p.coeffs.cache_util(p.batch, p.resources);
                let pw = p.coeffs.power_w(p.batch, p.resources);
                (p, cu, pw)
            }),
        );
        assert_eq!(full.len(), cached.len());
        assert_eq!(
            full.power_demand_w().to_bits(),
            cached.power_demand_w().to_bits()
        );
        for i in 0..placed.len() {
            assert_eq!(bits(&full.predict(i)), bits(&cached.predict(i)));
        }
    }

    #[test]
    fn growth_increases_target_and_relieves_others() {
        // Growing a victim's partition must speed the victim up; the
        // co-runner count is unchanged so others see (at most) more cache
        // pressure — exactly what alloc_gpus banks on.
        let (hw, wls) = crate::profiler::profile_all(GpuKind::V100, 42);
        let mut scorer = DeviceScorer::from_placed(
            &hw,
            (0..3).map(|i| PlacedWorkload {
                coeffs: &wls[i],
                batch: 8.0,
                resources: 0.2,
            }),
        );
        let before = scorer.predict(0).t_inf;
        scorer.set_resources(0, 0.4);
        assert!(scorer.predict(0).t_inf < before);
        assert_eq!(scorer.len(), 3);
    }
}
