//! Coefficient stores for the analytical performance model (Table 2).
//!
//! `HardwareCoeffs` holds the 7 hardware-specific coefficients; per-workload
//! `WorkloadCoeffs` holds the 8 workload-specific ones (with the Eq.-(11)
//! active-time law and the Fig.-9 power/cache-utilization lines expanded
//! into their fitted parameters).  Both are produced by `profiler::` — the
//! analytical model never touches the simulator's ground truth directly.

use crate::util::json::Json;
use crate::util::lsq::KactFit;

/// Hardware-specific coefficients (profiled once per GPU type, Sec. 3.1).
#[derive(Debug, Clone, PartialEq)]
pub struct HardwareCoeffs {
    /// GPU type label ("V100", "T4").
    pub gpu: String,
    /// Upper power limit P (W).
    pub max_power_w: f64,
    /// Maximum frequency F (MHz).
    pub max_freq_mhz: f64,
    /// Idle power p_idle (W).
    pub idle_power_w: f64,
    /// Available PCIe bandwidth B_pcie (GB/s).
    pub pcie_gbps: f64,
    /// Frequency/power coefficient alpha_f (MHz/W, negative).
    pub alpha_f: f64,
    /// Scheduling-delay coefficients (Eq. 6).
    pub alpha_sch: f64,
    pub beta_sch: f64,
    /// Allocation unit r_unit and cap r_max.
    pub r_unit: f64,
    pub r_max: f64,
    /// Hourly unit price of an instance holding one such GPU ($/h).
    pub unit_price: f64,
}

impl HardwareCoeffs {
    /// Increased per-kernel scheduling delay Delta_sch (Eq. 6).
    pub fn delta_sch(&self, co_located: usize) -> f64 {
        if co_located <= 1 {
            0.0
        } else {
            (self.alpha_sch * co_located as f64 + self.beta_sch).max(0.0)
        }
    }

    /// Predicted frequency (Eq. 9) under total demand (W).
    pub fn frequency(&self, demand_w: f64) -> f64 {
        if demand_w <= self.max_power_w {
            self.max_freq_mhz
        } else {
            (self.max_freq_mhz + self.alpha_f * (demand_w - self.max_power_w)).max(1.0)
        }
    }

    /// PCIe transfer (ms) for `bytes`.
    pub fn pcie_ms(&self, bytes: f64) -> f64 {
        bytes / (self.pcie_gbps * 1e6)
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("gpu", self.gpu.as_str())
            .set("max_power_w", self.max_power_w)
            .set("max_freq_mhz", self.max_freq_mhz)
            .set("idle_power_w", self.idle_power_w)
            .set("pcie_gbps", self.pcie_gbps)
            .set("alpha_f", self.alpha_f)
            .set("alpha_sch", self.alpha_sch)
            .set("beta_sch", self.beta_sch)
            .set("r_unit", self.r_unit)
            .set("r_max", self.r_max)
            .set("unit_price", self.unit_price)
    }

    pub fn from_json(j: &Json) -> Option<HardwareCoeffs> {
        Some(HardwareCoeffs {
            gpu: j.get("gpu")?.as_str()?.to_string(),
            max_power_w: j.get("max_power_w")?.as_f64()?,
            max_freq_mhz: j.get("max_freq_mhz")?.as_f64()?,
            idle_power_w: j.get("idle_power_w")?.as_f64()?,
            pcie_gbps: j.get("pcie_gbps")?.as_f64()?,
            alpha_f: j.get("alpha_f")?.as_f64()?,
            alpha_sch: j.get("alpha_sch")?.as_f64()?,
            beta_sch: j.get("beta_sch")?.as_f64()?,
            r_unit: j.get("r_unit")?.as_f64()?,
            r_max: j.get("r_max")?.as_f64()?,
            unit_price: j.get("unit_price")?.as_f64()?,
        })
    }
}

/// Workload-specific coefficients (profiled once per workload, Sec. 3.1).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadCoeffs {
    /// Workload / model label.
    pub name: String,
    /// Input / result bytes per request (d_load, d_feedback).
    pub d_load_bytes: f64,
    pub d_feedback_bytes: f64,
    /// Number of kernels n_k.
    pub n_kernels: f64,
    /// Solo per-kernel scheduling delay k_sch (ms).
    pub k_sch: f64,
    /// Fitted Eq.-(11) active-time law.
    pub kact: KactFit,
    /// Power line p = alpha_power * ability + beta_power (W above idle).
    pub alpha_power: f64,
    pub beta_power: f64,
    /// Cache-utilization line c = alpha_cu * ability + beta_cu (fraction).
    pub alpha_cacheutil: f64,
    pub beta_cacheutil: f64,
    /// Active-time dilation per unit of co-located cache utilization.
    pub alpha_cache: f64,
}

impl WorkloadCoeffs {
    /// Predicted solo active time k_act(b, r) (Eq. 11).
    pub fn k_act(&self, batch: f64, r: f64) -> f64 {
        self.kact.eval(batch, r)
    }

    /// GPU processing ability b / k_act (queries/ms).
    pub fn ability(&self, batch: f64, r: f64) -> f64 {
        batch / self.k_act(batch, r)
    }

    /// Predicted power contribution (W above idle).
    pub fn power_w(&self, batch: f64, r: f64) -> f64 {
        (self.alpha_power * self.ability(batch, r) + self.beta_power).max(0.0)
    }

    /// Predicted L2 cache utilization (fraction).
    pub fn cache_util(&self, batch: f64, r: f64) -> f64 {
        (self.alpha_cacheutil * self.ability(batch, r) + self.beta_cacheutil).clamp(0.0, 1.0)
    }

    /// Predicted solo total scheduling delay (ms).
    pub fn solo_sched_ms(&self) -> f64 {
        self.k_sch * self.n_kernels
    }

    /// Scale every *timing* coefficient by `f` — the model-mismatch knob:
    /// `f < 1` makes a planner believing these coefficients optimistic
    /// (it thinks the workload runs faster than the simulator's physics),
    /// `f > 1` pessimistic.  The power/cache *line coefficients* are left
    /// alone, but note both laws are functions of `ability = b / k_act`,
    /// so the believed interference contributions (power demand, cache
    /// pressure on co-runners) shift consistently with the believed
    /// speed — exactly as if the class really ran `1/f` as fast.  The
    /// perturbation is therefore a coherent wrong belief about the
    /// workload, not an isolated latency-term tweak.
    pub fn scale_time(&mut self, f: f64) {
        assert!(f > 0.0 && f.is_finite());
        self.kact.k1 *= f;
        self.kact.k2 *= f;
        self.kact.k3 *= f;
        self.kact.k5 *= f;
        self.k_sch *= f;
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("name", self.name.as_str())
            .set("d_load_bytes", self.d_load_bytes)
            .set("d_feedback_bytes", self.d_feedback_bytes)
            .set("n_kernels", self.n_kernels)
            .set("k_sch", self.k_sch)
            .set(
                "kact",
                Json::obj()
                    .set("k1", self.kact.k1)
                    .set("k2", self.kact.k2)
                    .set("k3", self.kact.k3)
                    .set("k4", self.kact.k4)
                    .set("k5", self.kact.k5)
                    .set("rss", self.kact.rss),
            )
            .set("alpha_power", self.alpha_power)
            .set("beta_power", self.beta_power)
            .set("alpha_cacheutil", self.alpha_cacheutil)
            .set("beta_cacheutil", self.beta_cacheutil)
            .set("alpha_cache", self.alpha_cache)
    }

    pub fn from_json(j: &Json) -> Option<WorkloadCoeffs> {
        let k = j.get("kact")?;
        Some(WorkloadCoeffs {
            name: j.get("name")?.as_str()?.to_string(),
            d_load_bytes: j.get("d_load_bytes")?.as_f64()?,
            d_feedback_bytes: j.get("d_feedback_bytes")?.as_f64()?,
            n_kernels: j.get("n_kernels")?.as_f64()?,
            k_sch: j.get("k_sch")?.as_f64()?,
            kact: KactFit {
                k1: k.get("k1")?.as_f64()?,
                k2: k.get("k2")?.as_f64()?,
                k3: k.get("k3")?.as_f64()?,
                k4: k.get("k4")?.as_f64()?,
                k5: k.get("k5")?.as_f64()?,
                rss: k.get("rss").and_then(|x| x.as_f64()).unwrap_or(0.0),
            },
            alpha_power: j.get("alpha_power")?.as_f64()?,
            beta_power: j.get("beta_power")?.as_f64()?,
            alpha_cacheutil: j.get("alpha_cacheutil")?.as_f64()?,
            beta_cacheutil: j.get("beta_cacheutil")?.as_f64()?,
            alpha_cache: j.get("alpha_cache")?.as_f64()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hw() -> HardwareCoeffs {
        HardwareCoeffs {
            gpu: "V100".into(),
            max_power_w: 300.0,
            max_freq_mhz: 1530.0,
            idle_power_w: 53.5,
            pcie_gbps: 10.0,
            alpha_f: -1.025,
            alpha_sch: 0.00475,
            beta_sch: -0.00902,
            r_unit: 0.025,
            r_max: 1.0,
            unit_price: 3.06,
        }
    }

    fn wl() -> WorkloadCoeffs {
        WorkloadCoeffs {
            name: "resnet50".into(),
            d_load_bytes: 602_112.0,
            d_feedback_bytes: 4_000.0,
            n_kernels: 80.0,
            k_sch: 0.0025,
            kact: KactFit {
                k1: 0.0004,
                k2: 0.628,
                k3: 0.45,
                k4: 0.02,
                k5: 0.10,
                rss: 0.0,
            },
            alpha_power: 60.0,
            beta_power: 35.0,
            alpha_cacheutil: 0.12,
            beta_cacheutil: 0.02,
            alpha_cache: 0.9,
        }
    }

    #[test]
    fn hardware_json_roundtrip() {
        let h = hw();
        let j = h.to_json();
        assert_eq!(HardwareCoeffs::from_json(&j).unwrap(), h);
    }

    #[test]
    fn workload_json_roundtrip() {
        let w = wl();
        let j = w.to_json();
        assert_eq!(WorkloadCoeffs::from_json(&j).unwrap(), w);
    }

    #[test]
    fn delta_sch_and_frequency() {
        let h = hw();
        assert_eq!(h.delta_sch(1), 0.0);
        assert!(h.delta_sch(4) > 0.0);
        assert_eq!(h.frequency(200.0), 1530.0);
        assert!(h.frequency(350.0) < 1530.0);
    }

    #[test]
    fn derived_quantities() {
        let w = wl();
        assert!(w.k_act(8.0, 0.3) > w.k_act(8.0, 0.9));
        assert!(w.power_w(8.0, 0.5) > 0.0);
        assert!((0.0..=1.0).contains(&w.cache_util(8.0, 0.5)));
        assert!((w.solo_sched_ms() - 0.2).abs() < 1e-12);
    }
}
