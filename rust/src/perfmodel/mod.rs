//! The performance-model layer (Sec. 3): coefficient stores, the
//! Eq. (1)-(11) predictor plus the Theorem-1 closed forms, and — on top —
//! the first-class model API:
//!
//! * [`PerfModel`] — the trait every placement-scoring consumer goes
//!   through (provisioner strategies, the online planner, the serving
//!   `Reprovisioner`);
//! * [`AnalyticModel`] — the paper's static model behind the trait
//!   (bitwise-identical to the free functions);
//! * [`CalibratedModel`] — the analytic model plus per-workload-class
//!   residual corrections fit online from serving telemetry (recursive
//!   least squares over `util::lsq::Rls2`);
//! * [`DeviceScorer`] — incremental per-device interference aggregates
//!   for O(1)-per-candidate placement scoring, bit-identical to the full
//!   recomputation by construction.

pub mod calibrate;
pub mod coeffs;
pub mod model;
pub mod scorer;
pub mod traits;

pub use calibrate::{CalibratedModel, MAX_CORRECTION, MIN_OBSERVATIONS};
pub use coeffs::{HardwareCoeffs, WorkloadCoeffs};
pub use model::{
    appropriate_batch, lower_bound_resources, power_demand_w, predict, predict_solo,
    rel_error, PlacedWorkload, Prediction,
};
pub use scorer::DeviceScorer;
pub use traits::{AnalyticModel, PerfModel};
