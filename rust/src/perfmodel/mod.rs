//! Analytical DNN-inference performance model (Sec. 3): coefficient
//! stores and the Eq. (1)-(11) predictor plus the Theorem-1 closed forms.

pub mod coeffs;
pub mod model;

pub use coeffs::{HardwareCoeffs, WorkloadCoeffs};
pub use model::{
    appropriate_batch, lower_bound_resources, power_demand_w, predict, predict_solo,
    rel_error, PlacedWorkload, Prediction,
};
