//! Online-calibrated performance model: the analytic Sec.-3.1 predictor
//! wrapped with per-workload-class residual corrections fit **online**
//! (recursive least squares, `util::lsq::Rls2`) from serving-observed
//! execution latencies.
//!
//! The paper handles prediction error reactively (Sec. 4.2 shadow
//! processes soak up to ~10 %); static interference models are known to
//! drift further from ground truth in richer co-location regimes
//! (arXiv 2501.16909), and predictability has to survive that error
//! (arXiv 2512.18725).  `CalibratedModel` closes the loop *proactively*:
//! the `Reprovisioner` feeds each monitor tick's (analytic-predicted,
//! observed) exec-latency pair into `observe`, and every later placement
//! decision (`alloc_gpus` growth, respec validation, capacity checks)
//! sees the corrected prediction.
//!
//! Safety rules (all load-bearing):
//!
//! * **zero observations = bitwise identity** — with no fit past
//!   `MIN_OBSERVATIONS`, `correct` returns the analytic prediction
//!   unchanged, so goldens / sweep fingerprints / determinism tests are
//!   untouched by merely *threading* this type;
//! * **corrections only dilate** — the ratio is clamped to
//!   `[1.0, MAX_CORRECTION]`.  Observed speedups are dominated by
//!   partial-batch artifacts (the batcher dispatches below the configured
//!   batch at low load), and trusting them would let the re-packer
//!   tighten allocations below truth — the exact failure the layer
//!   exists to prevent.  Slowdowns, the dangerous direction, are what
//!   the fit is for;
//! * the correction folds into `t_gpu` / `t_inf` / `throughput_rps`
//!   only — the PCIe phases and the raw component breakdown stay
//!   analytic.

use super::model::{ModelTerms, Prediction};
use super::traits::{AnalyticModel, PerfModel};
use crate::util::lsq::Rls2;

/// Observations of a class required before its correction applies.
pub const MIN_OBSERVATIONS: u64 = 8;
/// Upper clamp on the correction ratio (a runaway fit must never inflate
/// a prediction past this factor).
pub const MAX_CORRECTION: f64 = 3.0;
/// RLS forgetting factor: ~200-tick memory, so the fit tracks re-plans
/// and operating-point moves without forgetting within one.
pub const RLS_LAMBDA: f64 = 0.995;
/// Prior covariance scale: weak prior around the identity correction.
pub const RLS_P0: f64 = 100.0;

/// The analytic model + online per-class residual corrections.
#[derive(Debug, Clone)]
pub struct CalibratedModel {
    inner: AnalyticModel,
    /// (workload-class name, observed = a*predicted + b fit), insertion
    /// order — the class set is tiny (the model zoo), linear scan wins.
    fits: Vec<(String, Rls2)>,
    total_obs: u64,
}

impl Default for CalibratedModel {
    fn default() -> CalibratedModel {
        CalibratedModel::new()
    }
}

impl CalibratedModel {
    pub fn new() -> CalibratedModel {
        CalibratedModel::with_terms(ModelTerms::ALL)
    }

    pub fn with_terms(terms: ModelTerms) -> CalibratedModel {
        CalibratedModel {
            inner: AnalyticModel::with_terms(terms),
            fits: Vec::new(),
            total_obs: 0,
        }
    }

    fn fit(&self, key: &str) -> Option<&Rls2> {
        self.fits.iter().find(|(k, _)| k == key).map(|(_, f)| f)
    }

    /// Correction ratio (>= 1.0) the model would apply to a prediction of
    /// `pred_ms` for class `key`.
    pub fn correction_ratio(&self, key: &str, pred_ms: f64) -> f64 {
        let Some(rls) = self.fit(key) else { return 1.0 };
        if rls.n() < MIN_OBSERVATIONS || !(pred_ms > 0.0) {
            return 1.0;
        }
        let corrected = rls.predict([pred_ms, 1.0]);
        if !corrected.is_finite() {
            return 1.0;
        }
        (corrected / pred_ms).clamp(1.0, MAX_CORRECTION)
    }

    /// Classes with an applied (past-`MIN_OBSERVATIONS`) correction.
    pub fn calibrated_classes(&self) -> usize {
        self.fits.iter().filter(|(_, f)| f.n() >= MIN_OBSERVATIONS).count()
    }
}

impl PerfModel for CalibratedModel {
    fn name(&self) -> &'static str {
        "calibrated"
    }

    fn terms(&self) -> ModelTerms {
        self.inner.terms
    }

    fn correct(&self, key: &str, pred: Prediction) -> Prediction {
        let ratio = self.correction_ratio(key, pred.t_inf);
        if ratio == 1.0 {
            // identity path: the prediction passes through untouched, bit
            // for bit (the zero-observation determinism guard)
            return pred;
        }
        // dilate the GPU-resident span so t_inf lands on the corrected
        // value; PCIe phases are analytic and stay put
        let extra = pred.t_inf * (ratio - 1.0);
        let t_gpu = pred.t_gpu + extra;
        let scale = (pred.t_gpu + pred.t_feedback) / (t_gpu + pred.t_feedback);
        Prediction {
            t_gpu,
            t_inf: pred.t_inf + extra,
            throughput_rps: pred.throughput_rps * scale,
            ..pred
        }
    }

    fn observe(&mut self, key: &str, predicted_ms: f64, observed_ms: f64) {
        if !(predicted_ms > 0.0 && predicted_ms.is_finite())
            || !(observed_ms > 0.0 && observed_ms.is_finite())
        {
            return;
        }
        let idx = match self.fits.iter().position(|(k, _)| k == key) {
            Some(i) => i,
            None => {
                self.fits
                    .push((key.to_string(), Rls2::new([1.0, 0.0], RLS_P0, RLS_LAMBDA)));
                self.fits.len() - 1
            }
        };
        self.fits[idx].1.update([predicted_ms, 1.0], observed_ms);
        self.total_obs += 1;
    }

    fn observations(&self) -> u64 {
        self.total_obs
    }

    fn clone_box(&self) -> Box<dyn PerfModel> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuKind;
    use crate::perfmodel::{model, PlacedWorkload};

    fn placed(wls: &[crate::perfmodel::WorkloadCoeffs]) -> Vec<PlacedWorkload<'_>> {
        wls.iter()
            .map(|wc| PlacedWorkload {
                coeffs: wc,
                batch: 8.0,
                resources: 0.25,
            })
            .collect()
    }

    #[test]
    fn zero_observations_is_bitwise_the_analytic_model() {
        // The determinism guard behind every existing golden and sweep
        // fingerprint: merely swapping the model type changes nothing.
        let (hw, wls) = crate::profiler::profile_all(GpuKind::V100, 42);
        let view = placed(&wls);
        let cal = CalibratedModel::new();
        let ana = AnalyticModel::ALL;
        for i in 0..view.len() {
            let c = cal.predict(&hw, &view, i);
            let a = ana.predict(&hw, &view, i);
            assert_eq!(c.t_inf.to_bits(), a.t_inf.to_bits());
            assert_eq!(c.t_gpu.to_bits(), a.t_gpu.to_bits());
            assert_eq!(c.throughput_rps.to_bits(), a.throughput_rps.to_bits());
            assert_eq!(c.freq_mhz.to_bits(), a.freq_mhz.to_bits());
        }
        let cs = cal.predict_solo(&hw, &wls[0], 4.0, 0.3);
        let as_ = ana.predict_solo(&hw, &wls[0], 4.0, 0.3);
        assert_eq!(cs.t_inf.to_bits(), as_.t_inf.to_bits());
        assert_eq!(cal.observations(), 0);
        assert_eq!(cal.calibrated_classes(), 0);
    }

    #[test]
    fn sustained_slowdown_is_learned_and_applied() {
        let (hw, wls) = crate::profiler::profile_all(GpuKind::V100, 42);
        let view = placed(&wls);
        let mut cal = CalibratedModel::new();
        let key = wls[1].name.clone();
        let raw = model::predict(&hw, &view, 1);
        // below the observation floor: still identity
        for _ in 0..(MIN_OBSERVATIONS - 1) {
            cal.observe(&key, raw.t_inf, raw.t_inf * 1.25);
        }
        assert_eq!(cal.predict(&hw, &view, 1).t_inf.to_bits(), raw.t_inf.to_bits());
        cal.observe(&key, raw.t_inf, raw.t_inf * 1.25);
        // past the floor: the corrected prediction tracks the observations
        let c = cal.predict(&hw, &view, 1);
        assert!(
            (c.t_inf / raw.t_inf - 1.25).abs() < 0.05,
            "corrected {:.3} vs raw {:.3}",
            c.t_inf,
            raw.t_inf
        );
        // throughput shrinks consistently with the dilated span
        assert!(c.throughput_rps < raw.throughput_rps);
        // other classes stay analytic
        let other = cal.predict(&hw, &view, 2);
        assert_eq!(other.t_inf.to_bits(), model::predict(&hw, &view, 2).t_inf.to_bits());
        assert_eq!(cal.calibrated_classes(), 1);
        assert_eq!(cal.observations(), MIN_OBSERVATIONS);
    }

    #[test]
    fn corrections_never_shrink_and_are_clamped() {
        let (hw, wls) = crate::profiler::profile_all(GpuKind::V100, 42);
        let view = placed(&wls);
        let raw = model::predict(&hw, &view, 0);
        // observed speedups (partial-batch artifacts) clamp to identity
        let mut fast = CalibratedModel::new();
        for _ in 0..20 {
            fast.observe(&wls[0].name, raw.t_inf, raw.t_inf * 0.6);
        }
        assert_eq!(fast.predict(&hw, &view, 0).t_inf.to_bits(), raw.t_inf.to_bits());
        // absurd slowdowns clamp at MAX_CORRECTION
        let mut slow = CalibratedModel::new();
        for _ in 0..20 {
            slow.observe(&wls[0].name, raw.t_inf, raw.t_inf * 50.0);
        }
        let c = slow.predict(&hw, &view, 0);
        assert!((c.t_inf / raw.t_inf - MAX_CORRECTION).abs() < 1e-9);
        // poisoned observations are ignored outright
        let mut p = CalibratedModel::new();
        p.observe("x", f64::NAN, 3.0);
        p.observe("x", 3.0, -1.0);
        assert_eq!(p.observations(), 0);
    }
}
