//! The analytical DNN-inference performance model (Sec. 3.1, Eqs. 1-11)
//! plus the Theorem-1 closed forms (Eqs. 17-18).
//!
//! Everything here is *prediction* from profiled coefficients; the
//! simulator's richer ground truth is never consulted.

use super::coeffs::{HardwareCoeffs, WorkloadCoeffs};

/// A workload as placed on a GPU: its coefficients + configuration.
#[derive(Debug, Clone)]
pub struct PlacedWorkload<'a> {
    pub coeffs: &'a WorkloadCoeffs,
    pub batch: f64,
    pub resources: f64,
}

/// Predicted latency breakdown (ms) — mirrors `gpu::QueryLatency`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Prediction {
    pub t_load: f64,
    pub t_sched: f64,
    pub t_act: f64,
    pub t_feedback: f64,
    pub freq_mhz: f64,
    pub t_gpu: f64,
    pub t_inf: f64,
    /// Predicted sustainable throughput (req/s, Eq. 2).
    pub throughput_rps: f64,
}

/// Predicted total power demand of a device (Eq. 10).
pub fn power_demand_w(hw: &HardwareCoeffs, placed: &[PlacedWorkload]) -> f64 {
    hw.idle_power_w
        + placed
            .iter()
            .map(|p| p.coeffs.power_w(p.batch, p.resources))
            .sum::<f64>()
}

/// Which interference terms of the model are enabled — used by the
/// ablation study (`experiments::ablation`) to quantify each mechanism's
/// contribution to prediction accuracy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelTerms {
    /// Eq. 6: increased kernel scheduling delay.
    pub scheduler: bool,
    /// Eq. 8: L2-cache-contention dilation.
    pub cache: bool,
    /// Eq. 9-10: power-cap frequency reduction.
    pub power: bool,
}

impl ModelTerms {
    pub const ALL: ModelTerms = ModelTerms {
        scheduler: true,
        cache: true,
        power: true,
    };
    pub const NONE: ModelTerms = ModelTerms {
        scheduler: false,
        cache: false,
        power: false,
    };
}

impl Default for ModelTerms {
    fn default() -> ModelTerms {
        ModelTerms::ALL
    }
}

/// The Eq. 1-11 composition given **precomputed device aggregates**: the
/// target's own placement, the co-located process count `m`, the
/// co-runners' aggregate cache utilization (already zeroed when the cache
/// term is off), and the device's total power demand.
///
/// Single numeric source for both the free-function predictor below and
/// the incremental [`super::scorer::DeviceScorer`] — the scorer's bitwise
/// identity with `predict_with` (property-tested in `scorer.rs`) holds
/// because both paths feed the *same* f64 aggregates into this one pure
/// function.
pub(crate) fn predict_core(
    hw: &HardwareCoeffs,
    w: &PlacedWorkload,
    m: usize,
    others_util: f64,
    demand_w: f64,
    terms: ModelTerms,
) -> Prediction {
    // Eq. 3: PCIe phases.
    let t_load = hw.pcie_ms(w.coeffs.d_load_bytes * w.batch);
    let t_feedback = hw.pcie_ms(w.coeffs.d_feedback_bytes * w.batch);

    // Eq. 5 + 6: scheduling delay.
    let delta = if terms.scheduler { hw.delta_sch(m) } else { 0.0 };
    let t_sched = (w.coeffs.k_sch + delta) * w.coeffs.n_kernels;

    // Eq. 8: active time dilated by co-runners' cache utilization.
    let t_act =
        w.coeffs.k_act(w.batch, w.resources) * (1.0 + w.coeffs.alpha_cache * others_util);

    // Eq. 9 + 10: frequency under total power demand.
    let freq = if terms.power {
        hw.frequency(demand_w)
    } else {
        hw.max_freq_mhz
    };

    // Eq. 4: GPU execution latency.
    let t_gpu = (t_sched + t_act) / (freq / hw.max_freq_mhz);

    // Eq. 1 + 2.
    let t_inf = t_load + t_gpu + t_feedback;
    let throughput_rps = w.batch / (t_gpu + t_feedback) * 1000.0;

    Prediction {
        t_load,
        t_sched,
        t_act,
        t_feedback,
        freq_mhz: freq,
        t_gpu,
        t_inf,
        throughput_rps,
    }
}

/// Predict the inference latency of `placed[target]` under the co-location
/// described by `placed` (Eqs. 1-11).
pub fn predict(hw: &HardwareCoeffs, placed: &[PlacedWorkload], target: usize) -> Prediction {
    predict_with(hw, placed, target, ModelTerms::ALL)
}

/// `predict` with selectable interference terms (ablation support).
///
/// Aggregation invariant: the co-runner cache utilization is computed as
/// the **in-order total minus the target's own contribution** (not a
/// filtered sum), so a per-device running total maintained by
/// `DeviceScorer` reproduces it bitwise with O(1) work per candidate.
pub fn predict_with(
    hw: &HardwareCoeffs,
    placed: &[PlacedWorkload],
    target: usize,
    terms: ModelTerms,
) -> Prediction {
    let w = &placed[target];
    let others_util: f64 = if terms.cache {
        let total: f64 = placed
            .iter()
            .map(|p| p.coeffs.cache_util(p.batch, p.resources))
            .sum();
        total - w.coeffs.cache_util(w.batch, w.resources)
    } else {
        0.0
    };
    predict_core(
        hw,
        w,
        placed.len(),
        others_util,
        power_demand_w(hw, placed),
        terms,
    )
}

/// Predict a workload running **alone** on a GPU of this type.
pub fn predict_solo(hw: &HardwareCoeffs, w: &WorkloadCoeffs, batch: f64, r: f64) -> Prediction {
    predict_solo_with(hw, w, batch, r, ModelTerms::ALL)
}

/// `predict_solo` with selectable interference terms.
pub fn predict_solo_with(
    hw: &HardwareCoeffs,
    w: &WorkloadCoeffs,
    batch: f64,
    r: f64,
    terms: ModelTerms,
) -> Prediction {
    let placed = [PlacedWorkload {
        coeffs: w,
        batch,
        resources: r,
    }];
    predict_with(hw, &placed, 0, terms)
}

/// Eq. 17: the appropriate batch size that just meets the arrival rate
/// `rate_rps` under latency SLO `slo_ms`.
pub fn appropriate_batch(
    hw: &HardwareCoeffs,
    w: &WorkloadCoeffs,
    slo_ms: f64,
    rate_rps: f64,
) -> u32 {
    // Work in ms: rate (req/ms) = rate_rps / 1000; B_pcie in bytes/ms.
    let rate = rate_rps / 1000.0;
    let bw = hw.pcie_gbps * 1e6; // bytes per ms
    let b = (slo_ms * rate * bw) / (2.0 * (bw + rate * w.d_load_bytes));
    (b.ceil() as u32).max(1)
}

/// Eq. 18: lower bound of GPU resources for `(slo, rate)` with the
/// appropriate batch size, quantized up to `r_unit`.  Returns `None` when
/// the SLO is infeasible even at full resources (delta <= 0 or r > r_max).
pub fn lower_bound_resources(
    hw: &HardwareCoeffs,
    w: &WorkloadCoeffs,
    slo_ms: f64,
    rate_rps: f64,
) -> Option<(u32, f64)> {
    let b = appropriate_batch(hw, w, slo_ms, rate_rps);
    let bf = b as f64;
    let gamma = w.kact.k1 * bf * bf + w.kact.k2 * bf + w.kact.k3;
    let delta = slo_ms / 2.0
        - (w.d_load_bytes + w.d_feedback_bytes) * bf / (hw.pcie_gbps * 1e6)
        - w.kact.k5
        - w.k_sch * w.n_kernels;
    if delta <= 0.0 {
        return None;
    }
    let r_raw = gamma / delta - w.kact.k4;
    if r_raw > hw.r_max + 1e-9 {
        return None;
    }
    let r = ((r_raw / hw.r_unit).ceil() * hw.r_unit).clamp(hw.r_unit, hw.r_max);
    Some((b, r))
}

/// Relative prediction error |pred - obs| / obs.
pub fn rel_error(pred: f64, obs: f64) -> f64 {
    (pred - obs).abs() / obs.abs().max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::lsq::KactFit;

    fn hw() -> HardwareCoeffs {
        HardwareCoeffs {
            gpu: "V100".into(),
            max_power_w: 300.0,
            max_freq_mhz: 1530.0,
            idle_power_w: 53.5,
            pcie_gbps: 10.0,
            alpha_f: -1.025,
            alpha_sch: 0.00475,
            beta_sch: -0.00902,
            r_unit: 0.025,
            r_max: 1.0,
            unit_price: 3.06,
        }
    }

    fn wl(name: &str, k2: f64, apow: f64, acu: f64) -> WorkloadCoeffs {
        WorkloadCoeffs {
            name: name.into(),
            d_load_bytes: 602_112.0,
            d_feedback_bytes: 4_000.0,
            n_kernels: 80.0,
            k_sch: 0.0025,
            kact: KactFit {
                k1: 0.0004,
                k2,
                k3: 0.45,
                k4: 0.02,
                k5: 0.10,
                rss: 0.0,
            },
            alpha_power: apow,
            beta_power: 35.0,
            alpha_cacheutil: acu,
            beta_cacheutil: 0.02,
            alpha_cache: 0.9,
        }
    }

    #[test]
    fn solo_prediction_composes_eq1() {
        let h = hw();
        let w = wl("r", 0.628, 60.0, 0.12);
        let p = predict_solo(&h, &w, 8.0, 0.3);
        assert!((p.t_inf - (p.t_load + p.t_gpu + p.t_feedback)).abs() < 1e-12);
        assert_eq!(p.freq_mhz, 1530.0); // solo never throttles
        assert!((p.t_sched - 0.0025 * 80.0).abs() < 1e-12); // no Delta solo
    }

    #[test]
    fn colocation_increases_prediction() {
        let h = hw();
        let w = wl("r", 0.628, 60.0, 0.12);
        let solo = predict_solo(&h, &w, 8.0, 0.3).t_inf;
        let placed: Vec<PlacedWorkload> = (0..4)
            .map(|_| PlacedWorkload {
                coeffs: &w,
                batch: 8.0,
                resources: 0.25,
            })
            .collect();
        // same r for fairness
        let mut placed2 = placed.clone();
        placed2[0].resources = 0.3;
        let co = predict(&h, &placed2, 0).t_inf;
        assert!(co > solo, "{co} !> {solo}");
    }

    #[test]
    fn throttling_prediction() {
        let h = hw();
        // power-hungry workloads exceed the 300 W cap when stacked
        let w = wl("v", 1.797, 400.0, 0.4);
        let placed: Vec<PlacedWorkload> = (0..5)
            .map(|_| PlacedWorkload {
                coeffs: &w,
                batch: 16.0,
                resources: 0.2,
            })
            .collect();
        assert!(power_demand_w(&h, &placed) > 300.0);
        let p = predict(&h, &placed, 0);
        assert!(p.freq_mhz < 1530.0);
    }

    #[test]
    fn eq17_batch_scales_with_rate_and_slo() {
        let h = hw();
        let w = wl("r", 0.628, 60.0, 0.12);
        let b1 = appropriate_batch(&h, &w, 40.0, 100.0);
        let b2 = appropriate_batch(&h, &w, 40.0, 400.0);
        let b3 = appropriate_batch(&h, &w, 80.0, 400.0);
        assert!(b1 <= b2 && b2 <= b3, "{b1} {b2} {b3}");
        assert!(b1 >= 1);
        // Table-1-like anchor: R @ 40 ms / 400 r/s -> b = 8-ish
        assert!((4..=10).contains(&b2), "b2={b2}");
    }

    #[test]
    fn eq18_lower_bound_properties() {
        let h = hw();
        let w = wl("r", 0.628, 60.0, 0.12);
        let (b, r) = lower_bound_resources(&h, &w, 40.0, 400.0).unwrap();
        // quantized to the grid
        assert!((r / h.r_unit - (r / h.r_unit).round()).abs() < 1e-9);
        // the bound must actually satisfy the half-SLO solo
        let p = predict_solo(&h, &w, b as f64, r);
        assert!(p.t_inf <= 40.0 / 2.0 + 1e-6, "t_inf={}", p.t_inf);
        // and one unit less must violate it (tightness) unless at floor
        if r > h.r_unit {
            let p2 = predict_solo(&h, &w, b as f64, r - h.r_unit);
            assert!(p2.t_inf > 40.0 / 2.0 - 1e-9, "bound not tight");
        }
        // tighter SLO needs at least as many resources
        let (_, r_tight) = lower_bound_resources(&h, &w, 25.0, 400.0).unwrap();
        assert!(r_tight >= r);
    }

    #[test]
    fn eq18_infeasible_slo_is_none() {
        let h = hw();
        let w = wl("r", 0.628, 60.0, 0.12);
        // sub-millisecond SLO cannot be met
        assert!(lower_bound_resources(&h, &w, 0.5, 400.0).is_none());
    }

    #[test]
    fn throughput_constraint_met_at_bound() {
        // By Theorem 1 the chosen (b_appr, r_lower) must meet the rate.
        let h = hw();
        let w = wl("r", 0.628, 60.0, 0.12);
        for rate in [100.0, 300.0, 600.0] {
            if let Some((b, r)) = lower_bound_resources(&h, &w, 40.0, rate) {
                let p = predict_solo(&h, &w, b as f64, r);
                assert!(
                    p.throughput_rps >= rate * 0.999,
                    "rate={rate}: thpt {}",
                    p.throughput_rps
                );
            }
        }
    }
}
