//! # iGniter — interference-aware GPU resource provisioning (reproduction)
//!
//! Three-layer Rust + JAX + Pallas reproduction of "iGniter:
//! Interference-Aware GPU Resource Provisioning for Predictable DNN
//! Inference in the Cloud".
//!
//! See `DESIGN.md` (repo root) for the module inventory, build/verify
//! instructions, and the PJRT/artifact gating rules, and `EXPERIMENTS.md`
//! for the experiment index (`igniter experiment <id>` regenerates each
//! paper table/figure).  The crate builds offline with zero crates.io
//! dependencies; every external-crate niche is filled by an in-tree
//! substrate under [`util`] (and [`runtime::xla_stub`] for PJRT).

pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod gpu;
pub mod perfmodel;
pub mod profiler;
pub mod provisioner;
pub mod runtime;
pub mod sim;
pub mod sweep;
pub mod util;
pub mod workload;
