//! # iGniter — interference-aware GPU resource provisioning (reproduction)
//!
//! Three-layer Rust + JAX + Pallas reproduction of "iGniter:
//! Interference-Aware GPU Resource Provisioning for Predictable DNN
//! Inference in the Cloud".  See DESIGN.md for the system inventory and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod gpu;
pub mod perfmodel;
pub mod profiler;
pub mod provisioner;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workload;
