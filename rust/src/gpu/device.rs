//! The simulated GPU device: MPS-style spatial sharing with the three
//! interference mechanisms the paper measures (Sec. 2.2):
//!
//!  1. kernel scheduling delay — emergent from a round-robin dispatch model
//!     over co-located process streams (not the paper's linear fit: the
//!     linear Eq. (6) is what the *analytical model* uses to approximate
//!     this behaviour);
//!  2. L2-cache contention — active-time dilation driven by the aggregate
//!     cache utilization of the co-runners, with a mild superlinear term
//!     the analytical model does not capture;
//!  3. power-cap frequency reduction — demand aggregation through a
//!     governor with the paper's alpha_f slope.
//!
//! Per-query measurement noise is multiplicative lognormal-ish (~1.5 %),
//! matching the error bars of the paper's figures.

use super::profile::{profile, Model, WorkloadProfile};
use super::spec::{GpuKind, GpuSpec};
use crate::util::rng::Rng;

/// A serving process pinned to an MPS partition of the device.
#[derive(Debug, Clone)]
pub struct ProcessSlot {
    /// Caller-chosen identifier (workload id).
    pub tag: u64,
    pub model: Model,
    /// MPS active-thread percentage as a fraction (0, 1].
    pub resources: f64,
    /// Configured (preferred) batch size — determines steady-state power
    /// and cache footprint of this co-runner.
    pub batch: u32,
}

/// Detailed latency breakdown of one inference query (all ms).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QueryLatency {
    pub t_load: f64,
    pub t_sched: f64,
    pub t_act: f64,
    pub t_feedback: f64,
    /// governor frequency during the query (MHz)
    pub freq_mhz: f64,
    /// (t_sched + t_act) / (freq / F)
    pub t_gpu: f64,
    /// t_load + t_gpu + t_feedback (Eq. 1)
    pub t_inf: f64,
}

/// Device-level observables (what nvidia-smi / Nsight would report).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceTelemetry {
    pub power_w: f64,
    pub freq_mhz: f64,
    pub l2_hit_ratio: f64,
    pub total_cache_util: f64,
    pub allocated_resources: f64,
}

/// One simulated GPU device with its resident processes.
#[derive(Debug, Clone)]
pub struct GpuDevice {
    pub spec: GpuSpec,
    slots: Vec<ProcessSlot>,
    rng: Rng,
    /// Per-query noise sigma (multiplicative on active time).
    pub noise_sigma: f64,
    /// Hard failure (fault injection): a dead device holds no processes
    /// and rejects launches until the end of the run.
    dead: bool,
}

impl GpuDevice {
    pub fn new(kind: GpuKind, seed: u64) -> GpuDevice {
        GpuDevice {
            spec: GpuSpec::get(kind),
            slots: Vec::new(),
            rng: Rng::new(seed),
            noise_sigma: 0.015,
            dead: false,
        }
    }

    /// Kill the whole device: every resident process vanishes (their
    /// queued requests are the *caller's* failover problem) and future
    /// launches are refused.  Irreversible within a run — cloud failover
    /// replaces the instance rather than resurrecting it.
    pub fn fail(&mut self) {
        self.dead = true;
        self.slots.clear();
    }

    pub fn is_dead(&self) -> bool {
        self.dead
    }

    /// Deterministic device (for fitting / analytical comparisons).
    pub fn noiseless(kind: GpuKind) -> GpuDevice {
        let mut d = GpuDevice::new(kind, 0);
        d.noise_sigma = 0.0;
        d
    }

    // -- process management --------------------------------------------

    /// Launch a process; fails if the partition would exceed r_max.
    pub fn launch(&mut self, tag: u64, model: Model, resources: f64, batch: u32) -> bool {
        if self.dead || resources <= 0.0 || self.allocated() + resources > self.spec.r_max + 1e-9
        {
            return false;
        }
        self.slots.push(ProcessSlot {
            tag,
            model,
            resources,
            batch,
        });
        true
    }

    pub fn kill(&mut self, tag: u64) -> bool {
        let before = self.slots.len();
        self.slots.retain(|s| s.tag != tag);
        self.slots.len() != before
    }

    /// Launch without the capacity check (models an interference-unaware
    /// controller like GSLICE force-growing past 100 %; the device then
    /// time-slices SMs, shrinking everyone's *effective* partition).
    pub fn launch_unchecked(&mut self, tag: u64, model: Model, resources: f64, batch: u32) {
        debug_assert!(!self.dead, "launch on a dead device (tag {tag})");
        self.slots.push(ProcessSlot {
            tag,
            model,
            resources: resources.max(self.spec.r_unit),
            batch,
        });
    }

    /// Set a process's partition without the capacity check (see
    /// `launch_unchecked`).
    pub fn force_resources(&mut self, tag: u64, resources: f64) -> bool {
        for s in &mut self.slots {
            if s.tag == tag {
                s.resources = resources.max(self.spec.r_unit);
                return true;
            }
        }
        false
    }

    /// Effective partition of a slot: nominal, scaled down when the device
    /// is oversubscribed (sum > r_max) — SM time-slicing.
    pub fn effective_resources(&self, slot: &ProcessSlot) -> f64 {
        let total = self.allocated();
        if total > self.spec.r_max {
            slot.resources * self.spec.r_max / total
        } else {
            slot.resources
        }
    }

    /// Adjust an existing process's partition / batch (MPS
    /// set_active_thread_percentage + Triton batch reconfig).
    pub fn reconfigure(&mut self, tag: u64, resources: Option<f64>, batch: Option<u32>) -> bool {
        let allocated_others: f64 = self
            .slots
            .iter()
            .filter(|s| s.tag != tag)
            .map(|s| s.resources)
            .sum();
        for s in &mut self.slots {
            if s.tag == tag {
                if let Some(r) = resources {
                    if r <= 0.0 || allocated_others + r > self.spec.r_max + 1e-9 {
                        return false;
                    }
                    s.resources = r;
                }
                if let Some(b) = batch {
                    s.batch = b.max(1);
                }
                return true;
            }
        }
        false
    }

    pub fn slots(&self) -> &[ProcessSlot] {
        &self.slots
    }

    pub fn slot(&self, tag: u64) -> Option<&ProcessSlot> {
        self.slots.iter().find(|s| s.tag == tag)
    }

    pub fn allocated(&self) -> f64 {
        self.slots.iter().map(|s| s.resources).sum()
    }

    pub fn free_resources(&self) -> f64 {
        (self.spec.r_max - self.allocated()).max(0.0)
    }

    pub fn co_located(&self) -> usize {
        self.slots.len()
    }

    // -- interference physics --------------------------------------------

    fn prof(&self, model: Model) -> WorkloadProfile {
        profile(model, self.spec.kind)
    }

    /// Aggregate L2 demand of all processes except `except_tag`.
    /// Uses *effective* partitions so oversubscribed devices (GSLICE-style
    /// force-growth past 100 %) time-slice instead of exceeding the
    /// physical resource range.
    fn others_cache_util(&self, except_tag: u64) -> f64 {
        self.slots
            .iter()
            .filter(|s| s.tag != except_tag)
            .map(|s| {
                self.prof(s.model)
                    .cache_util(s.batch as f64, self.effective_resources(s))
            })
            .sum()
    }

    /// Total power demand (Eq. 10 ground truth): idle + per-process power
    /// at each process's effective partition.
    pub fn power_demand_w(&self) -> f64 {
        self.spec.idle_power_w
            + self
                .slots
                .iter()
                .map(|s| {
                    self.prof(s.model)
                        .power_w(s.batch as f64, self.effective_resources(s))
                })
                .sum::<f64>()
    }

    /// Current governor frequency (MHz).
    pub fn frequency_mhz(&self) -> f64 {
        self.spec.frequency(self.power_demand_w())
    }

    /// Round-robin kernel scheduling: each kernel of the query waits one
    /// dispatch slot per *other* active stream before being issued.  The
    /// emergent per-kernel delay is k_sch + (m-1) * slot, slightly convex
    /// in m because the dispatcher saturates.  (The analytical model
    /// approximates this with the linear Eq. (5)+(6).)
    fn sched_delay_ms(&self, p: &WorkloadProfile) -> f64 {
        let m = self.slots.len().max(1);
        let others = (m - 1) as f64;
        // Per-slot dispatch cost for this hardware, chosen so the linear
        // fit over m in 2..=5 recovers approximately (alpha_sch, beta_sch).
        let slot = self.spec.alpha_sch;
        let convexity = 1.0 + 0.04 * others; // dispatcher saturation
        let per_kernel = p.k_sch + others * slot * convexity;
        per_kernel * p.n_kernels as f64
    }

    /// L2 contention dilation factor for a query of `tag`.  Linear in the
    /// co-runners' aggregate demand plus a mild superlinear correction.
    fn cache_dilation(&self, tag: u64, p: &WorkloadProfile) -> f64 {
        let u = self.others_cache_util(tag);
        1.0 + p.alpha_cache * u * (1.0 + 0.3 * u)
    }

    /// PCIe link utilization of all processes except `except_tag`: their
    /// steady-state transfer demand (ability x bytes/query) over the link
    /// bandwidth.  The paper *observes* this contention (Sec. 5.2 — it is
    /// why their model underpredicts AlexNet, whose load/feedback phases
    /// are 7-20 % of latency) but deliberately leaves it out of Eq. (3);
    /// the simulator models it so that omission shows up as a realistic
    /// prediction bias.
    fn others_pcie_util(&self, except_tag: u64) -> f64 {
        let bw_bytes_per_ms = self.spec.pcie_gbps * 1e6;
        self.slots
            .iter()
            .filter(|s| s.tag != except_tag)
            .map(|s| {
                let p = self.prof(s.model);
                let per_query = p.d_load_bytes + p.d_feedback_bytes;
                p.ability(s.batch as f64, self.effective_resources(s)) * per_query
                    / bw_bytes_per_ms
            })
            .sum::<f64>()
            .min(0.9)
    }

    /// L2 request hit ratio telemetry (Fig. 6 shape: decreasing in the
    /// total demand on the fixed-size cache).
    pub fn l2_hit_ratio(&self) -> f64 {
        let total: f64 = self
            .slots
            .iter()
            .map(|s| {
                self.prof(s.model)
                    .cache_util(s.batch as f64, self.effective_resources(s))
            })
            .sum();
        let base = 0.85;
        base * (1.0 - 0.45 * total / (total + 0.35))
    }

    pub fn telemetry(&self) -> DeviceTelemetry {
        DeviceTelemetry {
            power_w: self.power_demand_w(),
            freq_mhz: self.frequency_mhz(),
            l2_hit_ratio: self.l2_hit_ratio(),
            total_cache_util: self.others_cache_util(u64::MAX),
            allocated_resources: self.allocated(),
        }
    }

    /// Ground-truth latency of one query executed by process `tag` with
    /// `batch` requests, under the device's *current* co-location state.
    pub fn query_latency(&mut self, tag: u64, batch: u32) -> Option<QueryLatency> {
        let slot = self.slots.iter().find(|s| s.tag == tag)?.clone();
        let r_eff = self.effective_resources(&slot);
        let p = self.prof(slot.model);
        let b = batch.max(1) as f64;

        // PCIe phases stretched by link contention from co-runners (the
        // analytical model ignores this — see others_pcie_util).
        let pcie_dilation = 1.0 + self.others_pcie_util(tag);
        let t_load = p.load_ms(&self.spec, b) * pcie_dilation;
        let t_feedback = p.feedback_ms(&self.spec, b) * pcie_dilation;
        let t_sched = self.sched_delay_ms(&p);
        let mut t_act = p.k_act(b, r_eff) * self.cache_dilation(tag, &p);
        if self.noise_sigma > 0.0 {
            let noise = 1.0 + self.noise_sigma * self.rng.normal();
            t_act *= noise.max(0.5);
        }
        let freq = self.frequency_mhz();
        let t_gpu = (t_sched + t_act) / (freq / self.spec.max_freq_mhz);
        Some(QueryLatency {
            t_load,
            t_sched,
            t_act,
            t_feedback,
            freq_mhz: freq,
            t_gpu,
            t_inf: t_load + t_gpu + t_feedback,
        })
    }

    /// Steady-state throughput (req/s) of process `tag` at its configured
    /// batch: b / (t_gpu + t_feedback) (Eq. 2 — loading overlaps).
    pub fn process_throughput_rps(&mut self, tag: u64) -> Option<f64> {
        let slot = self.slots.iter().find(|s| s.tag == tag)?.clone();
        let q = self.query_latency(tag, slot.batch)?;
        Some(slot.batch as f64 / (q.t_gpu + q.t_feedback) * 1000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev() -> GpuDevice {
        GpuDevice::noiseless(GpuKind::V100)
    }

    #[test]
    fn launch_respects_capacity() {
        let mut d = dev();
        assert!(d.launch(1, Model::AlexNet, 0.6, 4));
        assert!(!d.launch(2, Model::Vgg19, 0.5, 4), "over-allocation allowed");
        assert!(d.launch(2, Model::Vgg19, 0.4, 4));
        assert!((d.free_resources() - 0.0).abs() < 1e-9);
        assert!(d.kill(1));
        assert!(!d.kill(1));
        assert!((d.free_resources() - 0.6).abs() < 1e-9);
    }

    #[test]
    fn reconfigure_checks_budget() {
        let mut d = dev();
        d.launch(1, Model::AlexNet, 0.5, 4);
        d.launch(2, Model::ResNet50, 0.3, 8);
        assert!(d.reconfigure(1, Some(0.7), None));
        assert!(!d.reconfigure(1, Some(0.8), None));
        assert!(d.reconfigure(2, None, Some(16)));
        assert_eq!(d.slot(2).unwrap().batch, 16);
        assert!(!d.reconfigure(99, Some(0.1), None));
    }

    #[test]
    fn colocation_increases_latency() {
        // Fig. 3: latency grows as identical co-runners are added.
        let mut prev = 0.0;
        for n in 1..=5u64 {
            let mut d = dev();
            for i in 0..n {
                assert!(d.launch(i, Model::ResNet50, 0.2, 4));
            }
            let q = d.query_latency(0, 4).unwrap();
            assert!(
                q.t_inf > prev,
                "n={n}: {:.3} !> {prev:.3}",
                q.t_inf
            );
            prev = q.t_inf;
        }
    }

    #[test]
    fn fig3_inflation_band() {
        // Paper: 0.83 % - 34.98 % inflation going 2 -> 5 co-located.
        let solo = {
            let mut d = dev();
            d.launch(0, Model::ResNet50, 0.2, 4);
            d.query_latency(0, 4).unwrap().t_inf
        };
        let mut d = dev();
        for i in 0..5 {
            d.launch(i, Model::ResNet50, 0.2, 4);
        }
        let five = d.query_latency(0, 4).unwrap().t_inf;
        let infl = five / solo - 1.0;
        assert!(
            (0.05..0.60).contains(&infl),
            "5-way inflation {:.1}% outside plausible band",
            infl * 100.0
        );
    }

    #[test]
    fn cobatch_affects_victim() {
        // Fig. 4: increasing the co-runner's batch inflates the victim.
        let mut lat = Vec::new();
        for b_co in [1u32, 8, 32] {
            let mut d = dev();
            d.launch(0, Model::ResNet50, 0.5, 16);
            d.launch(1, Model::Vgg19, 0.5, b_co);
            lat.push(d.query_latency(0, 16).unwrap().t_inf);
        }
        assert!(lat[0] < lat[1] && lat[1] < lat[2], "{lat:?}");
    }

    #[test]
    fn power_cap_reduces_frequency() {
        // Fig. 7: frequency at max below cap, dropping past it.
        let mut d = dev();
        d.launch(0, Model::Vgg19, 0.2, 16);
        assert_eq!(d.frequency_mhz(), d.spec.max_freq_mhz);
        for i in 1..5 {
            d.launch(i, Model::Vgg19, 0.2, 16);
        }
        assert!(d.power_demand_w() > d.spec.max_power_w);
        assert!(d.frequency_mhz() < d.spec.max_freq_mhz);
    }

    #[test]
    fn hit_ratio_decreases_with_colocation() {
        let mut prev = 1.0;
        for n in 1..=5u64 {
            let mut d = dev();
            for i in 0..n {
                d.launch(i, Model::ResNet50, 0.2, 4);
            }
            let h = d.l2_hit_ratio();
            assert!(h < prev, "n={n}");
            prev = h;
        }
    }

    #[test]
    fn more_resources_faster() {
        let mut d1 = dev();
        d1.launch(0, Model::Vgg19, 0.25, 8);
        let mut d2 = dev();
        d2.launch(0, Model::Vgg19, 0.75, 8);
        assert!(
            d2.query_latency(0, 8).unwrap().t_inf < d1.query_latency(0, 8).unwrap().t_inf
        );
    }

    #[test]
    fn throughput_matches_eq2() {
        let mut d = dev();
        d.launch(0, Model::ResNet50, 0.3, 8);
        let q = d.query_latency(0, 8).unwrap();
        let h = d.process_throughput_rps(0).unwrap();
        assert!((h - 8.0 / (q.t_gpu + q.t_feedback) * 1000.0).abs() < 1e-6);
        // Table 1: R(30 %, b8) sustains 400 req/s solo.
        assert!(h >= 400.0, "throughput {h:.0}");
    }

    #[test]
    fn noise_reproducible_per_seed() {
        let mut a = GpuDevice::new(GpuKind::V100, 7);
        let mut b = GpuDevice::new(GpuKind::V100, 7);
        a.launch(0, Model::Ssd, 0.5, 4);
        b.launch(0, Model::Ssd, 0.5, 4);
        for _ in 0..10 {
            assert_eq!(a.query_latency(0, 4), b.query_latency(0, 4));
        }
    }

    #[test]
    fn pcie_contention_stretches_transfers() {
        // SSD moves ~1.3 MB per query; co-locating transfer-heavy
        // neighbours must stretch t_load/t_feedback (the term Eq. (3)
        // deliberately ignores — Sec. 5.2's AlexNet underprediction).
        let mut solo = dev();
        solo.launch(0, Model::AlexNet, 0.25, 8);
        let q_solo = solo.query_latency(0, 8).unwrap();

        let mut busy = dev();
        busy.launch(0, Model::AlexNet, 0.25, 8);
        for i in 1..4 {
            busy.launch(i, Model::Ssd, 0.25, 16);
        }
        let q_busy = busy.query_latency(0, 8).unwrap();
        assert!(
            q_busy.t_load > q_solo.t_load * 1.01,
            "t_load {} !> {}",
            q_busy.t_load,
            q_solo.t_load
        );
        assert!(q_busy.t_feedback > q_solo.t_feedback * 1.01);
        // contention is bounded (link never past 90 % foreign utilization)
        assert!(q_busy.t_load < q_solo.t_load * 2.0);
    }

    #[test]
    fn query_latency_unknown_tag_is_none() {
        let mut d = dev();
        assert!(d.query_latency(42, 1).is_none());
        assert!(d.process_throughput_rps(42).is_none());
    }

    #[test]
    fn failed_device_drops_processes_and_refuses_launches() {
        let mut d = dev();
        assert!(d.launch(1, Model::AlexNet, 0.4, 4));
        assert!(d.launch(2, Model::ResNet50, 0.3, 8));
        d.fail();
        assert!(d.is_dead());
        assert_eq!(d.co_located(), 0, "resident processes vanish");
        assert_eq!(d.allocated(), 0.0);
        // resident queries now resolve to None, like any unknown tag
        assert!(d.query_latency(1, 4).is_none());
        assert!(!d.launch(3, Model::Ssd, 0.1, 1), "dead device accepted a launch");
        assert!(d.is_dead(), "death is permanent within a run");
    }
}
