//! GPU hardware specifications (the "hardware-specific coefficients" of
//! Sec. 3.1, plus the physical constants the simulator needs).
//!
//! Values for the V100 are the paper's measured ones (Sec. 5.1): max power
//! P = 300 W, max frequency F = 1530 MHz, idle power 53.5 W, PCIe bandwidth
//! 10 GB/s, frequency coefficient alpha_f = -1.025 MHz/W, scheduling
//! coefficients alpha_sch = 0.00475 ms, beta_sch = -0.00902 ms.  The T4
//! (g4dn.xlarge) has roughly half the compute and a third of the memory
//! bandwidth (Sec. 5.3).

/// Identifier of a GPU hardware generation.
///
/// A100/H100 are the MIG generations: their `r_unit` is one GPC (1/7 of
/// the device) and their contention coefficients are zero, because MIG
/// slices are hardware-isolated (dedicated SMs, partitioned L2, per-slice
/// schedulers).  See `provisioner::partition` for the planning-side view.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GpuKind {
    V100,
    T4,
    A100,
    H100,
}

impl GpuKind {
    pub fn name(&self) -> &'static str {
        match self {
            GpuKind::V100 => "V100",
            GpuKind::T4 => "T4",
            GpuKind::A100 => "A100",
            GpuKind::H100 => "H100",
        }
    }

    pub fn parse(s: &str) -> Option<GpuKind> {
        match s.to_ascii_lowercase().as_str() {
            "v100" => Some(GpuKind::V100),
            "t4" => Some(GpuKind::T4),
            "a100" => Some(GpuKind::A100),
            "h100" => Some(GpuKind::H100),
            _ => None,
        }
    }

    /// MIG-capable generations partition into discrete GPC slices instead
    /// of continuous MPS percentages.
    pub fn is_mig(&self) -> bool {
        matches!(self, GpuKind::A100 | GpuKind::H100)
    }
}

/// Hardware-specific coefficients of one GPU generation.
///
/// All times in **milliseconds**, power in watts, frequency in MHz,
/// bandwidth in GB/s, resources as fractions of the device in [0, 1].
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub kind: GpuKind,
    /// Number of streaming multiprocessors (resource granularity context;
    /// 2.5 % of a V100's 80 SMs = 2 SMs, the paper's `r_unit`).
    pub sm_count: u32,
    /// Upper power limit P (W).
    pub max_power_w: f64,
    /// Idle power p_idle (W).
    pub idle_power_w: f64,
    /// Maximum core frequency F (MHz).
    pub max_freq_mhz: f64,
    /// Frequency floor the governor will not go below (MHz).
    pub min_freq_mhz: f64,
    /// Frequency/power coefficient alpha_f (MHz per W above cap; negative).
    pub alpha_f: f64,
    /// Increased per-kernel scheduling delay slope alpha_sch (ms/workload).
    pub alpha_sch: f64,
    /// Increased per-kernel scheduling delay intercept beta_sch (ms).
    pub beta_sch: f64,
    /// Available PCIe bandwidth B_pcie (GB/s).
    pub pcie_gbps: f64,
    /// L2 cache size (MB) — scales cache-contention sensitivity.
    pub l2_cache_mb: f64,
    /// GPU resource allocation unit r_unit (fraction; 2.5 % on V100).
    pub r_unit: f64,
    /// Maximum allocatable resources r_max (fraction).
    pub r_max: f64,
}

impl GpuSpec {
    pub fn v100() -> GpuSpec {
        GpuSpec {
            kind: GpuKind::V100,
            sm_count: 80,
            max_power_w: 300.0,
            idle_power_w: 53.5,
            max_freq_mhz: 1530.0,
            min_freq_mhz: 900.0,
            alpha_f: -1.025,
            alpha_sch: 0.00475,
            beta_sch: -0.00902,
            pcie_gbps: 10.0,
            l2_cache_mb: 6.0,
            r_unit: 0.025,
            r_max: 1.0,
        }
    }

    pub fn t4() -> GpuSpec {
        GpuSpec {
            kind: GpuKind::T4,
            sm_count: 40,
            max_power_w: 70.0,
            idle_power_w: 17.0,
            max_freq_mhz: 1590.0,
            min_freq_mhz: 900.0,
            alpha_f: -3.4,
            alpha_sch: 0.00610,
            beta_sch: -0.01104,
            pcie_gbps: 8.0,
            l2_cache_mb: 4.0,
            r_unit: 0.025,
            r_max: 1.0,
        }
    }

    /// A100 (p4d): a MIG device.  One GPC = 1/7 of the part is the
    /// allocation unit, and the contention coefficients are zero — MIG
    /// slices own their SMs, their L2 partition, and their scheduler, so
    /// co-located slices neither delay each other's kernel dispatch nor
    /// dilate each other's active time.  PCIe is the one resource MIG
    /// does NOT partition; the shared-link coefficient stays live.
    pub fn a100() -> GpuSpec {
        GpuSpec {
            kind: GpuKind::A100,
            sm_count: 108,
            max_power_w: 400.0,
            idle_power_w: 52.0,
            max_freq_mhz: 1410.0,
            min_freq_mhz: 900.0,
            alpha_f: -1.0,
            alpha_sch: 0.0,
            beta_sch: 0.0,
            pcie_gbps: 25.0,
            l2_cache_mb: 40.0,
            r_unit: 1.0 / 7.0,
            r_max: 1.0,
        }
    }

    /// H100 (p5): same MIG geometry as the A100 with ~1.5x the compute
    /// and a 700 W envelope that co-located slices never approach.
    pub fn h100() -> GpuSpec {
        GpuSpec {
            kind: GpuKind::H100,
            sm_count: 132,
            max_power_w: 700.0,
            idle_power_w: 70.0,
            max_freq_mhz: 1980.0,
            min_freq_mhz: 1000.0,
            alpha_f: -1.0,
            alpha_sch: 0.0,
            beta_sch: 0.0,
            pcie_gbps: 50.0,
            l2_cache_mb: 50.0,
            r_unit: 1.0 / 7.0,
            r_max: 1.0,
        }
    }

    pub fn get(kind: GpuKind) -> GpuSpec {
        match kind {
            GpuKind::V100 => GpuSpec::v100(),
            GpuKind::T4 => GpuSpec::t4(),
            GpuKind::A100 => GpuSpec::a100(),
            GpuKind::H100 => GpuSpec::h100(),
        }
    }

    /// Increased per-kernel scheduling delay Delta_sch (Eq. 6) for `m`
    /// co-located workloads on this hardware.
    pub fn delta_sch(&self, co_located: usize) -> f64 {
        if co_located <= 1 {
            0.0
        } else {
            (self.alpha_sch * co_located as f64 + self.beta_sch).max(0.0)
        }
    }

    /// Governor frequency (Eq. 9) for a total power demand (W).
    pub fn frequency(&self, demand_w: f64) -> f64 {
        if demand_w <= self.max_power_w {
            self.max_freq_mhz
        } else {
            (self.max_freq_mhz + self.alpha_f * (demand_w - self.max_power_w))
                .max(self.min_freq_mhz)
        }
    }

    /// Quantize a resource fraction up to the allocation grid.
    pub fn quantize_up(&self, r: f64) -> f64 {
        ((r / self.r_unit).ceil() * self.r_unit).clamp(self.r_unit, self.r_max)
    }

    /// PCIe transfer time (ms) for `bytes` at full bandwidth.
    pub fn pcie_ms(&self, bytes: f64) -> f64 {
        // GB/s = bytes/ns; ms = bytes / (GB/s * 1e6)
        bytes / (self.pcie_gbps * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_constants() {
        let v = GpuSpec::v100();
        assert_eq!(v.max_power_w, 300.0);
        assert_eq!(v.max_freq_mhz, 1530.0);
        assert_eq!(v.idle_power_w, 53.5);
        assert_eq!(v.pcie_gbps, 10.0);
        assert_eq!(v.r_unit, 0.025);
    }

    #[test]
    fn delta_sch_zero_for_solo() {
        let v = GpuSpec::v100();
        assert_eq!(v.delta_sch(0), 0.0);
        assert_eq!(v.delta_sch(1), 0.0);
        // paper: Delta = 0.00475 * m - 0.00902
        assert!((v.delta_sch(3) - (0.00475 * 3.0 - 0.00902)).abs() < 1e-12);
        // monotone in co-location
        assert!(v.delta_sch(5) > v.delta_sch(3));
    }

    #[test]
    fn frequency_governor() {
        let v = GpuSpec::v100();
        assert_eq!(v.frequency(250.0), 1530.0);
        assert_eq!(v.frequency(300.0), 1530.0);
        let f = v.frequency(320.0);
        assert!((f - (1530.0 - 1.025 * 20.0)).abs() < 1e-9);
        // floor respected
        assert_eq!(v.frequency(5000.0), 900.0);
    }

    #[test]
    fn quantize() {
        let v = GpuSpec::v100();
        assert!((v.quantize_up(0.30) - 0.30).abs() < 1e-12);
        assert!((v.quantize_up(0.301) - 0.325).abs() < 1e-12);
        assert!((v.quantize_up(0.0) - 0.025).abs() < 1e-12);
        assert!((v.quantize_up(2.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pcie_time() {
        let v = GpuSpec::v100();
        // 10 MB at 10 GB/s = 1 ms
        assert!((v.pcie_ms(10e6) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn t4_is_weaker() {
        let t = GpuSpec::t4();
        let v = GpuSpec::v100();
        assert!(t.max_power_w < v.max_power_w);
        assert!(t.l2_cache_mb < v.l2_cache_mb);
        assert_eq!(GpuKind::parse("t4"), Some(GpuKind::T4));
        assert_eq!(GpuKind::parse("V100"), Some(GpuKind::V100));
        assert_eq!(GpuKind::parse("a100"), Some(GpuKind::A100));
        assert_eq!(GpuKind::parse("H100"), Some(GpuKind::H100));
        assert_eq!(GpuKind::parse("b200"), None);
    }

    #[test]
    fn mig_specs_are_hardware_isolated() {
        for spec in [GpuSpec::a100(), GpuSpec::h100()] {
            assert!(spec.kind.is_mig());
            // slice granularity: exactly seven GPCs per device
            assert!((spec.r_unit * 7.0 - 1.0).abs() < 1e-12, "{:?}", spec.kind);
            // no cross-slice scheduling delay, at any co-location level
            assert_eq!(spec.alpha_sch, 0.0);
            assert_eq!(spec.beta_sch, 0.0);
            for m in 0..8 {
                assert_eq!(spec.delta_sch(m), 0.0);
            }
        }
        assert!(!GpuKind::V100.is_mig());
        assert!(!GpuKind::T4.is_mig());
    }

    #[test]
    fn mig_quantize_lands_on_gpc_grid() {
        let a = GpuSpec::a100();
        for i in 1..=7u32 {
            let r = i as f64 / 7.0;
            // anything in the notch below rounds up to exactly this GPC count
            assert!((a.quantize_up(r - 1e-9) - r).abs() < 1e-9);
            assert!((a.quantize_up(r - 0.01) - r).abs() < 1e-9);
        }
    }
}
