//! Ground-truth workload profiles: the simulator's "physics" for each DNN
//! model on each GPU generation.
//!
//! These play the role of the authors' TensorRT engines on real V100/T4
//! hardware.  Magnitudes are calibrated to the paper's published
//! measurements (Sec. 2.2, Sec. 5, Figs. 4-9, 13; Table 1/3): e.g. VGG-19's
//! solo scheduling delay is 0.19 ms, AlexNet's power grows from ~108 W to
//! ~156 W as batch goes 1 -> 32, ResNet-50 at (30 %, b=8) sustains
//! ~400 req/s inside a 40 ms SLO, and so on.  The analytical model of
//! Sec. 3 never sees these structs — it only sees profiled observations, as
//! in the paper.
//!
//! Units: milliseconds, watts, fractions in [0,1] for resources and cache
//! utilization.

use super::spec::{GpuKind, GpuSpec};

/// The four paper workloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Model {
    AlexNet,
    ResNet50,
    Vgg19,
    Ssd,
}

pub const ALL_MODELS: [Model; 4] = [Model::AlexNet, Model::ResNet50, Model::Vgg19, Model::Ssd];

impl Model {
    pub fn name(&self) -> &'static str {
        match self {
            Model::AlexNet => "alexnet",
            Model::ResNet50 => "resnet50",
            Model::Vgg19 => "vgg19",
            Model::Ssd => "ssd",
        }
    }

    pub fn parse(s: &str) -> Option<Model> {
        match s.to_ascii_lowercase().as_str() {
            "alexnet" | "a" => Some(Model::AlexNet),
            "resnet50" | "resnet-50" | "r" => Some(Model::ResNet50),
            "vgg19" | "vgg-19" | "v" => Some(Model::Vgg19),
            "ssd" | "s" => Some(Model::Ssd),
            _ => None,
        }
    }

    pub fn short(&self) -> &'static str {
        match self {
            Model::AlexNet => "A",
            Model::ResNet50 => "R",
            Model::Vgg19 => "V",
            Model::Ssd => "S",
        }
    }
}

/// Ground-truth per-(model, GPU) physics.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadProfile {
    pub model: Model,
    pub gpu: GpuKind,
    /// Number of CUDA kernels per inference query (n_k).
    pub n_kernels: u32,
    /// Solo per-kernel scheduling delay k_sch (ms).
    pub k_sch: f64,
    /// Solo active-time law (Eq. 11 shape): (k1 b^2 + k2 b + k3)/(r + k4) + k5.
    pub k1: f64,
    pub k2: f64,
    pub k3: f64,
    pub k4: f64,
    pub k5: f64,
    /// Power law p = alpha_p * ability + beta_p where ability = b / k_act
    /// (queries per ms); watts above idle.
    pub alpha_power: f64,
    pub beta_power: f64,
    /// L2 cache-utilization law c = alpha_cu * ability + beta_cu (fraction).
    pub alpha_cacheutil: f64,
    pub beta_cacheutil: f64,
    /// Active-time dilation per unit of co-located cache utilization.
    pub alpha_cache: f64,
    /// Input / result bytes per single request (d_load, d_feedback).
    pub d_load_bytes: f64,
    pub d_feedback_bytes: f64,
}

impl WorkloadProfile {
    /// Solo GPU active time k_act(b, r) in ms — the Eq.-(11) ground truth.
    pub fn k_act(&self, batch: f64, r: f64) -> f64 {
        debug_assert!(r > 0.0 && r <= 1.0);
        (self.k1 * batch * batch + self.k2 * batch + self.k3) / (r + self.k4) + self.k5
    }

    /// GPU processing ability (queries/ms) at (b, r).
    pub fn ability(&self, batch: f64, r: f64) -> f64 {
        batch / self.k_act(batch, r)
    }

    /// Power contribution above idle (W) at (b, r); clamped at a small floor.
    pub fn power_w(&self, batch: f64, r: f64) -> f64 {
        (self.alpha_power * self.ability(batch, r) + self.beta_power).max(5.0)
    }

    /// L2 cache utilization (fraction of device L2 demanded) at (b, r).
    pub fn cache_util(&self, batch: f64, r: f64) -> f64 {
        (self.alpha_cacheutil * self.ability(batch, r) + self.beta_cacheutil).clamp(0.0, 1.0)
    }

    /// Solo total scheduling delay (ms).
    pub fn solo_sched_ms(&self) -> f64 {
        self.k_sch * self.n_kernels as f64
    }

    /// PCIe data-loading time for a batch (ms).
    pub fn load_ms(&self, spec: &GpuSpec, batch: f64) -> f64 {
        spec.pcie_ms(self.d_load_bytes * batch)
    }

    /// PCIe result-feedback time for a batch (ms).
    pub fn feedback_ms(&self, spec: &GpuSpec, batch: f64) -> f64 {
        spec.pcie_ms(self.d_feedback_bytes * batch)
    }
}

/// Ground-truth catalog.  V100 laws are primary; T4 derives from them with
/// the paper's "2x compute / 3x memory-bandwidth" ratio (Sec. 5.3).
/// A100/H100 derive the other way — faster parts — and, because MIG slices
/// are hardware-isolated (dedicated SMs + partitioned L2), their
/// cross-tenant dilation coefficient `alpha_cache` is zero: a neighbor's
/// cache pressure cannot reach a slice's own L2 partition.  PCIe stays
/// shared (MIG does not partition the host link).
pub fn profile(model: Model, gpu: GpuKind) -> WorkloadProfile {
    let v100 = v100_profile(model);
    match gpu {
        GpuKind::V100 => v100,
        GpuKind::T4 => WorkloadProfile {
            gpu: GpuKind::T4,
            // Half the compute throughput: active-time numerator doubles.
            k1: v100.k1 * 2.0,
            k2: v100.k2 * 2.0,
            k3: v100.k3 * 2.0,
            k4: v100.k4,
            k5: v100.k5 * 1.5,
            // Kernel dispatch is slightly slower on the smaller part.
            k_sch: v100.k_sch * 1.3,
            // T4 tops out at 70 W: power laws scale down.
            alpha_power: v100.alpha_power * 0.22,
            beta_power: v100.beta_power * 0.22,
            // Smaller L2 (4 MB vs 6 MB): same demand hurts more.
            alpha_cacheutil: v100.alpha_cacheutil * 1.5,
            beta_cacheutil: v100.beta_cacheutil * 1.5,
            alpha_cache: v100.alpha_cache * 1.5,
            ..v100
        },
        GpuKind::A100 => WorkloadProfile {
            gpu: GpuKind::A100,
            // ~2x V100 inference throughput (Ampere tensor cores).
            k1: v100.k1 * 0.5,
            k2: v100.k2 * 0.5,
            k3: v100.k3 * 0.5,
            k4: v100.k4,
            k5: v100.k5 * 0.8,
            k_sch: v100.k_sch * 0.9,
            // More efficient per query, and per-slice static draw is
            // small — the 400 W envelope is never the binding constraint
            // for any legal slice mix (even 7x 1g tenants).
            alpha_power: v100.alpha_power * 0.9,
            beta_power: v100.beta_power * 0.3,
            // 40 MB L2, partitioned per slice: own-footprint telemetry
            // shrinks and cross-tenant dilation is physically impossible.
            alpha_cacheutil: v100.alpha_cacheutil * 0.3,
            beta_cacheutil: v100.beta_cacheutil * 0.3,
            alpha_cache: 0.0,
            ..v100
        },
        GpuKind::H100 => WorkloadProfile {
            gpu: GpuKind::H100,
            // ~3x V100 throughput (Hopper), same MIG isolation story.
            k1: v100.k1 / 3.0,
            k2: v100.k2 / 3.0,
            k3: v100.k3 / 3.0,
            k4: v100.k4,
            k5: v100.k5 * 0.7,
            k_sch: v100.k_sch * 0.8,
            alpha_power: v100.alpha_power,
            beta_power: v100.beta_power * 0.35,
            alpha_cacheutil: v100.alpha_cacheutil * 0.25,
            beta_cacheutil: v100.beta_cacheutil * 0.25,
            alpha_cache: 0.0,
            ..v100
        },
    }
}

fn v100_profile(model: Model) -> WorkloadProfile {
    match model {
        // Calibration notes (paper refs in brackets):
        //  - AlexNet power 108->156 W for b 1->32 [Sec. 2.2]; cache util
        //    11.1 % -> 18.4 % [Sec. 2.2]; Table 1 plan A(10 %, b4) serves
        //    500 r/s inside a 15 ms SLO.
        Model::AlexNet => WorkloadProfile {
            model,
            gpu: GpuKind::V100,
            n_kernels: 29,
            k_sch: 0.0030,
            k1: 0.0001,
            k2: 0.155,
            k3: 0.09,
            k4: 0.02,
            k5: 0.05,
            alpha_power: 20.0,
            beta_power: 15.0,
            alpha_cacheutil: 0.035,
            beta_cacheutil: -0.004,
            alpha_cache: 0.5,
            d_load_bytes: 602_112.0,  // 224*224*3*4
            d_feedback_bytes: 4_000.0, // 1000 classes
        },
        //  - ResNet-50: Table 1 plan R(30 %, b8) serves 400 r/s inside a
        //    40 ms SLO; many small kernels -> scheduling-delay sensitive
        //    [Fig. 5, Sec. 5.2]; cache-contention sensitive [Fig. 4].
        Model::ResNet50 => WorkloadProfile {
            model,
            gpu: GpuKind::V100,
            n_kernels: 80,
            k_sch: 0.0025,
            k1: 0.0004,
            k2: 0.628,
            k3: 0.45,
            k4: 0.02,
            k5: 0.10,
            alpha_power: 60.0,
            beta_power: 35.0,
            alpha_cacheutil: 0.12,
            beta_cacheutil: 0.02,
            alpha_cache: 0.9,
            d_load_bytes: 602_112.0,
            d_feedback_bytes: 4_000.0,
        },
        //  - VGG-19: solo scheduling delay 0.19 ms [Sec. 5.2]; power
        //    139->179 W for b 1->32 and cache util 16.9 % -> 22.0 %
        //    [Sec. 2.2]; Table 1 plan V(37.5 %, b6) serves 200 r/s
        //    inside a 60 ms SLO.
        Model::Vgg19 => WorkloadProfile {
            model,
            gpu: GpuKind::V100,
            n_kernels: 43,
            k_sch: 0.0045,
            k1: 0.0005,
            k2: 1.797,
            k3: 0.50,
            k4: 0.02,
            k5: 0.15,
            alpha_power: 120.0,
            beta_power: 40.0,
            alpha_cacheutil: 0.40,
            beta_cacheutil: 0.0,
            alpha_cache: 0.8,
            d_load_bytes: 602_112.0,
            d_feedback_bytes: 4_000.0,
        },
        //  - SSD: heaviest (62.8 GFLOPs, Table 3); large detection output.
        Model::Ssd => WorkloadProfile {
            model,
            gpu: GpuKind::V100,
            n_kernels: 95,
            k_sch: 0.0030,
            k1: 0.0008,
            k2: 2.315,
            k3: 0.80,
            k4: 0.02,
            k5: 0.30,
            alpha_power: 180.0,
            beta_power: 50.0,
            alpha_cacheutil: 0.35,
            beta_cacheutil: 0.05,
            alpha_cache: 0.7,
            d_load_bytes: 1_080_000.0, // 300*300*3*4
            d_feedback_bytes: 200_000.0,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kact_monotonicity() {
        for m in ALL_MODELS {
            let p = profile(m, GpuKind::V100);
            // decreasing in resources
            assert!(p.k_act(8.0, 0.2) > p.k_act(8.0, 0.4));
            assert!(p.k_act(8.0, 0.4) > p.k_act(8.0, 1.0));
            // increasing in batch
            assert!(p.k_act(16.0, 0.5) > p.k_act(4.0, 0.5));
        }
    }

    #[test]
    fn ability_grows_with_batch() {
        // Fig. 9 premise: processing ability (and hence power/cache util)
        // grows with batch size at fixed resources.
        for m in ALL_MODELS {
            let p = profile(m, GpuKind::V100);
            assert!(
                p.ability(32.0, 1.0) > p.ability(1.0, 1.0),
                "{m:?}: {} !> {}",
                p.ability(32.0, 1.0),
                p.ability(1.0, 1.0)
            );
        }
    }

    #[test]
    fn table1_plans_feasible() {
        // Table 1: A(10 %, b4) @ 500 r/s / 15 ms, R(30 %, b8) @ 400 r/s
        // / 40 ms, V(37.5 %, b6) @ 200 r/s / 60 ms — solo latencies must
        // fit half the SLO (Eq. 14) with a little headroom for
        // interference.
        let spec = GpuSpec::v100();
        let cases = [
            (Model::AlexNet, 4.0, 0.10, 15.0, 500.0),
            (Model::ResNet50, 8.0, 0.30, 40.0, 400.0),
            (Model::Vgg19, 6.0, 0.375, 60.0, 200.0),
        ];
        for (m, b, r, slo, rate) in cases {
            let p = profile(m, GpuKind::V100);
            let t_gpu = p.solo_sched_ms() + p.k_act(b, r);
            let t_inf = p.load_ms(&spec, b) + t_gpu + p.feedback_ms(&spec, b);
            assert!(
                t_inf < slo / 2.0,
                "{m:?}: t_inf {t_inf:.2} !< {}",
                slo / 2.0
            );
            let thpt = b / (t_gpu + p.feedback_ms(&spec, b)) * 1000.0;
            assert!(thpt >= rate, "{m:?}: thpt {thpt:.0} < {rate}");
        }
    }

    #[test]
    fn model_ordering_matches_flops() {
        // Table 3 ordering: AlexNet < ResNet-50 < VGG-19 < SSD at the
        // same operating point.
        let at = |m| profile(m, GpuKind::V100).k_act(8.0, 0.5);
        assert!(at(Model::AlexNet) < at(Model::ResNet50));
        assert!(at(Model::ResNet50) < at(Model::Vgg19));
        assert!(at(Model::Vgg19) < at(Model::Ssd));
    }

    #[test]
    fn t4_slower_than_v100() {
        for m in ALL_MODELS {
            let v = profile(m, GpuKind::V100);
            let t = profile(m, GpuKind::T4);
            assert!(t.k_act(8.0, 0.5) > 1.5 * v.k_act(8.0, 0.5));
        }
    }

    #[test]
    fn mig_parts_are_faster_and_isolated() {
        for m in ALL_MODELS {
            let v = profile(m, GpuKind::V100);
            let a = profile(m, GpuKind::A100);
            let h = profile(m, GpuKind::H100);
            // strictly faster than V100, H100 faster still
            assert!(a.k_act(8.0, 0.5) < v.k_act(8.0, 0.5));
            assert!(h.k_act(8.0, 0.5) < a.k_act(8.0, 0.5));
            // the isolation statement: zero cross-tenant dilation
            assert_eq!(a.alpha_cache, 0.0);
            assert_eq!(h.alpha_cache, 0.0);
        }
    }

    #[test]
    fn mig_power_fits_the_envelope_with_full_tenancy() {
        // Seven 1g tenants plus one full-device tenant's worth of power
        // must stay far from the cap: MIG fleets never throttle, so the
        // solo-collapsed planner predictions stay honest.
        for (spec, kind) in [
            (GpuSpec::a100(), GpuKind::A100),
            (GpuSpec::h100(), GpuKind::H100),
        ] {
            for m in ALL_MODELS {
                let p = profile(m, kind);
                let one_gpc = 1.0 / 7.0;
                let demand = spec.idle_power_w + 7.0 * p.power_w(4.0, one_gpc);
                assert!(
                    demand < spec.max_power_w,
                    "{m:?} on {kind:?}: {demand:.0} W >= cap"
                );
                assert_eq!(spec.frequency(demand), spec.max_freq_mhz);
            }
        }
    }

    #[test]
    fn power_ranges_sane() {
        // total demand of a plausible single workload stays under cap
        let spec = GpuSpec::v100();
        for m in ALL_MODELS {
            let p = profile(m, GpuKind::V100);
            let pw = p.power_w(16.0, 1.0);
            assert!(pw > 5.0 && pw + spec.idle_power_w < spec.max_power_w,
                "{m:?} power {pw}");
        }
    }

    #[test]
    fn names_roundtrip() {
        for m in ALL_MODELS {
            assert_eq!(Model::parse(m.name()), Some(m));
        }
    }
}
