//! Simulated GPU substrate: hardware specs, ground-truth workload physics,
//! and the MPS spatial-sharing device model with the paper's three
//! interference mechanisms (scheduler, L2 cache, power/DVFS).

pub mod device;
pub mod profile;
pub mod spec;

pub use device::{DeviceTelemetry, GpuDevice, ProcessSlot, QueryLatency};
pub use profile::{profile, Model, WorkloadProfile, ALL_MODELS};
pub use spec::{GpuKind, GpuSpec};
