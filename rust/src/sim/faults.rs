//! Seeded fault injection: deterministic chaos plans for the serving sim.
//!
//! A [`FaultPlan`] is a pure function of `(space, master_seed, id)` under
//! the same SplitMix64 lane discipline as `sweep/scenario.rs`: scenario
//! generation owns lane `(1, id+1)`, the sim/arrival seed lane `(2,
//! task+1)`, and fault plans lane `(3, task+1)` — so enabling faults
//! never perturbs the scenario mix or the arrival realizations, and the
//! chaos sweep stays bit-identical across `--parallel` widths.
//!
//! All randomness is baked at plan-generation time.  Events carry *raw*
//! `u64` targets that the sim resolves modulo the live entity count at
//! fire time (device count for deaths/stragglers, routable replica count
//! for hangs); the sim itself draws no RNG for faults, so the arrival
//! streams are byte-identical with and without a plan installed.  An
//! empty plan schedules nothing — zero extra events, zero extra sequence
//! numbers — making the disabled lane a bitwise no-op (the committed
//! sweep-fingerprint golden is the proof obligation; see
//! `tests/sweep_determinism.rs`).

use crate::util::rng::Rng;

/// Envelope the chaos lane samples fault plans from.  `OFF` (all maxima
/// zero) generates the empty plan without consuming any RNG.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpace {
    /// Maximum GPU devices killed per task (actual count is uniform in
    /// `0..=max`, so some chaos tasks stay fault-free on purpose).
    pub max_device_deaths: u32,
    /// Maximum transient straggler episodes per task.
    pub max_stragglers: u32,
    /// Maximum replica hangs per task.
    pub max_hangs: u32,
    /// Straggler latency dilation factor, uniform in `[lo, hi)`.  Kept
    /// well above the detector's trip ratio so episodes are observable.
    pub straggler_factor: (f64, f64),
    /// Straggler episode length (ms), uniform in `[lo, hi)`.
    pub straggler_span_ms: (f64, f64),
    /// Fraction of the horizon faults may fire in.  The default leaves
    /// the tail free so recovery (respec -> warm -> switch -> first
    /// served batch) completes inside the measured run.
    pub window: (f64, f64),
}

impl FaultSpace {
    /// The disabled lane: generates the empty plan, injects nothing.
    pub const OFF: FaultSpace = FaultSpace {
        max_device_deaths: 0,
        max_stragglers: 0,
        max_hangs: 0,
        straggler_factor: (0.0, 0.0),
        straggler_span_ms: (0.0, 0.0),
        window: (0.0, 0.0),
    };

    /// The `--faults` chaos envelope: up to one device death plus a
    /// couple of latency pathologies per task, inside the mid-run window.
    pub fn chaos() -> FaultSpace {
        FaultSpace {
            max_device_deaths: 1,
            max_stragglers: 2,
            max_hangs: 1,
            straggler_factor: (2.0, 5.0),
            straggler_span_ms: (300.0, 900.0),
            window: (0.25, 0.60),
        }
    }

    pub fn is_off(&self) -> bool {
        self.max_device_deaths == 0 && self.max_stragglers == 0 && self.max_hangs == 0
    }

    /// Parse a `serve --faults` spec: comma-separated `key=value` pairs
    /// over the `chaos()` defaults (`deaths`, `stragglers`, `hangs`,
    /// `factor` = straggler dilation upper bound, `span_ms` = episode
    /// upper bound).  An empty spec is the plain chaos envelope.
    pub fn parse_spec(spec: &str) -> Result<FaultSpace, String> {
        let mut space = FaultSpace::chaos();
        for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| format!("fault spec '{part}' is not key=value"))?;
            let num = || {
                value
                    .trim()
                    .parse::<f64>()
                    .map_err(|_| format!("fault spec '{key}' value '{value}' is not a number"))
            };
            match key.trim() {
                "deaths" => space.max_device_deaths = num()? as u32,
                "stragglers" => space.max_stragglers = num()? as u32,
                "hangs" => space.max_hangs = num()? as u32,
                "factor" => {
                    let hi = num()?;
                    if hi <= 1.0 {
                        return Err(format!("straggler factor {hi} must exceed 1.0"));
                    }
                    space.straggler_factor = (space.straggler_factor.0.min(hi), hi);
                }
                "span_ms" => {
                    let hi = num()?;
                    if hi <= 0.0 {
                        return Err(format!("straggler span {hi} must be positive"));
                    }
                    space.straggler_span_ms = (space.straggler_span_ms.0.min(hi), hi);
                }
                other => {
                    return Err(format!(
                        "unknown fault spec key '{other}' (deaths, stragglers, hangs, \
                         factor, span_ms)"
                    ))
                }
            }
        }
        Ok(space)
    }
}

/// What a scheduled fault does when it fires.  Targets are raw draws;
/// the sim resolves them modulo the live entity count at fire time so
/// the plan never needs to know fleet shapes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Kill device `target % num_devices`: resident replicas retire,
    /// queued requests fail over, the planner replaces capacity.
    DeviceDeath { target: u64 },
    /// Dilate exec latency on device `target % num_devices` by `factor`
    /// for `span_ms` — transient, clears on its own.
    Straggler {
        target: u64,
        factor: f64,
        span_ms: f64,
    },
    /// Freeze replica `target % live_replicas`: it keeps accepting work
    /// but never completes until the monitor's breaker condemns it.
    ReplicaHang { target: u64 },
}

/// One timed fault.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub at_ms: f64,
    pub kind: FaultKind,
}

/// A deterministic schedule of faults for one serving task.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// The empty plan (what `FaultSpace::OFF` generates).
    pub fn none() -> FaultPlan {
        FaultPlan { events: Vec::new() }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Sample a plan: pure in `(space, master, id, horizon_ms)`.  Lane
    /// `(3, id+1)` of the master seed — disjoint from scenario
    /// generation `(1, id+1)` and sim seeds `(2, task+1)` by the split
    /// tag.  Draw order is fixed (counts, then per-event fields in kind
    /// order) so extending the space later cannot silently reshuffle
    /// existing draws.
    pub fn generate(space: &FaultSpace, master: u64, id: usize, horizon_ms: f64) -> FaultPlan {
        if space.is_off() {
            return FaultPlan::none();
        }
        let mut rng = Rng::new(master).split(3).split(id as u64 + 1);
        let (wlo, whi) = space.window;
        let mut at = |rng: &mut Rng| horizon_ms * (wlo + (whi - wlo) * rng.f64());
        let n_deaths = rng.below(space.max_device_deaths as u64 + 1);
        let n_stragglers = rng.below(space.max_stragglers as u64 + 1);
        let n_hangs = rng.below(space.max_hangs as u64 + 1);
        let mut events = Vec::with_capacity((n_deaths + n_stragglers + n_hangs) as usize);
        for _ in 0..n_deaths {
            events.push(FaultEvent {
                at_ms: at(&mut rng),
                kind: FaultKind::DeviceDeath {
                    target: rng.next_u64(),
                },
            });
        }
        for _ in 0..n_stragglers {
            events.push(FaultEvent {
                at_ms: at(&mut rng),
                kind: FaultKind::Straggler {
                    target: rng.next_u64(),
                    factor: rng.range_f64(space.straggler_factor.0, space.straggler_factor.1),
                    span_ms: rng
                        .range_f64(space.straggler_span_ms.0, space.straggler_span_ms.1),
                },
            });
        }
        for _ in 0..n_hangs {
            events.push(FaultEvent {
                at_ms: at(&mut rng),
                kind: FaultKind::ReplicaHang {
                    target: rng.next_u64(),
                },
            });
        }
        // Stable sort by fire time: equal times keep kind order, so the
        // plan (and thus the event-queue schedule order) is deterministic.
        events.sort_by(|a, b| a.at_ms.total_cmp(&b.at_ms));
        FaultPlan { events }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_space_generates_the_empty_plan() {
        assert!(FaultSpace::OFF.is_off());
        let plan = FaultPlan::generate(&FaultSpace::OFF, 42, 7, 6000.0);
        assert!(plan.is_empty());
        assert_eq!(plan, FaultPlan::none());
    }

    #[test]
    fn property_generation_is_pure_and_seed_sensitive() {
        let space = FaultSpace::chaos();
        crate::util::quick::forall(
            811,
            24,
            |r| (r.next_u64(), r.below(64) as usize),
            |&(master, id)| {
                let a = FaultPlan::generate(&space, master, id, 6000.0);
                let b = FaultPlan::generate(&space, master, id, 6000.0);
                if a != b {
                    return Err(format!("plan not pure for ({master}, {id})"));
                }
                let other = FaultPlan::generate(&space, master ^ 0x5A5A, id, 6000.0);
                // a different master *may* coincide on the empty plan;
                // only flag identical non-trivial plans
                if !a.is_empty() && a == other {
                    return Err(format!("master seed ignored for ({master}, {id})"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn events_fire_inside_the_window_in_time_order() {
        let space = FaultSpace::chaos();
        let horizon = 8000.0;
        let mut any = false;
        for id in 0..48 {
            let plan = FaultPlan::generate(&space, 99, id, horizon);
            any |= !plan.is_empty();
            for w in plan.events.windows(2) {
                assert!(w[0].at_ms <= w[1].at_ms, "plan not sorted: {plan:?}");
            }
            for ev in &plan.events {
                assert!(
                    ev.at_ms >= horizon * space.window.0 - 1e-9
                        && ev.at_ms <= horizon * space.window.1 + 1e-9,
                    "event outside window: {ev:?}"
                );
            }
        }
        assert!(any, "chaos space never produced a fault across 48 ids");
    }

    #[test]
    fn chaos_space_draws_every_fault_kind_somewhere() {
        let space = FaultSpace::chaos();
        let (mut deaths, mut strag, mut hangs) = (0, 0, 0);
        for id in 0..64 {
            for ev in &FaultPlan::generate(&space, 7, id, 5000.0).events {
                match ev.kind {
                    FaultKind::DeviceDeath { .. } => deaths += 1,
                    FaultKind::Straggler { factor, span_ms, .. } => {
                        assert!((2.0..5.0).contains(&factor), "factor {factor}");
                        assert!((300.0..900.0).contains(&span_ms), "span {span_ms}");
                        strag += 1;
                    }
                    FaultKind::ReplicaHang { .. } => hangs += 1,
                }
            }
        }
        assert!(
            deaths > 0 && strag > 0 && hangs > 0,
            "kinds not all drawn: deaths={deaths} stragglers={strag} hangs={hangs}"
        );
    }

    #[test]
    fn spec_parsing_overrides_and_rejects() {
        let s = FaultSpace::parse_spec("deaths=2,hangs=0,factor=3.5,span_ms=500").unwrap();
        assert_eq!(s.max_device_deaths, 2);
        assert_eq!(s.max_hangs, 0);
        assert_eq!(s.straggler_factor.1, 3.5);
        assert_eq!(s.straggler_span_ms.1, 500.0);
        assert_eq!(FaultSpace::parse_spec("").unwrap(), FaultSpace::chaos());
        assert!(FaultSpace::parse_spec("bogus=1").is_err());
        assert!(FaultSpace::parse_spec("deaths").is_err());
        assert!(FaultSpace::parse_spec("factor=0.5").is_err());
    }
}
