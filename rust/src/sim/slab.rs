//! Slab-backed FIFO request queues: all replicas' in-flight request
//! timestamps live in ONE arena with an intrusive free list, instead of
//! a `VecDeque<f64>` per replica.  Queue handles (`ReqQueue`) are three
//! `u32`s, so the struct-of-arrays replica state stays `Copy`-dense, and
//! the steady-state serve loop (push arrival / pop completion at matched
//! rates) recycles nodes without ever touching the allocator.

/// Sentinel index: "no node".
pub const NIL: u32 = u32::MAX;

#[derive(Debug, Clone, Copy)]
struct Node {
    arrival: f64,
    /// Next node in its queue, or next free node when on the free list.
    next: u32,
}

/// One FIFO of arrival timestamps inside a [`RequestSlab`].  Plain data:
/// every operation goes through the slab, which owns the nodes.
#[derive(Debug, Clone, Copy)]
pub struct ReqQueue {
    head: u32,
    tail: u32,
    len: u32,
}

impl ReqQueue {
    pub const fn new() -> ReqQueue {
        ReqQueue {
            head: NIL,
            tail: NIL,
            len: 0,
        }
    }

    /// Queue depth — kept in the handle so routing reads it without
    /// chasing slab pointers.
    pub fn len(&self) -> usize {
        self.len as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl Default for ReqQueue {
    fn default() -> Self {
        Self::new()
    }
}

/// The shared arena of queue nodes (one per in-flight request).
#[derive(Debug, Default)]
pub struct RequestSlab {
    nodes: Vec<Node>,
    /// Head of the free list threaded through `Node::next`.
    free: u32,
    live: usize,
}

impl RequestSlab {
    pub fn new() -> RequestSlab {
        RequestSlab {
            nodes: Vec::new(),
            free: NIL,
            live: 0,
        }
    }

    fn alloc(&mut self, arrival: f64) -> u32 {
        self.live += 1;
        if self.free != NIL {
            let i = self.free;
            self.free = self.nodes[i as usize].next;
            self.nodes[i as usize] = Node { arrival, next: NIL };
            i
        } else {
            let i = self.nodes.len();
            assert!(i < NIL as usize, "request slab exhausted u32 index space");
            self.nodes.push(Node { arrival, next: NIL });
            i as u32
        }
    }

    /// Append an arrival timestamp to `q`.
    pub fn push_back(&mut self, q: &mut ReqQueue, arrival: f64) {
        let i = self.alloc(arrival);
        if q.tail == NIL {
            q.head = i;
        } else {
            self.nodes[q.tail as usize].next = i;
        }
        q.tail = i;
        q.len += 1;
    }

    /// Pop the oldest arrival from `q`, recycling its node.
    pub fn pop_front(&mut self, q: &mut ReqQueue) -> Option<f64> {
        if q.head == NIL {
            return None;
        }
        let i = q.head;
        let node = self.nodes[i as usize];
        q.head = node.next;
        if q.head == NIL {
            q.tail = NIL;
        }
        q.len -= 1;
        self.nodes[i as usize].next = self.free;
        self.free = i;
        self.live -= 1;
        Some(node.arrival)
    }

    /// Oldest arrival in `q` without popping.
    pub fn front(&self, q: &ReqQueue) -> Option<f64> {
        if q.head == NIL {
            None
        } else {
            Some(self.nodes[q.head as usize].arrival)
        }
    }

    /// Requests currently queued across all queues.
    pub fn live(&self) -> usize {
        self.live
    }

    /// Nodes ever allocated (high-water mark of concurrent requests).
    pub fn capacity(&self) -> usize {
        self.nodes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_per_queue_across_a_shared_slab() {
        let mut slab = RequestSlab::new();
        let mut a = ReqQueue::new();
        let mut b = ReqQueue::new();
        // interleave pushes so node indices alternate between queues
        for i in 0..5 {
            slab.push_back(&mut a, i as f64);
            slab.push_back(&mut b, 100.0 + i as f64);
        }
        assert_eq!(a.len(), 5);
        assert_eq!(slab.front(&a), Some(0.0));
        assert_eq!(slab.front(&b), Some(100.0));
        for i in 0..5 {
            assert_eq!(slab.pop_front(&mut a), Some(i as f64));
            assert_eq!(slab.pop_front(&mut b), Some(100.0 + i as f64));
        }
        assert!(a.is_empty() && b.is_empty());
        assert_eq!(slab.pop_front(&mut a), None);
        assert_eq!(slab.live(), 0);
    }

    #[test]
    fn free_list_reuse_bounds_capacity() {
        // Steady-state churn (push/pop at matched rates) must recycle
        // nodes: capacity stays at the high-water mark, not the total
        // number of requests ever pushed.
        let mut slab = RequestSlab::new();
        let mut q = ReqQueue::new();
        for i in 0..4 {
            slab.push_back(&mut q, i as f64);
        }
        let high_water = slab.capacity();
        for i in 4..10_000 {
            assert_eq!(slab.pop_front(&mut q), Some((i - 4) as f64));
            slab.push_back(&mut q, i as f64);
        }
        assert_eq!(slab.capacity(), high_water);
        assert_eq!(q.len(), 4);
        assert_eq!(slab.front(&q), Some(9_996.0));
    }

    #[test]
    fn property_interleaved_ops_match_a_vecdeque_oracle() {
        // Random interleavings of push/pop/front across several queues
        // sharing one slab must match independent `VecDeque`s exactly —
        // FIFO order, lengths, and the live count.  Failover re-queueing
        // (coordinator/server.rs) leans on exactly this behavior when it
        // drains a dead replica's queue into survivors.
        use std::collections::VecDeque;
        crate::util::quick::forall(
            812,
            40,
            |r| {
                let n = 50 + r.below(250) as usize;
                (0..n)
                    .map(|_| (r.below(100), r.below(4) as usize, r.f64()))
                    .collect::<Vec<(u64, usize, f64)>>()
            },
            |ops| {
                let mut slab = RequestSlab::new();
                let mut qs = [ReqQueue::new(); 4];
                let mut oracle: [VecDeque<f64>; 4] = Default::default();
                for &(sel, qi, val) in ops {
                    if sel < 55 {
                        slab.push_back(&mut qs[qi], val);
                        oracle[qi].push_back(val);
                    } else if sel < 90 {
                        let got = slab.pop_front(&mut qs[qi]);
                        let want = oracle[qi].pop_front();
                        crate::prop_assert!(
                            got.map(f64::to_bits) == want.map(f64::to_bits),
                            "pop diverged on queue {qi}: {got:?} vs {want:?}"
                        );
                    } else {
                        let got = slab.front(&qs[qi]);
                        let want = oracle[qi].front().copied();
                        crate::prop_assert!(
                            got.map(f64::to_bits) == want.map(f64::to_bits),
                            "front diverged on queue {qi}"
                        );
                    }
                    crate::prop_assert!(
                        qs[qi].len() == oracle[qi].len(),
                        "len diverged on queue {qi}: {} vs {}",
                        qs[qi].len(),
                        oracle[qi].len()
                    );
                }
                let live: usize = oracle.iter().map(|q| q.len()).sum();
                crate::prop_assert!(slab.live() == live, "live count diverged");
                // drain everything; each queue must replay its oracle
                for (qi, q) in qs.iter_mut().enumerate() {
                    while let Some(want) = oracle[qi].pop_front() {
                        let got = slab.pop_front(q);
                        crate::prop_assert!(
                            got.map(f64::to_bits) == Some(want.to_bits()),
                            "drain diverged on queue {qi}"
                        );
                    }
                    crate::prop_assert!(slab.pop_front(q).is_none(), "queue {qi} not empty");
                }
                crate::prop_assert!(slab.live() == 0, "slab live after full drain");
                Ok(())
            },
        );
    }

    #[test]
    fn free_list_recycles_nodes_in_lifo_order() {
        // The free list is intrusive and LIFO: after popping nodes 0..3,
        // fresh pushes must reuse index 3, 2, 1, 0 — no growth.  Pinning
        // the reuse order catches accidental rewrites that would still
        // pass the capacity bound but change allocation locality.
        let mut slab = RequestSlab::new();
        let mut q = ReqQueue::new();
        for i in 0..4 {
            slab.push_back(&mut q, i as f64);
        }
        for _ in 0..4 {
            slab.pop_front(&mut q);
        }
        assert_eq!(slab.capacity(), 4);
        assert_eq!(slab.live(), 0);
        for i in 0..4 {
            slab.push_back(&mut q, 10.0 + i as f64);
            assert_eq!(slab.capacity(), 4, "push {i} allocated a fresh node");
        }
        // a fifth push must grow the arena exactly once
        slab.push_back(&mut q, 99.0);
        assert_eq!(slab.capacity(), 5);
        assert_eq!(q.len(), 5);
        for want in [10.0, 11.0, 12.0, 13.0, 99.0] {
            assert_eq!(slab.pop_front(&mut q), Some(want));
        }
    }

    #[test]
    fn emptied_queue_handle_is_reusable() {
        let mut slab = RequestSlab::new();
        let mut q = ReqQueue::new();
        slab.push_back(&mut q, 1.0);
        assert_eq!(slab.pop_front(&mut q), Some(1.0));
        // tail must have been reset alongside head
        slab.push_back(&mut q, 2.0);
        slab.push_back(&mut q, 3.0);
        assert_eq!(slab.pop_front(&mut q), Some(2.0));
        assert_eq!(slab.pop_front(&mut q), Some(3.0));
        assert_eq!(slab.pop_front(&mut q), None);
    }
}
