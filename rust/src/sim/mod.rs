//! Discrete-event simulation engine: a virtual millisecond clock and an
//! event queue with deterministic tie-breaking.  The serving coordinator
//! (rust/src/coordinator) runs on top of this for all latency/throughput
//! experiments, so results are exactly reproducible per seed.
//!
//! The queue is a calendar (bucketed) queue rather than a single binary
//! heap: serving timestamps are dense and bounded (sub-ms gaps, horizons
//! of seconds to minutes), so binning events into 1 ms buckets makes the
//! common push O(1) instead of O(log n) while popping in exactly the same
//! `total_cmp`-then-FIFO order as the heap it replaced.  The old heap
//! survives under `#[cfg(test)]` as `reference::HeapQueue`, the ordering
//! oracle for the differential property test below.  See DESIGN.md
//! §"Sim-core memory layout" for the pop-order proof sketch.

pub mod faults;
pub mod slab;

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Virtual time in milliseconds.
pub type SimTime = f64;

/// Bucket width is 1 ms; the ring covers this many consecutive buckets.
/// Must be a power of two (slot index is `bucket & RING_MASK`).
const RING_BUCKETS: u64 = 2048;
const RING_MASK: u64 = RING_BUCKETS - 1;

/// Millisecond bucket of a timestamp: `floor(at)`.  Monotone in `at`, so
/// ordering buckets first and `(at, seq)` within a bucket is the same
/// total order the old single heap used.  (`as u64` clamps negatives to
/// 0 and saturates at `u64::MAX` — both fine: times are clamped to `now`
/// on insert and saturated buckets still sort last.)
#[inline]
fn bucket(at: SimTime) -> u64 {
    at as u64
}

/// A scheduled event carrying a caller-defined payload.
#[derive(Debug, Clone)]
struct Scheduled<E> {
    at: SimTime,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        // Defined via `cmp` so Eq stays consistent with the total_cmp-based
        // Ord (IEEE `==` would disagree on NaN and -0.0 timestamps).
        self.cmp(other) == Ordering::Equal
    }
}
impl<E> Eq for Scheduled<E> {}

impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap: earliest time first, then FIFO by sequence number.
        // `total_cmp` gives a genuine total order even if a NaN timestamp
        // ever slips in (with `partial_cmp(..).unwrap_or(Equal)` a NaN
        // would silently corrupt the heap invariant instead).
        other
            .at
            .total_cmp(&self.at)
            .then(other.seq.cmp(&self.seq))
    }
}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Event queue + clock.
///
/// Invariants (between public calls):
/// * `current` holds every pending event whose bucket is <= `cursor`;
///   it is a real heap, so mixed buckets inside it still pop in exact
///   `(at, seq)` order.
/// * `ring[b & RING_MASK]` holds exactly the events with bucket `b` for
///   `cursor < b < cursor + RING_BUCKETS` — distinct buckets in that
///   window map to distinct slots, so a slot never mixes buckets.
/// * `overflow` holds events whose bucket was >= `cursor + RING_BUCKETS`
///   at insert time; its min bucket is always > `cursor`.
///
/// `refill` advances `cursor` to the minimum pending bucket across ring
/// and overflow and drains that whole bucket into `current`, so the head
/// of `current` is always the global minimum.
#[derive(Debug)]
pub struct EventQueue<E> {
    current: BinaryHeap<Scheduled<E>>,
    ring: Vec<Vec<Scheduled<E>>>,
    /// Total events parked in `ring` (so `len` is O(1)).
    ring_len: usize,
    overflow: BinaryHeap<Scheduled<E>>,
    /// Bucket the `current` heap is (at least) caught up to.
    cursor: u64,
    now: SimTime,
    seq: u64,
    processed: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            current: BinaryHeap::new(),
            ring: (0..RING_BUCKETS).map(|_| Vec::new()).collect(),
            ring_len: 0,
            overflow: BinaryHeap::new(),
            cursor: 0,
            now: 0.0,
            seq: 0,
            processed: 0,
        }
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn len(&self) -> usize {
        self.current.len() + self.ring_len + self.overflow.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Schedule `payload` at absolute time `at` (clamped to now).
    pub fn schedule_at(&mut self, at: SimTime, payload: E) {
        debug_assert!(at.is_finite(), "non-finite event time {at}");
        let at = if at < self.now { self.now } else { at };
        let ev = Scheduled {
            at,
            seq: self.seq,
            payload,
        };
        self.seq += 1;
        let b = bucket(at);
        if b <= self.cursor {
            // Current (or, after a peek advanced the cursor, an earlier)
            // bucket: goes straight into the heap, which totally orders
            // its members — nothing in ring/overflow can precede it.
            self.current.push(ev);
        } else if b - self.cursor < RING_BUCKETS {
            self.ring[(b & RING_MASK) as usize].push(ev);
            self.ring_len += 1;
        } else {
            self.overflow.push(ev);
        }
    }

    /// Schedule `payload` after a delay from now.
    pub fn schedule_in(&mut self, delay: SimTime, payload: E) {
        debug_assert!(delay >= 0.0, "negative delay {delay}");
        self.schedule_at(self.now + delay.max(0.0), payload);
    }

    /// When `current` is drained, advance `cursor` to the earliest
    /// pending bucket and move that whole bucket (from the ring slot
    /// and/or overflow) into `current`.
    fn refill(&mut self) {
        if !self.current.is_empty() {
            return;
        }
        let ring_next = if self.ring_len == 0 {
            None
        } else {
            // The nearest non-empty slot is at most RING_BUCKETS-1 ahead.
            let mut b = self.cursor + 1;
            loop {
                debug_assert!(b - self.cursor < RING_BUCKETS, "ring scan escaped its window");
                if !self.ring[(b & RING_MASK) as usize].is_empty() {
                    break Some(b);
                }
                b += 1;
            }
        };
        let overflow_next = self.overflow.peek().map(|e| bucket(e.at));
        let target = match (ring_next, overflow_next) {
            (Some(r), Some(o)) => r.min(o),
            (Some(r), None) => r,
            (None, Some(o)) => o,
            (None, None) => return,
        };
        self.cursor = target;
        if ring_next == Some(target) {
            let slot = (target & RING_MASK) as usize;
            let mut drained = std::mem::take(&mut self.ring[slot]);
            self.ring_len -= drained.len();
            for ev in drained.drain(..) {
                self.current.push(ev);
            }
            // hand the (empty, capacity-retaining) Vec back to the slot
            self.ring[slot] = drained;
        }
        // Overflow events were binned against an older cursor, so some may
        // share the target bucket (or an equal one the ring also holds) —
        // drain them too or they would pop after later ring buckets.
        while self
            .overflow
            .peek()
            .is_some_and(|e| bucket(e.at) == target)
        {
            self.current.push(self.overflow.pop().expect("peeked"));
        }
    }

    /// Pop the next event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        self.refill();
        let ev = self.current.pop()?;
        debug_assert!(ev.at >= self.now, "time went backwards");
        self.now = ev.at;
        self.processed += 1;
        Some((ev.at, ev.payload))
    }

    /// Peek the next event time without advancing.  (`&mut` because the
    /// head may need to be pulled forward out of the ring first.)
    pub fn peek_time(&mut self) -> Option<SimTime> {
        self.refill();
        self.current.peek().map(|e| e.at)
    }
}

/// The pre-calendar-queue implementation: one `BinaryHeap` over the very
/// same `Scheduled` ordering.  Kept (test-only) as the ordering oracle
/// for the differential property test — if the calendar queue ever pops
/// in a different order, the test names the diverging element.
#[cfg(test)]
pub(crate) mod reference {
    use super::{Scheduled, SimTime};
    use std::collections::BinaryHeap;

    pub struct HeapQueue<E> {
        heap: BinaryHeap<Scheduled<E>>,
        now: SimTime,
        seq: u64,
    }

    impl<E> HeapQueue<E> {
        pub fn new() -> Self {
            HeapQueue {
                heap: BinaryHeap::new(),
                now: 0.0,
                seq: 0,
            }
        }

        pub fn now(&self) -> SimTime {
            self.now
        }

        pub fn schedule_at(&mut self, at: SimTime, payload: E) {
            let at = if at < self.now { self.now } else { at };
            self.heap.push(Scheduled {
                at,
                seq: self.seq,
                payload,
            });
            self.seq += 1;
        }

        pub fn pop(&mut self) -> Option<(SimTime, E)> {
            let ev = self.heap.pop()?;
            self.now = ev.at;
            Some((ev.at, ev.payload))
        }

        pub fn peek_time(&self) -> Option<SimTime> {
            self.heap.peek().map(|e| e.at)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(5.0, "c");
        q.schedule_at(1.0, "a");
        q.schedule_at(3.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert_eq!(q.now(), 5.0);
        assert_eq!(q.processed(), 3);
    }

    #[test]
    fn fifo_tie_break() {
        let mut q = EventQueue::new();
        q.schedule_at(2.0, 1);
        q.schedule_at(2.0, 2);
        q.schedule_at(2.0, 3);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn relative_scheduling_advances() {
        let mut q = EventQueue::new();
        q.schedule_in(10.0, "x");
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 10.0);
        q.schedule_in(5.0, "y");
        let (t2, _) = q.pop().unwrap();
        assert_eq!(t2, 15.0);
    }

    #[test]
    fn past_events_clamped_to_now() {
        let mut q = EventQueue::new();
        q.schedule_at(10.0, "a");
        q.pop();
        q.schedule_at(3.0, "late");
        let (t, e) = q.pop().unwrap();
        assert_eq!(e, "late");
        assert_eq!(t, 10.0);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "non-finite")]
    fn nan_event_time_asserts_in_debug() {
        let mut q = EventQueue::new();
        q.schedule_at(f64::NAN, "bad");
    }

    #[test]
    fn empty_queue() {
        let mut q: EventQueue<()> = EventQueue::new();
        assert!(q.pop().is_none());
        assert!(q.is_empty());
        assert_eq!(q.peek_time(), None);
    }

    #[test]
    fn far_future_events_route_through_overflow() {
        // Beyond the ring window at insert time -> overflow; order and
        // clock still exact across the ring/overflow boundary.
        let mut q = EventQueue::new();
        let far = (RING_BUCKETS as f64) * 3.0 + 0.5;
        q.schedule_at(far, "far");
        q.schedule_at(1.5, "near");
        q.schedule_at(far, "far2"); // FIFO tie inside overflow
        assert_eq!(q.len(), 3);
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["near", "far", "far2"]);
        assert_eq!(q.now(), far);
    }

    #[test]
    fn overflow_bucket_can_precede_ring_bucket_after_jump() {
        // An overflow event binned against cursor=0 can, after the cursor
        // jumps forward, be EARLIER than the next ring bucket — refill
        // must take the min across both, not prefer the ring.
        let mut q = EventQueue::new();
        let of = RING_BUCKETS as f64 + 10.0; // overflow at insert (cursor 0)
        q.schedule_at(of, "overflow-early");
        q.schedule_at(5.0, "first");
        q.pop(); // now = 5, cursor = 5
        // lands in the ring (bucket within 5..5+RING) but AFTER the parked
        // overflow event's bucket
        q.schedule_at(of + 100.0, "ring-late");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["overflow-early", "ring-late"]);
    }

    #[test]
    fn ring_rotates_across_many_windows() {
        // March the clock through several full ring rotations; every slot
        // gets reused and the clock stays exact.
        let mut q = EventQueue::new();
        q.schedule_at(0.25, 0u64);
        let mut popped = 0u64;
        let mut last = -1.0;
        while let Some((t, i)) = q.pop() {
            assert!(t > last);
            last = t;
            popped += 1;
            if i < 3 * RING_BUCKETS {
                // +1.75 ms per hop: hits every slot parity over time
                q.schedule_in(1.75, i + 1);
            }
        }
        assert_eq!(popped, 3 * RING_BUCKETS + 1);
        assert!(q.is_empty());
    }

    #[test]
    fn peek_after_cursor_advance_keeps_earlier_inserts_ordered() {
        // peek_time refills (cursor jumps to the peeked bucket); an event
        // scheduled afterwards at an earlier-but->=now time must still pop
        // first.
        let mut q = EventQueue::new();
        q.schedule_at(100.0, "late");
        assert_eq!(q.peek_time(), Some(100.0)); // cursor -> 100, now still 0
        q.schedule_at(40.0, "early");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["early", "late"]);
    }

    #[test]
    fn property_calendar_pops_identical_to_heap_reference() {
        // Differential test: random interleavings of schedule / pop /
        // peek — with integral-time ties, sub-ms offsets, far-future
        // (overflow) times, and deliberately-late (clamped) times — must
        // produce bit-identical pop sequences on the calendar queue and
        // the retained BinaryHeap reference.
        use super::reference::HeapQueue;
        crate::util::quick::forall(
            1106,
            60,
            |r| {
                let n = 30 + r.below(150) as usize;
                (0..n)
                    .map(|_| (r.below(100), r.next_u64()))
                    .collect::<Vec<(u64, u64)>>()
            },
            |ops| {
                let mut cal: EventQueue<u32> = EventQueue::new();
                let mut heap: HeapQueue<u32> = HeapQueue::new();
                let mut id: u32 = 0;
                for &(sel, raw) in ops {
                    if sel < 55 {
                        let t = match sel % 4 {
                            // integral ms: maximal tie pressure
                            0 => (raw % 50) as f64,
                            // half-ms grid inside the ring window
                            1 => (raw % 4_000) as f64 * 0.5,
                            // far future: exercises overflow + cursor jumps
                            2 => cal.now() + (raw % 20_000) as f64 * 1.7,
                            // late (often < now): exercises the clamp path
                            _ => cal.now() - 5.0 - (raw % 100) as f64,
                        };
                        cal.schedule_at(t, id);
                        heap.schedule_at(t, id);
                        id += 1;
                    } else if sel < 90 {
                        let a = cal.pop();
                        let b = heap.pop();
                        crate::prop_assert!(
                            a.map(|(t, e)| (t.to_bits(), e)) == b.map(|(t, e)| (t.to_bits(), e)),
                            "pop diverged: calendar {a:?} vs heap {b:?}"
                        );
                    } else {
                        let a = cal.peek_time().map(f64::to_bits);
                        let b = heap.peek_time().map(f64::to_bits);
                        crate::prop_assert!(a == b, "peek diverged");
                    }
                    crate::prop_assert!(
                        cal.now().to_bits() == heap.now().to_bits(),
                        "clock diverged: {} vs {}",
                        cal.now(),
                        heap.now()
                    );
                }
                // drain both to the end
                loop {
                    let a = cal.pop();
                    let b = heap.pop();
                    crate::prop_assert!(
                        a.map(|(t, e)| (t.to_bits(), e)) == b.map(|(t, e)| (t.to_bits(), e)),
                        "drain diverged: calendar {a:?} vs heap {b:?}"
                    );
                    if a.is_none() {
                        break;
                    }
                }
                crate::prop_assert!(cal.is_empty(), "calendar not empty after drain");
                Ok(())
            },
        );
    }
}
