//! Discrete-event simulation engine: a virtual millisecond clock and an
//! event queue with deterministic tie-breaking.  The serving coordinator
//! (rust/src/coordinator) runs on top of this for all latency/throughput
//! experiments, so results are exactly reproducible per seed.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Virtual time in milliseconds.
pub type SimTime = f64;

/// A scheduled event carrying a caller-defined payload.
#[derive(Debug, Clone)]
struct Scheduled<E> {
    at: SimTime,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        // Defined via `cmp` so Eq stays consistent with the total_cmp-based
        // Ord (IEEE `==` would disagree on NaN and -0.0 timestamps).
        self.cmp(other) == Ordering::Equal
    }
}
impl<E> Eq for Scheduled<E> {}

impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap: earliest time first, then FIFO by sequence number.
        // `total_cmp` gives a genuine total order even if a NaN timestamp
        // ever slips in (with `partial_cmp(..).unwrap_or(Equal)` a NaN
        // would silently corrupt the heap invariant instead).
        other
            .at
            .total_cmp(&self.at)
            .then(other.seq.cmp(&self.seq))
    }
}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Event queue + clock.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    now: SimTime,
    seq: u64,
    processed: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            now: 0.0,
            seq: 0,
            processed: 0,
        }
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Schedule `payload` at absolute time `at` (clamped to now).
    pub fn schedule_at(&mut self, at: SimTime, payload: E) {
        debug_assert!(at.is_finite(), "non-finite event time {at}");
        let at = if at < self.now { self.now } else { at };
        self.heap.push(Scheduled {
            at,
            seq: self.seq,
            payload,
        });
        self.seq += 1;
    }

    /// Schedule `payload` after a delay from now.
    pub fn schedule_in(&mut self, delay: SimTime, payload: E) {
        debug_assert!(delay >= 0.0, "negative delay {delay}");
        self.schedule_at(self.now + delay.max(0.0), payload);
    }

    /// Pop the next event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let ev = self.heap.pop()?;
        debug_assert!(ev.at >= self.now, "time went backwards");
        self.now = ev.at;
        self.processed += 1;
        Some((ev.at, ev.payload))
    }

    /// Peek the next event time without advancing.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(5.0, "c");
        q.schedule_at(1.0, "a");
        q.schedule_at(3.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert_eq!(q.now(), 5.0);
        assert_eq!(q.processed(), 3);
    }

    #[test]
    fn fifo_tie_break() {
        let mut q = EventQueue::new();
        q.schedule_at(2.0, 1);
        q.schedule_at(2.0, 2);
        q.schedule_at(2.0, 3);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn relative_scheduling_advances() {
        let mut q = EventQueue::new();
        q.schedule_in(10.0, "x");
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 10.0);
        q.schedule_in(5.0, "y");
        let (t2, _) = q.pop().unwrap();
        assert_eq!(t2, 15.0);
    }

    #[test]
    fn past_events_clamped_to_now() {
        let mut q = EventQueue::new();
        q.schedule_at(10.0, "a");
        q.pop();
        q.schedule_at(3.0, "late");
        let (t, e) = q.pop().unwrap();
        assert_eq!(e, "late");
        assert_eq!(t, 10.0);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "non-finite")]
    fn nan_event_time_asserts_in_debug() {
        let mut q = EventQueue::new();
        q.schedule_at(f64::NAN, "bad");
    }

    #[test]
    fn empty_queue() {
        let mut q: EventQueue<()> = EventQueue::new();
        assert!(q.pop().is_none());
        assert!(q.is_empty());
        assert_eq!(q.peek_time(), None);
    }
}
