//! Fleet-scale parallel scenario sweeps (beyond the paper; the ParvaGPU
//! large-scale regime, arXiv 2409.14447): generate hundreds of randomized
//! workload-mix x fleet x rate-trace scenarios, serve each through the
//! full closed loop (provision -> estimator -> online re-plan -> shadow
//! migration), fan them over scoped worker threads, and emit a
//! machine-readable `BENCH_sweep.json` that CI tracks run-over-run.
//!
//! Three invariants hold by construction (and are property-tested in
//! `rust/tests/sweep_determinism.rs`):
//!
//! 1. **Pure scenarios** — `Scenario::generate(space, master, id)` is a
//!    pure function; ids can be generated in any order or in isolation.
//! 2. **Ordered merge** — workers write results into pre-sized slots
//!    indexed by task id, so a parallel sweep is bit-identical to the
//!    sequential one for the same master seed.
//! 3. **Wall-clock quarantine** — measured timing never enters the
//!    deterministic report subset (`SweepReport::fingerprint`).

pub mod report;
pub mod runner;
pub mod scenario;

pub use report::{Aggregate, SweepReport};
pub use runner::{run_sweep, run_task, ScenarioResult, SweepConfig};
pub use scenario::{profiled_fleet, profiled_pair, Fleet, Scenario, ScenarioSpace, SloTier};
