//! Seeded fleet-scale scenario generation: each scenario is a randomized
//! large workload mix (model zoo x SLO tier x arrival rate), a GPU fleet
//! shape (homogeneous V100 / T4 or the heterogeneous pair), and a live
//! rate trace — everything a closed-loop serving run needs.
//!
//! Determinism contract: a `Scenario` is a **pure function** of
//! `(space, master_seed, id)`.  Generation derives a private SplitMix64
//! stream per scenario (`stream`), so generating scenario 7 alone yields
//! bit-identically the same mix as generating scenarios 0..100 — the
//! property the parallel sweep runner relies on to merge results in
//! submission order regardless of worker interleaving.

use crate::gpu::{GpuKind, ALL_MODELS};
use crate::provisioner::{ProfiledSystem, WorkloadSpec};
use crate::sim::faults::FaultSpace;
use crate::util::rng::Rng;
use crate::workload::envelope;
use crate::workload::trace::TraceKind;

/// Near-idle band of the long-tail lane (req/s, inclusive): tenants drawn
/// inside it count as the tail in the report's structural metrics.
pub const NEAR_IDLE_RPS_MIN: f64 = 0.1;
pub const NEAR_IDLE_RPS_MAX: f64 = 2.0;

/// Derive the independent deterministic RNG stream `(a, b)` under
/// `master`: a fresh SplitMix64 root split twice, so distinct `(a, b)`
/// pairs never share state and the result is order-independent.
pub fn stream(master: u64, a: u64, b: u64) -> Rng {
    let mut root = Rng::new(master);
    let mut lane = root.split(a);
    lane.split(b)
}

/// SLO tightness tier of a scenario: which band of each model's feasible
/// SLO envelope the workload SLOs are sampled from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SloTier {
    /// Lower third of the envelope — latency-critical serving.
    Tight,
    /// The full envelope (the Fig.-21 synthetic distribution).
    Nominal,
    /// Upper third — throughput-oriented batch-ish serving.
    Relaxed,
}

impl SloTier {
    pub fn name(self) -> &'static str {
        match self {
            SloTier::Tight => "tight",
            SloTier::Nominal => "nominal",
            SloTier::Relaxed => "relaxed",
        }
    }
}

/// GPU fleet shape offered to the provisioner.  `Heterogeneous` lets
/// `provisioner::heterogeneous::select_cheapest` pick the cheaper of the
/// per-type plans (replicating workloads a weaker GPU cannot hold alone).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fleet {
    V100Only,
    T4Only,
    Heterogeneous,
    /// Homogeneous MIG fleet of A100s: discrete slice partitioning, the
    /// fragmentation-aware packer, zero cross-tenant interference.
    MigA100,
    /// Homogeneous MIG fleet of H100s.
    MigH100,
}

impl Fleet {
    pub fn name(self) -> &'static str {
        match self {
            Fleet::V100Only => "v100",
            Fleet::T4Only => "t4",
            Fleet::Heterogeneous => "hetero",
            Fleet::MigA100 => "mig-a100",
            Fleet::MigH100 => "mig-h100",
        }
    }

    /// Whether this fleet partitions devices into discrete MIG slices.
    pub fn is_mig(self) -> bool {
        matches!(self, Fleet::MigA100 | Fleet::MigH100)
    }

    /// The candidate systems of this fleet, as a sub-slice of the
    /// profiled fleet: `[V100, T4]` for non-MIG sweeps (bit-identical to
    /// the historical pair slicing), `[V100, T4, A100, H100]` when a MIG
    /// lane asked `profiled_fleet` for the MIG parts too.
    pub fn systems<'a>(self, fleet: &'a [ProfiledSystem]) -> &'a [ProfiledSystem] {
        debug_assert!(fleet.len() == 2 || fleet.len() == 4, "{}", fleet.len());
        match self {
            Fleet::V100Only => &fleet[0..1],
            Fleet::T4Only => &fleet[1..2],
            Fleet::Heterogeneous => &fleet[0..2],
            Fleet::MigA100 => &fleet[2..3],
            Fleet::MigH100 => &fleet[3..4],
        }
    }
}

/// The sampling space a sweep draws scenarios from.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpace {
    /// Workload-mix size range (inclusive).
    pub min_workloads: usize,
    pub max_workloads: usize,
    /// Trace shape: epochs x epoch span of virtual time.
    pub epochs: usize,
    pub epoch_ms: f64,
    /// Serving-stats warm-up excluded from latency records (ms).
    pub warmup_ms: f64,
    /// Fleet shapes scenarios may sample.
    pub fleets: Vec<Fleet>,
    /// Model-mismatch lane: when `true`, each scenario perturbs the
    /// timing coefficients the **planner believes** by a per-model-class
    /// factor of 1 +/- U[0.10, 0.30] while the simulator's physics stay
    /// the ground truth — the planner's model is now 10-30% wrong, the
    /// regime the calibration layer exists for.
    pub mismatch: bool,
    /// Chaos lane: the fault space each scenario's `FaultPlan` is drawn
    /// from (its own RNG lane `(3, id+1)`, independent of scenario
    /// generation and sim seeds).  `FaultSpace::OFF` — the default for
    /// every non-chaos space — generates empty plans, which the serving
    /// loop treats as a bitwise no-op.
    pub faults: FaultSpace,
    /// Long-tail lane: ~90% of each mix's tenants are drawn near-idle
    /// (0.1-2 req/s, **unrounded** — integer rounding would zero them)
    /// with the rest heavy hitters from the full rate envelope, and
    /// traces are restricted to the bursty shapes (diurnal / spiky).
    /// Every extra RNG draw is gated behind this flag, so non-longtail
    /// spaces generate byte-identical scenarios.
    pub longtail: bool,
}

impl ScenarioSpace {
    /// CI-quick profile: small mixes over short horizons, sized so a
    /// 200-scenario x 2-seed sweep finishes inside a CI job.
    pub fn quick() -> ScenarioSpace {
        ScenarioSpace {
            min_workloads: 12,
            max_workloads: 40,
            epochs: 4,
            epoch_ms: 1_500.0,
            warmup_ms: 500.0,
            fleets: vec![Fleet::V100Only, Fleet::T4Only, Fleet::Heterogeneous],
            mismatch: false,
            faults: FaultSpace::OFF,
            longtail: false,
        }
    }

    /// Full fleet-scale profile (ParvaGPU regime): 100-1000-workload
    /// mixes over a longer horizon.  Not run in CI.
    pub fn full() -> ScenarioSpace {
        ScenarioSpace {
            min_workloads: 100,
            max_workloads: 1_000,
            epochs: 12,
            epoch_ms: 2_500.0,
            warmup_ms: 1_000.0,
            fleets: vec![Fleet::V100Only, Fleet::T4Only, Fleet::Heterogeneous],
            mismatch: false,
            faults: FaultSpace::OFF,
            longtail: false,
        }
    }

    /// The model-mismatch lane: the quick space with per-scenario
    /// coefficient perturbation enabled (`igniter sweep --mismatch`).
    pub fn mismatch() -> ScenarioSpace {
        ScenarioSpace {
            mismatch: true,
            ..ScenarioSpace::quick()
        }
    }

    /// The chaos lane (`igniter sweep --faults`): the quick space with
    /// fault injection enabled — every scenario draws a `FaultPlan`
    /// (device deaths, stragglers, hangs) from its own RNG lane and the
    /// serving policy gets full resilience (`Resilience::ALL`).
    pub fn chaos() -> ScenarioSpace {
        ScenarioSpace {
            faults: FaultSpace::chaos(),
            ..ScenarioSpace::quick()
        }
    }

    /// The MIG lane (`igniter sweep --fleet mig`): the quick space over
    /// homogeneous A100/H100 MIG fleets — discrete slice packing, where
    /// fragmentation (stranded GPCs) replaces interference as the cost
    /// driver.
    pub fn mig() -> ScenarioSpace {
        ScenarioSpace {
            fleets: vec![Fleet::MigA100, Fleet::MigH100],
            ..ScenarioSpace::quick()
        }
    }

    /// The long-tail lane (`igniter sweep --longtail`): the "millions of
    /// users, most of them idle" regime — 200-1000-tenant mixes where
    /// ~90% of tenants sit near-idle (0.1-2 req/s) under bursty
    /// diurnal/spiky traces while a handful of heavy hitters carry the
    /// load.  This is the shape the idle-aware monitor fast path exists
    /// for: per-tick cost proportional to *activity*, not *tenancy*.
    pub fn longtail() -> ScenarioSpace {
        ScenarioSpace {
            min_workloads: 200,
            max_workloads: 1_000,
            longtail: true,
            ..ScenarioSpace::quick()
        }
    }

    /// Whether any fleet in this space needs the MIG parts profiled.
    pub fn needs_mig(&self) -> bool {
        self.fleets.iter().any(|f| f.is_mig())
    }

    /// Virtual serving horizon of one scenario (ms).
    pub fn horizon_ms(&self) -> f64 {
        self.epochs as f64 * self.epoch_ms
    }
}

/// One randomized fleet-scale serving scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub id: usize,
    pub fleet: Fleet,
    pub tier: SloTier,
    pub trace: TraceKind,
    pub specs: Vec<WorkloadSpec>,
    pub epochs: usize,
    pub epoch_ms: f64,
    pub warmup_ms: f64,
    /// Per-model-class timing perturbation factors (indexed like
    /// `ALL_MODELS`; empty when the mismatch lane is off).  Applied to
    /// the planner's *believed* coefficients, never the simulator.
    pub mismatch: Vec<f64>,
}

impl Scenario {
    /// Generate scenario `id` of a sweep — pure in `(space, master, id)`.
    pub fn generate(space: &ScenarioSpace, master: u64, id: usize) -> Scenario {
        let mut rng = stream(master, 1, id as u64 + 1);
        let hi = space.max_workloads.max(space.min_workloads) as u64;
        let n = rng.range_u64(space.min_workloads as u64, hi) as usize;
        let fleet = space.fleets[rng.below(space.fleets.len() as u64) as usize];
        let tier = match rng.below(3) {
            0 => SloTier::Tight,
            1 => SloTier::Nominal,
            _ => SloTier::Relaxed,
        };
        let trace = if space.longtail {
            // long-tail lane: bursty shapes only — a ramp never goes
            // quiet, which defeats the regime the lane exists to probe
            match rng.below(2) {
                0 => TraceKind::Diurnal {
                    period_epochs: space.epochs.max(1),
                    floor: rng.range_f64(0.25, 0.45),
                },
                _ => TraceKind::Spiky {
                    base: rng.range_f64(0.25, 0.5),
                    p: rng.range_f64(0.15, 0.35),
                },
            }
        } else {
            match rng.below(3) {
                0 => TraceKind::Diurnal {
                    period_epochs: space.epochs.max(1),
                    floor: rng.range_f64(0.25, 0.45),
                },
                1 => TraceKind::Spiky {
                    base: rng.range_f64(0.25, 0.5),
                    p: rng.range_f64(0.15, 0.35),
                },
                _ => TraceKind::Ramp {
                    from: rng.range_f64(0.2, 0.5),
                    to: rng.range_f64(0.8, 1.0),
                },
            }
        };
        let specs = (0..n)
            .map(|i| {
                let model = ALL_MODELS[rng.below(ALL_MODELS.len() as u64) as usize];
                let (slo_lo, slo_hi, rate_lo, rate_hi) = envelope(model);
                // tier picks the band of the feasible envelope, so every
                // sampled SLO stays provisionable on the stronger GPU
                let span = slo_hi - slo_lo;
                let (lo, hi) = match tier {
                    SloTier::Tight => (slo_lo, slo_lo + 0.35 * span),
                    SloTier::Nominal => (slo_lo, slo_hi),
                    SloTier::Relaxed => (slo_lo + 0.65 * span, slo_hi),
                };
                let slo_ms = rng.range_f64(lo, hi);
                let rate = if space.longtail {
                    // ~90% near-idle (unrounded — integer rounding would
                    // zero the tail), ~10% heavy hitters from the full
                    // envelope
                    if rng.below(10) == 0 {
                        rng.range_f64(rate_lo, rate_hi).round().max(1.0)
                    } else {
                        rng.range_f64(NEAR_IDLE_RPS_MIN, NEAR_IDLE_RPS_MAX)
                    }
                } else {
                    rng.range_f64(rate_lo, rate_hi).round()
                };
                WorkloadSpec::new(i, model, slo_ms, rate)
            })
            .collect();
        // mismatch lane: each model class's believed timing is off by
        // +/- 10-30%, sign and magnitude drawn per scenario
        let mismatch = if space.mismatch {
            ALL_MODELS
                .iter()
                .map(|_| {
                    let mag = rng.range_f64(0.10, 0.30);
                    if rng.bool() {
                        1.0 + mag
                    } else {
                        1.0 - mag
                    }
                })
                .collect()
        } else {
            Vec::new()
        };
        Scenario {
            id,
            fleet,
            tier,
            trace,
            specs,
            epochs: space.epochs,
            epoch_ms: space.epoch_ms,
            warmup_ms: space.warmup_ms,
            mismatch,
        }
    }

    pub fn horizon_ms(&self) -> f64 {
        self.epochs as f64 * self.epoch_ms
    }

    /// How many of this scenario's tenants sit in the near-idle band —
    /// the long-tail lane's structural metric (reported per scenario and
    /// checked by the bench gate's active-fraction bar).
    pub fn near_idle_workloads(&self) -> usize {
        self.specs
            .iter()
            .filter(|w| w.rate_rps <= NEAR_IDLE_RPS_MAX)
            .count()
    }

    /// Worst-case believed-coefficient error of this scenario (0 when the
    /// mismatch lane is off) — reported per scenario in the sweep JSON.
    pub fn mismatch_pct(&self) -> f64 {
        self.mismatch
            .iter()
            .map(|f| (f - 1.0).abs())
            .fold(0.0, f64::max)
    }

    /// The systems the **planner believes**: the profiled pair with this
    /// scenario's per-class timing perturbation applied.  Returns the
    /// input unchanged when the lane is off.  The simulator always runs
    /// on the unperturbed physics — the gap is the injected model error.
    pub fn believed_systems(&self, systems: &[ProfiledSystem]) -> Vec<ProfiledSystem> {
        if self.mismatch.is_empty() {
            return systems.to_vec();
        }
        systems
            .iter()
            .map(|sys| {
                let mut s = sys.clone();
                for (m, wc) in &mut s.coeffs {
                    let idx = ALL_MODELS
                        .iter()
                        .position(|x| x == m)
                        .expect("profiled model is in the zoo");
                    wc.scale_time(self.mismatch[idx]);
                }
                s
            })
            .collect()
    }
}

/// Build the profiled `[V100, T4]` pair every sweep provisions against
/// (deterministic per profiling seed; computed once and shared read-only
/// by all workers).
pub fn profiled_pair(seed: u64) -> Vec<ProfiledSystem> {
    [GpuKind::V100, GpuKind::T4]
        .into_iter()
        .map(|kind| crate::experiments::common::profiled_system(kind, seed))
        .collect()
}

/// The profiled fleet for a sweep: the historical `[V100, T4]` pair, plus
/// `[A100, H100]` appended only when a MIG lane needs them — non-MIG
/// sweeps never pay the extra profiling wall and keep their fleet slices
/// (and hence every downstream byte) identical.
pub fn profiled_fleet(seed: u64, include_mig: bool) -> Vec<ProfiledSystem> {
    let mut fleet = profiled_pair(seed);
    if include_mig {
        fleet.extend(
            [GpuKind::A100, GpuKind::H100]
                .into_iter()
                .map(|kind| crate::experiments::common::profiled_system(kind, seed)),
        );
    }
    fleet
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_pure_in_master_and_id() {
        let space = ScenarioSpace::quick();
        let a = Scenario::generate(&space, 42, 7);
        let b = Scenario::generate(&space, 42, 7);
        assert_eq!(a, b);
        // neighbours nor master reuse the stream
        assert_ne!(a.specs, Scenario::generate(&space, 42, 8).specs);
        assert_ne!(a.specs, Scenario::generate(&space, 43, 7).specs);
    }

    #[test]
    fn sizes_respect_the_space() {
        let space = ScenarioSpace::quick();
        for id in 0..50 {
            let s = Scenario::generate(&space, 1, id);
            assert!(
                (space.min_workloads..=space.max_workloads).contains(&s.specs.len()),
                "scenario {id}: {} workloads",
                s.specs.len()
            );
            assert!(s.specs.iter().all(|w| w.slo_ms > 0.0 && w.rate_rps > 0.0));
        }
    }

    #[test]
    fn slos_stay_inside_the_feasible_envelope() {
        let space = ScenarioSpace::quick();
        for id in 0..50 {
            for w in &Scenario::generate(&space, 9, id).specs {
                let (lo, hi, rlo, rhi) = envelope(w.model);
                assert!((lo - 1e-9..=hi + 1e-9).contains(&w.slo_ms), "{w:?}");
                assert!((rlo - 1.0..=rhi + 1.0).contains(&w.rate_rps), "{w:?}");
            }
        }
    }

    #[test]
    fn all_fleets_and_tiers_get_sampled() {
        let space = ScenarioSpace::quick();
        let scenarios: Vec<Scenario> =
            (0..60).map(|id| Scenario::generate(&space, 5, id)).collect();
        for fleet in [Fleet::V100Only, Fleet::T4Only, Fleet::Heterogeneous] {
            assert!(scenarios.iter().any(|s| s.fleet == fleet), "{fleet:?} never drawn");
        }
        for tier in [SloTier::Tight, SloTier::Nominal, SloTier::Relaxed] {
            assert!(scenarios.iter().any(|s| s.tier == tier), "{tier:?} never drawn");
        }
    }

    #[test]
    fn mig_space_samples_both_mig_fleets() {
        let space = ScenarioSpace::mig();
        assert!(space.needs_mig());
        assert!(!ScenarioSpace::quick().needs_mig());
        let scenarios: Vec<Scenario> =
            (0..40).map(|id| Scenario::generate(&space, 5, id)).collect();
        for fleet in [Fleet::MigA100, Fleet::MigH100] {
            assert!(fleet.is_mig());
            assert!(scenarios.iter().any(|s| s.fleet == fleet), "{fleet:?} never drawn");
        }
        assert!(!Fleet::Heterogeneous.is_mig());
    }

    #[test]
    fn fleet_slicing_covers_pair_and_mig_fleet() {
        let pair = profiled_pair(42);
        // historical pair slicing is unchanged
        assert_eq!(Fleet::V100Only.systems(&pair).len(), 1);
        assert_eq!(Fleet::V100Only.systems(&pair)[0].hw.gpu, "V100");
        assert_eq!(Fleet::T4Only.systems(&pair)[0].hw.gpu, "T4");
        assert_eq!(Fleet::Heterogeneous.systems(&pair).len(), 2);
        // the 4-system fleet adds the MIG parts at stable indices
        let fleet = profiled_fleet(42, true);
        assert_eq!(fleet.len(), 4);
        // the shared prefix is bit-identical to the pair
        for (a, b) in fleet.iter().take(2).zip(&pair) {
            assert_eq!(a.hw, b.hw);
        }
        assert_eq!(Fleet::MigA100.systems(&fleet)[0].hw.gpu, "A100");
        assert_eq!(Fleet::MigH100.systems(&fleet)[0].hw.gpu, "H100");
        assert_eq!(Fleet::Heterogeneous.systems(&fleet).len(), 2);
        // without MIG, profiled_fleet is exactly the pair
        assert_eq!(profiled_fleet(42, false).len(), 2);
    }

    #[test]
    fn mismatch_lane_perturbs_beliefs_within_the_band() {
        let space = ScenarioSpace::mismatch();
        let systems = profiled_pair(42);
        for id in 0..20 {
            let s = Scenario::generate(&space, 11, id);
            assert_eq!(s.mismatch.len(), ALL_MODELS.len());
            for f in &s.mismatch {
                let mag = (f - 1.0).abs();
                assert!((0.10 - 1e-9..=0.30 + 1e-9).contains(&mag), "factor {f}");
            }
            assert!(s.mismatch_pct() >= 0.10);
            let believed = s.believed_systems(&systems);
            assert_eq!(believed.len(), systems.len());
            for (b, t) in believed.iter().zip(&systems) {
                for ((m, bw), (_, tw)) in b.coeffs.iter().zip(&t.coeffs) {
                    let idx = ALL_MODELS.iter().position(|x| x == m).unwrap();
                    let f = s.mismatch[idx];
                    assert!((bw.kact.k2 - tw.kact.k2 * f).abs() < 1e-12);
                    assert!((bw.k_sch - tw.k_sch * f).abs() < 1e-12);
                    // power/cache laws untouched
                    assert_eq!(bw.alpha_power, tw.alpha_power);
                    assert_eq!(bw.alpha_cacheutil, tw.alpha_cacheutil);
                }
            }
        }
        // generation stays pure
        assert_eq!(
            Scenario::generate(&space, 11, 3),
            Scenario::generate(&space, 11, 3)
        );
    }

    #[test]
    fn longtail_space_draws_a_near_idle_majority() {
        let space = ScenarioSpace::longtail();
        assert!(space.longtail && !ScenarioSpace::quick().longtail);
        let scenarios: Vec<Scenario> =
            (0..8).map(|id| Scenario::generate(&space, 7, id)).collect();
        let (mut tail, mut total, mut heavy) = (0usize, 0usize, 0usize);
        for s in &scenarios {
            assert!(
                (space.min_workloads..=space.max_workloads).contains(&s.specs.len()),
                "scenario {}: {} tenants",
                s.id,
                s.specs.len()
            );
            // bursty shapes only — a ramp never goes quiet
            assert!(
                matches!(s.trace, TraceKind::Diurnal { .. } | TraceKind::Spiky { .. }),
                "{:?}",
                s.trace
            );
            for w in &s.specs {
                if w.rate_rps <= NEAR_IDLE_RPS_MAX {
                    tail += 1;
                    assert!(w.rate_rps >= NEAR_IDLE_RPS_MIN, "{}", w.rate_rps);
                } else {
                    heavy += 1;
                    assert_eq!(w.rate_rps, w.rate_rps.round(), "heavy rates stay integral");
                }
            }
            total += s.specs.len();
            assert_eq!(s.near_idle_workloads(), s.specs.iter()
                .filter(|w| w.rate_rps <= NEAR_IDLE_RPS_MAX).count());
        }
        // ~90% of the population is the tail; heavy hitters exist
        let frac = tail as f64 / total as f64;
        assert!(frac > 0.80 && frac < 0.97, "near-idle fraction {frac}");
        assert!(heavy > 0, "no heavy hitters drawn");
        // the tail is genuinely fractional (rounding would have zeroed it)
        assert!(scenarios.iter().any(|s| s
            .specs
            .iter()
            .any(|w| w.rate_rps > 0.0 && w.rate_rps != w.rate_rps.round())));
        // generation stays pure
        assert_eq!(
            Scenario::generate(&space, 7, 2),
            Scenario::generate(&space, 7, 2)
        );
    }

    #[test]
    fn default_spaces_have_no_mismatch() {
        let systems = profiled_pair(42);
        let s = Scenario::generate(&ScenarioSpace::quick(), 42, 0);
        assert!(s.mismatch.is_empty());
        assert_eq!(s.mismatch_pct(), 0.0);
        // believed == truth, allocation for the runner's sharing contract
        let believed = s.believed_systems(&systems);
        for (b, t) in believed.iter().zip(&systems) {
            assert_eq!(b.hw, t.hw);
        }
    }

    #[test]
    fn stream_lanes_are_independent() {
        let mut a = stream(3, 1, 1);
        let mut b = stream(3, 1, 2);
        let mut c = stream(3, 2, 1);
        let xs: Vec<u64> = (0..32).map(|_| a.next_u64()).collect();
        assert!(xs.iter().zip((0..32).map(|_| b.next_u64())).all(|(x, y)| *x != y));
        assert!(xs.iter().zip((0..32).map(|_| c.next_u64())).all(|(x, y)| *x != y));
        // and re-derivable: the same lane replays bit-identically
        let mut a2 = stream(3, 1, 1);
        assert_eq!(xs, (0..32).map(|_| a2.next_u64()).collect::<Vec<_>>());
    }
}
