//! Machine-readable sweep reports (`BENCH_sweep.json`): per-scenario
//! metrics, a deterministic aggregate, and a separate wall-clock section.
//!
//! The JSON is split on the determinism boundary on purpose:
//!
//! * `config`, `scenarios`, `aggregate` — pure functions of
//!   `(space, master_seed)`; bit-identical across `--parallel` widths
//!   and across machines.  `fingerprint()` serializes exactly this
//!   subset, and the CI bench gate compares its metrics run-over-run.
//! * `wall` — measured wall-clock (total seconds, scenarios/s, served
//!   virtual requests per wall second).  Machine-dependent by nature;
//!   the bench gate applies its tolerance here, never equality.

use super::runner::{ScenarioResult, SweepConfig};
use crate::util::json::Json;
use std::path::Path;

/// Deterministic aggregate over a sweep's results.
#[derive(Debug, Clone, PartialEq)]
pub struct Aggregate {
    pub tasks: usize,
    pub feasible: usize,
    /// Mean plan cost over feasible tasks ($/h).
    pub mean_cost_per_hour: f64,
    /// Mean SLO attainment over feasible tasks.
    pub mean_slo_attainment: f64,
    pub total_migrations: u64,
    pub total_served: u64,
    pub total_arrivals: u64,
    pub total_dropped: i64,
    pub total_gpu_seconds: f64,
    pub mean_gpus: f64,
    /// Mean over feasible tasks *with recorded samples* of each task's
    /// mean prediction error (rel_error of model-predicted vs
    /// serving-observed exec latency).  Sample-less tasks are excluded —
    /// counting them as zero error would bias the CI gate toward 0 and
    /// let a telemetry-loss regression read as an improvement.
    pub mean_pred_error: f64,
    /// Mean over the same sampled tasks of each task's p95 error.
    pub p95_pred_error: f64,
    /// Total prediction-error samples across all tasks.
    pub pred_err_samples: u64,
    /// Chaos lane: faults that actually fired across all tasks (0 with
    /// faults off — the fault keys below are then omitted from the JSON
    /// so fault-free reports stay byte-identical to pre-chaos ones).
    pub faults_injected: u64,
    /// Recovery episodes closed across all tasks.
    pub recovery_samples: u64,
    /// Worst per-task recovery p95 (ms) — the chaos gate metric.  Max,
    /// not mean: one task recovering slowly is exactly the regression
    /// the lane exists to catch.
    pub recovery_ms_p95: f64,
    /// MIG lane: feasible tasks that ran on a MIG fleet (0 outside it —
    /// the MIG keys below are then omitted from the JSON so non-MIG
    /// reports stay byte-identical to pre-MIG ones).
    pub mig_tasks: usize,
    /// Mean stranded slice capacity (%) over feasible MIG tasks — the
    /// fragmentation gate metric.
    pub mean_stranded_pct: f64,
    /// Live-device slice reconfigurations across all MIG tasks.
    pub total_reconfigurations: u64,
    /// Mean head-to-head hourly costs over feasible MIG tasks.
    pub mean_mig_cost_packed: f64,
    pub mean_mig_cost_ffd: f64,
    pub mean_mig_cost_igniter: f64,
    /// Total packed cost / total FFD cost over feasible MIG tasks — the
    /// packer-quality gate metric (<= 1.0 by construction: the packer
    /// adopts the FFD packing whenever FFD lands on fewer devices).
    pub packer_vs_ffd_cost_ratio: f64,
    /// Long-tail lane: tasks that ran under the long-tail space (0
    /// outside it — the key below is then omitted from the JSON so
    /// non-longtail reports stay byte-identical to pre-longtail ones).
    pub longtail_tasks: usize,
    /// Mean over feasible long-tail tasks of each mix's near-idle tenant
    /// fraction — the structural number the bench gate's active-fraction
    /// bar checks (a lane whose "idle" tenants are not actually the
    /// majority is not measuring the long-tail regime).
    pub mean_near_idle_fraction: f64,
}

/// Mean of `f` over the tasks that actually recorded prediction-error
/// samples (0.0 when none did).
fn sampled_mean(feasible: &[&ScenarioResult], f: impl Fn(&ScenarioResult) -> f64) -> f64 {
    let sampled: Vec<f64> = feasible
        .iter()
        .filter(|r| r.pred_err_samples > 0)
        .map(|r| f(r))
        .collect();
    if sampled.is_empty() {
        0.0
    } else {
        sampled.iter().sum::<f64>() / sampled.len() as f64
    }
}

impl Aggregate {
    pub fn of(results: &[ScenarioResult]) -> Aggregate {
        let feasible: Vec<&ScenarioResult> = results.iter().filter(|r| r.feasible).collect();
        let n = feasible.len();
        // mean over feasible tasks only: infeasible scenarios report zero
        // cost/attainment and would silently dilute the gate metrics
        let mean_of = |sum: f64| if n == 0 { 0.0 } else { sum / n as f64 };
        let mig: Vec<&&ScenarioResult> = feasible.iter().filter(|r| r.is_mig).collect();
        let m = mig.len();
        let mig_mean = |f: &dyn Fn(&ScenarioResult) -> f64| {
            if m == 0 {
                0.0
            } else {
                mig.iter().map(|r| f(r)).sum::<f64>() / m as f64
            }
        };
        let packed_total: f64 = mig.iter().map(|r| r.mig_cost_packed).sum();
        let ffd_total: f64 = mig.iter().map(|r| r.mig_cost_ffd).sum();
        let lt: Vec<&&ScenarioResult> = feasible.iter().filter(|r| r.longtail).collect();
        Aggregate {
            tasks: results.len(),
            feasible: n,
            mean_cost_per_hour: mean_of(feasible.iter().map(|r| r.cost_per_hour).sum()),
            mean_slo_attainment: mean_of(feasible.iter().map(|r| r.slo_attainment).sum()),
            total_migrations: results.iter().map(|r| r.migrations as u64).sum(),
            total_served: results.iter().map(|r| r.served).sum(),
            total_arrivals: results.iter().map(|r| r.arrivals).sum(),
            total_dropped: results.iter().map(|r| r.dropped).sum(),
            total_gpu_seconds: results.iter().map(|r| r.gpu_seconds).sum(),
            mean_gpus: mean_of(feasible.iter().map(|r| r.gpus as f64).sum()),
            mean_pred_error: sampled_mean(&feasible, |r| r.pred_err_mean),
            p95_pred_error: sampled_mean(&feasible, |r| r.pred_err_p95),
            pred_err_samples: results.iter().map(|r| r.pred_err_samples).sum(),
            faults_injected: results.iter().map(|r| r.faults_injected).sum(),
            recovery_samples: results.iter().map(|r| r.recovery_samples).sum(),
            recovery_ms_p95: results
                .iter()
                .map(|r| r.recovery_ms_p95)
                .fold(0.0, f64::max),
            mig_tasks: m,
            mean_stranded_pct: mig_mean(&|r| r.stranded_capacity_pct),
            total_reconfigurations: mig.iter().map(|r| r.reconfigurations).sum(),
            mean_mig_cost_packed: mig_mean(&|r| r.mig_cost_packed),
            mean_mig_cost_ffd: mig_mean(&|r| r.mig_cost_ffd),
            mean_mig_cost_igniter: mig_mean(&|r| r.mig_cost_igniter),
            packer_vs_ffd_cost_ratio: if ffd_total > 0.0 {
                packed_total / ffd_total
            } else {
                0.0
            },
            longtail_tasks: results.iter().filter(|r| r.longtail).count(),
            mean_near_idle_fraction: if lt.is_empty() {
                0.0
            } else {
                lt.iter()
                    .map(|r| r.near_idle_workloads as f64 / r.workloads.max(1) as f64)
                    .sum::<f64>()
                    / lt.len() as f64
            },
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("tasks", self.tasks)
            .set("feasible", self.feasible)
            .set("mean_cost_per_hour", self.mean_cost_per_hour)
            .set("mean_slo_attainment", self.mean_slo_attainment)
            .set("total_migrations", self.total_migrations)
            .set("total_served", self.total_served)
            .set("total_arrivals", self.total_arrivals)
            .set("total_dropped", self.total_dropped)
            .set("total_gpu_seconds", self.total_gpu_seconds)
            .set("mean_gpus", self.mean_gpus)
            .set("mean_pred_error", self.mean_pred_error)
            .set("p95_pred_error", self.p95_pred_error)
            .set("pred_err_samples", self.pred_err_samples);
        // fault keys only when a fault actually fired: fault-free reports
        // (and the committed fingerprint golden) stay byte-identical
        if self.faults_injected > 0 {
            j = j
                .set("faults_injected", self.faults_injected)
                .set("recovery_samples", self.recovery_samples)
                .set("recovery_ms_p95", self.recovery_ms_p95);
        }
        // MIG keys only when a MIG task ran: non-MIG reports (and the
        // committed fingerprint golden) stay byte-identical
        if self.mig_tasks > 0 {
            j = j
                .set("mig_tasks", self.mig_tasks)
                .set("mean_stranded_pct", self.mean_stranded_pct)
                .set("total_reconfigurations", self.total_reconfigurations)
                .set("mean_mig_cost_packed", self.mean_mig_cost_packed)
                .set("mean_mig_cost_ffd", self.mean_mig_cost_ffd)
                .set("mean_mig_cost_igniter", self.mean_mig_cost_igniter)
                .set("packer_vs_ffd_cost_ratio", self.packer_vs_ffd_cost_ratio);
        }
        // long-tail keys only when the lane ran: non-longtail reports
        // (and the committed fingerprint golden) stay byte-identical
        if self.longtail_tasks > 0 {
            j = j
                .set("longtail_tasks", self.longtail_tasks)
                .set("mean_near_idle_fraction", self.mean_near_idle_fraction);
        }
        j
    }
}

/// Complete outcome of one sweep invocation.
#[derive(Debug, Clone)]
pub struct SweepReport {
    pub config: SweepConfig,
    pub results: Vec<ScenarioResult>,
    /// Total wall-clock of the fan-out (seconds; not deterministic).
    pub wall_s: f64,
}

fn result_json(r: &ScenarioResult, with_wall: bool) -> Json {
    let mut j = Json::obj()
        .set("scenario", r.scenario)
        .set("seed_index", r.seed_index)
        .set("gpu", r.gpu.as_str())
        .set("fleet", r.fleet)
        .set("tier", r.tier)
        .set("workloads", r.workloads)
        .set("feasible", r.feasible)
        .set("gpus", r.gpus)
        .set("cost_per_hour", r.cost_per_hour)
        .set("slo_attainment", r.slo_attainment)
        .set("migrations", r.migrations as u64)
        .set("served", r.served)
        .set("arrivals", r.arrivals)
        .set("dropped", r.dropped)
        .set("gpu_seconds", r.gpu_seconds)
        .set("mismatch_pct", r.mismatch_pct)
        .set("pred_err_mean", r.pred_err_mean)
        .set("pred_err_p95", r.pred_err_p95)
        .set("pred_err_samples", r.pred_err_samples);
    if r.faults_injected > 0 {
        // same conditional-key discipline as the aggregate: a task that
        // saw no fault serializes exactly as it did pre-chaos
        j = j
            .set("faults_injected", r.faults_injected)
            .set("recovery_samples", r.recovery_samples)
            .set("recovery_ms_p95", r.recovery_ms_p95);
    }
    if r.is_mig {
        // MIG keys only on MIG tasks: non-MIG tasks serialize exactly as
        // they did pre-MIG
        j = j
            .set("is_mig", true)
            .set("stranded_capacity_pct", r.stranded_capacity_pct)
            .set("reconfigurations", r.reconfigurations)
            .set("mig_cost_packed", r.mig_cost_packed)
            .set("mig_cost_ffd", r.mig_cost_ffd)
            .set("mig_cost_igniter", r.mig_cost_igniter);
    }
    if r.longtail {
        // long-tail keys only on long-tail tasks: other lanes serialize
        // exactly as they did pre-longtail
        j = j
            .set("longtail", true)
            .set("near_idle_workloads", r.near_idle_workloads);
    }
    if with_wall {
        // `placements` is deterministic, but it is a work count feeding
        // `plan_throughput_pps`, not a scenario outcome — it stays in the
        // wall section so the deterministic fingerprint (and its FNV-1a
        // golden) is byte-identical to pre-engine reports.
        j = j
            .set("wall_ms", r.wall_ms)
            .set("placements", r.placements)
            .set("plan_wall_ms", r.plan_wall_ms);
    }
    j
}

impl SweepReport {
    pub fn new(config: SweepConfig, results: Vec<ScenarioResult>, wall_s: f64) -> SweepReport {
        SweepReport {
            config,
            results,
            wall_s,
        }
    }

    pub fn aggregate(&self) -> Aggregate {
        Aggregate::of(&self.results)
    }

    fn config_json(&self) -> Json {
        let mut j = Json::obj()
            .set("scenarios", self.config.scenarios)
            .set("seeds", self.config.seeds)
            .set("master_seed", self.config.master_seed)
            .set("min_workloads", self.config.space.min_workloads)
            .set("max_workloads", self.config.space.max_workloads)
            .set("epochs", self.config.space.epochs)
            .set("epoch_ms", self.config.space.epoch_ms)
            .set("mismatch", self.config.space.mismatch)
            .set("calibrate", self.config.calibrate);
        // written only in the chaos lane; the bench gate treats a missing
        // key as `false` so pre-chaos baselines still shape-match
        if !self.config.space.faults.is_off() {
            j = j.set("faults", true);
        }
        // written only when the space offers a MIG fleet; the bench gate
        // treats a missing key as `false` so pre-MIG baselines shape-match
        if self.config.space.needs_mig() {
            j = j.set("mig", true);
        }
        // written only in the long-tail lane; the bench gate treats a
        // missing key as `false` so older baselines still shape-match
        if self.config.space.longtail {
            j = j.set("longtail", true);
        }
        j
    }

    /// The deterministic subset: identical across `--parallel` widths.
    pub fn deterministic_json(&self) -> Json {
        Json::obj()
            .set("config", self.config_json())
            .set(
                "scenarios",
                Json::Arr(self.results.iter().map(|r| result_json(r, false)).collect()),
            )
            .set("aggregate", self.aggregate().to_json())
    }

    /// Compact serialization of the deterministic subset — what the
    /// parallel==sequential property test compares.
    pub fn fingerprint(&self) -> String {
        self.deterministic_json().to_string()
    }

    /// Wall-clock section: total seconds, scenario throughput, and sim
    /// throughput (served virtual requests per wall second).
    pub fn wall_json(&self) -> Json {
        let agg = self.aggregate();
        let wall = self.wall_s.max(1e-9);
        // Sim-core throughput: served virtual requests per second of
        // summed per-task simulation wall — independent of the worker
        // count, unlike `served_per_wall_s` (which divides by the
        // parallel whole-sweep wall).  This is the number the sim-core
        // refactors are gated on (`benches/simulator.rs`,
        // `check_bench_regression.py`).
        let sim_wall_s: f64 = self.results.iter().map(|r| r.wall_ms).sum::<f64>() / 1e3;
        // Placement-engine throughput: placement items executed (initial
        // provisioning over every candidate GPU type + every closed-loop
        // respec/rebalance placement) per second of summed planning wall.
        // The number the provisioner-engine refactors are gated on
        // (`benches/provisioner.rs`, `check_bench_regression.py`).
        let placements: u64 = self.results.iter().map(|r| r.placements).sum();
        let plan_wall_s: f64 = self.results.iter().map(|r| r.plan_wall_ms).sum::<f64>() / 1e3;
        Json::obj()
            .set("wall_s", self.wall_s)
            .set("scenarios_per_s", self.results.len() as f64 / wall)
            .set("served_per_wall_s", agg.total_served as f64 / wall)
            .set(
                "sim_throughput_rps",
                agg.total_served as f64 / sim_wall_s.max(1e-9),
            )
            .set("total_placements", placements)
            .set(
                "plan_throughput_pps",
                placements as f64 / plan_wall_s.max(1e-9),
            )
            .set("parallel", self.config.parallel)
    }

    /// Full report: deterministic subset + per-scenario wall + `wall`.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("config", self.config_json())
            .set(
                "scenarios",
                Json::Arr(self.results.iter().map(|r| result_json(r, true)).collect()),
            )
            .set("aggregate", self.aggregate().to_json())
            .set("wall", self.wall_json())
    }

    /// Persist the full report (pretty JSON, trailing newline).
    pub fn write(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_json().to_string_pretty() + "\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(scenario: usize, cost: f64, slo: f64) -> ScenarioResult {
        ScenarioResult {
            scenario,
            seed_index: 0,
            gpu: "V100".into(),
            fleet: "v100",
            tier: "nominal",
            workloads: 12,
            feasible: true,
            gpus: 6,
            cost_per_hour: cost,
            slo_attainment: slo,
            migrations: 2,
            served: 1000,
            arrivals: 1010,
            dropped: 0,
            faults_injected: 0,
            recovery_samples: 0,
            recovery_ms_p95: 0.0,
            gpu_seconds: 33.0,
            mismatch_pct: 0.0,
            longtail: false,
            near_idle_workloads: 0,
            pred_err_mean: 0.2,
            pred_err_p95: 0.5,
            pred_err_samples: 40,
            is_mig: false,
            stranded_capacity_pct: 0.0,
            reconfigurations: 0,
            mig_cost_packed: 0.0,
            mig_cost_ffd: 0.0,
            mig_cost_igniter: 0.0,
            placements: 50,
            plan_wall_ms: 2.5,
            wall_ms: 12.5,
        }
    }

    fn config() -> SweepConfig {
        SweepConfig {
            scenarios: 2,
            seeds: 1,
            parallel: 4,
            master_seed: 42,
            space: crate::sweep::ScenarioSpace::quick(),
            calibrate: false,
        }
    }

    #[test]
    fn aggregate_means_over_feasible_only() {
        let mut infeasible = result(2, 0.0, 0.0);
        infeasible.feasible = false;
        infeasible.served = 0;
        infeasible.arrivals = 0;
        let agg = Aggregate::of(&[result(0, 10.0, 1.0), result(1, 30.0, 0.5), infeasible]);
        assert_eq!(agg.tasks, 3);
        assert_eq!(agg.feasible, 2);
        assert!((agg.mean_cost_per_hour - 20.0).abs() < 1e-12);
        assert!((agg.mean_slo_attainment - 0.75).abs() < 1e-12);
        assert_eq!(agg.total_served, 2000);
        assert_eq!(agg.total_migrations, 6);
        // pred-error means ignore infeasible tasks like the other means
        assert!((agg.mean_pred_error - 0.2).abs() < 1e-12);
        assert!((agg.p95_pred_error - 0.5).abs() < 1e-12);
    }

    #[test]
    fn sample_less_tasks_do_not_dilute_the_error_means() {
        // A feasible task that recorded no prediction-error samples must
        // be excluded from the error means — counting it as zero error
        // would bias the lower-is-better CI gate toward passing exactly
        // when the telemetry breaks.
        let mut silent = result(1, 20.0, 0.9);
        silent.pred_err_mean = 0.0;
        silent.pred_err_p95 = 0.0;
        silent.pred_err_samples = 0;
        let agg = Aggregate::of(&[result(0, 10.0, 1.0), silent]);
        assert_eq!(agg.feasible, 2);
        assert!((agg.mean_pred_error - 0.2).abs() < 1e-12, "{}", agg.mean_pred_error);
        assert!((agg.p95_pred_error - 0.5).abs() < 1e-12);
        assert_eq!(agg.pred_err_samples, 40);
        // ...and with no sampled task at all the means are plain zero
        let mut other = result(0, 10.0, 1.0);
        other.pred_err_samples = 0;
        let none = Aggregate::of(&[other]);
        assert_eq!(none.mean_pred_error, 0.0);
    }

    #[test]
    fn fingerprint_excludes_wall_clock() {
        let a = SweepReport::new(config(), vec![result(0, 10.0, 1.0)], 1.0);
        let mut slower = a.clone();
        slower.wall_s = 99.0;
        slower.results[0].wall_ms = 9999.0;
        // the planning work-count/wall live in the wall section only
        slower.results[0].placements = 77;
        slower.results[0].plan_wall_ms = 123.0;
        assert_eq!(a.fingerprint(), slower.fingerprint());
        // ...while any deterministic metric changes it
        let mut different = a.clone();
        different.results[0].cost_per_hour = 11.0;
        assert_ne!(a.fingerprint(), different.fingerprint());
    }

    #[test]
    fn fault_keys_appear_only_when_faults_fired() {
        // fault-free: no fault keys anywhere (byte-compat with the
        // pre-chaos report shape and the committed fingerprint golden)
        let clean = SweepReport::new(config(), vec![result(0, 10.0, 1.0)], 1.0);
        let text = clean.fingerprint();
        for key in ["faults_injected", "recovery_ms_p95", "\"faults\""] {
            assert!(!text.contains(key), "fault-free report leaked {key}: {text}");
        }
        // chaos: per-task + aggregate fault keys and the config marker
        let mut chaotic = clean.clone();
        chaotic.config.space = crate::sweep::ScenarioSpace::chaos();
        chaotic.results[0].faults_injected = 2;
        chaotic.results[0].recovery_samples = 1;
        chaotic.results[0].recovery_ms_p95 = 812.5;
        let parsed = Json::parse(&chaotic.fingerprint()).unwrap();
        assert_eq!(parsed.path("config.faults").unwrap().as_bool(), Some(true));
        assert_eq!(
            parsed.path("scenarios.0.faults_injected").unwrap().as_u64(),
            Some(2)
        );
        assert_eq!(
            parsed.path("aggregate.recovery_ms_p95").unwrap().as_f64(),
            Some(812.5)
        );
        // aggregate recovery p95 is the max over tasks (worst recovery)
        let b = {
            let mut r = result(1, 10.0, 1.0);
            r.faults_injected = 1;
            r.recovery_samples = 1;
            r.recovery_ms_p95 = 300.0;
            r
        };
        let agg = Aggregate::of(&[chaotic.results[0].clone(), b]);
        assert_eq!(agg.faults_injected, 3);
        assert_eq!(agg.recovery_samples, 2);
        assert_eq!(agg.recovery_ms_p95, 812.5);
    }

    /// A feasible MIG task result (mig-a100 fleet, head-to-head filled).
    fn mig_result(scenario: usize, packed: f64, ffd: f64) -> ScenarioResult {
        let mut r = result(scenario, packed, 0.97);
        r.gpu = "A100".into();
        r.fleet = "mig-a100";
        r.is_mig = true;
        r.stranded_capacity_pct = 10.0;
        r.reconfigurations = 3;
        r.mig_cost_packed = packed;
        r.mig_cost_ffd = ffd;
        r.mig_cost_igniter = ffd;
        r
    }

    #[test]
    fn mig_keys_appear_only_when_a_mig_task_ran() {
        // non-MIG: no MIG keys anywhere (byte-compat with the pre-MIG
        // report shape and the committed fingerprint golden)
        let clean = SweepReport::new(config(), vec![result(0, 10.0, 1.0)], 1.0);
        let text = clean.fingerprint();
        for key in ["is_mig", "stranded", "mig_tasks", "\"mig\"", "reconfigurations"] {
            assert!(!text.contains(key), "non-MIG report leaked {key}: {text}");
        }
        // MIG lane: per-task + aggregate keys and the config marker
        let mut cfg = config();
        cfg.space.fleets = vec![crate::sweep::scenario::Fleet::MigA100];
        let mig = SweepReport::new(cfg, vec![mig_result(0, 8.2, 12.3), mig_result(1, 4.1, 4.1)], 1.0);
        let parsed = Json::parse(&mig.fingerprint()).unwrap();
        assert_eq!(parsed.path("config.mig").unwrap().as_bool(), Some(true));
        assert_eq!(parsed.path("scenarios.0.is_mig").unwrap().as_bool(), Some(true));
        assert_eq!(
            parsed.path("scenarios.0.stranded_capacity_pct").unwrap().as_f64(),
            Some(10.0)
        );
        assert_eq!(parsed.path("aggregate.mig_tasks").unwrap().as_usize(), Some(2));
        assert_eq!(
            parsed.path("aggregate.total_reconfigurations").unwrap().as_u64(),
            Some(6)
        );
        assert_eq!(
            parsed.path("aggregate.mean_stranded_pct").unwrap().as_f64(),
            Some(10.0)
        );
        // ratio = total packed / total FFD, not the mean of ratios
        let ratio = parsed
            .path("aggregate.packer_vs_ffd_cost_ratio")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!((ratio - (8.2 + 4.1) / (12.3 + 4.1)).abs() < 1e-12, "{ratio}");
        assert!(ratio <= 1.0);
        // a mixed sweep aggregates MIG metrics over MIG tasks only
        let agg = Aggregate::of(&[result(0, 10.0, 1.0), mig_result(1, 4.1, 8.2)]);
        assert_eq!(agg.mig_tasks, 1);
        assert_eq!(agg.mean_mig_cost_packed, 4.1);
        assert_eq!(agg.packer_vs_ffd_cost_ratio, 0.5);
    }

    #[test]
    fn longtail_keys_appear_only_when_the_lane_ran() {
        // non-longtail: no long-tail keys anywhere (byte-compat with the
        // pre-longtail report shape and the committed fingerprint golden)
        let clean = SweepReport::new(config(), vec![result(0, 10.0, 1.0)], 1.0);
        let text = clean.fingerprint();
        for key in ["\"longtail\"", "near_idle", "longtail_tasks"] {
            assert!(!text.contains(key), "plain report leaked {key}: {text}");
        }
        // long-tail lane: per-task + aggregate keys and the config marker
        let mut lt = clean.clone();
        lt.config.space = crate::sweep::ScenarioSpace::longtail();
        lt.results[0].longtail = true;
        lt.results[0].workloads = 400;
        lt.results[0].near_idle_workloads = 360;
        let parsed = Json::parse(&lt.fingerprint()).unwrap();
        assert_eq!(parsed.path("config.longtail").unwrap().as_bool(), Some(true));
        assert_eq!(parsed.path("scenarios.0.longtail").unwrap().as_bool(), Some(true));
        assert_eq!(
            parsed.path("scenarios.0.near_idle_workloads").unwrap().as_usize(),
            Some(360)
        );
        assert_eq!(parsed.path("aggregate.longtail_tasks").unwrap().as_usize(), Some(1));
        assert_eq!(
            parsed.path("aggregate.mean_near_idle_fraction").unwrap().as_f64(),
            Some(0.9)
        );
        // a mixed set averages the fraction over long-tail tasks only,
        // and infeasible tasks do not dilute it
        let mut infeasible = lt.results[0].clone();
        infeasible.feasible = false;
        infeasible.near_idle_workloads = 0;
        let agg = Aggregate::of(&[result(0, 10.0, 1.0), lt.results[0].clone(), infeasible]);
        assert_eq!(agg.longtail_tasks, 2);
        assert!((agg.mean_near_idle_fraction - 0.9).abs() < 1e-12);
    }

    #[test]
    fn report_roundtrips_through_the_json_parser() {
        let report = SweepReport::new(config(), vec![result(0, 18.36, 0.95)], 2.0);
        let text = report.to_json().to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.path("scenarios.0.gpu").unwrap().as_str(), Some("V100"));
        assert_eq!(parsed.path("aggregate.feasible").unwrap().as_usize(), Some(1));
        assert!(parsed.path("wall.scenarios_per_s").unwrap().as_f64().unwrap() > 0.0);
        // total_served / (sum of per-task sim wall): 1000 / 0.0125 s
        let sim_rps = parsed.path("wall.sim_throughput_rps").unwrap().as_f64().unwrap();
        assert!((sim_rps - 1000.0 / 0.0125).abs() < 1e-6, "sim_rps {sim_rps}");
        // placements / (sum of per-task planning wall): 50 / 0.0025 s
        assert_eq!(parsed.path("wall.total_placements").unwrap().as_u64(), Some(50));
        let pps = parsed.path("wall.plan_throughput_pps").unwrap().as_f64().unwrap();
        assert!((pps - 50.0 / 0.0025).abs() < 1e-6, "plan_pps {pps}");
        assert_eq!(parsed.path("scenarios.0.placements").unwrap().as_u64(), Some(50));
        assert_eq!(parsed.path("config.master_seed").unwrap().as_u64(), Some(42));
    }
}
