//! The parallel sweep runner: fans `scenarios x seeds` closed-loop
//! serving tasks out over `std::thread::scope` workers and merges results
//! **in submission order**, so a `--parallel 8` sweep is bit-identical to
//! the `--parallel 1` run.
//!
//! Determinism contract: every task is a pure function of
//! `(space, master_seed, task_index)` — scenario generation, the sim
//! seed, and the rate trace all derive from private `scenario::stream`
//! lanes, and no state is shared between tasks except the read-only
//! profiled `[V100, T4]` pair.  Worker interleaving only decides *when*
//! a slot is filled, never *what* fills it.  Wall-clock fields
//! (`wall_ms`, `SweepReport::wall_s`) are the one exception and are
//! excluded from the deterministic report section (see `report.rs`).

use super::report::SweepReport;
use super::scenario::{stream, Scenario, ScenarioSpace};
use crate::coordinator::{dropped_requests, ClusterSim, Policy, Reprovisioner, Resilience};
use crate::gpu::GpuKind;
use crate::provisioner::{heterogeneous, ProfiledSystem};
use crate::sim::faults::FaultPlan;
use crate::util::stats::{mean, percentile};
use crate::workload::trace::RateTrace;
use crate::workload::ArrivalKind;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Sweep shape: how many scenarios, how many arrival seeds per scenario,
/// and how many worker threads to fan them over.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepConfig {
    pub scenarios: usize,
    /// Independent arrival/trace seeds served per scenario.
    pub seeds: usize,
    /// Worker threads (1 = sequential reference order).
    pub parallel: usize,
    pub master_seed: u64,
    pub space: ScenarioSpace,
    /// Serve every task with online calibration
    /// (`Reprovisioner::with_calibration`) instead of the static model —
    /// the closed-loop answer to the `--mismatch` lane.
    pub calibrate: bool,
}

impl SweepConfig {
    pub fn tasks(&self) -> usize {
        self.scenarios * self.seeds.max(1)
    }
}

/// Outcome of one `(scenario, seed)` closed-loop serving task.  Every
/// field except `wall_ms` is deterministic per `(config, task index)`.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioResult {
    pub scenario: usize,
    pub seed_index: usize,
    /// GPU type of the adopted (cheapest) plan.
    pub gpu: String,
    pub fleet: &'static str,
    pub tier: &'static str,
    pub workloads: usize,
    /// False when no fleet shape could provision the mix.
    pub feasible: bool,
    pub gpus: usize,
    /// Hourly cost of the provisioned plan (Eq. 12).
    pub cost_per_hour: f64,
    /// Fraction of workloads whose lifetime P99 met the SLO.
    pub slo_attainment: f64,
    /// Executed shadow migrations over the closed-loop run.
    pub migrations: u32,
    pub served: u64,
    pub arrivals: u64,
    /// Conservation residual `arrivals - served - still_queued`.  Must be
    /// 0 fault-free; under an injected `FaultPlan` it equals the explicit
    /// per-workload `dropped` counts (shed + orphaned requests), which the
    /// chaos lane bounds and gates rather than forbids.
    pub dropped: i64,
    /// Faults that actually fired (resolved to a live target).  0 outside
    /// the chaos lane; fault keys are serialized only when nonzero so the
    /// fault-free report (and its fingerprint golden) is byte-identical.
    pub faults_injected: u64,
    /// Recovery episodes closed (fault instant -> first batch served by a
    /// replacement replica) and their p95 in ms (0 when no samples).
    pub recovery_samples: u64,
    pub recovery_ms_p95: f64,
    /// Integrated occupied-device time over the run.
    pub gpu_seconds: f64,
    /// Worst believed-coefficient error injected by the mismatch lane
    /// (0 outside it).
    pub mismatch_pct: f64,
    /// Whether this task came from the long-tail lane.  The count below
    /// is serialized only when set, so non-longtail reports (and the
    /// fingerprint golden) stay byte-identical.
    pub longtail: bool,
    /// Tenants of this mix drawn in the near-idle band (<= 2 req/s) —
    /// the structural number the bench gate's active-fraction bar checks.
    pub near_idle_workloads: usize,
    /// Mean / p95 of the serving-observed prediction error
    /// (rel_error(model-predicted t_inf, observed exec), sampled per
    /// monitor tick per workload; 0 when no samples were recorded —
    /// `pred_err_samples` tells the two cases apart, and the aggregate
    /// excludes sample-less tasks from the error means).
    pub pred_err_mean: f64,
    pub pred_err_p95: f64,
    pub pred_err_samples: u64,
    /// Whether this task ran on a MIG fleet (discrete slice partitioning).
    /// The five fields below are meaningful — and serialized — only when
    /// set, so non-MIG reports stay byte-identical.
    pub is_mig: bool,
    /// Stranded slice capacity of the adopted packing: free GPCs on
    /// provisioned devices as a % of all provisioned GPCs.
    pub stranded_capacity_pct: f64,
    /// Live-device slice reconfigurations the serving policy's planner
    /// performed over the closed-loop run.
    pub reconfigurations: u64,
    /// Head-to-head hourly costs on identical quantized demands:
    /// the fragmentation-aware packer vs. FFD++ vs. iGniter's Alg. 1.
    pub mig_cost_packed: f64,
    pub mig_cost_ffd: f64,
    pub mig_cost_igniter: f64,
    /// Placement items executed for this task: the initial provisioning
    /// pass over every candidate GPU type (charged to seed 0, where the
    /// shared work happens) plus every closed-loop respec/rebalance
    /// placement.  Deterministic, but serialized only in the wall section
    /// (it is a work count for `plan_throughput_pps`, not a result).
    pub placements: u64,
    /// Wall-clock spent inside placement (provisioning + online
    /// re-plans); subset of `wall_ms` (NOT deterministic).
    pub plan_wall_ms: f64,
    /// Wall-clock of provision + simulate (NOT deterministic).
    pub wall_ms: f64,
}

/// A scenario's provisioned state, shared by all of its arrival seeds
/// (the plan is a pure function of the scenario — seed-invariant).
struct Provisioned {
    kind: GpuKind,
    plan: crate::provisioner::Plan,
    /// Replicated spec set (rate shares) the plan indexes.
    rspecs: Vec<crate::provisioner::WorkloadSpec>,
    /// Placement items Alg. 1 executed across ALL candidate GPU types
    /// (cheapest-selection provisions every type, not just the winner).
    placements: u64,
    /// MIG head-to-head metrics (None on continuous fleets).
    mig: Option<MigMetrics>,
}

/// The numbers the MIG head-to-head produced for one scenario's plan.
struct MigMetrics {
    stranded_pct: f64,
    cost_packed: f64,
    cost_ffd: f64,
    cost_igniter: f64,
}

/// Provision the cheapest fleet shape for a scenario; `None` when no
/// offered fleet can hold the mix.  MIG fleets (exactly one system) run
/// the packer head-to-head against FFD and iGniter on identical
/// quantized demands and adopt the packed plan.
fn provision_scenario(scenario: &Scenario, systems: &[ProfiledSystem]) -> Option<Provisioned> {
    if scenario.fleet.is_mig() {
        let fleet = scenario.fleet.systems(systems);
        debug_assert_eq!(fleet.len(), 1, "MIG fleets are homogeneous");
        let (tp, h2h) = heterogeneous::provision_mig_head_to_head(&fleet[0], &scenario.specs)?;
        let kind = GpuKind::parse(&tp.plan.gpu).expect("plan carries a known GPU type");
        return Some(Provisioned {
            kind,
            plan: tp.plan,
            rspecs: tp.replicated.specs,
            placements: h2h.placements as u64,
            mig: Some(MigMetrics {
                stranded_pct: h2h.stranded_pct,
                cost_packed: h2h.cost_packed,
                cost_ffd: h2h.cost_ffd,
                cost_igniter: h2h.cost_igniter,
            }),
        });
    }
    let mut candidates =
        heterogeneous::select_cheapest(scenario.fleet.systems(systems), &scenario.specs);
    if candidates.is_empty() {
        return None;
    }
    let placements: u64 = candidates.iter().map(|tp| tp.placements() as u64).sum();
    let tp = candidates.remove(0);
    let kind = GpuKind::parse(&tp.plan.gpu).expect("plan carries a known GPU type");
    Some(Provisioned {
        kind,
        plan: tp.plan,
        rspecs: tp.replicated.specs,
        placements,
        mig: None,
    })
}

/// Serve one `(scenario, seed)` task closed-loop (estimator -> online
/// re-plan -> shadow-instance migration) under a live rate trace.
/// `wall_ms` covers the simulation only; the caller charges the shared
/// provisioning wall where it actually happened.
fn serve_task(
    cfg: &SweepConfig,
    believed: &[ProfiledSystem],
    scenario: &Scenario,
    prov: Option<&Provisioned>,
    task: usize,
) -> ScenarioResult {
    let seeds = cfg.seeds.max(1);
    let sim_seed = stream(cfg.master_seed, 2, task as u64 + 1).next_u64();
    let mut result = ScenarioResult {
        scenario: task / seeds,
        seed_index: task % seeds,
        gpu: String::new(),
        fleet: scenario.fleet.name(),
        tier: scenario.tier.name(),
        workloads: scenario.specs.len(),
        feasible: false,
        gpus: 0,
        cost_per_hour: 0.0,
        slo_attainment: 0.0,
        migrations: 0,
        served: 0,
        arrivals: 0,
        dropped: 0,
        faults_injected: 0,
        recovery_samples: 0,
        recovery_ms_p95: 0.0,
        gpu_seconds: 0.0,
        mismatch_pct: scenario.mismatch_pct(),
        longtail: cfg.space.longtail,
        near_idle_workloads: scenario.near_idle_workloads(),
        pred_err_mean: 0.0,
        pred_err_p95: 0.0,
        pred_err_samples: 0,
        is_mig: false,
        stranded_capacity_pct: 0.0,
        reconfigurations: 0,
        mig_cost_packed: 0.0,
        mig_cost_ffd: 0.0,
        mig_cost_igniter: 0.0,
        placements: 0,
        plan_wall_ms: 0.0,
        wall_ms: 0.0,
    };
    let Some(p) = prov else {
        return result; // infeasible on every fleet shape offered
    };
    // the Reprovisioner plans with what the planner *believes*; the sim's
    // physics stay the unperturbed ground truth
    let sys = believed
        .iter()
        .find(|s| s.hw.gpu == p.plan.gpu)
        .expect("adopted plan's system is in the profiled pair");

    let t0 = Instant::now();
    let trace = RateTrace::generate(scenario.trace, scenario.epochs, p.rspecs.len(), sim_seed);
    let mut sim = ClusterSim::new(
        p.kind,
        &p.plan,
        &p.rspecs,
        Policy::Static,
        ArrivalKind::Poisson,
        sim_seed,
        &[],
    );
    let mut policy = Reprovisioner::new(sys.clone(), p.rspecs.clone(), p.plan.clone());
    if cfg.calibrate {
        policy = policy.with_calibration();
    }
    if !cfg.space.faults.is_off() {
        // chaos lane: full resilience (breakers + shed + hedge) and a
        // fault plan from its own RNG lane (3, task+1) — disjoint from
        // scenario generation and sim seeds, so the arrival streams are
        // byte-identical with faults on or off
        policy = policy.with_resilience(Resilience::ALL);
        sim.set_fault_plan(FaultPlan::generate(
            &cfg.space.faults,
            cfg.master_seed,
            task,
            scenario.horizon_ms(),
        ));
    }
    sim.set_serving_policy(Box::new(policy));
    sim.set_rate_trace(&trace, scenario.epoch_ms);
    sim.set_horizon(scenario.horizon_ms(), scenario.warmup_ms);
    let stats = sim.run();

    let met = stats.iter().filter(|s| !s.violation).count();
    result.feasible = true;
    result.gpu = p.plan.gpu.clone();
    result.gpus = p.plan.num_gpus();
    result.cost_per_hour = p.plan.cost_per_hour();
    result.slo_attainment = met as f64 / stats.len().max(1) as f64;
    result.migrations = sim.migrations();
    result.served = stats.iter().map(|s| s.served).sum();
    result.arrivals = stats.iter().map(|s| s.arrivals).sum();
    result.dropped = dropped_requests(&stats);
    result.faults_injected = sim.faults_injected();
    let recovery = sim.recovery_ms();
    if !recovery.is_empty() {
        result.recovery_samples = recovery.len() as u64;
        result.recovery_ms_p95 = percentile(recovery, 0.95);
    }
    result.gpu_seconds = sim.gpu_seconds();
    let errs = sim.serving_policy().prediction_errors();
    if !errs.is_empty() {
        result.pred_err_mean = mean(errs);
        result.pred_err_p95 = percentile(errs, 0.95);
        result.pred_err_samples = errs.len() as u64;
    }
    if let Some(m) = &p.mig {
        result.is_mig = true;
        result.stranded_capacity_pct = m.stranded_pct;
        result.mig_cost_packed = m.cost_packed;
        result.mig_cost_ffd = m.cost_ffd;
        result.mig_cost_igniter = m.cost_igniter;
        result.reconfigurations = sim.serving_policy().reconfigurations();
    }
    let (placements, plan_wall_ms) = sim.serving_policy().planning_activity();
    result.placements = placements;
    result.plan_wall_ms = plan_wall_ms;
    result.wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    result
}

/// Run one task standalone: generate + provision + serve.  The sweep
/// path uses `run_scenario` instead so sibling seeds share one
/// provisioning pass; the results are identical either way.
pub fn run_task(cfg: &SweepConfig, systems: &[ProfiledSystem], task: usize) -> ScenarioResult {
    let seeds = cfg.seeds.max(1);
    let scenario = Scenario::generate(&cfg.space, cfg.master_seed, task / seeds);
    let t0 = Instant::now();
    let believed = scenario.believed_systems(systems);
    let prov = provision_scenario(&scenario, &believed);
    let prov_ms = t0.elapsed().as_secs_f64() * 1e3;
    let mut r = serve_task(cfg, &believed, &scenario, prov.as_ref(), task);
    r.wall_ms += prov_ms;
    r.plan_wall_ms += prov_ms;
    r.placements += prov.as_ref().map_or(0, |p| p.placements);
    r
}

/// Run every seed of one scenario, provisioning once (the plan is
/// seed-invariant).  The provisioning wall is charged to seed 0, where
/// the work happened.
fn run_scenario(
    cfg: &SweepConfig,
    systems: &[ProfiledSystem],
    scenario_id: usize,
) -> Vec<ScenarioResult> {
    let seeds = cfg.seeds.max(1);
    let scenario = Scenario::generate(&cfg.space, cfg.master_seed, scenario_id);
    let t0 = Instant::now();
    let believed = scenario.believed_systems(systems);
    let prov = provision_scenario(&scenario, &believed);
    let prov_ms = t0.elapsed().as_secs_f64() * 1e3;
    let mut out: Vec<ScenarioResult> = (0..seeds)
        .map(|si| serve_task(cfg, &believed, &scenario, prov.as_ref(), scenario_id * seeds + si))
        .collect();
    out[0].wall_ms += prov_ms;
    out[0].plan_wall_ms += prov_ms;
    out[0].placements += prov.as_ref().map_or(0, |p| p.placements);
    out
}

/// Run the whole sweep.  Whole scenarios (all their seeds) are pulled
/// off a shared atomic counter by `parallel` scoped workers; each
/// writes its seeds-block of the pre-sized result vector, so the merged
/// order is always submission order regardless of worker interleaving.
pub fn run_sweep(cfg: &SweepConfig) -> SweepReport {
    let systems =
        super::scenario::profiled_fleet(crate::experiments::common::SEED, cfg.space.needs_mig());
    let seeds = cfg.seeds.max(1);
    let t0 = Instant::now();
    let results: Vec<ScenarioResult> = if cfg.parallel <= 1 {
        (0..cfg.scenarios)
            .flat_map(|s| run_scenario(cfg, &systems, s))
            .collect()
    } else {
        let next = AtomicUsize::new(0);
        let slots: Mutex<Vec<Option<ScenarioResult>>> = Mutex::new(vec![None; cfg.tasks()]);
        std::thread::scope(|scope| {
            for _ in 0..cfg.parallel {
                scope.spawn(|| loop {
                    let s = next.fetch_add(1, Ordering::Relaxed);
                    if s >= cfg.scenarios {
                        break;
                    }
                    let block = run_scenario(cfg, &systems, s);
                    let mut guard = slots.lock().unwrap();
                    for (si, r) in block.into_iter().enumerate() {
                        guard[s * seeds + si] = Some(r);
                    }
                });
            }
        });
        slots
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("every task slot filled"))
            .collect()
    };
    SweepReport::new(cfg.clone(), results, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::faults::FaultSpace;
    use crate::sweep::scenario::Fleet;

    fn tiny() -> SweepConfig {
        SweepConfig {
            scenarios: 3,
            seeds: 1,
            parallel: 1,
            master_seed: 11,
            space: ScenarioSpace {
                min_workloads: 6,
                max_workloads: 10,
                epochs: 3,
                epoch_ms: 800.0,
                warmup_ms: 200.0,
                fleets: vec![Fleet::V100Only, Fleet::Heterogeneous],
                mismatch: false,
                faults: FaultSpace::OFF,
                longtail: false,
            },
            calibrate: false,
        }
    }

    #[test]
    fn tasks_conserve_requests_and_meet_structural_invariants() {
        let cfg = tiny();
        let report = run_sweep(&cfg);
        assert_eq!(report.results.len(), 3);
        for r in &report.results {
            assert!(r.feasible, "tiny envelope mixes must be provisionable");
            assert_eq!(r.dropped, 0, "closed loop dropped requests: {r:?}");
            assert!(r.gpus > 0 && r.cost_per_hour > 0.0);
            assert!(r.served > 0 && r.arrivals >= r.served);
            assert!((0.0..=1.0).contains(&r.slo_attainment));
            assert!(r.gpu_seconds > 0.0);
        }
    }

    #[test]
    fn prediction_error_metrics_are_recorded() {
        let cfg = tiny();
        let report = run_sweep(&cfg);
        assert!(
            report.results.iter().any(|r| r.pred_err_mean > 0.0),
            "no task recorded prediction errors"
        );
        for r in &report.results {
            assert!(r.pred_err_mean >= 0.0 && r.pred_err_mean.is_finite());
            assert!(r.pred_err_p95 >= 0.0 && r.pred_err_p95.is_finite());
            assert_eq!(r.mismatch_pct, 0.0, "no mismatch outside the lane");
        }
    }

    #[test]
    fn mismatch_lane_with_calibration_conserves_requests() {
        let mut cfg = tiny();
        cfg.space.mismatch = true;
        cfg.calibrate = true;
        let report = run_sweep(&cfg);
        for r in &report.results {
            assert_eq!(r.dropped, 0, "calibrated closed loop dropped: {r:?}");
            if r.feasible {
                assert!(
                    (0.10..=0.30 + 1e-9).contains(&r.mismatch_pct),
                    "mismatch_pct {}",
                    r.mismatch_pct
                );
                assert!(r.served > 0);
            }
        }
    }

    #[test]
    fn chaos_lane_injects_faults_and_serves_through_them() {
        let mut cfg = tiny();
        cfg.scenarios = 6;
        cfg.space.faults = FaultSpace::chaos();
        let report = run_sweep(&cfg);
        let injected: u64 = report.results.iter().map(|r| r.faults_injected).sum();
        assert!(injected > 0, "chaos space never landed a fault in 6 tasks");
        for r in &report.results {
            assert!(r.feasible && r.served > 0);
            // explicit accounting: the residual IS the dropped count, and
            // it stays a small fraction of the offered load
            assert!(r.dropped >= 0, "negative residual (double count): {r:?}");
            assert!(
                (r.dropped as u64) <= r.arrivals / 10,
                "chaos lane dropped {} of {} arrivals: {r:?}",
                r.dropped,
                r.arrivals
            );
            if r.recovery_samples > 0 {
                assert!(r.recovery_ms_p95 > 0.0 && r.recovery_ms_p95.is_finite());
            }
            if r.faults_injected == 0 {
                assert_eq!(r.dropped, 0, "dropped without a fired fault: {r:?}");
            }
        }
    }

    #[test]
    fn mig_lane_reports_fragmentation_and_the_packer_never_loses() {
        let mut cfg = tiny();
        cfg.scenarios = 4;
        cfg.space.fleets = vec![Fleet::MigA100, Fleet::MigH100];
        let report = run_sweep(&cfg);
        assert_eq!(report.results.len(), 4);
        for r in &report.results {
            assert!(r.is_mig, "MIG lane produced a non-MIG result: {r:?}");
            assert!(r.feasible && r.served > 0, "{r:?}");
            assert!(r.gpu == "A100" || r.gpu == "H100", "{}", r.gpu);
            assert!(
                (0.0..100.0).contains(&r.stranded_capacity_pct),
                "stranded {}",
                r.stranded_capacity_pct
            );
            // the adopted plan IS the packed plan
            assert_eq!(r.cost_per_hour, r.mig_cost_packed);
            assert!(r.mig_cost_packed > 0.0);
            // head-to-head on identical demands: packer beats or ties both
            assert!(r.mig_cost_packed <= r.mig_cost_ffd + 1e-9, "{r:?}");
            assert!(r.mig_cost_packed <= r.mig_cost_igniter + 1e-9, "{r:?}");
            assert_eq!(r.dropped, 0);
        }
        // non-MIG lanes never carry MIG metrics
        let base = run_sweep(&tiny());
        for r in &base.results {
            assert!(!r.is_mig);
            assert_eq!(r.reconfigurations, 0);
            assert_eq!(r.mig_cost_packed, 0.0);
        }
    }

    #[test]
    fn longtail_lane_serves_a_near_idle_majority_without_drops() {
        let mut cfg = tiny();
        cfg.scenarios = 2;
        // the real lane draws 200-1000 tenants; a scaled-down band keeps
        // the unit test fast while exercising the same draw paths
        cfg.space.min_workloads = 20;
        cfg.space.max_workloads = 30;
        cfg.space.longtail = true;
        let report = run_sweep(&cfg);
        for r in &report.results {
            assert!(r.longtail);
            assert!(r.feasible && r.served > 0, "{r:?}");
            assert_eq!(r.dropped, 0, "longtail closed loop dropped: {r:?}");
            assert!(
                r.near_idle_workloads > 0 && r.near_idle_workloads <= r.workloads,
                "near-idle {} of {}",
                r.near_idle_workloads,
                r.workloads
            );
        }
        // the population-level tail fraction holds even at this tiny size
        let (tail, total): (usize, usize) = report
            .results
            .iter()
            .fold((0, 0), |(t, n), r| (t + r.near_idle_workloads, n + r.workloads));
        assert!(tail * 2 > total, "tail {} of {}", tail, total);
        // plain lanes never carry the flag
        for r in &run_sweep(&tiny()).results {
            assert!(!r.longtail);
        }
    }

    #[test]
    fn seeds_change_serving_but_not_the_scenario() {
        let mut cfg = tiny();
        cfg.scenarios = 1;
        cfg.seeds = 3;
        let report = run_sweep(&cfg);
        let a = &report.results[0];
        for b in &report.results[1..] {
            // same provisioned mix for every seed...
            assert_eq!(a.scenario, b.scenario);
            assert_eq!(
                (a.workloads, a.gpus, a.gpu.clone()),
                (b.workloads, b.gpus, b.gpu.clone())
            );
            assert_eq!(a.cost_per_hour, b.cost_per_hour);
        }
        // ...but the arrival realizations are independent: three Poisson
        // seeds tying on every count simultaneously would mean the seed
        // is ignored
        let prints: Vec<_> = report
            .results
            .iter()
            .map(|r| (r.served, r.arrivals, r.gpu_seconds.to_bits()))
            .collect();
        assert!(
            prints.windows(2).any(|w| w[0] != w[1]),
            "all seeds produced identical serving: {prints:?}"
        );
    }
}
