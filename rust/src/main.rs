//! `igniter` — the Layer-3 leader binary.
//!
//! Subcommands:
//!   profile     print profiled hardware/workload coefficients
//!   provision   compute a provisioning plan for a workload set
//!   serve       run the serving simulation (and optionally real compute)
//!   sweep       parallel fleet-scale scenario sweep -> BENCH_sweep.json
//!   verify      check compiled HLO artifacts against Python goldens
//!   experiment  regenerate a paper table/figure (see DESIGN.md §5)
//!
//! Examples:
//!   igniter experiment fig14
//!   igniter provision --strategy gpulets --workloads app
//!   igniter serve --policy shadow --horizon-s 30 --real-batches 2
//!   igniter sweep --scenarios 200 --seeds 2 --parallel 8 --out BENCH_sweep.json
//!   igniter verify

use igniter::util::error::{anyhow, bail, Result};
use igniter::coordinator::{self, ClusterSim, Policy, Reprovisioner, Resilience};
use igniter::sim::faults::{FaultPlan, FaultSpace};
use igniter::gpu::GpuKind;
use igniter::provisioner::{ffd, gpulets, gslice, igniter as ig, Plan, ProfiledSystem};
use igniter::runtime::{Engine, Manifest};
use igniter::util::cli::Args;
use igniter::util::table::{f, pct, Table};
use igniter::workload::trace::{RateTrace, TraceKind};
use igniter::workload::{self, ArrivalKind};
use std::path::{Path, PathBuf};

fn main() {
    let args = Args::from_env(&[
        "poisson",
        "json",
        "verbose",
        "script",
        "full",
        "calibrate",
        "mismatch",
        "longtail",
    ]);
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn gpu_kind(args: &Args) -> Result<GpuKind> {
    if let Some(cfg) = load_config(args)? {
        return Ok(cfg.gpu);
    }
    let s = args.opt_or("gpu", "v100");
    GpuKind::parse(s).ok_or_else(|| anyhow!("unknown GPU type '{s}' (v100|t4|a100|h100)"))
}

/// `--config file.json` overrides gpu/strategy/workloads/serving options.
fn load_config(args: &Args) -> Result<Option<igniter::config::Config>> {
    match args.opt("config") {
        Some(path) => Ok(Some(igniter::config::Config::load(Path::new(path))?)),
        None => Ok(None),
    }
}

fn profiled(args: &Args) -> Result<ProfiledSystem> {
    let kind = gpu_kind(args)?;
    let seed = args.opt_u64("seed", 42);
    let (hw, wls) = igniter::profiler::profile_all(kind, seed);
    Ok(ProfiledSystem {
        hw,
        coeffs: igniter::gpu::ALL_MODELS.iter().cloned().zip(wls).collect(),
    })
}

fn workload_set(args: &Args) -> Result<Vec<igniter::provisioner::WorkloadSpec>> {
    if let Some(cfg) = load_config(args)? {
        return Ok(cfg.workloads);
    }
    let w = args.opt_or("workloads", "app");
    if let Some(n) = w.strip_prefix("synthetic:") {
        return Ok(workload::synthetic_workloads(
            n.parse()?,
            args.opt_u64("seed", 42),
        ));
    }
    match w {
        "app" => Ok(workload::app_workloads()),
        "table1" => Ok(workload::table1_workloads()),
        other => bail!("unknown workload set '{other}' (app|table1|synthetic:N)"),
    }
}

fn plan_for(args: &Args, sys: &ProfiledSystem) -> Result<Plan> {
    let specs = workload_set(args)?;
    let strategy = match load_config(args)? {
        Some(cfg) => cfg.strategy,
        None => args.opt_or("strategy", "igniter").to_string(),
    };
    Ok(match strategy.as_str() {
        "igniter" => ig::provision(sys, &specs),
        "ffd" => ffd::provision_ffd(sys, &specs),
        "ffd++" => ffd::provision_ffd_pp(sys, &specs),
        "gslice" => gslice::provision_gslice(sys, &specs),
        "gpulets" => gpulets::provision_gpulets(sys, &specs),
        other => bail!("unknown strategy '{other}'"),
    })
}

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("profile") => cmd_profile(args),
        Some("provision") => cmd_provision(args),
        Some("serve") => cmd_serve(args),
        Some("sweep") => cmd_sweep(args),
        Some("deploy") => cmd_deploy(args),
        Some("verify") => cmd_verify(),
        Some("experiment") => {
            let id = args
                .positional
                .first()
                .map(String::as_str)
                .unwrap_or("all");
            igniter::experiments::run(id, gpu_kind(args)?)
        }
        Some(other) => bail!("unknown subcommand '{other}'"),
        None => {
            println!(
                "igniter — interference-aware GPU resource provisioning (paper reproduction)\n\n\
                 usage: igniter <profile|provision|serve|sweep|verify|experiment> [options]\n\
                 \x20 profile     [--gpu v100|t4] [--seed N]\n\
                 \x20 provision   [--strategy igniter|ffd|ffd++|gslice|gpulets] [--workloads app|table1|synthetic:N]\n\
                 \x20 serve       [--policy shadow|static|gslice|autoscale] [--calibrate] [--trace diurnal|spiky|ramp]\n\
                 \x20             [--epochs N] [--epoch-s S] [--horizon-s S] [--poisson] [--real-batches N]\n\
                 \x20             [--faults [deaths=N,stragglers=N,hangs=N,factor=F,span_ms=S]]\n\
                 \x20 sweep       [--scenarios N] [--seeds K] [--parallel M] [--master-seed S]\n\
                 \x20             [--out BENCH_sweep.json] [--full] [--mismatch] [--calibrate] [--faults [spec]]\n\
                 \x20             [--fleet mig] [--longtail] — fleet-scale scenario sweep (mismatch = model-error\n\
                 \x20             lane, faults = chaos lane, fleet mig = A100/H100 discrete-slice lane,\n\
                 \x20             longtail = 200-1000 mostly-idle tenants)\n\
                 \x20 deploy      [--strategy ...] [--script] — emit the launcher manifest\n\
                 \x20 verify\n\
                 \x20 experiment  [fig3..fig21|table1|overhead|all]"
            );
            Ok(())
        }
    }
}

fn cmd_profile(args: &Args) -> Result<()> {
    let sys = profiled(args)?;
    println!(
        "hardware ({}):\n{}",
        sys.hw.gpu,
        sys.hw.to_json().to_string_pretty()
    );
    let mut t = Table::new(
        "workload coefficients",
        &["model", "n_k", "k_sch", "k1", "k2", "k3", "k4", "k5", "a_pow", "a_cache"],
    );
    for (m, wc) in &sys.coeffs {
        t.row(&[
            m.name().to_string(),
            f(wc.n_kernels, 0),
            f(wc.k_sch, 5),
            format!("{:.5}", wc.kact.k1),
            f(wc.kact.k2, 4),
            f(wc.kact.k3, 4),
            f(wc.kact.k4, 4),
            f(wc.kact.k5, 4),
            f(wc.alpha_power, 1),
            f(wc.alpha_cache, 3),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_provision(args: &Args) -> Result<()> {
    let sys = profiled(args)?;
    let specs = workload_set(args)?;
    let plan = plan_for(args, &sys)?;
    if args.flag("json") {
        println!("{}", plan.to_json().to_string_pretty());
        return Ok(());
    }
    let mut t = Table::new(
        &format!(
            "{} plan on {}: {} GPUs, ${:.2}/h",
            plan.strategy,
            plan.gpu,
            plan.num_gpus(),
            plan.cost_per_hour()
        ),
        &["gpu", "workload", "resources", "batch", "pred_t_inf_ms", "half_slo_ms"],
    );
    let preds = ig::predict_plan(&sys, &specs, &plan);
    for (g, a) in plan.all() {
        let p = preds.iter().find(|(w, _, _)| *w == a.workload).unwrap();
        t.row(&[
            format!("GPU{}", g + 1),
            specs[a.workload].name.clone(),
            pct(a.resources),
            a.batch.to_string(),
            f(p.1, 2),
            f(specs[a.workload].slo_ms / 2.0, 1),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let kind = gpu_kind(args)?;
    let sys = profiled(args)?;
    let specs = workload_set(args)?;
    let plan = plan_for(args, &sys)?;
    let cfg = load_config(args)?;
    let policy_s = cfg
        .as_ref()
        .map(|c| c.serving.policy.clone())
        .unwrap_or_else(|| args.opt_or("policy", "shadow").to_string());
    let policy = match policy_s.as_str() {
        "shadow" => Policy::IgniterShadow,
        "static" => Policy::Static,
        "gslice" => Policy::GsliceTuner { period_ms: 10_000.0 },
        // the closed loop is installed below (it needs the plan + system)
        "autoscale" => Policy::Static,
        other => bail!("unknown policy '{other}'"),
    };
    if args.flag("calibrate") && policy_s != "autoscale" {
        bail!("--calibrate requires --policy autoscale (it feeds the closed-loop Reprovisioner)");
    }
    let arrival = if args.flag("poisson") || cfg.as_ref().map_or(false, |c| c.serving.poisson) {
        ArrivalKind::Poisson
    } else {
        ArrivalKind::Constant
    };
    let horizon = cfg
        .as_ref()
        .map(|c| c.serving.horizon_s)
        .unwrap_or_else(|| args.opt_f64("horizon-s", 30.0))
        * 1000.0;
    let mut sim = ClusterSim::new(
        kind,
        &plan,
        &specs,
        policy,
        arrival,
        args.opt_u64("seed", 42),
        &[],
    );
    // --faults [spec]: deterministic chaos — a FaultPlan seeded from
    // --seed (bare flag = the default chaos envelope, a value is parsed
    // as key=value overrides, e.g. --faults deaths=1,hangs=0)
    let fault_spec: Option<FaultSpace> = match (args.opt("faults"), args.flag("faults")) {
        (Some(spec), _) => Some(FaultSpace::parse_spec(spec).map_err(|e| anyhow!("{e}"))?),
        (None, true) => Some(FaultSpace::chaos()),
        (None, false) => None,
    };
    if policy_s == "autoscale" {
        // estimator -> online re-plan -> shadow-instance migration, with
        // the submitted rates as the planned design points; --calibrate
        // additionally fits residual corrections from observed exec
        // latencies and re-plans with the corrected model
        let mut rp = Reprovisioner::new(sys.clone(), specs.clone(), plan.clone());
        if args.flag("calibrate") {
            rp = rp.with_calibration();
        }
        if fault_spec.is_some() {
            // breakers + shed + hedge: serve *through* the injected
            // faults instead of merely counting them
            rp = rp.with_resilience(Resilience::ALL);
        }
        sim.set_serving_policy(Box::new(rp));
    }
    if let Some(fspace) = &fault_spec {
        let fplan = FaultPlan::generate(fspace, args.opt_u64("seed", 42), 0, horizon);
        println!(
            "fault plan: {} event(s) from seed {}{}",
            fplan.len(),
            args.opt_u64("seed", 42),
            if policy_s == "autoscale" {
                ""
            } else {
                "  (note: only --policy autoscale replaces lost capacity)"
            }
        );
        sim.set_fault_plan(fplan);
    }
    if let Some(trace_s) = args.opt("trace") {
        let epochs = args.opt_usize("epochs", 24).max(1);
        let epoch_ms = args.opt_f64("epoch-s", horizon / 1000.0 / epochs as f64) * 1000.0;
        let tk = match trace_s {
            "diurnal" => TraceKind::Diurnal {
                period_epochs: epochs,
                floor: 0.35,
            },
            "spiky" => TraceKind::Spiky { base: 0.3, p: 0.2 },
            "ramp" => TraceKind::Ramp { from: 0.3, to: 1.0 },
            other => bail!("unknown trace '{other}' (diurnal|spiky|ramp)"),
        };
        let trace = RateTrace::generate(tk, epochs, specs.len(), args.opt_u64("seed", 42));
        sim.set_rate_trace(&trace, epoch_ms);
    }
    sim.set_horizon(horizon, 1000.0);
    let stats = sim.run();
    let mut t = Table::new(
        &format!(
            "virtual-time serving: {} on {} GPUs ({:.0}s horizon)",
            plan.strategy,
            plan.num_gpus(),
            horizon / 1000.0
        ),
        &["workload", "P99_ms", "mean_ms", "SLO_ms", "rps", "target", "ok", "switches"],
    );
    for s in &stats {
        t.row(&[
            s.name.clone(),
            f(s.p99_ms, 2),
            f(s.mean_ms, 2),
            f(s.slo_ms, 0),
            f(s.achieved_rps, 0),
            f(s.rate_rps, 0),
            (!(s.violation || s.throughput_violation)).to_string(),
            s.shadow_switches.to_string(),
        ]);
    }
    println!("{}", t.render());
    if fault_spec.is_some() {
        let recovery = sim.recovery_ms();
        let dropped: u64 = stats.iter().map(|s| s.dropped).sum();
        println!(
            "faults injected {}  recovery p95 {:.0} ms ({} episode(s))  dropped {}",
            sim.faults_injected(),
            if recovery.is_empty() {
                0.0
            } else {
                igniter::util::stats::percentile(recovery, 0.95)
            },
            recovery.len(),
            dropped
        );
    }
    if policy_s == "autoscale" || args.opt("trace").is_some() {
        println!(
            "gpu-seconds {:.1}  migrations {}",
            sim.gpu_seconds(),
            sim.migrations()
        );
        let errs = sim.serving_policy().prediction_errors();
        if !errs.is_empty() {
            println!(
                "prediction error mean {:.3}  p95 {:.3}  ({} samples{})",
                igniter::util::stats::mean(errs),
                igniter::util::stats::percentile(errs, 0.95),
                errs.len(),
                if args.flag("calibrate") {
                    "; calibrated re-planning ON"
                } else {
                    ""
                }
            );
        }
    }

    let real_batches = args.opt_usize("real-batches", 0);
    if real_batches > 0 {
        let manifest = Manifest::load(&artifacts_dir())?;
        let mut engine = Engine::new(manifest)?;
        let rs = coordinator::realrun::serve_real(
            &mut engine,
            &plan,
            &specs,
            real_batches as u32,
            args.opt_u64("seed", 42),
        )?;
        let mut rt = Table::new(
            "real PJRT compute (wall clock; numerics from the AOT-compiled HLO)",
            &["workload", "model", "batch", "requests", "ms_per_batch", "wall_rps"],
        );
        for s in &rs {
            rt.row(&[
                s.name.clone(),
                s.model.clone(),
                s.batch.to_string(),
                s.requests.to_string(),
                f(s.mean_batch_ms, 2),
                f(s.wall_rps, 0),
            ]);
        }
        println!("{}", rt.render());
    }
    Ok(())
}

/// Fleet-scale parallel scenario sweep: `scenarios x seeds` closed-loop
/// serving tasks over `parallel` workers, summarized on stdout and
/// persisted as machine-readable JSON (default `BENCH_sweep.json`) for
/// the CI bench gate.  Deterministic per master seed: the report's
/// non-wall sections are bit-identical for any `--parallel` width.
fn cmd_sweep(args: &Args) -> Result<()> {
    use igniter::sweep::{run_sweep, Fleet, ScenarioSpace, SweepConfig};
    let mut space = if args.flag("longtail") {
        // --longtail: the long-tail lane — 200-1000-tenant mixes, ~90%
        // near-idle, bursty traces; the regime the idle-aware monitor
        // fast path is gated on.  Takes precedence over --full (both set
        // a workload-count band).
        ScenarioSpace::longtail()
    } else if args.flag("full") {
        ScenarioSpace::full()
    } else {
        ScenarioSpace::quick()
    };
    // --fleet mig: the MIG lane — scenarios sample homogeneous A100/H100
    // MIG fleets; demands are slice-quantized, packing is fragmentation-
    // aware, and each plan is scored head-to-head vs FFD and iGniter.
    // Composes with --full/--mismatch/--calibrate/--faults.
    if let Some(fleet) = args.opt("fleet") {
        match fleet {
            "mig" => space.fleets = vec![Fleet::MigA100, Fleet::MigH100],
            other => bail!("unknown fleet '{other}' (mig)"),
        }
    }
    // --mismatch: perturb the planner's believed coefficients per
    // scenario (the model-error lane); --calibrate serves every task
    // with online calibration so the sweep measures the closed loop's
    // answer to exactly that error
    space.mismatch = args.flag("mismatch");
    // --faults [spec]: the chaos lane — every task draws a FaultPlan
    // (deaths/stragglers/hangs) and serves with full resilience; a bare
    // flag uses the default chaos envelope, a value overrides it
    if let Some(spec) = args.opt("faults") {
        space.faults = FaultSpace::parse_spec(spec).map_err(|e| anyhow!("{e}"))?;
    } else if args.flag("faults") {
        space.faults = FaultSpace::chaos();
    }
    let cfg = SweepConfig {
        scenarios: args.opt_usize("scenarios", 200).max(1),
        seeds: args.opt_usize("seeds", 2).max(1),
        parallel: args.opt_usize("parallel", 8).max(1),
        master_seed: args.opt_u64("master-seed", 42),
        space,
        calibrate: args.flag("calibrate"),
    };
    let report = run_sweep(&cfg);
    let agg = report.aggregate();

    let mut t = Table::new(
        &format!(
            "fleet-scale sweep: {} scenarios x {} seeds ({} mode, parallel {})",
            cfg.scenarios,
            cfg.seeds,
            if args.flag("longtail") {
                "longtail"
            } else if args.flag("full") {
                "full"
            } else {
                "quick"
            },
            cfg.parallel
        ),
        &["metric", "value"],
    );
    t.row(&["feasible tasks".into(), format!("{}/{}", agg.feasible, agg.tasks)]);
    t.row(&["mean cost ($/h)".into(), f(agg.mean_cost_per_hour, 2)]);
    t.row(&[
        "mean SLO attainment".into(),
        format!("{:.2}%", agg.mean_slo_attainment * 100.0),
    ]);
    t.row(&["mean GPUs per plan".into(), f(agg.mean_gpus, 1)]);
    t.row(&["total migrations".into(), agg.total_migrations.to_string()]);
    t.row(&["total served".into(), agg.total_served.to_string()]);
    t.row(&["total dropped".into(), agg.total_dropped.to_string()]);
    t.row(&["total GPU-seconds".into(), f(agg.total_gpu_seconds, 1)]);
    t.row(&["mean pred error".into(), f(agg.mean_pred_error, 3)]);
    t.row(&["p95 pred error".into(), f(agg.p95_pred_error, 3)]);
    if !cfg.space.faults.is_off() {
        t.row(&["faults injected".into(), agg.faults_injected.to_string()]);
        t.row(&[
            "recovery p95 (ms)".into(),
            format!("{} ({} episodes)", f(agg.recovery_ms_p95, 0), agg.recovery_samples),
        ]);
    }
    if agg.mig_tasks > 0 {
        t.row(&["MIG tasks".into(), agg.mig_tasks.to_string()]);
        t.row(&[
            "mean stranded capacity".into(),
            format!("{:.2}%", agg.mean_stranded_pct),
        ]);
        t.row(&[
            "slice reconfigurations".into(),
            agg.total_reconfigurations.to_string(),
        ]);
        t.row(&[
            "mean MIG cost packed/ffd/igniter ($/h)".into(),
            format!(
                "{:.2} / {:.2} / {:.2}",
                agg.mean_mig_cost_packed, agg.mean_mig_cost_ffd, agg.mean_mig_cost_igniter
            ),
        ]);
        t.row(&[
            "packer vs FFD cost ratio".into(),
            f(agg.packer_vs_ffd_cost_ratio, 4),
        ]);
    }
    if agg.longtail_tasks > 0 {
        t.row(&["longtail tasks".into(), agg.longtail_tasks.to_string()]);
        t.row(&[
            "mean near-idle tenant fraction".into(),
            format!("{:.1}%", agg.mean_near_idle_fraction * 100.0),
        ]);
    }
    t.row(&["wall (s)".into(), f(report.wall_s, 2)]);
    t.row(&[
        "scenarios/s (wall)".into(),
        f(report.results.len() as f64 / report.wall_s.max(1e-9), 1),
    ]);
    t.row(&[
        "served req/s (wall)".into(),
        f(agg.total_served as f64 / report.wall_s.max(1e-9), 0),
    ]);
    let sim_wall_s: f64 = report.results.iter().map(|r| r.wall_ms).sum::<f64>() / 1e3;
    t.row(&[
        "sim throughput (req/s, 1 core)".into(),
        f(agg.total_served as f64 / sim_wall_s.max(1e-9), 0),
    ]);
    let placements: u64 = report.results.iter().map(|r| r.placements).sum();
    let plan_wall_s: f64 = report.results.iter().map(|r| r.plan_wall_ms).sum::<f64>() / 1e3;
    t.row(&[
        "plan throughput (placements/s)".into(),
        f(placements as f64 / plan_wall_s.max(1e-9), 0),
    ]);
    println!("{}", t.render());

    // persist before any failure exit: the per-scenario JSON is exactly
    // the evidence needed to debug a conservation violation
    let out = PathBuf::from(args.opt_or("out", "BENCH_sweep.json"));
    report.write(&out)?;
    println!("wrote {}", out.display());
    if cfg.space.faults.is_off() {
        if agg.total_dropped != 0 {
            bail!("sweep dropped {} requests — conservation violated", agg.total_dropped);
        }
    } else {
        // chaos lane: drops are explicit and bounded, never silent.  A
        // negative residual means double-counted serving; a large one
        // means the failover path stopped absorbing faults.  The fine-
        // grained run-over-run bound lives in check_bench_regression.py.
        if agg.total_dropped < 0 {
            bail!("chaos sweep residual {} < 0 — requests double-counted", agg.total_dropped);
        }
        if agg.total_dropped as u64 > agg.total_arrivals / 10 {
            bail!(
                "chaos sweep dropped {} of {} arrivals — failover not absorbing faults",
                agg.total_dropped,
                agg.total_arrivals
            );
        }
    }
    // MIG lane structural bar: the portfolio packer adopts the FFD
    // packing whenever FFD lands on fewer devices, so losing to FFD on
    // any task means the fallback is broken, not that the heuristic had
    // an off day.
    let packer_losses = report
        .results
        .iter()
        .filter(|r| r.feasible && r.is_mig && r.mig_cost_packed > r.mig_cost_ffd + 1e-9)
        .count();
    if packer_losses > 0 {
        bail!("MIG packer lost to FFD on {packer_losses} task(s) — portfolio fallback broken");
    }
    // Long-tail lane structural bar: the lane measures the mostly-idle
    // regime — if the drawn mixes are not actually dominated by near-idle
    // tenants, the headline throughput number is measuring something else.
    if cfg.space.longtail && agg.feasible > 0 && agg.mean_near_idle_fraction < 0.75 {
        bail!(
            "longtail sweep near-idle fraction {:.2} < 0.75 — lane is not long-tailed",
            agg.mean_near_idle_fraction
        );
    }
    Ok(())
}

fn cmd_deploy(args: &Args) -> Result<()> {
    let sys = profiled(args)?;
    let specs = workload_set(args)?;
    let plan = plan_for(args, &sys)?;
    let deployment = igniter::cluster::deploy(&plan, &specs, true);
    if args.flag("script") {
        print!("{}", deployment.to_script());
    } else {
        println!("{}", deployment.to_json().to_string_pretty());
    }
    Ok(())
}

fn cmd_verify() -> Result<()> {
    let manifest = Manifest::load(&artifacts_dir())?;
    let names: Vec<String> = manifest.models.iter().map(|m| m.name.clone()).collect();
    let mut engine = Engine::new(manifest)?;
    for n in &names {
        let err = engine.verify_golden(n, 1e-3)?;
        println!("{n}: golden max |err| = {err:.2e}  OK");
    }
    println!("all {} models verified against Python goldens", names.len());
    Ok(())
}
