//! Artifact manifest: the contract between `python/compile/aot.py` (which
//! writes `artifacts/manifest.json`) and the Rust runtime (which loads it).

use crate::util::json::Json;
use crate::util::error::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// One compiled (model, batch) HLO variant.
#[derive(Debug, Clone, PartialEq)]
pub struct Variant {
    pub batch: usize,
    pub file: String,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
}

impl Variant {
    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    pub fn output_len(&self) -> usize {
        self.output_shape.iter().product()
    }
}

/// One zoo model with its batch-size ladder.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelArtifact {
    pub name: String,
    pub input_hwc: [usize; 3],
    pub param_count: usize,
    pub variants: Vec<Variant>,
    pub golden: Option<String>,
}

impl ModelArtifact {
    /// Smallest compiled batch >= `n` (the batcher pads up to it), falling
    /// back to the largest variant when `n` exceeds the ladder.
    pub fn variant_for(&self, n: usize) -> Option<&Variant> {
        self.variants
            .iter()
            .filter(|v| v.batch >= n)
            .min_by_key(|v| v.batch)
            .or_else(|| self.variants.iter().max_by_key(|v| v.batch))
    }

    pub fn max_batch(&self) -> usize {
        self.variants.iter().map(|v| v.batch).max().unwrap_or(0)
    }

    /// Per-request input element count (batch dimension stripped).
    pub fn input_elems_per_request(&self) -> usize {
        self.input_hwc.iter().product()
    }
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: Vec<ModelArtifact>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        if j.get("format").and_then(|f| f.as_str()) != Some("hlo-text") {
            bail!("manifest format is not 'hlo-text'");
        }
        let mut models = Vec::new();
        for m in j
            .get("models")
            .and_then(|m| m.as_arr())
            .ok_or_else(|| anyhow!("manifest missing 'models'"))?
        {
            let name = m
                .get("name")
                .and_then(|n| n.as_str())
                .ok_or_else(|| anyhow!("model missing 'name'"))?
                .to_string();
            let hwc = m
                .get("input_hwc")
                .and_then(|v| v.usizes())
                .ok_or_else(|| anyhow!("model {name}: bad input_hwc"))?;
            if hwc.len() != 3 {
                bail!("model {name}: input_hwc must have 3 dims");
            }
            let mut variants = Vec::new();
            for v in m
                .get("variants")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("model {name}: missing variants"))?
            {
                variants.push(Variant {
                    batch: v
                        .get("batch")
                        .and_then(|b| b.as_usize())
                        .ok_or_else(|| anyhow!("model {name}: variant missing batch"))?,
                    file: v
                        .get("file")
                        .and_then(|f| f.as_str())
                        .ok_or_else(|| anyhow!("model {name}: variant missing file"))?
                        .to_string(),
                    input_shape: v
                        .get("input_shape")
                        .and_then(|s| s.usizes())
                        .ok_or_else(|| anyhow!("model {name}: bad input_shape"))?,
                    output_shape: v
                        .get("output_shape")
                        .and_then(|s| s.usizes())
                        .ok_or_else(|| anyhow!("model {name}: bad output_shape"))?,
                });
            }
            variants.sort_by_key(|v| v.batch);
            models.push(ModelArtifact {
                name,
                input_hwc: [hwc[0], hwc[1], hwc[2]],
                param_count: m.get("param_count").and_then(|p| p.as_usize()).unwrap_or(0),
                variants,
                golden: m.get("golden").and_then(|g| g.as_str()).map(String::from),
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            models,
        })
    }

    pub fn model(&self, name: &str) -> Option<&ModelArtifact> {
        self.models.iter().find(|m| m.name == name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.models.iter().map(|m| m.name.as_str()).collect()
    }
}

/// Golden input/output pair produced by aot.py for numerics verification.
#[derive(Debug, Clone)]
pub struct Golden {
    pub model: String,
    pub batch: usize,
    pub input: Vec<f32>,
    pub output: Vec<f32>,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
}

impl Golden {
    pub fn load(dir: &Path, file: &str) -> Result<Golden> {
        let path = dir.join(file);
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading golden {}", path.display()))?;
        let j = Json::parse(&text).context("parsing golden json")?;
        let fetch = |k: &str| -> Result<Vec<f64>> {
            j.get(k)
                .and_then(|v| v.f64s())
                .ok_or_else(|| anyhow!("golden missing '{k}'"))
        };
        Ok(Golden {
            model: j
                .get("model")
                .and_then(|m| m.as_str())
                .unwrap_or_default()
                .to_string(),
            batch: j.get("batch").and_then(|b| b.as_usize()).unwrap_or(1),
            input: fetch("input")?.into_iter().map(|x| x as f32).collect(),
            output: fetch("output")?.into_iter().map(|x| x as f32).collect(),
            input_shape: j
                .get("input_shape")
                .and_then(|s| s.usizes())
                .ok_or_else(|| anyhow!("golden missing input_shape"))?,
            output_shape: j
                .get("output_shape")
                .and_then(|s| s.usizes())
                .ok_or_else(|| anyhow!("golden missing output_shape"))?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text",
      "models": [
        {"name": "alexnet", "input_hwc": [32, 32, 3], "param_count": 93754,
         "golden": "golden_alexnet.json",
         "variants": [
            {"batch": 1, "file": "alexnet_b1.hlo.txt",
             "input_shape": [1, 32, 32, 3], "output_shape": [1, 10]},
            {"batch": 8, "file": "alexnet_b8.hlo.txt",
             "input_shape": [8, 32, 32, 3], "output_shape": [8, 10]}
         ]}
      ]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert_eq!(m.names(), vec!["alexnet"]);
        let a = m.model("alexnet").unwrap();
        assert_eq!(a.param_count, 93754);
        assert_eq!(a.max_batch(), 8);
        assert_eq!(a.input_elems_per_request(), 32 * 32 * 3);
    }

    #[test]
    fn variant_selection_rounds_up() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        let a = m.model("alexnet").unwrap();
        assert_eq!(a.variant_for(1).unwrap().batch, 1);
        assert_eq!(a.variant_for(2).unwrap().batch, 8);
        assert_eq!(a.variant_for(8).unwrap().batch, 8);
        // beyond ladder -> largest
        assert_eq!(a.variant_for(100).unwrap().batch, 8);
    }

    #[test]
    fn rejects_bad_format() {
        let bad = SAMPLE.replace("hlo-text", "protobuf");
        assert!(Manifest::parse(Path::new("/tmp"), &bad).is_err());
    }

    #[test]
    fn variant_lengths() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        let v = m.model("alexnet").unwrap().variant_for(8).unwrap();
        assert_eq!(v.input_len(), 8 * 32 * 32 * 3);
        assert_eq!(v.output_len(), 80);
    }
}
