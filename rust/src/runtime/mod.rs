//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them on
//! the serving hot path (Layer 3).  See DESIGN.md §6.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, LoadedVariant};
pub use manifest::{Golden, Manifest, ModelArtifact, Variant};
