//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them on
//! the serving hot path (Layer 3).  See DESIGN.md §6.

pub mod engine;
pub mod manifest;
pub mod xla_stub;

pub use engine::{Engine, LoadedVariant};
pub use manifest::{Golden, Manifest, ModelArtifact, Variant};

/// Whether real PJRT execution is available.  False while the engine is
/// backed by [`xla_stub`]; artifact-driven tests and benches must check
/// this in addition to artifact presence, since compiled artifacts can
/// exist on a machine whose build still lacks the native bindings.
pub const PJRT_AVAILABLE: bool = xla_stub::AVAILABLE;
