//! Offline stand-in for the `xla` (PJRT) native bindings.
//!
//! The build environment has no XLA shared library and no network access,
//! so the real `xla` crate cannot be used.  This module mirrors the exact
//! API surface `engine.rs` consumes — client / HLO-text loading / compile /
//! execute — with the same shapes and error plumbing.  Loading HLO text and
//! "compiling" it succeed (the artifact pipeline and manifest contracts stay
//! exercisable end-to-end); only `execute` reports that real numerics are
//! unavailable.  Swapping this module for the real bindings is a one-line
//! change in `engine.rs` (see DESIGN.md §PJRT runtime).

use std::fmt;

/// False: this is the stub backend — `execute` cannot produce real
/// numerics.  Runtime-dependent tests/benches key off
/// [`crate::runtime::PJRT_AVAILABLE`] to skip instead of failing.
pub const AVAILABLE: bool = false;

/// Error type standing in for `xla::Error`.
#[derive(Debug)]
pub struct XlaError {
    msg: String,
}

impl XlaError {
    fn new(msg: impl Into<String>) -> XlaError {
        XlaError { msg: msg.into() }
    }

    fn unavailable(what: &str) -> XlaError {
        XlaError::new(format!(
            "{what}: XLA PJRT runtime is unavailable in this build \
             (native `xla` bindings are stubbed; see DESIGN.md)"
        ))
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for XlaError {}

type Result<T> = std::result::Result<T, XlaError>;

/// A host literal: flat f32 data plus a shape.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a flat f32 slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            data: data.to_vec(),
        }
    }

    /// Reinterpret the literal under new dimensions (element count must
    /// be preserved).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want != self.data.len() as i64 {
            return Err(XlaError::new(format!(
                "reshape: {} elements cannot view as {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(XlaError::unavailable("to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(XlaError::unavailable("to_vec"))
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO-text module (the stub keeps the raw text).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    /// Load an `.hlo.txt` artifact.  Mirrors the real parser's contract:
    /// the file must exist and look like an HLO module.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| XlaError::new(format!("reading HLO text {path}: {e}")))?;
        if !text.contains("HloModule") {
            return Err(XlaError::new(format!(
                "{path} does not look like HLO text (missing 'HloModule')"
            )));
        }
        Ok(HloModuleProto { text })
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    #[allow(dead_code)]
    text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            text: proto.text.clone(),
        }
    }
}

/// A "compiled" executable.  Executing it reports that the native runtime
/// is unavailable; everything up to that point behaves like the real thing.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    #[allow(dead_code)]
    text_len: usize,
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::unavailable("execute"))
    }
}

/// A device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError::unavailable("to_literal_sync"))
    }
}

/// The PJRT client.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable {
            text_len: comp.text.len(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shapes() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.dims(), &[6]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert_eq!(r.element_count(), 6);
        assert!(l.reshape(&[4, 4]).is_err());
    }

    #[test]
    fn compile_pipeline_up_to_execute() {
        let dir = std::env::temp_dir().join("igniter_xla_stub_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.hlo.txt");
        std::fs::write(&path, "HloModule m\nENTRY e { ROOT c = f32[] constant(0) }").unwrap();

        let proto = HloModuleProto::from_text_file(path.to_str().unwrap()).unwrap();
        let comp = XlaComputation::from_proto(&proto);
        let client = PjRtClient::cpu().unwrap();
        let exe = client.compile(&comp).unwrap();
        let lit = Literal::vec1(&[0.5f32]);
        let err = exe.execute::<Literal>(&[lit]).unwrap_err();
        assert!(err.to_string().contains("unavailable"), "{err}");
    }

    #[test]
    fn missing_or_malformed_files_error() {
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo.txt").is_err());
        let dir = std::env::temp_dir().join("igniter_xla_stub_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.txt");
        std::fs::write(&path, "not hlo").unwrap();
        assert!(HloModuleProto::from_text_file(path.to_str().unwrap()).is_err());
    }
}
